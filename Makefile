# fsa — build/verify entry points (see README.md quickstart).

.PHONY: verify build test doc artifacts artifacts-full serve bench-smoke bench-json clean

# Tier-1 verification: release build + tests + clean rustdoc.
verify:
	./verify.sh

# Every bench target at minimal iterations (FSA_BENCH_SMOKE shrinks
# sweeps/budgets), asserting exit 0.  Optional verify stage: VERIFY_BENCH=1.
BENCHES = ablation causal cycles decode fig1 fig11 fig12 hotpath longcontext multihead serving simcycles table2 table3
bench-smoke:
	@for b in $(BENCHES); do \
		echo "== cargo bench --bench $$b (smoke) =="; \
		FSA_BENCH_SMOKE=1 cargo bench --bench $$b || exit 1; \
	done

# Refresh the perf records: BENCH_simcycles.json (sim throughput),
# BENCH_serving.json (serving-path SLO trajectory), and
# BENCH_hotpath.json (cached-vs-uncached shard dispatch); see
# EXPERIMENTS.md §Perf log.  Honors FSA_BENCH_SMOKE=1 for a quick pass
# that still writes the JSON (flagged "smoke": true).
bench-json:
	cargo bench --bench simcycles
	cargo bench --bench serving
	cargo bench --bench hotpath

build:
	cargo build --release

test:
	cargo test -q

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Compile the JAX/Pallas AOT artifacts the PJRT backend serves
# (requires the python toolchain; the reference backend needs none).
artifacts:
	python3 python/compile/aot.py --out artifacts

artifacts-full:
	python3 python/compile/aot.py --out artifacts --full

# Boot the coordinator on the artifact-free reference backend.
serve:
	cargo run --release --bin fsa -- serve --backend reference \
		--heads 8 --kv-heads 2 --devices 2 --seq 128

clean:
	cargo clean
	rm -rf artifacts
