# fsa — build/verify entry points (see README.md quickstart).

.PHONY: verify build test doc artifacts artifacts-full serve clean

# Tier-1 verification: release build + tests + clean rustdoc.
verify:
	./verify.sh

build:
	cargo build --release

test:
	cargo test -q

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Compile the JAX/Pallas AOT artifacts the PJRT backend serves
# (requires the python toolchain; the reference backend needs none).
artifacts:
	python3 python/compile/aot.py --out artifacts

artifacts-full:
	python3 python/compile/aot.py --out artifacts --full

# Boot the coordinator on the artifact-free reference backend.
serve:
	cargo run --release --bin fsa -- serve --backend reference \
		--heads 8 --kv-heads 2 --devices 2 --seq 128

clean:
	cargo clean
	rm -rf artifacts
