//! DECODE-SESSION DRIVER: the decode-phase serving walkthrough
//! (DESIGN.md §5).
//!
//! Opens a session against the live coordinator (prefill), advances it
//! token by token (decode steps over the per-device paged KV cache),
//! and closes it — while a client-side mirror recomputes every step
//! statelessly over the full prefix and asserts the served output is
//! **bitwise identical**.  Then forces an eviction → recompute →
//! re-cache cycle with a second session on a deliberately tiny cache
//! and shows the modeled per-step cost of hits vs misses
//! (`perfmodel::fsa_decode_perf`: O(L) streamed bytes vs O(L²)
//! recompute cycles).
//!
//!     cargo run --release --example decode_loop -- \
//!         [--seq 256 --steps 48 --d 64 --heads 4 --kv-heads 2 \
//!          --kv-pages 48 --page-size 16]

use fsa::cli::Args;
use fsa::config::{AccelConfig, BackendKind, EvictionPolicy, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::numerics::reference::decode_pwl;
use fsa::numerics::SplitMix64;
use fsa::perfmodel::fsa_decode_perf;
use fsa::schedule::Variant;

fn main() -> fsa::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let seq = args.get("seq", 256usize)?;
    let steps = args.get("steps", 48usize)?;
    let d = args.get("d", 64usize)?;
    let heads = args.get("heads", 4usize)?;
    let kv_heads = args.get("kv-heads", 2usize)?;
    // Default capacity holds one session's two growing streams
    // (2 x ceil((256+48)/16) = 38 pages) but not two sessions — the
    // second prefill below forces the eviction cycle.
    let kv_pages = args.get("kv-pages", 48usize)?;
    let page_size = args.get("page-size", 16usize)?;
    let accel = AccelConfig::builtin("fsa")?;

    println!("== FSA decode-session driver ==");
    println!(
        "prefix L={seq}, {steps} decode steps, d={d}, {heads}q/{kv_heads}kv heads, \
         kv cache {kv_pages} x {page_size}-token pages/device"
    );

    let coord = Coordinator::start(RunConfig {
        devices: 1, // deterministic placement for the walkthrough
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        queue_depth: 256,
        artifacts_dir: args.flag("artifacts").unwrap_or("artifacts").to_string(),
        backend: BackendKind::Reference,
        num_heads: heads,
        num_kv_heads: kv_heads,
        kv_cache_pages: kv_pages,
        kv_page_size: page_size,
        kv_eviction: EvictionPolicy::Lru,
        ..RunConfig::default()
    })?;

    // Client-side mirror: full K/V history per KV head, for stateless
    // recomputation of every step.
    let mut rng = SplitMix64::new(42);
    let mut hist_k: Vec<Vec<f32>> = vec![Vec::new(); kv_heads];
    let mut hist_v: Vec<Vec<f32>> = vec![Vec::new(); kv_heads];
    let mut id = 0u64;
    let mut next_id = move || {
        id += 1;
        id
    };

    // -- prefill --
    let q = rng.normal_matrix(heads * seq, d);
    let k = rng.normal_matrix(kv_heads * seq, d);
    let v = rng.normal_matrix(kv_heads * seq, d);
    for h in 0..kv_heads {
        hist_k[h].extend_from_slice(&k[h * seq * d..(h + 1) * seq * d]);
        hist_v[h].extend_from_slice(&v[h * seq * d..(h + 1) * seq * d]);
    }
    let resp = coord.submit_wait(AttentionRequest::prefill(
        next_id(), 1, seq, d, heads, kv_heads, q, k, v,
    ))?;
    resp.output.map_err(|e| anyhow::anyhow!("prefill failed: {e}"))?;
    println!("session 1 prefilled: {} shards on device {:?}", resp.shards, resp.devices_used);

    // -- decode loop, verified bitwise against stateless recompute --
    let group = heads / kv_heads;
    let (mut hits, mut misses) = (0usize, 0usize);
    for step in 0..steps as u64 {
        let q = rng.normal_matrix(heads, d);
        let k = rng.normal_matrix(kv_heads, d);
        let v = rng.normal_matrix(kv_heads, d);
        for h in 0..kv_heads {
            hist_k[h].extend_from_slice(&k[h * d..(h + 1) * d]);
            hist_v[h].extend_from_slice(&v[h * d..(h + 1) * d]);
        }
        let resp = coord.submit_wait(AttentionRequest::decode(
            next_id(), 1, step, d, heads, kv_heads, q.clone(), k, v,
        ))?;
        let got = resp.output.map_err(|e| anyhow::anyhow!("step {step} failed: {e}"))?;
        // Stateless full-prefix recompute, same kernel, same tiling.
        for head in 0..heads {
            let kv = head / group;
            let want = decode_pwl(
                &q[head * d..(head + 1) * d],
                &hist_k[kv],
                &hist_v[kv],
                d,
                accel.array_size,
                accel.pwl_segments,
            );
            assert_eq!(
                &got[head * d..(head + 1) * d],
                &want[..],
                "step {step} head {head}: served decode diverged from stateless recompute"
            );
        }
        hits += resp.kv_hits;
        misses += resp.kv_misses;
    }
    println!(
        "{steps} steps verified bitwise against stateless recompute \
         ({hits} hit / {misses} miss shards)"
    );

    // -- forced eviction: a second session displaces the first --
    let seq2 = seq;
    let q = rng.normal_matrix(heads * seq2, d);
    let k = rng.normal_matrix(kv_heads * seq2, d);
    let v = rng.normal_matrix(kv_heads * seq2, d);
    coord
        .submit_wait(AttentionRequest::prefill(next_id(), 2, seq2, d, heads, kv_heads, q, k, v))?
        .output
        .map_err(|e| anyhow::anyhow!("second prefill failed: {e}"))?;

    let q = rng.normal_matrix(heads, d);
    let k = rng.normal_matrix(kv_heads, d);
    let v = rng.normal_matrix(kv_heads, d);
    for h in 0..kv_heads {
        hist_k[h].extend_from_slice(&k[h * d..(h + 1) * d]);
        hist_v[h].extend_from_slice(&v[h * d..(h + 1) * d]);
    }
    let resp = coord.submit_wait(AttentionRequest::decode(
        next_id(), 1, steps as u64, d, heads, kv_heads, q.clone(), k, v,
    ))?;
    let got = resp.output.map_err(|e| anyhow::anyhow!("post-eviction step failed: {e}"))?;
    for head in 0..heads {
        let kv = head / group;
        let want = decode_pwl(
            &q[head * d..(head + 1) * d], &hist_k[kv], &hist_v[kv],
            d, accel.array_size, accel.pwl_segments,
        );
        assert_eq!(&got[head * d..(head + 1) * d], &want[..], "post-eviction divergence");
    }
    println!(
        "post-eviction step: {} miss / {} hit shards — recompute fallback stayed \
         bitwise-exact and re-cached the stream",
        resp.kv_misses, resp.kv_hits
    );

    for sid in [1u64, 2] {
        coord.submit_wait(AttentionRequest::close(next_id(), sid))?;
    }

    // -- modeled per-step economics of the cache --
    let prefix = seq + steps + 1;
    let hit = fsa_decode_perf(&accel, prefix, d.min(accel.array_size), true, Variant::DualPath, accel.pwl_segments);
    let miss = fsa_decode_perf(&accel, prefix, d.min(accel.array_size), false, Variant::DualPath, accel.pwl_segments);
    println!("\n-- modeled decode step at prefix {prefix} (d={d}) --");
    println!(
        "cached:    {} cycles, {:.1} KiB streamed (O(L) per step)",
        hit.total_cycles,
        hit.bytes_streamed as f64 / 1024.0
    );
    println!(
        "recompute: {} cycles ({} of them rebuilding the prefix, O(L^2)) — {:.1}x a cached step",
        miss.total_cycles,
        miss.recompute_cycles,
        miss.total_cycles as f64 / hit.total_cycles as f64
    );
    println!("\ncoordinator metrics: {}", coord.metrics.summary());
    coord.shutdown();
    println!("\ndecode_loop OK");
    Ok(())
}
