//! Quickstart: one attention head through the full FSA stack.
//!
//! Builds the Listing-2 FlashAttention program with the kernel builder,
//! runs it on the cycle-accurate FSA device simulator, verifies the
//! output against the dense SDPA oracle, and checks the §3.5 timing
//! (5N+10 cycles per inner iteration).
//!
//!     cargo run --release --example quickstart [-- --n 16 --seq 64]

use fsa::cli::Args;
use fsa::kernel::flash::detranspose_output;
use fsa::kernel::{flash_attention_program, FlashLayout, FlashParams};
use fsa::numerics::reference::{mat_error, sdpa, Mat};
use fsa::numerics::SplitMix64;
use fsa::schedule::{fsa_total_cycles, Variant};
use fsa::sim::{Machine, MachineConfig};

fn main() -> fsa::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n = args.get("n", 16usize)?;
    let seq = args.get("seq", 64usize)?;

    println!("== FSA quickstart: {seq}-token head, d = {n}, {n}x{n} array ==\n");

    // 1. Author the kernel (paper §5 programming model).
    let params = FlashParams {
        seq_len: seq,
        d: n,
        spad_elems: (6 * n * n) as u32,
        accum_elems: (n * n + n) as u32,
    };
    let layout = FlashLayout::packed(&params);
    let program = flash_attention_program(&params, &layout)?;
    let (loads, stores, computes) = program.class_counts();
    println!(
        "compiled FlashAttention program: {} instructions ({loads} loads, \
         {stores} stores, {computes} compute)",
        program.len()
    );

    // 2. Generate a workload and run it on the cycle-accurate device.
    let mut cfg = MachineConfig::small(n);
    cfg.mem_elems = layout.mem_elems(&params).max(1 << 16);
    let mut machine = Machine::new(cfg);
    let mut rng = SplitMix64::new(7);
    let q = Mat::new(seq, n, rng.normal_matrix(seq, n));
    let k = Mat::new(seq, n, rng.normal_matrix(seq, n));
    let v = Mat::new(seq, n, rng.normal_matrix(seq, n));
    machine.write_mem(layout.q_addr, &q.data);
    machine.write_mem(layout.k_addr, &k.data);
    machine.write_mem(layout.v_addr, &v.data);

    let stats = machine.run_program(&program)?;
    println!(
        "simulated {} cycles, {} matmul MACs, FLOPs/s utilization {:.1}%",
        stats.cycles,
        stats.matmul_macs,
        100.0 * stats.utilization(n)
    );

    // 3. Verify numerics against the dense oracle.
    let out = detranspose_output(
        machine.read_mem(0, layout.mem_elems(&params)),
        &layout,
        &params,
    );
    let want = sdpa(&q, &k, &v);
    let err = mat_error(&Mat::new(seq, n, out), &want);
    println!(
        "vs dense SDPA: MAE {:.2e}, RMSE {:.2e}, max |err| {:.2e}",
        err.mae, err.rmse, err.max_abs
    );
    assert!(err.mae < 2e-2, "numerics out of the paper's error band");

    // 4. Cross-check the paper's closed-form timing.
    let formula = fsa_total_cycles(seq, n, Variant::DualPath, 8);
    println!(
        "closed-form §3.5 estimate: {formula} cycles (sim adds DMA epilogue; \
         inner loop is exactly 5N+10 = {})",
        5 * n + 10
    );
    println!("\nquickstart OK");
    Ok(())
}
