//! Accuracy study (Table-2 style, plus the PWL-segment ablation): runs
//! the cycle-accurate FSA device across sequence lengths and segment
//! counts and reports MAE/RMSE/MRE against the dense SDPA oracle.
//!
//!     cargo run --release --example accuracy_sweep [-- --n 16]

use fsa::benchutil::Table;
use fsa::cli::Args;
use fsa::experiments::{paper_input, sim_accuracy_row};
use fsa::kernel::flash::detranspose_output;
use fsa::kernel::{flash_attention_program, FlashLayout, FlashParams};
use fsa::numerics::reference::{mat_error, sdpa, Mat};
use fsa::numerics::SplitMix64;
use fsa::sim::{Machine, MachineConfig};

fn main() -> fsa::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n = args.get("n", 16usize)?;

    println!("== accuracy sweep on the cycle-accurate FSA device (d = {n}) ==\n");

    // Part 1: error vs sequence length (Table-2 shape at sim scale).
    let mut t = Table::new(&["seq", "MAE", "RMSE", "MRE"]);
    for mult in [2usize, 4, 8] {
        let seq = mult * n;
        let e = sim_accuracy_row(n, seq, 40 + mult as u64)?;
        t.row(&[
            seq.to_string(),
            format!("{:.3e}", e.mae),
            format!("{:.3e}", e.rmse),
            format!("{:.3e}", e.mre),
        ]);
    }
    println!("error vs sequence length (reference: dense fp32 SDPA):\n{}", t.to_string());

    // Part 2: error vs PWL segment count (the Fig-12 knob, end to end).
    let mut t2 = Table::new(&["segments", "MAE", "max|err|"]);
    let seq = 4 * n;
    for segments in [2usize, 4, 8, 16] {
        let p = FlashParams {
            seq_len: seq,
            d: n,
            spad_elems: (6 * n * n) as u32,
            accum_elems: (n * n + n) as u32,
        };
        let layout = FlashLayout::packed(&p);
        let prog = flash_attention_program(&p, &layout)?;
        let mut cfg = MachineConfig::small(n);
        cfg.segments = segments;
        cfg.mem_elems = layout.mem_elems(&p).max(1 << 16);
        let mut m = Machine::new(cfg);
        let mut rng = SplitMix64::new(99);
        let q = paper_input(&mut rng, seq, n);
        let k = paper_input(&mut rng, seq, n);
        let v = paper_input(&mut rng, seq, n);
        m.write_mem(layout.q_addr, &q.data);
        m.write_mem(layout.k_addr, &k.data);
        m.write_mem(layout.v_addr, &v.data);
        m.run_program(&prog)?;
        let out = detranspose_output(m.read_mem(0, layout.mem_elems(&p)), &layout, &p);
        let err = mat_error(&Mat::new(seq, n, out), &sdpa(&q, &k, &v));
        t2.row(&[
            segments.to_string(),
            format!("{:.3e}", err.mae),
            format!("{:.3e}", err.max_abs),
        ]);
    }
    println!("error vs PWL segments at seq = {seq} (paper uses 8):\n{}", t2.to_string());
    println!("accuracy_sweep OK");
    Ok(())
}
