//! Custom-kernel authoring with the §5 programming model.
//!
//! The paper's Python library lets users write their own FSA kernels;
//! this example does the same in Rust: a *windowed* attention kernel
//! (each query block attends only to its own and the previous KV block —
//! a sliding-window variant the paper's Listing 2 doesn't ship), built
//! with the typed-tile KernelBuilder, JIT-encoded to the binary ISA,
//! round-tripped through the decoder, and executed on the cycle-accurate
//! device.
//!
//!     cargo run --release --example custom_kernel

use fsa::isa::encode::{decode_program, encode_program};
use fsa::isa::{Space, TileDesc};
use fsa::kernel::builder::{ATile, Alloc, KernelBuilder, MTile, STile};
use fsa::numerics::reference::{flash_pwl, mat_error, Mat};
use fsa::numerics::SplitMix64;
use fsa::sim::{Machine, MachineConfig};

fn main() -> fsa::Result<()> {
    let n = 16usize; // array dim = head dim = tile size
    let blocks = 4usize; // sequence = 4 tiles
    let seq = n * blocks;
    let nn = n as u16;

    println!("== custom FSA kernel: sliding-window attention (window = 2 blocks) ==\n");

    // ---- Author the kernel with typed tiles ----
    let q_mem = MTile(TileDesc::contiguous(Space::Main, 0, seq as u16, nn));
    let k_mem = MTile(TileDesc::contiguous(Space::Main, (seq * n) as u32, seq as u16, nn));
    let v_mem = MTile(TileDesc::contiguous(Space::Main, (2 * seq * n) as u32, seq as u16, nn));
    let o_base = (3 * seq * n) as u32;

    let mut spad = Alloc::new(Space::Spad, (6 * n * n) as u32);
    let q_st = [STile(spad.tile(nn, nn)?), STile(spad.tile(nn, nn)?)];
    let k_st = [STile(spad.tile(nn, nn)?), STile(spad.tile(nn, nn)?)];
    let v_st = [STile(spad.tile(nn, nn)?), STile(spad.tile(nn, nn)?)];
    let mut accum = Alloc::new(Space::Accum, (n * n + n) as u32);
    let lse = ATile(accum.tile(1, nn)?);
    let ot = ATile(accum.tile(nn, nn)?);

    let q_blocks = q_mem.split_rows(nn);
    let k_blocks = k_mem.split_rows(nn);
    let v_blocks = v_mem.split_rows(nn);

    let mut b = KernelBuilder::new();
    for (i, q_i) in q_blocks.iter().enumerate() {
        b.load_tile(*q_i, q_st[i % 2])?;
        // Sliding window: only blocks j in [i-1, i].
        let window: Vec<usize> = (i.saturating_sub(1)..=i).collect();
        for (w, &j) in window.iter().enumerate() {
            b.load_stationary(q_st[i % 2]);
            b.load_tile(k_blocks[j], k_st[j % 2])?;
            b.attn_score(k_st[j % 2], lse, w == 0);
            b.load_tile(v_blocks[j], v_st[j % 2])?;
            b.attn_value(v_st[j % 2], ot, w == 0);
        }
        b.reciprocal(lse);
        b.attn_lse_norm(ot, lse);
        let o_dst = MTile(TileDesc::contiguous(Space::Main, o_base + (i * n * n) as u32, nn, nn));
        b.store_tile(ot, o_dst)?;
    }
    let program = b.build();
    println!("{} instructions; first rows of the listing:", program.len());
    for line in program.disasm().lines().take(6) {
        println!("  {line}");
    }

    // ---- JIT to the binary ISA and round-trip ----
    let words = encode_program(&program)?;
    println!("\nencoded to {} x u64 instruction words", words.len());
    assert_eq!(decode_program(&words)?, program, "binary round-trip");

    // ---- Execute on the cycle-accurate device ----
    let mut cfg = MachineConfig::small(n);
    cfg.mem_elems = (4 * seq * n).max(1 << 14);
    let mut m = Machine::new(cfg);
    let mut rng = SplitMix64::new(11);
    let q = Mat::new(seq, n, rng.normal_matrix(seq, n));
    let k = Mat::new(seq, n, rng.normal_matrix(seq, n));
    let v = Mat::new(seq, n, rng.normal_matrix(seq, n));
    m.write_mem(0, &q.data);
    m.write_mem((seq * n) as u32, &k.data);
    m.write_mem((2 * seq * n) as u32, &v.data);
    let stats = m.run_program(&program)?;
    println!(
        "ran in {} cycles, utilization {:.1}%",
        stats.cycles,
        100.0 * stats.utilization(n)
    );

    // ---- Verify block-by-block against the windowed reference ----
    let mut worst = 0.0f64;
    for i in 0..blocks {
        let lo = i.saturating_sub(1) * n;
        let hi = (i + 1) * n;
        let qw = Mat::new(n, n, q.data[i * n * n..(i + 1) * n * n].to_vec());
        let kw = Mat::new(hi - lo, n, k.data[lo * n..hi * n].to_vec());
        let vw = Mat::new(hi - lo, n, v.data[lo * n..hi * n].to_vec());
        let want = flash_pwl(&qw, &kw, &vw, n, n, 8);
        // Device output is O^T per block.
        let mut got = Mat::zeros(n, n);
        let base = o_base as usize + i * n * n;
        for h in 0..n {
            for mm in 0..n {
                got.set(mm, h, m.read_mem(0, cfg_mem_len(&m))[base + h * n + mm]);
            }
        }
        let err = mat_error(&got, &want);
        worst = worst.max(err.max_abs);
        assert!(err.max_abs < 1e-3, "block {i}: {err:?}");
    }
    println!("windowed outputs match the windowed flash_pwl oracle (worst |err| {worst:.2e})");
    println!("\ncustom_kernel OK");
    Ok(())
}

fn cfg_mem_len(m: &fsa::sim::Machine) -> usize {
    m.cfg.mem_elems
}
