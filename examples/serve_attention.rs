//! END-TO-END DRIVER (the repo's required full-stack validation).
//!
//! Boots the serving coordinator with a pool of simulated FSA devices,
//! submits a batch of mixed-length multi-head / grouped-query attention
//! requests — each sharded per query head and scattered across the pool
//! with KV-head affinity — and for every gathered response:
//!
//!   * numerics come from the device worker backend: the AOT Pallas
//!     artifact (`fsa_attn_*`, the device's software twin) executed via
//!     PJRT from Rust when artifacts are present, or the in-crate
//!     `flash_pwl` reference twin otherwise — Python is nowhere on
//!     this path;
//!   * timing comes from the validated FSA performance model (device
//!     cycles at the paper's 1.5 GHz clock), composed per head into
//!     whole-operator pool accounting;
//!   * outputs are verified head-by-head against the exact SDPA oracle.
//!
//! Reports throughput, latency percentiles, and the paper's headline
//! metric (whole-operator FLOPs/s utilization) for the served workload.
//! Results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example serve_attention -- \
//!         [--devices 2 --heads 8 --kv-heads 2 --mask none|causal]
//!         [--backend auto|reference|sim|pjrt]   (sim = the cycle-accurate
//!          machine, bitwise vs reference, measured-cycle pricing — slow at
//!          the default 128-array; see `fsa serve --array-size`)

use std::time::Instant;

use fsa::cli::Args;
use fsa::config::{AccelConfig, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::mask::MaskKind;
use fsa::numerics::reference::{mat_error, sdpa_masked, Mat};
use fsa::numerics::SplitMix64;
use fsa::perfmodel::multi_head_perf_masked;
use fsa::schedule::Variant;

fn main() -> fsa::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let devices = args.get("devices", 2usize)?;
    let per_bucket = args.get("per-bucket", 4usize)?;
    let heads = args.get("heads", 8usize)?;
    let kv_heads = args.get("kv-heads", 2usize)?;
    let artifacts = args.flag("artifacts").unwrap_or("artifacts").to_string();
    let mask: MaskKind = args.flag("mask").unwrap_or("none").parse()?;
    let d = 128usize;
    let buckets = args.get_list("buckets", &[128, 512])?;

    println!("== FSA end-to-end serving driver ==");
    println!(
        "devices={devices} buckets={buckets:?} heads={heads}/{kv_heads} mask={mask} requests={}",
        per_bucket * buckets.len()
    );

    let cfg = RunConfig {
        devices,
        max_batch: 4,
        batch_timeout_cycles: 100_000,
        queue_depth: 256,
        artifacts_dir: artifacts,
        backend: args.flag("backend").unwrap_or("auto").parse()?,
        num_heads: heads,
        num_kv_heads: kv_heads,
        mask,
        ..RunConfig::default()
    };
    let coord = Coordinator::start(cfg)?;

    // Build the workload: mixed sequence lengths, paper's §6.2.2 inputs,
    // GQA head layout (heads query heads sharing kv_heads K/V heads).
    let mut rng = SplitMix64::new(2026);
    let mut requests = Vec::new();
    for (i, &seq) in buckets.iter().enumerate() {
        for j in 0..per_bucket {
            let id = (i * per_bucket + j) as u64;
            requests.push(
                AttentionRequest::gqa(
                    id,
                    seq,
                    d,
                    heads,
                    kv_heads,
                    rng.spiky_matrix(heads * seq, d),
                    rng.spiky_matrix(kv_heads * seq, d),
                    rng.spiky_matrix(kv_heads * seq, d),
                )
                .with_mask(mask),
            );
        }
    }

    // Submit everything, then collect the gathered responses.
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for r in &requests {
        pending.push((r.clone(), coord.submit(r.clone())?));
    }
    let mut responses = Vec::new();
    for (req, rx) in pending {
        let resp = rx.recv().map_err(|_| anyhow::anyhow!("request {} dropped", req.id))?;
        responses.push((req, resp));
    }
    let wall = t0.elapsed();

    // Verify every head of every response against the exact SDPA oracle
    // (f64 accumulation; the paper's Table-2 error band applies).
    let mut worst = 0.0f64;
    let mut verified = 0usize;
    let mut scattered = 0usize;
    for (req, resp) in &responses {
        let out = resp
            .output
            .as_ref()
            .map_err(|e| anyhow::anyhow!("request {} failed: {e}", req.id))?;
        let head_elems = req.seq_len * d;
        for h in 0..heads {
            let (k, v) = req.head_kv(req.kv_head_for(h));
            let want = sdpa_masked(
                &Mat::new(req.seq_len, d, req.head_q(h).to_vec()),
                &Mat::new(req.seq_len, d, k.to_vec()),
                &Mat::new(req.seq_len, d, v.to_vec()),
                req.mask,
            );
            let got = Mat::new(
                req.seq_len,
                d,
                out[h * head_elems..(h + 1) * head_elems].to_vec(),
            );
            let err = mat_error(&got, &want);
            assert!(
                err.mae < 5e-2,
                "request {} head {h} diverged from reference: {err:?}",
                req.id
            );
            worst = worst.max(err.mae);
            verified += 1;
        }
        if resp.devices_used.len() > 1 {
            scattered += 1;
        }
    }
    // Scatter is load-dependent under concurrent traffic (the router
    // balances globally, not per request); the deterministic ≥2-device
    // guarantee for an idle pool is asserted in
    // rust/tests/coordinator_gqa.rs.
    if devices > 1 && kv_heads > 1 {
        println!(
            "{scattered}/{} responses gathered from more than one device",
            responses.len()
        );
    }

    // Headline metrics: whole-operator utilization, measured (gathered
    // responses) vs modeled (perfmodel composition).
    let fsa = AccelConfig::builtin("fsa")?;
    let total_flops: u64 = responses.iter().map(|(r, _)| r.flops()).sum();
    let total_device_cycles: u64 = responses.iter().map(|(_, r)| r.device_cycles).sum();
    let device_seconds = total_device_cycles as f64 / (fsa.freq_ghz * 1e9) / devices as f64;

    println!("\n-- results --");
    println!("served {} requests in {wall:.2?} host time", responses.len());
    println!("verified {verified} head outputs against exact SDPA (worst MAE {worst:.2e})");
    println!(
        "simulated device time: {:.3} ms across {devices} devices \
         ({total_device_cycles} cycles total)",
        device_seconds * 1e3
    );
    for &seq in &buckets {
        let model = multi_head_perf_masked(
            &fsa, seq, d, heads, kv_heads, devices, Variant::DualPath, fsa.pwl_segments, mask,
        );
        let measured: Vec<f64> = responses
            .iter()
            .filter(|(r, _)| r.seq_len == seq)
            .map(|(_, resp)| resp.utilization)
            .collect();
        let avg = measured.iter().sum::<f64>() / measured.len().max(1) as f64;
        println!(
            "L={seq}: whole-operator FLOPs/s utilization {:.1}% measured vs {:.1}% modeled \
             ({} heads on {} of {} devices, {} per busiest device)",
            100.0 * avg,
            100.0 * model.utilization,
            heads,
            model.devices_used,
            devices,
            model.rounds
        );
    }
    println!(
        "attention FLOPs served: {:.2} GFLOP (paper FSA single-array asymptote ~39%)",
        total_flops as f64 / 1e9
    );
    println!("coordinator metrics: {}", coord.metrics.summary());
    coord.shutdown();
    println!("\nserve_attention OK");
    Ok(())
}
