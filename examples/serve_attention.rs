//! END-TO-END DRIVER (the repo's required full-stack validation).
//!
//! Boots the serving coordinator with a pool of simulated FSA devices,
//! submits a batch of mixed-length single-head attention requests, and
//! for every response:
//!
//!   * numerics come from the AOT Pallas artifact (`fsa_attn_*`, the
//!     device's software twin) executed via PJRT from Rust — Python is
//!     nowhere on this path;
//!   * timing comes from the validated FSA performance model (device
//!     cycles at the paper's 1.5 GHz clock);
//!   * outputs are verified against the exact SDPA artifact.
//!
//! Reports throughput, latency percentiles, and the paper's headline
//! metric (FLOPs/s utilization) for the served workload.  Results are
//! recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example serve_attention

use std::time::Instant;

use fsa::cli::Args;
use fsa::config::{AccelConfig, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::numerics::reference::{mat_error, Mat};
use fsa::numerics::SplitMix64;
use fsa::runtime::Runtime;
use fsa::schedule::attention_flops;

fn main() -> fsa::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let devices = args.get("devices", 2usize)?;
    let per_bucket = args.get("per-bucket", 6usize)?;
    let artifacts = args.flag("artifacts").unwrap_or("artifacts").to_string();
    let d = 128usize;
    let buckets = args.get_list("buckets", &[128, 512, 2048])?;

    println!("== FSA end-to-end serving driver ==");
    println!("devices={devices} buckets={buckets:?} requests={}", per_bucket * buckets.len());

    let cfg = RunConfig {
        devices,
        max_batch: 4,
        batch_timeout_cycles: 100_000,
        queue_depth: 256,
        artifacts_dir: artifacts.clone(),
    };
    let coord = Coordinator::start(cfg)?;

    // Build the workload: mixed sequence lengths, paper's §6.2.2 inputs.
    let mut rng = SplitMix64::new(2026);
    let mut requests = Vec::new();
    for (i, &seq) in buckets.iter().enumerate() {
        for j in 0..per_bucket {
            let id = (i * per_bucket + j) as u64;
            requests.push(AttentionRequest::new(
                id,
                seq,
                d,
                rng.spiky_matrix(seq, d),
                rng.spiky_matrix(seq, d),
                rng.spiky_matrix(seq, d),
            ));
        }
    }

    // Submit everything, then collect.
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for r in &requests {
        pending.push((r.clone(), coord.submit(r.clone())?));
    }
    let mut responses = Vec::new();
    for (req, rx) in pending {
        let resp = rx.recv().map_err(|_| anyhow::anyhow!("request {} dropped", req.id))?;
        responses.push((req, resp));
    }
    let wall = t0.elapsed();

    // Verify numerics against the exact SDPA artifact (falling back to
    // the exact-exp2 flash twin where dense SDPA wasn't exported).
    let mut verifier = Runtime::new(std::path::Path::new(&artifacts))?;
    let mut worst = 0.0f64;
    let mut verified = 0usize;
    for (req, resp) in &responses {
        let out = resp
            .output
            .as_ref()
            .map_err(|e| anyhow::anyhow!("request {} failed: {e}", req.id))?;
        let ref_meta = verifier
            .manifest
            .best_for("sdpa", req.seq_len, d)
            .or_else(|| verifier.manifest.best_for("flash_exact", req.seq_len, d))
            .filter(|m| m.seq_len == req.seq_len)
            .map(|m| m.name.clone());
        if let Some(name) = ref_meta {
            let want = verifier.execute_attention(&name, &req.q, &req.k, &req.v)?;
            let err = mat_error(
                &Mat::new(req.seq_len, d, out.clone()),
                &Mat::new(req.seq_len, d, want),
            );
            assert!(
                err.mae < 5e-2,
                "request {} diverged from reference: {err:?}",
                req.id
            );
            worst = worst.max(err.mae);
            verified += 1;
        }
    }

    // Headline metrics.
    let fsa = AccelConfig::builtin("fsa")?;
    let total_flops: u64 = responses.iter().map(|(r, _)| attention_flops(r.seq_len, d)).sum();
    let total_device_cycles: u64 = responses.iter().map(|(_, r)| r.device_cycles).sum();
    let device_seconds = total_device_cycles as f64 / (fsa.freq_ghz * 1e9) / devices as f64;
    let utilization = total_flops as f64
        / (total_device_cycles as f64 * 2.0 * (fsa.array_size * fsa.array_size) as f64);

    println!("\n-- results --");
    println!("served {} requests in {wall:.2?} host time", responses.len());
    println!("verified {verified} against exact references (worst MAE {worst:.2e})");
    println!(
        "simulated device time: {:.3} ms across {devices} devices \
         ({total_device_cycles} cycles total)",
        device_seconds * 1e3
    );
    println!(
        "attention FLOPs served: {:.2} GFLOP -> simulated FLOPs/s utilization {:.1}% \
         (paper FSA asymptote ~39%)",
        total_flops as f64 / 1e9,
        100.0 * utilization
    );
    println!("coordinator metrics: {}", coord.metrics.summary());
    coord.shutdown();
    println!("\nserve_attention OK");
    Ok(())
}
