//! Offline API-compatible stub of the `xla` crate (DESIGN.md
//! §substitutions).
//!
//! The build image ships neither the `xla` Rust bindings nor the
//! `xla_extension` shared library, so this in-tree crate mirrors the
//! exact API surface `fsa::runtime` uses and fails at the *client
//! construction* step: [`PjRtClient::cpu`] returns an error, every
//! downstream type is unreachable at runtime but type-checks.  The
//! serving stack detects the failure and falls back to the in-crate
//! reference backend (`fsa::runtime::Backend::Reference`), so the full
//! request path still runs; swap this vendor entry for the real
//! bindings to light up PJRT execution of the AOT Pallas artifacts.

use std::fmt;

/// Stub error type (the real crate's `xla::Error` is also opaque here).
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT unavailable: in-tree xla stub (offline image has no xla_extension); \
         use the reference backend"
            .to_string(),
    ))
}

/// Element types used by the artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F16,
    F32,
    F64,
    S32,
    S64,
}

/// Host literal (stub: never holds data — no client can produce one).
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute over per-device argument lists; result is
    /// `[device][output]` buffers in the real crate.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client (stub: construction always fails, which is how the
/// serving stack discovers PJRT is absent).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("stub"));
    }

    #[test]
    fn literal_pipeline_fails_cleanly() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.convert(PrimitiveType::F16).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
