//! Offline stand-in for the `anyhow` crate (DESIGN.md §substitutions).
//!
//! The build environment has no crates.io access, so this in-tree crate
//! provides the subset of anyhow's API the repo uses with the same
//! semantics:
//!
//! * [`Error`]: an opaque error carrying a context chain.  `Display`
//!   shows the outermost message; `{:#}` (alternate) shows the whole
//!   chain joined by `": "`, exactly like anyhow.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! * [`Context`] for adding context to `Result<T, E>` — including
//!   results that already carry an [`Error`].
//! * A blanket `From<E: std::error::Error>` so `?` converts foreign
//!   errors (IO, parse, ...) and captures their source chain.
//!
//! Not implemented (unused in this repo): downcasting, backtraces.

use std::fmt;

/// `Result<T, anyhow::Error>` alias, with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Opaque error: a chain of messages, outermost context first.
pub struct Error {
    /// `chain[0]` is the outermost message (what `Display` shows);
    /// later entries are the causes, innermost last.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (the `Context` entry point).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause messages, outermost first (anyhow's `chain()` analogue,
    /// as strings).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `?` conversion from any std error, capturing its source chain.  The
// same blanket-vs-reflexive shape as real anyhow: valid because `Error`
// itself does not implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension for `Result`.
///
/// Implemented over `E: Into<Error>` so it covers both foreign error
/// types and results that already hold an [`Error`] — one blanket impl
/// instead of anyhow's sealed-trait pair.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => { return Err($crate::anyhow!($($tt)*).into()) };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))).into());
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($tt)*).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ctx(s: &str) -> Result<u32> {
        let n: u32 = s.parse().context("parsing test integer")?;
        Ok(n)
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = parse_ctx("nope").unwrap_err();
        assert_eq!(e.to_string(), "parsing test integer");
        let full = format!("{e:#}");
        assert!(full.starts_with("parsing test integer: "), "{full}");
        assert!(full.contains("invalid digit"), "{full}");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        let owned: Error = anyhow!(String::from("owned message"));
        assert_eq!(owned.to_string(), "owned message");
    }

    #[test]
    fn question_mark_from_io_error() {
        fn f() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        let e = f().unwrap_err();
        assert!(!e.to_string().is_empty());
    }
}
