//! Tiny CLI argument parser (no clap offline): `fsa <command> [--flag
//! value | --flag=value | --switch] [positionals...]`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> crate::Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut args = Args { command, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(body.to_string(), v);
                } else {
                    args.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                args.positionals.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> crate::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> crate::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} {v:?}: {e}")),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str, default: &[usize]) -> crate::Result<Vec<usize>> {
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().map_err(|e| anyhow!("--{name} {s:?}: {e}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = parse("fig11 --seqs 2048,4096 --d=128 extra --verbose");
        assert_eq!(a.command, "fig11");
        assert_eq!(a.flag("seqs"), Some("2048,4096"));
        assert_eq!(a.get::<usize>("d", 0).unwrap(), 128);
        assert!(a.switch("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
        assert_eq!(a.get_list("seqs", &[]).unwrap(), vec![2048, 4096]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("table3");
        assert_eq!(a.get::<usize>("n", 128).unwrap(), 128);
        assert!(!a.switch("verbose"));
        assert_eq!(a.get_list("seqs", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn bad_values_error() {
        let a = parse("x --n nope");
        assert!(a.get::<usize>("n", 1).is_err());
        assert!(Args::parse(vec!["c".into(), "--".into()]).is_err());
    }

    #[test]
    fn empty_argv_is_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "help");
    }
}
