//! Near-memory accumulator + accumulation SRAM (paper §2.2 & §3.4).
//!
//! Sits at the bottom edge of the array.  For each column m it receives,
//! per inner iteration:
//!
//! 1. `a[m] = old_m - new_m` (from the CMP row, §3.5 "fifth operation") —
//!    it forms the rescale factor `b[m] = exp2(scale * a)` with its own
//!    Split+PWL block (hardware assumption documented in DESIGN.md §3);
//! 2. the rowsum `local_l[m]` — applies `l = l * b + local_l`;
//! 3. d PV partial sums `local_O[m, h]` in h order — applies
//!    `O[h][m] = O[h][m] * b + local_O` (the diag(b) rescale, exactly
//!    once per element per iteration).
//!
//! The accumulation SRAM stores O transposed (`[d][Br]`, Listing 2's
//! `Ot`) plus the l / lse vectors, element-addressed f32.

use crate::numerics::pwl::PwlExp2;
use crate::sim::array::BottomOut;

/// Accumulator + accumulation SRAM for an N x N array.
pub struct Accumulator {
    pub n: usize,
    /// exp2 scale = log2(e) / sqrt(d).
    pub scale: f32,
    /// Evaluate the rescale factor b on the fp16 PWL datapath.
    pub f16_mode: bool,
    pwl: PwlExp2,
    /// Accumulation SRAM, element-addressed f32.
    pub sram: Vec<f32>,

    // Per-column iteration state:
    b: Vec<f32>,
    /// Per-column count of PV arrivals this iteration (recovers h).
    pv_seen: Vec<u16>,
    /// Whether the diag(b) rescale applies (false on `first` iterations,
    /// where old state must be ignored — b is forced to 0).
    first: bool,

    /// Current bindings: where l and O^T live in the accumulation SRAM.
    l_addr: u32,
    o_addr: u32,
    o_stride: u32,
}

impl Accumulator {
    pub fn new(n: usize, segments: usize, scale: f32, sram_elems: usize) -> Accumulator {
        Accumulator {
            n,
            scale,
            f16_mode: false,
            pwl: PwlExp2::new(segments),
            sram: vec![0.0; sram_elems],
            b: vec![0.0; n],
            pv_seen: vec![0; n],
            first: true,
            l_addr: 0,
            o_addr: 0,
            o_stride: n as u32,
        }
    }

    /// Reset to the just-constructed state for machine reuse across
    /// shards (the shard-batching hazard fence, DESIGN.md §8): zero the
    /// accumulation SRAM and per-iteration state, rebind the softmax
    /// scale of the next shard.
    pub fn reset(&mut self, scale: f32) {
        self.scale = scale;
        self.sram.fill(0.0);
        self.b.fill(0.0);
        self.pv_seen.fill(0);
        self.first = true;
        self.l_addr = 0;
        self.o_addr = 0;
        self.o_stride = self.n as u32;
    }

    /// Bind the accumulation targets for the current inner iteration and
    /// reset per-iteration state.  `first` marks j == 0 of Algorithm 1.
    pub fn begin_iteration(&mut self, l_addr: u32, o_addr: u32, o_stride: u32, first: bool) {
        self.l_addr = l_addr;
        self.o_addr = o_addr;
        self.o_stride = o_stride;
        self.first = first;
        self.pv_seen.iter_mut().for_each(|c| *c = 0);
        // b defaults to 1 until the AVal arrives (it always arrives before
        // the rowsum in a legal schedule; the assert below enforces it).
        self.b.iter_mut().for_each(|v| *v = f32::NAN);
    }

    /// Consume one bottom-edge event from the array.
    pub fn accept(&mut self, out: BottomOut, cycle: u64) {
        match out {
            BottomOut::AVal { col, val } => {
                let b = if self.first {
                    0.0 // no previous state: diag(b)*old contributes nothing
                } else if self.f16_mode {
                    self.pwl.eval_f16_mac(self.scale * val)
                } else {
                    self.pwl.eval_f32(self.scale * val)
                };
                self.b[col] = b;
            }
            BottomOut::RowSum { col, val } => {
                let b = self.b[col];
                assert!(
                    !b.is_nan(),
                    "rowsum for col {col} arrived before its a-value (cycle {cycle})"
                );
                let addr = self.l_addr as usize + col;
                self.sram[addr] = self.sram[addr] * b + val;
            }
            BottomOut::Pv { col, val } => {
                let b = self.b[col];
                assert!(
                    !b.is_nan(),
                    "PV psum for col {col} arrived before its a-value (cycle {cycle})"
                );
                let h = self.pv_seen[col] as usize;
                self.pv_seen[col] += 1;
                assert!(h < self.n, "too many PV arrivals for col {col}");
                let addr = self.o_addr as usize + h * self.o_stride as usize + col;
                self.sram[addr] = self.sram[addr] * b + val;
            }
        }
    }

    /// Reciprocal instruction: l <- 1/l over an N-vector (outer loop).
    ///
    /// `1/0` is flushed to 0: an exactly-zero exponent sum means the §8
    /// mask wave zeroed every lane of the column (a fully-masked query
    /// row, or a zero-padded garbage column), and the defined output for
    /// such a row is zero (`FlashPartial::finalize`'s rule) — an `inf`
    /// here would poison the reused accumulator tile through the next
    /// row block's `b = 0` reset (`0 · inf = NaN`).  Live columns always
    /// have `l >= exp2(0) = 1` for their max lane, so this never
    /// triggers on real data.
    pub fn reciprocal(&mut self, l_addr: u32, len: usize) {
        for i in 0..len {
            let a = l_addr as usize + i;
            self.sram[a] = if self.sram[a] == 0.0 { 0.0 } else { 1.0 / self.sram[a] };
        }
    }

    /// AttnLseNorm: scale O^T[h][m] by l[m] (the reciprocal already
    /// applied in place by [`Self::reciprocal`]).
    pub fn lse_norm(&mut self, o_addr: u32, o_stride: u32, rows: usize, l_addr: u32) {
        for h in 0..rows {
            for m in 0..self.n {
                let oa = o_addr as usize + h * o_stride as usize + m;
                let la = l_addr as usize + m;
                self.sram[oa] *= self.sram[la];
            }
        }
    }

    /// Zero a region (fresh output allocation).
    pub fn clear(&mut self, addr: u32, elems: usize) {
        for i in 0..elems {
            self.sram[addr as usize + i] = 0.0;
        }
    }

    pub fn read(&self, addr: u32, len: usize) -> &[f32] {
        &self.sram[addr as usize..addr as usize + len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_iteration_ignores_old_state() {
        let mut acc = Accumulator::new(4, 8, 1.0, 64);
        // Poison old state; first=true must zero it via b=0.
        acc.sram[0..4].copy_from_slice(&[9.0, 9.0, 9.0, 9.0]);
        acc.begin_iteration(0, 16, 4, true);
        for col in 0..4 {
            acc.accept(BottomOut::AVal { col, val: -1e30 }, 0);
            acc.accept(BottomOut::RowSum { col, val: 2.0 }, 1);
        }
        assert_eq!(acc.read(0, 4), &[2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn rescale_applies_exactly_once_per_element() {
        let mut acc = Accumulator::new(2, 8, 1.0, 64);
        acc.begin_iteration(0, 8, 2, true);
        for col in 0..2 {
            acc.accept(BottomOut::AVal { col, val: 0.0 }, 0);
            acc.accept(BottomOut::RowSum { col, val: 1.0 }, 1);
            for _h in 0..2 {
                acc.accept(BottomOut::Pv { col, val: 3.0 }, 2);
            }
        }
        assert_eq!(acc.read(8, 4), &[3.0; 4]);
        // Second iteration with a = -1 -> b = exp2(-1) = 0.5.
        acc.begin_iteration(0, 8, 2, false);
        for col in 0..2 {
            acc.accept(BottomOut::AVal { col, val: -1.0 }, 3);
            acc.accept(BottomOut::RowSum { col, val: 1.0 }, 4);
            for _h in 0..2 {
                acc.accept(BottomOut::Pv { col, val: 1.0 }, 5);
            }
        }
        // O = 3 * 0.5 + 1 = 2.5 everywhere; l = 1 * 0.5 + 1 = 1.5.
        assert_eq!(acc.read(8, 4), &[2.5; 4]);
        assert_eq!(acc.read(0, 2), &[1.5; 2]);
    }

    #[test]
    #[should_panic(expected = "before its a-value")]
    fn rowsum_before_a_is_illegal() {
        let mut acc = Accumulator::new(2, 8, 1.0, 16);
        acc.begin_iteration(0, 4, 2, false);
        acc.accept(BottomOut::RowSum { col: 0, val: 1.0 }, 0);
    }

    #[test]
    fn reciprocal_and_norm() {
        let mut acc = Accumulator::new(2, 8, 1.0, 16);
        acc.sram[0] = 2.0;
        acc.sram[1] = 4.0;
        acc.sram[4..8].copy_from_slice(&[2.0, 4.0, 6.0, 8.0]);
        acc.reciprocal(0, 2);
        acc.lse_norm(4, 2, 2, 0);
        assert_eq!(acc.read(4, 4), &[1.0, 1.0, 3.0, 2.0]);
    }

    #[test]
    fn reciprocal_of_zero_is_the_defined_zero() {
        // §8: a fully-masked column's exponent sum is exactly 0; the
        // reciprocal flushes 1/0 to 0 so the norm yields the defined
        // zero output instead of inf (which would NaN-poison the reused
        // tile through the next block's b = 0 reset).
        let mut acc = Accumulator::new(2, 8, 1.0, 16);
        acc.sram[0] = 0.0;
        acc.sram[1] = 4.0;
        acc.sram[4..8].copy_from_slice(&[0.0, 4.0, 0.0, 8.0]);
        acc.reciprocal(0, 2);
        assert_eq!(acc.read(0, 2), &[0.0, 0.25]);
        acc.lse_norm(4, 2, 2, 0);
        assert_eq!(acc.read(4, 4), &[0.0, 1.0, 0.0, 2.0]);
    }
}
