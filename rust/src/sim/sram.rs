//! Scratchpad SRAM model.
//!
//! Element-addressed f32 backing store (activations are fp16 on the wire;
//! the quantization happens at array injection, so the scratchpad keeps
//! f32 payloads with fp16-rounded values written by the DMA).  Tracks a
//! ready-generation per region so the machine can scoreboard compute
//! instructions against outstanding DMA loads (§4.1: "the systolic array
//! controller issues compute instructions once the required data has been
//! loaded into SRAM").

use crate::isa::TileDesc;

pub struct Sram {
    pub data: Vec<f32>,
    /// Monotonic completion cycle per element region, coarse-grained to
    /// `GRAIN`-element lines to stay cheap.
    ready_at: Vec<u64>,
}

const GRAIN: usize = 64;

impl Sram {
    pub fn new(elems: usize) -> Sram {
        Sram { data: vec![0.0; elems], ready_at: vec![0; elems.div_ceil(GRAIN)] }
    }

    pub fn capacity(&self) -> usize {
        self.data.len()
    }

    /// Reset to the just-constructed state for machine reuse across
    /// shards (the shard-batching hazard fence): zero the data *and* the
    /// readiness scoreboard — a stale ready cycle from a previous
    /// program would delay (and so change) the next program's schedule
    /// relative to a fresh machine.
    pub fn reset(&mut self) {
        self.data.fill(0.0);
        self.ready_at.fill(0);
    }

    /// Record that `tile` becomes valid at `cycle` (DMA completion).
    pub fn mark_ready(&mut self, tile: &TileDesc, cycle: u64) {
        let (lo, hi) = (tile.addr as usize, tile.end_addr() as usize);
        for line in (lo / GRAIN)..=((hi.max(1) - 1) / GRAIN).min(self.ready_at.len() - 1) {
            self.ready_at[line] = self.ready_at[line].max(cycle);
        }
    }

    /// Earliest cycle at which every element of `tile` is valid.
    pub fn ready_cycle(&self, tile: &TileDesc) -> u64 {
        let (lo, hi) = (tile.addr as usize, tile.end_addr() as usize);
        let mut r = 0;
        for line in (lo / GRAIN)..=((hi.max(1) - 1) / GRAIN).min(self.ready_at.len() - 1) {
            r = r.max(self.ready_at[line]);
        }
        r
    }

    /// Read tile element (r, c).
    #[inline]
    pub fn at(&self, tile: &TileDesc, r: usize, c: usize) -> f32 {
        self.data[tile.addr as usize + r * tile.stride as usize + c]
    }

    /// Write tile element (r, c).
    #[inline]
    pub fn set(&mut self, tile: &TileDesc, r: usize, c: usize, v: f32) {
        self.data[tile.addr as usize + r * tile.stride as usize + c] = v;
    }

    pub fn write_tile(&mut self, tile: &TileDesc, rowmajor: &[f32]) {
        assert_eq!(rowmajor.len(), tile.elems(), "payload/tile shape mismatch");
        for r in 0..tile.rows as usize {
            for c in 0..tile.cols as usize {
                self.set(tile, r, c, rowmajor[r * tile.cols as usize + c]);
            }
        }
    }

    pub fn read_tile(&self, tile: &TileDesc) -> Vec<f32> {
        let mut out = Vec::with_capacity(tile.elems());
        for r in 0..tile.rows as usize {
            for c in 0..tile.cols as usize {
                out.push(self.at(tile, r, c));
            }
        }
        out
    }
}

/// Double-buffer allocator helper: carves a scratchpad into named
/// ping-pong tile pairs (the Listing-2 `K_STiles = (alloc, alloc)`
/// pattern) and fails loudly when capacity is exceeded — reproducing the
/// paper's point that 192 KiB suffices for double-buffered FlashAttention.
pub struct SpadAllocator {
    next: u32,
    capacity: u32,
}

impl SpadAllocator {
    pub fn new(capacity_elems: u32) -> SpadAllocator {
        SpadAllocator { next: 0, capacity: capacity_elems }
    }

    pub fn alloc(&mut self, rows: u16, cols: u16) -> crate::Result<TileDesc> {
        let elems = rows as u32 * cols as u32;
        anyhow::ensure!(
            self.next + elems <= self.capacity,
            "scratchpad exhausted: need {elems} elems at offset {}, capacity {}",
            self.next,
            self.capacity
        );
        let t = TileDesc::contiguous(crate::isa::Space::Spad, self.next, rows, cols);
        self.next += elems;
        Ok(t)
    }

    pub fn alloc_pair(&mut self, rows: u16, cols: u16) -> crate::Result<[TileDesc; 2]> {
        Ok([self.alloc(rows, cols)?, self.alloc(rows, cols)?])
    }

    pub fn used(&self) -> u32 {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Space;

    #[test]
    fn tile_read_write_with_stride() {
        let mut s = Sram::new(256);
        let t = TileDesc { space: Space::Spad, addr: 10, rows: 3, cols: 4, stride: 8 };
        let payload: Vec<f32> = (0..12).map(|x| x as f32).collect();
        s.write_tile(&t, &payload);
        assert_eq!(s.read_tile(&t), payload);
        assert_eq!(s.at(&t, 2, 3), 11.0);
        // Strided rows don't clobber the gap.
        assert_eq!(s.data[10 + 4], 0.0);
    }

    #[test]
    fn readiness_scoreboard() {
        let mut s = Sram::new(1024);
        let t = TileDesc::contiguous(Space::Spad, 128, 4, 32);
        assert_eq!(s.ready_cycle(&t), 0);
        s.mark_ready(&t, 500);
        assert_eq!(s.ready_cycle(&t), 500);
        // Overlapping tile sees the same readiness; disjoint one doesn't.
        let t2 = TileDesc::contiguous(Space::Spad, 192, 2, 16);
        assert_eq!(s.ready_cycle(&t2), 500);
        let t3 = TileDesc::contiguous(Space::Spad, 512, 2, 16);
        assert_eq!(s.ready_cycle(&t3), 0);
    }

    #[test]
    fn allocator_double_buffers_and_overflows() {
        // Paper footnote: 192 KiB = 96 Ki f16 elements... we model elems
        // directly; 3 double-buffered 128x128 tiles fit exactly in 96 Ki.
        let mut a = SpadAllocator::new(96 * 1024);
        let _q = a.alloc_pair(128, 128).unwrap();
        let _k = a.alloc_pair(128, 128).unwrap();
        let _v = a.alloc_pair(128, 128).unwrap();
        assert_eq!(a.used(), 6 * 128 * 128);
        assert!(a.alloc(128, 128).is_err());
    }
}
