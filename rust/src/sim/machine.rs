//! The whole FSA device: memories, DMA queues, instruction sequencing and
//! the cycle-accurate execution loop (paper Fig. 8).
//!
//! `run_program` works in two phases, mirroring the hardware's split
//! between asynchronous instruction issue (§4.1) and fully deterministic
//! compute execution (§4.2):
//!
//! 1. **Schedule**: walk the program in order, resolving issue cycles —
//!    DMA latencies from the bandwidth model, compute chaining at the
//!    SystolicAttention initiation interval (5N+10), stationary preloads
//!    overlapped into the previous iteration's drain window, scoreboarded
//!    against SRAM readiness.  This produces one combined absolute-cycle
//!    control-signal stream (the §4.3 dual-FSM + combiner).
//! 2. **Execute**: step the array cycle by cycle, applying edge signals
//!    and routing bottom-edge values into the accumulator.  Numerics and
//!    port-legality are checked *here*, by actual dataflow.

use std::sync::Arc;

use anyhow::{bail, ensure, Context};

use crate::config::AccelConfig;
use crate::isa::{Instruction, LaneBound, Program, Space, TileDesc};
use crate::mask::MaskKind;
use crate::numerics::f16::quantize_ftz_f32 as quantize_f32;
use crate::numerics::LOG2E;
use crate::schedule::{masked_tile_counts, InnerSchedule, Variant};
use crate::sim::accumulator::Accumulator;
use crate::sim::array::{Array, LeftTag};
use crate::sim::controller::{self, Signal};
use crate::sim::dma::{DmaConfig, DmaQueue};
use crate::sim::sram::Sram;

#[derive(Clone, Debug)]
pub struct MachineConfig {
    /// Array dimension N (= Br = Bc, §3.5 tiling).
    pub n: usize,
    /// Head dim of the softmax scale `log2(e)/sqrt(d)`.  Equal to `n`
    /// on the paper's native tiling; smaller when the serving backend
    /// zero-pads a `d < N` head up to the array (DESIGN.md §8) — the
    /// padded lanes contribute exact zeros, but the scale must stay the
    /// real head's.
    pub scale_dim: usize,
    pub segments: usize,
    pub variant: Variant,
    /// Quantize activations through fp16 (Table-1 numerics) or keep f32.
    pub quantize: bool,
    pub mem_elems: usize,
    pub spad_elems: usize,
    pub accum_elems: usize,
    pub dma: DmaConfig,
    /// Step the array with the frozen pre-refactor per-lane path instead
    /// of the vectorized one ([`Array::scalar_reference_step`]) — the
    /// differential harness and the old-vs-new bench sweep set this; it
    /// must never change results or cycle counts.
    pub scalar_reference: bool,
}

impl MachineConfig {
    /// A small device for tests: N x N array, generous memories.
    pub fn small(n: usize) -> MachineConfig {
        MachineConfig {
            n,
            scale_dim: n,
            segments: 8,
            variant: Variant::DualPath,
            quantize: true,
            mem_elems: 1 << 22,
            spad_elems: 1 << 18,
            accum_elems: 1 << 16,
            dma: DmaConfig::for_bandwidth(820.0, 1.5, 4),
            scalar_reference: false,
        }
    }

    /// The paper's FSA configuration (128 x 128).
    pub fn paper() -> MachineConfig {
        let mut c = MachineConfig::small(128);
        c.mem_elems = 1 << 26;
        c
    }

    /// A machine mirroring an [`AccelConfig`]: same array dim, PWL
    /// segment count, and DMA bandwidth at the configured clock — the
    /// config the serving backend and the perfmodel cross-validation
    /// (DESIGN.md §8) build from.  Memory sizes default to the 6-tile
    /// scratchpad / lse+O^T accumulator budget; callers grow
    /// `mem_elems` to their workload.
    pub fn from_accel(cfg: &AccelConfig) -> MachineConfig {
        let n = cfg.array_size;
        MachineConfig {
            n,
            scale_dim: n,
            segments: cfg.pwl_segments.max(1),
            variant: Variant::DualPath,
            quantize: true,
            mem_elems: 1 << 16,
            spad_elems: 6 * n * n,
            accum_elems: n * n + n,
            dma: DmaConfig::for_bandwidth(cfg.mem_bw_gbs, cfg.freq_ghz, 4),
            scalar_reference: false,
        }
    }
}

/// Per-instruction-class cycle attribution of one program run
/// (DESIGN.md §9): *where* the measured cycles went.  Constructed so
/// the classes sum **exactly** to [`RunStats::cycles`]: the compute
/// classes partition `compute_busy` (each inner interval decomposes as
/// QK^T score + exp window + rowsum + PV remainder, per §3.5), `stall`
/// is the compute-timeline idle gap (scoreboard waits on SRAM
/// readiness, WAR hazards, standalone stationary preloads), and `dma`
/// is the tail where a DMA queue outlives the compute stream.
/// `total() == cycles` is debug-asserted per run and pinned e2e by
/// `rust/tests/coordinator_sim.rs`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CycleBreakdown {
    /// QK^T score MACs (2N of each inner interval).
    pub score: u64,
    /// Subtract-max + PWL exp2 window (N + 2 + segments per interval).
    pub exp: u64,
    /// Row-sum accumulation (N per interval) plus the `1/l` reciprocal.
    pub rowsum: u64,
    /// PV (attention-value) MACs — the interval remainder — plus the
    /// LSE output normalization.
    pub pv: u64,
    /// §6 mask-wave cycles (one per masked score iteration).
    pub mask_wave: u64,
    /// DMA tail beyond the last compute cycle (loads/stores that
    /// outlive the compute stream; overlapped DMA is hidden under the
    /// compute classes, as on the device).
    pub dma: u64,
    /// Compute-timeline idle: hazard/scoreboard stalls and stationary
    /// preload occupancy.
    pub stall: u64,
    /// Modeled recompute charge added by the serving layer on decode
    /// cache misses (never produced by the machine itself).
    pub recompute: u64,
}

impl CycleBreakdown {
    /// Sum of every class — equals the measured total cycles by
    /// construction.
    pub fn total(&self) -> u64 {
        self.score
            + self.exp
            + self.rowsum
            + self.pv
            + self.mask_wave
            + self.dma
            + self.stall
            + self.recompute
    }

    /// Accumulate another breakdown (shard batching in the sim backend,
    /// shard→response rollup at gather).
    pub fn add(&mut self, other: &CycleBreakdown) {
        self.score += other.score;
        self.exp += other.exp;
        self.rowsum += other.rowsum;
        self.pv += other.pv;
        self.mask_wave += other.mask_wave;
        self.dma += other.dma;
        self.stall += other.stall;
        self.recompute += other.recompute;
    }
}

/// Timing + utilization results of one program run.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunStats {
    pub cycles: u64,
    /// MACs spent in the two matmuls (useful FLOPs = 2x this).
    pub matmul_macs: u64,
    /// All PE operations including the elementwise softmax chain.
    pub total_pe_ops: u64,
    pub dma_load_busy: u64,
    pub dma_store_busy: u64,
    pub compute_busy: u64,
    pub instructions: usize,
    /// Exact-sum cycle attribution (`breakdown.total() == cycles`).
    pub breakdown: CycleBreakdown,
}

impl RunStats {
    /// FLOPs/s utilization vs the 2N^2/cycle peak (paper §6.1 metric).
    ///
    /// Note the numerator is the *measured* MAC counter, which counts
    /// every streamed lane — masked lanes of a partially-masked tile
    /// stream through the array like any other, so on masked programs
    /// this overstates useful work; use [`RunStats::masked_utilization`]
    /// there.
    pub fn utilization(&self, n: usize) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.matmul_macs as f64 / ((n * n) as f64 * self.cycles as f64)
    }

    /// Mask-aware utilization (DESIGN.md §8): achieved cycles vs the
    /// tile work the tile-skipping schedule actually *issues* — the
    /// `full + partial` census of [`masked_tile_counts`] at `2·N³` MACs
    /// per issued tile — instead of assuming the full square grid or
    /// trusting the streamed-MAC counter (which counts masked lanes as
    /// work).  With `MaskKind::None` and exact tiling this equals
    /// [`RunStats::utilization`] bit for bit (the census and the
    /// counter agree); under a causal mask it credits only the issued
    /// triangle, so a perfectly-scheduled causal run scores the same
    /// utilization as its square sibling rather than double.
    pub fn masked_utilization(&self, n: usize, seq_len: usize, mask: MaskKind) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        let (full, partial, _) = masked_tile_counts(seq_len, n, mask);
        let issued_macs = (full + partial) * 2 * (n as u64).pow(3);
        issued_macs as f64 / ((n * n) as f64 * self.cycles as f64)
    }
}

/// Machine-level events (controller signals resolved with tile bindings).
#[derive(Clone, Copy, Debug)]
enum Ev {
    Sig { sig: Signal, k_tile: TileDesc, v_tile: TileDesc, q_tile: TileDesc },
    AccumBegin { l_addr: u32, o_addr: u32, o_stride: u32, first: bool },
    DmaLoadDone { src: TileDesc, dst: TileDesc },
    DmaStoreDone { src: TileDesc, dst: TileDesc },
    Reciprocal { addr: u32, len: usize },
    LseNorm { o_addr: u32, o_stride: u32, rows: usize, l_addr: u32 },
}

pub struct Machine {
    pub cfg: MachineConfig,
    pub mem: Vec<f32>,
    pub spad: Sram,
    pub array: Array,
    pub accum: Accumulator,
    /// Inner-loop schedule, hoisted out of `run_program`: a pure
    /// function of `(n, variant, segments)`, none of which
    /// [`Machine::reset_for_reuse`] can change — so one machine serving
    /// many shards builds it exactly once.
    sched: InnerSchedule,
    /// Per-instruction signal tables ([`controller::EventTemplates`]),
    /// equally shape-pure and hoisted for the same reason (the O(N²)
    /// generate+sort used to run on every `run_program` call).
    tpl: Arc<controller::EventTemplates>,
}

impl Machine {
    pub fn new(cfg: MachineConfig) -> Machine {
        let scale = (LOG2E / (cfg.scale_dim as f64).sqrt()) as f32;
        let mut accum = Accumulator::new(cfg.n, cfg.segments, scale, cfg.accum_elems);
        accum.f16_mode = cfg.quantize;
        let mut array = Array::new(cfg.n, cfg.segments, cfg.quantize);
        array.scalar_reference = cfg.scalar_reference;
        let sched = InnerSchedule::new(cfg.n, cfg.variant, cfg.segments);
        let tpl = Arc::new(controller::EventTemplates::new(&sched));
        Machine {
            mem: vec![0.0; cfg.mem_elems],
            spad: Sram::new(cfg.spad_elems),
            array,
            accum,
            sched,
            tpl,
            cfg,
        }
    }

    /// Reset the device for reuse by another shard — the shard-batching
    /// hazard fence (DESIGN.md §8).  Zeroes main memory (`write_padded`
    /// relies on zero padding), the scratchpad data *and* its
    /// DMA-readiness scoreboard (a stale ready cycle would poison the
    /// next program's schedule), the accumulator, and every array
    /// register and counter; `scale_dim` rebinds the softmax scale to
    /// the next shard's head dim.  After this the next `run_program` is
    /// bitwise and cycle-for-cycle the run a fresh machine would
    /// produce (pinned by `sim_backend.rs` / `sim_differential.rs`).
    pub fn reset_for_reuse(&mut self, scale_dim: usize) {
        self.cfg.scale_dim = scale_dim;
        self.mem.fill(0.0);
        self.spad.reset();
        self.array.reset();
        let scale = (LOG2E / (scale_dim as f64).sqrt()) as f32;
        self.accum.reset(scale);
    }

    pub fn write_mem(&mut self, addr: u32, data: &[f32]) {
        self.mem[addr as usize..addr as usize + data.len()].copy_from_slice(data);
    }

    pub fn read_mem(&self, addr: u32, len: usize) -> &[f32] {
        &self.mem[addr as usize..addr as usize + len]
    }

    /// Schedule + execute a program; returns timing statistics.
    pub fn run_program(&mut self, program: &Program) -> crate::Result<RunStats> {
        let n = self.cfg.n;
        // Shape-pure schedule + signal tables, built once in
        // [`Machine::new`] (copied / Arc-cloned here because Phase 2
        // calls `&mut self` methods).
        let sched = self.sched;
        let ii = sched.inner_latency();
        let tpl = Arc::clone(&self.tpl);

        // ---------------- Phase 1: schedule ----------------
        let mut events: Vec<(u64, Ev)> = Vec::new();
        let mut load_q = DmaQueue::new();
        let mut store_q = DmaQueue::new();
        let mut compute_free: u64 = 0;
        let mut last_score_t: Option<u64> = None;
        let mut last_score_ii: u64 = 0;
        let mut pending_q: Option<TileDesc> = None;
        let mut stationary_loaded = false;
        // §8 mask wave: the boundary register programmed by MaskBound,
        // consumed by the next masked AttnScore.
        let mut pending_bound: Option<LaneBound> = None;
        // Completion cycle of writes into accumulator regions (for stores)
        // and of stores reading them (for subsequent compute reuse).
        let mut accum_writes: Vec<(TileDesc, u64)> = Vec::new();
        let mut store_reads: Vec<(TileDesc, u64)> = Vec::new();
        // Last cycle each scratchpad region is read by compute: DMA loads
        // into a double-buffer slot must wait for the previous consumer
        // (WAR hazard the real controller resolves via its scoreboard).
        let mut spad_reads: Vec<(TileDesc, u64)> = Vec::new();
        let mut compute_busy: u64 = 0;
        let mut bd = CycleBreakdown::default();

        let overlap_region = |list: &[(TileDesc, u64)], t: &TileDesc| -> u64 {
            list.iter().filter(|(r, _)| r.overlaps(t)).map(|&(_, c)| c).max().unwrap_or(0)
        };

        // Schedule one DMA load (helper so the score arm can pull the V
        // load that Listing 2 places between attn_score and attn_value
        // forward in walk order — queue order is preserved because it is
        // still earlier than any unwalked load).
        macro_rules! sched_load {
            ($src:expr, $dst:expr) => {{
                let (src, dst) = ($src, $dst);
                ensure!(src.space == Space::Main && dst.space == Space::Spad,
                    "load_tile must move main -> spad: {src:?} -> {dst:?}");
                ensure!((dst.end_addr() as usize) <= self.spad.capacity(),
                    "load_tile overruns scratchpad: {dst:?}");
                let war = overlap_region(&spad_reads, &dst);
                let done = load_q.issue(&self.cfg.dma, src, dst, war);
                self.spad.mark_ready(&dst, done);
                events.push((done, Ev::DmaLoadDone { src, dst }));
            }};
        }

        let insns = &program.instructions;
        let mut consumed = vec![false; insns.len()];
        let mut idx = 0usize;
        while idx < insns.len() {
            if consumed[idx] {
                idx += 1;
                continue;
            }
            let insn = insns[idx];
            match insn {
                Instruction::LoadTile { src, dst } => {
                    sched_load!(src, dst);
                }
                Instruction::StoreTile { src, dst } => {
                    ensure!(src.space == Space::Accum && dst.space == Space::Main,
                        "store_tile must move accum -> main: {insn:?}");
                    let ready = overlap_region(&accum_writes, &src);
                    let done = store_q.issue(&self.cfg.dma, src, dst, ready);
                    store_reads.push((src, done));
                    events.push((done, Ev::DmaStoreDone { src, dst }));
                }
                Instruction::LoadStationary { src } => {
                    ensure!(src.space == Space::Spad, "load_stationary reads spad");
                    ensure!(src.rows as usize == n && src.cols as usize == n,
                        "stationary tile must be {n}x{n}, got {src:?}");
                    pending_q = Some(src);
                }
                Instruction::MaskBound { bound } => {
                    // Zero-latency control-register write, folded into
                    // the next masked score's issue.
                    ensure!(pending_bound.is_none(),
                        "mask_bound already pending (unconsumed by any attn_score)");
                    pending_bound = Some(bound);
                }
                Instruction::AttnScore { k, lse, first, masked } => {
                    ensure!(k.space == Space::Spad && lse.space == Space::Accum,
                        "attn_score reads spad K, writes accum lse");
                    ensure!(k.rows as usize == n && k.cols as usize == n,
                        "K tile must be {n}x{n}, got {k:?}");
                    // Resolve the §8 boundary register: masked scores
                    // consume the pending MaskBound; unmasked ones must
                    // not leave one dangling (it would silently apply to
                    // a later tile).
                    let bound = if masked {
                        Some(pending_bound.take().ok_or_else(|| anyhow::anyhow!(
                            "masked attn_score without a preceding mask_bound"
                        ))?)
                    } else {
                        ensure!(pending_bound.is_none(),
                            "mask_bound pending before an unmasked attn_score");
                        None
                    };
                    // The mask wave is one extra element-wise cycle
                    // (schedule::masked_inner_latency) in the chaining
                    // interval.
                    let ii = if masked { sched.masked_inner_latency() } else { ii };
                    // Pair with the next *compute-class* instruction when
                    // it is the AttnValue (Listing 2 interleaves DMA loads
                    // between score and value — different queues, §4.1);
                    // any loads in between are pulled forward so their
                    // completion times are known to the pairing.
                    let mut value = None;
                    let mut value_idx = 0usize;
                    for j in idx + 1..insns.len() {
                        match insns[j] {
                            Instruction::LoadTile { src, dst } if !consumed[j] => {
                                sched_load!(src, dst);
                                consumed[j] = true;
                            }
                            Instruction::LoadTile { .. } | Instruction::StoreTile { .. } => {}
                            Instruction::AttnValue { v, out, .. } => {
                                value = Some((v, out));
                                value_idx = j;
                                break;
                            }
                            _ => break,
                        }
                    }
                    let k_ready = self.spad.ready_cycle(&k);
                    let v_ready = value.map(|(v, _)| self.spad.ready_cycle(&v)).unwrap_or(0);
                    let out_busy = value
                        .map(|(_, o)| overlap_region(&store_reads, &o))
                        .unwrap_or(0);
                    let lse_busy = overlap_region(&store_reads, &lse);

                    let mut t = compute_free
                        .max(k_ready)
                        .max(v_ready.saturating_sub(sched.pv_start().saturating_sub(1)))
                        .max(out_busy)
                        .max(lse_busy);

                    // Stationary preload placement.
                    if let Some(q) = pending_q.take() {
                        let q_ready = self.spad.ready_cycle(&q);
                        let window = last_score_t.map(|lt| lt + (3 * n + 4 + self.cfg.segments) as u64);
                        match window {
                            Some(w) if q_ready <= w && stationary_loaded => {
                                // Overlapped into the previous iteration's
                                // drain window (offsets are relative to the
                                // previous score's issue cycle).
                                let base = last_score_t.unwrap();
                                for &(c, sig) in &tpl.preload_overlapped {
                                    events.push((base + c,
                                        Ev::Sig { sig, k_tile: k, v_tile: k, q_tile: q }));
                                }
                                spad_reads.push((q, base + (5 * n + 12) as u64));
                            }
                            _ => {
                                // Standalone: wait for array drain + data.
                                let drained =
                                    last_score_t.map(|lt| lt + last_score_ii).unwrap_or(0);
                                let start = q_ready.max(drained).max(compute_free.saturating_sub(0));
                                for &(c, sig) in &tpl.preload_standalone {
                                    events.push((start + c,
                                        Ev::Sig { sig, k_tile: k, v_tile: k, q_tile: q }));
                                }
                                spad_reads.push((q, start + controller::preload_standalone_cycles(n)));
                                t = t.max(start + controller::preload_standalone_cycles(n));
                            }
                        }
                        stationary_loaded = true;
                    }
                    ensure!(stationary_loaded, "attn_score before any load_stationary");

                    // Emit score events.
                    for &(c, sig) in tpl.score(first) {
                        if matches!(sig, Signal::AccumBegin) {
                            let (o_addr, o_stride) = value
                                .map(|(_, o)| (o.addr, o.stride))
                                .unwrap_or((lse.addr, n as u32));
                            events.push((t + c, Ev::AccumBegin {
                                l_addr: lse.addr, o_addr, o_stride, first,
                            }));
                        } else {
                            events.push((t + c, Ev::Sig {
                                sig, k_tile: k, v_tile: k, q_tile: k,
                            }));
                        }
                    }
                    // Program the CMP boundary registers for this
                    // iteration — pushed after the reset/next-iter
                    // events of the same cycle (stable sort keeps the
                    // order).  Unmasked scores restore the full width.
                    for col in 0..n {
                        let b = bound.map(|lb| lb.bound(col)).unwrap_or(n as u16);
                        events.push((t, Ev::Sig {
                            sig: Signal::CmpSetBound { col, bound: b },
                            k_tile: k, v_tile: k, q_tile: k,
                        }));
                    }
                    accum_writes.push((lse, t + ii));
                    spad_reads.push((k, t + ii));
                    last_score_t = Some(t);
                    last_score_ii = ii;
                    compute_free = t + ii;
                    compute_busy += ii;
                    // Attribute this interval to instruction classes
                    // (DESIGN.md §9): the unmasked interval decomposes
                    // as score (2N) + exp window (N + 2 + segments) +
                    // rowsum (N) + PV remainder; a masked score adds
                    // exactly the one-cycle §6 mask wave — so the
                    // charges sum to the `ii` added to `compute_busy`.
                    let base_ii = if masked { ii - 1 } else { ii };
                    bd.score += 2 * n as u64;
                    bd.exp += (n + 2 + self.cfg.segments) as u64;
                    bd.rowsum += n as u64;
                    bd.pv += base_ii - (4 * n + 2 + self.cfg.segments) as u64;
                    if masked {
                        bd.mask_wave += 1;
                    }

                    // Emit the paired value events now (same t).
                    if let Some((v, out)) = value {
                        ensure!(v.space == Space::Spad && out.space == Space::Accum,
                            "attn_value reads spad V, writes accum O");
                        for &(c, sig) in &tpl.value {
                            events.push((t + c, Ev::Sig {
                                sig, k_tile: k, v_tile: v, q_tile: k,
                            }));
                        }
                        accum_writes.push((out, t + ii));
                        spad_reads.push((v, t + ii));
                        consumed[value_idx] = true;
                    }
                }
                Instruction::AttnValue { .. } => {
                    bail!("attn_value must follow its attn_score (only DMA may sit between)");
                }
                Instruction::Reciprocal { l } => {
                    ensure!(l.space == Space::Accum, "reciprocal operates on accum");
                    let ready = overlap_region(&accum_writes, &l);
                    let t = compute_free.max(ready);
                    let lat = n as u64 + 10;
                    events.push((t, Ev::Reciprocal { addr: l.addr, len: l.elems() }));
                    accum_writes.push((l, t + lat));
                    compute_free = t + lat;
                    compute_busy += lat;
                    // The 1/l reciprocal finishes the row-sum chain.
                    bd.rowsum += lat;
                }
                Instruction::AttnLseNorm { out, l } => {
                    ensure!(out.space == Space::Accum && l.space == Space::Accum,
                        "attn_lse_norm operates on accum");
                    let ready = overlap_region(&accum_writes, &out)
                        .max(overlap_region(&accum_writes, &l));
                    let t = compute_free.max(ready);
                    let lat = n as u64 + 10;
                    events.push((t, Ev::LseNorm {
                        o_addr: out.addr,
                        o_stride: out.stride,
                        rows: out.rows as usize,
                        l_addr: l.addr,
                    }));
                    accum_writes.push((out, t + lat));
                    compute_free = t + lat;
                    compute_busy += lat;
                    // LSE normalization finishes the PV output.
                    bd.pv += lat;
                }
            }
            idx += 1;
        }
        ensure!(pending_bound.is_none(), "trailing mask_bound never consumed");

        // ---------------- Phase 2: execute ----------------
        events.sort_by_key(|&(c, _)| c);
        let end_cycle = events
            .iter()
            .map(|&(c, _)| c)
            .max()
            .unwrap_or(0)
            .max(compute_free)
            .max(load_q.free_at())
            .max(store_q.free_at())
            + 8 * n as u64
            + 64; // drain margin

        let scale = (LOG2E / (self.cfg.scale_dim as f64).sqrt()) as f32;
        let trace = std::env::var_os("FSA_TRACE").is_some();
        let mut ei = 0usize;
        let mut outs = Vec::new();
        let mut cycle: u64 = 0;
        // Span-based execution: drain this cycle's events, then tight-step
        // the array to the next event boundary with no event polling (and
        // no per-cycle Vec allocation) in between.
        while cycle < end_cycle {
            while ei < events.len() && events[ei].0 == cycle {
                let (_, ev) = events[ei];
                if trace {
                    eprintln!("cycle {cycle}: {ev:?}");
                }
                self.apply_event(ev, scale, cycle)
                    .with_context(|| format!("applying event at cycle {cycle}"))?;
                ei += 1;
            }
            debug_assert!(ei >= events.len() || events[ei].0 > cycle);
            let until = events
                .get(ei)
                .map(|&(c, _)| c.min(end_cycle))
                .unwrap_or(end_cycle);
            loop {
                self.array.step_into(&mut outs);
                for &out in &outs {
                    self.accum.accept(out, cycle);
                }
                cycle += 1;
                if cycle >= until {
                    break;
                }
            }
        }
        ensure!(self.array.quiescent(), "array not quiescent at program end");

        // Close the attribution: the compute classes partition
        // `compute_busy`, the residual idle on the compute timeline is
        // `stall`, and any DMA tail past the last compute cycle is
        // `dma` — so the classes sum exactly to the reported cycles.
        let cycles = compute_free.max(store_q.free_at()).max(load_q.free_at());
        bd.stall = compute_free.saturating_sub(compute_busy);
        bd.dma = cycles - compute_free;
        debug_assert_eq!(
            bd.total(),
            cycles,
            "cycle attribution must sum exactly to the measured total"
        );

        Ok(RunStats {
            cycles,
            matmul_macs: self.array.matmul_macs,
            total_pe_ops: self.array.mac_ops,
            dma_load_busy: load_q.busy_cycles(),
            dma_store_busy: store_q.busy_cycles(),
            compute_busy,
            instructions: program.len(),
            breakdown: bd,
        })
    }

    fn apply_event(&mut self, ev: Ev, scale: f32, _cycle: u64) -> crate::Result<()> {
        let n = self.cfg.n;
        match ev {
            Ev::Sig { sig, k_tile, v_tile, q_tile } => match sig {
                Signal::InjectK { row, n: nn } => {
                    let v = self.spad.at(&k_tile, nn, row);
                    self.array.inject_left(row, v, LeftTag::MacUp);
                }
                Signal::InjectConst { row } => {
                    self.array.inject_left(row, scale, LeftTag::MulConst);
                }
                Signal::InjectPwl { row, pair } => {
                    let slope = self.array.pwl().slopes[pair] as f32;
                    let intercept = self.array.pwl().intercepts[pair] as f32;
                    self.array.inject_left(row, slope, LeftTag::Pwl { seg: pair as u8, intercept });
                }
                Signal::InjectRowSumOne { row } => {
                    self.array.inject_left(row, 1.0, LeftTag::RowSum);
                }
                Signal::InjectV { row, h } => {
                    let v = self.spad.at(&v_tile, row, h);
                    self.array.inject_left(row, v, LeftTag::MacDown);
                }
                Signal::InjectPreload { col, k } => {
                    let v = self.spad.at(&q_tile, col, k);
                    self.array.inject_top(col, crate::sim::array::DownMsg::Preload {
                        val: v,
                        hops: k as u16,
                    });
                }
                Signal::CmpReset { col } => self.array.cmp_reset(col),
                Signal::CmpNextIter { col } => self.array.cmp_next_iter(col),
                Signal::CmpSetBound { col, bound } => self.array.cmp_set_bound(col, bound),
                Signal::CmpEmitSub { col } => self.array.cmp_emit_sub(col),
                Signal::CmpEmitA { col } => self.array.cmp_emit_a(col),
                Signal::AccumBegin => unreachable!("resolved at schedule time"),
            },
            Ev::AccumBegin { l_addr, o_addr, o_stride, first } => {
                self.accum.begin_iteration(l_addr, o_addr, o_stride, first);
            }
            Ev::DmaLoadDone { src, dst } => {
                for r in 0..dst.rows as usize {
                    for c in 0..dst.cols as usize {
                        let v = self.mem[src.addr as usize + r * src.stride as usize + c];
                        let v = if self.cfg.quantize { quantize_f32(v) } else { v };
                        self.spad.set(&dst, r, c, v);
                    }
                }
            }
            Ev::DmaStoreDone { src, dst } => {
                for r in 0..src.rows as usize {
                    for c in 0..src.cols as usize {
                        let v = self.accum.sram
                            [src.addr as usize + r * src.stride as usize + c];
                        self.mem[dst.addr as usize + r * dst.stride as usize + c] = v;
                    }
                }
            }
            Ev::Reciprocal { addr, len } => self.accum.reciprocal(addr, len),
            Ev::LseNorm { o_addr, o_stride, rows, l_addr } => {
                self.accum.lse_norm(o_addr, o_stride, rows, l_addr);
            }
        }
        let _ = n;
        Ok(())
    }
}
