//! The systolic array controller (paper §4.3): per-instruction static
//! control-signal schedules.
//!
//! The real FSA drives every PE/accumulator/SRAM control line from two
//! counter-based FSMs whose signal tables are synthesized from a
//! scheduling DSL.  Here the "DSL" is a set of generator functions that
//! emit `(cycle, Signal)` events from the closed-form wave timing of
//! [`crate::schedule::InnerSchedule`]; the combiner is a single sorted
//! event list, and the array's port-hazard asserts play the role of the
//! conflict checker.
//!
//! All cycles are absolute (the machine adds instruction issue times).

use crate::schedule::InnerSchedule;
#[cfg(test)]
use crate::schedule::Variant;

/// One control signal to apply at a specific cycle.  Data payloads are
/// fetched from SRAM at apply time (the SRAM-priority rule of §4.1 makes
/// reads deterministic).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Signal {
    /// Inject K element [n][row] of the bound K tile (MacUp) into `row`.
    InjectK { row: usize, n: usize },
    /// Inject the log2(e)/sqrt(d) constant (MulConst) into `row`.
    InjectConst { row: usize },
    /// Inject PWL pair `pair` into `row`.
    InjectPwl { row: usize, pair: usize },
    /// Inject a rowsum "one" into `row`.
    InjectRowSumOne { row: usize },
    /// Inject V element [row][h] of the bound V tile (MacDown) into `row`.
    InjectV { row: usize, h: usize },
    /// Preload stationary element Q[col][k] into column `col`, `k` hops.
    InjectPreload { col: usize, k: usize },
    /// CMP bookkeeping at iteration start.
    CmpNextIter { col: usize },
    CmpReset { col: usize },
    /// Program CMP `col`'s §8 boundary register for the iteration (the
    /// resolved [`crate::isa::LaneBound`] value; `n` when unmasked).
    /// Emitted by the machine *after* the reset/next-iter events of the
    /// same cycle.
    CmpSetBound { col: usize, bound: u16 },
    /// CMP emissions (−new_m broadcast; a = old_m − new_m pass-down).
    CmpEmitSub { col: usize },
    CmpEmitA { col: usize },
    /// Bind the accumulator for the iteration's bottom-edge arrivals.
    AccumBegin,
}

/// Events for one instruction, relative to its issue cycle.
pub type Events = Vec<(u64, Signal)>;

/// AttnScore: first matmul + rowmax + in-place softmax chain + rowsum.
/// (The §4.2 instruction also computes the exponent-sum — the rowsum wave
/// is part of this schedule; the paired AttnValue only adds the V waves.)
pub fn attn_score_events(s: &InnerSchedule, first: bool) -> Events {
    let n = s.n;
    let mut ev = Events::new();
    for col in 0..n {
        ev.push((0, if first { Signal::CmpReset { col } } else { Signal::CmpNextIter { col } }));
    }
    // Injections are queued one cycle before the intended col-0 arrival.
    for nn in 0..n {
        for k in 0..n {
            // Arrival at (k, 0) at `k_inject + 1`; queue at k_inject.
            ev.push((s.k_inject(nn, k), Signal::InjectK { row: k, n: nn }));
        }
    }
    for col in 0..n {
        // -new_m broadcast arrives (0, col) at elementwise(0, 0, col) =
        // 2N + col + 1; CMP emits one cycle earlier.
        ev.push((s.elementwise(0, 0, col) - 1, Signal::CmpEmitSub { col }));
        // a = old_m - new_m rides the next slot.
        ev.push((s.elementwise(0, 0, col), Signal::CmpEmitA { col }));
    }
    for row in 0..n {
        // Const wave arrives (row, 0) at elementwise(1, row, 0).
        ev.push((s.elementwise(1, row, 0) - 1, Signal::InjectConst { row }));
        for pair in 0..s.segments {
            ev.push((s.elementwise(2 + pair, row, 0) - 1, Signal::InjectPwl { row, pair }));
        }
        ev.push((s.rowsum_at(row, 0) - 1, Signal::InjectRowSumOne { row }));
    }
    // Accumulator must rebind after every previous-iteration arrival
    // (last one lands at inner_latency - 1) and before this iteration's
    // first AVal (3N + 1).  3N sits in that window for II = 5N + 10.
    ev.push(((3 * n) as u64, Signal::AccumBegin));
    ev.sort_by_key(|&(c, _)| c);
    ev
}

/// AttnValue: the V waves of the second matmul (downward path).
pub fn attn_value_events(s: &InnerSchedule) -> Events {
    let n = s.n;
    let mut ev = Events::new();
    for row in 0..n {
        for h in 0..n {
            // V[row][h] arrives (row, 0) at pv_start + h + row.
            ev.push((s.pv_at(row, 0, h) - 1 - 0, Signal::InjectV { row, h }));
        }
    }
    ev.sort_by_key(|&(c, _)| c);
    ev
}

/// Stationary preload for the *next* iteration, overlapped into the
/// current iteration's drain window (see DESIGN.md §3): column `m`
/// injects its deepest element first starting at `3N + 11 + m`, finishing
/// all columns before the next iteration's park stream returns.
pub fn preload_events_overlapped(s: &InnerSchedule) -> Events {
    let n = s.n;
    // First legal cycle: one past the last PV psum through each column's
    // top PE, i.e. pv_at(0, col, N-1) = 3N + 4 + segments + col.  For the
    // paper's 8 segments this is the 3N+12 window of DESIGN.md §3.
    let base = (3 * n + 4 + s.segments) as u64;
    let mut ev = Events::new();
    for col in 0..n {
        for k in 0..n {
            // Deepest (largest k) first so all land simultaneously.
            ev.push((base + col as u64 + (n - 1 - k) as u64, Signal::InjectPreload { col, k }));
        }
    }
    ev.sort_by_key(|&(c, _)| c);
    ev
}

/// Standalone stationary preload (first iteration / after a stall): safe
/// any time the array is quiescent.  Duration N + 1 cycles.
pub fn preload_events_standalone(n: usize) -> Events {
    let mut ev = Events::new();
    for col in 0..n {
        for k in 0..n {
            ev.push(((n - 1 - k) as u64, Signal::InjectPreload { col, k }));
        }
    }
    ev.sort_by_key(|&(c, _)| c);
    ev
}

/// Duration of the standalone preload.
pub fn preload_standalone_cycles(n: usize) -> u64 {
    n as u64 + 1
}

/// Per-instruction event streams generated once per `run_program` and
/// reused for every tile (the signal tables are pure functions of the
/// [`InnerSchedule`], so the machine's dispatch loop hoists the
/// O(N²)-event generate+sort out of the per-instruction hot path — one
/// generation instead of one per scheduled tile).
pub struct EventTemplates {
    pub score_first: Events,
    pub score_next: Events,
    pub value: Events,
    pub preload_overlapped: Events,
    pub preload_standalone: Events,
}

impl EventTemplates {
    pub fn new(s: &InnerSchedule) -> EventTemplates {
        EventTemplates {
            score_first: attn_score_events(s, true),
            score_next: attn_score_events(s, false),
            value: attn_value_events(s),
            preload_overlapped: preload_events_overlapped(s),
            preload_standalone: preload_events_standalone(s.n),
        }
    }

    pub fn score(&self, first: bool) -> &Events {
        if first {
            &self.score_first
        } else {
            &self.score_next
        }
    }
}

/// Merge (combine) event streams with per-instruction issue offsets — the
/// §4.3 "combiner unit".  Returns a single sorted absolute-cycle stream.
pub fn combine(streams: Vec<(u64, Events)>) -> Vec<(u64, Signal)> {
    let mut all: Vec<(u64, Signal)> = streams
        .into_iter()
        .flat_map(|(t0, ev)| ev.into_iter().map(move |(c, s)| (t0 + c, s)))
        .collect();
    all.sort_by_key(|&(c, _)| c);
    all
}

/// Sanity helper used by tests and the machine: the largest event cycle in
/// a stream.
pub fn last_event_cycle(ev: &Events) -> u64 {
    ev.iter().map(|&(c, _)| c).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(n: usize) -> InnerSchedule {
        InnerSchedule::new(n, Variant::DualPath, 8)
    }

    #[test]
    fn score_event_counts() {
        let n = 8;
        let ev = attn_score_events(&sched(n), true);
        // n resets + n^2 K + n sub + n a + n const + 8n pwl + n rowsum + 1.
        assert_eq!(ev.len(), n + n * n + 2 * n + n + 8 * n + n + 1);
        // Sorted by cycle.
        assert!(ev.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn value_events_cover_all_vh() {
        let n = 4;
        let ev = attn_value_events(&sched(n));
        assert_eq!(ev.len(), n * n);
        // Last V injection is at pv_at(n-1, 0, n-1) - 1.
        let s = sched(n);
        assert_eq!(last_event_cycle(&ev), s.pv_at(n - 1, 0, n - 1) - 1);
    }

    #[test]
    fn overlapped_preload_fits_inside_iteration() {
        for n in [4usize, 16, 128] {
            let s = sched(n);
            let ev = preload_events_overlapped(&s);
            assert_eq!(ev.len(), n * n);
            // Entire preload must finish within the iteration interval
            // (last injection + landing <= inner_latency + n margin used
            // by the machine's legality argument, see DESIGN.md §3).
            let last = last_event_cycle(&ev);
            assert_eq!(last, (3 * n + 4 + 8 + (n - 1) + (n - 1)) as u64);
            assert!(last + 1 <= s.inner_latency() + n as u64);
            // Preload of column 0 is injected no earlier than the cycle
            // the last PV psum passes its top PE (arrival is one cycle
            // after injection, so >= keeps a strict one-cycle gap).
            let first_col0 = ev.iter().find(|(_, s)| matches!(s, Signal::InjectPreload { col: 0, .. })).unwrap().0;
            assert!(first_col0 >= s.pv_at(0, 0, n - 1));
        }
    }

    #[test]
    fn templates_equal_direct_generation() {
        for n in [4usize, 32] {
            let s = sched(n);
            let tpl = EventTemplates::new(&s);
            assert_eq!(tpl.score_first, attn_score_events(&s, true));
            assert_eq!(tpl.score_next, attn_score_events(&s, false));
            assert_eq!(tpl.score(true), &tpl.score_first);
            assert_eq!(tpl.score(false), &tpl.score_next);
            assert_eq!(tpl.value, attn_value_events(&s));
            assert_eq!(tpl.preload_overlapped, preload_events_overlapped(&s));
            assert_eq!(tpl.preload_standalone, preload_events_standalone(n));
        }
    }

    #[test]
    fn combiner_orders_and_offsets() {
        let a: Events = vec![(0, Signal::AccumBegin), (5, Signal::AccumBegin)];
        let b: Events = vec![(1, Signal::AccumBegin)];
        let merged = combine(vec![(100, a), (0, b)]);
        let cycles: Vec<u64> = merged.iter().map(|&(c, _)| c).collect();
        assert_eq!(cycles, vec![1, 100, 105]);
    }
}
