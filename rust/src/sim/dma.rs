//! iDMA-style DMA engine model (paper §4.1).
//!
//! 2D descriptors (tile in main memory <-> tile in SRAM), multi-channel
//! AXI bandwidth: a transfer of B bytes over `channels` AXI4 ports with
//! per-port width `bytes_per_cycle` completes in
//! `setup + ceil(B / (channels * bytes_per_cycle))` cycles.  Transfers of
//! the same class are serviced in order (one outstanding per direction),
//! which is what the Listing-2 double buffering is sized for.

use crate::isa::TileDesc;

#[derive(Clone, Copy, Debug)]
pub struct DmaConfig {
    pub channels: usize,
    /// Per-channel payload bytes per cycle (AXI data width / 8).
    pub bytes_per_cycle: f64,
    /// Fixed per-descriptor setup cost in cycles.
    pub setup_cycles: u64,
    /// Element size on the wire (fp16 activations = 2 bytes).
    pub elem_bytes: u64,
}

impl DmaConfig {
    /// Config matching an 820 GB/s memory system at `freq_ghz` with
    /// `channels` AXI ports splitting the bandwidth.
    pub fn for_bandwidth(mem_bw_gbs: f64, freq_ghz: f64, channels: usize) -> DmaConfig {
        let total_bpc = mem_bw_gbs / freq_ghz; // bytes per cycle
        DmaConfig {
            channels,
            bytes_per_cycle: total_bpc / channels as f64,
            setup_cycles: 16,
            elem_bytes: 2,
        }
    }

    /// Latency of one 2D transfer (paper: the engine auto-partitions the
    /// transfer across channels, so the aggregate bandwidth applies).
    pub fn transfer_cycles(&self, tile: &TileDesc) -> u64 {
        let bytes = tile.elems() as u64 * self.elem_bytes;
        let agg = self.bytes_per_cycle * self.channels as f64;
        self.setup_cycles + (bytes as f64 / agg).ceil() as u64
    }
}

/// One in-flight transfer.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub issued_at: u64,
    pub done_at: u64,
    pub src: TileDesc,
    pub dst: TileDesc,
}

/// In-order DMA queue (one per direction/class).
#[derive(Debug, Default)]
pub struct DmaQueue {
    /// Cycle at which the engine becomes free.
    free_at: u64,
    pub completed: Vec<Transfer>,
}

impl DmaQueue {
    pub fn new() -> DmaQueue {
        DmaQueue::default()
    }

    /// Issue a transfer no earlier than `ready` (descriptor dependencies);
    /// returns its completion cycle.
    pub fn issue(&mut self, cfg: &DmaConfig, src: TileDesc, dst: TileDesc, ready: u64) -> u64 {
        let start = self.free_at.max(ready);
        let done = start + cfg.transfer_cycles(&src.max_dims(&dst));
        self.free_at = done;
        self.completed.push(Transfer { issued_at: start, done_at: done, src, dst });
        done
    }

    pub fn free_at(&self) -> u64 {
        self.free_at
    }

    /// Total busy cycles (active-time accounting, Fig. 1 style).
    pub fn busy_cycles(&self) -> u64 {
        self.completed.iter().map(|t| t.done_at - t.issued_at).sum()
    }
}

impl TileDesc {
    /// The larger of two descriptors element-wise (a transfer moves
    /// min(src, dst) shapes; they should match, and tests enforce it —
    /// this is belt-and-braces for latency accounting).
    pub fn max_dims(&self, other: &TileDesc) -> TileDesc {
        let mut t = *self;
        t.rows = t.rows.max(other.rows);
        t.cols = t.cols.max(other.cols);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Space;

    #[test]
    fn bandwidth_math() {
        // 820 GB/s @ 1.5 GHz = 546.67 B/cycle aggregate.
        let cfg = DmaConfig::for_bandwidth(820.0, 1.5, 4);
        let tile = TileDesc::contiguous(Space::Main, 0, 128, 128);
        // 128*128*2 B = 32768 B -> 60 cycles + 16 setup.
        let c = cfg.transfer_cycles(&tile);
        assert_eq!(c, 16 + (32768.0f64 / (820.0 / 1.5)).ceil() as u64);
        assert!(c < 100, "tile DMA must hide under a 650-cycle iteration");
    }

    #[test]
    fn queue_serializes_in_order() {
        let cfg = DmaConfig::for_bandwidth(820.0, 1.5, 1);
        let mut q = DmaQueue::new();
        let t = TileDesc::contiguous(Space::Main, 0, 128, 128);
        let d = TileDesc::contiguous(Space::Spad, 0, 128, 128);
        let c1 = q.issue(&cfg, t, d, 0);
        let c2 = q.issue(&cfg, t, d, 0);
        assert_eq!(c2 - c1, c1); // back-to-back, same duration
        let c3 = q.issue(&cfg, t, d, c2 + 1000); // dependency-delayed
        assert!(c3 > c2 + 1000);
        assert_eq!(q.busy_cycles(), 3 * c1);
    }
}
