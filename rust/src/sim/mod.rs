//! Cycle-accurate FSA device simulator.
//!
//! This is the substitution for the paper's Chisel RTL + Verilator
//! cosimulation (see DESIGN.md §substitutions): a genuine per-cycle
//! dataflow model of the enhanced systolic array.  Values move exactly one
//! hop per cycle; operands are injected at the array edges by the
//! statically-scheduled controller (as in §4.3) and carry hardware-style
//! control tags; correctness *emerges* from the data arriving at the right
//! PEs on the right cycles, and the array asserts a structural hazard if
//! two values ever contend for one port — which is how the
//! SystolicAttention schedule of [`crate::schedule`] is validated.
//!
//! Components (paper Fig. 3 / Fig. 8):
//!
//! * [`array`]   — the N x N PE grid with upward + downward paths, Split
//!   units (PWL exp2) and the top row of CMP units.
//! * [`accumulator`] — near-memory accumulator + accumulation SRAM.
//! * [`sram`]    — scratchpad SRAM with double-buffer bookkeeping.
//! * [`dma`]     — iDMA-style 2D DMA engine with a bandwidth model.
//! * [`controller`] — per-instruction static control-signal schedules
//!   (the counter-FSM pair + combiner of §4.3).
//! * [`machine`] — the whole device: instruction queues by class,
//!   scoreboarding, and a `run_program` entry point.

pub mod accumulator;
pub mod array;
pub mod controller;
pub mod dma;
pub mod machine;
pub mod sram;

pub use array::{Array, LeftTag};
pub use machine::{CycleBreakdown, Machine, MachineConfig, RunStats};
