//! The N x N PE grid with FSA's three architectural additions (paper §3.1):
//! a CMP-unit row on top, a Split unit per PE, and an upward data path.
//!
//! Per cycle, every in-flight value moves exactly one hop:
//!
//! * left operands move right along their row (one port per PE);
//! * upward partial sums move from row k+1 to row k (first matmul);
//! * downward values move from row k-1 to row k (S park, broadcasts,
//!   rowsum, PV psums, stationary preload);
//! * the CMP row consumes the top-row upward exits (running rowmax), and
//!   re-emits values downward (S re-streaming, -new_m broadcast, a = old_m
//!   - new_m pass-down).
//!
//! Each port accepts at most one value per cycle; a second injection into
//! an occupied slot is a *structural hazard* and panics with a diagnostic
//! — the cycle-model tests rely on this to prove the SystolicAttention
//! schedule is legal.

use crate::numerics::f16::quantize_ftz_f32 as quantize_f32;
use crate::numerics::pwl::PwlExp2;

/// Operand tag traveling with left-injected values (hardware sends these
/// as sideband control bits alongside the data bus).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LeftTag {
    /// First matmul: up_psum += stat * x (K stream; upward path).
    MacUp,
    /// In-place multiply: res *= x (the log2(e)/sqrt(d) constant wave).
    MulConst,
    /// PWL pair wave j: if the PE's fraction segment == `seg`, apply
    /// res = 2^xi * (slope * xf + intercept).  `intercept` rides in the
    /// second payload lane (hardware streams it from the top edge with the
    /// segment index encoded in its exponent MSBs — §3.3; the sim carries
    /// the pair together and checks the encoding property in unit tests).
    Pwl { seg: u8, intercept: f32 },
    /// Rowsum: down_psum += res (fp32), streaming "ones" wave.
    RowSum,
    /// Second matmul: down_psum += f16(res) * x (V stream; downward path).
    MacDown,
}

/// Values on the downward path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DownMsg {
    /// S value re-streamed from the CMP row; parks in `hops` more rows.
    /// `masked` is the §8 mask-wave sideband bit: the lane fell at or
    /// beyond the CMP boundary register, so it parks as zero and sets
    /// the PE's masked latch (its P stays exactly 0 through the
    /// element-wise chain, the rowsum and the PV wave).
    Park { val: f32, hops: u16, masked: bool },
    /// -new_m broadcast: every PE on the way applies res += val.
    AddBroadcast { val: f32 },
    /// a = old_m - new_m passing through to the accumulator.
    AVal { val: f32 },
    /// Rowsum partial sum.
    RowSum { val: f32 },
    /// Second-matmul partial sum (the accumulator recovers the output
    /// index h from per-column arrival order — outputs exit in h order by
    /// construction of the static schedule).
    Pv { val: f32 },
    /// Stationary preload value; lands in the stationary register after
    /// `hops` more rows.
    Preload { val: f32, hops: u16 },
}

/// A value leaving the bottom edge into the accumulator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BottomOut {
    AVal { col: usize, val: f32 },
    RowSum { col: usize, val: f32 },
    Pv { col: usize, val: f32 },
}

#[derive(Clone, Copy, Debug)]
struct LeftOp {
    val: f32,
    tag: LeftTag,
}

/// One comparison unit (top row, paper §3.1): tracks old/new row max and
/// re-streams S downward.  The §8 mask wave rides here: `bound` is the
/// boundary register ([`crate::isa::LaneBound`] resolved per column by
/// the controller) — arrivals at `seen >= bound` are masked lanes,
/// excluded from the running max and re-streamed as zero with the
/// masked sideband bit.
#[derive(Clone, Copy, Debug)]
struct CmpUnit {
    old_m: f32,
    new_m: f32,
    /// Arrival counter: how many S elements of the current iteration have
    /// passed through (the park hop count).
    seen: u16,
    /// Valid-lane boundary of the current iteration (`u16::MAX` =
    /// unmasked).
    bound: u16,
}

/// Finite stand-in for -inf: keeps the Split unit NaN-free (same
/// convention as the Pallas kernel and flash references).
pub const NEG_INF: f32 = -1e30;

impl CmpUnit {
    fn new() -> CmpUnit {
        CmpUnit { old_m: NEG_INF, new_m: NEG_INF, seen: 0, bound: u16::MAX }
    }
}

/// The PE grid + CMP row.  See module docs for the stepping contract.
pub struct Array {
    pub n: usize,
    /// PWL segments for the Split-unit exp2.
    pwl: PwlExp2,
    /// Softmax scale log2(e)/sqrt(d) applied by the MulConst wave
    /// (kept here for the CMP a-value handoff; the wave carries it too).
    pub quantize_inputs: bool,

    // State, all row-major [row * n + col]:
    stat: Vec<f32>,
    res: Vec<f32>,
    /// Per-PE masked latch (§8 mask wave): set by a masked park, cleared
    /// by the next unmasked one.  While set, the element-wise waves skip
    /// the PE so its parked zero stays exactly zero.
    masked: Vec<bool>,
    /// Left operands *arriving* at each PE this cycle.
    ops: Vec<Option<LeftOp>>,
    /// Upward psums arriving this cycle (from the row below).
    up: Vec<Option<f32>>,
    /// Downward values arriving this cycle (from the row above).
    down: Vec<Option<DownMsg>>,
    cmp: Vec<CmpUnit>,
    /// S values that exited the top last cycle, processed by the CMP row
    /// this cycle (one-cycle CMP latency, matching §3.2's timing).
    cmp_inbox: Vec<Option<f32>>,

    /// Pending edge injections for the *next* step: left[row], top[col].
    inject_left: Vec<Option<LeftOp>>,
    inject_top: Vec<Option<DownMsg>>,

    // Double buffers reused across cycles (perf: avoids 3 x n^2 Vec
    // allocations per simulated cycle — see EXPERIMENTS.md §Perf).
    next_ops: Vec<Option<LeftOp>>,
    next_up: Vec<Option<f32>>,
    next_down: Vec<Option<DownMsg>>,

    pub cycle: u64,
    /// Busy-PE count accumulated per cycle (utilization accounting).
    pub mac_ops: u64,
    /// MACs spent in the two matmuls only (useful-FLOPs accounting).
    pub matmul_macs: u64,
}

impl Array {
    pub fn new(n: usize, segments: usize, quantize_inputs: bool) -> Array {
        Array {
            n,
            pwl: PwlExp2::new(segments),
            quantize_inputs,
            stat: vec![0.0; n * n],
            res: vec![0.0; n * n],
            masked: vec![false; n * n],
            ops: vec![None; n * n],
            up: vec![None; n * n],
            down: vec![None; n * n],
            cmp: vec![CmpUnit::new(); n],
            cmp_inbox: vec![None; n],
            inject_left: vec![None; n],
            inject_top: vec![None; n],
            next_ops: vec![None; n * n],
            next_up: vec![None; n * n],
            next_down: vec![None; n * n],
            cycle: 0,
            mac_ops: 0,
            matmul_macs: 0,
        }
    }

    /// Queue a left-edge injection for row `row` (consumed by the next
    /// [`Self::step`]).  Panics on port contention.
    pub fn inject_left(&mut self, row: usize, val: f32, tag: LeftTag) {
        assert!(
            self.inject_left[row].is_none(),
            "structural hazard: left port of row {row} double-driven at cycle {}",
            self.cycle
        );
        let (val, tag) = if self.quantize_inputs {
            match tag {
                LeftTag::MacUp | LeftTag::MacDown => (quantize_f32(val), tag),
                LeftTag::Pwl { seg, intercept } => (
                    quantize_f32(val),
                    LeftTag::Pwl { seg, intercept: quantize_f32(intercept) },
                ),
                _ => (val, tag),
            }
        } else {
            (val, tag)
        };
        self.inject_left[row] = Some(LeftOp { val, tag });
    }

    /// Queue a top-edge downward injection into column `col` (stationary
    /// preload uses this path; CMP-sourced values are emitted by
    /// [`Self::cmp_emit_sub`] / [`Self::cmp_emit_a`] instead).
    pub fn inject_top(&mut self, col: usize, msg: DownMsg) {
        assert!(
            self.inject_top[col].is_none(),
            "structural hazard: top port of column {col} double-driven at cycle {}",
            self.cycle
        );
        self.inject_top[col] = Some(msg);
    }

    /// Reset CMP unit `col` for a new row block (AttnScore with
    /// `first = true`): old max becomes -inf.
    pub fn cmp_reset(&mut self, col: usize) {
        self.cmp[col] = CmpUnit::new();
    }

    /// Begin a new inner iteration at CMP `col`: the running max of the
    /// previous iteration becomes old_m, the arrival counter clears.
    pub fn cmp_next_iter(&mut self, col: usize) {
        let c = &mut self.cmp[col];
        c.old_m = c.new_m;
        c.seen = 0;
    }

    /// Program CMP `col`'s boundary register for the coming iteration
    /// (§8 mask wave): arrivals at `seen >= bound` are masked.  The
    /// controller emits this for every AttnScore — `n` (all lanes
    /// valid) when the score is unmasked.
    pub fn cmp_set_bound(&mut self, col: usize, bound: u16) {
        self.cmp[col].bound = bound;
    }

    /// CMP row emits the -new_m broadcast into column `col`.
    pub fn cmp_emit_sub(&mut self, col: usize) {
        let v = -self.cmp[col].new_m;
        self.inject_top(col, DownMsg::AddBroadcast { val: v });
    }

    /// CMP row emits a = old_m - new_m toward the accumulator.
    pub fn cmp_emit_a(&mut self, col: usize) {
        let c = self.cmp[col];
        self.inject_top(col, DownMsg::AVal { val: c.old_m - c.new_m });
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        row * self.n + col
    }

    /// Result-register write quantization: fp16 + flush-to-zero in f16
    /// mode (PE result registers are half-precision), identity otherwise.
    #[inline]
    fn q_res(&self, v: f32) -> f32 {
        if self.quantize_inputs {
            quantize_f32(v)
        } else {
            v
        }
    }

    /// Advance one clock cycle.  Returns every value that left the bottom
    /// edge this cycle (routed to the accumulator by the machine).
    pub fn step(&mut self) -> Vec<BottomOut> {
        let n = self.n;
        let mut outs = Vec::new();

        // Reuse the double buffers (cleared from the previous cycle).
        let mut next_ops = std::mem::take(&mut self.next_ops);
        let mut next_up = std::mem::take(&mut self.next_up);
        let mut next_down = std::mem::take(&mut self.next_down);

        // 1. CMP row: process last cycle's top exits (one-cycle latency):
        //    update the running max and re-stream S down the column.
        for col in 0..n {
            if let Some(s) = self.cmp_inbox[col].take() {
                // The fp32 psum is quantized to the fp16 register width
                // *here* so the tracked max and the parked value are the
                // same number (otherwise the max row's N could land just
                // above zero and skip the Split unit's sign-guarded PWL).
                let s = self.q_res(s);
                let c = &mut self.cmp[col];
                // §8 mask wave: a lane at or beyond the boundary register
                // is excluded from the running max and parks as zero with
                // the masked sideband bit set.
                let masked = c.seen >= c.bound;
                if !masked {
                    c.new_m = c.new_m.max(s);
                }
                let hops = c.seen;
                c.seen += 1;
                next_down[self.idx(0, col)] = Some(DownMsg::Park {
                    val: if masked { 0.0 } else { s },
                    hops,
                    masked,
                });
            }
        }

        // 2. Per-PE processing, row by row.  Movement semantics:
        //    ops[r][c] (arriving this cycle) -> next_ops[r][c+1];
        //    up[r][c] is the psum arriving at (r, c) this cycle from
        //    (r+1, c); after row r adds its term it becomes next_up[r-1][c]
        //    (or exits to CMP when r == 0).  Down likewise, top-down.
        let mut up_exit: Vec<Option<f32>> = vec![None; n];
        for row in 0..n {
            for col in 0..n {
                let i = self.idx(row, col);
                // ---- Left operand path ----
                if let Some(op) = self.ops[i] {
                    // Forward right (unless at the last column).
                    if col + 1 < n {
                        next_ops[self.idx(row, col + 1)] = Some(op);
                    }
                    match op.tag {
                        LeftTag::MacUp => {
                            let acc_in = self.up[i].unwrap_or(0.0);
                            let term = self.stat[i] * op.val;
                            let out = acc_in + term;
                            self.mac_ops += 1;
                            self.matmul_macs += 1;
                            if row == 0 {
                                up_exit[col] = Some(out);
                            } else {
                                next_up[self.idx(row - 1, col)] = Some(out);
                            }
                        }
                        LeftTag::MulConst => {
                            if !self.masked[i] {
                                self.res[i] = self.q_res(self.res[i] * op.val);
                                self.mac_ops += 1;
                            }
                        }
                        LeftTag::Pwl { seg, intercept } => {
                            // Split unit: decompose the resident value.
                            // Sign guard = one-shot latch: exp2 inputs are
                            // always <= 0 and outputs always > 0, so a PE
                            // whose register is already positive has
                            // consumed its pair (cheap hardware: sign bit).
                            // The §8 masked latch overrides: a masked
                            // lane's parked zero must stay exactly zero.
                            let x = self.res[i];
                            let xi = x.ceil();
                            let xf = self.q_res(x - xi);
                            let k = self.pwl.segment(xf as f64) as u8;
                            if !self.masked[i] && x <= 0.0 && k == seg {
                                // fp16 interpolation MAC (PE datapath).
                                let frac = self.q_res(op.val * xf + intercept);
                                self.res[i] =
                                    self.q_res(frac * xi.clamp(-126.0, 127.0).exp2());
                                self.mac_ops += 1;
                            }
                        }
                        LeftTag::RowSum => {
                            let acc_in = match self.down[i] {
                                Some(DownMsg::RowSum { val }) => val,
                                None => 0.0,
                                other => panic!(
                                    "rowsum wave met unexpected down value {other:?} \
                                     at ({row},{col}) cycle {}",
                                    self.cycle
                                ),
                            };
                            self.down[i] = None;
                            let out = acc_in + self.res[i];
                            self.mac_ops += 1;
                            let msg = DownMsg::RowSum { val: out };
                            if row + 1 < n {
                                next_down[self.idx(row + 1, col)] = Some(msg);
                            } else {
                                outs.push(BottomOut::RowSum { col, val: out });
                            }
                        }
                        LeftTag::MacDown => {
                            // PV psums are born at row 0 (downward path).
                            let acc_in = match self.down[i] {
                                Some(DownMsg::Pv { val }) => val,
                                None => {
                                    assert_eq!(
                                        row, 0,
                                        "PV operand without psum below row 0 \
                                         at ({row},{col}) cycle {}",
                                        self.cycle
                                    );
                                    0.0
                                }
                                other => panic!(
                                    "PV wave met unexpected down value {other:?} \
                                     at ({row},{col}) cycle {}",
                                    self.cycle
                                ),
                            };
                            self.down[i] = None;
                            let p = if self.quantize_inputs {
                                quantize_f32(self.res[i])
                            } else {
                                self.res[i]
                            };
                            let out = acc_in + p * op.val;
                            self.mac_ops += 1;
                            self.matmul_macs += 1;
                            if row + 1 < n {
                                next_down[self.idx(row + 1, col)] = Some(DownMsg::Pv { val: out });
                            } else {
                                outs.push(BottomOut::Pv { col, val: out });
                            }
                        }
                    }
                } else if let Some(psum) = self.up[i] {
                    // An upward psum with no matching operand would mean a
                    // skew bug: MacUp operands and psums travel together.
                    panic!(
                        "orphan upward psum {psum} at ({row},{col}) cycle {}",
                        self.cycle
                    );
                }

                // ---- Downward path (non-operand-coupled messages) ----
                if let Some(msg) = self.down[i].take() {
                    match msg {
                        DownMsg::Park { val, hops, masked } => {
                            if hops == 0 {
                                // fp16 result registers (FTZ) in f16 mode;
                                // a masked lane parks exactly 0 and latches.
                                self.res[i] = if masked { 0.0 } else { self.q_res(val) };
                                self.masked[i] = masked;
                            } else if row + 1 < n {
                                next_down[self.idx(row + 1, col)] =
                                    Some(DownMsg::Park { val, hops: hops - 1, masked });
                            } else {
                                panic!(
                                    "park value fell off column {col} cycle {}",
                                    self.cycle
                                );
                            }
                        }
                        DownMsg::AddBroadcast { val } => {
                            if !self.masked[i] {
                                self.res[i] = self.q_res(self.res[i] + val);
                                self.mac_ops += 1;
                            }
                            if row + 1 < n {
                                next_down[self.idx(row + 1, col)] =
                                    Some(DownMsg::AddBroadcast { val });
                            }
                        }
                        DownMsg::AVal { val } => {
                            if row + 1 < n {
                                next_down[self.idx(row + 1, col)] = Some(DownMsg::AVal { val });
                            } else {
                                outs.push(BottomOut::AVal { col, val });
                            }
                        }
                        DownMsg::Preload { val, hops } => {
                            if hops == 0 {
                                self.stat[i] = val;
                            } else if row + 1 < n {
                                next_down[self.idx(row + 1, col)] =
                                    Some(DownMsg::Preload { val, hops: hops - 1 });
                            } else {
                                panic!(
                                    "preload value fell off column {col} cycle {}",
                                    self.cycle
                                );
                            }
                        }
                        DownMsg::RowSum { .. } | DownMsg::Pv { .. } => {
                            // These must always be consumed by an operand in
                            // the left-path arm above.
                            panic!(
                                "unconsumed {msg:?} at ({row},{col}) cycle {} — \
                                 operand wave and psum wave desynchronized",
                                self.cycle
                            );
                        }
                    }
                }
            }
        }

        // 3. Stage this cycle's top exits for CMP processing next cycle.
        for col in 0..n {
            if let Some(s) = up_exit[col] {
                assert!(
                    self.cmp_inbox[col].is_none(),
                    "structural hazard: CMP inbox col {col} cycle {}",
                    self.cycle
                );
                self.cmp_inbox[col] = Some(s);
            }
        }

        // 4. Apply edge injections queued for this boundary.
        for row in 0..n {
            if let Some(op) = self.inject_left[row].take() {
                assert!(
                    next_ops[self.idx(row, 0)].is_none(),
                    "structural hazard: left edge row {row} cycle {}",
                    self.cycle
                );
                next_ops[self.idx(row, 0)] = Some(op);
            }
        }
        for col in 0..n {
            if let Some(msg) = self.inject_top[col].take() {
                assert!(
                    next_down[self.idx(0, col)].is_none(),
                    "structural hazard: top edge col {col} cycle {}",
                    self.cycle
                );
                next_down[self.idx(0, col)] = Some(msg);
            }
        }

        // Swap: the consumed arrival buffers become next cycle's blank
        // next-buffers (they are fully drained by the loops above, which
        // `take()` every slot they read).
        self.ops.iter_mut().for_each(|x| *x = None);
        self.up.iter_mut().for_each(|x| *x = None);
        self.down.iter_mut().for_each(|x| *x = None);
        self.next_ops = std::mem::replace(&mut self.ops, next_ops);
        self.next_up = std::mem::replace(&mut self.up, next_up);
        self.next_down = std::mem::replace(&mut self.down, next_down);
        self.cycle += 1;
        outs
    }

    /// True when no value is in flight anywhere in the array.
    pub fn quiescent(&self) -> bool {
        self.ops.iter().all(Option::is_none)
            && self.up.iter().all(Option::is_none)
            && self.down.iter().all(Option::is_none)
            && self.cmp_inbox.iter().all(Option::is_none)
            && self.inject_left.iter().all(Option::is_none)
            && self.inject_top.iter().all(Option::is_none)
    }

    /// Read the resident matrix (for tests): res[row][col].
    pub fn resident(&self, row: usize, col: usize) -> f32 {
        self.res[self.idx(row, col)]
    }

    pub fn stationary(&self, row: usize, col: usize) -> f32 {
        self.stat[self.idx(row, col)]
    }

    /// Direct stationary write (used by tests; the machine preloads via
    /// the top-edge `Preload` path).
    pub fn set_stationary(&mut self, row: usize, col: usize, v: f32) {
        let i = self.idx(row, col);
        self.stat[i] = if self.quantize_inputs { quantize_f32(v) } else { v };
    }

    pub fn cmp_new_m(&self, col: usize) -> f32 {
        self.cmp[col].new_m
    }

    pub fn pwl(&self) -> &PwlExp2 {
        &self.pwl
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive a bare first matmul (upward) through a tiny array and check
    /// S = Q K^T lands at the CMP row and parks correctly.
    #[test]
    fn upward_matmul_and_park() {
        let n = 4;
        let mut a = Array::new(n, 8, false);
        // stat[k][m] = Q[m][k]; Q = identity-ish pattern.
        let q = [[1.0f32, 2.0, 0.0, 0.0],
                 [0.0, 1.0, 0.0, 0.0],
                 [0.0, 0.0, 1.0, 0.5],
                 [1.0, 0.0, 0.0, 1.0]];
        let k = [[1.0f32, 0.0, 0.0, 0.0],
                 [0.5, 1.0, 0.0, 0.0],
                 [0.0, 0.0, 2.0, 0.0],
                 [0.0, 1.0, 0.0, 1.0]];
        for m in 0..n {
            for kk in 0..n {
                a.set_stationary(kk, m, q[m][kk]);
            }
        }
        // Expected S[m][nn] = sum_k q[m][k] * kmat[nn][k].
        let mut want = [[0.0f32; 4]; 4];
        for m in 0..n {
            for nn in 0..n {
                for kk in 0..n {
                    want[m][nn] += q[m][kk] * k[nn][kk];
                }
            }
        }
        // Drive: K row nn enters array row kk at cycle nn + (n-1-kk).
        let total = 6 * n as u64;
        for cycle in 0..total {
            for kk in 0..n {
                // nn = cycle - (n-1-kk)
                let skew = (n - 1 - kk) as i64;
                let nn = cycle as i64 - skew;
                if (0..n as i64).contains(&nn) {
                    a.inject_left(kk, k[nn as usize][kk], LeftTag::MacUp);
                }
            }
            let outs = a.step();
            assert!(outs.is_empty(), "nothing should exit the bottom");
        }
        // After the run: parked S in res[nn][m], CMP max per column m.
        for m in 0..n {
            for nn in 0..n {
                assert!(
                    (a.resident(nn, m) - want[m][nn]).abs() < 1e-6,
                    "S[{m}][{nn}]: got {} want {}",
                    a.resident(nn, m),
                    want[m][nn]
                );
            }
            let want_max = (0..n).map(|nn| want[m][nn]).fold(f32::MIN, f32::max);
            assert!((a.cmp_new_m(m) - want_max).abs() < 1e-6, "rowmax col {m}");
        }
        assert!(a.quiescent());
    }

    #[test]
    fn broadcast_and_mulconst_waves() {
        let n = 3;
        let mut a = Array::new(n, 8, false);
        // Park known residents directly.
        for r in 0..n {
            for c in 0..n {
                a.res[r * n + c] = (r * n + c) as f32;
            }
        }
        // Subtract broadcast of 1.0 down column 1, then a x2 wave on row 0.
        a.inject_top(1, DownMsg::AddBroadcast { val: -1.0 });
        for _ in 0..(n + 1) {
            a.step();
        }
        for r in 0..n {
            let want = (r * n + 1) as f32 - 1.0;
            assert_eq!(a.resident(r, 1), want);
        }
        a.inject_left(0, 2.0, LeftTag::MulConst);
        for _ in 0..(n + 1) {
            a.step();
        }
        assert_eq!(a.resident(0, 0), 0.0 * 2.0);
        assert_eq!(a.resident(0, 2), 2.0 * 2.0);
    }

    #[test]
    fn pwl_wave_applies_correct_segment() {
        let n = 2;
        let mut a = Array::new(n, 8, false);
        let pwl = PwlExp2::new(8);
        // Residents: values in (-1, 0] across different segments, plus one
        // with integer part.
        a.res[0] = -0.05; // seg 0
        a.res[1] = -0.4; // seg 3
        a.res[2] = -1.3; // xf = -0.3 -> seg 2
        a.res[3] = 0.0; // seg 0
        let want: Vec<f32> = (0..4).map(|i| pwl.eval_f32(a.res[i])).collect();
        // Stream all 8 pairs along both rows, one per cycle.
        for j in 0..8u8 {
            for row in 0..n {
                a.inject_left(
                    row,
                    pwl.slopes[j as usize] as f32,
                    LeftTag::Pwl { seg: j, intercept: pwl.intercepts[j as usize] as f32 },
                );
            }
            a.step();
        }
        for _ in 0..n {
            a.step();
        }
        for i in 0..4 {
            assert!(
                (a.res[i] - want[i]).abs() <= 1e-6 * want[i].abs().max(1e-20),
                "res[{i}] got {} want {}",
                a.res[i],
                want[i]
            );
        }
    }

    #[test]
    fn rowsum_and_pv_exit_bottom() {
        let n = 3;
        let mut a = Array::new(n, 8, false);
        for r in 0..n {
            for c in 0..n {
                a.res[r * n + c] = (1 + r + c) as f32; // P[c-th row of P][r]
            }
        }
        // Rowsum wave: ones enter row r at cycle r.
        let mut sums = vec![0.0f32; n];
        let mut got = vec![false; n];
        for cycle in 0..(4 * n as u64) {
            if (cycle as usize) < n {
                a.inject_left(cycle as usize, 1.0, LeftTag::RowSum);
            }
            for out in a.step() {
                if let BottomOut::RowSum { col, val } = out {
                    sums[col] = val;
                    got[col] = true;
                }
            }
        }
        for c in 0..n {
            assert!(got[c]);
            let want: f32 = (0..n).map(|r| (1 + r + c) as f32).sum();
            assert_eq!(sums[c], want, "col {c}");
        }
    }

    #[test]
    fn mask_wave_excludes_lanes_from_max_and_parks_zero() {
        // Drive the same matmul as `upward_matmul_and_park`, but with
        // column 1's boundary register set to 2: lanes 2..3 must be
        // excluded from the CMP max, park as exact zero, and stay zero
        // through a subsequent broadcast/const wave (the masked latch).
        let n = 4;
        let mut a = Array::new(n, 8, false);
        for m in 0..n {
            for kk in 0..n {
                a.set_stationary(kk, m, if m == kk { 1.0 } else { 0.0 }); // Q = I
            }
        }
        let k = [[5.0f32, 1.0, 1.0, 1.0],
                 [1.0, 6.0, 1.0, 1.0],
                 [1.0, 1.0, 7.0, 1.0],
                 [1.0, 1.0, 1.0, 8.0]];
        for col in 0..n {
            a.cmp_set_bound(col, if col == 1 { 2 } else { n as u16 });
        }
        for cycle in 0..6 * n as u64 {
            for kk in 0..n {
                let nn = cycle as i64 - (n - 1 - kk) as i64;
                if (0..n as i64).contains(&nn) {
                    a.inject_left(kk, k[nn as usize][kk], LeftTag::MacUp);
                }
            }
            a.step();
        }
        // With Q = I, S[m][nn] = K[nn][m].  Column 1 sees 1, 6, 1, 1;
        // bound 2 keeps lanes {0, 1} -> max 6; unmasked col 3 keeps 8.
        assert_eq!(a.cmp_new_m(1), 6.0);
        assert_eq!(a.cmp_new_m(3), 8.0);
        // Masked lanes parked exactly zero; valid lanes parked normally.
        assert_eq!(a.resident(2, 1), 0.0);
        assert_eq!(a.resident(3, 1), 0.0);
        assert_eq!(a.resident(1, 1), 6.0);
        assert_eq!(a.resident(2, 3), 1.0);
        // The masked latch pins them through elementwise waves.
        a.inject_top(1, DownMsg::AddBroadcast { val: 100.0 });
        for _ in 0..n + 1 {
            a.step();
        }
        assert_eq!(a.resident(1, 1), 106.0, "valid lane takes the wave");
        assert_eq!(a.resident(2, 1), 0.0, "masked lane stays zero");
    }

    #[test]
    #[should_panic(expected = "structural hazard")]
    fn double_left_injection_panics() {
        let mut a = Array::new(2, 8, false);
        a.inject_left(0, 1.0, LeftTag::MulConst);
        a.inject_left(0, 2.0, LeftTag::MulConst);
    }

    #[test]
    fn quantization_applies_to_mac_operands() {
        let mut a = Array::new(2, 8, true);
        // 1/3 is not representable in fp16; MacUp operands get quantized.
        a.inject_left(0, 1.0 / 3.0, LeftTag::MulConst); // NOT quantized
        a.inject_left(1, 1.0 / 3.0, LeftTag::MacUp); // quantized
        // (behavioral check happens via the flash pipeline tests; here we
        // just ensure the call path doesn't quantize const waves)
        assert!(a.inject_left[0].unwrap().val == 1.0 / 3.0);
        assert!((a.inject_left[1].unwrap().val - 1.0 / 3.0).abs() > 0.0);
    }
}
