//! The N x N PE grid with FSA's three architectural additions (paper §3.1):
//! a CMP-unit row on top, a Split unit per PE, and an upward data path.
//!
//! Per cycle, every in-flight value moves exactly one hop:
//!
//! * left operands move right along their row (one port per PE);
//! * upward partial sums move from row k+1 to row k (first matmul);
//! * downward values move from row k-1 to row k (S park, broadcasts,
//!   rowsum, PV psums, stationary preload);
//! * the CMP row consumes the top-row upward exits (running rowmax), and
//!   re-emits values downward (S re-streaming, -new_m broadcast, a = old_m
//!   - new_m pass-down).
//!
//! Each port accepts at most one value per cycle; a second injection into
//! an occupied slot is a *structural hazard* and panics with a diagnostic
//! — the cycle-model tests rely on this to prove the SystolicAttention
//! schedule is legal.
//!
//! ## Struct-of-arrays layout (DESIGN.md §8)
//!
//! The three wave buffers are stored as separate lane vectors (tag/kind
//! byte + payload lanes + hop counters + the §8 masked-sideband bits)
//! rather than `Vec<Option<enum>>`: the hot row/column advance then runs
//! as contiguous slice copies and tag-homogeneous runs the autovectorizer
//! can take.  [`Array::step`] dispatches to the vectorized path;
//! [`Array::scalar_reference_step`] keeps the frozen pre-refactor per-lane
//! control flow as the differential-reference twin
//! (`tests/sim_differential.rs`, `benches/simcycles.rs`).  The two paths
//! are bitwise-equal in state and emit the same structural-hazard panics
//! at the same cycles for single-fault scenarios.

use crate::numerics::f16::quantize_ftz_f32 as quantize_f32;
use crate::numerics::pwl::PwlExp2;

/// Operand tag traveling with left-injected values (hardware sends these
/// as sideband control bits alongside the data bus).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LeftTag {
    /// First matmul: up_psum += stat * x (K stream; upward path).
    MacUp,
    /// In-place multiply: res *= x (the log2(e)/sqrt(d) constant wave).
    MulConst,
    /// PWL pair wave j: if the PE's fraction segment == `seg`, apply
    /// res = 2^xi * (slope * xf + intercept).  `intercept` rides in the
    /// second payload lane (hardware streams it from the top edge with the
    /// segment index encoded in its exponent MSBs — §3.3; the sim carries
    /// the pair together and checks the encoding property in unit tests).
    Pwl { seg: u8, intercept: f32 },
    /// Rowsum: down_psum += res (fp32), streaming "ones" wave.
    RowSum,
    /// Second matmul: down_psum += f16(res) * x (V stream; downward path).
    MacDown,
}

/// Values on the downward path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DownMsg {
    /// S value re-streamed from the CMP row; parks in `hops` more rows.
    /// `masked` is the §8 mask-wave sideband bit: the lane fell at or
    /// beyond the CMP boundary register, so it parks as zero and sets
    /// the PE's masked latch (its P stays exactly 0 through the
    /// element-wise chain, the rowsum and the PV wave).
    Park { val: f32, hops: u16, masked: bool },
    /// -new_m broadcast: every PE on the way applies res += val.
    AddBroadcast { val: f32 },
    /// a = old_m - new_m passing through to the accumulator.
    AVal { val: f32 },
    /// Rowsum partial sum.
    RowSum { val: f32 },
    /// Second-matmul partial sum (the accumulator recovers the output
    /// index h from per-column arrival order — outputs exit in h order by
    /// construction of the static schedule).
    Pv { val: f32 },
    /// Stationary preload value; lands in the stationary register after
    /// `hops` more rows.
    Preload { val: f32, hops: u16 },
}

/// A value leaving the bottom edge into the accumulator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BottomOut {
    AVal { col: usize, val: f32 },
    RowSum { col: usize, val: f32 },
    Pv { col: usize, val: f32 },
}

#[derive(Clone, Copy, Debug)]
struct LeftOp {
    val: f32,
    tag: LeftTag,
}

// Operand-wave tag bytes (struct-of-arrays encoding of `LeftTag`).
const OP_NONE: u8 = 0;
const OP_MAC_UP: u8 = 1;
const OP_MUL_CONST: u8 = 2;
const OP_PWL: u8 = 3;
const OP_ROW_SUM: u8 = 4;
const OP_MAC_DOWN: u8 = 5;

// Downward-wave kind bytes (struct-of-arrays encoding of `DownMsg`).
const DOWN_NONE: u8 = 0;
const DOWN_PARK: u8 = 1;
const DOWN_ADD_BROADCAST: u8 = 2;
const DOWN_AVAL: u8 = 3;
const DOWN_ROW_SUM: u8 = 4;
const DOWN_PV: u8 = 5;
const DOWN_PRELOAD: u8 = 6;

/// Left-operand wave, one lane per PE: tag byte + payload (`val`), the
/// PWL intercept in a second payload lane (`aux`) and the PWL segment
/// index (`seg`).  Payload lanes of `OP_NONE` slots are dead (every read
/// is tag-guarded), so the one-hop-right advance is a plain slice shift.
#[derive(Default)]
struct OpWave {
    tag: Vec<u8>,
    val: Vec<f32>,
    aux: Vec<f32>,
    seg: Vec<u8>,
}

impl OpWave {
    fn new(len: usize) -> OpWave {
        OpWave {
            tag: vec![OP_NONE; len],
            val: vec![0.0; len],
            aux: vec![0.0; len],
            seg: vec![0; len],
        }
    }

    fn clear(&mut self) {
        self.tag.fill(OP_NONE);
    }

    fn set(&mut self, i: usize, op: LeftOp) {
        self.val[i] = op.val;
        self.tag[i] = match op.tag {
            LeftTag::MacUp => OP_MAC_UP,
            LeftTag::MulConst => OP_MUL_CONST,
            LeftTag::Pwl { seg, intercept } => {
                self.seg[i] = seg;
                self.aux[i] = intercept;
                OP_PWL
            }
            LeftTag::RowSum => OP_ROW_SUM,
            LeftTag::MacDown => OP_MAC_DOWN,
        };
    }

    fn decode(&self, i: usize) -> Option<LeftOp> {
        let tag = match self.tag[i] {
            OP_NONE => return None,
            OP_MAC_UP => LeftTag::MacUp,
            OP_MUL_CONST => LeftTag::MulConst,
            OP_PWL => LeftTag::Pwl { seg: self.seg[i], intercept: self.aux[i] },
            OP_ROW_SUM => LeftTag::RowSum,
            OP_MAC_DOWN => LeftTag::MacDown,
            t => unreachable!("bad op tag {t}"),
        };
        Some(LeftOp { val: self.val[i], tag })
    }
}

/// Upward-psum wave.  Invariant: `val[i] == 0.0` whenever `!live[i]`, so
/// the MacUp accumulate (`val + stat * op`) is the old `unwrap_or(0.0)`
/// without a branch.
#[derive(Default)]
struct UpWave {
    live: Vec<bool>,
    val: Vec<f32>,
}

impl UpWave {
    fn new(len: usize) -> UpWave {
        UpWave { live: vec![false; len], val: vec![0.0; len] }
    }

    fn clear(&mut self) {
        self.live.fill(false);
        self.val.fill(0.0);
    }
}

/// Downward wave: kind byte + payload + park/preload hop counter + the
/// §8 masked-sideband bit.  Payload lanes of `DOWN_NONE` slots are dead.
#[derive(Default)]
struct DownWave {
    kind: Vec<u8>,
    val: Vec<f32>,
    hops: Vec<u16>,
    masked: Vec<bool>,
}

impl DownWave {
    fn new(len: usize) -> DownWave {
        DownWave {
            kind: vec![DOWN_NONE; len],
            val: vec![0.0; len],
            hops: vec![0; len],
            masked: vec![false; len],
        }
    }

    fn clear(&mut self) {
        self.kind.fill(DOWN_NONE);
    }

    fn set(&mut self, i: usize, msg: DownMsg) {
        self.kind[i] = match msg {
            DownMsg::Park { val, hops, masked } => {
                self.val[i] = val;
                self.hops[i] = hops;
                self.masked[i] = masked;
                DOWN_PARK
            }
            DownMsg::AddBroadcast { val } => {
                self.val[i] = val;
                DOWN_ADD_BROADCAST
            }
            DownMsg::AVal { val } => {
                self.val[i] = val;
                DOWN_AVAL
            }
            DownMsg::RowSum { val } => {
                self.val[i] = val;
                DOWN_ROW_SUM
            }
            DownMsg::Pv { val } => {
                self.val[i] = val;
                DOWN_PV
            }
            DownMsg::Preload { val, hops } => {
                self.val[i] = val;
                self.hops[i] = hops;
                DOWN_PRELOAD
            }
        };
    }

    /// Rebuild the enum for a live lane (cold paths only: panic
    /// diagnostics must format exactly like the pre-refactor messages).
    fn msg(&self, i: usize) -> DownMsg {
        match self.kind[i] {
            DOWN_PARK => DownMsg::Park {
                val: self.val[i],
                hops: self.hops[i],
                masked: self.masked[i],
            },
            DOWN_ADD_BROADCAST => DownMsg::AddBroadcast { val: self.val[i] },
            DOWN_AVAL => DownMsg::AVal { val: self.val[i] },
            DOWN_ROW_SUM => DownMsg::RowSum { val: self.val[i] },
            DOWN_PV => DownMsg::Pv { val: self.val[i] },
            DOWN_PRELOAD => DownMsg::Preload { val: self.val[i], hops: self.hops[i] },
            k => unreachable!("bad down kind {k}"),
        }
    }

    fn decode(&self, i: usize) -> Option<DownMsg> {
        if self.kind[i] == DOWN_NONE {
            None
        } else {
            Some(self.msg(i))
        }
    }
}

/// Finite stand-in for -inf: keeps the Split unit NaN-free (same
/// convention as the Pallas kernel and flash references).
pub const NEG_INF: f32 = -1e30;

/// The PE grid + CMP row.  See module docs for the stepping contract and
/// the struct-of-arrays layout.
pub struct Array {
    pub n: usize,
    /// PWL segments for the Split-unit exp2.
    pwl: PwlExp2,
    /// Softmax scale log2(e)/sqrt(d) applied by the MulConst wave
    /// (kept here for the CMP a-value handoff; the wave carries it too).
    pub quantize_inputs: bool,
    /// Step with the frozen pre-refactor per-lane path instead of the
    /// vectorized one ([`MachineConfig::scalar_reference`]
    /// (crate::sim::MachineConfig::scalar_reference) plumbs it here).
    pub scalar_reference: bool,

    // State, all row-major [row * n + col]:
    stat: Vec<f32>,
    res: Vec<f32>,
    /// Per-PE masked latch (§8 mask wave): set by a masked park, cleared
    /// by the next unmasked one.  While set, the element-wise waves skip
    /// the PE so its parked zero stays exactly zero.
    masked: Vec<bool>,
    /// Left operands *arriving* at each PE this cycle.
    ops: OpWave,
    /// Upward psums arriving this cycle (from the row below).
    up: UpWave,
    /// Downward values arriving this cycle (from the row above).
    down: DownWave,

    // CMP row (paper §3.1), one lane per column: running old/new row max,
    // the arrival counter (park hop count) and the §8 boundary register
    // ([`crate::isa::LaneBound`] resolved per column by the controller) —
    // arrivals at `seen >= bound` are masked lanes, excluded from the
    // running max and re-streamed as zero with the masked sideband bit.
    cmp_old: Vec<f32>,
    cmp_new: Vec<f32>,
    cmp_seen: Vec<u16>,
    cmp_bound: Vec<u16>,
    /// S values that exited the top last cycle, processed by the CMP row
    /// this cycle (one-cycle CMP latency, matching §3.2's timing).
    cmp_inbox_live: Vec<bool>,
    cmp_inbox_val: Vec<f32>,

    /// Pending edge injections for the *next* step: left[row], top[col].
    inject_left: Vec<Option<LeftOp>>,
    inject_top: Vec<Option<DownMsg>>,

    // Double buffers reused across cycles (perf: avoids 3 x n^2 Vec
    // allocations per simulated cycle — see EXPERIMENTS.md §Perf).
    next_ops: OpWave,
    next_up: UpWave,
    next_down: DownWave,

    // Per-step scratch (n lanes): top exits staged for the CMP inbox, and
    // bottom exits staged per column so the vectorized two-pass row sweep
    // emits them in the same column-ascending order as the per-lane path.
    up_exit_live: Vec<bool>,
    up_exit_val: Vec<f32>,
    bottom: Vec<Option<BottomOut>>,

    pub cycle: u64,
    /// Busy-PE count accumulated per cycle (utilization accounting).
    pub mac_ops: u64,
    /// MACs spent in the two matmuls only (useful-FLOPs accounting).
    pub matmul_macs: u64,
}

impl Array {
    pub fn new(n: usize, segments: usize, quantize_inputs: bool) -> Array {
        Array {
            n,
            pwl: PwlExp2::new(segments),
            quantize_inputs,
            scalar_reference: false,
            stat: vec![0.0; n * n],
            res: vec![0.0; n * n],
            masked: vec![false; n * n],
            ops: OpWave::new(n * n),
            up: UpWave::new(n * n),
            down: DownWave::new(n * n),
            cmp_old: vec![NEG_INF; n],
            cmp_new: vec![NEG_INF; n],
            cmp_seen: vec![0; n],
            cmp_bound: vec![u16::MAX; n],
            cmp_inbox_live: vec![false; n],
            cmp_inbox_val: vec![0.0; n],
            inject_left: vec![None; n],
            inject_top: vec![None; n],
            next_ops: OpWave::new(n * n),
            next_up: UpWave::new(n * n),
            next_down: DownWave::new(n * n),
            up_exit_live: vec![false; n],
            up_exit_val: vec![0.0; n],
            bottom: vec![None; n],
            cycle: 0,
            mac_ops: 0,
            matmul_macs: 0,
        }
    }

    /// Reset every register, wave buffer and counter to the
    /// just-constructed state.  This is the shard-batching hazard fence
    /// (DESIGN.md §8): a machine reused across independent shards calls
    /// this between programs so the next run is bitwise the run a fresh
    /// machine would produce.
    pub fn reset(&mut self) {
        self.stat.fill(0.0);
        self.res.fill(0.0);
        self.masked.fill(false);
        self.ops.clear();
        self.up.clear();
        self.down.clear();
        self.next_ops.clear();
        self.next_up.clear();
        self.next_down.clear();
        self.cmp_old.fill(NEG_INF);
        self.cmp_new.fill(NEG_INF);
        self.cmp_seen.fill(0);
        self.cmp_bound.fill(u16::MAX);
        self.cmp_inbox_live.fill(false);
        self.inject_left.fill(None);
        self.inject_top.fill(None);
        self.up_exit_live.fill(false);
        self.bottom.fill(None);
        self.cycle = 0;
        self.mac_ops = 0;
        self.matmul_macs = 0;
    }

    /// Queue a left-edge injection for row `row` (consumed by the next
    /// [`Self::step`]).  Panics on port contention.
    pub fn inject_left(&mut self, row: usize, val: f32, tag: LeftTag) {
        assert!(
            self.inject_left[row].is_none(),
            "structural hazard: left port of row {row} double-driven at cycle {}",
            self.cycle
        );
        let (val, tag) = if self.quantize_inputs {
            match tag {
                LeftTag::MacUp | LeftTag::MacDown => (quantize_f32(val), tag),
                LeftTag::Pwl { seg, intercept } => (
                    quantize_f32(val),
                    LeftTag::Pwl { seg, intercept: quantize_f32(intercept) },
                ),
                _ => (val, tag),
            }
        } else {
            (val, tag)
        };
        self.inject_left[row] = Some(LeftOp { val, tag });
    }

    /// Queue a top-edge downward injection into column `col` (stationary
    /// preload uses this path; CMP-sourced values are emitted by
    /// [`Self::cmp_emit_sub`] / [`Self::cmp_emit_a`] instead).
    pub fn inject_top(&mut self, col: usize, msg: DownMsg) {
        assert!(
            self.inject_top[col].is_none(),
            "structural hazard: top port of column {col} double-driven at cycle {}",
            self.cycle
        );
        self.inject_top[col] = Some(msg);
    }

    /// Reset CMP unit `col` for a new row block (AttnScore with
    /// `first = true`): old max becomes -inf.
    pub fn cmp_reset(&mut self, col: usize) {
        self.cmp_old[col] = NEG_INF;
        self.cmp_new[col] = NEG_INF;
        self.cmp_seen[col] = 0;
        self.cmp_bound[col] = u16::MAX;
    }

    /// Begin a new inner iteration at CMP `col`: the running max of the
    /// previous iteration becomes old_m, the arrival counter clears.
    pub fn cmp_next_iter(&mut self, col: usize) {
        self.cmp_old[col] = self.cmp_new[col];
        self.cmp_seen[col] = 0;
    }

    /// Program CMP `col`'s boundary register for the coming iteration
    /// (§8 mask wave): arrivals at `seen >= bound` are masked.  The
    /// controller emits this for every AttnScore — `n` (all lanes
    /// valid) when the score is unmasked.
    pub fn cmp_set_bound(&mut self, col: usize, bound: u16) {
        self.cmp_bound[col] = bound;
    }

    /// CMP row emits the -new_m broadcast into column `col`.
    pub fn cmp_emit_sub(&mut self, col: usize) {
        let v = -self.cmp_new[col];
        self.inject_top(col, DownMsg::AddBroadcast { val: v });
    }

    /// CMP row emits a = old_m - new_m toward the accumulator.
    pub fn cmp_emit_a(&mut self, col: usize) {
        let v = self.cmp_old[col] - self.cmp_new[col];
        self.inject_top(col, DownMsg::AVal { val: v });
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        row * self.n + col
    }

    /// Result-register write quantization: fp16 + flush-to-zero in f16
    /// mode (PE result registers are half-precision), identity otherwise.
    #[inline]
    fn q_res(&self, v: f32) -> f32 {
        if self.quantize_inputs {
            quantize_f32(v)
        } else {
            v
        }
    }

    /// Advance one clock cycle.  Returns every value that left the bottom
    /// edge this cycle (routed to the accumulator by the machine).
    pub fn step(&mut self) -> Vec<BottomOut> {
        let mut outs = Vec::new();
        self.step_into(&mut outs);
        outs
    }

    /// [`Self::step`] into a caller-owned buffer (the machine's per-cycle
    /// loop reuses one Vec instead of allocating each cycle).
    pub fn step_into(&mut self, outs: &mut Vec<BottomOut>) {
        outs.clear();
        if self.scalar_reference {
            self.scalar_step_into(outs);
        } else {
            self.vector_step_into(outs);
        }
    }

    /// CMP row: process last cycle's top exits (one-cycle latency):
    /// update the running max and re-stream S down the column.  Shared
    /// verbatim by both stepping paths.
    fn cmp_phase(&mut self, next_down: &mut DownWave) {
        for col in 0..self.n {
            if self.cmp_inbox_live[col] {
                self.cmp_inbox_live[col] = false;
                // The fp32 psum is quantized to the fp16 register width
                // *here* so the tracked max and the parked value are the
                // same number (otherwise the max row's N could land just
                // above zero and skip the Split unit's sign-guarded PWL).
                let s = self.q_res(self.cmp_inbox_val[col]);
                // §8 mask wave: a lane at or beyond the boundary register
                // is excluded from the running max and parks as zero with
                // the masked sideband bit set.
                let masked = self.cmp_seen[col] >= self.cmp_bound[col];
                if !masked {
                    self.cmp_new[col] = self.cmp_new[col].max(s);
                }
                let hops = self.cmp_seen[col];
                self.cmp_seen[col] += 1;
                next_down.set(
                    col, // row 0
                    DownMsg::Park { val: if masked { 0.0 } else { s }, hops, masked },
                );
            }
        }
    }

    /// Stage this cycle's top exits for CMP processing next cycle, then
    /// apply the edge injections queued for this boundary.  Shared
    /// verbatim by both stepping paths.
    fn edges_phase(&mut self, next_ops: &mut OpWave, next_down: &mut DownWave) {
        let n = self.n;
        for col in 0..n {
            if self.up_exit_live[col] {
                self.up_exit_live[col] = false;
                assert!(
                    !self.cmp_inbox_live[col],
                    "structural hazard: CMP inbox col {col} cycle {}",
                    self.cycle
                );
                self.cmp_inbox_live[col] = true;
                self.cmp_inbox_val[col] = self.up_exit_val[col];
            }
        }
        for row in 0..n {
            if let Some(op) = self.inject_left[row].take() {
                assert!(
                    next_ops.tag[row * n] == OP_NONE,
                    "structural hazard: left edge row {row} cycle {}",
                    self.cycle
                );
                next_ops.set(row * n, op);
            }
        }
        for col in 0..n {
            if let Some(msg) = self.inject_top[col].take() {
                assert!(
                    next_down.kind[col] == DOWN_NONE,
                    "structural hazard: top edge col {col} cycle {}",
                    self.cycle
                );
                next_down.set(col, msg);
            }
        }
    }

    /// Vectorized per-PE advance: the operand wave moves one hop right as
    /// a whole-row slice shift, then each row is processed as contiguous
    /// tag-homogeneous runs (operand pass, then downward pass) — the
    /// run bodies are branch-light loops over adjacent lanes that the
    /// autovectorizer can take.  Lane arithmetic is the exact per-lane
    /// fp32/fp16 expression of the scalar path, so state stays bitwise
    /// identical.
    fn vector_step_into(&mut self, outs: &mut Vec<BottomOut>) {
        let n = self.n;
        let ops = std::mem::take(&mut self.ops);
        let up = std::mem::take(&mut self.up);
        let mut down = std::mem::take(&mut self.down);
        let mut next_ops = std::mem::take(&mut self.next_ops);
        let mut next_up = std::mem::take(&mut self.next_up);
        let mut next_down = std::mem::take(&mut self.next_down);

        self.cmp_phase(&mut next_down);

        for row in 0..n {
            let base = row * n;

            // Operand wave forward: ops[r][c] -> next_ops[r][c+1], the
            // whole row at once (NONE lanes copy harmlessly; column 0 of
            // the next buffer is left for the edge injection below).
            if n > 1 {
                next_ops.tag[base + 1..base + n].copy_from_slice(&ops.tag[base..base + n - 1]);
                next_ops.val[base + 1..base + n].copy_from_slice(&ops.val[base..base + n - 1]);
                next_ops.aux[base + 1..base + n].copy_from_slice(&ops.aux[base..base + n - 1]);
                next_ops.seg[base + 1..base + n].copy_from_slice(&ops.seg[base..base + n - 1]);
            }

            // ---- Operand pass, in tag-homogeneous runs ----
            let mut c0 = 0usize;
            while c0 < n {
                let tag = ops.tag[base + c0];
                let mut c1 = c0 + 1;
                while c1 < n && ops.tag[base + c1] == tag {
                    c1 += 1;
                }
                match tag {
                    OP_NONE => {
                        // An upward psum with no matching operand would
                        // mean a skew bug: MacUp operands and psums
                        // travel together.
                        for col in c0..c1 {
                            let i = base + col;
                            if up.live[i] {
                                panic!(
                                    "orphan upward psum {} at ({row},{col}) cycle {}",
                                    up.val[i], self.cycle
                                );
                            }
                        }
                    }
                    OP_MAC_UP => {
                        self.mac_ops += (c1 - c0) as u64;
                        self.matmul_macs += (c1 - c0) as u64;
                        if row == 0 {
                            for col in c0..c1 {
                                let i = base + col;
                                self.up_exit_val[col] = up.val[i] + self.stat[i] * ops.val[i];
                                self.up_exit_live[col] = true;
                            }
                        } else {
                            for col in c0..c1 {
                                let i = base + col;
                                next_up.val[i - n] = up.val[i] + self.stat[i] * ops.val[i];
                                next_up.live[i - n] = true;
                            }
                        }
                    }
                    OP_MUL_CONST => {
                        for col in c0..c1 {
                            let i = base + col;
                            if !self.masked[i] {
                                self.res[i] = self.q_res(self.res[i] * ops.val[i]);
                                self.mac_ops += 1;
                            }
                        }
                    }
                    OP_PWL => {
                        // Split unit: decompose the resident value.  Sign
                        // guard = one-shot latch: exp2 inputs are always
                        // <= 0 and outputs always > 0, so a PE whose
                        // register is already positive has consumed its
                        // pair (cheap hardware: sign bit).  The §8 masked
                        // latch overrides: a masked lane's parked zero
                        // must stay exactly zero.
                        for col in c0..c1 {
                            let i = base + col;
                            let x = self.res[i];
                            let xi = x.ceil();
                            let xf = self.q_res(x - xi);
                            let k = self.pwl.segment(xf as f64) as u8;
                            if !self.masked[i] && x <= 0.0 && k == ops.seg[i] {
                                // fp16 interpolation MAC (PE datapath).
                                let frac = self.q_res(ops.val[i] * xf + ops.aux[i]);
                                self.res[i] =
                                    self.q_res(frac * xi.clamp(-126.0, 127.0).exp2());
                                self.mac_ops += 1;
                            }
                        }
                    }
                    OP_ROW_SUM => {
                        self.mac_ops += (c1 - c0) as u64;
                        for col in c0..c1 {
                            let i = base + col;
                            let acc_in = match down.kind[i] {
                                DOWN_ROW_SUM => down.val[i],
                                DOWN_NONE => 0.0,
                                _ => panic!(
                                    "rowsum wave met unexpected down value {:?} \
                                     at ({row},{col}) cycle {}",
                                    down.decode(i),
                                    self.cycle
                                ),
                            };
                            down.kind[i] = DOWN_NONE;
                            let out = acc_in + self.res[i];
                            if row + 1 < n {
                                next_down.kind[i + n] = DOWN_ROW_SUM;
                                next_down.val[i + n] = out;
                            } else {
                                self.bottom[col] = Some(BottomOut::RowSum { col, val: out });
                            }
                        }
                    }
                    OP_MAC_DOWN => {
                        self.mac_ops += (c1 - c0) as u64;
                        self.matmul_macs += (c1 - c0) as u64;
                        for col in c0..c1 {
                            let i = base + col;
                            // PV psums are born at row 0 (downward path).
                            let acc_in = match down.kind[i] {
                                DOWN_PV => down.val[i],
                                DOWN_NONE => {
                                    assert_eq!(
                                        row, 0,
                                        "PV operand without psum below row 0 \
                                         at ({row},{col}) cycle {}",
                                        self.cycle
                                    );
                                    0.0
                                }
                                _ => panic!(
                                    "PV wave met unexpected down value {:?} \
                                     at ({row},{col}) cycle {}",
                                    down.decode(i),
                                    self.cycle
                                ),
                            };
                            down.kind[i] = DOWN_NONE;
                            let p = if self.quantize_inputs {
                                quantize_f32(self.res[i])
                            } else {
                                self.res[i]
                            };
                            let out = acc_in + p * ops.val[i];
                            if row + 1 < n {
                                next_down.kind[i + n] = DOWN_PV;
                                next_down.val[i + n] = out;
                            } else {
                                self.bottom[col] = Some(BottomOut::Pv { col, val: out });
                            }
                        }
                    }
                    t => unreachable!("bad op tag {t}"),
                }
                c0 = c1;
            }

            // ---- Downward pass (non-operand-coupled messages), in
            // kind-homogeneous runs; lanes consumed by the operand pass
            // above are DOWN_NONE by now ----
            let mut c0 = 0usize;
            while c0 < n {
                let kind = down.kind[base + c0];
                let mut c1 = c0 + 1;
                while c1 < n && down.kind[base + c1] == kind {
                    c1 += 1;
                }
                match kind {
                    DOWN_NONE => {}
                    DOWN_PARK => {
                        for col in c0..c1 {
                            let i = base + col;
                            if down.hops[i] == 0 {
                                // fp16 result registers (FTZ) in f16
                                // mode; a masked lane parks exactly 0
                                // and latches.
                                let m = down.masked[i];
                                self.res[i] = if m { 0.0 } else { self.q_res(down.val[i]) };
                                self.masked[i] = m;
                            } else if row + 1 < n {
                                next_down.kind[i + n] = DOWN_PARK;
                                next_down.val[i + n] = down.val[i];
                                next_down.hops[i + n] = down.hops[i] - 1;
                                next_down.masked[i + n] = down.masked[i];
                            } else {
                                panic!(
                                    "park value fell off column {col} cycle {}",
                                    self.cycle
                                );
                            }
                        }
                    }
                    DOWN_ADD_BROADCAST => {
                        for col in c0..c1 {
                            let i = base + col;
                            if !self.masked[i] {
                                self.res[i] = self.q_res(self.res[i] + down.val[i]);
                                self.mac_ops += 1;
                            }
                        }
                        if row + 1 < n {
                            next_down.kind[base + n + c0..base + n + c1]
                                .fill(DOWN_ADD_BROADCAST);
                            next_down.val[base + n + c0..base + n + c1]
                                .copy_from_slice(&down.val[base + c0..base + c1]);
                        }
                    }
                    DOWN_AVAL => {
                        if row + 1 < n {
                            next_down.kind[base + n + c0..base + n + c1].fill(DOWN_AVAL);
                            next_down.val[base + n + c0..base + n + c1]
                                .copy_from_slice(&down.val[base + c0..base + c1]);
                        } else {
                            for col in c0..c1 {
                                self.bottom[col] =
                                    Some(BottomOut::AVal { col, val: down.val[base + col] });
                            }
                        }
                    }
                    DOWN_PRELOAD => {
                        for col in c0..c1 {
                            let i = base + col;
                            if down.hops[i] == 0 {
                                self.stat[i] = down.val[i];
                            } else if row + 1 < n {
                                next_down.kind[i + n] = DOWN_PRELOAD;
                                next_down.val[i + n] = down.val[i];
                                next_down.hops[i + n] = down.hops[i] - 1;
                            } else {
                                panic!(
                                    "preload value fell off column {col} cycle {}",
                                    self.cycle
                                );
                            }
                        }
                    }
                    DOWN_ROW_SUM | DOWN_PV => {
                        // These must always be consumed by an operand in
                        // the operand pass above.
                        let col = c0;
                        panic!(
                            "unconsumed {:?} at ({row},{col}) cycle {} — \
                             operand wave and psum wave desynchronized",
                            down.msg(base + col),
                            self.cycle
                        );
                    }
                    k => unreachable!("bad down kind {k}"),
                }
                c0 = c1;
            }
        }

        // Bottom exits, in the per-lane path's column-ascending order (at
        // most one exit per column per cycle: an operand that emits
        // downward consumed the lane's down slot or panicked, so the two
        // passes can never both stage the same column).
        for col in 0..n {
            if let Some(o) = self.bottom[col].take() {
                outs.push(o);
            }
        }

        self.edges_phase(&mut next_ops, &mut next_down);
        self.finish_step(ops, up, down, next_ops, next_up, next_down);
    }

    /// The frozen pre-refactor per-lane stepping path, kept verbatim as
    /// the differential-reference twin: `tests/sim_differential.rs` pins
    /// the vectorized path bitwise against it, and `benches/simcycles.rs`
    /// sweeps old-vs-new host throughput.  Not `#[cfg(test)]` precisely
    /// so the bench (a non-test build) can drive it.
    pub fn scalar_reference_step(&mut self) -> Vec<BottomOut> {
        let mut outs = Vec::new();
        self.scalar_step_into(&mut outs);
        outs
    }

    fn scalar_step_into(&mut self, outs: &mut Vec<BottomOut>) {
        let n = self.n;
        let ops = std::mem::take(&mut self.ops);
        let up = std::mem::take(&mut self.up);
        let mut down = std::mem::take(&mut self.down);
        let mut next_ops = std::mem::take(&mut self.next_ops);
        let mut next_up = std::mem::take(&mut self.next_up);
        let mut next_down = std::mem::take(&mut self.next_down);

        self.cmp_phase(&mut next_down);

        // Per-PE processing, lane by lane in row-major order.  Movement
        // semantics: ops[r][c] (arriving this cycle) -> next_ops[r][c+1];
        // up[r][c] is the psum arriving at (r, c) this cycle from
        // (r+1, c); after row r adds its term it becomes next_up[r-1][c]
        // (or exits to CMP when r == 0).  Down likewise, top-down.
        for row in 0..n {
            for col in 0..n {
                let i = row * n + col;
                // ---- Left operand path ----
                if let Some(op) = ops.decode(i) {
                    // Forward right (unless at the last column).
                    if col + 1 < n {
                        next_ops.set(i + 1, op);
                    }
                    match op.tag {
                        LeftTag::MacUp => {
                            let acc_in = if up.live[i] { up.val[i] } else { 0.0 };
                            let term = self.stat[i] * op.val;
                            let out = acc_in + term;
                            self.mac_ops += 1;
                            self.matmul_macs += 1;
                            if row == 0 {
                                self.up_exit_val[col] = out;
                                self.up_exit_live[col] = true;
                            } else {
                                next_up.val[i - n] = out;
                                next_up.live[i - n] = true;
                            }
                        }
                        LeftTag::MulConst => {
                            if !self.masked[i] {
                                self.res[i] = self.q_res(self.res[i] * op.val);
                                self.mac_ops += 1;
                            }
                        }
                        LeftTag::Pwl { seg, intercept } => {
                            let x = self.res[i];
                            let xi = x.ceil();
                            let xf = self.q_res(x - xi);
                            let k = self.pwl.segment(xf as f64) as u8;
                            if !self.masked[i] && x <= 0.0 && k == seg {
                                let frac = self.q_res(op.val * xf + intercept);
                                self.res[i] =
                                    self.q_res(frac * xi.clamp(-126.0, 127.0).exp2());
                                self.mac_ops += 1;
                            }
                        }
                        LeftTag::RowSum => {
                            let acc_in = match down.decode(i) {
                                Some(DownMsg::RowSum { val }) => val,
                                None => 0.0,
                                other => panic!(
                                    "rowsum wave met unexpected down value {other:?} \
                                     at ({row},{col}) cycle {}",
                                    self.cycle
                                ),
                            };
                            down.kind[i] = DOWN_NONE;
                            let out = acc_in + self.res[i];
                            self.mac_ops += 1;
                            if row + 1 < n {
                                next_down.set(i + n, DownMsg::RowSum { val: out });
                            } else {
                                outs.push(BottomOut::RowSum { col, val: out });
                            }
                        }
                        LeftTag::MacDown => {
                            // PV psums are born at row 0 (downward path).
                            let acc_in = match down.decode(i) {
                                Some(DownMsg::Pv { val }) => val,
                                None => {
                                    assert_eq!(
                                        row, 0,
                                        "PV operand without psum below row 0 \
                                         at ({row},{col}) cycle {}",
                                        self.cycle
                                    );
                                    0.0
                                }
                                other => panic!(
                                    "PV wave met unexpected down value {other:?} \
                                     at ({row},{col}) cycle {}",
                                    self.cycle
                                ),
                            };
                            down.kind[i] = DOWN_NONE;
                            let p = if self.quantize_inputs {
                                quantize_f32(self.res[i])
                            } else {
                                self.res[i]
                            };
                            let out = acc_in + p * op.val;
                            self.mac_ops += 1;
                            self.matmul_macs += 1;
                            if row + 1 < n {
                                next_down.set(i + n, DownMsg::Pv { val: out });
                            } else {
                                outs.push(BottomOut::Pv { col, val: out });
                            }
                        }
                    }
                } else if up.live[i] {
                    panic!(
                        "orphan upward psum {} at ({row},{col}) cycle {}",
                        up.val[i], self.cycle
                    );
                }

                // ---- Downward path (non-operand-coupled messages) ----
                if let Some(msg) = down.decode(i) {
                    down.kind[i] = DOWN_NONE;
                    match msg {
                        DownMsg::Park { val, hops, masked } => {
                            if hops == 0 {
                                self.res[i] = if masked { 0.0 } else { self.q_res(val) };
                                self.masked[i] = masked;
                            } else if row + 1 < n {
                                next_down
                                    .set(i + n, DownMsg::Park { val, hops: hops - 1, masked });
                            } else {
                                panic!(
                                    "park value fell off column {col} cycle {}",
                                    self.cycle
                                );
                            }
                        }
                        DownMsg::AddBroadcast { val } => {
                            if !self.masked[i] {
                                self.res[i] = self.q_res(self.res[i] + val);
                                self.mac_ops += 1;
                            }
                            if row + 1 < n {
                                next_down.set(i + n, DownMsg::AddBroadcast { val });
                            }
                        }
                        DownMsg::AVal { val } => {
                            if row + 1 < n {
                                next_down.set(i + n, DownMsg::AVal { val });
                            } else {
                                outs.push(BottomOut::AVal { col, val });
                            }
                        }
                        DownMsg::Preload { val, hops } => {
                            if hops == 0 {
                                self.stat[i] = val;
                            } else if row + 1 < n {
                                next_down.set(i + n, DownMsg::Preload { val, hops: hops - 1 });
                            } else {
                                panic!(
                                    "preload value fell off column {col} cycle {}",
                                    self.cycle
                                );
                            }
                        }
                        DownMsg::RowSum { .. } | DownMsg::Pv { .. } => {
                            // These must always be consumed by an operand
                            // in the left-path arm above.
                            panic!(
                                "unconsumed {msg:?} at ({row},{col}) cycle {} — \
                                 operand wave and psum wave desynchronized",
                                self.cycle
                            );
                        }
                    }
                }
            }
        }

        self.edges_phase(&mut next_ops, &mut next_down);
        self.finish_step(ops, up, down, next_ops, next_up, next_down);
    }

    /// Swap: the consumed arrival buffers become next cycle's blank
    /// next-buffers (the passes drain every slot they read; `clear`
    /// wipes the tag/kind/live lanes wholesale).
    fn finish_step(
        &mut self,
        mut ops: OpWave,
        mut up: UpWave,
        mut down: DownWave,
        next_ops: OpWave,
        next_up: UpWave,
        next_down: DownWave,
    ) {
        ops.clear();
        up.clear();
        down.clear();
        self.ops = next_ops;
        self.next_ops = ops;
        self.up = next_up;
        self.next_up = up;
        self.down = next_down;
        self.next_down = down;
        self.cycle += 1;
    }

    /// True when no value is in flight anywhere in the array.
    pub fn quiescent(&self) -> bool {
        self.ops.tag.iter().all(|&t| t == OP_NONE)
            && !self.up.live.iter().any(|&l| l)
            && self.down.kind.iter().all(|&k| k == DOWN_NONE)
            && !self.cmp_inbox_live.iter().any(|&l| l)
            && self.inject_left.iter().all(Option::is_none)
            && self.inject_top.iter().all(Option::is_none)
    }

    /// Read the resident matrix (for tests): res[row][col].
    pub fn resident(&self, row: usize, col: usize) -> f32 {
        self.res[self.idx(row, col)]
    }

    pub fn stationary(&self, row: usize, col: usize) -> f32 {
        self.stat[self.idx(row, col)]
    }

    /// Direct stationary write (used by tests; the machine preloads via
    /// the top-edge `Preload` path).
    pub fn set_stationary(&mut self, row: usize, col: usize, v: f32) {
        let i = self.idx(row, col);
        self.stat[i] = if self.quantize_inputs { quantize_f32(v) } else { v };
    }

    pub fn cmp_new_m(&self, col: usize) -> f32 {
        self.cmp_new[col]
    }

    pub fn pwl(&self) -> &PwlExp2 {
        &self.pwl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::SplitMix64;

    /// Drive a bare first matmul (upward) through a tiny array and check
    /// S = Q K^T lands at the CMP row and parks correctly.
    #[test]
    fn upward_matmul_and_park() {
        let n = 4;
        let mut a = Array::new(n, 8, false);
        // stat[k][m] = Q[m][k]; Q = identity-ish pattern.
        let q = [[1.0f32, 2.0, 0.0, 0.0],
                 [0.0, 1.0, 0.0, 0.0],
                 [0.0, 0.0, 1.0, 0.5],
                 [1.0, 0.0, 0.0, 1.0]];
        let k = [[1.0f32, 0.0, 0.0, 0.0],
                 [0.5, 1.0, 0.0, 0.0],
                 [0.0, 0.0, 2.0, 0.0],
                 [0.0, 1.0, 0.0, 1.0]];
        for m in 0..n {
            for kk in 0..n {
                a.set_stationary(kk, m, q[m][kk]);
            }
        }
        // Expected S[m][nn] = sum_k q[m][k] * kmat[nn][k].
        let mut want = [[0.0f32; 4]; 4];
        for m in 0..n {
            for nn in 0..n {
                for kk in 0..n {
                    want[m][nn] += q[m][kk] * k[nn][kk];
                }
            }
        }
        // Drive: K row nn enters array row kk at cycle nn + (n-1-kk).
        let total = 6 * n as u64;
        for cycle in 0..total {
            for kk in 0..n {
                // nn = cycle - (n-1-kk)
                let skew = (n - 1 - kk) as i64;
                let nn = cycle as i64 - skew;
                if (0..n as i64).contains(&nn) {
                    a.inject_left(kk, k[nn as usize][kk], LeftTag::MacUp);
                }
            }
            let outs = a.step();
            assert!(outs.is_empty(), "nothing should exit the bottom");
        }
        // After the run: parked S in res[nn][m], CMP max per column m.
        for m in 0..n {
            for nn in 0..n {
                assert!(
                    (a.resident(nn, m) - want[m][nn]).abs() < 1e-6,
                    "S[{m}][{nn}]: got {} want {}",
                    a.resident(nn, m),
                    want[m][nn]
                );
            }
            let want_max = (0..n).map(|nn| want[m][nn]).fold(f32::MIN, f32::max);
            assert!((a.cmp_new_m(m) - want_max).abs() < 1e-6, "rowmax col {m}");
        }
        assert!(a.quiescent());
    }

    #[test]
    fn broadcast_and_mulconst_waves() {
        let n = 3;
        let mut a = Array::new(n, 8, false);
        // Park known residents directly.
        for r in 0..n {
            for c in 0..n {
                a.res[r * n + c] = (r * n + c) as f32;
            }
        }
        // Subtract broadcast of 1.0 down column 1, then a x2 wave on row 0.
        a.inject_top(1, DownMsg::AddBroadcast { val: -1.0 });
        for _ in 0..(n + 1) {
            a.step();
        }
        for r in 0..n {
            let want = (r * n + 1) as f32 - 1.0;
            assert_eq!(a.resident(r, 1), want);
        }
        a.inject_left(0, 2.0, LeftTag::MulConst);
        for _ in 0..(n + 1) {
            a.step();
        }
        assert_eq!(a.resident(0, 0), 0.0 * 2.0);
        assert_eq!(a.resident(0, 2), 2.0 * 2.0);
    }

    #[test]
    fn pwl_wave_applies_correct_segment() {
        let n = 2;
        let mut a = Array::new(n, 8, false);
        let pwl = PwlExp2::new(8);
        // Residents: values in (-1, 0] across different segments, plus one
        // with integer part.
        a.res[0] = -0.05; // seg 0
        a.res[1] = -0.4; // seg 3
        a.res[2] = -1.3; // xf = -0.3 -> seg 2
        a.res[3] = 0.0; // seg 0
        let want: Vec<f32> = (0..4).map(|i| pwl.eval_f32(a.res[i])).collect();
        // Stream all 8 pairs along both rows, one per cycle.
        for j in 0..8u8 {
            for row in 0..n {
                a.inject_left(
                    row,
                    pwl.slopes[j as usize] as f32,
                    LeftTag::Pwl { seg: j, intercept: pwl.intercepts[j as usize] as f32 },
                );
            }
            a.step();
        }
        for _ in 0..n {
            a.step();
        }
        for i in 0..4 {
            assert!(
                (a.res[i] - want[i]).abs() <= 1e-6 * want[i].abs().max(1e-20),
                "res[{i}] got {} want {}",
                a.res[i],
                want[i]
            );
        }
    }

    #[test]
    fn rowsum_and_pv_exit_bottom() {
        let n = 3;
        let mut a = Array::new(n, 8, false);
        for r in 0..n {
            for c in 0..n {
                a.res[r * n + c] = (1 + r + c) as f32; // P[c-th row of P][r]
            }
        }
        // Rowsum wave: ones enter row r at cycle r.
        let mut sums = vec![0.0f32; n];
        let mut got = vec![false; n];
        for cycle in 0..(4 * n as u64) {
            if (cycle as usize) < n {
                a.inject_left(cycle as usize, 1.0, LeftTag::RowSum);
            }
            for out in a.step() {
                if let BottomOut::RowSum { col, val } = out {
                    sums[col] = val;
                    got[col] = true;
                }
            }
        }
        for c in 0..n {
            assert!(got[c]);
            let want: f32 = (0..n).map(|r| (1 + r + c) as f32).sum();
            assert_eq!(sums[c], want, "col {c}");
        }
    }

    #[test]
    fn mask_wave_excludes_lanes_from_max_and_parks_zero() {
        // Drive the same matmul as `upward_matmul_and_park`, but with
        // column 1's boundary register set to 2: lanes 2..3 must be
        // excluded from the CMP max, park as exact zero, and stay zero
        // through a subsequent broadcast/const wave (the masked latch).
        let n = 4;
        let mut a = Array::new(n, 8, false);
        for m in 0..n {
            for kk in 0..n {
                a.set_stationary(kk, m, if m == kk { 1.0 } else { 0.0 }); // Q = I
            }
        }
        let k = [[5.0f32, 1.0, 1.0, 1.0],
                 [1.0, 6.0, 1.0, 1.0],
                 [1.0, 1.0, 7.0, 1.0],
                 [1.0, 1.0, 1.0, 8.0]];
        for col in 0..n {
            a.cmp_set_bound(col, if col == 1 { 2 } else { n as u16 });
        }
        for cycle in 0..6 * n as u64 {
            for kk in 0..n {
                let nn = cycle as i64 - (n - 1 - kk) as i64;
                if (0..n as i64).contains(&nn) {
                    a.inject_left(kk, k[nn as usize][kk], LeftTag::MacUp);
                }
            }
            a.step();
        }
        // With Q = I, S[m][nn] = K[nn][m].  Column 1 sees 1, 6, 1, 1;
        // bound 2 keeps lanes {0, 1} -> max 6; unmasked col 3 keeps 8.
        assert_eq!(a.cmp_new_m(1), 6.0);
        assert_eq!(a.cmp_new_m(3), 8.0);
        // Masked lanes parked exactly zero; valid lanes parked normally.
        assert_eq!(a.resident(2, 1), 0.0);
        assert_eq!(a.resident(3, 1), 0.0);
        assert_eq!(a.resident(1, 1), 6.0);
        assert_eq!(a.resident(2, 3), 1.0);
        // The masked latch pins them through elementwise waves.
        a.inject_top(1, DownMsg::AddBroadcast { val: 100.0 });
        for _ in 0..n + 1 {
            a.step();
        }
        assert_eq!(a.resident(1, 1), 106.0, "valid lane takes the wave");
        assert_eq!(a.resident(2, 1), 0.0, "masked lane stays zero");
    }

    #[test]
    #[should_panic(expected = "structural hazard")]
    fn double_left_injection_panics() {
        let mut a = Array::new(2, 8, false);
        a.inject_left(0, 1.0, LeftTag::MulConst);
        a.inject_left(0, 2.0, LeftTag::MulConst);
    }

    #[test]
    fn quantization_applies_to_mac_operands() {
        let mut a = Array::new(2, 8, true);
        // 1/3 is not representable in fp16; MacUp operands get quantized.
        a.inject_left(0, 1.0 / 3.0, LeftTag::MulConst); // NOT quantized
        a.inject_left(1, 1.0 / 3.0, LeftTag::MacUp); // quantized
        // (behavioral check happens via the flash pipeline tests; here we
        // just ensure the call path doesn't quantize const waves)
        assert!(a.inject_left[0].unwrap().val == 1.0 / 3.0);
        assert!((a.inject_left[1].unwrap().val - 1.0 / 3.0).abs() > 0.0);
    }

    /// Drive the same randomized (legal) injection schedule through a
    /// vectorized array and its scalar-reference twin, comparing the full
    /// observable state after every phase — the in-module half of the
    /// `tests/sim_differential.rs` contract.
    #[test]
    fn vectorized_step_matches_scalar_reference_on_random_waves() {
        let n = 4;
        let mut rng = SplitMix64::new(0xA113);
        for trial in 0..4 {
            let mut v = Array::new(n, 8, trial % 2 == 0);
            let mut s = Array::new(n, 8, trial % 2 == 0);
            s.scalar_reference = true;

            let assert_same = |v: &Array, s: &Array, what: &str| {
                let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&v.res), bits(&s.res), "res after {what} trial {trial}");
                assert_eq!(bits(&v.stat), bits(&s.stat), "stat after {what}");
                assert_eq!(v.masked, s.masked, "masked after {what}");
                assert_eq!(bits(&v.cmp_new), bits(&s.cmp_new), "cmp_new after {what}");
                assert_eq!(bits(&v.cmp_old), bits(&s.cmp_old), "cmp_old after {what}");
                assert_eq!(v.cmp_seen, s.cmp_seen, "cmp_seen after {what}");
                assert_eq!(v.cycle, s.cycle, "cycle after {what}");
                assert_eq!(v.mac_ops, s.mac_ops, "mac_ops after {what}");
                assert_eq!(v.matmul_macs, s.matmul_macs, "matmul_macs after {what}");
            };

            // Phase 1: stationary preload + bounds + skewed MacUp matmul.
            for r in 0..n {
                for c in 0..n {
                    let x = rng.next_normal() as f32;
                    v.set_stationary(r, c, x);
                    s.set_stationary(r, c, x);
                }
            }
            for col in 0..n {
                let b = 1 + rng.next_below(n as u64) as u16;
                v.cmp_set_bound(col, b);
                s.cmp_set_bound(col, b);
            }
            let kmat: Vec<f32> = (0..n * n).map(|_| rng.next_normal() as f32).collect();
            for cycle in 0..6 * n as i64 {
                for kk in 0..n {
                    let nn = cycle - (n - 1 - kk) as i64;
                    if (0..n as i64).contains(&nn) {
                        let x = kmat[nn as usize * n + kk];
                        v.inject_left(kk, x, LeftTag::MacUp);
                        s.inject_left(kk, x, LeftTag::MacUp);
                    }
                }
                assert_eq!(v.step(), s.scalar_reference_step());
            }
            assert_same(&v, &s, "matmul");

            // Phase 2: -new_m broadcast + a-value passdown + const wave.
            for col in 0..n {
                v.cmp_emit_sub(col);
                s.cmp_emit_sub(col);
            }
            for row in 0..n {
                v.inject_left(row, 0.7, LeftTag::MulConst);
                s.inject_left(row, 0.7, LeftTag::MulConst);
            }
            for _ in 0..n + 2 {
                assert_eq!(v.step(), s.scalar_reference_step());
            }
            for col in 0..n {
                v.cmp_emit_a(col);
                s.cmp_emit_a(col);
            }
            for _ in 0..n + 2 {
                assert_eq!(v.step(), s.scalar_reference_step());
            }
            assert_same(&v, &s, "elementwise");

            // Phase 3: PWL pairs, then skewed rowsum + PV waves.
            let pwl = PwlExp2::new(8);
            for j in 0..8u8 {
                for row in 0..n {
                    let sl = pwl.slopes[j as usize] as f32;
                    let ic = pwl.intercepts[j as usize] as f32;
                    v.inject_left(row, sl, LeftTag::Pwl { seg: j, intercept: ic });
                    s.inject_left(row, sl, LeftTag::Pwl { seg: j, intercept: ic });
                }
                assert_eq!(v.step(), s.scalar_reference_step());
            }
            let vmat: Vec<f32> = (0..n).map(|_| rng.next_normal() as f32).collect();
            for cycle in 0..4 * n as i64 {
                if (0..n as i64).contains(&cycle) {
                    v.inject_left(cycle as usize, 1.0, LeftTag::RowSum);
                    s.inject_left(cycle as usize, 1.0, LeftTag::RowSum);
                }
                assert_eq!(v.step(), s.scalar_reference_step());
            }
            for cycle in 0..4 * n as i64 {
                if (0..n as i64).contains(&cycle) {
                    let x = vmat[cycle as usize];
                    v.inject_left(cycle as usize, x, LeftTag::MacDown);
                    s.inject_left(cycle as usize, x, LeftTag::MacDown);
                }
                assert_eq!(v.step(), s.scalar_reference_step());
            }
            assert_same(&v, &s, "rowsum+pv");
            assert!(v.quiescent() && s.quiescent());
        }
    }

    /// `reset` restores the just-constructed state (the shard-batching
    /// hazard fence): a reused array replays a program bitwise like a
    /// fresh one.
    #[test]
    fn reset_restores_fresh_state() {
        let n = 3;
        let run = |a: &mut Array| {
            for r in 0..n {
                for c in 0..n {
                    a.set_stationary(r, c, (r + 2 * c) as f32);
                }
            }
            for cycle in 0..6 * n as i64 {
                for kk in 0..n {
                    let nn = cycle - (n - 1 - kk) as i64;
                    if (0..n as i64).contains(&nn) {
                        a.inject_left(kk, (nn + kk as i64) as f32, LeftTag::MacUp);
                    }
                }
                a.step();
            }
            (a.res.clone(), a.cmp_new.clone(), a.cycle, a.mac_ops)
        };
        let mut fresh = Array::new(n, 8, true);
        let want = run(&mut fresh);
        let mut reused = Array::new(n, 8, true);
        run(&mut reused);
        reused.reset();
        assert_eq!(reused.cycle, 0);
        assert_eq!(reused.mac_ops, 0);
        assert!(reused.quiescent());
        assert_eq!(run(&mut reused), want, "post-reset run differs from fresh");
    }
}
