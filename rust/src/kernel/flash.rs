//! The FlashAttention FSA kernel — Listing 2 of the paper, expressed with
//! the Rust kernel builder.
//!
//! Layout convention matches the paper: Q, K, V are `(L, d)` row-major in
//! main memory (V is *not* pre-transposed here: the device streams V rows
//! along array rows, so the natural row-major layout is already right for
//! our DMA model), and the output is produced transposed (`Ot`, `[d, Br]`
//! per row-block) exactly as the accumulation SRAM holds it; the host
//! runtime de-transposes, as Listing 2 does with `.to_numpy().T`.

use anyhow::ensure;

use crate::isa::{Program, Space, TileDesc};
use crate::kernel::builder::{ATile, Alloc, KernelBuilder, MTile, STile};
use crate::mask::{MaskKind, TileCoverage};

/// Static workload description.
#[derive(Clone, Copy, Debug)]
pub struct FlashParams {
    /// Sequence length (queries == keys/values length here).
    pub seq_len: usize,
    /// Head dim == array dim == Br == Bc (paper §3.5 tiling).
    pub d: usize,
    /// Scratchpad / accumulator capacities in elements.
    pub spad_elems: u32,
    pub accum_elems: u32,
}

/// Where the kernel expects its operands in device main memory.
#[derive(Clone, Copy, Debug)]
pub struct FlashLayout {
    pub q_addr: u32,
    pub k_addr: u32,
    pub v_addr: u32,
    /// Output O^T blocks: row-block i lives at `o_addr + i*d*d` as a
    /// `[d, Br]` tile (host de-transposes).
    pub o_addr: u32,
}

impl FlashLayout {
    /// Packed default layout for a given workload.
    pub fn packed(p: &FlashParams) -> FlashLayout {
        let mat = (p.seq_len * p.d) as u32;
        FlashLayout { q_addr: 0, k_addr: mat, v_addr: 2 * mat, o_addr: 3 * mat }
    }

    /// Total main-memory elements the kernel touches.
    pub fn mem_elems(&self, p: &FlashParams) -> usize {
        self.o_addr as usize + p.seq_len * p.d
    }
}

/// Build the full FlashAttention program (Listing 2): double-buffered K/V
/// loads, per-row-block Q preload, the attn_score/attn_value inner loop,
/// and the reciprocal + lse-norm + store epilogue.
pub fn flash_attention_program(p: &FlashParams, layout: &FlashLayout) -> crate::Result<Program> {
    flash_attention_program_masked(p, layout, MaskKind::None)
}

/// Masked variant with the tile-skipping schedule (DESIGN.md §6): fully
/// masked `(row block, column block)` tiles are never emitted — no K/V
/// load, no attn_score/attn_value — which is exact because a fully
/// masked tile contributes nothing to any row's online-softmax state.
/// For causal this halves the instruction stream (the `t(t-1)/2` upper
/// triangle disappears; asserted by the unit tests).
///
/// Partially masked tiles (causal diagonal, padding boundary) are
/// emitted unchanged here: the element-wise mask wave that zeroes their
/// invalid lanes is a controller wave below the ISA's instruction
/// granularity, priced by `schedule::InnerSchedule::masked_inner_latency`
/// and modeled exactly by the reference numerics — encoding it as an ISA
/// flag is listed in DESIGN.md §future-work alongside masked artifacts.
pub fn flash_attention_program_masked(
    p: &FlashParams,
    layout: &FlashLayout,
    mask: MaskKind,
) -> crate::Result<Program> {
    let n = p.d;
    ensure!(p.seq_len % n == 0, "seq_len {} must be a multiple of d {}", p.seq_len, n);
    let tiles = p.seq_len / n;
    let nn = n as u16;

    let q_mem = MTile(TileDesc::contiguous(Space::Main, layout.q_addr, p.seq_len as u16, nn));
    let k_mem = MTile(TileDesc::contiguous(Space::Main, layout.k_addr, p.seq_len as u16, nn));
    let v_mem = MTile(TileDesc::contiguous(Space::Main, layout.v_addr, p.seq_len as u16, nn));

    let q_blocks = q_mem.split_rows(nn);
    let k_blocks = k_mem.split_rows(nn);
    let v_blocks = v_mem.split_rows(nn);

    // Double buffering (Listing 2): ping-pong STile pairs for Q, K, V.
    let mut spad = Alloc::new(Space::Spad, p.spad_elems);
    let q_st = [STile(spad.tile(nn, nn)?), STile(spad.tile(nn, nn)?)];
    let k_st = [STile(spad.tile(nn, nn)?), STile(spad.tile(nn, nn)?)];
    let v_st = [STile(spad.tile(nn, nn)?), STile(spad.tile(nn, nn)?)];

    // Accumulator: log-exp-sum vector + O^T tile (reused per row block —
    // legal because the epilogue store completes before the next block's
    // first attn_value, which the machine scoreboards).
    let mut accum = Alloc::new(Space::Accum, p.accum_elems);
    let lse = ATile(accum.tile(1, nn)?);
    let ot = ATile(accum.tile(nn, nn)?);

    let mut b = KernelBuilder::new();
    for (i, q_i) in q_blocks.iter().enumerate() {
        b.load_tile(*q_i, q_st[i % 2])?;
        // Tile-skipping schedule: only issue column tiles the mask
        // leaves at least partially live; ping-pong buffers alternate
        // over *issued* tiles, and the `first` accumulate-reset flag
        // belongs to the first issued tile of the row block.
        let mut issued = 0usize;
        for (j, (k_j, v_j)) in k_blocks.iter().zip(&v_blocks).enumerate() {
            if mask.coverage(i * n, n, j * n, n) == TileCoverage::Empty {
                continue;
            }
            b.load_stationary(q_st[i % 2]);
            b.load_tile(*k_j, k_st[issued % 2])?;
            b.attn_score(k_st[issued % 2], lse, issued == 0);
            b.load_tile(*v_j, v_st[issued % 2])?;
            b.attn_value(v_st[issued % 2], ot, issued == 0);
            issued += 1;
        }
        ensure!(issued > 0, "mask leaves row block {i} without any live tile");
        b.reciprocal(lse);
        b.attn_lse_norm(ot, lse);
        // O^T block i -> main memory.
        let o_dst = MTile(TileDesc::contiguous(
            Space::Main,
            layout.o_addr + (i * n * n) as u32,
            nn,
            nn,
        ));
        b.store_tile(ot, o_dst)?;
        let _ = tiles;
    }
    Ok(b.build())
}

/// De-transpose the stored `[d, Br]` output blocks into a row-major
/// `(L, d)` matrix (the host-side `.T` of Listing 2).
pub fn detranspose_output(mem: &[f32], layout: &FlashLayout, p: &FlashParams) -> Vec<f32> {
    let n = p.d;
    let tiles = p.seq_len / n;
    let mut out = vec![0.0f32; p.seq_len * n];
    for i in 0..tiles {
        let base = layout.o_addr as usize + i * n * n;
        for h in 0..n {
            for m in 0..n {
                out[(i * n + m) * n + h] = mem[base + h * n + m];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instruction;

    #[test]
    fn program_shape_matches_listing2() {
        let p = FlashParams { seq_len: 512, d: 128, spad_elems: 6 * 128 * 128, accum_elems: 128 * 129 };
        let layout = FlashLayout::packed(&p);
        let prog = flash_attention_program(&p, &layout).unwrap();
        let t = 512 / 128;
        // Per row block: 1 Q load + t*(stationary + K load + score + V
        // load + value) + recip + norm + store.
        assert_eq!(prog.len(), t * (1 + t * 5 + 3));
        let (loads, stores, computes) = prog.class_counts();
        assert_eq!(loads, t + 2 * t * t);
        assert_eq!(stores, t);
        assert_eq!(computes, t * (3 * t + 2));
        // First instruction loads Q block 0; first compute is stationary.
        assert!(matches!(prog.instructions[0], Instruction::LoadTile { .. }));
        assert!(matches!(prog.instructions[1], Instruction::LoadStationary { .. }));
    }

    #[test]
    fn first_flags_reset_per_row_block() {
        let p = FlashParams { seq_len: 256, d: 128, spad_elems: 6 * 128 * 128, accum_elems: 128 * 129 };
        let prog = flash_attention_program(&p, &FlashLayout::packed(&p)).unwrap();
        let firsts: Vec<bool> = prog
            .instructions
            .iter()
            .filter_map(|i| match i {
                Instruction::AttnScore { first, .. } => Some(*first),
                _ => None,
            })
            .collect();
        assert_eq!(firsts, vec![true, false, true, false]);
    }

    #[test]
    fn causal_program_skips_the_upper_triangle() {
        let p = FlashParams { seq_len: 512, d: 128, spad_elems: 6 * 128 * 128, accum_elems: 128 * 129 };
        let layout = FlashLayout::packed(&p);
        let square = flash_attention_program(&p, &layout).unwrap();
        let causal = flash_attention_program_masked(&p, &layout, MaskKind::Causal).unwrap();
        let t = 512 / 128;
        // Row block i issues i+1 column tiles instead of t: the inner
        // loop shrinks from t² = 16 to t(t+1)/2 = 10 iterations.
        let issued = t * (t + 1) / 2;
        assert_eq!(causal.len(), t * (1 + 3) + issued * 5);
        assert!(causal.len() < square.len());
        let (loads, stores, computes) = causal.class_counts();
        assert_eq!(loads, t + 2 * issued, "1 Q load per block + K/V per issued tile");
        assert_eq!(stores, t);
        assert_eq!(computes, 3 * issued + 2 * t);
        // The accumulate-reset flag moves to the first *issued* tile of
        // each row block — exactly one reset per block.
        let firsts: Vec<bool> = causal
            .instructions
            .iter()
            .filter_map(|i| match i {
                Instruction::AttnScore { first, .. } => Some(*first),
                _ => None,
            })
            .collect();
        assert_eq!(firsts.len(), issued);
        assert_eq!(firsts.iter().filter(|&&f| f).count(), t);
        // An unmasked mask reproduces the Listing-2 program exactly.
        let none = flash_attention_program_masked(&p, &layout, MaskKind::None).unwrap();
        assert_eq!(none.len(), square.len());
        // A fully-masking padding mask is rejected, not miscompiled.
        assert!(flash_attention_program_masked(
            &p,
            &layout,
            MaskKind::PaddingKeys { valid: 0 }
        )
        .is_err());
    }

    #[test]
    fn paper_spad_budget_suffices() {
        // §6.1 footnote: 192 KiB scratchpad supports the algorithm with
        // double buffering: 6 tiles of 128x128 fp16 = 196608 B exactly.
        // The 64 KiB accumulation SRAM holds the fp32 O^T tile exactly;
        // the 128-entry l vector lives in accumulator-unit registers
        // (modeled as +n elements here).
        let p = FlashParams {
            seq_len: 16384,
            d: 128,
            spad_elems: 192 * 1024 / 2,      // fp16 elements in 192 KiB
            accum_elems: 64 * 1024 / 4 + 128, // f32 elements + l registers
        };
        assert!(flash_attention_program(&p, &FlashLayout::packed(&p)).is_ok());
        // One fp16 element less of scratchpad must fail: the budget is tight.
        let q = FlashParams { spad_elems: 192 * 1024 / 2 - 1, ..p };
        assert!(flash_attention_program(&q, &FlashLayout::packed(&q)).is_err());
    }

    #[test]
    fn detranspose_round_trip() {
        let p = FlashParams { seq_len: 4, d: 2, spad_elems: 1024, accum_elems: 1024 };
        let layout = FlashLayout::packed(&p);
        // Two blocks of O^T [2, 2]: block i holds O^T[h][m] = O[m][h].
        let mut mem = vec![0.0f32; layout.mem_elems(&p)];
        let base = layout.o_addr as usize;
        // Block 0: O = [[1, 2], [3, 4]] -> O^T = [[1, 3], [2, 4]].
        mem[base..base + 4].copy_from_slice(&[1.0, 3.0, 2.0, 4.0]);
        // Block 1: O = [[5, 6], [7, 8]].
        mem[base + 4..base + 8].copy_from_slice(&[5.0, 7.0, 6.0, 8.0]);
        let out = detranspose_output(&mem, &layout, &p);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
    }
}
