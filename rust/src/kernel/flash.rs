//! The FlashAttention FSA kernel — Listing 2 of the paper, expressed with
//! the Rust kernel builder.
//!
//! Layout convention matches the paper: Q, K, V are `(L, d)` row-major in
//! main memory (V is *not* pre-transposed here: the device streams V rows
//! along array rows, so the natural row-major layout is already right for
//! our DMA model), and the output is produced transposed (`Ot`, `[d, Br]`
//! per row-block) exactly as the accumulation SRAM holds it; the host
//! runtime de-transposes, as Listing 2 does with `.to_numpy().T`.

use anyhow::ensure;

use crate::isa::{LaneBound, Program, Space, TileDesc};
use crate::kernel::builder::{ATile, Alloc, KernelBuilder, MTile, STile};
use crate::mask::MaskKind;

/// Static workload description.
#[derive(Clone, Copy, Debug)]
pub struct FlashParams {
    /// Sequence length (queries == keys/values length here).
    pub seq_len: usize,
    /// Head dim == array dim == Br == Bc (paper §3.5 tiling).
    pub d: usize,
    /// Scratchpad / accumulator capacities in elements.
    pub spad_elems: u32,
    pub accum_elems: u32,
}

/// Where the kernel expects its operands in device main memory.
#[derive(Clone, Copy, Debug)]
pub struct FlashLayout {
    pub q_addr: u32,
    pub k_addr: u32,
    pub v_addr: u32,
    /// Output O^T blocks: row-block i lives at `o_addr + i*d*d` as a
    /// `[d, Br]` tile (host de-transposes).
    pub o_addr: u32,
}

impl FlashLayout {
    /// Packed default layout for a given workload.
    pub fn packed(p: &FlashParams) -> FlashLayout {
        let mat = (p.seq_len * p.d) as u32;
        FlashLayout { q_addr: 0, k_addr: mat, v_addr: 2 * mat, o_addr: 3 * mat }
    }

    /// Total main-memory elements the kernel touches.
    pub fn mem_elems(&self, p: &FlashParams) -> usize {
        self.o_addr as usize + p.seq_len * p.d
    }
}

/// Build the full FlashAttention program (Listing 2): double-buffered K/V
/// loads, per-row-block Q preload, the attn_score/attn_value inner loop,
/// and the reciprocal + lse-norm + store epilogue.
pub fn flash_attention_program(p: &FlashParams, layout: &FlashLayout) -> crate::Result<Program> {
    flash_attention_program_masked(p, layout, MaskKind::None)
}

/// Masked variant with the tile-skipping schedule (DESIGN.md §6): fully
/// masked `(row block, column block)` tiles are never emitted — no K/V
/// load, no attn_score/attn_value — which is exact because a fully
/// masked tile contributes nothing to any row's online-softmax state.
/// For causal this halves the instruction stream (the `t(t-1)/2` upper
/// triangle disappears; asserted by the unit tests).
///
/// Partially masked tiles (causal diagonal, padding boundary) are
/// emitted with the §8 mask wave encoded ([`crate::isa::LaneBound`] via
/// `MaskBound` + the AttnScore mask flag), so running the program on
/// the cycle simulator computes them bit-exactly — the CMP row excludes
/// masked lanes from the rowmax and parks them as zero.  Priced by
/// `schedule::InnerSchedule::masked_inner_latency` (one extra
/// element-wise cycle), matching the perfmodel.
pub fn flash_attention_program_masked(
    p: &FlashParams,
    layout: &FlashLayout,
    mask: MaskKind,
) -> crate::Result<Program> {
    let n = p.d;
    ensure!(p.seq_len % n == 0, "seq_len {} must be a multiple of d {}", p.seq_len, n);
    let cp = ChunkParams {
        n,
        valid_queries: p.seq_len,
        query_offset: 0,
        valid_keys: p.seq_len,
        key_offset: 0,
        total_keys: p.seq_len,
        mask,
        spad_elems: p.spad_elems,
        accum_elems: p.accum_elems,
    };
    let cl = ChunkLayout {
        q_addr: layout.q_addr,
        k_addr: layout.k_addr,
        v_addr: layout.v_addr,
        o_addr: layout.o_addr,
        // The legacy layout carries no l region; normalized programs
        // never store it.
        l_addr: layout.o_addr,
    };
    flash_chunk_program(&cp, &cl)
}

// ---------------------------------------------------------------------
// Serving-shaped program variants (DESIGN.md §8): the units the sim
// backend executes.  Q/K/V live zero-padded to whole N x N tiles; the
// §8 mask wave covers partial tiles AND the zero-padded ragged tails,
// so any (seq_len, d <= N) shape runs on the array bit-exactly.
// ---------------------------------------------------------------------

/// One serving-shaped workload: the (zero-padded) query sequence
/// against one key/value chunk at *global* key coordinates — the whole
/// sequence for stateless/prefill heads (`key_offset = 0`,
/// `valid_keys == total_keys`), a sub-range for sequence-parallel
/// chunks, and a single query row for decode
/// ([`ChunkParams::decode_row`]).
#[derive(Clone, Copy, Debug)]
pub struct ChunkParams {
    /// Array dim N (tile size; the head dim rides zero-padded to it).
    pub n: usize,
    /// Real query rows (the rest of the last row block is zero padding;
    /// its columns compute garbage the caller never reads).
    pub valid_queries: usize,
    /// Global query index of the first real query row — nonzero only
    /// for resumed (prefix-cache warm) prefills, whose Q buffer holds
    /// just the suffix rows.  The mask wave is programmed at global
    /// query coordinates, so the suffix rows compute bitwise what the
    /// cold run computed for them (DESIGN.md §11).
    pub query_offset: usize,
    /// Real key rows in this chunk.
    pub valid_keys: usize,
    /// Global key index of the chunk's first key.
    pub key_offset: usize,
    /// Real keys of the whole sequence (mask coordinates).
    pub total_keys: usize,
    pub mask: MaskKind,
    pub spad_elems: u32,
    pub accum_elems: u32,
}

impl ChunkParams {
    /// Whole-sequence params for one `(seq_len, d)` head on an `n`-array
    /// with the default 6-tile scratchpad / lse+O^T accumulator budget.
    pub fn whole(n: usize, seq_len: usize, mask: MaskKind) -> ChunkParams {
        ChunkParams {
            n,
            valid_queries: seq_len,
            query_offset: 0,
            valid_keys: seq_len,
            key_offset: 0,
            total_keys: seq_len,
            mask,
            spad_elems: (6 * n * n) as u32,
            accum_elems: (n * n + n) as u32,
        }
    }

    /// The `br = 1` decode-row degeneration: one real query row over a
    /// `prefix_len`-key prefix, no mask (the step row attends the whole
    /// prefix; a ragged final tile rides zero-padded under the wave).
    pub fn decode_row(n: usize, prefix_len: usize) -> ChunkParams {
        let mut p = ChunkParams::whole(n, prefix_len, MaskKind::None);
        p.valid_queries = 1;
        p
    }

    /// Sequence-parallel chunk params: keys `[key_offset, key_offset +
    /// chunk_len)` of a `total_keys` sequence (DESIGN.md §7).
    pub fn chunk(
        n: usize,
        seq_len: usize,
        mask: MaskKind,
        key_offset: usize,
        chunk_len: usize,
        total_keys: usize,
    ) -> ChunkParams {
        let mut p = ChunkParams::whole(n, seq_len, mask);
        p.valid_keys = chunk_len;
        p.key_offset = key_offset;
        p.total_keys = total_keys;
        p
    }

    /// Resumed-prefill chunk params (DESIGN.md §11): only the suffix
    /// query rows `[query_offset, seq_len)` are present in the Q
    /// buffer, over keys `[key_offset, key_offset + chunk_len)` of a
    /// `total_keys` sequence.  `query_offset = 0` reproduces
    /// [`ChunkParams::chunk`].
    pub fn resumed(
        n: usize,
        seq_len: usize,
        mask: MaskKind,
        query_offset: usize,
        key_offset: usize,
        chunk_len: usize,
        total_keys: usize,
    ) -> ChunkParams {
        assert!(query_offset < seq_len, "resume point must leave suffix rows");
        let mut p = ChunkParams::chunk(n, seq_len, mask, key_offset, chunk_len, total_keys);
        p.valid_queries = seq_len - query_offset;
        p.query_offset = query_offset;
        p
    }

    /// Query rows padded up to whole row blocks.
    pub fn padded_queries(&self) -> usize {
        self.valid_queries.div_ceil(self.n).max(1) * self.n
    }

    /// Key rows padded up to whole column tiles.
    pub fn padded_keys(&self) -> usize {
        self.valid_keys.div_ceil(self.n).max(1) * self.n
    }

    /// Row blocks of the padded query sequence.
    pub fn row_blocks(&self) -> usize {
        self.padded_queries() / self.n
    }

    /// The §8 lane boundary of tile `(row block i, column tile j)` and
    /// whether the tile is issued at all (live for at least one *real*
    /// query row).  The boundary is exactly the reference kernel's
    /// per-row valid-lane prefix, `clamp(valid_keys(q) - key_offset -
    /// lk0, 0, w)` with `w` the tile's real key lanes — linear in the
    /// stationary column for both mask kinds.
    pub fn tile_bound(&self, block: usize, col_tile: usize) -> (bool, LaneBound) {
        let n = self.n;
        let lq0 = block * n;
        let gq0 = self.query_offset + lq0;
        let lk0 = col_tile * n;
        let w = n.min(self.valid_keys.saturating_sub(lk0));
        let gk0 = (self.key_offset + lk0) as i64;
        let bound = match self.mask {
            MaskKind::Causal => LaneBound {
                base: (gq0 as i64 + 1 - gk0).clamp(i32::MIN as i64, i32::MAX as i64) as i32,
                diag: true,
                cap: w as u16,
            },
            MaskKind::None => LaneBound { base: w as i32, diag: false, cap: w as u16 },
            MaskKind::PaddingKeys { valid } => LaneBound {
                base: (valid as i64 - gk0).clamp(0, w as i64) as i32,
                diag: false,
                cap: w as u16,
            },
        };
        let rows_real = n.min(self.valid_queries.saturating_sub(lq0));
        let live = w > 0 && (0..rows_real).any(|m| bound.bound(m) > 0);
        (live, bound)
    }
}

/// Where a chunk program's operands live in device main memory, all
/// zero-padded `(padded rows, n)` row-major: Q `(padded_queries, n)`,
/// K/V `(padded_keys, n)`, O^T blocks (`[n, n]` per row block) at
/// `o_addr`, and — partial programs only — the per-block accumulated
/// `l` vectors (`[1, n]` each) at `l_addr`.
#[derive(Clone, Copy, Debug)]
pub struct ChunkLayout {
    pub q_addr: u32,
    pub k_addr: u32,
    pub v_addr: u32,
    pub o_addr: u32,
    pub l_addr: u32,
}

impl ChunkLayout {
    /// Packed default layout for a workload.
    pub fn packed(p: &ChunkParams) -> ChunkLayout {
        let n = p.n as u32;
        let q = (p.padded_queries() as u32) * n;
        let k = (p.padded_keys() as u32) * n;
        ChunkLayout {
            q_addr: 0,
            k_addr: q,
            v_addr: q + k,
            o_addr: q + 2 * k,
            l_addr: 2 * q + 2 * k,
        }
    }

    /// Total main-memory elements the program touches.
    pub fn mem_elems(&self, p: &ChunkParams) -> usize {
        self.l_addr as usize + p.row_blocks() * p.n
    }
}

/// Emit one row block's inner loop (Q load, tile-skipping K/V stream
/// with the §8 mask wave) into `b`.  Returns the number of issued
/// tiles.
#[allow(clippy::too_many_arguments)]
fn emit_row_block(
    b: &mut KernelBuilder,
    p: &ChunkParams,
    q_block: MTile,
    k_blocks: &[MTile],
    v_blocks: &[MTile],
    st: &BlockTiles,
    block: usize,
) -> crate::Result<usize> {
    let n = p.n;
    b.load_tile(q_block, st.q[block % 2])?;
    let mut issued = 0usize;
    for (j, (k_j, v_j)) in k_blocks.iter().zip(v_blocks).enumerate() {
        if j * n >= p.valid_keys {
            break; // pure-padding column tiles are never issued
        }
        let (live, bound) = p.tile_bound(block, j);
        if !live {
            continue;
        }
        b.load_stationary(st.q[block % 2]);
        b.load_tile(*k_j, st.k[issued % 2])?;
        if bound.is_full(n) {
            b.attn_score(st.k[issued % 2], st.lse, issued == 0);
        } else {
            b.masked_attn_score(st.k[issued % 2], st.lse, issued == 0, bound);
        }
        b.load_tile(*v_j, st.v[issued % 2])?;
        b.attn_value(st.v[issued % 2], st.ot, issued == 0);
        issued += 1;
    }
    Ok(issued)
}

/// The double-buffered scratchpad tiles + accumulator tiles one chunk
/// program works in.
struct BlockTiles {
    q: [STile; 2],
    k: [STile; 2],
    v: [STile; 2],
    lse: ATile,
    ot: ATile,
}

fn alloc_tiles(p: &ChunkParams) -> crate::Result<BlockTiles> {
    let nn = p.n as u16;
    let mut spad = Alloc::new(Space::Spad, p.spad_elems);
    let q = [STile(spad.tile(nn, nn)?), STile(spad.tile(nn, nn)?)];
    let k = [STile(spad.tile(nn, nn)?), STile(spad.tile(nn, nn)?)];
    let v = [STile(spad.tile(nn, nn)?), STile(spad.tile(nn, nn)?)];
    let mut accum = Alloc::new(Space::Accum, p.accum_elems);
    let lse = ATile(accum.tile(1, nn)?);
    let ot = ATile(accum.tile(nn, nn)?);
    Ok(BlockTiles { q, k, v, lse, ot })
}

fn mem_blocks(p: &ChunkParams, l: &ChunkLayout) -> (Vec<MTile>, Vec<MTile>, Vec<MTile>) {
    let nn = p.n as u16;
    let q = MTile(TileDesc::contiguous(Space::Main, l.q_addr, p.padded_queries() as u16, nn));
    let k = MTile(TileDesc::contiguous(Space::Main, l.k_addr, p.padded_keys() as u16, nn));
    let v = MTile(TileDesc::contiguous(Space::Main, l.v_addr, p.padded_keys() as u16, nn));
    (q.split_rows(nn), k.split_rows(nn), v.split_rows(nn))
}

/// The full chunk program with the normalizing epilogue — the sim
/// backend's unit for stateless/prefill heads and (via
/// [`ChunkParams::decode_row`]) decode steps.  Errors when the mask
/// leaves a row block without any live tile (a fully-masked operator;
/// callers return the defined zero output without running the array).
pub fn flash_chunk_program(p: &ChunkParams, layout: &ChunkLayout) -> crate::Result<Program> {
    let n = p.n;
    let st = alloc_tiles(p)?;
    let (q_blocks, k_blocks, v_blocks) = mem_blocks(p, layout);
    let mut b = KernelBuilder::new();
    for (i, q_i) in q_blocks.iter().enumerate() {
        let issued = emit_row_block(&mut b, p, *q_i, &k_blocks, &v_blocks, &st, i)?;
        ensure!(issued > 0, "mask leaves row block {i} without any live tile");
        b.reciprocal(st.lse);
        b.attn_lse_norm(st.ot, st.lse);
        let o_dst = MTile(TileDesc::contiguous(
            Space::Main,
            layout.o_addr + (i * n * n) as u32,
            n as u16,
            n as u16,
        ));
        b.store_tile(st.ot, o_dst)?;
    }
    Ok(b.build())
}

/// One row block of the *partial-state* variant (DESIGN.md §8): no
/// reciprocal/norm — the unnormalized O^T block and the accumulated
/// `l` vector are stored raw, and the per-row running max `m` is read
/// from the CMP registers after the run (which is why partial programs
/// are per-row-block: the CMP row holds one block's state at a time).
/// `Ok(None)` when the chunk leaves the block without any live tile —
/// the partial stays the empty `(0, -inf, 0)` state, the merge
/// identity.
pub fn flash_chunk_partial_program(
    p: &ChunkParams,
    layout: &ChunkLayout,
    block: usize,
) -> crate::Result<Option<Program>> {
    let n = p.n;
    ensure!(block < p.row_blocks(), "row block {block} out of range");
    let st = alloc_tiles(p)?;
    let (q_blocks, k_blocks, v_blocks) = mem_blocks(p, layout);
    let mut b = KernelBuilder::new();
    let issued = emit_row_block(&mut b, p, q_blocks[block], &k_blocks, &v_blocks, &st, block)?;
    if issued == 0 {
        return Ok(None);
    }
    let o_dst = MTile(TileDesc::contiguous(
        Space::Main,
        layout.o_addr + (block * n * n) as u32,
        n as u16,
        n as u16,
    ));
    b.store_tile(st.ot, o_dst)?;
    let l_dst = MTile(TileDesc::contiguous(
        Space::Main,
        layout.l_addr + (block * n) as u32,
        1,
        n as u16,
    ));
    b.store_tile(st.lse, l_dst)?;
    Ok(Some(b.build()))
}

/// The `br = 1` decode-row program (normalized): convenience wrapper
/// over [`flash_chunk_program`] at [`ChunkParams::decode_row`] shape.
pub fn flash_decode_row_program(n: usize, prefix_len: usize) -> crate::Result<(ChunkParams, ChunkLayout, Program)> {
    let p = ChunkParams::decode_row(n, prefix_len);
    let layout = ChunkLayout::packed(&p);
    let prog = flash_chunk_program(&p, &layout)?;
    Ok((p, layout, prog))
}

/// The split-KV decode-range program (partial state, single row
/// block): the unit a `ShardPlan::DecodeRange` execution runs.
pub fn flash_decode_row_partial_program(
    n: usize,
    range_len: usize,
) -> crate::Result<(ChunkParams, ChunkLayout, Program)> {
    let p = ChunkParams::decode_row(n, range_len);
    let layout = ChunkLayout::packed(&p);
    let prog = flash_chunk_partial_program(&p, &layout, 0)?
        .expect("an unmasked decode range always has live tiles");
    Ok((p, layout, prog))
}

/// De-transpose the stored `[d, Br]` output blocks into a row-major
/// `(L, d)` matrix (the host-side `.T` of Listing 2).
pub fn detranspose_output(mem: &[f32], layout: &FlashLayout, p: &FlashParams) -> Vec<f32> {
    let n = p.d;
    let tiles = p.seq_len / n;
    let mut out = vec![0.0f32; p.seq_len * n];
    for i in 0..tiles {
        let base = layout.o_addr as usize + i * n * n;
        for h in 0..n {
            for m in 0..n {
                out[(i * n + m) * n + h] = mem[base + h * n + m];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instruction;

    #[test]
    fn program_shape_matches_listing2() {
        let p = FlashParams { seq_len: 512, d: 128, spad_elems: 6 * 128 * 128, accum_elems: 128 * 129 };
        let layout = FlashLayout::packed(&p);
        let prog = flash_attention_program(&p, &layout).unwrap();
        let t = 512 / 128;
        // Per row block: 1 Q load + t*(stationary + K load + score + V
        // load + value) + recip + norm + store.
        assert_eq!(prog.len(), t * (1 + t * 5 + 3));
        let (loads, stores, computes) = prog.class_counts();
        assert_eq!(loads, t + 2 * t * t);
        assert_eq!(stores, t);
        assert_eq!(computes, t * (3 * t + 2));
        // First instruction loads Q block 0; first compute is stationary.
        assert!(matches!(prog.instructions[0], Instruction::LoadTile { .. }));
        assert!(matches!(prog.instructions[1], Instruction::LoadStationary { .. }));
    }

    #[test]
    fn first_flags_reset_per_row_block() {
        let p = FlashParams { seq_len: 256, d: 128, spad_elems: 6 * 128 * 128, accum_elems: 128 * 129 };
        let prog = flash_attention_program(&p, &FlashLayout::packed(&p)).unwrap();
        let firsts: Vec<bool> = prog
            .instructions
            .iter()
            .filter_map(|i| match i {
                Instruction::AttnScore { first, .. } => Some(*first),
                _ => None,
            })
            .collect();
        assert_eq!(firsts, vec![true, false, true, false]);
    }

    #[test]
    fn causal_program_skips_the_upper_triangle() {
        let p = FlashParams { seq_len: 512, d: 128, spad_elems: 6 * 128 * 128, accum_elems: 128 * 129 };
        let layout = FlashLayout::packed(&p);
        let square = flash_attention_program(&p, &layout).unwrap();
        let causal = flash_attention_program_masked(&p, &layout, MaskKind::Causal).unwrap();
        let t = 512 / 128;
        // Row block i issues i+1 column tiles instead of t: the inner
        // loop shrinks from t² = 16 to t(t+1)/2 = 10 iterations.  The t
        // diagonal tiles each add one MaskBound register write (the §8
        // mask wave encoding).
        let issued = t * (t + 1) / 2;
        assert_eq!(causal.len(), t * (1 + 3) + issued * 5 + t);
        assert!(causal.len() < square.len());
        let (loads, stores, computes) = causal.class_counts();
        assert_eq!(loads, t + 2 * issued, "1 Q load per block + K/V per issued tile");
        assert_eq!(stores, t);
        assert_eq!(computes, 3 * issued + 2 * t + t, "+t diagonal MaskBounds");
        // Exactly the diagonal scores carry the mask flag, each paired
        // with the MaskBound programming its boundary register.
        let masked_scores =
            causal.instructions.iter().filter(|i| i.is_masked_score()).count();
        let bounds = causal
            .instructions
            .iter()
            .filter(|i| matches!(i, Instruction::MaskBound { .. }))
            .count();
        assert_eq!((masked_scores, bounds), (t, t));
        // The accumulate-reset flag moves to the first *issued* tile of
        // each row block — exactly one reset per block.
        let firsts: Vec<bool> = causal
            .instructions
            .iter()
            .filter_map(|i| match i {
                Instruction::AttnScore { first, .. } => Some(*first),
                _ => None,
            })
            .collect();
        assert_eq!(firsts.len(), issued);
        assert_eq!(firsts.iter().filter(|&&f| f).count(), t);
        // An unmasked mask reproduces the Listing-2 program exactly.
        let none = flash_attention_program_masked(&p, &layout, MaskKind::None).unwrap();
        assert_eq!(none.len(), square.len());
        // A fully-masking padding mask is rejected, not miscompiled.
        assert!(flash_attention_program_masked(
            &p,
            &layout,
            MaskKind::PaddingKeys { valid: 0 }
        )
        .is_err());
    }

    #[test]
    fn paper_spad_budget_suffices() {
        // §6.1 footnote: 192 KiB scratchpad supports the algorithm with
        // double buffering: 6 tiles of 128x128 fp16 = 196608 B exactly.
        // The 64 KiB accumulation SRAM holds the fp32 O^T tile exactly;
        // the 128-entry l vector lives in accumulator-unit registers
        // (modeled as +n elements here).
        let p = FlashParams {
            seq_len: 16384,
            d: 128,
            spad_elems: 192 * 1024 / 2,      // fp16 elements in 192 KiB
            accum_elems: 64 * 1024 / 4 + 128, // f32 elements + l registers
        };
        assert!(flash_attention_program(&p, &FlashLayout::packed(&p)).is_ok());
        // One fp16 element less of scratchpad must fail: the budget is tight.
        let q = FlashParams { spad_elems: 192 * 1024 / 2 - 1, ..p };
        assert!(flash_attention_program(&q, &FlashLayout::packed(&q)).is_err());
    }

    #[test]
    fn chunk_params_cover_ragged_padded_and_chunked_shapes() {
        // Ragged: 40 queries / 40 keys on a 32-array pad to 64 each.
        let p = ChunkParams::whole(32, 40, MaskKind::None);
        assert_eq!((p.padded_queries(), p.padded_keys(), p.row_blocks()), (64, 64, 2));
        // The ragged tail tile masks its 24 padded lanes uniformly.
        let (live, b) = p.tile_bound(0, 1);
        assert!(live);
        assert_eq!((b.base, b.diag, b.cap), (8, false, 8));
        assert!(!b.is_full(32));
        // Full interior tile needs no wave.
        let (live, b) = p.tile_bound(0, 0);
        assert!(live && b.is_full(32));

        // Causal diagonal tile: boundary advances with the column.
        let c = ChunkParams::whole(32, 64, MaskKind::Causal);
        let (live, b) = c.tile_bound(1, 1);
        assert!(live);
        assert_eq!((b.base, b.diag, b.cap), (1, true, 32));
        // Above-diagonal tile is never issued.
        assert!(!c.tile_bound(0, 1).0);
        // Below-diagonal tile runs unmasked.
        assert!(c.tile_bound(1, 0).1.is_full(32));

        // A sequence-parallel chunk evaluates the mask at global key
        // coordinates: the second 32-key chunk of a 64-key causal
        // sequence is dead for row block 0 and diagonal for block 1.
        let ch = ChunkParams::chunk(32, 64, MaskKind::Causal, 32, 32, 64);
        assert!(!ch.tile_bound(0, 0).0);
        let (live, b) = ch.tile_bound(1, 0);
        assert!(live);
        assert_eq!((b.base, b.diag), (1, true));

        // Decode row: one real query, ragged prefix.
        let d = ChunkParams::decode_row(32, 37);
        assert_eq!((d.valid_queries, d.row_blocks(), d.padded_keys()), (1, 1, 64));
        assert_eq!(d.tile_bound(0, 1).1.bound(0), 5);
    }

    #[test]
    fn resumed_params_program_the_mask_at_global_query_rows() {
        // Resume at row 32 of a 64-row causal head on a 32-array: one
        // suffix row block whose global rows are [32, 64) — its tile
        // bounds are exactly the cold run's row block 1.
        let r = ChunkParams::resumed(32, 64, MaskKind::Causal, 32, 0, 64, 64);
        assert_eq!((r.valid_queries, r.row_blocks()), (32, 1));
        let cold = ChunkParams::whole(32, 64, MaskKind::Causal);
        for j in 0..2 {
            let (live_r, b_r) = r.tile_bound(0, j);
            let (live_c, b_c) = cold.tile_bound(1, j);
            assert_eq!(live_r, live_c, "tile {j}");
            assert_eq!((b_r.base, b_r.diag, b_r.cap), (b_c.base, b_c.diag, b_c.cap));
        }
        // A tile-misaligned resume point: rows [40, 64) are one ragged
        // row block; the causal boundary still sits at global row 40.
        let m = ChunkParams::resumed(32, 64, MaskKind::Causal, 40, 0, 64, 64);
        assert_eq!((m.valid_queries, m.row_blocks()), (24, 1));
        let (live, b) = m.tile_bound(0, 1);
        assert!(live);
        assert_eq!(b.bound(0), 9, "valid_keys(40) - key tile start 32");
        // query_offset = 0 reproduces the chunk constructor's bounds.
        let z = ChunkParams::resumed(32, 64, MaskKind::Causal, 0, 32, 32, 64);
        let c = ChunkParams::chunk(32, 64, MaskKind::Causal, 32, 32, 64);
        for blk in 0..2 {
            let (lz, bz) = z.tile_bound(blk, 0);
            let (lc, bc) = c.tile_bound(blk, 0);
            assert_eq!(lz, lc);
            assert_eq!((bz.base, bz.diag, bz.cap), (bc.base, bc.diag, bc.cap));
        }
    }

    #[test]
    fn chunk_and_partial_programs_have_serving_shapes() {
        // Normalized chunk program == the legacy masked program on the
        // legacy shape (exact tiles, whole range).
        let p = FlashParams { seq_len: 256, d: 128, spad_elems: 6 * 128 * 128, accum_elems: 128 * 129 };
        let legacy = flash_attention_program_masked(&p, &FlashLayout::packed(&p), MaskKind::Causal)
            .unwrap();
        let cp = ChunkParams {
            spad_elems: p.spad_elems,
            accum_elems: p.accum_elems,
            ..ChunkParams::whole(128, 256, MaskKind::Causal)
        };
        let fl = FlashLayout::packed(&p);
        let cl = ChunkLayout {
            q_addr: fl.q_addr,
            k_addr: fl.k_addr,
            v_addr: fl.v_addr,
            o_addr: fl.o_addr,
            l_addr: fl.o_addr,
        };
        assert_eq!(flash_chunk_program(&cp, &cl).unwrap(), legacy);

        // Partial program: one row block, stores O^T + l raw, no
        // reciprocal / lse-norm.
        let cp = ChunkParams::whole(32, 64, MaskKind::None);
        let cl = ChunkLayout::packed(&cp);
        let part = flash_chunk_partial_program(&cp, &cl, 1).unwrap().unwrap();
        assert!(!part
            .instructions
            .iter()
            .any(|i| matches!(i, Instruction::Reciprocal { .. } | Instruction::AttnLseNorm { .. })));
        let (_, stores, _) = part.class_counts();
        assert_eq!(stores, 2, "O^T block + l vector");

        // A block the chunk fully masks yields no program (the merge
        // identity): causal chunk [32, 64) for row block 0.
        let dead = ChunkParams::chunk(32, 64, MaskKind::Causal, 32, 32, 64);
        assert!(flash_chunk_partial_program(&dead, &ChunkLayout::packed(&dead), 0)
            .unwrap()
            .is_none());
        assert!(flash_chunk_partial_program(&dead, &ChunkLayout::packed(&dead), 1)
            .unwrap()
            .is_some());

        // Decode-row wrappers: single row block, ragged prefix padded.
        let (dp, dl, prog) = flash_decode_row_program(32, 37).unwrap();
        assert_eq!(dp.row_blocks(), 1);
        assert!(dl.mem_elems(&dp) > 0);
        assert!(!prog.is_empty());
        let (_, _, partial) = flash_decode_row_partial_program(32, 37).unwrap();
        assert!(partial.len() < prog.len(), "partial drops the epilogue");
    }

    #[test]
    fn detranspose_round_trip() {
        let p = FlashParams { seq_len: 4, d: 2, spad_elems: 1024, accum_elems: 1024 };
        let layout = FlashLayout::packed(&p);
        // Two blocks of O^T [2, 2]: block i holds O^T[h][m] = O[m][h].
        let mut mem = vec![0.0f32; layout.mem_elems(&p)];
        let base = layout.o_addr as usize;
        // Block 0: O = [[1, 2], [3, 4]] -> O^T = [[1, 3], [2, 4]].
        mem[base..base + 4].copy_from_slice(&[1.0, 3.0, 2.0, 4.0]);
        // Block 1: O = [[5, 6], [7, 8]].
        mem[base + 4..base + 8].copy_from_slice(&[5.0, 7.0, 6.0, 8.0]);
        let out = detranspose_output(&mem, &layout, &p);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
    }
}
