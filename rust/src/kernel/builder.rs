//! Typed tiles + the JIT kernel builder (paper §5.1–§5.3).

use anyhow::ensure;

use crate::isa::{Instruction, LaneBound, Program, Space, TileDesc};

/// Main-memory tensor handle (paper's `MTile`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MTile(pub TileDesc);

/// Scratchpad-SRAM tile handle (`STile`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct STile(pub TileDesc);

/// Accumulation-SRAM tile handle (`ATile`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ATile(pub TileDesc);

impl MTile {
    pub fn rows(&self) -> usize {
        self.0.rows as usize
    }
    pub fn cols(&self) -> usize {
        self.0.cols as usize
    }

    /// Split along rows into `rows / chunk` sub-tiles (PyTorch-like
    /// `split(chunk, dim=-2)` for 2D row-major tensors).
    pub fn split_rows(&self, chunk: u16) -> Vec<MTile> {
        assert!(self.0.rows % chunk == 0, "ragged split: {} % {chunk}", self.0.rows);
        (0..self.0.rows / chunk)
            .map(|i| {
                let mut t = self.0;
                t.addr += i as u32 * chunk as u32 * t.stride;
                t.rows = chunk;
                MTile(t)
            })
            .collect()
    }
}

/// Memory-space allocators for kernel authors: bump allocators over the
/// three spaces, mirroring `F.alloc_mem / F.alloc_spad / F.alloc_accum`.
pub struct Alloc {
    space: Space,
    next: u32,
    capacity: u32,
}

impl Alloc {
    pub fn new(space: Space, capacity_elems: u32) -> Alloc {
        Alloc { space, next: 0, capacity: capacity_elems }
    }

    pub fn tile(&mut self, rows: u16, cols: u16) -> crate::Result<TileDesc> {
        let elems = rows as u32 * cols as u32;
        ensure!(
            self.next + elems <= self.capacity,
            "{:?} space exhausted: need {elems} at {}, cap {}",
            self.space,
            self.next,
            self.capacity
        );
        let t = TileDesc::contiguous(self.space, self.next, rows, cols);
        self.next += elems;
        Ok(t)
    }

    pub fn used(&self) -> u32 {
        self.next
    }
}

/// The JIT builder: each method emits one ISA instruction, with tile
/// types enforcing the §4.2 operand contracts.
#[derive(Default)]
pub struct KernelBuilder {
    program: Program,
}

impl KernelBuilder {
    pub fn new() -> KernelBuilder {
        KernelBuilder::default()
    }

    /// `load_tile(src: MTile, dst: STile)` — DMA into scratchpad.
    pub fn load_tile(&mut self, src: MTile, dst: STile) -> crate::Result<()> {
        ensure!(
            src.0.rows == dst.0.rows && src.0.cols == dst.0.cols,
            "load_tile shape mismatch: {:?} -> {:?}",
            src.0,
            dst.0
        );
        self.program.push(Instruction::LoadTile { src: src.0, dst: dst.0 });
        Ok(())
    }

    /// `store_tile(src: ATile, dst: MTile)` — DMA out of the accumulator.
    pub fn store_tile(&mut self, src: ATile, dst: MTile) -> crate::Result<()> {
        ensure!(
            src.0.rows == dst.0.rows && src.0.cols == dst.0.cols,
            "store_tile shape mismatch: {:?} -> {:?}",
            src.0,
            dst.0
        );
        self.program.push(Instruction::StoreTile { src: src.0, dst: dst.0 });
        Ok(())
    }

    /// `load_stationary(tile: STile)` — preload Q.
    pub fn load_stationary(&mut self, tile: STile) {
        self.program.push(Instruction::LoadStationary { src: tile.0 });
    }

    /// `attn_score(K: STile, l: ATile)` — fused S = QK^T + online softmax.
    pub fn attn_score(&mut self, k: STile, l: ATile, first: bool) {
        self.program.push(Instruction::AttnScore { k: k.0, lse: l.0, first, masked: false });
    }

    /// Masked `attn_score` (DESIGN.md §8): programs the boundary
    /// register and sets the score's mask flag, so the controller runs
    /// the element-wise mask wave over the tile's invalid lanes.
    pub fn masked_attn_score(&mut self, k: STile, l: ATile, first: bool, bound: LaneBound) {
        self.program.push(Instruction::MaskBound { bound });
        self.program.push(Instruction::AttnScore { k: k.0, lse: l.0, first, masked: true });
    }

    /// `attn_value(V: STile, O: ATile)` — O += P V.
    pub fn attn_value(&mut self, v: STile, o: ATile, first: bool) {
        self.program.push(Instruction::AttnValue { v: v.0, out: o.0, first });
    }

    /// `reciprocal(l: ATile)`.
    pub fn reciprocal(&mut self, l: ATile) {
        self.program.push(Instruction::Reciprocal { l: l.0 });
    }

    /// `attn_lse_norm(O: ATile)`.
    pub fn attn_lse_norm(&mut self, o: ATile, l: ATile) {
        self.program.push(Instruction::AttnLseNorm { out: o.0, l: l.0 });
    }

    /// Finish: returns the compiled program (the "binary" the device's
    /// instruction queue consumes; see [`crate::isa::encode`]).
    pub fn build(self) -> Program {
        self.program
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_rows_produces_disjoint_tiles() {
        let m = MTile(TileDesc::contiguous(Space::Main, 0, 64, 16));
        let parts = m.split_rows(16);
        assert_eq!(parts.len(), 4);
        for (i, p) in parts.iter().enumerate() {
            assert_eq!(p.0.addr, (i * 16 * 16) as u32);
            assert_eq!(p.rows(), 16);
        }
        for w in parts.windows(2) {
            assert!(!w[0].0.overlaps(&w[1].0));
        }
    }

    #[test]
    fn allocator_respects_capacity() {
        let mut a = Alloc::new(Space::Spad, 1024);
        let t1 = a.tile(16, 16).unwrap();
        let t2 = a.tile(16, 16).unwrap();
        assert!(!t1.overlaps(&t2));
        assert!(a.tile(32, 32).is_err());
        assert_eq!(a.used(), 512);
    }

    #[test]
    fn builder_emits_in_order() {
        let mut b = KernelBuilder::new();
        let q = STile(TileDesc::contiguous(Space::Spad, 0, 8, 8));
        let l = ATile(TileDesc::contiguous(Space::Accum, 0, 1, 8));
        b.load_stationary(q);
        b.attn_score(q, l, true);
        b.reciprocal(l);
        let p = b.build();
        assert_eq!(p.len(), 3);
        assert!(p.disasm().contains("attn_score"));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut b = KernelBuilder::new();
        let src = MTile(TileDesc::contiguous(Space::Main, 0, 8, 8));
        let dst = STile(TileDesc::contiguous(Space::Spad, 0, 8, 16));
        assert!(b.load_tile(src, dst).is_err());
    }
}
