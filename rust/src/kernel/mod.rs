//! The FSA kernel programming model (paper §5), in Rust.
//!
//! The paper ships an NKI-inspired Python library: type-safe tensors over
//! three memory spaces (`MTile`/`STile`/`ATile`), one Python function per
//! ISA instruction, and a lightweight JIT that turns a decorated kernel
//! into a binary instruction stream.  Since our runtime is Rust, the same
//! model lives here: typed tile handles, a [`KernelBuilder`] whose methods
//! mirror Listing 1, and [`flash_attention_program`] — the Listing-2
//! FlashAttention kernel — as the canonical user.
//!
//! Type safety: `MTile`, `STile` and `ATile` are distinct types, so e.g.
//! `attn_score` can only take a scratchpad K tile and an accumulator lse
//! tile; misuse is a compile error exactly like the Python library's
//! runtime type checks — but earlier.

pub mod builder;
pub mod flash;

pub use builder::{ATile, KernelBuilder, MTile, STile};
pub use flash::{
    flash_attention_program, flash_attention_program_masked, flash_chunk_partial_program,
    flash_chunk_program, flash_decode_row_partial_program, flash_decode_row_program,
    ChunkLayout, ChunkParams, FlashLayout, FlashParams,
};
