//! Area model — paper Table 3 (16 nm, 1.5 GHz synthesis of the array).
//!
//! We cannot synthesize RTL in this environment, so the model is
//! component-level with per-unit constants *fitted once* to the paper's
//! 128 x 128 breakdown, and structural scaling laws in the array size:
//! per-PE components (PE MAC, upward-path mux/regs, Split unit) scale as
//! N^2, the CMP row as N, and "other logic" (controller, edge skew
//! registers) as N.  This reproduces Table 3 exactly at N = 128 and lets
//! the ablation bench explore other array sizes.

/// Fitted per-unit areas in um^2 (paper Table 3 / component counts).
const PE_AREA: f64 = 24_445_044.0 / (128.0 * 128.0); // 1492.0 um^2 per MAC PE
const OTHER_PER_EDGE: f64 = 313_457.0 / 128.0; // skew regs + control per row
const UP_PATH_PER_PE: f64 = 1_756_641.0 / (128.0 * 128.0);
const SPLIT_PER_PE: f64 = 1_493_150.0 / (128.0 * 128.0);
const CMP_PER_COL: f64 = 149_524.0 / 128.0;

/// One Table-3 row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AreaItem {
    pub group: &'static str,
    pub component: &'static str,
    pub area_um2: f64,
}

/// Full breakdown for an N x N FSA array.
#[derive(Clone, Debug)]
pub struct AreaBreakdown {
    pub n: usize,
    pub items: Vec<AreaItem>,
}

impl AreaBreakdown {
    pub fn for_array(n: usize) -> AreaBreakdown {
        let pes = (n * n) as f64;
        let items = vec![
            AreaItem { group: "Standard", component: "PEs", area_um2: PE_AREA * pes },
            AreaItem {
                group: "Standard",
                component: "Other logic",
                area_um2: OTHER_PER_EDGE * n as f64,
            },
            AreaItem {
                group: "FSA additional",
                component: "Upward data path",
                area_um2: UP_PATH_PER_PE * pes,
            },
            AreaItem {
                group: "FSA additional",
                component: "Split units",
                area_um2: SPLIT_PER_PE * pes,
            },
            AreaItem {
                group: "FSA additional",
                component: "CMP units",
                area_um2: CMP_PER_COL * n as f64,
            },
        ];
        AreaBreakdown { n, items }
    }

    pub fn total(&self) -> f64 {
        self.items.iter().map(|i| i.area_um2).sum()
    }

    pub fn group_total(&self, group: &str) -> f64 {
        self.items.iter().filter(|i| i.group == group).map(|i| i.area_um2).sum()
    }

    /// FSA's additional area as a fraction of the total (the paper's
    /// headline "12% area overhead").
    pub fn overhead_fraction(&self) -> f64 {
        self.group_total("FSA additional") / self.total()
    }

    /// Render the Table-3 style report.
    pub fn to_table(&self) -> String {
        let total = self.total();
        let mut out = String::from(
            "Group           Component          Area(%)   Area(um^2)\n",
        );
        for i in &self.items {
            out.push_str(&format!(
                "{:<15} {:<18} {:>6.2}    {:>12.0}\n",
                i.group,
                i.component,
                100.0 * i.area_um2 / total,
                i.area_um2
            ));
        }
        for g in ["Standard", "FSA additional"] {
            out.push_str(&format!(
                "{:<15} {:<18} {:>6.2}    {:>12.0}\n",
                g,
                "Total",
                100.0 * self.group_total(g) / total,
                self.group_total(g)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_table3_at_128() {
        let a = AreaBreakdown::for_array(128);
        // Absolute um^2 match the paper's numbers by construction.
        let by_name = |c: &str| a.items.iter().find(|i| i.component == c).unwrap().area_um2;
        assert!((by_name("PEs") - 24_445_044.0).abs() < 1.0);
        assert!((by_name("Upward data path") - 1_756_641.0).abs() < 1.0);
        assert!((by_name("Split units") - 1_493_150.0).abs() < 1.0);
        assert!((by_name("CMP units") - 149_524.0).abs() < 1.0);
        // Percentages: standard 87.92%, additional 12.07%.
        assert!((100.0 * a.overhead_fraction() - 12.07).abs() < 0.05);
        assert!((100.0 * a.group_total("Standard") / a.total() - 87.92).abs() < 0.05);
    }

    #[test]
    fn overhead_shrinks_slightly_with_array_size() {
        // CMP row and other-logic are O(N) while PE-attached parts are
        // O(N^2): the relative overhead converges to the per-PE ratio.
        let small = AreaBreakdown::for_array(32).overhead_fraction();
        let big = AreaBreakdown::for_array(256).overhead_fraction();
        let per_pe_ratio = (UP_PATH_PER_PE + SPLIT_PER_PE) / (PE_AREA + UP_PATH_PER_PE + SPLIT_PER_PE);
        assert!((big - per_pe_ratio).abs() < 0.01);
        assert!((small - big).abs() < 0.02, "small {small} big {big}");
    }

    #[test]
    fn table_renders_all_rows() {
        let t = AreaBreakdown::for_array(128).to_table();
        for c in ["PEs", "Split units", "CMP units", "Upward data path", "Total"] {
            assert!(t.contains(c), "missing {c}\n{t}");
        }
    }
}
