//! Head sharding + gather: the scatter/gather layer between one
//! [`AttentionRequest`] and the per-head units of work the device pool
//! actually executes.
//!
//! [`explode`] splits an ingress [`Envelope`] into one
//! [`ShardEnvelope`] per query head, all sharing the request data
//! behind an `Arc` (no Q/K/V copies) and one [`Gather`] cell.  Workers
//! call [`Gather::complete`] per finished shard; the worker that lands
//! the final shard assembles the whole-operator [`AttentionResponse`]
//! — outputs re-interleaved head-major, cycle cost summed, the
//! critical path and FLOPs/s utilization computed over the devices
//! that actually served shards — and sends the reply.  A request is
//! therefore answered exactly once, no matter how its shards were
//! batched, chunked, or re-routed.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::AccelConfig;
use crate::perfmodel::pool_utilization;

use super::request::{AttentionRequest, AttentionResponse, Envelope};
use super::session::{SessionId, SessionOp};

/// One query head of one request: the unit of routing and execution.
pub struct HeadShard {
    pub req: Arc<AttentionRequest>,
    /// Query head index in `0..req.num_heads`.
    pub head: usize,
    /// KV head this query head attends over (`req.kv_head_for(head)`),
    /// carried here because the router keys affinity on it.
    pub kv_head: usize,
}

impl HeadShard {
    /// Router affinity key: shards sharing a KV head under GQA want the
    /// same device so the K/V tiles are fetched (and could be cached)
    /// once per device rather than once per query head.
    pub fn affinity_key(&self) -> (u64, usize) {
        (self.req.id, self.kv_head)
    }
}

/// Session context a device worker needs to execute a shard, derived
/// from the request's [`SessionOp`] at explode time (`Close` never
/// reaches the device pool — the batcher answers it directly).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardCtx {
    /// One-shot operator: execute and forget.
    Stateless,
    /// Full-prefix attention whose K/V the worker inserts into its
    /// paged cache after executing.  `epoch` is the session's
    /// incarnation stamp (batcher-assigned) so caches never confuse a
    /// reused id with its dead predecessor.
    Prefill { session: SessionId, epoch: u64 },
    /// Single-query-row attention over `prefix_len` tokens: pages on a
    /// hit (same `epoch` only), host-tier recompute fallback on a miss.
    Decode { session: SessionId, prefix_len: usize, epoch: u64 },
}

/// Whether a shard was served from KV-cache pages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Not a decode shard (stateless / prefill).
    NotApplicable,
    /// Decode served from pages (O(L) stream).
    Hit,
    /// Decode took the recompute fallback (O(L²) charge).
    Miss,
}

/// A shard in flight: work item + its request's gather cell.
pub struct ShardEnvelope {
    pub shard: HeadShard,
    pub gather: Arc<Gather>,
    /// Copied from the ingress envelope so the batcher's timeout logic
    /// works per shard without touching the gather.
    pub enqueued: Instant,
    /// Session context for the executing worker and the router's
    /// sticky placement.
    pub ctx: ShardCtx,
}

/// What a device worker reports for one executed shard.
pub struct ShardResult {
    pub head: usize,
    pub device_id: usize,
    /// Simulated FSA device cycles for this head.
    pub cycles: u64,
    pub output: Result<Vec<f32>, String>,
    /// KV-cache outcome (decode shards only).
    pub cache: CacheOutcome,
}

struct GatherInner {
    /// Per-head `(device_id, cycles, output)`, indexed by query head.
    done: Vec<Option<(usize, u64, Result<Vec<f32>, String>)>>,
    remaining: usize,
    kv_hits: usize,
    kv_misses: usize,
}

/// Per-request gather cell shared by all of the request's shards.
pub struct Gather {
    req: Arc<AttentionRequest>,
    reply: mpsc::Sender<AttentionResponse>,
    enqueued: Instant,
    inner: Mutex<GatherInner>,
}

impl Gather {
    /// Record one shard result.  Returns the assembled whole-operator
    /// response if this was the request's final outstanding shard (so
    /// the caller can record metrics before [`Gather::send`]), `None`
    /// while shards are still in flight.  `cfg` supplies the clock and
    /// peak-FLOPs constants for the whole-operator utilization metric.
    pub fn complete_and_report(
        &self,
        result: ShardResult,
        cfg: &AccelConfig,
    ) -> Option<AttentionResponse> {
        let mut inner = super::lock(&self.inner);
        debug_assert!(inner.done[result.head].is_none(), "head completed twice");
        if inner.done[result.head].is_none() {
            inner.remaining -= 1;
            match result.cache {
                CacheOutcome::Hit => inner.kv_hits += 1,
                CacheOutcome::Miss => inner.kv_misses += 1,
                CacheOutcome::NotApplicable => {}
            }
        }
        inner.done[result.head] = Some((result.device_id, result.cycles, result.output));
        if inner.remaining > 0 {
            return None;
        }
        Some(self.assemble(&mut inner))
    }

    /// Deliver the gathered response to the submitter.  A vanished
    /// client (dropped receiver) is not an error.
    pub fn send(&self, response: AttentionResponse) {
        let _ = self.reply.send(response);
    }

    /// Convenience for tests and simple callers: record, and send the
    /// response if this shard completed the gather.
    pub fn complete(&self, result: ShardResult, cfg: &AccelConfig) {
        if let Some(resp) = self.complete_and_report(result, cfg) {
            self.send(resp);
        }
    }

    /// Build the whole-operator response from the completed shards.
    fn assemble(&self, inner: &mut GatherInner) -> AttentionResponse {
        let req = &self.req;
        let head_elems = req.seq_len * req.d;

        let mut output: Result<Vec<f32>, String> =
            Ok(Vec::with_capacity(req.num_heads * head_elems));
        let mut device_cycles = 0u64;
        let mut per_device: Vec<(usize, u64)> = Vec::new();
        let mut devices_used = Vec::new();
        let mut device_id = 0usize;

        for (head, slot) in inner.done.iter_mut().enumerate() {
            let (dev, cycles, head_out) = slot.take().expect("gather complete with missing head");
            if head == 0 {
                device_id = dev;
            }
            device_cycles += cycles;
            match per_device.iter_mut().find(|(d, _)| *d == dev) {
                Some((_, c)) => *c += cycles,
                None => {
                    per_device.push((dev, cycles));
                    devices_used.push(dev);
                }
            }
            match head_out {
                Ok(o) => {
                    if let Ok(buf) = &mut output {
                        debug_assert_eq!(o.len(), head_elems);
                        buf.extend_from_slice(&o);
                    }
                }
                // Keep the first failing head's error (head order).
                Err(e) => {
                    if output.is_ok() {
                        output = Err(format!("head {head}: {e}"));
                    }
                }
            }
        }
        devices_used.sort_unstable();

        let critical_path_cycles =
            per_device.iter().map(|&(_, c)| c).max().unwrap_or(0);
        let cycles_by_device: Vec<u64> = per_device.iter().map(|&(_, c)| c).collect();
        let utilization = pool_utilization(cfg, req.flops(), &cycles_by_device);

        AttentionResponse {
            id: req.id,
            output,
            num_heads: req.num_heads,
            num_kv_heads: req.num_kv_heads,
            shards: req.num_heads,
            device_cycles,
            critical_path_cycles,
            device_time: Duration::from_nanos(
                (critical_path_cycles as f64 / cfg.freq_ghz) as u64,
            ),
            utilization,
            latency: self.enqueued.elapsed(),
            device_id,
            devices_used,
            bucket: req.seq_len,
            kv_hits: inner.kv_hits,
            kv_misses: inner.kv_misses,
        }
    }
}

/// Split an ingress envelope into its per-head shards (one per query
/// head), sharing the request behind an `Arc` and one gather cell.
pub fn explode(env: Envelope) -> Vec<ShardEnvelope> {
    let Envelope { req, reply, enqueued } = env;
    let num_heads = req.num_heads;
    let ctx = match req.op {
        SessionOp::Prefill { session } => ShardCtx::Prefill { session, epoch: req.epoch },
        SessionOp::Decode { session, .. } => {
            ShardCtx::Decode { session, prefix_len: req.prefix_len, epoch: req.epoch }
        }
        // Close is answered by the batcher and never dispatched; treat
        // a stray one as stateless rather than panicking.
        SessionOp::Stateless | SessionOp::Close { .. } => ShardCtx::Stateless,
    };
    let req = Arc::new(req);
    let gather = Arc::new(Gather {
        req: req.clone(),
        reply,
        enqueued,
        inner: Mutex::new(GatherInner {
            done: (0..num_heads).map(|_| None).collect(),
            remaining: num_heads,
            kv_hits: 0,
            kv_misses: 0,
        }),
    });
    (0..num_heads)
        .map(|head| ShardEnvelope {
            shard: HeadShard { req: req.clone(), head, kv_head: req.kv_head_for(head) },
            gather: gather.clone(),
            enqueued,
            ctx,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fsa() -> AccelConfig {
        AccelConfig::builtin("fsa").unwrap()
    }

    fn gqa_envelope(
        heads: usize,
        kv_heads: usize,
        seq: usize,
        d: usize,
    ) -> (Envelope, mpsc::Receiver<AttentionResponse>) {
        let (tx, rx) = mpsc::channel();
        let q = vec![0.5f32; heads * seq * d];
        let kv = vec![0.25f32; kv_heads * seq * d];
        let env = Envelope {
            req: AttentionRequest::gqa(7, seq, d, heads, kv_heads, q, kv.clone(), kv),
            reply: tx,
            enqueued: Instant::now(),
        };
        (env, rx)
    }

    #[test]
    fn explode_yields_one_shard_per_query_head() {
        let (env, _rx) = gqa_envelope(8, 2, 4, 2);
        let shards = explode(env);
        assert_eq!(shards.len(), 8);
        let kv: Vec<usize> = shards.iter().map(|s| s.shard.kv_head).collect();
        assert_eq!(kv, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        // All shards share one request allocation and one gather cell.
        assert!(Arc::ptr_eq(&shards[0].shard.req, &shards[7].shard.req));
        assert!(Arc::ptr_eq(&shards[0].gather, &shards[7].gather));
        assert_eq!(shards[3].shard.affinity_key(), (7, 0));
        assert_eq!(shards[4].shard.affinity_key(), (7, 1));
    }

    #[test]
    fn gather_assembles_head_major_output_and_pool_accounting() {
        let (seq, d) = (2, 2);
        let (env, rx) = gqa_envelope(4, 2, seq, d);
        let shards = explode(env);
        // Complete out of order, two devices, head h output = constant h.
        for &h in &[2usize, 0, 3, 1] {
            shards[h].gather.complete(
                ShardResult {
                    head: h,
                    device_id: h % 2,
                    cycles: 100,
                    output: Ok(vec![h as f32; seq * d]),
                    cache: CacheOutcome::NotApplicable,
                },
                &fsa(),
            );
        }
        let resp = rx.try_recv().expect("gather must reply after last shard");
        assert_eq!(resp.id, 7);
        assert_eq!(resp.shards, 4);
        assert_eq!(resp.num_heads, 4);
        assert_eq!(resp.num_kv_heads, 2);
        assert_eq!(resp.devices_used, vec![0, 1]);
        assert_eq!(resp.device_id, 0); // head 0 ran on device 0
        assert_eq!(resp.device_cycles, 400);
        assert_eq!(resp.critical_path_cycles, 200); // 2 heads per device
        let out = resp.output.unwrap();
        // Head-major: head h occupies [h*4 .. (h+1)*4).
        for h in 0..4 {
            assert!(out[h * 4..(h + 1) * 4].iter().all(|&x| x == h as f32));
        }
        assert!(resp.utilization > 0.0);
    }

    #[test]
    fn gather_surfaces_first_failing_head() {
        let (env, rx) = gqa_envelope(2, 1, 2, 2);
        let shards = explode(env);
        for h in 0..2 {
            shards[h].gather.complete(
                ShardResult {
                    head: h,
                    device_id: 0,
                    cycles: 10,
                    output: if h == 1 { Err("boom".into()) } else { Ok(vec![0.0; 4]) },
                    cache: CacheOutcome::NotApplicable,
                },
                &fsa(),
            );
        }
        let resp = rx.try_recv().unwrap();
        let err = resp.output.unwrap_err();
        assert!(err.contains("head 1") && err.contains("boom"), "{err}");
        assert_eq!(resp.device_cycles, 20);
    }

    #[test]
    fn decode_shards_carry_ctx_and_gather_counts_cache_outcomes() {
        let d = 2;
        let (tx, rx) = mpsc::channel();
        let mut req = AttentionRequest::decode(
            11, 42, 3, d, 4, 2,
            vec![0.0; 4 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
        );
        req.prefix_len = 9; // batcher stamps
        req.epoch = 5;
        let shards = explode(Envelope { req, reply: tx, enqueued: Instant::now() });
        assert_eq!(shards.len(), 4);
        for s in &shards {
            assert_eq!(s.ctx, ShardCtx::Decode { session: 42, prefix_len: 9, epoch: 5 });
        }
        for h in 0..4 {
            shards[h].gather.complete(
                ShardResult {
                    head: h,
                    device_id: 0,
                    cycles: 7,
                    output: Ok(vec![0.5; d]),
                    cache: if h == 2 { CacheOutcome::Miss } else { CacheOutcome::Hit },
                },
                &fsa(),
            );
        }
        let resp = rx.try_recv().unwrap();
        assert_eq!(resp.kv_hits, 3);
        assert_eq!(resp.kv_misses, 1);
        // Decode output is one row per head.
        assert_eq!(resp.output.unwrap().len(), 4 * d);
    }
}
