//! Head × sequence-chunk sharding + gather: the scatter/gather layer
//! between one [`AttentionRequest`] and the units of work the device
//! pool actually executes.
//!
//! [`explode`] splits an ingress [`Envelope`] into a `(head, kv-range)`
//! grid of [`ShardEnvelope`]s: one shard per query head per *live*
//! sequence chunk ([`crate::schedule::chunk_ranges`], DESIGN.md §7),
//! all sharing the request data behind an `Arc` (no Q/K/V copies) and
//! one [`Gather`] cell.  With `seq_shards = 1` (the default) the grid
//! degenerates to the legacy one-shard-per-head layout, bit for bit.
//! Fully-masked chunks (a padding mask's dead tail) are never
//! dispatched — their partial would be the merge identity.
//!
//! Workers call [`Gather::complete`] per finished shard; sequence-
//! sharded shards report a partial `(O~, m, l)` triple
//! ([`ShardOut::Partial`]) which the worker that lands the final shard
//! merges **in chunk order** with the online-softmax merge operator
//! ([`FlashPartial::merge_from`]) before normalizing — so the gathered
//! output is a pure function of the chunk grid, bitwise-invariant to
//! which device served which chunk.  The assembled whole-operator
//! [`AttentionResponse`] re-interleaves heads head-major, sums cycle
//! cost, and computes the critical path and FLOPs/s utilization over
//! the devices that actually served shards.  A request is therefore
//! answered exactly once, no matter how its shards were batched,
//! chunked, or re-routed.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::AccelConfig;
use crate::mask::MaskKind;
use crate::numerics::pwl::PwlExp2;
use crate::numerics::reference::{Exp2, FlashPartial};
use crate::perfmodel::pool_utilization;
use crate::schedule::live_chunk_ranges;
use crate::sim::CycleBreakdown;

use super::request::{AttentionRequest, AttentionResponse, Envelope, OpKind, ResponseStats};
use super::session::{SessionId, SessionOp};

/// One query head × one sequence chunk of one request: the unit of
/// routing and execution.
pub struct HeadShard {
    pub req: Arc<AttentionRequest>,
    /// Query head index in `0..req.num_heads`.
    pub head: usize,
    /// KV head this query head attends over (`req.kv_head_for(head)`),
    /// carried here because the router keys affinity on it.
    pub kv_head: usize,
    /// Global sequence-chunk index in the request's chunk grid (0 on
    /// the legacy unsharded path).
    pub chunk: usize,
    /// Position among the request's *live* (dispatched) chunks — the
    /// gather slot coordinate.
    pub chunk_pos: usize,
    /// Global K/V token range `[start, start + len)` this shard
    /// attends (the whole sequence on the legacy path).
    pub kv_range: (usize, usize),
    /// Live chunks per head (`1` = legacy whole-sequence shard; workers
    /// emit [`ShardOut::Partial`] iff this is `> 1`).
    pub live_chunks: usize,
}

impl HeadShard {
    /// Router affinity key: shards sharing a KV head *and* chunk under
    /// GQA want the same device so each chunk's K/V tiles are fetched
    /// (and could be cached) once per device — while distinct chunks
    /// scatter, which is the whole point of sequence parallelism.
    pub fn affinity_key(&self) -> (u64, usize, usize) {
        (self.req.id, self.kv_head, self.chunk)
    }

    /// Whether this shard computes a partial (sequence-sharded) result.
    pub fn is_partial(&self) -> bool {
        self.live_chunks > 1
    }
}

/// Session context a device worker needs to execute a shard, derived
/// from the request's [`SessionOp`] at explode time (`Close` never
/// reaches the device pool — the admission gate answers it directly).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardCtx {
    /// One-shot operator: execute and forget.
    Stateless,
    /// Full-prefix attention whose K/V the worker inserts into its
    /// paged cache after executing.  `epoch` is the session's
    /// incarnation stamp (admission-gate-assigned) so caches never confuse a
    /// reused id with its dead predecessor.
    Prefill { session: SessionId, epoch: u64 },
    /// Single-query-row attention over `prefix_len` tokens: pages on a
    /// hit (same `epoch` only), host-tier recompute fallback on a miss.
    Decode { session: SessionId, prefix_len: usize, epoch: u64 },
}

/// Whether a shard was served from KV-cache pages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Not a decode shard (stateless / prefill).
    NotApplicable,
    /// Decode served from pages (O(L) stream).
    Hit,
    /// Decode took the recompute fallback (O(L²) charge).
    Miss,
}

/// What a shard's execution produced.
#[derive(Clone, Debug)]
pub enum ShardOut {
    /// The legacy whole-sequence result: `(seq_len, d)` for
    /// stateless/prefill, one `(1, d)` row for decode.
    Full(Vec<f32>),
    /// A sequence chunk's partial online-softmax state, merged at
    /// gather (DESIGN.md §7).
    Partial(FlashPartial),
}

/// A shard in flight: work item + its request's gather cell.
pub struct ShardEnvelope {
    pub shard: HeadShard,
    pub gather: Arc<Gather>,
    /// Copied from the ingress envelope so the scheduler's timeout
    /// logic works per shard without touching the gather.
    pub enqueued: Instant,
    /// Session context for the executing worker and the router's
    /// sticky placement.
    pub ctx: ShardCtx,
}

/// What a device worker reports for one executed shard.
pub struct ShardResult {
    pub head: usize,
    /// The shard's `chunk_pos` (0 on the legacy path).
    pub chunk_pos: usize,
    pub device_id: usize,
    /// Simulated FSA device cycles for this shard.
    pub cycles: u64,
    /// Whether `cycles` was *measured* by the executing backend (the
    /// cycle-accurate sim, DESIGN.md §8) rather than predicted by the
    /// perfmodel.
    pub measured: bool,
    pub output: Result<ShardOut, String>,
    /// KV-cache outcome (decode shards only).
    pub cache: CacheOutcome,
    /// Per-instruction-class attribution of `cycles` when the backend
    /// measured them on the cycle-accurate machine (DESIGN.md §9);
    /// `None` on modeled backends.  When present its `total()` equals
    /// `cycles` exactly (including the decode-miss recompute charge).
    pub breakdown: Option<CycleBreakdown>,
    /// KV pages this shard's stream attached by content match instead
    /// of copying (prefill inserts, DESIGN.md §11).
    pub attached_pages: usize,
    /// Copy-on-write tail copies this shard's append triggered.
    pub cow_copies: usize,
    /// Modeled cycles a resumed prefill avoided vs. the cold run of
    /// this shard (0 when nothing resumed).
    pub saved_cycles: u64,
}

struct GatherInner {
    /// Per-shard `(device_id, cycles, output)`, indexed by
    /// `head * live_chunks + chunk_pos`.
    done: Vec<Option<(usize, u64, Result<ShardOut, String>)>>,
    remaining: usize,
    kv_hits: usize,
    kv_misses: usize,
    /// Shards whose cycles were measured on the sim machine rather
    /// than modeled (DESIGN.md §8).
    measured_shards: usize,
    /// Sum of the shard breakdowns (order-independent) and how many
    /// shards carried one — the response reports attribution iff every
    /// shard did (DESIGN.md §9).
    breakdown_sum: CycleBreakdown,
    breakdown_shards: usize,
    /// Prefix-cache accounting summed over shards (DESIGN.md §11).
    attached_pages: usize,
    cow_copies: usize,
    saved_cycles: u64,
}

/// Per-request gather cell shared by all of the request's shards.
pub struct Gather {
    req: Arc<AttentionRequest>,
    reply: mpsc::Sender<AttentionResponse>,
    enqueued: Instant,
    /// Live chunks per head (1 = legacy layout).
    live_chunks: usize,
    /// Global chunk index of each live slot (for error messages).
    chunk_ids: Vec<usize>,
    inner: Mutex<GatherInner>,
}

impl Gather {
    /// Record one shard result.  Returns the assembled whole-operator
    /// response if this was the request's final outstanding shard (so
    /// the caller can record metrics before [`Gather::send`]), `None`
    /// while shards are still in flight.  `cfg` supplies the clock and
    /// peak-FLOPs constants for the whole-operator utilization metric —
    /// and, for sequence-sharded requests, the PWL segment count the
    /// in-order partial merge evaluates `exp2` with (the same device
    /// numerics the chunks were computed with).
    pub fn complete_and_report(
        &self,
        result: ShardResult,
        cfg: &AccelConfig,
    ) -> Option<AttentionResponse> {
        let slot = result.head * self.live_chunks + result.chunk_pos;
        let mut inner = super::lock(&self.inner);
        debug_assert!(inner.done[slot].is_none(), "shard completed twice");
        if inner.done[slot].is_none() {
            inner.remaining -= 1;
            match result.cache {
                CacheOutcome::Hit => inner.kv_hits += 1,
                CacheOutcome::Miss => inner.kv_misses += 1,
                CacheOutcome::NotApplicable => {}
            }
            if result.measured {
                inner.measured_shards += 1;
            }
            if let Some(bd) = &result.breakdown {
                inner.breakdown_sum.add(bd);
                inner.breakdown_shards += 1;
            }
            inner.attached_pages += result.attached_pages;
            inner.cow_copies += result.cow_copies;
            inner.saved_cycles += result.saved_cycles;
        }
        inner.done[slot] = Some((result.device_id, result.cycles, result.output));
        if inner.remaining > 0 {
            return None;
        }
        Some(self.assemble(&mut inner, cfg))
    }

    /// Deliver the gathered response to the submitter.  A vanished
    /// client (dropped receiver) is not an error.
    pub fn send(&self, response: AttentionResponse) {
        let _ = self.reply.send(response);
    }

    /// Convenience for tests and simple callers: record, and send the
    /// response if this shard completed the gather.
    pub fn complete(&self, result: ShardResult, cfg: &AccelConfig) {
        if let Some(resp) = self.complete_and_report(result, cfg) {
            self.send(resp);
        }
    }

    /// Build the whole-operator response from the completed shards:
    /// per head, either the legacy whole result or the in-chunk-order
    /// merge of the sequence partials.
    fn assemble(&self, inner: &mut GatherInner, cfg: &AccelConfig) -> AttentionResponse {
        let req = &self.req;
        // A resumed (prefix-cache warm) prefill computes only the
        // uncovered suffix query rows, so the response carries
        // `seq_len - resumed_from` rows per head; row 0 of the output
        // is global row `resumed_from` (= `stats.prefix_reused_tokens`,
        // DESIGN.md §11).  Admission caps `resumed_from` below
        // `seq_len`; the defensive min keeps a corrupt stamp from
        // underflowing.
        let head_elems = (req.seq_len - req.resumed_from.min(req.seq_len.saturating_sub(1)))
            * req.d;
        let live = self.live_chunks;
        // The merge evaluates exp2 exactly like the reference backend
        // that produced the partials (PWL + fp16 MAC, DESIGN.md §7).
        let exp2 = Exp2::PwlF16(PwlExp2::new(cfg.pwl_segments.max(1)));

        let mut output: Result<Vec<f32>, String> =
            Ok(Vec::with_capacity(req.num_heads * head_elems));
        let mut merge_steps = 0usize;
        let mut device_cycles = 0u64;
        let mut per_device: Vec<(usize, u64)> = Vec::new();
        let mut devices_used = Vec::new();
        let mut device_id = 0usize;

        for head in 0..req.num_heads {
            let mut state: Option<FlashPartial> = None;
            for pos in 0..live {
                let slot = head * live + pos;
                let (dev, cycles, out) =
                    inner.done[slot].take().expect("gather complete with missing shard");
                if head == 0 && pos == 0 {
                    device_id = dev;
                }
                device_cycles += cycles;
                match per_device.iter_mut().find(|(d, _)| *d == dev) {
                    Some((_, c)) => *c += cycles,
                    None => {
                        per_device.push((dev, cycles));
                        devices_used.push(dev);
                    }
                }
                // Keep the first failing shard's error (grid order).
                let fail = |output: &mut Result<Vec<f32>, String>, e: String| {
                    if output.is_ok() {
                        *output = Err(format!(
                            "head {head} chunk {}: {e}",
                            self.chunk_ids[pos]
                        ));
                    }
                };
                match out {
                    Ok(ShardOut::Full(o)) if live == 1 => {
                        if let Ok(buf) = &mut output {
                            debug_assert_eq!(o.len(), head_elems);
                            buf.extend_from_slice(&o);
                        }
                    }
                    Ok(ShardOut::Partial(p)) if live > 1 => {
                        if let Some(s) = state.as_mut() {
                            s.merge_from(&p, &exp2);
                            merge_steps += 1;
                        } else {
                            state = Some(p); // chunk 0: adopted, like flash's init
                        }
                    }
                    Ok(_) => fail(
                        &mut output,
                        "shard output kind does not match the chunk grid".into(),
                    ),
                    Err(e) => fail(&mut output, e),
                }
            }
            if live > 1 {
                if let (Ok(buf), Some(s)) = (&mut output, state) {
                    let merged = s.finalize();
                    debug_assert_eq!(merged.data.len(), head_elems);
                    buf.extend_from_slice(&merged.data);
                }
            }
        }
        devices_used.sort_unstable();

        let critical_path_cycles =
            per_device.iter().map(|&(_, c)| c).max().unwrap_or(0);
        let cycles_by_device: Vec<u64> = per_device.iter().map(|&(_, c)| c).collect();
        let utilization = pool_utilization(cfg, req.flops(), &cycles_by_device);

        AttentionResponse {
            id: req.id,
            output,
            num_heads: req.num_heads,
            num_kv_heads: req.num_kv_heads,
            shards: req.num_heads * live,
            device_cycles,
            critical_path_cycles,
            device_time: Duration::from_nanos(
                (critical_path_cycles as f64 / cfg.freq_ghz) as u64,
            ),
            utilization,
            latency: self.enqueued.elapsed(),
            device_id,
            devices_used,
            bucket: req.seq_len,
            kind: OpKind::of(&req.op),
            stats: ResponseStats {
                seq_chunks: live,
                merge_steps,
                kv_hits: inner.kv_hits,
                kv_misses: inner.kv_misses,
                measured_shards: inner.measured_shards,
                cycle_breakdown: (inner.breakdown_shards == req.num_heads * live)
                    .then_some(inner.breakdown_sum),
                prefix_reused_tokens: req.resumed_from,
                prefix_attached_pages: inner.attached_pages,
                cow_copies: inner.cow_copies,
                saved_prefill_cycles: inner.saved_cycles,
            },
        }
    }
}

/// The request's sequence-chunk grid: the global index, range, and
/// liveness of every chunk, from the shared
/// [`live_chunk_ranges`] rule the perfmodel prices with
/// (DESIGN.md §7).  Stateless and prefill requests split their
/// `seq_len` evenly; decode splits the grown `prefix_len` on the
/// prefill-time basis (so earlier chunk boundaries never move) and
/// carries no mask.  When no chunk survives (a fully-masked operator)
/// the whole sequence is served as one legacy shard, which produces
/// the defined zero output.
fn live_chunk_grid(req: &AttentionRequest, seq_shards: usize) -> Vec<(usize, (usize, usize))> {
    let (total, basis, mask) = match req.op {
        // Decode steps carry no mask: every token of the prefix counts.
        SessionOp::Decode { .. } => (
            req.prefix_len.max(req.seq_len),
            req.prefill_len.max(1),
            MaskKind::None,
        ),
        _ => (req.seq_len, req.seq_len, req.mask),
    };
    let mut live = live_chunk_ranges(req.seq_len, total, basis, seq_shards, mask);
    if live.is_empty() {
        // Fully-masked (or empty) operator: one legacy whole shard.
        live.push((0, (0, total)));
    }
    live
}

/// Split an ingress envelope into its `(head, chunk)` shard grid,
/// sharing the request behind an `Arc` and one gather cell.
/// `seq_shards = 1` (the legacy path) yields exactly one whole-sequence
/// shard per query head.
pub fn explode(env: Envelope, seq_shards: usize) -> Vec<ShardEnvelope> {
    let Envelope { req, reply, enqueued } = env;
    let num_heads = req.num_heads;
    let ctx = match req.op {
        SessionOp::Prefill { session } => ShardCtx::Prefill { session, epoch: req.epoch },
        SessionOp::Decode { session, .. } => {
            ShardCtx::Decode { session, prefix_len: req.prefix_len, epoch: req.epoch }
        }
        // Close is answered at the admission gate and never dispatched; treat
        // a stray one as stateless rather than panicking.
        SessionOp::Stateless | SessionOp::Close { .. } => ShardCtx::Stateless,
    };
    let grid = live_chunk_grid(&req, seq_shards.max(1));
    let live = grid.len();
    let req = Arc::new(req);
    let gather = Arc::new(Gather {
        req: req.clone(),
        reply,
        enqueued,
        live_chunks: live,
        chunk_ids: grid.iter().map(|&(c, _)| c).collect(),
        inner: Mutex::new(GatherInner {
            done: (0..num_heads * live).map(|_| None).collect(),
            remaining: num_heads * live,
            kv_hits: 0,
            kv_misses: 0,
            measured_shards: 0,
            breakdown_sum: CycleBreakdown::default(),
            breakdown_shards: 0,
            attached_pages: 0,
            cow_copies: 0,
            saved_cycles: 0,
        }),
    });
    let mut shards = Vec::with_capacity(num_heads * live);
    for head in 0..num_heads {
        for (pos, &(chunk, kv_range)) in grid.iter().enumerate() {
            shards.push(ShardEnvelope {
                shard: HeadShard {
                    req: req.clone(),
                    head,
                    kv_head: req.kv_head_for(head),
                    chunk,
                    chunk_pos: pos,
                    kv_range,
                    live_chunks: live,
                },
                gather: gather.clone(),
                enqueued,
                ctx,
            });
        }
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::MaskKind;
    use crate::numerics::reference::{flash_pwl_masked, flash_pwl_partial, Mat};
    use crate::numerics::SplitMix64;

    fn fsa() -> AccelConfig {
        AccelConfig::builtin("fsa").unwrap()
    }

    fn gqa_envelope(
        heads: usize,
        kv_heads: usize,
        seq: usize,
        d: usize,
    ) -> (Envelope, mpsc::Receiver<AttentionResponse>) {
        let (tx, rx) = mpsc::channel();
        let q = vec![0.5f32; heads * seq * d];
        let kv = vec![0.25f32; kv_heads * seq * d];
        let env = Envelope {
            req: AttentionRequest::gqa(7, seq, d, heads, kv_heads, q, kv.clone(), kv),
            reply: tx,
            enqueued: Instant::now(),
        };
        (env, rx)
    }

    fn full(head: usize, dev: usize, cycles: u64, out: Vec<f32>) -> ShardResult {
        ShardResult {
            head,
            chunk_pos: 0,
            device_id: dev,
            cycles,
            measured: false,
            output: Ok(ShardOut::Full(out)),
            cache: CacheOutcome::NotApplicable,
            breakdown: None,
            attached_pages: 0,
            cow_copies: 0,
            saved_cycles: 0,
        }
    }

    #[test]
    fn explode_yields_one_shard_per_query_head() {
        let (env, _rx) = gqa_envelope(8, 2, 4, 2);
        let shards = explode(env, 1);
        assert_eq!(shards.len(), 8);
        let kv: Vec<usize> = shards.iter().map(|s| s.shard.kv_head).collect();
        assert_eq!(kv, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        // All shards share one request allocation and one gather cell.
        assert!(Arc::ptr_eq(&shards[0].shard.req, &shards[7].shard.req));
        assert!(Arc::ptr_eq(&shards[0].gather, &shards[7].gather));
        assert_eq!(shards[3].shard.affinity_key(), (7, 0, 0));
        assert_eq!(shards[4].shard.affinity_key(), (7, 1, 0));
        // Legacy layout: one whole-sequence chunk, not partial.
        assert!(shards.iter().all(|s| s.shard.kv_range == (0, 4)));
        assert!(shards.iter().all(|s| !s.shard.is_partial()));
    }

    #[test]
    fn explode_builds_the_head_chunk_grid() {
        let (env, _rx) = gqa_envelope(4, 2, 64, 2);
        let shards = explode(env, 4);
        assert_eq!(shards.len(), 16, "4 heads x 4 chunks");
        // Head-major, chunk-minor order with even 16-token ranges.
        let s0: Vec<_> = shards[..4].iter().map(|s| s.shard.kv_range).collect();
        assert_eq!(s0, vec![(0, 16), (16, 16), (32, 16), (48, 16)]);
        assert!(shards.iter().all(|s| s.shard.is_partial()));
        // Chunks of one head have distinct affinity keys (they scatter);
        // the same chunk of two grouped heads shares one (they travel
        // together).
        assert_ne!(shards[0].shard.affinity_key(), shards[1].shard.affinity_key());
        assert_eq!(shards[0].shard.affinity_key(), shards[4].shard.affinity_key());
        assert_eq!(shards[0].shard.chunk_pos, 0);
        assert_eq!(shards[3].shard.chunk, 3);
    }

    #[test]
    fn fully_masked_chunks_are_never_dispatched() {
        let (env, _rx) = gqa_envelope(2, 1, 64, 2);
        let mut env = env;
        // Keys beyond 20 are padding: chunks 2 and 3 ([32,48), [48,64))
        // are dead; chunk 1 ([16,32)) is partially live.
        env.req.mask = MaskKind::PaddingKeys { valid: 20 };
        let shards = explode(env, 4);
        assert_eq!(shards.len(), 4, "2 heads x 2 live chunks");
        let ranges: Vec<_> = shards[..2].iter().map(|s| s.shard.kv_range).collect();
        assert_eq!(ranges, vec![(0, 16), (16, 16)]);
        assert_eq!(shards[0].shard.live_chunks, 2);

        // A fully-masked operator degenerates to one legacy shard per
        // head (defined zero output), never zero shards.
        let (env, _rx) = gqa_envelope(2, 1, 64, 2);
        let mut env = env;
        env.req.mask = MaskKind::PaddingKeys { valid: 0 };
        let shards = explode(env, 4);
        assert_eq!(shards.len(), 2);
        assert!(!shards[0].shard.is_partial());
        assert_eq!(shards[0].shard.kv_range, (0, 64));
    }

    #[test]
    fn gather_assembles_head_major_output_and_pool_accounting() {
        let (seq, d) = (2, 2);
        let (env, rx) = gqa_envelope(4, 2, seq, d);
        let shards = explode(env, 1);
        // Complete out of order, two devices, head h output = constant h.
        for &h in &[2usize, 0, 3, 1] {
            shards[h].gather.complete(full(h, h % 2, 100, vec![h as f32; seq * d]), &fsa());
        }
        let resp = rx.try_recv().expect("gather must reply after last shard");
        assert_eq!(resp.id, 7);
        assert_eq!(resp.shards, 4);
        assert_eq!(resp.stats.seq_chunks, 1);
        assert_eq!(resp.stats.merge_steps, 0);
        assert_eq!(resp.num_heads, 4);
        assert_eq!(resp.num_kv_heads, 2);
        assert_eq!(resp.devices_used, vec![0, 1]);
        assert_eq!(resp.device_id, 0); // head 0 ran on device 0
        assert_eq!(resp.device_cycles, 400);
        assert_eq!(resp.critical_path_cycles, 200); // 2 heads per device
        let out = resp.output.unwrap();
        // Head-major: head h occupies [h*4 .. (h+1)*4).
        for h in 0..4 {
            assert!(out[h * 4..(h + 1) * 4].iter().all(|&x| x == h as f32));
        }
        assert!(resp.utilization > 0.0);
        assert_eq!(resp.kind, OpKind::Stateless);
        assert!(resp.stats.cycle_breakdown.is_none(), "modeled shards carry no attribution");
        assert_eq!(resp.stats.prefix_reused_tokens, 0, "stateless never resumes");
    }

    #[test]
    fn gather_sums_breakdowns_iff_every_shard_carried_one() {
        let mk = |with_bd: [bool; 2]| {
            let (env, rx) = gqa_envelope(2, 1, 2, 2);
            let shards = explode(env, 1);
            for h in 0..2 {
                let mut r = full(h, 0, 50, vec![0.0; 4]);
                if with_bd[h] {
                    let mut bd = CycleBreakdown::default();
                    bd.score = 30;
                    bd.dma = 20;
                    r.breakdown = Some(bd);
                    r.measured = true;
                }
                shards[h].gather.complete(r, &fsa());
            }
            rx.try_recv().unwrap()
        };
        // All shards measured: attribution present, summed, exact.
        let resp = mk([true, true]);
        let bd = resp.stats.cycle_breakdown.expect("all shards carried a breakdown");
        assert_eq!(bd.score, 60);
        assert_eq!(bd.dma, 40);
        assert_eq!(bd.total(), resp.device_cycles);
        // A single modeled shard suppresses the whole-operator claim.
        assert!(mk([true, false]).stats.cycle_breakdown.is_none());
    }

    #[test]
    fn sequence_sharded_gather_merges_partials_in_chunk_order() {
        // Two chunks per head, completed in *reverse* order across two
        // devices: the merged output must still be the in-chunk-order
        // fold — bitwise the host-side oracle — proving completion
        // order and placement cannot perturb the numerics.
        let (seq, d, heads) = (32usize, 8usize, 2usize);
        let cfg = fsa();
        let mut rng = SplitMix64::new(91);
        let (tx, rx) = mpsc::channel();
        let q = rng.normal_matrix(heads * seq, d);
        let kv = rng.normal_matrix(seq, d);
        let req = AttentionRequest::gqa(3, seq, d, heads, 1, q.clone(), kv.clone(), kv.clone());
        let shards = explode(
            Envelope { req, reply: tx, enqueued: Instant::now() },
            2,
        );
        assert_eq!(shards.len(), 4);

        // Host-side oracle: per-head partials over the same grid.
        let oracle_part = |head: usize, (start, len): (usize, usize)| {
            let qm = Mat::new(seq, d, q[head * seq * d..(head + 1) * seq * d].to_vec());
            let km = Mat::new(len, d, kv[start * d..(start + len) * d].to_vec());
            let vm = Mat::new(len, d, kv[start * d..(start + len) * d].to_vec());
            flash_pwl_partial(
                &qm, &km, &vm,
                cfg.array_size, cfg.array_size, cfg.pwl_segments,
                MaskKind::None, start, seq,
            )
        };
        // Complete chunk 1 before chunk 0 on different devices.
        for env in shards.iter().rev() {
            let s = &env.shard;
            env.gather.complete(
                ShardResult {
                    head: s.head,
                    chunk_pos: s.chunk_pos,
                    device_id: s.chunk_pos, // chunk -> its own device
                    cycles: 10,
                    measured: false,
                    output: Ok(ShardOut::Partial(oracle_part(s.head, s.kv_range))),
                    cache: CacheOutcome::NotApplicable,
                    breakdown: None,
                    attached_pages: 0,
                    cow_copies: 0,
                    saved_cycles: 0,
                },
                &cfg,
            );
        }
        let resp = rx.try_recv().expect("gather replies once all shards land");
        assert_eq!(resp.shards, 4);
        assert_eq!(resp.stats.seq_chunks, 2);
        assert_eq!(resp.stats.merge_steps, heads * 1, "one merge per head");
        assert_eq!(resp.devices_used, vec![0, 1]);
        let out = resp.output.unwrap();
        // The merged result equals the ordered host-side fold, which for
        // these inputs is within the PWL band of the whole kernel — and
        // bitwise equal to merging the oracle partials directly.
        use crate::numerics::reference::merge_partials;
        let exp2 = Exp2::PwlF16(PwlExp2::new(cfg.pwl_segments));
        for h in 0..heads {
            let want = merge_partials(
                &[oracle_part(h, (0, 16)), oracle_part(h, (16, 16))],
                &exp2,
            );
            assert_eq!(&out[h * seq * d..(h + 1) * seq * d], &want.data[..], "head {h}");
            // Sanity: the merge is numerically the whole-head kernel.
            let qm = Mat::new(seq, d, q[h * seq * d..(h + 1) * seq * d].to_vec());
            let km = Mat::new(seq, d, kv.clone());
            let whole = flash_pwl_masked(&qm, &km, &km, 128, 128, 8, MaskKind::None);
            let err = crate::numerics::reference::mat_error(&want, &whole);
            assert!(err.mae < 3e-2, "head {h}: {err:?}");
        }
    }

    #[test]
    fn gather_surfaces_first_failing_head() {
        let (env, rx) = gqa_envelope(2, 1, 2, 2);
        let shards = explode(env, 1);
        for h in 0..2 {
            shards[h].gather.complete(
                ShardResult {
                    head: h,
                    chunk_pos: 0,
                    device_id: 0,
                    cycles: 10,
                    measured: false,
                    output: if h == 1 {
                        Err("boom".into())
                    } else {
                        Ok(ShardOut::Full(vec![0.0; 4]))
                    },
                    cache: CacheOutcome::NotApplicable,
                    breakdown: None,
                    attached_pages: 0,
                    cow_copies: 0,
                    saved_cycles: 0,
                },
                &fsa(),
            );
        }
        let resp = rx.try_recv().unwrap();
        let err = resp.output.unwrap_err();
        assert!(err.contains("head 1") && err.contains("boom"), "{err}");
        assert_eq!(resp.device_cycles, 20);
    }

    #[test]
    fn decode_shards_carry_ctx_and_gather_counts_cache_outcomes() {
        let d = 2;
        let (tx, rx) = mpsc::channel();
        let mut req = AttentionRequest::decode(
            11, 42, 3, d, 4, 2,
            vec![0.0; 4 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
        );
        req.prefix_len = 9; // admission gate stamps
        req.epoch = 5;
        let shards = explode(Envelope { req, reply: tx, enqueued: Instant::now() }, 1);
        assert_eq!(shards.len(), 4);
        for s in &shards {
            assert_eq!(s.ctx, ShardCtx::Decode { session: 42, prefix_len: 9, epoch: 5 });
            assert_eq!(s.shard.kv_range, (0, 9), "legacy decode covers the prefix");
        }
        for h in 0..4 {
            shards[h].gather.complete(
                ShardResult {
                    head: h,
                    chunk_pos: 0,
                    device_id: 0,
                    cycles: 7,
                    measured: h == 0,
                    output: Ok(ShardOut::Full(vec![0.5; d])),
                    cache: if h == 2 { CacheOutcome::Miss } else { CacheOutcome::Hit },
                    breakdown: None,
                    attached_pages: 0,
                    cow_copies: 0,
                    saved_cycles: 0,
                },
                &fsa(),
            );
        }
        let resp = rx.try_recv().unwrap();
        assert_eq!(resp.stats.kv_hits, 3);
        assert_eq!(resp.stats.kv_misses, 1);
        assert_eq!(resp.stats.measured_shards, 1, "one shard priced from measured cycles");
        assert_eq!(resp.kind, OpKind::Decode);
        // Decode output is one row per head.
        assert_eq!(resp.output.unwrap().len(), 4 * d);
    }

    #[test]
    fn decode_chunk_grid_uses_the_prefill_basis() {
        // Prefill basis 8, prefix grown to 11 by decode: the first
        // chunk keeps its prefill-time boundary, the last absorbs the
        // appended tokens (last-chunk-grows, DESIGN.md §7).
        let d = 2;
        let (tx, _rx) = mpsc::channel();
        let mut req = AttentionRequest::decode(
            1, 9, 2, d, 2, 1, vec![0.0; 2 * d], vec![0.0; d], vec![0.0; d],
        );
        req.prefix_len = 11;
        req.prefill_len = 8;
        req.epoch = 1;
        let shards = explode(Envelope { req, reply: tx, enqueued: Instant::now() }, 2);
        assert_eq!(shards.len(), 4, "2 heads x 2 chunks");
        let ranges: Vec<_> = shards[..2].iter().map(|s| s.shard.kv_range).collect();
        assert_eq!(ranges, vec![(0, 4), (4, 7)]);
        assert!(shards.iter().all(|s| s.shard.is_partial()));
    }
}
