//! The continuous-batching serving loop (DESIGN.md §10): a persistent
//! scheduler that drains the ingress into the [`super::queue::WaitQueue`],
//! decides per iteration what may run (token budgets + the
//! waiting-vs-served ratio), and assembles dispatch waves in which
//! decode steps of many live sessions — and compatible prefill-class
//! shards — share device batches.
//!
//! This is the TGI `Infer`/`Queue`/batching-task topology on the
//! repo's threads-and-channels substrate: requests *join* a running
//! batch as they arrive, finished/closed sessions *leave* it, and a
//! fresh prefill is admitted only when [`allow_prefill`] says the
//! waiting side has earned its slot.  The one-shot `Batcher` this
//! replaces admitted everything immediately; its admission gate
//! ([`super::batcher::admit_session_op`]) and grouping rules live on
//! here unchanged, which is why the serving contract holds:
//!
//! **Bitwise one-shot equivalence.**  Scheduling decides only *when*
//! an envelope reaches the admission gate, never *what* it computes.
//! The wait queue preserves per-session order (a deferred prefill
//! blocks its session's later entries, [`super::queue`]), the gate
//! stamps the same epochs/prefixes it always did, and each request's
//! shard grid, gather merge order, and numerics are untouched — so
//! every response is bitwise identical to the one-shot path's, pinned
//! by `rust/tests/coordinator_continuous.rs` across backends, masks,
//! and shard counts.
//!
//! Responses stream per request, as they always have: each envelope
//! carries its own reply channel, answered the moment its last shard
//! gathers — a decode step's client is answered mid-run while other
//! sessions' steps are still in flight, not at end-of-batch.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::mask::MaskKind;

use super::batcher::{admit_session_op, op_session, reply_inline, PoolCapabilities};
use super::metrics::Metrics;
use super::queue::{Verdict, WaitQueue, WavePolicy};
use super::request::Envelope;
use super::router::Router;
use super::session::{SessionOp, SessionTable};
use super::shard::{explode, ShardCtx, ShardEnvelope};
use super::trace::{EventKind, Tracer, NO_DEVICE, NO_HEAD};

/// Batch compatibility key: shards sharing it may run in one device
/// batch (same kernel shape) — sequence length, head dim, and mask
/// *kind* (`std::mem::Discriminant`): masked and unmasked shards are
/// different kernels, but two `PaddingKeys` requests with different
/// `valid` prefixes share one (execution is per-shard with the shard's
/// own mask, so batching them together is safe — keying on the exact
/// `valid` would put every padded length in its own group and defeat
/// cross-request batching on exactly the padded traffic).  Decode
/// shards carry `seq_len = 1` and no mask, so steps of *different
/// sessions* share a key — the continuous-batching payoff.
type GroupKey = (usize, usize, std::mem::Discriminant<MaskKind>);

/// The scheduler's token-budget knobs, from
/// [`RunConfig`](crate::config::RunConfig) (INI `[run]` keys /
/// `fsa serve` flags of the same names).
#[derive(Clone, Copy, Debug)]
pub struct TokenBudget {
    /// `max_batch_prefill_tokens`: Σ `seq_len` over prefill-class
    /// (stateless + prefill) entries admitted per wave.
    pub max_prefill_tokens: usize,
    /// `max_batch_total_tokens`: live session tokens + this wave's
    /// prefill-class tokens.
    pub max_total_tokens: usize,
    /// `waiting_served_ratio`: admit a fresh prefill over pending
    /// decode work once waiting prefill tokens ≥ ratio × live tokens.
    pub waiting_served_ratio: f64,
}

impl TokenBudget {
    /// Budgets that never defer or reject (unit tests and callers that
    /// only want the grouping behavior).
    pub fn unlimited() -> TokenBudget {
        TokenBudget {
            max_prefill_tokens: usize::MAX,
            max_total_tokens: usize::MAX,
            waiting_served_ratio: 0.0,
        }
    }
}

/// The waiting-vs-served prefill decision (TGI's `max_waiting_tokens`
/// knob, expressed as a ratio): should this wave admit prefill-class
/// work, or keep the array to pending decode steps?
///
/// Admit when any of:
/// * no runnable decode step is waiting — there is nothing to starve;
/// * no session tokens are live — an idle pool must never hold work
///   back (this is what keeps sequential `submit_wait` clients
///   prompt);
/// * the oldest waiting prefill-class entry has aged past the batch
///   timeout — the starvation bound that makes deferral time-bounded;
/// * waiting prefill tokens ≥ `ratio` × live tokens — the waiting side
///   has earned its slot.
///
/// Otherwise defer: pending decode steps keep their TPOT.
pub fn allow_prefill(
    waiting_prefill_tokens: usize,
    live_tokens: usize,
    decode_pending: bool,
    oldest_wait: Option<Duration>,
    timeout: Duration,
    ratio: f64,
) -> bool {
    if !decode_pending || live_tokens == 0 {
        return true;
    }
    if oldest_wait.map(|w| w >= timeout).unwrap_or(false) {
        return true;
    }
    waiting_prefill_tokens as f64 >= ratio * live_tokens as f64
}

/// The persistent serving loop: one per coordinator, owning the wait
/// queue and the open (not-yet-dispatched) shard groups.
pub struct Scheduler {
    max_batch: usize,
    /// Timeout expressed in simulated device cycles in the config; the
    /// scheduler converts at the *configured* clock
    /// (`RunConfig::freq_ghz`) to a host duration.  It bounds both
    /// group dispatch (a non-full group flushes once its oldest shard
    /// ages past it) and prefill deferral ([`allow_prefill`]).
    timeout: Duration,
    /// Sequence-parallel shard count every admitted request explodes at
    /// (`RunConfig::seq_shards`; 1 = legacy whole-sequence shards).
    seq_shards: usize,
    /// Resolved backend capabilities
    /// ([`super::batcher::PoolCapabilities`]).
    caps: PoolCapabilities,
    /// Token budgets + ratio knob (DESIGN.md §10).
    budget: TokenBudget,
    /// Cross-session prefix cache page size in tokens (DESIGN.md §11):
    /// the block granularity of the [`SessionTable`] prefix index the
    /// admission match hash-walks.  0 (the default) disables prefix
    /// matching entirely — every request runs cold, exactly the
    /// pre-§11 behavior.
    prefix_page_size: usize,
    /// Request-path event sink (DESIGN.md §9); disabled by default.
    tracer: Arc<Tracer>,
}

impl Scheduler {
    pub fn new(
        max_batch: usize,
        timeout_cycles: u64,
        freq_ghz: f64,
        seq_shards: usize,
        caps: PoolCapabilities,
        budget: TokenBudget,
    ) -> Scheduler {
        assert!(freq_ghz > 0.0, "clock must be positive (RunConfig::validate)");
        Scheduler {
            max_batch: max_batch.max(1),
            timeout: Duration::from_nanos((timeout_cycles as f64 / freq_ghz) as u64),
            seq_shards: seq_shards.max(1),
            caps,
            budget,
            prefix_page_size: 0,
            tracer: Tracer::off(),
        }
    }

    /// Attach a request-path tracer (the coordinator threads its own;
    /// directly constructed schedulers keep the disabled default).
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> Scheduler {
        self.tracer = tracer;
        self
    }

    /// Enable cross-session prefix matching at `page_size`-token block
    /// granularity (DESIGN.md §11); 0 keeps it off (the default).
    pub fn with_prefix_cache(mut self, page_size: usize) -> Scheduler {
        self.prefix_page_size = page_size;
        self
    }

    /// The serving loop.  Each iteration: (1) ingest whatever the
    /// ingress holds into the wait queue, (2) compute this wave's
    /// [`WavePolicy`] from the budgets and the pool's live tokens,
    /// (3) pop the admissible wave, push each admitted envelope through
    /// the admission gate and into shard groups, answer rejects inline,
    /// (4) dispatch groups that are full or whose oldest shard timed
    /// out.  Exits when the ingress disconnects, after flushing the
    /// queue under [`WavePolicy::flush`] (budgets are scheduling
    /// policy — with no ingress left, holding work back would strand
    /// clients, so everything still queued is admitted in order) and
    /// dispatching every open group.
    pub fn run(
        &self,
        rx: mpsc::Receiver<Envelope>,
        router: Router,
        metrics: Arc<Metrics>,
        sessions: Arc<SessionTable>,
    ) {
        let mut wait = WaitQueue::new();
        let mut groups: Vec<(GroupKey, Vec<ShardEnvelope>)> = Vec::new();
        loop {
            // Block briefly so group timeouts and deferred-entry
            // retries fire even when the ingress is idle.
            let mut disconnected = false;
            let mut ingested = 0usize;
            match rx.recv_timeout(self.timeout.min(Duration::from_millis(5))) {
                Ok(env) => {
                    self.ingest(env, &mut wait, &metrics, &sessions);
                    ingested += 1;
                    // Opportunistically drain whatever else is queued.
                    while let Ok(env) = rx.try_recv() {
                        self.ingest(env, &mut wait, &metrics, &sessions);
                        ingested += 1;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
            }

            // Iteration accounting: only iterations with work in sight
            // count (and sample queue depth) — idle 5 ms ticks would
            // otherwise flood the histogram with zeros and make
            // `sched_iterations` a wall-clock proxy instead of a
            // scheduling-decision count.
            if ingested > 0 || !wait.is_empty() || !groups.is_empty() {
                metrics.sched_iterations.fetch_add(1, Ordering::Relaxed);
                // Steady-state queueing, sampled once per working
                // iteration (the admit-time sample in `resolve` only
                // sees arrival bursts).
                metrics.record_queue_depth(wait.len() as u64);
            }

            let policy = if disconnected {
                WavePolicy::flush()
            } else {
                let live_tokens = sessions.live_tokens();
                WavePolicy {
                    max_prefill_tokens: self.budget.max_prefill_tokens,
                    max_total_tokens: self.budget.max_total_tokens,
                    live_tokens,
                    allow_prefill: allow_prefill(
                        wait.waiting_prefill_tokens(),
                        live_tokens,
                        wait.has_runnable_decode(),
                        wait.oldest_prefill_wait(Instant::now()),
                        self.timeout,
                        self.budget.waiting_served_ratio,
                    ),
                }
            };
            for verdict in wait.pop_wave(&policy) {
                match verdict {
                    Verdict::Admit(env) => self.resolve(env, &mut groups, &metrics, &sessions),
                    Verdict::Reject(env, msg) => {
                        metrics.sched_rejected.fetch_add(1, Ordering::Relaxed);
                        reply_inline(env, Err(msg), &metrics);
                    }
                }
            }

            if disconnected {
                for (_, g) in groups.drain(..) {
                    for chunk in Self::chunks(g, self.max_batch) {
                        self.dispatch_wave(chunk, &router, &metrics);
                    }
                }
                return;
            }

            // Dispatch full groups and timed-out groups.
            let now = Instant::now();
            let mut i = 0;
            while i < groups.len() {
                let ready = groups[i].1.len() >= self.max_batch
                    || groups[i]
                        .1
                        .first()
                        .map(|e| now.duration_since(e.enqueued) >= self.timeout)
                        .unwrap_or(false);
                if ready {
                    let (_, g) = groups.swap_remove(i);
                    for chunk in Self::chunks(g, self.max_batch) {
                        self.dispatch_wave(chunk, &router, &metrics);
                    }
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Ingest one envelope into the wait queue (trace payload: queue
    /// length after the push).
    ///
    /// With the prefix cache on, this is where a prefill is matched
    /// against the live sessions' indexed prefixes (DESIGN.md §11) and
    /// stamped `resumed_from`/`prefix_donor` — BEFORE it enters the
    /// queue, so the token budgets and the waiting ratio price only the
    /// uncovered suffix it will actually compute.  The match is
    /// hash-walked then byte-verified ([`SessionTable::match_prefix`]),
    /// so a stamp can never be a collision; a donor closing between
    /// here and execution is harmless (the stamp only selects which
    /// query rows the devices compute — the request carries its full
    /// K/V either way).
    fn ingest(
        &self,
        mut env: Envelope,
        wait: &mut WaitQueue,
        metrics: &Metrics,
        sessions: &SessionTable,
    ) {
        metrics.sched_queued.fetch_add(1, Ordering::Relaxed);
        let (id, session) = (env.req.id, op_session(&env.req.op));
        if self.prefix_page_size > 0 && matches!(env.req.op, SessionOp::Prefill { .. }) {
            match sessions.match_prefix(&env.req, self.prefix_page_size) {
                Some(m) => {
                    env.req.resumed_from = m.covered;
                    env.req.prefix_donor = Some(m.donor);
                    metrics.prefix_hits.fetch_add(1, Ordering::Relaxed);
                    self.tracer.record(
                        EventKind::PrefixHit,
                        id,
                        session,
                        NO_HEAD,
                        NO_HEAD,
                        NO_DEVICE,
                        m.covered as u64,
                    );
                }
                None => {
                    metrics.prefix_misses.fetch_add(1, Ordering::Relaxed);
                    self.tracer.record(
                        EventKind::PrefixMiss,
                        id,
                        session,
                        NO_HEAD,
                        NO_HEAD,
                        NO_DEVICE,
                        0,
                    );
                }
            }
        }
        wait.push(env);
        self.tracer.record(
            EventKind::Enqueue,
            id,
            session,
            NO_HEAD,
            NO_HEAD,
            NO_DEVICE,
            wait.len() as u64,
        );
    }

    /// Push one wave-admitted envelope through the session/capability
    /// gate and, if it survives, into its shard group.
    fn resolve(
        &self,
        env: Envelope,
        groups: &mut Vec<(GroupKey, Vec<ShardEnvelope>)>,
        metrics: &Metrics,
        sessions: &SessionTable,
    ) {
        // Requests in flight right now (submitted minus completed;
        // saturating because the two relaxed counters race by design) —
        // the per-envelope arrival-side sample, kept alongside the
        // per-iteration one in `run`.
        let o = Ordering::Relaxed;
        metrics.record_queue_depth(
            (metrics.submitted.load(o) as u64)
                .saturating_sub(metrics.completed.load(o) as u64),
        );
        let Some(env) = admit_session_op(env, sessions, metrics, self.caps, self.seq_shards)
        else {
            // Answered in place (close / lifecycle / capability error):
            // the inline-answer side of the reconciliation invariant.
            metrics.sched_rejected.fetch_add(1, o);
            return;
        };
        metrics.sched_admitted.fetch_add(1, o);
        // Prefix-cache bookkeeping (DESIGN.md §11), now that the gate
        // has opened the session: adopt the donor's device placement so
        // the warm session's KV streams land where the shared pages
        // live (attach by refcount instead of copying), then index the
        // new prefix so later arrivals can resume from it.  Matching
        // happens at ingest and indexing here, strictly after — so a
        // request can never match itself.  `adopt_placement` is a no-op
        // when the donor closed in between.
        if self.prefix_page_size > 0 {
            if let SessionOp::Prefill { session: sid } = env.req.op {
                if let Some(donor) = env.req.prefix_donor {
                    sessions.adopt_placement(donor, sid);
                }
                sessions.index_prefix(sid, self.prefix_page_size);
            }
        }
        let (id, session) = (env.req.id, op_session(&env.req.op));
        self.tracer.record(
            EventKind::Admit,
            id,
            session,
            NO_HEAD,
            NO_HEAD,
            NO_DEVICE,
            env.req.seq_len as u64,
        );
        let key = (env.req.seq_len, env.req.d, std::mem::discriminant(&env.req.mask));
        let shards = explode(env, self.seq_shards);
        self.tracer.record(
            EventKind::Shard,
            id,
            session,
            NO_HEAD,
            NO_HEAD,
            NO_DEVICE,
            shards.len() as u64,
        );
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, g)) => g.extend(shards),
            None => groups.push((key, shards)),
        }
    }

    /// Dispatch one device batch, classifying its wave mix for the
    /// scheduler counters: occupancy, prefill/decode presence, and —
    /// the continuous-batching payoff — decode waves spanning more
    /// than one session.
    fn dispatch_wave(&self, chunk: Vec<ShardEnvelope>, router: &Router, metrics: &Metrics) {
        let o = Ordering::Relaxed;
        metrics.batches.fetch_add(1, o);
        metrics.record_batch_occupancy(chunk.len() as u64);
        let mut decode_sessions: Vec<u64> = Vec::new();
        let mut prefill_class = false;
        for e in &chunk {
            match e.ctx {
                ShardCtx::Decode { session, .. } => {
                    if !decode_sessions.contains(&session) {
                        decode_sessions.push(session);
                    }
                }
                ShardCtx::Prefill { .. } | ShardCtx::Stateless => prefill_class = true,
            }
        }
        if prefill_class {
            metrics.prefill_waves.fetch_add(1, o);
        }
        if !decode_sessions.is_empty() {
            metrics.decode_waves.fetch_add(1, o);
            if decode_sessions.len() > 1 {
                metrics.multi_session_decode_waves.fetch_add(1, o);
            }
        }
        router.dispatch(chunk);
    }

    fn chunks(mut g: Vec<ShardEnvelope>, max: usize) -> Vec<Vec<ShardEnvelope>> {
        let mut out = Vec::new();
        while g.len() > max {
            let rest = g.split_off(max);
            out.push(g);
            g = rest;
        }
        if !g.is_empty() {
            out.push(g);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::AttentionRequest;

    fn envs(n: u64, seq: usize) -> Vec<ShardEnvelope> {
        let d = 4;
        (0..n)
            .flat_map(|id| {
                let m = vec![0.0f32; seq * d];
                explode(
                    Envelope {
                        req: AttentionRequest::new(id, seq, d, m.clone(), m.clone(), m),
                        reply: mpsc::channel().0,
                        enqueued: Instant::now(),
                    },
                    1,
                )
            })
            .collect()
    }

    /// The batch timeout converts cycles at the configured clock, not a
    /// hard-coded 1.5 GHz — 150k cycles are 100 µs at 1.5 GHz but
    /// 150 µs at 1.0 GHz.
    #[test]
    fn timeout_converts_at_the_configured_clock() {
        let at = |ghz: f64| {
            Scheduler::new(
                4,
                150_000,
                ghz,
                1,
                PoolCapabilities::reference(),
                TokenBudget::unlimited(),
            )
            .timeout
        };
        assert_eq!(at(1.5), Duration::from_nanos(100_000));
        assert_eq!(at(1.0), Duration::from_nanos(150_000));
        assert_eq!(at(3.0), Duration::from_nanos(50_000));
    }

    #[test]
    fn chunking_respects_max_batch() {
        let g = envs(10, 8);
        let chunks = Scheduler::chunks(g, 4);
        let sizes: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
        // No shard lost or duplicated.
        let mut ids: Vec<u64> = chunks.iter().flatten().map(|e| e.shard.req.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn empty_group_produces_no_chunks() {
        assert!(Scheduler::chunks(vec![], 4).is_empty());
    }

    #[test]
    fn multi_head_request_contributes_one_shard_per_head() {
        let (seq, d, heads) = (8, 4, 4);
        let q = vec![0.0f32; heads * seq * d];
        let kv = vec![0.0f32; seq * d];
        let shards = explode(
            Envelope {
                req: AttentionRequest::gqa(1, seq, d, heads, 1, q, kv.clone(), kv),
                reply: mpsc::channel().0,
                enqueued: Instant::now(),
            },
            1,
        );
        // One 4-head request + batch limit 3 => chunks of 3 + 1.
        let sizes: Vec<usize> =
            Scheduler::chunks(shards, 3).iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![3, 1]);
    }

    #[test]
    fn group_keys_split_on_mask_kind_but_not_padding_valid() {
        // Masked and unmasked shards are different kernels and must not
        // share a batch; two key-padding requests padded to the same
        // bucket from different original lengths MUST share one (else
        // every padded length waits out its own batch timeout).
        let key = |m: MaskKind| std::mem::discriminant(&m);
        assert_ne!(key(MaskKind::None), key(MaskKind::Causal));
        assert_ne!(key(MaskKind::None), key(MaskKind::PaddingKeys { valid: 7 }));
        assert_eq!(
            key(MaskKind::PaddingKeys { valid: 100 }),
            key(MaskKind::PaddingKeys { valid: 101 })
        );
    }

    /// Satellite (prefix cache, DESIGN.md §11): ingest stamps a
    /// byte-verified prefix match onto the request — and only with the
    /// cache enabled — so the wait queue prices the uncovered suffix.
    #[test]
    fn ingest_stamps_prefix_matches_only_when_enabled() {
        use crate::coordinator::metrics::Metrics;
        use crate::coordinator::queue::WaitQueue;
        use crate::coordinator::session::SessionTable;

        let sessions = SessionTable::new();
        let metrics = Metrics::new();
        let d = 2;
        let kv: Vec<f32> = (0..8 * d).map(|x| x as f32 + 1.0).collect();
        // Donor: a live session with an indexed prefix.
        let donor =
            AttentionRequest::prefill(1, 7, 8, d, 1, 1, vec![0.0; 8 * d], kv.clone(), kv.clone());
        sessions.open(7, &donor, 1).unwrap();
        sessions.index_prefix(7, 4);
        let mk = || Envelope {
            req: AttentionRequest::prefill(
                2, 9, 8, d, 1, 1, vec![1.0; 8 * d], kv.clone(), kv.clone(),
            ),
            reply: mpsc::channel().0,
            enqueued: Instant::now(),
        };
        let sched = |page: usize| {
            Scheduler::new(
                4,
                150_000,
                1.5,
                1,
                PoolCapabilities::reference(),
                TokenBudget::unlimited(),
            )
            .with_prefix_cache(page)
        };
        let o = Ordering::Relaxed;
        // Disabled (the default): no stamp, no counters touched.
        let mut wait = WaitQueue::new();
        sched(0).ingest(mk(), &mut wait, &metrics, &sessions);
        assert_eq!(wait.waiting_prefill_tokens(), 8);
        assert_eq!(metrics.prefix_hits.load(o) + metrics.prefix_misses.load(o), 0);
        // Enabled: the shared 4-token page boundary matches (coverage
        // is capped below seq_len so at least one suffix row runs) and
        // the queue prices only the suffix.
        let mut wait = WaitQueue::new();
        sched(4).ingest(mk(), &mut wait, &metrics, &sessions);
        assert_eq!(wait.waiting_prefill_tokens(), 4);
        assert_eq!(metrics.prefix_hits.load(o), 1);
        // Divergent first-page content: a miss, priced at full length.
        let mut wait = WaitQueue::new();
        let mut env = mk();
        env.req.k[2] += 1.0;
        sched(4).ingest(env, &mut wait, &metrics, &sessions);
        assert_eq!(wait.waiting_prefill_tokens(), 8);
        assert_eq!(metrics.prefix_misses.load(o), 1);
    }

    /// Satellite (admission boundaries): the waiting-ratio decision —
    /// each admit clause in isolation, then the defer case.
    #[test]
    fn allow_prefill_ratio_decision() {
        let t = Duration::from_millis(1);
        // No runnable decode waiting: always admit.
        assert!(allow_prefill(10, 1000, false, None, t, 1.2));
        // Idle pool (no live tokens): always admit, even against
        // pending decode work in the queue.
        assert!(allow_prefill(10, 0, true, None, t, 1.2));
        // Starvation bound: an entry aged past the timeout is admitted
        // regardless of the ratio.
        assert!(allow_prefill(1, 1000, true, Some(Duration::from_millis(2)), t, 1.2));
        // Ratio satisfied: 1200 waiting ≥ 1.2 × 1000 live.
        assert!(allow_prefill(1200, 1000, true, Some(Duration::ZERO), t, 1.2));
        // One token short of the ratio, young, decode pending: defer.
        assert!(!allow_prefill(1199, 1000, true, Some(Duration::ZERO), t, 1.2));
        // Ratio 0 disables deferral entirely.
        assert!(allow_prefill(0, 1000, true, Some(Duration::ZERO), t, 0.0));
    }
}
