//! Session lifecycle for decode-phase serving (DESIGN.md §5).
//!
//! A *session* is one autoregressive generation stream: a client opens
//! it with a full-prefix [`SessionOp::Prefill`] operator, advances it
//! one token at a time with [`SessionOp::Decode`] steps (single query
//! row per head, one appended K/V row per KV head), and retires it with
//! [`SessionOp::Close`].  The op rides on
//! [`AttentionRequest`](super::request::AttentionRequest), so the whole
//! existing scatter/gather path (per-head shards, affinity router,
//! device pool) serves sessions without a second ingress.
//!
//! The [`SessionTable`] is the coordinator-global source of truth:
//!
//! * **lifecycle** — prefill registers a session, decode steps must
//!   arrive in order (`step == next_step`), close retires it; every
//!   violation is answered with an error response, never a panic;
//! * **host tier** — the authoritative per-KV-head K/V prefix.  Device
//!   workers hold the *cached* tier (paged HBM model,
//!   [`super::kvcache`]); on a cache miss they fall back to this copy,
//!   which models the upstream model re-running its forward pass to
//!   regenerate K/V (charged as a full recompute by
//!   [`crate::perfmodel::fsa_decode_perf`]);
//! * **placement** — the sticky `(session, kv_head) → device` pin the
//!   router consults so a session's decode steps keep landing on the
//!   device that holds its pages.  Pins are cleared when a device
//!   evicts the stream (eviction-aware re-placement) and when a worker
//!   dies (dead-worker cache invalidation).
//!
//! Lock discipline: one mutex over the table, held only for short
//! non-blocking critical sections (no channel sends, no numerics while
//! locked).  Prefix clones on the miss path copy `O(len · d)` floats
//! under the lock; at serving shapes this is far below the recompute
//! work the miss itself implies.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::mask::MaskKind;

use super::kvcache::{chain_hash, chain_seed};
use super::request::AttentionRequest;

/// Session identifier, chosen by the client (must be unique among live
/// sessions; reuse after close is allowed).
pub type SessionId = u64;

/// Lifecycle operation carried on an `AttentionRequest`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionOp {
    /// One-shot whole operator, no session state — the pre-session
    /// behavior and the default.
    Stateless,
    /// Open `session` with this request's full `(L, d)` prefix; the
    /// response is ordinary full-prefix attention (causal when the
    /// request carries `MaskKind::Causal` — the transformer-prefill
    /// regime, DESIGN.md §6) and the K/V prefix is retained for decode.
    /// Key-padding masks are rejected (a padded prefix would poison the
    /// host tier with zero K/V rows) — open sessions at their exact
    /// length.
    Prefill { session: SessionId },
    /// One decode step: the request carries one query row per head and
    /// one new K/V row per KV head (`seq_len == 1`); attention runs
    /// over the whole retained prefix *including* the appended row.
    /// Steps must arrive strictly in order, starting at 0.  A step
    /// that passes validation is *consumed* (at-most-once): the K/V
    /// row is appended and the step counter advances before dispatch,
    /// so a failure after admission surfaces in the response but the
    /// step cannot be resubmitted — abandon the session on such
    /// errors.  (Foreseeable failures are rejected *before* admission:
    /// shape/order violations here, missing decode backend support in
    /// the admission gate.)
    Decode { session: SessionId, step: u64 },
    /// Retire the session: host-tier K/V is dropped immediately and
    /// device pages become reapable.  Answered directly at the
    /// admission gate with an empty-output success response.
    Close { session: SessionId },
}

/// What a validated decode step tells the admission gate (stamped onto
/// the request before dispatch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeAdmit {
    /// Prefix length this step attends (previous length + 1).
    pub prefix_len: usize,
    /// The session's incarnation epoch.
    pub epoch: u64,
    /// The session's prefill length — the fixed chunk-grid basis for
    /// sequence-parallel split-KV decode (DESIGN.md §7).
    pub prefill_len: usize,
}

/// One live session (internal representation).
struct Session {
    d: usize,
    num_heads: usize,
    num_kv_heads: usize,
    /// Mask the session was prefilled with (`None` or `Causal`): decode
    /// steps are mask-free by construction — each step's row attends
    /// the whole retained prefix, which IS the causal row for a
    /// causal-prefilled session.
    mask: MaskKind,
    /// Table-unique incarnation stamp (session ids may be reused after
    /// close; the epoch tells a device cache whether a resident stream
    /// belongs to *this* incarnation or a dead one).
    epoch: u64,
    /// Current prefix length in tokens (prefill length + appended
    /// decode rows).
    len: usize,
    /// Prefill length at open — the fixed basis of the sequence-chunk
    /// grid (DESIGN.md §7).
    prefill_len: usize,
    /// Sequence-shard count the pool serves this session with (fixed at
    /// open from `RunConfig::seq_shards`; 1 = legacy).
    seq_shards: usize,
    /// Next expected decode step.
    next_step: u64,
    /// Host-tier K/V, one growing `(len, d)` row-major matrix per KV
    /// head.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Sticky placement per `(kv_head, chunk)` stream — index `kv_head ·
    /// seq_shards + chunk`: the device whose page cache holds (or last
    /// held) that chunk of the stream.  `None` = unplaced/invalidated.
    placement: Vec<Option<usize>>,
}

/// A cross-session prefix match found at admission (DESIGN.md §11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefixMatch {
    /// The live session whose retained prefix covers the new request's
    /// leading tokens (byte-verified, not just hash-matched).
    pub donor: SessionId,
    /// Covered tokens — page-aligned and strictly below the new
    /// request's `seq_len`, so a warm prefill always computes at least
    /// one suffix row.  This is the `resumed_from` stamp.
    pub covered: usize,
}

/// Coordinator-global session registry shared by the scheduler
/// (lifecycle + host tier + live-token budgets), the router (sticky
/// placement) and the device workers (miss fallback + eviction
/// notifications).
#[derive(Default)]
struct Inner {
    sessions: HashMap<SessionId, Session>,
    /// Monotonic epoch source (starts at 1 so 0 means "no epoch").
    next_epoch: u64,
    /// Cross-session prefix index (DESIGN.md §11): chain hash over the
    /// first `i` pages of a session's K/V (all KV heads interleaved
    /// page-major) → candidate donors `(session, epoch, covered
    /// tokens)`.  Populated by [`SessionTable::index_prefix`] after a
    /// cold prefill admits; consulted hash-first, then byte-verified
    /// against the donor's host tier, by
    /// [`SessionTable::match_prefix`].
    prefix: HashMap<u64, Vec<(SessionId, u64, usize)>>,
}

#[derive(Default)]
pub struct SessionTable {
    inner: Mutex<Inner>,
}

impl SessionTable {
    pub fn new() -> SessionTable {
        SessionTable::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        super::lock(&self.inner)
    }

    /// Register `sid` from a prefill request served at `seq_shards`
    /// sequence shards (1 = legacy; the pool's `RunConfig::seq_shards`,
    /// fixed for the session's lifetime so chunk placements and cached
    /// chunk streams stay consistent across steps).  Returns the
    /// session's fresh epoch (stamped onto the request so device caches
    /// can tell this incarnation's streams from a closed
    /// predecessor's).  Errors (as a response message, the serving path
    /// never panics) when the id is already live or the request shape
    /// is unusable.
    pub fn open(
        &self,
        sid: SessionId,
        req: &AttentionRequest,
        seq_shards: usize,
    ) -> Result<u64, String> {
        assert!(seq_shards >= 1, "seq_shards is validated at config load");
        if req.seq_len == 0 {
            return Err(format!("session {sid}: prefill needs a non-empty prefix"));
        }
        if let MaskKind::PaddingKeys { .. } = req.mask {
            return Err(format!(
                "session {sid}: prefill cannot carry a key-padding mask (the padded \
                 K/V rows would enter the retained prefix) — open the session at its \
                 exact length; mask none|causal"
            ));
        }
        let mut t = self.lock();
        if t.sessions.contains_key(&sid) {
            return Err(format!("session {sid} is already open"));
        }
        t.next_epoch += 1;
        let epoch = t.next_epoch;
        let mut k = Vec::with_capacity(req.num_kv_heads);
        let mut v = Vec::with_capacity(req.num_kv_heads);
        for h in 0..req.num_kv_heads {
            let (kh, vh) = req.head_kv(h);
            k.push(kh.to_vec());
            v.push(vh.to_vec());
        }
        t.sessions.insert(
            sid,
            Session {
                d: req.d,
                num_heads: req.num_heads,
                num_kv_heads: req.num_kv_heads,
                mask: req.mask,
                epoch,
                len: req.seq_len,
                prefill_len: req.seq_len,
                seq_shards,
                next_step: 0,
                k,
                v,
                placement: vec![None; req.num_kv_heads * seq_shards],
            },
        );
        Ok(epoch)
    }

    /// Validate a decode request against the session and append its new
    /// K/V row to the host tier.  Returns the [`DecodeAdmit`] stamp
    /// (prefix length, epoch, and the chunk-grid basis).  Must be
    /// called exactly once per step, before the step is dispatched, so
    /// in-flight shards always find their prefix present.
    pub fn begin_decode(
        &self,
        sid: SessionId,
        step: u64,
        req: &AttentionRequest,
    ) -> Result<DecodeAdmit, String> {
        let mut t = self.lock();
        let s = t
            .sessions
            .get_mut(&sid)
            .ok_or_else(|| format!("session {sid} is not open (decode step {step})"))?;
        if req.seq_len != 1 {
            return Err(format!(
                "session {sid}: decode carries one token, got seq_len {}",
                req.seq_len
            ));
        }
        if req.mask != MaskKind::None {
            return Err(format!(
                "session {sid}: decode steps take no mask ({}) — the step row \
                 attends the whole retained prefix, which already is the causal row",
                req.mask
            ));
        }
        if req.d != s.d || req.num_heads != s.num_heads || req.num_kv_heads != s.num_kv_heads {
            return Err(format!(
                "session {sid}: decode shape ({} heads/{} kv, d {}) does not match \
                 the prefilled shape ({} heads/{} kv, d {})",
                req.num_heads, req.num_kv_heads, req.d, s.num_heads, s.num_kv_heads, s.d
            ));
        }
        if step != s.next_step {
            return Err(format!(
                "session {sid}: expected decode step {}, got {step}",
                s.next_step
            ));
        }
        for h in 0..s.num_kv_heads {
            let (kh, vh) = req.head_kv(h);
            s.k[h].extend_from_slice(kh);
            s.v[h].extend_from_slice(vh);
        }
        s.len += 1;
        s.next_step += 1;
        Ok(DecodeAdmit { prefix_len: s.len, epoch: s.epoch, prefill_len: s.prefill_len })
    }

    /// Retire a session.  Returns false when it was not open.  Its
    /// prefix-index entries go with it — a dead session can never be a
    /// prefix donor (its host tier is gone and its device pages are
    /// reapable), and pruning here keeps the index from accreting
    /// unmatchable hashes.
    pub fn close(&self, sid: SessionId) -> bool {
        let mut t = self.lock();
        let gone = t.sessions.remove(&sid).is_some();
        if gone {
            t.prefix.retain(|_, cands| {
                cands.retain(|&(s, _, _)| s != sid);
                !cands.is_empty()
            });
        }
        gone
    }

    /// Register an admitted prefill's page-boundary chain hashes in the
    /// cross-session prefix index (DESIGN.md §11).  Call once, right
    /// after [`SessionTable::open`] succeeds; `page_size` is the device
    /// caches' `kv_page_size`, so admission-level coverage is exactly
    /// the page-aligned sharing the devices can realize.
    pub fn index_prefix(&self, sid: SessionId, page_size: usize) {
        if page_size == 0 {
            return;
        }
        let mut t = self.lock();
        let Some(s) = t.sessions.get(&sid) else { return };
        let (epoch, d, len, kv_heads) = (s.epoch, s.d, s.len, s.num_kv_heads);
        let mut chains = Vec::new();
        let mut c = chain_seed(page_size);
        let mut page = 0usize;
        while (page + 1) * page_size <= len {
            let (lo, hi) = (page * page_size * d, (page + 1) * page_size * d);
            for h in 0..kv_heads {
                c = chain_hash(c, &s.k[h][lo..hi], &s.v[h][lo..hi]);
            }
            chains.push((c, (page + 1) * page_size));
            page += 1;
        }
        for (c, covered) in chains {
            t.prefix.entry(c).or_default().push((sid, epoch, covered));
        }
    }

    /// Longest indexed prefix of a prefill request's K/V: the deepest
    /// page boundary whose chain hash names a live donor *and* whose
    /// bytes equal the donor's host tier (hash-first, byte-verified —
    /// a collision can never stamp a false resume).  Coverage is
    /// page-aligned and strictly below `req.seq_len`, so a warm
    /// prefill always computes at least one suffix row.
    pub fn match_prefix(&self, req: &AttentionRequest, page_size: usize) -> Option<PrefixMatch> {
        if page_size == 0 || req.num_kv_heads == 0 || req.d == 0 {
            return None;
        }
        let t = self.lock();
        // Hash-walk the request's page boundaries, shallow to deep,
        // collecting hash-matched live candidates; stop at the first
        // boundary with none (deeper chains extend this one).
        let mut candidates: Vec<(SessionId, usize)> = Vec::new();
        let mut c = chain_seed(page_size);
        let mut page = 0usize;
        loop {
            let covered = (page + 1) * page_size;
            if covered >= req.seq_len {
                break;
            }
            let (lo, hi) = (page * page_size * req.d, covered * req.d);
            for h in 0..req.num_kv_heads {
                let (k, v) = req.head_kv(h);
                c = chain_hash(c, &k[lo..hi], &v[lo..hi]);
            }
            let mut found = false;
            if let Some(cands) = t.prefix.get(&c) {
                for &(donor, epoch, donor_cov) in cands {
                    if donor_cov != covered {
                        continue;
                    }
                    if let Some(s) = t.sessions.get(&donor) {
                        if s.epoch == epoch
                            && s.d == req.d
                            && s.num_kv_heads == req.num_kv_heads
                            && s.len >= covered
                        {
                            candidates.push((donor, covered));
                            found = true;
                            break;
                        }
                    }
                }
            }
            if !found {
                break;
            }
            page += 1;
        }
        // Byte-verify deepest-first: a verified depth verifies every
        // shallower boundary, so the first success wins.
        while let Some((donor, covered)) = candidates.pop() {
            let Some(s) = t.sessions.get(&donor) else { continue };
            let n = covered * req.d;
            let verified = (0..req.num_kv_heads).all(|h| {
                let (k, v) = req.head_kv(h);
                s.k[h][..n] == k[..n] && s.v[h][..n] == v[..n]
            });
            if verified {
                return Some(PrefixMatch { donor, covered });
            }
        }
        None
    }

    /// Copy the donor's sticky placements onto a freshly opened warm
    /// session (empty slots only): the router then lands the warm
    /// prefill's shards on the devices already holding the donor's
    /// pages, where the content-keyed insert can attach instead of
    /// copy.
    pub fn adopt_placement(&self, donor: SessionId, sid: SessionId) {
        let mut t = self.lock();
        let Some(d) = t.sessions.get(&donor) else { return };
        let donor_placement = d.placement.clone();
        let (dkv, dss) = (d.num_kv_heads, d.seq_shards);
        let Some(s) = t.sessions.get_mut(&sid) else { return };
        for kv_head in 0..s.num_kv_heads.min(dkv) {
            for chunk in 0..s.seq_shards.min(dss) {
                let from = kv_head * dss + chunk;
                let to = kv_head * s.seq_shards + chunk;
                if s.placement[to].is_none() {
                    s.placement[to] = donor_placement[from];
                }
            }
        }
    }

    pub fn contains(&self, sid: SessionId) -> bool {
        self.lock().sessions.contains_key(&sid)
    }

    pub fn session_count(&self) -> usize {
        self.lock().sessions.len()
    }

    /// Total tokens currently held by open sessions (Σ prefix lengths) —
    /// the served side of the scheduler's waiting-vs-served ratio and
    /// the live term of its `max_batch_total_tokens` budget
    /// (DESIGN.md §10).
    pub fn live_tokens(&self) -> usize {
        self.lock().sessions.values().map(|s| s.len).sum()
    }

    /// Current prefix length of a live session.
    pub fn prefix_len(&self, sid: SessionId) -> Option<usize> {
        self.lock().sessions.get(&sid).map(|s| s.len)
    }

    /// Current incarnation epoch of a live session (used by device
    /// caches to tell live streams from dead-incarnation leftovers).
    pub fn epoch(&self, sid: SessionId) -> Option<u64> {
        self.lock().sessions.get(&sid).map(|s| s.epoch)
    }

    /// Mask the session was prefilled with (`None` or `Causal`).
    pub fn mask(&self, sid: SessionId) -> Option<MaskKind> {
        self.lock().sessions.get(&sid).map(|s| s.mask)
    }

    /// Clone the first `prefix_len` tokens of one KV head's host-tier
    /// K/V (the miss-path fallback).  `None` when the session is gone
    /// (closed mid-flight), the prefix is shorter than requested, or
    /// `epoch` names a different incarnation — an in-flight shard of a
    /// closed-and-reopened id must fail its step rather than silently
    /// read the new incarnation's K/V.
    pub fn clone_prefix(
        &self,
        sid: SessionId,
        kv_head: usize,
        prefix_len: usize,
        epoch: u64,
    ) -> Option<(Vec<f32>, Vec<f32>)> {
        self.clone_range(sid, kv_head, 0, prefix_len, epoch)
    }

    /// Clone the token range `[start, start + len)` of one KV head's
    /// host-tier K/V — the sequence-parallel miss-path fallback
    /// (DESIGN.md §7): a chunk device recomputes exactly its range.
    /// Same epoch/shape guards as [`SessionTable::clone_prefix`] (which
    /// delegates here with `start = 0`).
    pub fn clone_range(
        &self,
        sid: SessionId,
        kv_head: usize,
        start: usize,
        len: usize,
        epoch: u64,
    ) -> Option<(Vec<f32>, Vec<f32>)> {
        let t = self.lock();
        let s = t.sessions.get(&sid)?;
        if s.epoch != epoch || kv_head >= s.num_kv_heads || s.len < start + len {
            return None;
        }
        let (lo, hi) = (start * s.d, (start + len) * s.d);
        Some((s.k[kv_head][lo..hi].to_vec(), s.v[kv_head][lo..hi].to_vec()))
    }

    /// Placement slot of one `(kv_head, chunk)` stream.
    fn slot(s: &Session, kv_head: usize, chunk: usize) -> Option<usize> {
        if kv_head >= s.num_kv_heads || chunk >= s.seq_shards {
            return None;
        }
        Some(kv_head * s.seq_shards + chunk)
    }

    /// Sticky placement of one `(kv_head, chunk)` stream, if any
    /// (`chunk = 0` on the legacy unsharded path).
    pub fn placement(&self, sid: SessionId, kv_head: usize, chunk: usize) -> Option<usize> {
        let t = self.lock();
        let s = t.sessions.get(&sid)?;
        let slot = Self::slot(s, kv_head, chunk)?;
        s.placement[slot]
    }

    /// Pin a `(kv_head, chunk)` stream to `device` (the router just
    /// dispatched there).
    pub fn place(&self, sid: SessionId, kv_head: usize, chunk: usize, device: usize) {
        if let Some(s) = self.lock().sessions.get_mut(&sid) {
            if let Some(slot) = Self::slot(s, kv_head, chunk) {
                s.placement[slot] = Some(device);
            }
        }
    }

    /// Clear a pin, but only if it still points at `device` — a worker
    /// reporting an eviction must not un-pin a stream that has already
    /// been re-placed elsewhere.
    pub fn clear_placement(&self, sid: SessionId, kv_head: usize, chunk: usize, device: usize) {
        if let Some(s) = self.lock().sessions.get_mut(&sid) {
            if let Some(slot) = Self::slot(s, kv_head, chunk) {
                if s.placement[slot] == Some(device) {
                    s.placement[slot] = None;
                }
            }
        }
    }

    /// Drop every pin onto `device` (dead-worker cache invalidation:
    /// its pages are unreachable, so every pinned stream must re-place
    /// and recompute).
    pub fn invalidate_device(&self, device: usize) {
        for s in self.lock().sessions.values_mut() {
            for p in s.placement.iter_mut() {
                if *p == Some(device) {
                    *p = None;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prefill_req(sid: SessionId, seq: usize, d: usize, heads: usize, kv: usize) -> AttentionRequest {
        AttentionRequest::prefill(
            1,
            sid,
            seq,
            d,
            heads,
            kv,
            vec![0.5; heads * seq * d],
            (0..kv * seq * d).map(|x| x as f32).collect(),
            (0..kv * seq * d).map(|x| -(x as f32)).collect(),
        )
    }

    fn decode_req(sid: SessionId, step: u64, d: usize, heads: usize, kv: usize) -> AttentionRequest {
        AttentionRequest::decode(
            2,
            sid,
            step,
            d,
            heads,
            kv,
            vec![1.0; heads * d],
            vec![7.0; kv * d],
            vec![8.0; kv * d],
        )
    }

    #[test]
    fn lifecycle_open_decode_close() {
        let t = SessionTable::new();
        let (d, heads, kv) = (4usize, 4usize, 2usize);
        assert_eq!(t.live_tokens(), 0);
        t.open(9, &prefill_req(9, 8, d, heads, kv), 1).unwrap();
        assert!(t.contains(9));
        assert_eq!(t.prefix_len(9), Some(8));
        assert_eq!(t.live_tokens(), 8);
        // Double open is rejected.
        assert!(t.open(9, &prefill_req(9, 8, d, heads, kv), 1).is_err());

        // Steps must be sequential; each returns the admit stamp.
        assert!(t.begin_decode(9, 1, &decode_req(9, 1, d, heads, kv)).is_err());
        let a0 = t.begin_decode(9, 0, &decode_req(9, 0, d, heads, kv)).unwrap();
        let a1 = t.begin_decode(9, 1, &decode_req(9, 1, d, heads, kv)).unwrap();
        assert_eq!((a0.prefix_len, a1.prefix_len), (9, 10));
        assert_eq!(a0.epoch, a1.epoch);
        // The chunk-grid basis stays the prefill length as the prefix grows.
        assert_eq!((a0.prefill_len, a1.prefill_len), (8, 8));
        assert_eq!(t.prefix_len(9), Some(10));
        // live_tokens tracks the grown prefix (scheduler budget input).
        assert_eq!(t.live_tokens(), 10);
        let e0 = a0.epoch;

        // Appended rows are visible in the host tier.
        let (k, v) = t.clone_prefix(9, 1, 10, e0).unwrap();
        assert_eq!(k.len(), 10 * d);
        assert_eq!(&k[8 * d..], &[7.0; 8][..]);
        assert_eq!(&v[8 * d..], &[8.0; 8][..]);
        // Shorter prefixes slice the same data.
        let (k8, _) = t.clone_prefix(9, 1, 8, e0).unwrap();
        assert_eq!(k8, &k[..8 * d]);
        // Mid-sequence ranges slice the same data (split-KV decode).
        let (kr, vr) = t.clone_range(9, 1, 8, 2, e0).unwrap();
        assert_eq!(kr, &k[8 * d..]);
        assert_eq!(vr, &v[8 * d..]);
        // Over-long prefix/range, bad kv_head, and wrong incarnation are
        // refused.
        assert!(t.clone_prefix(9, 1, 11, e0).is_none());
        assert!(t.clone_range(9, 1, 8, 3, e0).is_none());
        assert!(t.clone_prefix(9, 2, 4, e0).is_none());
        assert!(t.clone_prefix(9, 1, 8, e0 + 1).is_none());

        assert!(t.close(9));
        assert!(!t.close(9));
        assert_eq!(t.live_tokens(), 0);
        assert!(t.begin_decode(9, 2, &decode_req(9, 2, d, heads, kv)).is_err());
    }

    #[test]
    fn decode_shape_mismatches_are_rejected() {
        let t = SessionTable::new();
        t.open(1, &prefill_req(1, 4, 4, 4, 2), 1).unwrap();
        // Wrong head count.
        assert!(t.begin_decode(1, 0, &decode_req(1, 0, 4, 2, 2)).is_err());
        // Wrong d.
        assert!(t.begin_decode(1, 0, &decode_req(1, 0, 8, 4, 2)).is_err());
        // A failed step does not advance the counter.
        assert_eq!(
            t.begin_decode(1, 0, &decode_req(1, 0, 4, 4, 2)).unwrap().prefix_len,
            5
        );
    }

    #[test]
    fn session_mask_rules() {
        let t = SessionTable::new();
        // Padding-masked prefill is rejected before any state mutates.
        let bad = prefill_req(1, 4, 4, 4, 2).with_mask(MaskKind::PaddingKeys { valid: 2 });
        assert!(t.open(1, &bad, 1).unwrap_err().contains("key-padding"));
        assert!(!t.contains(1));
        // Causal prefill opens normally and the mask is remembered.
        let causal = prefill_req(1, 4, 4, 4, 2).with_mask(MaskKind::Causal);
        t.open(1, &causal, 1).unwrap();
        assert_eq!(t.mask(1), Some(MaskKind::Causal));
        // Masked decode steps are rejected without consuming the step.
        let masked_step = decode_req(1, 0, 4, 4, 2).with_mask(MaskKind::Causal);
        assert!(t.begin_decode(1, 0, &masked_step).unwrap_err().contains("no mask"));
        assert_eq!(t.prefix_len(1), Some(4));
        // The unmasked step then succeeds.
        assert_eq!(
            t.begin_decode(1, 0, &decode_req(1, 0, 4, 4, 2)).unwrap().prefix_len,
            5
        );
        assert_eq!(t.mask(404), None);
    }

    #[test]
    fn reused_session_ids_get_fresh_epochs() {
        let t = SessionTable::new();
        let e1 = t.open(3, &prefill_req(3, 4, 2, 2, 1), 1).unwrap();
        assert!(t.close(3));
        let e2 = t.open(3, &prefill_req(3, 4, 2, 2, 1), 1).unwrap();
        assert_ne!(e1, e2, "a reused id must not look like its dead predecessor");
        let admit = t.begin_decode(3, 0, &decode_req(3, 0, 2, 2, 1)).unwrap();
        assert_eq!(admit.epoch, e2);
    }

    #[test]
    fn placement_is_sticky_and_invalidatable() {
        let t = SessionTable::new();
        t.open(5, &prefill_req(5, 4, 2, 4, 2), 1).unwrap();
        assert_eq!(t.placement(5, 0, 0), None);
        t.place(5, 0, 0, 3);
        t.place(5, 1, 0, 1);
        assert_eq!(t.placement(5, 0, 0), Some(3));
        // clear_placement is conditional on the device still matching.
        t.clear_placement(5, 0, 0, 2);
        assert_eq!(t.placement(5, 0, 0), Some(3));
        t.clear_placement(5, 0, 0, 3);
        assert_eq!(t.placement(5, 0, 0), None);
        // Dead-worker invalidation clears every pin onto that device.
        t.place(5, 0, 0, 1);
        t.invalidate_device(1);
        assert_eq!(t.placement(5, 0, 0), None);
        assert_eq!(t.placement(5, 1, 0), None);
        // Unknown sessions, out-of-range chunks are no-ops, not panics.
        t.place(404, 0, 0, 0);
        t.clear_placement(404, 0, 0, 0);
        assert_eq!(t.placement(404, 0, 0), None);
        t.place(5, 0, 7, 2); // chunk >= seq_shards: ignored
        assert_eq!(t.placement(5, 0, 7), None);
    }

    /// Row-major `(kv, seq, d)` K/V whose value is a pure function of
    /// `(head, token, lane)` — prefixes of longer matrices are bitwise
    /// prefixes of shorter ones, per head.
    fn kv_mat(kv: usize, seq: usize, d: usize, sign: f32) -> Vec<f32> {
        let mut out = Vec::with_capacity(kv * seq * d);
        for h in 0..kv {
            for t in 0..seq {
                for x in 0..d {
                    out.push(sign * (h * 9007 + t * 31 + x + 1) as f32);
                }
            }
        }
        out
    }

    /// A prefill whose K/V leading tokens are shared across requests of
    /// any seq_len (system-prompt shape).
    fn shared_req(sid: SessionId, seq: usize, d: usize, heads: usize, kv: usize) -> AttentionRequest {
        AttentionRequest::prefill(
            1,
            sid,
            seq,
            d,
            heads,
            kv,
            vec![0.5; heads * seq * d],
            kv_mat(kv, seq, d, 1.0),
            kv_mat(kv, seq, d, -1.0),
        )
    }

    #[test]
    fn prefix_index_finds_byte_verified_donors() {
        let t = SessionTable::new();
        let (d, heads, kv) = (2usize, 2usize, 2usize);
        t.open(1, &shared_req(1, 8, d, heads, kv), 1).unwrap();
        t.index_prefix(1, 4);
        // A longer request sharing the leading bytes matches the
        // donor's whole indexed prefix (pages of 4 tokens: 4 and 8).
        let m = t.match_prefix(&shared_req(2, 12, d, heads, kv), 4).unwrap();
        assert_eq!(m, PrefixMatch { donor: 1, covered: 8 });
        // An *identical-length* request is capped strictly below its
        // own seq_len — a warm prefill must keep at least one row.
        let m = t.match_prefix(&shared_req(2, 8, d, heads, kv), 4).unwrap();
        assert_eq!(m, PrefixMatch { donor: 1, covered: 4 });
        // Divergence inside the first page kills the match entirely.
        let mut k = kv_mat(kv, 12, d, 1.0);
        k[3] += 1.0; // head 0, token 1
        let diverged = AttentionRequest::prefill(
            1, 2, 12, d, heads, kv,
            vec![0.5; heads * 12 * d], k, kv_mat(kv, 12, d, -1.0),
        );
        assert_eq!(t.match_prefix(&diverged, 4), None);
        // The mask does not gate content sharing (it is evaluated at
        // global rows by the resumed kernel, DESIGN.md §11).
        let warm = shared_req(2, 12, d, heads, kv).with_mask(MaskKind::Causal);
        assert!(t.match_prefix(&warm, 4).is_some());
        // Shape mismatches never match.
        assert_eq!(t.match_prefix(&shared_req(2, 12, d * 2, heads, kv / 2), 4), None);
    }

    #[test]
    fn closing_the_donor_prunes_its_prefix_entries() {
        let t = SessionTable::new();
        let (d, heads, kv) = (2usize, 2usize, 1usize);
        t.open(1, &shared_req(1, 8, d, heads, kv), 1).unwrap();
        t.index_prefix(1, 4);
        assert!(t.match_prefix(&shared_req(2, 12, d, heads, kv), 4).is_some());
        assert!(t.close(1));
        assert_eq!(t.match_prefix(&shared_req(2, 12, d, heads, kv), 4), None);
        // A reused id with different content must not resurrect the
        // dead donor's coverage.
        t.open(1, &prefill_req(1, 8, d, heads, kv), 1).unwrap();
        assert_eq!(t.match_prefix(&shared_req(2, 12, d, heads, kv), 4), None);
    }

    #[test]
    fn adopt_placement_copies_only_empty_slots() {
        let t = SessionTable::new();
        let (d, heads, kv) = (2usize, 4usize, 2usize);
        t.open(1, &shared_req(1, 8, d, heads, kv), 2).unwrap();
        t.place(1, 0, 0, 3);
        t.place(1, 1, 1, 5);
        t.open(2, &shared_req(2, 12, d, heads, kv), 2).unwrap();
        t.place(2, 1, 1, 0); // already placed: adoption must not clobber
        t.adopt_placement(1, 2);
        assert_eq!(t.placement(2, 0, 0), Some(3));
        assert_eq!(t.placement(2, 0, 1), None);
        assert_eq!(t.placement(2, 1, 1), Some(0));
        // Unknown donors and sessions are no-ops.
        t.adopt_placement(404, 2);
        t.adopt_placement(1, 404);
    }

    #[test]
    fn chunk_placements_are_independent_streams() {
        // Sequence-sharded sessions pin every (kv_head, chunk) stream
        // separately — the router follows each chunk to the device
        // holding its pages.
        let t = SessionTable::new();
        let e = t.open(6, &prefill_req(6, 8, 2, 4, 2), 3).unwrap();
        t.place(6, 0, 0, 0);
        t.place(6, 0, 2, 2);
        t.place(6, 1, 1, 1);
        assert_eq!(t.placement(6, 0, 0), Some(0));
        assert_eq!(t.placement(6, 0, 1), None);
        assert_eq!(t.placement(6, 0, 2), Some(2));
        assert_eq!(t.placement(6, 1, 1), Some(1));
        // Clearing one chunk leaves its siblings pinned.
        t.clear_placement(6, 0, 2, 2);
        assert_eq!(t.placement(6, 0, 0), Some(0));
        assert_eq!(t.placement(6, 0, 2), None);
        // Dead-worker invalidation sweeps chunk pins too.
        t.invalidate_device(1);
        assert_eq!(t.placement(6, 1, 1), None);
        // The admit stamp carries the fixed chunk basis.
        let admit = t.begin_decode(6, 0, &decode_req(6, 0, 2, 4, 2)).unwrap();
        assert_eq!(admit, DecodeAdmit { prefix_len: 9, epoch: e, prefill_len: 8 });
    }
}
