//! Request-path tracing (DESIGN.md §9): a lock-light ring buffer of
//! typed span events covering a request's whole life — admission,
//! sharding, dispatch, execution, gather/merge, and KV-cache traffic.
//!
//! Off by default ([`TraceLevel::Off`]): `record` is one branch on a
//! plain field, so the hot path pays nothing when tracing is disabled
//! — which is what lets the e2e suite assert that enabling it changes
//! **no served bits** (`rust/tests/coordinator_trace.rs`).  `Summary`
//! keeps only per-kind relaxed-atomic counts; `Full` additionally
//! retains the last [`RING_CAP`] events in a mutex-guarded ring
//! (overwritten events are counted, never silently lost).
//!
//! Timestamps are monotonic nanoseconds since the tracer's creation
//! ([`Tracer::new`]), so event ordering is meaningful across threads on
//! one coordinator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// How much the coordinator records (the `trace` config key /
/// `--trace` flag).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TraceLevel {
    /// Record nothing (the default; zero overhead).
    #[default]
    Off,
    /// Per-kind event counts only.
    Summary,
    /// Counts plus the last [`RING_CAP`] events.
    Full,
}

impl std::str::FromStr for TraceLevel {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<TraceLevel, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" => Ok(TraceLevel::Off),
            "summary" => Ok(TraceLevel::Summary),
            "full" => Ok(TraceLevel::Full),
            other => anyhow::bail!("unknown trace level {other:?} (off|summary|full)"),
        }
    }
}

impl std::fmt::Display for TraceLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TraceLevel::Off => "off",
            TraceLevel::Summary => "summary",
            TraceLevel::Full => "full",
        })
    }
}

/// What happened (one per span point on the request path).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Scheduler admitted a request past the budget + lifecycle gates
    /// (payload: seq_len; for decode, the stamped prefix length).
    Admit,
    /// Request exploded into its shard grid (payload: shard count).
    Shard,
    /// Router placed a shard on a device (payload: device queue depth
    /// after the push).
    Dispatch,
    /// Device worker finished a shard's numerics (payload: the shard's
    /// device cycles, measured or modeled).
    Execute,
    /// The final shard landed and the response was assembled (payload:
    /// total device cycles of the response).
    Gather,
    /// Sequence-parallel partial merges performed at gather (payload:
    /// merge step count).
    Merge,
    /// Decode shard served from KV-cache pages.
    KvHit,
    /// Decode shard took the recompute fallback.
    KvMiss,
    /// A cached stream was evicted (payload: the evicted session id).
    KvEvict,
    /// Scheduler queued an ingressed envelope into the wait queue
    /// (payload: wait-queue length after the push, DESIGN.md §10).
    Enqueue,
    /// Admission matched a prefill against the cross-session prefix
    /// index (payload: covered tokens = the stamped resume point,
    /// DESIGN.md §11).
    PrefixHit,
    /// Admission found no cached prefix for a prefill (prefix cache
    /// enabled; payload: 0).
    PrefixMiss,
    /// A device cache insert attached already-resident pages by
    /// refcount instead of copying (payload: pages attached).
    PrefixAttach,
    /// An append copied a shared tail page before writing
    /// (copy-on-write; payload: copies).
    CowCopy,
}

/// Number of [`EventKind`] variants (the counts-array size).
pub const EVENT_KINDS: usize = 14;

impl EventKind {
    /// Stable index for the per-kind count array.
    pub fn index(self) -> usize {
        match self {
            EventKind::Admit => 0,
            EventKind::Shard => 1,
            EventKind::Dispatch => 2,
            EventKind::Execute => 3,
            EventKind::Gather => 4,
            EventKind::Merge => 5,
            EventKind::KvHit => 6,
            EventKind::KvMiss => 7,
            EventKind::KvEvict => 8,
            EventKind::Enqueue => 9,
            EventKind::PrefixHit => 10,
            EventKind::PrefixMiss => 11,
            EventKind::PrefixAttach => 12,
            EventKind::CowCopy => 13,
        }
    }

    /// Summary/JSON name.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Admit => "admit",
            EventKind::Shard => "shard",
            EventKind::Dispatch => "dispatch",
            EventKind::Execute => "execute",
            EventKind::Gather => "gather",
            EventKind::Merge => "merge",
            EventKind::KvHit => "kv_hit",
            EventKind::KvMiss => "kv_miss",
            EventKind::KvEvict => "kv_evict",
            EventKind::Enqueue => "enqueue",
            EventKind::PrefixHit => "prefix_hit",
            EventKind::PrefixMiss => "prefix_miss",
            EventKind::PrefixAttach => "prefix_attach",
            EventKind::CowCopy => "cow_copy",
        }
    }

    /// All kinds in [`EventKind::index`] order.
    pub const ALL: [EventKind; EVENT_KINDS] = [
        EventKind::Admit,
        EventKind::Shard,
        EventKind::Dispatch,
        EventKind::Execute,
        EventKind::Gather,
        EventKind::Merge,
        EventKind::KvHit,
        EventKind::KvMiss,
        EventKind::KvEvict,
        EventKind::Enqueue,
        EventKind::PrefixHit,
        EventKind::PrefixMiss,
        EventKind::PrefixAttach,
        EventKind::CowCopy,
    ];
}

/// `session` value when the event has no session (stateless requests).
pub const NO_SESSION: u64 = u64::MAX;
/// `device` value when the event precedes device placement.
pub const NO_DEVICE: u32 = u32::MAX;
/// `head`/`chunk` value for whole-request events.
pub const NO_HEAD: u32 = u32::MAX;

/// Events retained at [`TraceLevel::Full`] before overwrite.
pub const RING_CAP: usize = 4096;

/// One span event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Monotonic nanoseconds since the tracer was created.
    pub t_ns: u64,
    pub kind: EventKind,
    /// Request id.
    pub req: u64,
    /// Session id, or [`NO_SESSION`].
    pub session: u64,
    /// Query head, or [`NO_HEAD`] for whole-request events.
    pub head: u32,
    /// Sequence chunk, or [`NO_HEAD`].
    pub chunk: u32,
    /// Device id, or [`NO_DEVICE`].
    pub device: u32,
    /// Kind-specific payload (see [`EventKind`] docs).
    pub payload: u64,
}

struct Ring {
    buf: Vec<Event>,
    /// Next write slot (`buf` is a circular buffer once full).
    next: usize,
    overwritten: u64,
}

/// The coordinator's event sink, shared by the scheduler, router and
/// every device worker.
pub struct Tracer {
    level: TraceLevel,
    epoch: Instant,
    counts: [AtomicU64; EVENT_KINDS],
    ring: Mutex<Ring>,
}

impl Tracer {
    pub fn new(level: TraceLevel) -> Arc<Tracer> {
        Arc::new(Tracer {
            level,
            epoch: Instant::now(),
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            ring: Mutex::new(Ring { buf: Vec::new(), next: 0, overwritten: 0 }),
        })
    }

    /// A disabled tracer (the default for callers that don't thread one
    /// through, e.g. components constructed directly in tests).
    pub fn off() -> Arc<Tracer> {
        Tracer::new(TraceLevel::Off)
    }

    pub fn level(&self) -> TraceLevel {
        self.level
    }

    /// Whether any recording happens (`Summary` or `Full`).
    pub fn enabled(&self) -> bool {
        self.level != TraceLevel::Off
    }

    /// Record one event.  At [`TraceLevel::Off`] this is a single
    /// branch and returns immediately — the overhead bound that keeps
    /// tracing safe to thread through the hot path unconditionally.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &self,
        kind: EventKind,
        req: u64,
        session: u64,
        head: u32,
        chunk: u32,
        device: u32,
        payload: u64,
    ) {
        if self.level == TraceLevel::Off {
            return;
        }
        self.counts[kind.index()].fetch_add(1, Ordering::Relaxed);
        if self.level != TraceLevel::Full {
            return;
        }
        let ev = Event {
            t_ns: self.epoch.elapsed().as_nanos() as u64,
            kind,
            req,
            session,
            head,
            chunk,
            device,
            payload,
        };
        let mut ring = match self.ring.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if ring.buf.len() < RING_CAP {
            ring.buf.push(ev);
        } else {
            let slot = ring.next;
            ring.buf[slot] = ev;
            ring.overwritten += 1;
        }
        ring.next = (ring.next + 1) % RING_CAP;
    }

    /// Total events of one kind recorded (all levels but `Off`).
    pub fn count(&self, kind: EventKind) -> u64 {
        self.counts[kind.index()].load(Ordering::Relaxed)
    }

    /// Events overwritten after the ring filled.
    pub fn overwritten(&self) -> u64 {
        match self.ring.lock() {
            Ok(g) => g.overwritten,
            Err(p) => p.into_inner().overwritten,
        }
    }

    /// The retained events, oldest first ([`TraceLevel::Full`] only;
    /// empty otherwise).
    pub fn events(&self) -> Vec<Event> {
        let ring = match self.ring.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if ring.buf.len() < RING_CAP {
            ring.buf.clone()
        } else {
            let mut out = Vec::with_capacity(RING_CAP);
            out.extend_from_slice(&ring.buf[ring.next..]);
            out.extend_from_slice(&ring.buf[..ring.next]);
            out
        }
    }

    /// One-line per-kind counts for operator logs, e.g.
    /// `trace: admit=8 shard=8 dispatch=32 execute=32 gather=8`.
    pub fn summary(&self) -> String {
        let mut s = String::from("trace:");
        for kind in EventKind::ALL {
            let c = self.count(kind);
            if c > 0 {
                s.push_str(&format!(" {}={c}", kind.name()));
            }
        }
        let over = self.overwritten();
        if over > 0 {
            s.push_str(&format!(" overwritten={over}"));
        }
        if s == "trace:" {
            s.push_str(" (no events)");
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_print() {
        for l in [TraceLevel::Off, TraceLevel::Summary, TraceLevel::Full] {
            assert_eq!(l.to_string().parse::<TraceLevel>().unwrap(), l);
        }
        assert_eq!("FULL".parse::<TraceLevel>().unwrap(), TraceLevel::Full);
        assert!("verbose".parse::<TraceLevel>().is_err());
        assert_eq!(TraceLevel::default(), TraceLevel::Off);
    }

    #[test]
    fn off_records_nothing() {
        let t = Tracer::off();
        assert!(!t.enabled());
        t.record(EventKind::Admit, 1, NO_SESSION, NO_HEAD, NO_HEAD, NO_DEVICE, 0);
        assert_eq!(t.count(EventKind::Admit), 0);
        assert!(t.events().is_empty());
        assert_eq!(t.summary(), "trace: (no events)");
    }

    #[test]
    fn summary_counts_without_retaining_events() {
        let t = Tracer::new(TraceLevel::Summary);
        assert!(t.enabled());
        t.record(EventKind::Dispatch, 1, NO_SESSION, 0, 0, 3, 1);
        t.record(EventKind::Dispatch, 1, NO_SESSION, 1, 0, 2, 1);
        assert_eq!(t.count(EventKind::Dispatch), 2);
        assert!(t.events().is_empty());
        assert!(t.summary().contains("dispatch=2"), "{}", t.summary());
    }

    #[test]
    fn full_ring_overwrites_oldest_and_counts_losses() {
        let t = Tracer::new(TraceLevel::Full);
        for i in 0..(RING_CAP as u64 + 10) {
            t.record(EventKind::Execute, i, NO_SESSION, 0, 0, 0, i);
        }
        assert_eq!(t.count(EventKind::Execute), RING_CAP as u64 + 10);
        assert_eq!(t.overwritten(), 10);
        let evs = t.events();
        assert_eq!(evs.len(), RING_CAP);
        // Oldest first: the first 10 requests were overwritten.
        assert_eq!(evs[0].req, 10);
        assert_eq!(evs.last().unwrap().req, RING_CAP as u64 + 9);
        // Timestamps are monotone non-decreasing in retained order.
        assert!(evs.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
        assert!(t.summary().contains("overwritten=10"), "{}", t.summary());
    }
}
