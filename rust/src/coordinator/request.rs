//! Request/response types on the serving path.
//!
//! A request carries a full multi-head (optionally grouped-query)
//! attention operator: `num_heads` query heads attending over
//! `num_kv_heads` shared key/value heads (`num_heads == num_kv_heads`
//! is classic MHA, `num_kv_heads == 1` is MQA).  The coordinator shards
//! a request into per-head units of work, scatters them across the
//! device pool, and gathers one [`AttentionResponse`] with
//! whole-operator accounting — the granularity the paper's §6.1
//! FLOPs/s-utilization comparison is measured at.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::mask::MaskKind;
use crate::schedule::{
    decode_attention_flops, masked_attention_flops, masked_attention_flops_resumed,
};
use crate::sim::CycleBreakdown;

use super::session::{SessionId, SessionOp};

/// SLO class of a request: which latency histogram its completion lands
/// in ([`super::metrics::Metrics`], DESIGN.md §9).  Derived from the
/// [`SessionOp`], echoed on every [`AttentionResponse`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// One-shot operator (no session).
    Stateless,
    /// Session-opening full-prefix attention; its latency is the
    /// time-to-first-token (TTFT) numerator.
    Prefill,
    /// One decode step; its latency is the time-per-output-token (TPOT)
    /// numerator.
    Decode,
    /// Session retirement (inline reply, no tensors).
    Close,
}

impl OpKind {
    /// The class of a session op.
    pub fn of(op: &SessionOp) -> OpKind {
        match op {
            SessionOp::Stateless => OpKind::Stateless,
            SessionOp::Prefill { .. } => OpKind::Prefill,
            SessionOp::Decode { .. } => OpKind::Decode,
            SessionOp::Close { .. } => OpKind::Close,
        }
    }

    /// Stable index for per-kind metric arrays.
    pub fn index(self) -> usize {
        match self {
            OpKind::Stateless => 0,
            OpKind::Prefill => 1,
            OpKind::Decode => 2,
            OpKind::Close => 3,
        }
    }

    /// Snapshot/JSON name.
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Stateless => "stateless",
            OpKind::Prefill => "prefill",
            OpKind::Decode => "decode",
            OpKind::Close => "close",
        }
    }

    /// All kinds in [`OpKind::index`] order.
    pub const ALL: [OpKind; 4] =
        [OpKind::Stateless, OpKind::Prefill, OpKind::Decode, OpKind::Close];
}

/// One attention operator: row-major per-head `(seq_len, d)` matrices.
///
/// Layouts (all head-major, row-major within a head):
/// * `q`: `(num_heads, seq_len, d)`
/// * `k`, `v`: `(num_kv_heads, seq_len, d)`
///
/// For the single-head case (`num_heads == num_kv_heads == 1`, built by
/// [`AttentionRequest::new`]) these degenerate to the plain `(seq_len,
/// d)` matrices of the original API.
#[derive(Clone, Debug)]
pub struct AttentionRequest {
    pub id: u64,
    pub seq_len: usize,
    pub d: usize,
    /// Query head count (≥ 1).
    pub num_heads: usize,
    /// Key/value head count; must divide `num_heads` (GQA grouping).
    pub num_kv_heads: usize,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Session lifecycle op (decode-phase serving, DESIGN.md §5).
    /// `Stateless` for ordinary one-shot operators.
    pub op: SessionOp,
    /// Decode only: the prefix length (tokens attended over, including
    /// this step's appended row).  Stamped by the admission gate after
    /// session validation; 0 elsewhere.
    pub prefix_len: usize,
    /// Decode only: the session's *prefill* length — the fixed basis of
    /// the sequence-parallel chunk grid, so split-KV decode keeps the
    /// same chunk boundaries across steps while the last chunk grows
    /// ([`crate::schedule::chunk_ranges`], DESIGN.md §7).  Stamped by
    /// the admission gate after session validation; 0 elsewhere.
    pub prefill_len: usize,
    /// Prefill/decode only: the session's incarnation epoch (ids may be
    /// reused after close; device caches match streams on it).  Stamped
    /// by the admission gate after session validation; 0 elsewhere.
    pub epoch: u64,
    /// Attention mask of this operator (DESIGN.md §6): `Causal` for
    /// transformer prefill, `PaddingKeys` stamped by [`Self::padded`]
    /// so bucket padding is exact.  Decode steps take no mask (the step
    /// row attends the whole prefix); the admission gate rejects masked
    /// ones.
    pub mask: MaskKind,
    /// Prefill only: tokens already covered by the device prefix cache
    /// at admission (DESIGN.md §11) — the devices resume prefill from
    /// query row `resumed_from` and only the uncovered suffix is
    /// computed (bitwise the cold run's suffix rows).  Stamped by the
    /// admission gate's prefix match; 0 elsewhere (and whenever
    /// `--prefix-cache off`).
    pub resumed_from: usize,
    /// Prefill only: the live donor session whose indexed prefix the
    /// admission match byte-verified against (DESIGN.md §11) — the
    /// scheduler adopts its device placement so the warm session's
    /// shards land where the shared pages live.  Stamped together with
    /// `resumed_from`; `None` elsewhere.
    pub prefix_donor: Option<SessionId>,
}

impl AttentionRequest {
    /// Single-head request (the original API; `num_heads == num_kv_heads
    /// == 1`).
    pub fn new(id: u64, seq_len: usize, d: usize, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>) -> Self {
        Self::gqa(id, seq_len, d, 1, 1, q, k, v)
    }

    /// Multi-head / grouped-query request.  Panics on shape mismatch
    /// (requests are constructed by trusted in-process callers; the
    /// serving path proper returns errors, it never panics).
    #[allow(clippy::too_many_arguments)]
    pub fn gqa(
        id: u64,
        seq_len: usize,
        d: usize,
        num_heads: usize,
        num_kv_heads: usize,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
    ) -> Self {
        assert!(num_heads >= 1, "need at least one query head");
        assert!(num_kv_heads >= 1, "need at least one KV head");
        assert_eq!(
            num_heads % num_kv_heads,
            0,
            "num_heads {num_heads} must be a multiple of num_kv_heads {num_kv_heads}"
        );
        assert_eq!(q.len(), num_heads * seq_len * d, "Q shape mismatch");
        assert_eq!(k.len(), num_kv_heads * seq_len * d, "K shape mismatch");
        assert_eq!(v.len(), num_kv_heads * seq_len * d, "V shape mismatch");
        AttentionRequest {
            id,
            seq_len,
            d,
            num_heads,
            num_kv_heads,
            q,
            k,
            v,
            op: SessionOp::Stateless,
            prefix_len: 0,
            prefill_len: 0,
            epoch: 0,
            mask: MaskKind::None,
            resumed_from: 0,
            prefix_donor: None,
        }
    }

    /// Builder: set the attention mask (constructors default to
    /// [`MaskKind::None`], the original unmasked behavior).
    pub fn with_mask(mut self, mask: MaskKind) -> Self {
        self.mask = mask;
        self
    }

    /// Open a decode session: full-prefix attention whose K/V the
    /// coordinator retains (host tier) and the serving device caches.
    #[allow(clippy::too_many_arguments)]
    pub fn prefill(
        id: u64,
        session: SessionId,
        seq_len: usize,
        d: usize,
        num_heads: usize,
        num_kv_heads: usize,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
    ) -> Self {
        let mut r = Self::gqa(id, seq_len, d, num_heads, num_kv_heads, q, k, v);
        r.op = SessionOp::Prefill { session };
        r
    }

    /// One decode step of an open session: one query row per head
    /// (`q: (num_heads, 1, d)`) and the new token's K/V row per KV head
    /// (`k, v: (num_kv_heads, 1, d)`).  Steps must be submitted in
    /// order, starting at 0 after the prefill.
    #[allow(clippy::too_many_arguments)]
    pub fn decode(
        id: u64,
        session: SessionId,
        step: u64,
        d: usize,
        num_heads: usize,
        num_kv_heads: usize,
        q_rows: Vec<f32>,
        k_row: Vec<f32>,
        v_row: Vec<f32>,
    ) -> Self {
        let mut r = Self::gqa(id, 1, d, num_heads, num_kv_heads, q_rows, k_row, v_row);
        r.op = SessionOp::Decode { session, step };
        r
    }

    /// Retire a session (frees host-tier K/V; device pages become
    /// reapable).  Carries no tensors; answered with an empty-output
    /// success response.
    pub fn close(id: u64, session: SessionId) -> Self {
        AttentionRequest {
            id,
            seq_len: 0,
            d: 0,
            num_heads: 1,
            num_kv_heads: 1,
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            op: SessionOp::Close { session },
            prefix_len: 0,
            prefill_len: 0,
            epoch: 0,
            mask: MaskKind::None,
            resumed_from: 0,
            prefix_donor: None,
        }
    }

    /// Query heads per KV head (the GQA group size; 1 for MHA).
    pub fn group_size(&self) -> usize {
        self.num_heads / self.num_kv_heads
    }

    /// KV head serving query head `head` (standard GQA mapping: query
    /// heads are grouped contiguously).
    pub fn kv_head_for(&self, head: usize) -> usize {
        debug_assert!(head < self.num_heads);
        head / self.group_size()
    }

    /// The `(seq_len, d)` Q matrix of one query head.
    pub fn head_q(&self, head: usize) -> &[f32] {
        let stride = self.seq_len * self.d;
        &self.q[head * stride..(head + 1) * stride]
    }

    /// The `(seq_len, d)` K and V matrices of one KV head.
    pub fn head_kv(&self, kv_head: usize) -> (&[f32], &[f32]) {
        let stride = self.seq_len * self.d;
        (
            &self.k[kv_head * stride..(kv_head + 1) * stride],
            &self.v[kv_head * stride..(kv_head + 1) * stride],
        )
    }

    /// Whole-operator FLOPs: every query head runs `4 L² d` attention
    /// when unmasked, mask-reduced counts otherwise (causal ≈ half; see
    /// [`masked_attention_flops`]).  KV sharing changes memory traffic,
    /// not FLOPs.  For a decode step the per-head work is one query row
    /// over the whole prefix, `4 L d` with `L = prefix_len`.  A
    /// cache-resumed prefill (`resumed_from > 0`, DESIGN.md §11) counts
    /// only the suffix query rows actually computed — utilization stays
    /// achieved-work over spent-cycles, not a free lunch.
    pub fn flops(&self) -> u64 {
        match self.op {
            SessionOp::Decode { .. } => {
                self.num_heads as u64
                    * decode_attention_flops(self.prefix_len.max(self.seq_len), self.d)
            }
            _ if self.resumed_from > 0 && self.resumed_from < self.seq_len => {
                self.num_heads as u64
                    * masked_attention_flops_resumed(
                        self.seq_len,
                        self.d,
                        self.mask,
                        self.resumed_from,
                        0,
                        self.seq_len,
                    )
            }
            _ => self.num_heads as u64 * masked_attention_flops(self.seq_len, self.d, self.mask),
        }
    }

    /// Zero-pad every head's Q/K/V to a bucketed sequence length.
    ///
    /// EXACT: the padded request carries a mask that excludes the padded
    /// key rows from the softmax entirely — an unmasked request is
    /// stamped `PaddingKeys { valid: seq_len }`, a causal request stays
    /// causal (its real query rows `i < seq_len` can never see keys
    /// `j > i`, so the padded tail is already invisible to them).  The
    /// reference backend's output rows `0..seq_len` are therefore
    /// bitwise identical to the unpadded request's (pinned by
    /// `rust/tests/coordinator_masked.rs`); padded *query* rows are the
    /// caller's to slice away, as before.  (Historical note: padding
    /// used to be approximate — padded keys scored 0 and took residual
    /// softmax weight.  The mask removed that, DESIGN.md §6.)  The
    /// mask-free PJRT artifacts reject masked requests, so strict PJRT
    /// pools still require exact-bucket artifacts.
    ///
    /// Stateless requests only (panics otherwise, like the shape
    /// asserts — trusted in-process callers): a session prefill's K/V
    /// becomes the *retained* prefix that every decode step attends, so
    /// padded zero rows must never enter it — open sessions at their
    /// exact length instead (the reference backend, which decode
    /// requires anyway, serves any length).
    pub fn padded(&self, bucket: usize) -> AttentionRequest {
        assert!(
            matches!(self.op, SessionOp::Stateless),
            "padded() is for stateless requests; a session's K/V prefix is retained \
             for decode, so open sessions at their exact length (DESIGN.md §6)"
        );
        assert!(bucket >= self.seq_len);
        if bucket == self.seq_len {
            return self.clone();
        }
        let pad = |m: &[f32], heads: usize| {
            let old = self.seq_len * self.d;
            let new = bucket * self.d;
            let mut out = vec![0.0f32; heads * new];
            for h in 0..heads {
                out[h * new..h * new + old].copy_from_slice(&m[h * old..(h + 1) * old]);
            }
            out
        };
        AttentionRequest {
            id: self.id,
            seq_len: bucket,
            d: self.d,
            num_heads: self.num_heads,
            num_kv_heads: self.num_kv_heads,
            q: pad(&self.q, self.num_heads),
            k: pad(&self.k, self.num_kv_heads),
            v: pad(&self.v, self.num_kv_heads),
            op: self.op,
            prefix_len: self.prefix_len,
            prefill_len: self.prefill_len,
            epoch: self.epoch,
            mask: match self.mask {
                // Mask out the padded keys; re-padding keeps the
                // original valid prefix.
                MaskKind::None => MaskKind::PaddingKeys { valid: self.seq_len },
                m => m,
            },
            resumed_from: self.resumed_from,
            prefix_donor: self.prefix_donor,
        }
    }
}

/// Execution statistics gathered into one [`AttentionResponse`]: the
/// sharding/caching/measurement accounting, consolidated so the
/// response proper stays the answer ("output, cost, latency") and every
/// diagnostic rides in one structured place.  `Default` is the inline
/// lifecycle reply (all zero, no attribution).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResponseStats {
    /// Sequence chunks each head was split into (DESIGN.md §7); 1 on
    /// the legacy whole-sequence path, 0 for inline lifecycle replies.
    pub seq_chunks: usize,
    /// Partial-merge steps the gather performed (`num_heads ·
    /// (seq_chunks − 1)` when sequence-sharded, else 0) — counted
    /// distinctly from head shards in [`super::metrics::Metrics`].
    pub merge_steps: usize,
    /// Decode shards served from device KV-cache pages.
    pub kv_hits: usize,
    /// Decode shards that took the cache-miss recompute fallback.
    pub kv_misses: usize,
    /// Shards whose `device_cycles` share was *measured* on the
    /// cycle-accurate machine (`backend=sim`, DESIGN.md §8) rather than
    /// predicted by the perfmodel — `shards` on a sim pool, 0 on the
    /// modeled backends.
    pub measured_shards: usize,
    /// Per-instruction-class attribution of `device_cycles` (DESIGN.md
    /// §9): present iff *every* shard executed on the cycle-accurate
    /// machine (`measured_shards == shards`, plus the decode-miss
    /// recompute charge); its `total()` equals `device_cycles` exactly.
    /// `None` on modeled backends and inline lifecycle replies.
    pub cycle_breakdown: Option<CycleBreakdown>,
    /// Prefill only: tokens per KV head the prefix cache covered at
    /// admission (the request's `resumed_from`, DESIGN.md §11) — the
    /// devices computed only the `seq_len − prefix_reused_tokens`
    /// suffix rows.
    pub prefix_reused_tokens: usize,
    /// KV pages this request's streams attached by content match
    /// instead of copying (prefix sharing across its shards).
    pub prefix_attached_pages: usize,
    /// Copy-on-write tail copies this request's decode appends
    /// triggered on its devices.
    pub cow_copies: usize,
    /// Modeled device cycles the resumed prefill avoided relative to a
    /// cold full-prefix run, summed over shards
    /// ([`crate::perfmodel::fsa_flash_resumed_perf`]); 0 when nothing
    /// resumed.
    pub saved_prefill_cycles: u64,
}

/// Completed request, gathered over all of its head shards.
#[derive(Clone, Debug)]
pub struct AttentionResponse {
    pub id: u64,
    /// Head-major `(num_heads, seq_len, d)` output, each head sliced
    /// back to the original length; for a single-head request this is
    /// the plain row-major `(seq_len, d)` matrix.  `Err` carries the
    /// first failing head's message.
    pub output: Result<Vec<f32>, String>,
    /// Query/KV head counts echoed from the request.
    pub num_heads: usize,
    pub num_kv_heads: usize,
    /// Shards gathered into this response (`num_heads · seq_chunks`).
    pub shards: usize,
    /// Total simulated FSA device cycles *consumed* across all shards
    /// (the cost metric: what the pool spent).
    pub device_cycles: u64,
    /// Simulated whole-operator latency in cycles: the busiest device's
    /// share of the shards (the paper's whole-operator metric divides
    /// FLOPs by this, not by the summed cycles).
    pub critical_path_cycles: u64,
    /// `critical_path_cycles` at the configured clock.
    pub device_time: Duration,
    /// Whole-operator achieved/peak FLOPs/s over the devices that served
    /// this request (comparable to paper Fig. 11 / §6.1).
    pub utilization: f64,
    /// Host wall-clock from submit to gather completion.
    pub latency: Duration,
    /// Device that served head 0 (the only device for single-head
    /// requests).
    pub device_id: usize,
    /// All devices that served shards, sorted, deduplicated.
    pub devices_used: Vec<usize>,
    /// Padded bucket used.
    pub bucket: usize,
    /// SLO class of the request ([`OpKind::of`] its session op) — which
    /// latency histogram this completion lands in.
    pub kind: OpKind,
    /// Sharding / cache / measurement accounting (one struct instead of
    /// the historical six loose fields).
    pub stats: ResponseStats,
}

/// Internal envelope: request + reply channel + enqueue timestamp.
pub struct Envelope {
    pub req: AttentionRequest,
    pub reply: mpsc::Sender<AttentionResponse>,
    pub enqueued: Instant,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::attention_flops;

    #[test]
    fn padding_preserves_prefix() {
        let r = AttentionRequest::new(1, 2, 2, vec![1., 2., 3., 4.], vec![5., 6., 7., 8.], vec![9., 1., 2., 3.]);
        let p = r.padded(4);
        assert_eq!(p.seq_len, 4);
        assert_eq!(&p.q[..4], &[1., 2., 3., 4.]);
        assert_eq!(&p.q[4..], &[0.0; 4]);
        assert_eq!(p.id, 1);
        // Exactness: the padded keys are masked out, not approximated.
        assert_eq!(p.mask, MaskKind::PaddingKeys { valid: 2 });
        // No-op when already at bucket size (and no mask stamped).
        let same = r.padded(2);
        assert_eq!(same.q, r.q);
        assert_eq!(same.mask, MaskKind::None);
    }

    #[test]
    fn padding_keeps_existing_masks() {
        let r = AttentionRequest::new(
            1, 2, 2, vec![0.0; 4], vec![0.0; 4], vec![0.0; 4],
        );
        // A causal request stays causal: its real query rows already
        // cannot see the padded tail.
        let causal = r.clone().with_mask(MaskKind::Causal).padded(4);
        assert_eq!(causal.mask, MaskKind::Causal);
        // Re-padding keeps the original valid prefix.
        let twice = r.padded(4).padded(8);
        assert_eq!(twice.mask, MaskKind::PaddingKeys { valid: 2 });
        assert_eq!(twice.seq_len, 8);
    }

    #[test]
    fn masked_flops_accounting() {
        let (seq, d) = (8usize, 4usize);
        let m = vec![0.0f32; seq * d];
        let r = AttentionRequest::new(1, seq, d, m.clone(), m.clone(), m);
        assert_eq!(r.flops(), attention_flops(seq, d));
        let causal = r.clone().with_mask(MaskKind::Causal);
        assert_eq!(causal.flops(), 2 * 8 * 9 * 4);
        assert!(causal.flops() < r.flops());
        let padded = r.clone().with_mask(MaskKind::PaddingKeys { valid: 3 });
        assert_eq!(padded.flops(), 4 * 8 * 3 * 4);
        assert_eq!(r.mask, MaskKind::None, "constructors default unmasked");
    }

    #[test]
    fn padding_pads_every_head() {
        let (seq, d) = (2, 2);
        let q: Vec<f32> = (0..4 * seq * d).map(|x| x as f32).collect();
        let kv: Vec<f32> = (100..100 + 2 * seq * d).map(|x| x as f32).collect();
        let r = AttentionRequest::gqa(9, seq, d, 4, 2, q.clone(), kv.clone(), kv.clone());
        let p = r.padded(4);
        assert_eq!(p.q.len(), 4 * 4 * d);
        assert_eq!(p.k.len(), 2 * 4 * d);
        for h in 0..4 {
            // Original head data at the head's new offset, zeros after.
            assert_eq!(&p.q[h * 8..h * 8 + 4], &q[h * 4..(h + 1) * 4]);
            assert_eq!(&p.q[h * 8 + 4..(h + 1) * 8], &[0.0; 4]);
        }
    }

    #[test]
    fn gqa_head_mapping_and_slices() {
        let (seq, d) = (2, 3);
        let q: Vec<f32> = (0..8 * seq * d).map(|x| x as f32).collect();
        let kv: Vec<f32> = (0..2 * seq * d).map(|x| -(x as f32)).collect();
        let r = AttentionRequest::gqa(4, seq, d, 8, 2, q.clone(), kv.clone(), kv.clone());
        assert_eq!(r.group_size(), 4);
        assert_eq!(r.kv_head_for(0), 0);
        assert_eq!(r.kv_head_for(3), 0);
        assert_eq!(r.kv_head_for(4), 1);
        assert_eq!(r.kv_head_for(7), 1);
        assert_eq!(r.head_q(2), &q[2 * 6..3 * 6]);
        let (k1, v1) = r.head_kv(1);
        assert_eq!(k1, &kv[6..12]);
        assert_eq!(v1, k1);
        assert_eq!(r.flops(), 8 * 4 * (seq as u64) * (seq as u64) * d as u64);
    }

    #[test]
    fn session_ops_and_decode_flops() {
        let d = 4;
        let p = AttentionRequest::prefill(
            1, 77, 2, d, 2, 1,
            vec![0.0; 2 * 2 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
        );
        assert_eq!(p.op, SessionOp::Prefill { session: 77 });
        assert_eq!(p.flops(), 2 * attention_flops(2, d));

        let mut dec = AttentionRequest::decode(
            2, 77, 0, d, 2, 1,
            vec![0.0; 2 * d], vec![0.0; d], vec![0.0; d],
        );
        assert_eq!(dec.op, SessionOp::Decode { session: 77, step: 0 });
        assert_eq!(dec.seq_len, 1);
        // Before the admission gate stamps the prefix, flops fall back to the
        // one-token shape; after stamping they cover the prefix.
        assert_eq!(dec.flops(), 2 * decode_attention_flops(1, d));
        dec.prefix_len = 3;
        assert_eq!(dec.flops(), 2 * decode_attention_flops(3, d));

        let c = AttentionRequest::close(3, 77);
        assert_eq!(c.op, SessionOp::Close { session: 77 });
        assert_eq!(c.flops(), 0);
    }

    #[test]
    #[should_panic(expected = "padded() is for stateless requests")]
    fn padding_a_session_prefill_is_refused() {
        // A causal prefill padded to a bucket would retain zero K/V
        // rows in the session prefix that every decode step then
        // attends — the exact poisoning the mask work eliminates.
        let d = 2;
        AttentionRequest::prefill(
            1, 7, 2, d, 1, 1,
            vec![0.0; 2 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
        )
        .with_mask(MaskKind::Causal)
        .padded(4);
    }

    #[test]
    fn op_kind_classification() {
        assert_eq!(OpKind::of(&SessionOp::Stateless), OpKind::Stateless);
        assert_eq!(OpKind::of(&SessionOp::Prefill { session: 1 }), OpKind::Prefill);
        assert_eq!(OpKind::of(&SessionOp::Decode { session: 1, step: 0 }), OpKind::Decode);
        assert_eq!(OpKind::of(&SessionOp::Close { session: 1 }), OpKind::Close);
        for (i, k) in OpKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i, "{k:?}");
        }
        assert_eq!(OpKind::Decode.name(), "decode");
    }

    #[test]
    #[should_panic(expected = "Q shape mismatch")]
    fn shape_validation() {
        AttentionRequest::new(1, 2, 2, vec![1.0], vec![0.0; 4], vec![0.0; 4]);
    }

    #[test]
    #[should_panic(expected = "multiple of num_kv_heads")]
    fn gqa_divisibility_enforced() {
        let m = vec![0.0f32; 3 * 4];
        AttentionRequest::gqa(1, 2, 2, 3, 2, m.clone(), m.clone(), m);
    }
}
