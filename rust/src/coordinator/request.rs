//! Request/response types on the serving path.

use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One single-head attention request: row-major (seq_len, d) matrices.
#[derive(Clone, Debug)]
pub struct AttentionRequest {
    pub id: u64,
    pub seq_len: usize,
    pub d: usize,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl AttentionRequest {
    pub fn new(id: u64, seq_len: usize, d: usize, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>) -> Self {
        assert_eq!(q.len(), seq_len * d, "Q shape mismatch");
        assert_eq!(k.len(), seq_len * d, "K shape mismatch");
        assert_eq!(v.len(), seq_len * d, "V shape mismatch");
        AttentionRequest { id, seq_len, d, q, k, v }
    }

    /// Zero-pad Q/K/V to a bucketed sequence length.
    ///
    /// APPROXIMATE for keys: the AOT artifacts take no mask, so padded
    /// key rows score 0 and receive a small residual softmax weight
    /// (their V rows are zero, so the output error is a bounded
    /// denominator inflation).  Padded *query* rows are exact — they are
    /// sliced away.  The coordinator therefore runs in strict mode by
    /// default (exact-bucket artifacts only) and callers opt into padding
    /// explicitly; masked artifacts are listed as future work in
    /// DESIGN.md.
    pub fn padded(&self, bucket: usize) -> AttentionRequest {
        assert!(bucket >= self.seq_len);
        if bucket == self.seq_len {
            return self.clone();
        }
        let pad = |m: &[f32]| {
            let mut out = vec![0.0f32; bucket * self.d];
            out[..m.len()].copy_from_slice(m);
            out
        };
        AttentionRequest {
            id: self.id,
            seq_len: bucket,
            d: self.d,
            q: pad(&self.q),
            k: pad(&self.k),
            v: pad(&self.v),
        }
    }
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct AttentionResponse {
    pub id: u64,
    /// Row-major (seq_len, d) output, sliced back to the original length.
    pub output: Result<Vec<f32>, String>,
    /// Simulated FSA device cycles for this request's workload.
    pub device_cycles: u64,
    /// Simulated device time at the configured clock.
    pub device_time: Duration,
    /// Host wall-clock from submit to completion.
    pub latency: Duration,
    /// Which device served it.
    pub device_id: usize,
    /// Padded bucket used.
    pub bucket: usize,
}

/// Internal envelope: request + reply channel + enqueue timestamp.
pub struct Envelope {
    pub req: AttentionRequest,
    pub reply: mpsc::Sender<AttentionResponse>,
    pub enqueued: Instant,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_preserves_prefix() {
        let r = AttentionRequest::new(1, 2, 2, vec![1., 2., 3., 4.], vec![5., 6., 7., 8.], vec![9., 1., 2., 3.]);
        let p = r.padded(4);
        assert_eq!(p.seq_len, 4);
        assert_eq!(&p.q[..4], &[1., 2., 3., 4.]);
        assert_eq!(&p.q[4..], &[0.0; 4]);
        assert_eq!(p.id, 1);
        // No-op when already at bucket size.
        let same = r.padded(2);
        assert_eq!(same.q, r.q);
    }

    #[test]
    #[should_panic(expected = "Q shape mismatch")]
    fn shape_validation() {
        AttentionRequest::new(1, 2, 2, vec![1.0], vec![0.0; 4], vec![0.0; 4]);
    }
}
