//! Serving coordinator: the L3 layer that puts FSA devices on a request
//! path (vLLM-router-shaped, scoped to this paper's device).
//!
//! Pipeline (DESIGN.md §10): [`request`] types flow into the persistent
//! serving loop — a [`queue`] of waiting envelopes drained by the
//! [`scheduler`], which runs continuously: new requests join a running
//! batch, closed sessions leave it, decode steps from many live
//! sessions share each dispatch wave, and fresh prefills are admitted
//! under the token-budget/waiting-ratio policy. The [`batcher`] module
//! keeps the admission gate ([`batcher::admit_session_op`] +
//! [`batcher::PoolCapabilities`]): capability and lifecycle checks that
//! must not change under continuous scheduling. Admitted requests
//! explode into per-query-head [`shard`]s grouped into device batches
//! by padded sequence bucket; the [`router`] scatters shards across the
//! pool — least-loaded placement with KV-head affinity so GQA heads
//! sharing K/V land on one device; each [`device`] worker owns a
//! numerics backend ([`crate::runtime`]: PJRT artifacts, or the
//! in-crate reference twin) plus the [`crate::perfmodel`] for
//! device-cycle accounting (simulated FSA latency at 1.5 GHz); the
//! final shard's worker gathers the per-head outputs into one
//! whole-operator [`request::AttentionResponse`], answered on that
//! request's own reply channel the moment it completes (per-request
//! streaming — no end-of-batch barrier). [`metrics`] aggregates
//! throughput/latency at both request and shard granularity.
//!
//! Decode-phase serving (DESIGN.md §5) rides the same path: [`session`]
//! carries the prefill→decode→close lifecycle and the host-tier K/V,
//! [`kvcache`] is the per-device paged KV cache the decode steps stream
//! from, and the router pins a session's KV groups to the device
//! holding their pages.
//!
//! Threads + channels stand in for tokio (offline environment, see
//! DESIGN.md §substitutions); the structure is identical: bounded ingress
//! queue, worker pool, per-request completion channels.

pub mod batcher;
pub mod device;
pub mod kvcache;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod session;
pub mod shard;
pub mod trace;

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, ensure};

use crate::config::{AccelConfig, BackendKind, RunConfig};
use crate::runtime::Backend;
use device::DeviceWorker;
use metrics::Metrics;
use request::{AttentionRequest, AttentionResponse};
use router::Router;
use scheduler::{Scheduler, TokenBudget};
use session::SessionTable;
use trace::Tracer;

/// Handle to a running coordinator.
pub struct Coordinator {
    ingress: mpsc::SyncSender<request::Envelope>,
    scheduler_handle: Option<std::thread::JoinHandle<()>>,
    workers: Vec<DeviceWorker>,
    pub metrics: Arc<Metrics>,
    /// Session registry (decode-phase serving): lifecycle state, the
    /// host-tier K/V prefixes, and the sticky device placements.
    pub sessions: Arc<SessionTable>,
    /// Request-path event sink (DESIGN.md §9); disabled unless
    /// [`RunConfig::trace`] says otherwise, in which case it records
    /// admit→shard→dispatch→execute→gather spans plus KV traffic.
    pub tracer: Arc<Tracer>,
}

impl Coordinator {
    /// Boot the scheduler thread + device worker pool.
    ///
    /// Backend resolution ([`BackendKind`]): `Pjrt` (the default)
    /// requires the artifacts manifest up front and fails fast without
    /// it; `Reference` needs nothing; `Auto` takes PJRT when the
    /// manifest exists and silently serves on the reference twin
    /// otherwise.
    pub fn start(cfg: RunConfig) -> crate::Result<Coordinator> {
        cfg.validate()?;
        let metrics = Arc::new(Metrics::new());
        let artifacts = PathBuf::from(&cfg.artifacts_dir);
        if cfg.backend == BackendKind::Pjrt {
            ensure!(
                artifacts.join("manifest.txt").exists(),
                "artifacts manifest not found in {:?} — run `make artifacts` \
                 (or select backend=reference|auto)",
                artifacts
            );
        }

        let sessions = Arc::new(SessionTable::new());
        let tracer = Tracer::new(cfg.trace);
        let mut workers = Vec::with_capacity(cfg.devices);
        for id in 0..cfg.devices {
            workers.push(DeviceWorker::spawn(
                id,
                &cfg,
                sessions.clone(),
                metrics.clone(),
                tracer.clone(),
            )?);
        }
        let router = Router::new(
            workers.iter().map(|w| w.handle()).collect(),
            sessions.clone(),
        )
        .with_tracer(tracer.clone());

        // Resolve the pool's backend capabilities once: PJRT has no
        // `fsa_decode` artifact kind, its artifacts take no mask input
        // and emit no partial (O~, m, l) state, and `auto` lands on
        // PJRT exactly when the manifest is present and the client
        // boots — probe with the workers' own resolution logic so
        // decode steps, masked requests, and sequence-sharded serving
        // are rejected up front on an incapable pool (a decode step is
        // never consumed, a masked prefill never opens a session its
        // shards cannot serve).  The sim backend serves everything the
        // reference twin does (the §8 mask wave + decode/partial
        // program variants run on the array) but carries the O(L²)
        // `sim_max_seq` admission guard.
        let caps = match cfg.backend {
            BackendKind::Reference => batcher::PoolCapabilities::reference(),
            BackendKind::Sim => batcher::PoolCapabilities::sim(cfg.sim_max_seq),
            BackendKind::Pjrt => batcher::PoolCapabilities::pjrt(),
            BackendKind::Auto => {
                let accel = AccelConfig::builtin("fsa")?;
                let on_reference = Backend::new(BackendKind::Auto, &artifacts, &accel)
                    .map(|b| b.name() == "reference")
                    .unwrap_or(true);
                if on_reference {
                    batcher::PoolCapabilities::reference()
                } else {
                    batcher::PoolCapabilities::pjrt()
                }
            }
        };

        let (ingress, ingress_rx) = mpsc::sync_channel(cfg.queue_depth);
        // Prefix caching (DESIGN.md §11) needs a backend with a resumed
        // prefill kind: the reference twin and the sim serve it, the
        // AOT PJRT artifacts do not.  `validate` already refused the
        // strict-PJRT combination; an `auto` pool that resolved to PJRT
        // silently serves cold, matching auto's fallback contract.
        let prefix_page = if cfg.prefix_cache && caps.seqpar { cfg.kv_page_size } else { 0 };
        let scheduler = Scheduler::new(
            cfg.max_batch,
            cfg.batch_timeout_cycles,
            cfg.freq_ghz,
            cfg.seq_shards,
            caps,
            TokenBudget {
                max_prefill_tokens: cfg.max_batch_prefill_tokens,
                max_total_tokens: cfg.max_batch_total_tokens,
                waiting_served_ratio: cfg.waiting_served_ratio,
            },
        )
        .with_tracer(tracer.clone())
        .with_prefix_cache(prefix_page);
        let m2 = metrics.clone();
        let s2 = sessions.clone();
        let scheduler_handle = std::thread::Builder::new()
            .name("fsa-scheduler".into())
            .spawn(move || scheduler.run(ingress_rx, router, m2, s2))
            .expect("spawning scheduler");

        Ok(Coordinator {
            ingress,
            scheduler_handle: Some(scheduler_handle),
            workers,
            metrics,
            sessions,
            tracer,
        })
    }

    /// Submit a request (single-head or multi-head/GQA); the gathered
    /// whole-operator response arrives on the returned channel.
    /// Fails fast when the ingress queue is full (backpressure).
    pub fn submit(
        &self,
        req: AttentionRequest,
    ) -> crate::Result<mpsc::Receiver<AttentionResponse>> {
        let (tx, rx) = mpsc::channel();
        self.metrics.submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.ingress
            .try_send(request::Envelope { req, reply: tx, enqueued: std::time::Instant::now() })
            .map_err(|e| match e {
                mpsc::TrySendError::Full(_) => anyhow!("ingress queue full (backpressure)"),
                mpsc::TrySendError::Disconnected(_) => anyhow!("coordinator is shut down"),
            })?;
        Ok(rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn submit_wait(&self, req: AttentionRequest) -> crate::Result<AttentionResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| anyhow!("worker dropped the request"))
    }

    /// Graceful shutdown: drain the scheduler (flush policy — every
    /// still-queued envelope is served or answered, DESIGN.md §10),
    /// stop workers.
    pub fn shutdown(mut self) {
        drop(self.ingress);
        if let Some(h) = self.scheduler_handle.take() {
            let _ = h.join();
        }
        for w in self.workers.drain(..) {
            w.shutdown();
        }
    }
}

/// Shared helper: bucketize a sequence length to the padded artifact
/// sizes the runtime ships (powers of the artifact ladder).
pub fn seq_bucket(seq_len: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= seq_len).min()
}

#[derive(Debug)]
pub struct CoordinatorError;

/// Lock helper that survives poisoned mutexes (a panicked worker must not
/// wedge the whole coordinator).
pub fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let buckets = [128, 512, 2048, 4096];
        assert_eq!(seq_bucket(1, &buckets), Some(128));
        assert_eq!(seq_bucket(128, &buckets), Some(128));
        assert_eq!(seq_bucket(129, &buckets), Some(512));
        assert_eq!(seq_bucket(4096, &buckets), Some(4096));
        assert_eq!(seq_bucket(5000, &buckets), None);
    }
}
