//! Device worker: one thread owning a PJRT runtime (numerics) and the FSA
//! performance model (simulated device timing).
//!
//! Each worker is a simulated FSA card: requests execute through the
//! `fsa_attn` AOT artifact (the numerics twin of the silicon, see
//! DESIGN.md), while latency/throughput are accounted in device cycles
//! from [`crate::perfmodel`] at the paper's 1.5 GHz clock.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use crate::config::AccelConfig;
use crate::perfmodel::fsa_flash_perf;
use crate::runtime::Runtime;
use crate::schedule::Variant;

use super::metrics::Metrics;
use super::request::AttentionResponse;
use super::router::{Batch, WorkerHandle};

pub struct DeviceWorker {
    handle: WorkerHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl DeviceWorker {
    /// Spawn the worker thread.  The PJRT client is created inside the
    /// thread (it is not Send) — startup errors surface on first use via
    /// error responses.
    pub fn spawn(id: usize, artifacts: PathBuf, metrics: Arc<Metrics>) -> crate::Result<DeviceWorker> {
        let (tx, rx) = mpsc::channel::<Batch>();
        let load = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let handle = WorkerHandle { id, queue: tx, load: load.clone() };
        let thread = std::thread::Builder::new()
            .name(format!("fsa-device-{id}"))
            .spawn(move || worker_loop(id, artifacts, rx, load, metrics))?;
        Ok(DeviceWorker { handle, thread: Some(thread) })
    }

    pub fn handle(&self) -> WorkerHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        // Dropping our queue clone isn't enough (router holds clones);
        // the batcher going away drops those, and the loop exits.
        drop(self.handle);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn worker_loop(
    id: usize,
    artifacts: PathBuf,
    rx: mpsc::Receiver<Batch>,
    load: Arc<std::sync::atomic::AtomicUsize>,
    metrics: Arc<Metrics>,
) {
    let cfg = AccelConfig::builtin("fsa").expect("builtin fsa config");
    let mut runtime = match Runtime::new(&artifacts) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("device {id}: runtime init failed: {e:#}");
            None
        }
    };

    while let Ok(batch) = rx.recv() {
        let n = batch.len();
        for env in batch {
            let t0 = env.enqueued;
            let req = env.req;
            let perf = fsa_flash_perf(&cfg, req.seq_len.max(cfg.array_size), req.d.min(cfg.array_size), Variant::DualPath, cfg.pwl_segments);
            let output = match runtime.as_mut() {
                None => Err("device runtime unavailable".to_string()),
                Some(rt) => {
                    match rt.manifest.best_for("fsa_attn", req.seq_len, req.d) {
                        None => Err(format!(
                            "no fsa_attn artifact covers seq_len {} d {}",
                            req.seq_len, req.d
                        )),
                        Some(meta) if meta.seq_len != req.seq_len => Err(format!(
                            "strict mode: need exact artifact for seq_len {} (nearest is {}); \
                             pad client-side with AttentionRequest::padded",
                            req.seq_len, meta.seq_len
                        )),
                        Some(meta) => {
                            let name = meta.name.clone();
                            rt.execute_attention(&name, &req.q, &req.k, &req.v)
                                .map_err(|e| format!("{e:#}"))
                        }
                    }
                }
            };
            let ok = output.is_ok();
            let resp = AttentionResponse {
                id: req.id,
                output,
                device_cycles: perf.total_cycles,
                device_time: Duration::from_nanos(
                    (perf.total_cycles as f64 / cfg.freq_ghz) as u64,
                ),
                latency: t0.elapsed(),
                device_id: id,
                bucket: req.seq_len,
            };
            metrics.record(&resp, ok);
            let _ = env.reply.send(resp);
        }
        load.fetch_sub(n, Ordering::Relaxed);
    }
}
