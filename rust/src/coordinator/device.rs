//! Device worker: one thread owning a numerics [`Backend`] (PJRT
//! artifacts or the in-crate reference twin), the FSA performance
//! model (simulated device timing), and — for decode-phase serving —
//! a per-device paged KV cache (DESIGN.md §5).
//!
//! Each worker is a simulated FSA card.  The unit of work is one *head
//! shard* (see [`super::shard`]): numerics execute through the backend
//! (the `fsa_attn` AOT artifact — the numerics twin of the silicon,
//! see DESIGN.md §3 — or the `flash_pwl` reference), while
//! latency/throughput are accounted in device cycles from
//! [`crate::perfmodel`] at the paper's 1.5 GHz clock.
//!
//! Prefill shards additionally land their KV group's K/V prefix in the
//! worker's page cache; decode shards serve the prefix from pages when
//! cached (O(L) bytes streamed, [`fsa_decode_perf`] hit cost) and fall
//! back to the session host tier otherwise (charged as a full O(L²)
//! prefix recompute, then re-cached).  Evictions report back to the
//! [`SessionTable`] so the router can re-place the stream.  The worker
//! that finishes a request's final shard assembles and sends the
//! gathered whole-operator response.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;

use crate::config::{AccelConfig, RunConfig};
use crate::perfmodel::{
    fsa_decode_perf, fsa_flash_chunk_perf, fsa_flash_perf_masked, fsa_flash_resumed_perf,
};
use crate::runtime::{Backend, ShardPlan};
use crate::schedule::Variant;

use super::kvcache::{Admit, KvCache, KvCacheConfig};
use super::metrics::Metrics;
use super::router::{Batch, WorkerHandle};
use super::session::SessionTable;
use super::shard::{CacheOutcome, ShardCtx, ShardEnvelope, ShardOut, ShardResult};
use super::trace::{EventKind, Tracer, NO_HEAD, NO_SESSION};
use crate::sim::CycleBreakdown;

pub struct DeviceWorker {
    handle: WorkerHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl DeviceWorker {
    /// Spawn the worker thread.  The backend is created inside the
    /// thread (the PJRT client is not Send) — startup errors surface on
    /// first use via error responses.
    pub fn spawn(
        id: usize,
        cfg: &RunConfig,
        sessions: Arc<SessionTable>,
        metrics: Arc<Metrics>,
        tracer: Arc<Tracer>,
    ) -> crate::Result<DeviceWorker> {
        let (tx, rx) = mpsc::channel::<Batch>();
        let load = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let handle = WorkerHandle { id, queue: tx, load: load.clone() };
        let cfg = cfg.clone();
        let thread = std::thread::Builder::new()
            .name(format!("fsa-device-{id}"))
            .spawn(move || worker_loop(id, cfg, rx, load, metrics, sessions, tracer))?;
        Ok(DeviceWorker { handle, thread: Some(thread) })
    }

    pub fn handle(&self) -> WorkerHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        // Dropping our queue clone isn't enough (router holds clones);
        // the scheduler going away drops those, and the loop exits.
        drop(self.handle);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn worker_loop(
    id: usize,
    run_cfg: RunConfig,
    rx: mpsc::Receiver<Batch>,
    load: Arc<std::sync::atomic::AtomicUsize>,
    metrics: Arc<Metrics>,
    sessions: Arc<SessionTable>,
    tracer: Arc<Tracer>,
) {
    let mut cfg = AccelConfig::builtin("fsa").expect("builtin fsa config");
    // Device timing runs at the configured clock (also used by the
    // scheduler's timeout conversion — one clock everywhere), and the
    // configured array dim (tiling for the reference backend, machine
    // size for the sim backend, tile census for pricing).
    cfg.freq_ghz = run_cfg.freq_ghz;
    cfg.array_size = run_cfg.array_size;
    let artifacts = PathBuf::from(&run_cfg.artifacts_dir);
    let mut backend = match Backend::new(run_cfg.backend, &artifacts, &cfg) {
        Ok(mut b) => {
            // Shard batching for the sim backend (no-op elsewhere):
            // how many shards share one machine between hazard fences.
            b.set_sim_batch_shards(run_cfg.sim_batch_shards);
            // Compiled-program cache entries (DESIGN.md §12; 0 disables).
            b.set_sim_prog_cache(run_cfg.sim_prog_cache);
            Some(b)
        }
        Err(e) => {
            eprintln!("device {id}: backend init failed: {e:#}");
            None
        }
    };
    // The engine name is fixed at resolution; counted per dispatched
    // shard (satellite: per-backend-kind dispatch metrics).
    let backend_name = backend.as_ref().map(|b| b.name());
    let mut cache = KvCache::new(KvCacheConfig {
        pages: run_cfg.kv_cache_pages,
        page_size: run_cfg.kv_page_size,
        policy: run_cfg.kv_eviction,
    });
    let seq_shards = run_cfg.seq_shards.max(1);

    while let Ok(batch) = rx.recv() {
        let n = batch.len();
        for env in batch {
            let exec = execute_shard(
                id, &cfg, backend.as_mut(), &mut cache, &sessions, &metrics, &env, seq_shards,
                &tracer,
            );
            metrics.record_shard(exec.cycles);
            if let Some(name) = backend_name {
                metrics.record_dispatch(name);
            }
            if env.shard.is_partial() {
                metrics.seq_chunk_shards.fetch_add(1, Ordering::Relaxed);
            }
            let (req_id, session) = (env.shard.req.id, ctx_session(&env.ctx));
            let (head, chunk) = (env.shard.head as u32, env.shard.chunk as u32);
            tracer.record(EventKind::Execute, req_id, session, head, chunk, id as u32, exec.cycles);
            match exec.cache {
                CacheOutcome::Hit => {
                    metrics.kv_hits.fetch_add(1, Ordering::Relaxed);
                    tracer.record(EventKind::KvHit, req_id, session, head, chunk, id as u32, 0);
                }
                CacheOutcome::Miss => {
                    metrics.kv_misses.fetch_add(1, Ordering::Relaxed);
                    tracer.record(EventKind::KvMiss, req_id, session, head, chunk, id as u32, 0);
                }
                CacheOutcome::NotApplicable => {}
            }
            let resp = env.gather.complete_and_report(
                ShardResult {
                    head: env.shard.head,
                    chunk_pos: env.shard.chunk_pos,
                    device_id: id,
                    cycles: exec.cycles,
                    measured: exec.measured,
                    output: exec.output,
                    cache: exec.cache,
                    breakdown: exec.breakdown,
                    attached_pages: exec.attached_pages,
                    cow_copies: exec.cow_copies,
                    saved_cycles: exec.saved_cycles,
                },
                &cfg,
            );
            if let Some(resp) = resp {
                tracer.record(
                    EventKind::Gather, req_id, session, NO_HEAD, NO_HEAD, id as u32,
                    resp.device_cycles,
                );
                if resp.stats.merge_steps > 0 {
                    tracer.record(
                        EventKind::Merge, req_id, session, NO_HEAD, NO_HEAD, id as u32,
                        resp.stats.merge_steps as u64,
                    );
                }
                metrics.record(&resp, resp.output.is_ok());
                env.gather.send(resp);
            }
        }
        // KV occupancy gauge: pages used/total after each batch
        // (DESIGN.md §9's cache-pressure signal).
        metrics.set_kv_gauge(id, cache.used_pages(), cache.capacity_pages());
        // Hot-path counters (DESIGN.md §12): drain the backend's
        // program-cache hit/miss and machine-allocation deltas once per
        // batch instead of per shard.
        if let Some(b) = backend.as_mut() {
            let hp = b.take_hotpath_stats();
            if hp != Default::default() {
                metrics.prog_cache_hits.fetch_add(hp.prog_cache_hits, Ordering::Relaxed);
                metrics.prog_cache_misses.fetch_add(hp.prog_cache_misses, Ordering::Relaxed);
                metrics.machines_allocated.fetch_add(hp.machines_allocated, Ordering::Relaxed);
            }
        }
        load.fetch_sub(n, Ordering::Relaxed);
    }
}

/// Session id of a shard's context for trace events ([`NO_SESSION`]
/// for stateless work).
fn ctx_session(ctx: &ShardCtx) -> u64 {
    match ctx {
        ShardCtx::Stateless => NO_SESSION,
        ShardCtx::Prefill { session, .. } | ShardCtx::Decode { session, .. } => *session,
    }
}

/// What [`execute_shard`] hands back to the worker loop — everything
/// the [`ShardResult`] needs beyond the shard's own coordinates.
struct ShardExec {
    /// Device cycles charged to the shard (measured when the backend
    /// measured, modeled otherwise).
    cycles: u64,
    cache: CacheOutcome,
    output: Result<ShardOut, String>,
    /// Whether `cycles` came from the cycle-accurate machine.
    measured: bool,
    /// Per-class attribution when measured; its `total()` equals
    /// `cycles` (including the decode-miss recompute charge).
    breakdown: Option<CycleBreakdown>,
    /// KV pages this shard attached by content match instead of
    /// copying (DESIGN.md §11).
    attached_pages: usize,
    /// Copy-on-write tail copies this shard's cache traffic triggered.
    cow_copies: usize,
    /// Modeled cycles a resumed prefill avoided vs. the cold run.
    saved_cycles: u64,
}

impl ShardExec {
    /// A shard that produced `output` for `cycles` modeled cycles and
    /// touched no cache state.
    fn modeled(cycles: u64, cache: CacheOutcome, output: Result<ShardOut, String>) -> ShardExec {
        ShardExec {
            cycles,
            cache,
            output,
            measured: false,
            breakdown: None,
            attached_pages: 0,
            cow_copies: 0,
            saved_cycles: 0,
        }
    }
}

/// Execute one shard on this device: numerics + device-cycle pricing +
/// KV-cache bookkeeping.  The breakdown is `Some` only when the
/// backend measured the cycles on the machine (its `total()` equals
/// `cycles`, including the decode-miss recompute charge).
///
/// Pricing (DESIGN.md §8): backends that *measure* device time (the
/// cycle-accurate sim) report it via [`Backend::take_measured`], and
/// those cycles replace the perfmodel's prediction — `measured = true`
/// marks the shard so the gathered response can report how much of its
/// cost was measured rather than modeled.  On a decode cache miss the
/// modeled recompute charge (the upstream model re-running its forward
/// pass, which no backend executes) is added on top of the measured
/// step.
///
/// Sequence-sharded shards (`shard.is_partial()`, DESIGN.md §7)
/// execute only their `kv_range` chunk and emit [`ShardOut::Partial`];
/// their cache unit is the `(session, kv_head, chunk)` stream, keyed in
/// this device's [`KvCache`] as `kv_head * seq_shards + chunk` (one
/// device never legitimately holds two chunks under one key — and if
/// routing ever colocates them, distinct keys keep the streams apart).
#[allow(clippy::too_many_arguments)]
fn execute_shard(
    id: usize,
    cfg: &AccelConfig,
    backend: Option<&mut Backend>,
    cache: &mut KvCache,
    sessions: &SessionTable,
    metrics: &Metrics,
    env: &ShardEnvelope,
    seq_shards: usize,
    tracer: &Tracer,
) -> ShardExec {
    let shard = &env.shard;
    let req = &shard.req;
    let (start, len) = shard.kv_range;
    // The KvCache stream id of this (kv_head, chunk) pair; equals
    // kv_head on the legacy path (chunk 0, seq_shards 1).
    let stream = shard.kv_head * seq_shards + shard.chunk;
    // A cached stream is live only while its session incarnation is:
    // closed sessions and stale epochs (reused ids) both read as dead
    // and become reapable capacity.
    let live = |sid: u64, epoch: u64| sessions.epoch(sid) == Some(epoch);

    match env.ctx {
        ShardCtx::Stateless | ShardCtx::Prefill { .. } => {
            // Per-head device timing: the head runs on one array, seq
            // padded up to the array dim, head dim capped by it (§8.3);
            // the mask prices only the tiles the skipping schedule
            // issues (≈2x fewer for causal, DESIGN.md §6), and a
            // sequence chunk prices only its own key range (§7).
            let seq = req.seq_len.max(cfg.array_size);
            let d = req.d.min(cfg.array_size);
            let cold = if shard.is_partial() {
                fsa_flash_chunk_perf(
                    cfg, seq, d, start, len.max(1), Variant::DualPath, cfg.pwl_segments, req.mask,
                )
            } else {
                fsa_flash_perf_masked(cfg, seq, d, Variant::DualPath, cfg.pwl_segments, req.mask)
            };
            // A resumed (prefix-cache warm) prefill runs only the
            // uncovered suffix query rows [resumed_from, seq_len); the
            // covered rows' cycles are the saved-prefill term
            // (DESIGN.md §11).  The saving is always model-vs-model so
            // it stays meaningful when the backend measures.
            let resumed = req.resumed_from;
            let (perf, saved_cycles) = if resumed > 0 && resumed < req.seq_len {
                let (ks, kl) = if shard.is_partial() { (start, len.max(1)) } else { (0, seq) };
                let warm = fsa_flash_resumed_perf(
                    cfg, seq, d, resumed, ks, kl, Variant::DualPath, cfg.pwl_segments, req.mask,
                );
                (warm, cold.total_cycles.saturating_sub(warm.total_cycles))
            } else {
                (cold, 0)
            };
            let (k, v) = req.head_kv(shard.kv_head);
            let (k_chunk, v_chunk) =
                (&k[start * req.d..(start + len) * req.d], &v[start * req.d..(start + len) * req.d]);
            let mut measured = None;
            let mut breakdown = None;
            let output = match backend {
                None => Err("device backend unavailable".to_string()),
                Some(be) => {
                    let out = if resumed > 0 && resumed < req.seq_len {
                        let q_suffix = &req.head_q(shard.head)[resumed * req.d..];
                        let plan = ShardPlan::ResumedPrefill {
                            seq_len: req.seq_len,
                            d: req.d,
                            query_offset: resumed,
                            q_suffix,
                            k_chunk,
                            v_chunk,
                            mask: req.mask,
                            key_offset: start,
                            total_keys: req.seq_len,
                        };
                        if shard.is_partial() {
                            be.execute(plan).and_then(|o| o.into_partial()).map(ShardOut::Partial)
                        } else {
                            be.execute(plan).and_then(|o| o.into_full()).map(ShardOut::Full)
                        }
                    } else if shard.is_partial() {
                        be.execute(ShardPlan::HeadChunk {
                            seq_len: req.seq_len,
                            d: req.d,
                            q: req.head_q(shard.head),
                            k_chunk,
                            v_chunk,
                            mask: req.mask,
                            key_offset: start,
                            total_keys: req.seq_len,
                        })
                        .and_then(|o| o.into_partial())
                        .map(ShardOut::Partial)
                    } else {
                        be.execute(ShardPlan::Head {
                            seq_len: req.seq_len,
                            d: req.d,
                            q: req.head_q(shard.head),
                            k,
                            v,
                            mask: req.mask,
                        })
                        .and_then(|o| o.into_full())
                        .map(ShardOut::Full)
                    };
                    measured = be.take_measured();
                    breakdown = be.take_measured_breakdown();
                    out
                }
            };
            let mut attached_pages = 0;
            if let ShardCtx::Prefill { session, epoch } = env.ctx {
                // Land this chunk of the KV group's prefix in the page
                // cache once — skipped only when a groupmate of THIS
                // prefill (same epoch) already inserted it; a
                // same-length leftover from a closed predecessor
                // session (reused id, stale epoch) is replaced, never
                // trusted.  The insert carries the FULL chunk (the
                // request ships its K/V even when resumed); pages whose
                // content is already resident attach by refcount
                // instead of copying (DESIGN.md §11).
                if output.is_ok() && cache.cached_state(session, stream) != Some((len, epoch)) {
                    if let Admit::Cached { evicted, attached_pages: attached } =
                        cache.insert(session, stream, epoch, req.d, k_chunk, v_chunk, &live)
                    {
                        report_evictions(id, sessions, metrics, seq_shards, tracer, &evicted);
                        attached_pages = attached;
                        if attached > 0 {
                            tracer.record(
                                EventKind::PrefixAttach,
                                req.id,
                                session,
                                shard.kv_head as u32,
                                shard.chunk as u32,
                                id as u32,
                                attached as u64,
                            );
                        }
                    }
                }
            }
            ShardExec {
                cycles: measured.unwrap_or(perf.total_cycles),
                cache: CacheOutcome::NotApplicable,
                output,
                measured: measured.is_some(),
                breakdown,
                attached_pages,
                cow_copies: 0,
                saved_cycles,
            }
        }
        ShardCtx::Decode { session, prefix_len, epoch } => {
            // The request carries this step's appended K/V row; the
            // chunk's range lives in pages (hit) or the host tier
            // (miss).  Only streams of this session incarnation
            // (epoch) count — a stale same-id stream reads as a miss
            // and is replaced.  A chunk whose range ends at the grown
            // prefix owns this step's appended row (last-chunk-grows);
            // fixed-boundary chunks just stream their pages.
            let (k_row, v_row) = req.head_kv(shard.kv_head);
            let growing = start + len == prefix_len;
            let cached = cache.cached_state(session, stream);
            let mut outcome = CacheOutcome::Miss;
            let mut attached_pages = 0usize;
            // Appends onto a shared (refcounted) tail copy it first —
            // copy-on-write, DESIGN.md §11; count this shard's copies
            // by the cache counter's delta.
            let cow_before = cache.stats.cow_copies;
            let mut data: Option<(Vec<f32>, Vec<f32>)> = None;
            if cached == Some((len, epoch)) {
                // Range already resident (fixed chunk, or a groupmate
                // shard already appended this step's row).
                outcome = CacheOutcome::Hit;
                data = cache.gather(session, stream);
            } else if growing && len >= 1 && cached == Some((len - 1, epoch)) {
                match cache.append(session, stream, k_row, v_row, &live) {
                    Admit::Cached { evicted, attached_pages: attached } => {
                        report_evictions(id, sessions, metrics, seq_shards, tracer, &evicted);
                        attached_pages += attached;
                        outcome = CacheOutcome::Hit;
                        data = cache.gather(session, stream);
                    }
                    Admit::Rejected => {
                        // Stream dropped (cache full, no eviction):
                        // explicit fallback to recompute below.
                        sessions.clear_placement(session, shard.kv_head, shard.chunk, id);
                    }
                }
            }
            let (k_full, v_full) = match data {
                Some(kv) => kv,
                None => {
                    // Miss: recompute from the authoritative host tier
                    // (models the upstream model re-running its forward
                    // pass over the range), then re-cache for the next
                    // steps.
                    outcome = CacheOutcome::Miss;
                    match sessions.clone_range(session, shard.kv_head, start, len, epoch) {
                        None => {
                            let perf = fsa_decode_perf(
                                cfg,
                                len.max(1),
                                req.d.min(cfg.array_size),
                                false,
                                Variant::DualPath,
                                cfg.pwl_segments,
                            );
                            return ShardExec::modeled(
                                perf.total_cycles,
                                CacheOutcome::Miss,
                                Err(format!(
                                    "session {session} closed or prefix unavailable \
                                     (kv head {}, chunk {} range [{start}, {}), \
                                     prefix {prefix_len})",
                                    shard.kv_head,
                                    shard.chunk,
                                    start + len
                                )),
                            );
                        }
                        Some((k, v)) => {
                            if let Admit::Cached { evicted, attached_pages: attached } =
                                cache.insert(session, stream, epoch, req.d, &k, &v, &live)
                            {
                                report_evictions(id, sessions, metrics, seq_shards, tracer, &evicted);
                                attached_pages += attached;
                            }
                            (k, v)
                        }
                    }
                }
            };
            let perf = fsa_decode_perf(
                cfg,
                len.max(1),
                req.d.min(cfg.array_size),
                outcome == CacheOutcome::Hit,
                Variant::DualPath,
                cfg.pwl_segments,
            );
            let mut measured = None;
            let mut breakdown = None;
            let output = match backend {
                None => Err("device backend unavailable".to_string()),
                Some(be) => {
                    let out = if shard.is_partial() {
                        be.execute(ShardPlan::DecodeRange {
                            range_len: len,
                            d: req.d,
                            q_row: req.head_q(shard.head),
                            k: &k_full,
                            v: &v_full,
                        })
                        .and_then(|o| o.into_partial())
                        .map(ShardOut::Partial)
                    } else {
                        be.execute(ShardPlan::DecodeRow {
                            prefix_len,
                            d: req.d,
                            q_row: req.head_q(shard.head),
                            k: &k_full,
                            v: &v_full,
                        })
                        .and_then(|o| o.into_full())
                        .map(ShardOut::Full)
                    };
                    measured = be.take_measured();
                    breakdown = be.take_measured_breakdown();
                    out
                }
            };
            // Measured cycles cover the attention pass; the miss-path
            // recompute (the upstream model's forward pass over the
            // prefix) is not executed by any backend and stays modeled.
            // The attribution charges it to its own class so the
            // breakdown keeps summing exactly to `cycles`.
            let cycles = measured
                .map(|m| m + perf.recompute_cycles)
                .unwrap_or(perf.total_cycles);
            if let Some(bd) = &mut breakdown {
                bd.recompute += perf.recompute_cycles;
            }
            let cow_copies = (cache.stats.cow_copies - cow_before) as usize;
            if cow_copies > 0 {
                tracer.record(
                    EventKind::CowCopy,
                    req.id,
                    session,
                    shard.kv_head as u32,
                    shard.chunk as u32,
                    id as u32,
                    cow_copies as u64,
                );
            }
            if attached_pages > 0 {
                // A miss-path re-insert can re-attach still-resident
                // shared pages instead of copying them back.
                tracer.record(
                    EventKind::PrefixAttach,
                    req.id,
                    session,
                    shard.kv_head as u32,
                    shard.chunk as u32,
                    id as u32,
                    attached_pages as u64,
                );
            }
            ShardExec {
                cycles,
                cache: outcome,
                output,
                measured: measured.is_some(),
                breakdown,
                attached_pages,
                cow_copies,
                saved_cycles: 0,
            }
        }
    }
}

/// A stream was evicted from this device's cache: clear its sticky pin
/// (if it still points here) so the router re-places the next step, and
/// count it.  Cache keys carry the chunk folded into the stream id
/// (`kv_head * seq_shards + chunk`); decompose before clearing.
fn report_evictions(
    id: usize,
    sessions: &SessionTable,
    metrics: &Metrics,
    seq_shards: usize,
    tracer: &Tracer,
    evicted: &[(u64, usize)],
) {
    for &(sid, stream) in evicted {
        sessions.clear_placement(sid, stream / seq_shards, stream % seq_shards, id);
        metrics.kv_evictions.fetch_add(1, Ordering::Relaxed);
        tracer.record(
            EventKind::KvEvict,
            0,
            sid,
            (stream / seq_shards) as u32,
            (stream % seq_shards) as u32,
            id as u32,
            sid,
        );
    }
}
