//! Device worker: one thread owning a numerics [`Backend`] (PJRT
//! artifacts or the in-crate reference twin) and the FSA performance
//! model (simulated device timing).
//!
//! Each worker is a simulated FSA card.  The unit of work is one *head
//! shard* (see [`super::shard`]): numerics execute through the backend
//! (the `fsa_attn` AOT artifact — the numerics twin of the silicon,
//! see DESIGN.md §3 — or the `flash_pwl` reference), while
//! latency/throughput are accounted in device cycles from
//! [`crate::perfmodel`] at the paper's 1.5 GHz clock.  The worker that
//! finishes a request's final shard assembles and sends the gathered
//! whole-operator response.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;

use crate::config::{AccelConfig, BackendKind};
use crate::perfmodel::fsa_flash_perf;
use crate::runtime::Backend;
use crate::schedule::Variant;

use super::metrics::Metrics;
use super::router::{Batch, WorkerHandle};
use super::shard::ShardResult;

pub struct DeviceWorker {
    handle: WorkerHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl DeviceWorker {
    /// Spawn the worker thread.  The backend is created inside the
    /// thread (the PJRT client is not Send) — startup errors surface on
    /// first use via error responses.
    pub fn spawn(
        id: usize,
        artifacts: PathBuf,
        backend: BackendKind,
        metrics: Arc<Metrics>,
    ) -> crate::Result<DeviceWorker> {
        let (tx, rx) = mpsc::channel::<Batch>();
        let load = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let handle = WorkerHandle { id, queue: tx, load: load.clone() };
        let thread = std::thread::Builder::new()
            .name(format!("fsa-device-{id}"))
            .spawn(move || worker_loop(id, artifacts, backend, rx, load, metrics))?;
        Ok(DeviceWorker { handle, thread: Some(thread) })
    }

    pub fn handle(&self) -> WorkerHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        // Dropping our queue clone isn't enough (router holds clones);
        // the batcher going away drops those, and the loop exits.
        drop(self.handle);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn worker_loop(
    id: usize,
    artifacts: PathBuf,
    backend_kind: BackendKind,
    rx: mpsc::Receiver<Batch>,
    load: Arc<std::sync::atomic::AtomicUsize>,
    metrics: Arc<Metrics>,
) {
    let cfg = AccelConfig::builtin("fsa").expect("builtin fsa config");
    let mut backend = match Backend::new(backend_kind, &artifacts, &cfg) {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("device {id}: backend init failed: {e:#}");
            None
        }
    };

    while let Ok(batch) = rx.recv() {
        let n = batch.len();
        for env in batch {
            let shard = &env.shard;
            let req = &shard.req;
            // Per-head device timing: the head runs on one array, seq
            // padded up to the array dim, head dim capped by it (§8.3).
            let perf = fsa_flash_perf(
                &cfg,
                req.seq_len.max(cfg.array_size),
                req.d.min(cfg.array_size),
                Variant::DualPath,
                cfg.pwl_segments,
            );
            let (k, v) = req.head_kv(shard.kv_head);
            let output = match backend.as_mut() {
                None => Err("device backend unavailable".to_string()),
                Some(be) => be.execute_head(req.seq_len, req.d, shard.req.head_q(shard.head), k, v),
            };
            metrics.record_shard(perf.total_cycles);
            let resp = env.gather.complete_and_report(
                ShardResult {
                    head: shard.head,
                    device_id: id,
                    cycles: perf.total_cycles,
                    output,
                },
                &cfg,
            );
            if let Some(resp) = resp {
                metrics.record(&resp, resp.output.is_ok());
                env.gather.send(resp);
            }
        }
        load.fetch_sub(n, Ordering::Relaxed);
    }
}
