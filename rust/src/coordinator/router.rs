//! Shard router over the device worker pool: least-loaded placement
//! with KV-head affinity, sticky for sessions.
//!
//! The routing unit is the per-`(head, chunk)` [`ShardEnvelope`].
//! Within one dispatched batch, shards are partitioned by their
//! affinity key `(request, kv_head, chunk)` — query heads that share a
//! KV head *and* attend the same sequence chunk travel together so a
//! device fetches each chunk's K/V once — and every partition
//! independently goes to the least-loaded worker (round-robin among
//! ties).  A multi-head request therefore fans out across the pool
//! (scatter) while each KV group stays device-local; sequence-sharded
//! requests additionally scatter their chunks, which is what lifts the
//! `num_kv_heads` device ceiling (DESIGN.md §7).
//!
//! Session groups (prefill/decode, DESIGN.md §5) add stickiness on
//! top: the first placement of a `(session, kv_head, chunk)` group is
//! pinned in the [`SessionTable`] and every later decode step follows
//! the pin to the device holding the cached pages.  The pin is dropped
//! when that device evicts the stream (the worker clears it) or dies
//! (the router invalidates every pin onto the dead device — its pages
//! are gone, so the surviving device recomputes and re-caches).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use super::session::{SessionId, SessionTable};
use super::shard::{ShardCtx, ShardEnvelope};
use super::trace::{EventKind, Tracer, NO_SESSION};

/// A batch of shards handed to one device worker.
pub type Batch = Vec<ShardEnvelope>;

/// Cloneable handle to one worker's queue + load gauge.
#[derive(Clone)]
pub struct WorkerHandle {
    pub id: usize,
    pub queue: mpsc::Sender<Batch>,
    /// Outstanding shards (not batches) on this worker.
    pub load: Arc<AtomicUsize>,
}

pub struct Router {
    workers: Vec<WorkerHandle>,
    /// Round-robin tiebreaker so equal-load workers share traffic.
    rr: AtomicUsize,
    sessions: Arc<SessionTable>,
    /// Request-path event sink (DESIGN.md §9); disabled by default.
    tracer: Arc<Tracer>,
}

impl Router {
    pub fn new(workers: Vec<WorkerHandle>, sessions: Arc<SessionTable>) -> Router {
        assert!(!workers.is_empty());
        Router { workers, rr: AtomicUsize::new(0), sessions, tracer: Tracer::off() }
    }

    /// Attach a request-path tracer (the coordinator threads its own;
    /// directly constructed routers keep the disabled default).
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> Router {
        self.tracer = tracer;
        self
    }

    /// Scatter a batch: partition by KV affinity, then send each
    /// partition to its pinned device (session groups) or the
    /// least-loaded worker.  Order within a partition is preserved.
    pub fn dispatch(&self, batch: Batch) {
        if batch.is_empty() {
            return;
        }
        for group in partition_by_affinity(batch) {
            self.dispatch_group(group);
        }
    }

    /// Route one affinity group: follow the session pin when present
    /// and alive, otherwise pick the least-loaded worker (round-robin
    /// among ties) and record the pin for session groups.  Shards for
    /// a dead worker are bounced to the next-best one (its session
    /// pins are invalidated — the pages died with it); if all workers
    /// are gone the shards' gather cells drop, which callers observe
    /// as a disconnected response channel.
    fn dispatch_group(&self, group: Batch) {
        let skey = session_key(&group);
        let mut group = group;
        if let Some((sid, kv_head, chunk)) = skey {
            if let Some(dev) = self.sessions.placement(sid, kv_head, chunk) {
                match self.workers.iter().find(|w| w.id == dev) {
                    Some(w) => {
                        w.load.fetch_add(group.len(), Ordering::Relaxed);
                        let meta = self.dispatch_meta(&group);
                        match w.queue.send(group) {
                            Ok(()) => {
                                self.record_dispatches(meta, w);
                                return;
                            }
                            Err(mpsc::SendError(g)) => {
                                // Dead worker: its cached pages are
                                // unreachable — drop every pin onto it.
                                w.load.fetch_sub(g.len(), Ordering::Relaxed);
                                self.sessions.invalidate_device(dev);
                                group = g;
                            }
                        }
                    }
                    None => self.sessions.invalidate_device(dev),
                }
            }
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        let mut order: Vec<usize> = (0..self.workers.len()).collect();
        order.sort_by_key(|&i| {
            (
                self.workers[i].load.load(Ordering::Relaxed),
                (i + self.workers.len() - start % self.workers.len()) % self.workers.len(),
            )
        });
        for &i in &order {
            let w = &self.workers[i];
            w.load.fetch_add(group.len(), Ordering::Relaxed);
            let meta = self.dispatch_meta(&group);
            match w.queue.send(group) {
                Ok(()) => {
                    if let Some((sid, kv_head, chunk)) = skey {
                        self.sessions.place(sid, kv_head, chunk, w.id);
                    }
                    self.record_dispatches(meta, w);
                    return;
                }
                Err(mpsc::SendError(g)) => {
                    // Worker died: undo the gauge and try the next one.
                    w.load.fetch_sub(g.len(), Ordering::Relaxed);
                    group = g;
                }
            }
        }
        // All workers dead: drop the group (reply channels disconnect).
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Per-shard trace coordinates, captured *before* a send consumes
    /// the batch; `None` when tracing is off so the hot path allocates
    /// nothing.
    fn dispatch_meta(&self, group: &Batch) -> Option<Vec<(u64, u64, u32, u32)>> {
        if !self.tracer.enabled() {
            return None;
        }
        Some(
            group
                .iter()
                .map(|e| {
                    let session = match e.ctx {
                        ShardCtx::Prefill { session, .. }
                        | ShardCtx::Decode { session, .. } => session,
                        ShardCtx::Stateless => NO_SESSION,
                    };
                    (e.shard.req.id, session, e.shard.head as u32, e.shard.chunk as u32)
                })
                .collect(),
        )
    }

    /// Record one [`EventKind::Dispatch`] per placed shard (payload:
    /// the device's outstanding-shard gauge after the push).  Only
    /// called after a *successful* send — a bounced batch records
    /// nothing on the dead worker.
    fn record_dispatches(&self, meta: Option<Vec<(u64, u64, u32, u32)>>, w: &WorkerHandle) {
        let Some(meta) = meta else { return };
        let depth = w.load.load(Ordering::Relaxed) as u64;
        for (req, session, head, chunk) in meta {
            self.tracer
                .record(EventKind::Dispatch, req, session, head, chunk, w.id as u32, depth);
        }
    }
}

/// Sticky-placement key of a group: present for prefill/decode shards
/// (all shards of a group share one ctx, one kv_head, and one chunk by
/// construction).
fn session_key(group: &Batch) -> Option<(SessionId, usize, usize)> {
    group.first().and_then(|e| match e.ctx {
        ShardCtx::Prefill { session, .. } | ShardCtx::Decode { session, .. } => {
            Some((session, e.shard.kv_head, e.shard.chunk))
        }
        ShardCtx::Stateless => None,
    })
}

/// Split a batch into contiguous groups of equal affinity key,
/// preserving first-seen order (shards of one request arrive adjacent
/// from the scheduler, so this is a single pass, no map).
fn partition_by_affinity(batch: Batch) -> Vec<Batch> {
    let mut groups: Vec<((u64, usize, usize), Batch)> = Vec::new();
    for env in batch {
        let key = env.shard.affinity_key();
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, g)) => g.push(env),
            None => groups.push((key, vec![env])),
        }
    }
    groups.into_iter().map(|(_, g)| g).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AccelConfig;
    use crate::coordinator::request::{AttentionRequest, Envelope};
    use crate::coordinator::shard::{explode, CacheOutcome, ShardOut, ShardResult};

    fn table() -> Arc<SessionTable> {
        Arc::new(SessionTable::new())
    }

    /// Shards of a GQA request: `heads` query heads over `kv` KV heads.
    fn shards(id: u64, heads: usize, kv: usize) -> Vec<ShardEnvelope> {
        let (seq, d) = (2, 4);
        let q = vec![0.0f32; heads * seq * d];
        let m = vec![0.0f32; kv * seq * d];
        explode(
            Envelope {
                req: AttentionRequest::gqa(id, seq, d, heads, kv, q, m.clone(), m),
                reply: mpsc::channel().0,
                enqueued: std::time::Instant::now(),
            },
            1,
        )
    }

    fn handle(id: usize) -> (WorkerHandle, mpsc::Receiver<Batch>) {
        let (tx, rx) = mpsc::channel();
        (WorkerHandle { id, queue: tx, load: Arc::new(AtomicUsize::new(0)) }, rx)
    }

    #[test]
    fn prefers_least_loaded() {
        let (h0, rx0) = handle(0);
        let (h1, rx1) = handle(1);
        h0.load.store(10, Ordering::Relaxed);
        let r = Router::new(vec![h0, h1.clone()], table());
        r.dispatch(shards(1, 2, 2).into_iter().take(1).collect());
        assert_eq!(rx1.try_recv().unwrap().len(), 1);
        assert!(rx0.try_recv().is_err());
        assert_eq!(h1.load.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn gqa_heads_scatter_but_kv_groups_stay_together() {
        let (h0, rx0) = handle(0);
        let (h1, rx1) = handle(1);
        let r = Router::new(vec![h0.clone(), h1.clone()], table());
        // 8 query heads / 2 KV heads => two affinity groups of 4.
        r.dispatch(shards(9, 8, 2));
        let b0 = rx0.try_recv().expect("device 0 gets one KV group");
        let b1 = rx1.try_recv().expect("device 1 gets the other");
        assert_eq!(b0.len(), 4);
        assert_eq!(b1.len(), 4);
        // Each device's shards all share one kv_head, and the two
        // devices hold different KV heads.
        let kv0 = b0[0].shard.kv_head;
        let kv1 = b1[0].shard.kv_head;
        assert!(b0.iter().all(|s| s.shard.kv_head == kv0));
        assert!(b1.iter().all(|s| s.shard.kv_head == kv1));
        assert_ne!(kv0, kv1);
        assert_eq!(h0.load.load(Ordering::Relaxed), 4);
        assert_eq!(h1.load.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn fails_over_when_worker_dead() {
        let (h0, rx0) = handle(0);
        let (h1, rx1) = handle(1);
        drop(rx0); // worker 0 is gone
        let r = Router::new(vec![h0.clone(), h1], table());
        r.dispatch(shards(7, 1, 1));
        assert_eq!(rx1.try_recv().unwrap()[0].shard.req.id, 7);
        // Gauge on the dead worker was rolled back.
        assert_eq!(h0.load.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn all_dead_drops_batch_without_panic() {
        let (h0, rx0) = handle(0);
        drop(rx0);
        let r = Router::new(vec![h0], table());
        r.dispatch(shards(1, 1, 1));
    }

    #[test]
    fn session_groups_follow_the_pin() {
        let sessions = table();
        let (h0, rx0) = handle(0);
        let (h1, rx1) = handle(1);
        // Worker 1 is busier, but the session is pinned there.
        h1.load.store(10, Ordering::Relaxed);
        let r = Router::new(vec![h0, h1], sessions.clone());
        let d = 4;
        sessions
            .open(
                5,
                &AttentionRequest::prefill(
                    1, 5, 2, d, 2, 1,
                    vec![0.0; 2 * 2 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
                ),
                1,
            )
            .unwrap();
        sessions.place(5, 0, 0, 1);
        let mut req = AttentionRequest::decode(
            2, 5, 0, d, 2, 1, vec![0.0; 2 * d], vec![0.0; d], vec![0.0; d],
        );
        req.prefix_len = 3;
        let envs = explode(
            Envelope {
                req,
                reply: mpsc::channel().0,
                enqueued: std::time::Instant::now(),
            },
            1,
        );
        r.dispatch(envs);
        assert_eq!(rx1.try_recv().unwrap().len(), 2, "pin beats least-loaded");
        assert!(rx0.try_recv().is_err());
    }

    #[test]
    fn sequence_chunks_scatter_across_devices() {
        // One single-head request sharded 2 ways must land its chunks
        // on different (least-loaded) devices — sequence parallelism is
        // exactly this scatter.
        let (h0, rx0) = handle(0);
        let (h1, rx1) = handle(1);
        let r = Router::new(vec![h0, h1], table());
        let (seq, d) = (8, 4);
        let m = vec![0.0f32; seq * d];
        let envs = explode(
            Envelope {
                req: AttentionRequest::new(4, seq, d, m.clone(), m.clone(), m),
                reply: mpsc::channel().0,
                enqueued: std::time::Instant::now(),
            },
            2,
        );
        assert_eq!(envs.len(), 2);
        r.dispatch(envs);
        let b0 = rx0.try_recv().expect("chunk on device 0");
        let b1 = rx1.try_recv().expect("chunk on device 1");
        assert_ne!(b0[0].shard.chunk, b1[0].shard.chunk);
    }

    /// Satellite: dead-worker failover under GQA affinity.  A worker
    /// holding a pinned KV group dies mid-stream; the re-dispatched
    /// group must land whole on one surviving device, the dead
    /// device's pins must be invalidated, and the gathered response
    /// must complete exactly once.
    #[test]
    fn dead_worker_failover_lands_group_whole_and_completes_once() {
        let sessions = table();
        let (h0, rx0) = handle(0);
        let (h1, rx1) = handle(1);
        let (h2, rx2) = handle(2);
        let r = Router::new(vec![h0, h1, h2], sessions.clone());

        // Open a GQA session: 4 query heads over 2 KV heads; both KV
        // groups are pinned on worker 0 from a previous step.
        let d = 4;
        sessions
            .open(
                9,
                &AttentionRequest::prefill(
                    1, 9, 2, d, 4, 2,
                    vec![0.0; 4 * 2 * d], vec![0.0; 2 * 2 * d], vec![0.0; 2 * 2 * d],
                ),
                1,
            )
            .unwrap();
        sessions.place(9, 0, 0, 0);
        sessions.place(9, 1, 0, 0);

        // Worker 0 dies mid-stream.
        drop(rx0);

        let mut req = AttentionRequest::decode(
            2, 9, 0, d, 4, 2,
            vec![0.0; 4 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
        );
        req.prefix_len = 3;
        let (tx, resp_rx) = mpsc::channel();
        let envs = explode(
            Envelope { req, reply: tx, enqueued: std::time::Instant::now() },
            1,
        );
        r.dispatch(envs);

        // Each KV group was re-dispatched whole to one surviving device.
        let mut delivered = Vec::new();
        for rx in [&rx1, &rx2] {
            while let Ok(batch) = rx.try_recv() {
                let kv = batch[0].shard.kv_head;
                assert!(
                    batch.iter().all(|s| s.shard.kv_head == kv),
                    "KV group split across devices"
                );
                assert_eq!(batch.len(), 2, "whole group of 2 query heads");
                delivered.push(batch);
            }
        }
        assert_eq!(delivered.len(), 2, "both KV groups re-dispatched");
        // Pins moved off the dead device onto live ones.
        for kv in 0..2 {
            let pin = sessions.placement(9, kv, 0).expect("re-pinned");
            assert_ne!(pin, 0, "pin must leave the dead device");
        }

        // Complete every shard; the gathered response arrives exactly once.
        let cfg = AccelConfig::builtin("fsa").unwrap();
        for batch in delivered {
            for env in batch {
                let head = env.shard.head;
                env.gather.complete(
                    ShardResult {
                        head,
                        chunk_pos: 0,
                        device_id: 1,
                        cycles: 10,
                        measured: false,
                        output: Ok(ShardOut::Full(vec![0.0; d])),
                        cache: CacheOutcome::Hit,
                        breakdown: None,
                    },
                    &cfg,
                );
            }
        }
        let resp = resp_rx.try_recv().expect("gather completes");
        assert_eq!(resp.shards, 4);
        assert_eq!(resp.stats.kv_hits, 4);
        assert!(resp_rx.try_recv().is_err(), "answered exactly once");
    }
}
