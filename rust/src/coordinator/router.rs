//! Shard router over the device worker pool: least-loaded placement
//! with KV-head affinity.
//!
//! The routing unit is the per-head [`ShardEnvelope`].  Within one
//! dispatched batch, shards are partitioned by their GQA affinity key
//! `(request, kv_head)` — query heads that share a KV head travel
//! together so a device fetches each K/V pair once — and every
//! partition independently goes to the least-loaded worker
//! (round-robin among ties).  A multi-head request therefore fans out
//! across the pool (scatter) while each KV group stays device-local.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use super::shard::ShardEnvelope;

/// A batch of shards handed to one device worker.
pub type Batch = Vec<ShardEnvelope>;

/// Cloneable handle to one worker's queue + load gauge.
#[derive(Clone)]
pub struct WorkerHandle {
    pub id: usize,
    pub queue: mpsc::Sender<Batch>,
    /// Outstanding shards (not batches) on this worker.
    pub load: Arc<AtomicUsize>,
}

pub struct Router {
    workers: Vec<WorkerHandle>,
    /// Round-robin tiebreaker so equal-load workers share traffic.
    rr: AtomicUsize,
}

impl Router {
    pub fn new(workers: Vec<WorkerHandle>) -> Router {
        assert!(!workers.is_empty());
        Router { workers, rr: AtomicUsize::new(0) }
    }

    /// Scatter a batch: partition by KV affinity, then send each
    /// partition to the least-loaded worker.  Order within a partition
    /// is preserved.
    pub fn dispatch(&self, batch: Batch) {
        if batch.is_empty() {
            return;
        }
        for group in partition_by_affinity(batch) {
            self.dispatch_group(group);
        }
    }

    /// Pick the least-loaded worker (round-robin among ties) and
    /// enqueue one affinity group.  Shards for a dead worker are
    /// bounced to the next-best one; if all workers are gone the
    /// shards' gather cells drop, which callers observe as a
    /// disconnected response channel.
    fn dispatch_group(&self, group: Batch) {
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        let mut order: Vec<usize> = (0..self.workers.len()).collect();
        order.sort_by_key(|&i| {
            (
                self.workers[i].load.load(Ordering::Relaxed),
                (i + self.workers.len() - start % self.workers.len()) % self.workers.len(),
            )
        });
        let mut group = group;
        for &i in &order {
            let w = &self.workers[i];
            w.load.fetch_add(group.len(), Ordering::Relaxed);
            match w.queue.send(group) {
                Ok(()) => return,
                Err(mpsc::SendError(g)) => {
                    // Worker died: undo the gauge and try the next one.
                    w.load.fetch_sub(g.len(), Ordering::Relaxed);
                    group = g;
                }
            }
        }
        // All workers dead: drop the group (reply channels disconnect).
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

/// Split a batch into contiguous groups of equal affinity key,
/// preserving first-seen order (shards of one request arrive adjacent
/// from the batcher, so this is a single pass, no map).
fn partition_by_affinity(batch: Batch) -> Vec<Batch> {
    let mut groups: Vec<((u64, usize), Batch)> = Vec::new();
    for env in batch {
        let key = env.shard.affinity_key();
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, g)) => g.push(env),
            None => groups.push((key, vec![env])),
        }
    }
    groups.into_iter().map(|(_, g)| g).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{AttentionRequest, Envelope};
    use crate::coordinator::shard::explode;

    /// Shards of a GQA request: `heads` query heads over `kv` KV heads.
    fn shards(id: u64, heads: usize, kv: usize) -> Vec<ShardEnvelope> {
        let (seq, d) = (2, 4);
        let q = vec![0.0f32; heads * seq * d];
        let m = vec![0.0f32; kv * seq * d];
        explode(Envelope {
            req: AttentionRequest::gqa(id, seq, d, heads, kv, q, m.clone(), m),
            reply: mpsc::channel().0,
            enqueued: std::time::Instant::now(),
        })
    }

    fn handle(id: usize) -> (WorkerHandle, mpsc::Receiver<Batch>) {
        let (tx, rx) = mpsc::channel();
        (WorkerHandle { id, queue: tx, load: Arc::new(AtomicUsize::new(0)) }, rx)
    }

    #[test]
    fn prefers_least_loaded() {
        let (h0, rx0) = handle(0);
        let (h1, rx1) = handle(1);
        h0.load.store(10, Ordering::Relaxed);
        let r = Router::new(vec![h0, h1.clone()]);
        r.dispatch(shards(1, 2, 2).into_iter().take(1).collect());
        assert_eq!(rx1.try_recv().unwrap().len(), 1);
        assert!(rx0.try_recv().is_err());
        assert_eq!(h1.load.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn gqa_heads_scatter_but_kv_groups_stay_together() {
        let (h0, rx0) = handle(0);
        let (h1, rx1) = handle(1);
        let r = Router::new(vec![h0.clone(), h1.clone()]);
        // 8 query heads / 2 KV heads => two affinity groups of 4.
        r.dispatch(shards(9, 8, 2));
        let b0 = rx0.try_recv().expect("device 0 gets one KV group");
        let b1 = rx1.try_recv().expect("device 1 gets the other");
        assert_eq!(b0.len(), 4);
        assert_eq!(b1.len(), 4);
        // Each device's shards all share one kv_head, and the two
        // devices hold different KV heads.
        let kv0 = b0[0].shard.kv_head;
        let kv1 = b1[0].shard.kv_head;
        assert!(b0.iter().all(|s| s.shard.kv_head == kv0));
        assert!(b1.iter().all(|s| s.shard.kv_head == kv1));
        assert_ne!(kv0, kv1);
        assert_eq!(h0.load.load(Ordering::Relaxed), 4);
        assert_eq!(h1.load.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn fails_over_when_worker_dead() {
        let (h0, rx0) = handle(0);
        let (h1, rx1) = handle(1);
        drop(rx0); // worker 0 is gone
        let r = Router::new(vec![h0.clone(), h1]);
        r.dispatch(shards(7, 1, 1));
        assert_eq!(rx1.try_recv().unwrap()[0].shard.req.id, 7);
        // Gauge on the dead worker was rolled back.
        assert_eq!(h0.load.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn all_dead_drops_batch_without_panic() {
        let (h0, rx0) = handle(0);
        drop(rx0);
        let r = Router::new(vec![h0]);
        r.dispatch(shards(1, 1, 1));
    }
}
