//! Least-loaded router over the device worker pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use super::request::Envelope;

/// A batch handed to one device worker.
pub type Batch = Vec<Envelope>;

/// Cloneable handle to one worker's queue + load gauge.
#[derive(Clone)]
pub struct WorkerHandle {
    pub id: usize,
    pub queue: mpsc::Sender<Batch>,
    /// Outstanding requests (not batches) on this worker.
    pub load: Arc<AtomicUsize>,
}

pub struct Router {
    workers: Vec<WorkerHandle>,
    /// Round-robin tiebreaker so equal-load workers share traffic.
    rr: AtomicUsize,
}

impl Router {
    pub fn new(workers: Vec<WorkerHandle>) -> Router {
        assert!(!workers.is_empty());
        Router { workers, rr: AtomicUsize::new(0) }
    }

    /// Pick the least-loaded worker (round-robin among ties) and enqueue.
    /// Requests on a dead worker are bounced to the next-best one; if all
    /// workers are gone the batch's reply channels drop, which callers
    /// observe as a disconnected response channel.
    pub fn dispatch(&self, batch: Batch) {
        if batch.is_empty() {
            return;
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        let mut order: Vec<usize> = (0..self.workers.len()).collect();
        order.sort_by_key(|&i| {
            (self.workers[i].load.load(Ordering::Relaxed), (i + self.workers.len() - start % self.workers.len()) % self.workers.len())
        });
        let mut batch = batch;
        for &i in &order {
            let w = &self.workers[i];
            w.load.fetch_add(batch.len(), Ordering::Relaxed);
            match w.queue.send(batch) {
                Ok(()) => return,
                Err(mpsc::SendError(b)) => {
                    // Worker died: undo the gauge and try the next one.
                    w.load.fetch_sub(b.len(), Ordering::Relaxed);
                    batch = b;
                }
            }
        }
        // All workers dead: drop the batch (reply channels disconnect).
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::AttentionRequest;

    fn env(id: u64) -> Envelope {
        let m = vec![0.0f32; 8];
        Envelope {
            req: AttentionRequest::new(id, 2, 4, m.clone(), m.clone(), m),
            reply: mpsc::channel().0,
            enqueued: std::time::Instant::now(),
        }
    }

    fn handle(id: usize) -> (WorkerHandle, mpsc::Receiver<Batch>) {
        let (tx, rx) = mpsc::channel();
        (WorkerHandle { id, queue: tx, load: Arc::new(AtomicUsize::new(0)) }, rx)
    }

    #[test]
    fn prefers_least_loaded() {
        let (h0, rx0) = handle(0);
        let (h1, rx1) = handle(1);
        h0.load.store(10, Ordering::Relaxed);
        let r = Router::new(vec![h0, h1.clone()]);
        r.dispatch(vec![env(1), env(2)]);
        assert_eq!(rx1.try_recv().unwrap().len(), 2);
        assert!(rx0.try_recv().is_err());
        assert_eq!(h1.load.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn fails_over_when_worker_dead() {
        let (h0, rx0) = handle(0);
        let (h1, rx1) = handle(1);
        drop(rx0); // worker 0 is gone
        let r = Router::new(vec![h0.clone(), h1]);
        r.dispatch(vec![env(7)]);
        assert_eq!(rx1.try_recv().unwrap()[0].req.id, 7);
        // Gauge on the dead worker was rolled back.
        assert_eq!(h0.load.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn all_dead_drops_batch_without_panic() {
        let (h0, rx0) = handle(0);
        drop(rx0);
        let r = Router::new(vec![h0]);
        r.dispatch(vec![env(1)]);
    }
}
