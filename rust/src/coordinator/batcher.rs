//! Shard batcher: explodes ingress requests into per-head shards and
//! groups compatible shards so a device runs one compiled executable
//! per batch (amortizing PJRT dispatch), bounded by `max_batch` and a
//! timeout so short queues still make progress.
//!
//! A multi-head request enters as one [`Envelope`] and leaves as
//! `num_heads` [`ShardEnvelope`]s; shards of *different* requests with
//! the same `(seq_len, d)` shape share batches, so head-sharding and
//! cross-request batching compose.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use super::metrics::Metrics;
use super::request::Envelope;
use super::router::Router;
use super::shard::{explode, ShardEnvelope};

pub struct Batcher {
    max_batch: usize,
    /// Timeout expressed in simulated device cycles in the config; the
    /// batcher converts at the FSA clock (1.5 GHz) to a host duration.
    timeout: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, timeout_cycles: u64) -> Batcher {
        Batcher {
            max_batch: max_batch.max(1),
            timeout: Duration::from_nanos((timeout_cycles as f64 / 1.5) as u64),
        }
    }

    /// Main loop: drain the ingress channel, explode each request into
    /// head shards, group shards by `(seq_len, d)`, and dispatch a
    /// group when it reaches `max_batch` shards or its oldest member
    /// exceeds the timeout.  Exits when the ingress disconnects.
    pub fn run(&self, rx: mpsc::Receiver<Envelope>, router: Router, metrics: Arc<Metrics>) {
        // (seq_len, d) -> pending shards.
        let mut groups: Vec<((usize, usize), Vec<ShardEnvelope>)> = Vec::new();
        let admit = |env: Envelope, groups: &mut Vec<((usize, usize), Vec<ShardEnvelope>)>| {
            let key = (env.req.seq_len, env.req.d);
            let shards = explode(env);
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, g)) => g.extend(shards),
                None => groups.push((key, shards)),
            }
        };
        loop {
            // Block briefly so timeouts fire even when idle.
            let first = rx.recv_timeout(self.timeout.min(Duration::from_millis(5)));
            match first {
                Ok(env) => {
                    admit(env, &mut groups);
                    // Opportunistically drain whatever else is queued.
                    while let Ok(env) = rx.try_recv() {
                        admit(env, &mut groups);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Flush everything and exit.
                    for (_, g) in groups.drain(..) {
                        for chunk in Self::chunks(g, self.max_batch) {
                            metrics.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            router.dispatch(chunk);
                        }
                    }
                    return;
                }
            }

            // Dispatch full groups and timed-out groups.
            let now = std::time::Instant::now();
            let mut i = 0;
            while i < groups.len() {
                let ready = groups[i].1.len() >= self.max_batch
                    || groups[i]
                        .1
                        .first()
                        .map(|e| now.duration_since(e.enqueued) >= self.timeout)
                        .unwrap_or(false);
                if ready {
                    let (_, g) = groups.swap_remove(i);
                    for chunk in Self::chunks(g, self.max_batch) {
                        metrics.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        router.dispatch(chunk);
                    }
                } else {
                    i += 1;
                }
            }
        }
    }

    fn chunks(mut g: Vec<ShardEnvelope>, max: usize) -> Vec<Vec<ShardEnvelope>> {
        let mut out = Vec::new();
        while g.len() > max {
            let rest = g.split_off(max);
            out.push(g);
            g = rest;
        }
        if !g.is_empty() {
            out.push(g);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::AttentionRequest;

    fn envs(n: u64, seq: usize) -> Vec<ShardEnvelope> {
        let d = 4;
        (0..n)
            .flat_map(|id| {
                let m = vec![0.0f32; seq * d];
                explode(Envelope {
                    req: AttentionRequest::new(id, seq, d, m.clone(), m.clone(), m),
                    reply: mpsc::channel().0,
                    enqueued: std::time::Instant::now(),
                })
            })
            .collect()
    }

    #[test]
    fn chunking_respects_max_batch() {
        let g = envs(10, 8);
        let chunks = Batcher::chunks(g, 4);
        let sizes: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
        // No shard lost or duplicated.
        let mut ids: Vec<u64> = chunks.iter().flatten().map(|e| e.shard.req.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn empty_group_produces_no_chunks() {
        assert!(Batcher::chunks(vec![], 4).is_empty());
    }

    #[test]
    fn multi_head_request_contributes_one_shard_per_head() {
        let (seq, d, heads) = (8, 4, 4);
        let q = vec![0.0f32; heads * seq * d];
        let kv = vec![0.0f32; seq * d];
        let shards = explode(Envelope {
            req: AttentionRequest::gqa(1, seq, d, heads, 1, q, kv.clone(), kv),
            reply: mpsc::channel().0,
            enqueued: std::time::Instant::now(),
        });
        // One 4-head request + batch limit 3 => chunks of 3 + 1.
        let sizes: Vec<usize> =
            Batcher::chunks(shards, 3).iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![3, 1]);
    }
}
