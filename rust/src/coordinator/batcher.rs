//! Sequence-length batcher: groups compatible requests so a device runs
//! one compiled executable per batch (amortizing PJRT dispatch), bounded
//! by `max_batch` and a timeout so short queues still make progress.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use super::metrics::Metrics;
use super::request::Envelope;
use super::router::Router;

pub struct Batcher {
    max_batch: usize,
    /// Timeout expressed in simulated device cycles in the config; the
    /// batcher converts at the FSA clock (1.5 GHz) to a host duration.
    timeout: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, timeout_cycles: u64) -> Batcher {
        Batcher {
            max_batch: max_batch.max(1),
            timeout: Duration::from_nanos((timeout_cycles as f64 / 1.5) as u64),
        }
    }

    /// Main loop: drain the ingress channel into per-seq-length groups,
    /// dispatch a group when it reaches `max_batch` or its oldest member
    /// exceeds the timeout.  Exits when the ingress disconnects.
    pub fn run(&self, rx: mpsc::Receiver<Envelope>, router: Router, metrics: Arc<Metrics>) {
        // (seq_len, d) -> pending envelopes.
        let mut groups: Vec<((usize, usize), Vec<Envelope>)> = Vec::new();
        loop {
            // Block briefly so timeouts fire even when idle.
            let first = rx.recv_timeout(self.timeout.min(Duration::from_millis(5)));
            match first {
                Ok(env) => {
                    let key = (env.req.seq_len, env.req.d);
                    match groups.iter_mut().find(|(k, _)| *k == key) {
                        Some((_, g)) => g.push(env),
                        None => groups.push((key, vec![env])),
                    }
                    // Opportunistically drain whatever else is queued.
                    while let Ok(env) = rx.try_recv() {
                        let key = (env.req.seq_len, env.req.d);
                        match groups.iter_mut().find(|(k, _)| *k == key) {
                            Some((_, g)) => g.push(env),
                            None => groups.push((key, vec![env])),
                        }
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Flush everything and exit.
                    for (_, g) in groups.drain(..) {
                        for chunk in Self::chunks(g, self.max_batch) {
                            metrics.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            router.dispatch(chunk);
                        }
                    }
                    return;
                }
            }

            // Dispatch full groups and timed-out groups.
            let now = std::time::Instant::now();
            let mut i = 0;
            while i < groups.len() {
                let ready = groups[i].1.len() >= self.max_batch
                    || groups[i]
                        .1
                        .first()
                        .map(|e| now.duration_since(e.enqueued) >= self.timeout)
                        .unwrap_or(false);
                if ready {
                    let (_, g) = groups.swap_remove(i);
                    for chunk in Self::chunks(g, self.max_batch) {
                        metrics.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        router.dispatch(chunk);
                    }
                } else {
                    i += 1;
                }
            }
        }
    }

    fn chunks(mut g: Vec<Envelope>, max: usize) -> Vec<Vec<Envelope>> {
        let mut out = Vec::new();
        while g.len() > max {
            let rest = g.split_off(max);
            out.push(g);
            g = rest;
        }
        if !g.is_empty() {
            out.push(g);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(id: u64, seq: usize) -> Envelope {
        let d = 4;
        let m = vec![0.0f32; seq * d];
        Envelope {
            req: super::super::request::AttentionRequest::new(id, seq, d, m.clone(), m.clone(), m),
            reply: mpsc::channel().0,
            enqueued: std::time::Instant::now(),
        }
    }

    #[test]
    fn chunking_respects_max_batch() {
        let g: Vec<Envelope> = (0..10).map(|i| env(i, 8)).collect();
        let chunks = Batcher::chunks(g, 4);
        let sizes: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
        // No request lost or duplicated.
        let mut ids: Vec<u64> = chunks.iter().flatten().map(|e| e.req.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn empty_group_produces_no_chunks() {
        assert!(Batcher::chunks(vec![], 4).is_empty());
    }
}
