//! Admission gate of the serving path: pool capabilities and session
//! lifecycle resolution, shared by the continuous scheduler.
//!
//! Historically this module owned the whole one-shot `Batcher` loop —
//! ingress drain, shard grouping, and batch dispatch.  The continuous
//! refactor (DESIGN.md §10) split that loop into
//! [`super::queue`] (where requests wait) and [`super::scheduler`]
//! (when they run); what remains here is the part whose behavior the
//! bitwise one-shot-equivalence contract depends on staying put:
//!
//! * [`PoolCapabilities`] — what the pool's resolved backend can
//!   execute, probed once at
//!   [`Coordinator::start`](super::Coordinator::start);
//! * [`admit_session_op`] — the session lifecycle gate (DESIGN.md §5):
//!   prefill registers the session, decode validates step order and
//!   appends the new K/V row to the host tier *before* dispatch (so
//!   in-flight shards always find their prefix), close is answered
//!   right here, and every capability violation is rejected before any
//!   state mutates.
//!
//! Sessions mean the serving path ships no full K/V copies per step: a
//! decode envelope carries one row per KV head and the devices read
//! the prefix from their page caches.

use std::time::Duration;

use super::metrics::Metrics;
use super::request::{AttentionResponse, Envelope, OpKind, ResponseStats};
use super::session::{SessionOp, SessionTable};
use super::trace::NO_SESSION;

/// What the pool's resolved backend can execute, probed once at
/// [`Coordinator::start`](super::Coordinator::start).  Incapable pools
/// reject the corresponding traffic at admission — before any session
/// state mutates.  The three booleans currently coincide with "runs on
/// the reference or sim backend"; they are carried separately because
/// artifact export (DESIGN.md §future-work) would split them.
#[derive(Clone, Copy, Debug)]
pub struct PoolCapabilities {
    /// Decode steps (PJRT has no `fsa_decode` artifact kind).
    pub decode: bool,
    /// Masked shards (the AOT artifacts take no mask input,
    /// DESIGN.md §6).
    pub mask: bool,
    /// Sequence-parallel partial shards (the AOT artifacts emit no
    /// `(O~, m, l)` state, DESIGN.md §7).
    pub seqpar: bool,
    /// Longest admissible `seq_len`, when the backend's cost model
    /// demands a guard: `Some(RunConfig::sim_max_seq)` on the
    /// cycle-accurate sim pool (O(L²·N) PE-steps per shard,
    /// DESIGN.md §8), `None` everywhere else.
    pub max_seq: Option<usize>,
}

impl PoolCapabilities {
    /// Everything-on, unguarded (the reference backend).
    pub fn reference() -> PoolCapabilities {
        PoolCapabilities { decode: true, mask: true, seqpar: true, max_seq: None }
    }

    /// The cycle-accurate sim backend: mask ✓, decode ✓, seqpar ✓ —
    /// everything the reference twin serves, since the §8 mask wave and
    /// the decode/partial program variants all run on the array — but
    /// guarded at `sim_max_seq` tokens.
    pub fn sim(max_seq: usize) -> PoolCapabilities {
        PoolCapabilities { decode: true, mask: true, seqpar: true, max_seq: Some(max_seq) }
    }

    /// The strict PJRT artifact pool (no decode/mask/partial kinds).
    pub fn pjrt() -> PoolCapabilities {
        PoolCapabilities { decode: false, mask: false, seqpar: false, max_seq: None }
    }
}

/// Resolve a request's [`SessionOp`] against the session table.
/// Returns the (possibly prefix-stamped) envelope when it should be
/// dispatched to the pool, `None` when it was answered in place
/// (close, or a lifecycle/capability error).
pub fn admit_session_op(
    mut env: Envelope,
    sessions: &SessionTable,
    metrics: &Metrics,
    caps: PoolCapabilities,
    seq_shards: usize,
) -> Option<Envelope> {
    let o = std::sync::atomic::Ordering::Relaxed;
    // The sim pool's O(L²) guard (DESIGN.md §8): reject over-long
    // requests at admission — before a prefill opens a session — with
    // an error naming the knob.  Close is exempt (it executes no
    // kernel); decode steps carry seq_len = 1 and pass (their prefix
    // was admitted at prefill time).
    if let Some(cap) = caps.max_seq {
        if env.req.seq_len > cap && !matches!(env.req.op, SessionOp::Close { .. }) {
            let seq = env.req.seq_len;
            reply_inline(
                env,
                Err(format!(
                    "seq_len {seq} exceeds sim_max_seq ({cap}): the cycle-accurate \
                     sim backend is O(L²·N) PE-steps per head shard; raise \
                     `[run] sim_max_seq` / `--sim-max-seq`, or serve long \
                     sequences on backend=reference (DESIGN.md §8)"
                )),
                metrics,
            );
            return None;
        }
    }
    // Reject masked requests on a mask-incapable (PJRT) pool up front:
    // every shard would fail at the device anyway, and a masked
    // *prefill* must not get as far as opening a session it can never
    // serve (the session would be left orphaned-open).
    if !caps.mask && !env.req.mask.is_none() {
        let mask = env.req.mask;
        reply_inline(
            env,
            Err(format!(
                "the pool's PJRT backend takes no attention mask (got {mask}); \
                 restart with backend=reference, or export masked artifacts \
                 (DESIGN.md §6)"
            )),
            metrics,
        );
        return None;
    }
    // Reject sequence-sharded serving on a seqpar-incapable (PJRT) pool
    // the same way — the AOT artifacts emit normalized outputs, not the
    // partial (O~, m, l) state the gather merge needs (DESIGN.md §7).
    // Close is exempt: it executes no kernel and must stay idempotent
    // (answered below with its usual empty-success/not-open reply).
    if !caps.seqpar && seq_shards > 1 && !matches!(env.req.op, SessionOp::Close { .. }) {
        reply_inline(
            env,
            Err(format!(
                "the pool's PJRT backend emits no partial (O~, m, l) state \
                 (seq_shards = {seq_shards}); restart with backend=reference, \
                 or export partial artifacts (DESIGN.md §7)"
            )),
            metrics,
        );
        return None;
    }
    match env.req.op {
        SessionOp::Stateless => Some(env),
        SessionOp::Prefill { session } => {
            match sessions.open(session, &env.req, seq_shards) {
                Ok(epoch) => {
                    env.req.epoch = epoch;
                    metrics.sessions_opened.fetch_add(1, o);
                    Some(env)
                }
                Err(msg) => {
                    reply_inline(env, Err(msg), metrics);
                    None
                }
            }
        }
        SessionOp::Decode { session, step } => {
            // The sim pool's O(L²) guard also bounds the *prefix*: each
            // decode step executes a decode-row program over the grown
            // prefix, so without this check a 1-token step could grow a
            // session arbitrarily far past `sim_max_seq` and recreate
            // the worker-wedging cost the guard exists to prevent.
            // Checked BEFORE begin_decode so the rejected step is never
            // consumed (retryable on a reference pool).  An unknown
            // session falls through to begin_decode's lifecycle error.
            if let (Some(cap), Some(prefix)) = (caps.max_seq, sessions.prefix_len(session)) {
                if prefix >= cap {
                    reply_inline(
                        env,
                        Err(format!(
                            "session {session} decode step {step}: prefix {prefix} has \
                             reached sim_max_seq ({cap}) — the cycle-accurate sim \
                             backend is O(prefix·N²) PE-steps per decode shard; raise \
                             `[run] sim_max_seq` / `--sim-max-seq`, or serve long \
                             sessions on backend=reference (DESIGN.md §8)"
                        )),
                        metrics,
                    );
                    return None;
                }
            }
            // Reject before begin_decode consumes the step: a PJRT
            // pool (including `auto` that resolved to PJRT) has no
            // decode artifact kind, so admitting would burn the step
            // on a guaranteed execution error.
            if !caps.decode {
                reply_inline(
                    env,
                    Err(format!(
                        "session {session} decode step {step}: the pool's PJRT \
                         backend has no `fsa_decode` artifact kind; restart with \
                         backend=reference (DESIGN.md §5)"
                    )),
                    metrics,
                );
                return None;
            }
            match sessions.begin_decode(session, step, &env.req) {
                Ok(admit) => {
                    env.req.prefix_len = admit.prefix_len;
                    env.req.prefill_len = admit.prefill_len;
                    env.req.epoch = admit.epoch;
                    metrics.decode_steps.fetch_add(1, o);
                    Some(env)
                }
                Err(msg) => {
                    reply_inline(env, Err(msg), metrics);
                    None
                }
            }
        }
        SessionOp::Close { session } => {
            if sessions.close(session) {
                metrics.sessions_closed.fetch_add(1, o);
                reply_inline(env, Ok(Vec::new()), metrics);
            } else {
                reply_inline(env, Err(format!("session {session} is not open")), metrics);
            }
            None
        }
    }
}

/// Session id carried on an op, or [`NO_SESSION`] for stateless
/// requests (trace-event coordinate).
pub(super) fn op_session(op: &SessionOp) -> u64 {
    match op {
        SessionOp::Stateless => NO_SESSION,
        SessionOp::Prefill { session }
        | SessionOp::Decode { session, .. }
        | SessionOp::Close { session } => *session,
    }
}

/// Answer an envelope without touching the device pool (lifecycle
/// replies and validation errors).  A vanished client is not an error.
pub(super) fn reply_inline(env: Envelope, output: Result<Vec<f32>, String>, metrics: &Metrics) {
    let ok = output.is_ok();
    let resp = AttentionResponse {
        id: env.req.id,
        kind: OpKind::of(&env.req.op),
        output,
        num_heads: env.req.num_heads,
        num_kv_heads: env.req.num_kv_heads,
        shards: 0,
        device_cycles: 0,
        critical_path_cycles: 0,
        device_time: Duration::ZERO,
        utilization: 0.0,
        latency: env.enqueued.elapsed(),
        device_id: 0,
        devices_used: Vec::new(),
        bucket: env.req.seq_len,
        stats: ResponseStats::default(),
    };
    metrics.record(&resp, ok);
    let _ = env.reply.send(resp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::AttentionRequest;
    use crate::mask::MaskKind;
    use std::sync::mpsc;

    #[test]
    fn seqpar_requests_need_a_partial_capable_pool() {
        let sessions = SessionTable::new();
        let metrics = Metrics::new();
        let d = 4;
        let caps_pjrt = PoolCapabilities::pjrt();
        let mk = || -> (Envelope, mpsc::Receiver<AttentionResponse>) {
            let (tx, rx) = mpsc::channel();
            let m = vec![0.0f32; 8 * d];
            (
                Envelope {
                    req: AttentionRequest::new(1, 8, d, m.clone(), m.clone(), m),
                    reply: tx,
                    enqueued: std::time::Instant::now(),
                },
                rx,
            )
        };
        // seq_shards > 1 on a PJRT pool: rejected at admission with the
        // partial-state explanation.
        let (env, rx) = mk();
        assert!(admit_session_op(env, &sessions, &metrics, caps_pjrt, 2).is_none());
        let err = rx.try_recv().unwrap().output.unwrap_err();
        assert!(err.contains("partial") && err.contains("seq_shards"), "{err}");
        // The same request passes on a reference pool, and at
        // seq_shards = 1 even the PJRT pool admits it.
        let (env, _rx) = mk();
        assert!(admit_session_op(env, &sessions, &metrics, PoolCapabilities::reference(), 2)
            .is_some());
        let (env, _rx) = mk();
        assert!(admit_session_op(env, &sessions, &metrics, caps_pjrt, 1).is_some());
        // Close executes no kernel: it must keep its normal idempotent
        // reply shape even on the incapable pool (not the seqpar error).
        let (tx, rx) = mpsc::channel();
        let env = Envelope {
            req: AttentionRequest::close(9, 404),
            reply: tx,
            enqueued: std::time::Instant::now(),
        };
        assert!(admit_session_op(env, &sessions, &metrics, caps_pjrt, 2).is_none());
        let err = rx.try_recv().unwrap().output.unwrap_err();
        assert!(err.contains("not open"), "close must be answered as close: {err}");
    }

    /// Satellite: the sim pool's O(L²) guard rejects over-long requests
    /// at admission with an error naming the knob; close stays exempt
    /// and a prefill is refused before it can open a session.
    #[test]
    fn sim_pool_rejects_seq_len_above_the_guard() {
        let sessions = SessionTable::new();
        let metrics = Metrics::new();
        let d = 4;
        let caps = PoolCapabilities::sim(8);
        let mk = |req: AttentionRequest| -> (Envelope, mpsc::Receiver<AttentionResponse>) {
            let (tx, rx) = mpsc::channel();
            (Envelope { req, reply: tx, enqueued: std::time::Instant::now() }, rx)
        };
        // At the guard: admitted.
        let m = vec![0.0f32; 8 * d];
        let (env, _rx) = mk(AttentionRequest::new(1, 8, d, m.clone(), m.clone(), m));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_some());
        // Above it: rejected, and the error names the flag.
        let m = vec![0.0f32; 9 * d];
        let (env, rx) = mk(AttentionRequest::new(2, 9, d, m.clone(), m.clone(), m));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        let err = rx.try_recv().unwrap().output.unwrap_err();
        assert!(err.contains("sim_max_seq") && err.contains("9"), "{err}");
        // An over-long prefill must not open its session.
        let m = vec![0.0f32; 9 * d];
        let (env, rx) = mk(AttentionRequest::prefill(3, 77, 9, d, 1, 1, m.clone(), m.clone(), m));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        assert!(rx.try_recv().unwrap().output.is_err());
        assert!(!sessions.contains(77));
        // Close is exempt (executes no kernel; idempotent reply shape).
        let (env, rx) = mk(AttentionRequest::close(4, 77));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        assert!(rx.try_recv().unwrap().output.unwrap_err().contains("not open"));
        // Decode steps (seq_len = 1) pass while the prefix stays under
        // the guard — but the guard also bounds the *grown prefix*: a
        // session prefilled at 4 admits 4 steps (prefix 4..7), and the
        // step that would push past sim_max_seq = 8 is rejected before
        // being consumed.
        let m = vec![0.0f32; 4 * d];
        let (env, _rx) = mk(AttentionRequest::prefill(5, 9, 4, d, 1, 1, m.clone(), m.clone(), m));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_some());
        for step in 0..4u64 {
            let (env, _rx) = mk(AttentionRequest::decode(
                6 + step, 9, step, d, 1, 1, vec![0.0; d], vec![0.0; d], vec![0.0; d],
            ));
            assert!(
                admit_session_op(env, &sessions, &metrics, caps, 1).is_some(),
                "step {step} (prefix under the guard) must be admitted"
            );
        }
        assert_eq!(sessions.prefix_len(9), Some(8));
        let (env, rx) = mk(AttentionRequest::decode(
            10, 9, 4, d, 1, 1, vec![0.0; d], vec![0.0; d], vec![0.0; d],
        ));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        let err = rx.try_recv().unwrap().output.unwrap_err();
        assert!(err.contains("sim_max_seq") && err.contains("prefix 8"), "{err}");
        // The rejected step was not consumed: it is retryable (e.g.
        // after raising the guard).
        let unguarded = PoolCapabilities::reference();
        let (env, _rx) = mk(AttentionRequest::decode(
            11, 9, 4, d, 1, 1, vec![0.0; d], vec![0.0; d], vec![0.0; d],
        ));
        assert!(admit_session_op(env, &sessions, &metrics, unguarded, 1).is_some());
    }

    #[test]
    fn masked_requests_rejected_on_mask_incapable_pools_before_any_state() {
        let sessions = SessionTable::new();
        let metrics = Metrics::new();
        let d = 4;
        let mk = |req: AttentionRequest| -> (Envelope, mpsc::Receiver<AttentionResponse>) {
            let (tx, rx) = mpsc::channel();
            (Envelope { req, reply: tx, enqueued: std::time::Instant::now() }, rx)
        };
        // A causal prefill on a PJRT pool must be rejected WITHOUT
        // opening the session (else it would be orphaned-open: every
        // shard fails at the device, but the id stays registered).
        let incapable = PoolCapabilities::pjrt();
        let (env, rx) = mk(
            AttentionRequest::prefill(
                1, 7, 2, d, 2, 1,
                vec![0.0; 2 * 2 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
            )
            .with_mask(MaskKind::Causal),
        );
        assert!(admit_session_op(env, &sessions, &metrics, incapable, 1).is_none());
        assert!(rx.try_recv().unwrap().output.unwrap_err().contains("no attention mask"));
        assert!(!sessions.contains(7), "rejected prefill must not open the session");

        // Stateless masked traffic is rejected at admission too.
        let (env, rx) = mk(
            AttentionRequest::new(2, 2, d, vec![0.0; 2 * d], vec![0.0; 2 * d], vec![0.0; 2 * d])
                .with_mask(MaskKind::PaddingKeys { valid: 1 }),
        );
        assert!(admit_session_op(env, &sessions, &metrics, incapable, 1).is_none());
        assert!(rx.try_recv().unwrap().output.is_err());

        // The same requests pass admission on a mask-capable pool.
        let (env, _rx) = mk(
            AttentionRequest::prefill(
                3, 7, 2, d, 2, 1,
                vec![0.0; 2 * 2 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
            )
            .with_mask(MaskKind::Causal),
        );
        assert!(
            admit_session_op(env, &sessions, &metrics, PoolCapabilities::reference(), 1)
                .is_some()
        );
        assert!(sessions.contains(7));
    }

    #[test]
    fn session_ops_are_resolved_before_dispatch() {
        let sessions = SessionTable::new();
        let metrics = Metrics::new();
        let d = 4;
        let caps = PoolCapabilities::reference();
        let mk = |req: AttentionRequest| -> (Envelope, mpsc::Receiver<AttentionResponse>) {
            let (tx, rx) = mpsc::channel();
            (Envelope { req, reply: tx, enqueued: std::time::Instant::now() }, rx)
        };

        // Decode before prefill: answered in place with an error.
        let (env, rx) = mk(AttentionRequest::decode(
            1, 7, 0, d, 2, 1, vec![0.0; 2 * d], vec![0.0; d], vec![0.0; d],
        ));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        assert!(rx.try_recv().unwrap().output.is_err());

        // Prefill opens the session and is stamped with its epoch.
        let (env, _rx) = mk(AttentionRequest::prefill(
            2, 7, 2, d, 2, 1, vec![0.0; 2 * 2 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
        ));
        let env2 = admit_session_op(env, &sessions, &metrics, caps, 1).unwrap();
        assert!(env2.req.epoch > 0);
        assert!(sessions.contains(7));

        // A valid decode is stamped with the prefix length, the
        // chunk-grid basis, and the epoch.
        let (env, _rx) = mk(AttentionRequest::decode(
            3, 7, 0, d, 2, 1, vec![0.0; 2 * d], vec![0.0; d], vec![0.0; d],
        ));
        let env = admit_session_op(env, &sessions, &metrics, caps, 1).unwrap();
        assert_eq!(env.req.prefix_len, 3);
        assert_eq!(env.req.prefill_len, 2);
        assert_eq!(env.req.epoch, env2.req.epoch);

        // On a decode-incapable pool (PJRT, including auto resolved to
        // PJRT) a decode is rejected BEFORE the step is consumed: no
        // state mutation, retryable after a backend change.
        let before = sessions.prefix_len(7);
        let (env, rx2) = mk(AttentionRequest::decode(
            9, 7, 1, d, 2, 1, vec![0.0; 2 * d], vec![0.0; d], vec![0.0; d],
        ));
        let no_decode = PoolCapabilities { decode: false, mask: true, seqpar: true, max_seq: None };
        assert!(admit_session_op(env, &sessions, &metrics, no_decode, 1).is_none());
        assert!(rx2.try_recv().unwrap().output.unwrap_err().contains("fsa_decode"));
        assert_eq!(sessions.prefix_len(7), before, "rejected step must not consume state");

        // Close is answered in place with an empty success.
        let (env, rx) = mk(AttentionRequest::close(4, 7));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        let resp = rx.try_recv().unwrap();
        assert_eq!(resp.output.unwrap(), Vec::<f32>::new());
        assert!(!sessions.contains(7));

        let o = std::sync::atomic::Ordering::Relaxed;
        assert_eq!(metrics.sessions_opened.load(o), 1);
        assert_eq!(metrics.sessions_closed.load(o), 1);
        assert_eq!(metrics.decode_steps.load(o), 1);
        assert_eq!(metrics.completed.load(o), 3); // two error replies + close
        assert_eq!(metrics.failed.load(o), 2);
    }
}
