//! Shard batcher: explodes ingress requests into per-head shards and
//! groups compatible shards so a device runs one compiled executable
//! per batch (amortizing PJRT dispatch), bounded by `max_batch` and a
//! timeout so short queues still make progress.
//!
//! A multi-head request enters as one [`Envelope`] and leaves as
//! `num_heads · live_chunks` [`ShardEnvelope`]s (the `(head, kv-range)`
//! grid of DESIGN.md §7; one chunk per head on the legacy
//! `seq_shards = 1` path); shards of *different* requests with the
//! same `(seq_len, d, mask)` shape share batches, so head-sharding,
//! sequence-sharding, and cross-request batching compose (masked and
//! unmasked shards are different kernels and never share a batch).
//!
//! The batcher is also the session lifecycle gate (DESIGN.md §5):
//! prefill registers the session, decode validates step order and
//! appends the new K/V row to the host tier *before* dispatch (so
//! in-flight shards always find their prefix), and close is answered
//! right here — sessions mean the batcher no longer ships full K/V
//! copies per step: a decode envelope carries one row per KV head and
//! the devices read the prefix from their page caches.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use crate::mask::MaskKind;

use super::metrics::Metrics;
use super::request::{AttentionResponse, Envelope, OpKind};
use super::router::Router;
use super::session::{SessionOp, SessionTable};
use super::shard::{explode, ShardEnvelope};
use super::trace::{EventKind, Tracer, NO_DEVICE, NO_HEAD, NO_SESSION};

/// Batch compatibility key: shards sharing it may run in one device
/// batch (same kernel shape) — sequence length, head dim, and mask
/// *kind* (`std::mem::Discriminant`): masked and unmasked shards are
/// different kernels, but two `PaddingKeys` requests with different
/// `valid` prefixes share one (execution is per-shard with the shard's
/// own mask, so batching them together is safe — keying on the exact
/// `valid` would put every padded length in its own group and defeat
/// cross-request batching on exactly the padded traffic).
type GroupKey = (usize, usize, std::mem::Discriminant<MaskKind>);

/// What the pool's resolved backend can execute, probed once at
/// [`Coordinator::start`](super::Coordinator::start).  Incapable pools
/// reject the corresponding traffic at admission — before any session
/// state mutates.  The three booleans currently coincide with "runs on
/// the reference or sim backend"; they are carried separately because
/// artifact export (DESIGN.md §future-work) would split them.
#[derive(Clone, Copy, Debug)]
pub struct PoolCapabilities {
    /// Decode steps (PJRT has no `fsa_decode` artifact kind).
    pub decode: bool,
    /// Masked shards (the AOT artifacts take no mask input,
    /// DESIGN.md §6).
    pub mask: bool,
    /// Sequence-parallel partial shards (the AOT artifacts emit no
    /// `(O~, m, l)` state, DESIGN.md §7).
    pub seqpar: bool,
    /// Longest admissible `seq_len`, when the backend's cost model
    /// demands a guard: `Some(RunConfig::sim_max_seq)` on the
    /// cycle-accurate sim pool (O(L²·N) PE-steps per shard,
    /// DESIGN.md §8), `None` everywhere else.
    pub max_seq: Option<usize>,
}

impl PoolCapabilities {
    /// Everything-on, unguarded (the reference backend).
    pub fn reference() -> PoolCapabilities {
        PoolCapabilities { decode: true, mask: true, seqpar: true, max_seq: None }
    }

    /// The cycle-accurate sim backend: mask ✓, decode ✓, seqpar ✓ —
    /// everything the reference twin serves, since the §8 mask wave and
    /// the decode/partial program variants all run on the array — but
    /// guarded at `sim_max_seq` tokens.
    pub fn sim(max_seq: usize) -> PoolCapabilities {
        PoolCapabilities { decode: true, mask: true, seqpar: true, max_seq: Some(max_seq) }
    }

    /// The strict PJRT artifact pool (no decode/mask/partial kinds).
    pub fn pjrt() -> PoolCapabilities {
        PoolCapabilities { decode: false, mask: false, seqpar: false, max_seq: None }
    }
}

pub struct Batcher {
    max_batch: usize,
    /// Timeout expressed in simulated device cycles in the config; the
    /// batcher converts at the *configured* clock (`RunConfig::freq_ghz`)
    /// to a host duration.  (It used to hard-code the paper's 1.5 GHz,
    /// silently flushing batches 1.5x early on a 1.0 GHz config.)
    timeout: Duration,
    /// Sequence-parallel shard count every admitted request explodes at
    /// (`RunConfig::seq_shards`; 1 = legacy whole-sequence shards).
    seq_shards: usize,
    /// Resolved backend capabilities (see [`PoolCapabilities`]).
    caps: PoolCapabilities,
    /// Request-path event sink (DESIGN.md §9); disabled by default.
    tracer: Arc<Tracer>,
}

impl Batcher {
    pub fn new(
        max_batch: usize,
        timeout_cycles: u64,
        freq_ghz: f64,
        seq_shards: usize,
        caps: PoolCapabilities,
    ) -> Batcher {
        assert!(freq_ghz > 0.0, "clock must be positive (RunConfig::validate)");
        Batcher {
            max_batch: max_batch.max(1),
            timeout: Duration::from_nanos((timeout_cycles as f64 / freq_ghz) as u64),
            seq_shards: seq_shards.max(1),
            caps,
            tracer: Tracer::off(),
        }
    }

    /// Attach a request-path tracer (the coordinator threads its own;
    /// directly constructed batchers keep the disabled default).
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> Batcher {
        self.tracer = tracer;
        self
    }

    /// Main loop: drain the ingress channel, resolve session lifecycle
    /// ops, explode each dispatched request into head shards, group
    /// shards by `(seq_len, d, mask)`, and dispatch a group when it
    /// reaches `max_batch` shards or its oldest member exceeds the
    /// timeout.  Exits when the ingress disconnects.
    pub fn run(
        &self,
        rx: mpsc::Receiver<Envelope>,
        router: Router,
        metrics: Arc<Metrics>,
        sessions: Arc<SessionTable>,
    ) {
        let mut groups: Vec<(GroupKey, Vec<ShardEnvelope>)> = Vec::new();
        let admit = |env: Envelope, groups: &mut Vec<(GroupKey, Vec<ShardEnvelope>)>| {
            // Queue depth at admit: requests in flight right now
            // (submitted minus completed; saturating because the two
            // relaxed counters race by design).
            let o = std::sync::atomic::Ordering::Relaxed;
            metrics.record_queue_depth(
                (metrics.submitted.load(o) as u64)
                    .saturating_sub(metrics.completed.load(o) as u64),
            );
            let Some(env) =
                admit_session_op(env, &sessions, &metrics, self.caps, self.seq_shards)
            else {
                return; // answered in place (close / lifecycle error)
            };
            let (id, session) = (env.req.id, op_session(&env.req.op));
            self.tracer.record(
                EventKind::Admit,
                id,
                session,
                NO_HEAD,
                NO_HEAD,
                NO_DEVICE,
                env.req.seq_len as u64,
            );
            let key = (env.req.seq_len, env.req.d, std::mem::discriminant(&env.req.mask));
            let shards = explode(env, self.seq_shards);
            self.tracer.record(
                EventKind::Shard,
                id,
                session,
                NO_HEAD,
                NO_HEAD,
                NO_DEVICE,
                shards.len() as u64,
            );
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, g)) => g.extend(shards),
                None => groups.push((key, shards)),
            }
        };
        loop {
            // Block briefly so timeouts fire even when idle.
            let first = rx.recv_timeout(self.timeout.min(Duration::from_millis(5)));
            match first {
                Ok(env) => {
                    admit(env, &mut groups);
                    // Opportunistically drain whatever else is queued.
                    while let Ok(env) = rx.try_recv() {
                        admit(env, &mut groups);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Flush everything and exit.
                    for (_, g) in groups.drain(..) {
                        for chunk in Self::chunks(g, self.max_batch) {
                            metrics.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            router.dispatch(chunk);
                        }
                    }
                    return;
                }
            }

            // Dispatch full groups and timed-out groups.
            let now = std::time::Instant::now();
            let mut i = 0;
            while i < groups.len() {
                let ready = groups[i].1.len() >= self.max_batch
                    || groups[i]
                        .1
                        .first()
                        .map(|e| now.duration_since(e.enqueued) >= self.timeout)
                        .unwrap_or(false);
                if ready {
                    let (_, g) = groups.swap_remove(i);
                    for chunk in Self::chunks(g, self.max_batch) {
                        metrics.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        router.dispatch(chunk);
                    }
                } else {
                    i += 1;
                }
            }
        }
    }

    fn chunks(mut g: Vec<ShardEnvelope>, max: usize) -> Vec<Vec<ShardEnvelope>> {
        let mut out = Vec::new();
        while g.len() > max {
            let rest = g.split_off(max);
            out.push(g);
            g = rest;
        }
        if !g.is_empty() {
            out.push(g);
        }
        out
    }
}

/// Resolve a request's [`SessionOp`] against the session table.
/// Returns the (possibly prefix-stamped) envelope when it should be
/// dispatched to the pool, `None` when it was answered in place
/// (close, or a lifecycle/capability error).
fn admit_session_op(
    mut env: Envelope,
    sessions: &SessionTable,
    metrics: &Metrics,
    caps: PoolCapabilities,
    seq_shards: usize,
) -> Option<Envelope> {
    let o = std::sync::atomic::Ordering::Relaxed;
    // The sim pool's O(L²) guard (DESIGN.md §8): reject over-long
    // requests at admission — before a prefill opens a session — with
    // an error naming the knob.  Close is exempt (it executes no
    // kernel); decode steps carry seq_len = 1 and pass (their prefix
    // was admitted at prefill time).
    if let Some(cap) = caps.max_seq {
        if env.req.seq_len > cap && !matches!(env.req.op, SessionOp::Close { .. }) {
            let seq = env.req.seq_len;
            reply_inline(
                env,
                Err(format!(
                    "seq_len {seq} exceeds sim_max_seq ({cap}): the cycle-accurate \
                     sim backend is O(L²·N) PE-steps per head shard; raise \
                     `[run] sim_max_seq` / `--sim-max-seq`, or serve long \
                     sequences on backend=reference (DESIGN.md §8)"
                )),
                metrics,
            );
            return None;
        }
    }
    // Reject masked requests on a mask-incapable (PJRT) pool up front:
    // every shard would fail at the device anyway, and a masked
    // *prefill* must not get as far as opening a session it can never
    // serve (the session would be left orphaned-open).
    if !caps.mask && !env.req.mask.is_none() {
        let mask = env.req.mask;
        reply_inline(
            env,
            Err(format!(
                "the pool's PJRT backend takes no attention mask (got {mask}); \
                 restart with backend=reference, or export masked artifacts \
                 (DESIGN.md §6)"
            )),
            metrics,
        );
        return None;
    }
    // Reject sequence-sharded serving on a seqpar-incapable (PJRT) pool
    // the same way — the AOT artifacts emit normalized outputs, not the
    // partial (O~, m, l) state the gather merge needs (DESIGN.md §7).
    // Close is exempt: it executes no kernel and must stay idempotent
    // (answered below with its usual empty-success/not-open reply).
    if !caps.seqpar && seq_shards > 1 && !matches!(env.req.op, SessionOp::Close { .. }) {
        reply_inline(
            env,
            Err(format!(
                "the pool's PJRT backend emits no partial (O~, m, l) state \
                 (seq_shards = {seq_shards}); restart with backend=reference, \
                 or export partial artifacts (DESIGN.md §7)"
            )),
            metrics,
        );
        return None;
    }
    match env.req.op {
        SessionOp::Stateless => Some(env),
        SessionOp::Prefill { session } => {
            match sessions.open(session, &env.req, seq_shards) {
                Ok(epoch) => {
                    env.req.epoch = epoch;
                    metrics.sessions_opened.fetch_add(1, o);
                    Some(env)
                }
                Err(msg) => {
                    reply_inline(env, Err(msg), metrics);
                    None
                }
            }
        }
        SessionOp::Decode { session, step } => {
            // The sim pool's O(L²) guard also bounds the *prefix*: each
            // decode step executes a decode-row program over the grown
            // prefix, so without this check a 1-token step could grow a
            // session arbitrarily far past `sim_max_seq` and recreate
            // the worker-wedging cost the guard exists to prevent.
            // Checked BEFORE begin_decode so the rejected step is never
            // consumed (retryable on a reference pool).  An unknown
            // session falls through to begin_decode's lifecycle error.
            if let (Some(cap), Some(prefix)) = (caps.max_seq, sessions.prefix_len(session)) {
                if prefix >= cap {
                    reply_inline(
                        env,
                        Err(format!(
                            "session {session} decode step {step}: prefix {prefix} has \
                             reached sim_max_seq ({cap}) — the cycle-accurate sim \
                             backend is O(prefix·N²) PE-steps per decode shard; raise \
                             `[run] sim_max_seq` / `--sim-max-seq`, or serve long \
                             sessions on backend=reference (DESIGN.md §8)"
                        )),
                        metrics,
                    );
                    return None;
                }
            }
            // Reject before begin_decode consumes the step: a PJRT
            // pool (including `auto` that resolved to PJRT) has no
            // decode artifact kind, so admitting would burn the step
            // on a guaranteed execution error.
            if !caps.decode {
                reply_inline(
                    env,
                    Err(format!(
                        "session {session} decode step {step}: the pool's PJRT \
                         backend has no `fsa_decode` artifact kind; restart with \
                         backend=reference (DESIGN.md §5)"
                    )),
                    metrics,
                );
                return None;
            }
            match sessions.begin_decode(session, step, &env.req) {
                Ok(admit) => {
                    env.req.prefix_len = admit.prefix_len;
                    env.req.prefill_len = admit.prefill_len;
                    env.req.epoch = admit.epoch;
                    metrics.decode_steps.fetch_add(1, o);
                    Some(env)
                }
                Err(msg) => {
                    reply_inline(env, Err(msg), metrics);
                    None
                }
            }
        }
        SessionOp::Close { session } => {
            if sessions.close(session) {
                metrics.sessions_closed.fetch_add(1, o);
                reply_inline(env, Ok(Vec::new()), metrics);
            } else {
                reply_inline(env, Err(format!("session {session} is not open")), metrics);
            }
            None
        }
    }
}

/// Session id carried on an op, or [`NO_SESSION`] for stateless
/// requests (trace-event coordinate).
fn op_session(op: &SessionOp) -> u64 {
    match op {
        SessionOp::Stateless => NO_SESSION,
        SessionOp::Prefill { session }
        | SessionOp::Decode { session, .. }
        | SessionOp::Close { session } => *session,
    }
}

/// Answer an envelope without touching the device pool (lifecycle
/// replies and validation errors).  A vanished client is not an error.
fn reply_inline(env: Envelope, output: Result<Vec<f32>, String>, metrics: &Metrics) {
    let ok = output.is_ok();
    let resp = AttentionResponse {
        id: env.req.id,
        kind: OpKind::of(&env.req.op),
        output,
        num_heads: env.req.num_heads,
        num_kv_heads: env.req.num_kv_heads,
        shards: 0,
        seq_chunks: 0,
        merge_steps: 0,
        device_cycles: 0,
        critical_path_cycles: 0,
        device_time: Duration::ZERO,
        utilization: 0.0,
        latency: env.enqueued.elapsed(),
        device_id: 0,
        devices_used: Vec::new(),
        bucket: env.req.seq_len,
        kv_hits: 0,
        kv_misses: 0,
        measured_shards: 0,
        cycle_breakdown: None,
    };
    metrics.record(&resp, ok);
    let _ = env.reply.send(resp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::AttentionRequest;

    fn envs(n: u64, seq: usize) -> Vec<ShardEnvelope> {
        let d = 4;
        (0..n)
            .flat_map(|id| {
                let m = vec![0.0f32; seq * d];
                explode(
                    Envelope {
                        req: AttentionRequest::new(id, seq, d, m.clone(), m.clone(), m),
                        reply: mpsc::channel().0,
                        enqueued: std::time::Instant::now(),
                    },
                    1,
                )
            })
            .collect()
    }

    /// Satellite: the batch timeout converts cycles at the configured
    /// clock, not a hard-coded 1.5 GHz — 150k cycles are 100 µs at
    /// 1.5 GHz but 150 µs at 1.0 GHz (the old code flushed 1.5× early).
    #[test]
    fn timeout_converts_at_the_configured_clock() {
        let at = |ghz: f64| {
            Batcher::new(4, 150_000, ghz, 1, PoolCapabilities::reference()).timeout
        };
        assert_eq!(at(1.5), Duration::from_nanos(100_000));
        assert_eq!(at(1.0), Duration::from_nanos(150_000));
        assert_eq!(at(3.0), Duration::from_nanos(50_000));
    }

    #[test]
    fn seqpar_requests_need_a_partial_capable_pool() {
        let sessions = SessionTable::new();
        let metrics = Metrics::new();
        let d = 4;
        let caps_pjrt = PoolCapabilities::pjrt();
        let mk = || -> (Envelope, mpsc::Receiver<AttentionResponse>) {
            let (tx, rx) = mpsc::channel();
            let m = vec![0.0f32; 8 * d];
            (
                Envelope {
                    req: AttentionRequest::new(1, 8, d, m.clone(), m.clone(), m),
                    reply: tx,
                    enqueued: std::time::Instant::now(),
                },
                rx,
            )
        };
        // seq_shards > 1 on a PJRT pool: rejected at admission with the
        // partial-state explanation.
        let (env, rx) = mk();
        assert!(admit_session_op(env, &sessions, &metrics, caps_pjrt, 2).is_none());
        let err = rx.try_recv().unwrap().output.unwrap_err();
        assert!(err.contains("partial") && err.contains("seq_shards"), "{err}");
        // The same request passes on a reference pool, and at
        // seq_shards = 1 even the PJRT pool admits it.
        let (env, _rx) = mk();
        assert!(admit_session_op(env, &sessions, &metrics, PoolCapabilities::reference(), 2)
            .is_some());
        let (env, _rx) = mk();
        assert!(admit_session_op(env, &sessions, &metrics, caps_pjrt, 1).is_some());
        // Close executes no kernel: it must keep its normal idempotent
        // reply shape even on the incapable pool (not the seqpar error).
        let (tx, rx) = mpsc::channel();
        let env = Envelope {
            req: AttentionRequest::close(9, 404),
            reply: tx,
            enqueued: std::time::Instant::now(),
        };
        assert!(admit_session_op(env, &sessions, &metrics, caps_pjrt, 2).is_none());
        let err = rx.try_recv().unwrap().output.unwrap_err();
        assert!(err.contains("not open"), "close must be answered as close: {err}");
    }

    /// Satellite: the sim pool's O(L²) guard rejects over-long requests
    /// at admission with an error naming the knob; close stays exempt
    /// and a prefill is refused before it can open a session.
    #[test]
    fn sim_pool_rejects_seq_len_above_the_guard() {
        let sessions = SessionTable::new();
        let metrics = Metrics::new();
        let d = 4;
        let caps = PoolCapabilities::sim(8);
        let mk = |req: AttentionRequest| -> (Envelope, mpsc::Receiver<AttentionResponse>) {
            let (tx, rx) = mpsc::channel();
            (Envelope { req, reply: tx, enqueued: std::time::Instant::now() }, rx)
        };
        // At the guard: admitted.
        let m = vec![0.0f32; 8 * d];
        let (env, _rx) = mk(AttentionRequest::new(1, 8, d, m.clone(), m.clone(), m));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_some());
        // Above it: rejected, and the error names the flag.
        let m = vec![0.0f32; 9 * d];
        let (env, rx) = mk(AttentionRequest::new(2, 9, d, m.clone(), m.clone(), m));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        let err = rx.try_recv().unwrap().output.unwrap_err();
        assert!(err.contains("sim_max_seq") && err.contains("9"), "{err}");
        // An over-long prefill must not open its session.
        let m = vec![0.0f32; 9 * d];
        let (env, rx) = mk(AttentionRequest::prefill(3, 77, 9, d, 1, 1, m.clone(), m.clone(), m));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        assert!(rx.try_recv().unwrap().output.is_err());
        assert!(!sessions.contains(77));
        // Close is exempt (executes no kernel; idempotent reply shape).
        let (env, rx) = mk(AttentionRequest::close(4, 77));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        assert!(rx.try_recv().unwrap().output.unwrap_err().contains("not open"));
        // Decode steps (seq_len = 1) pass while the prefix stays under
        // the guard — but the guard also bounds the *grown prefix*: a
        // session prefilled at 4 admits 4 steps (prefix 4..7), and the
        // step that would push past sim_max_seq = 8 is rejected before
        // being consumed.
        let m = vec![0.0f32; 4 * d];
        let (env, _rx) = mk(AttentionRequest::prefill(5, 9, 4, d, 1, 1, m.clone(), m.clone(), m));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_some());
        for step in 0..4u64 {
            let (env, _rx) = mk(AttentionRequest::decode(
                6 + step, 9, step, d, 1, 1, vec![0.0; d], vec![0.0; d], vec![0.0; d],
            ));
            assert!(
                admit_session_op(env, &sessions, &metrics, caps, 1).is_some(),
                "step {step} (prefix under the guard) must be admitted"
            );
        }
        assert_eq!(sessions.prefix_len(9), Some(8));
        let (env, rx) = mk(AttentionRequest::decode(
            10, 9, 4, d, 1, 1, vec![0.0; d], vec![0.0; d], vec![0.0; d],
        ));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        let err = rx.try_recv().unwrap().output.unwrap_err();
        assert!(err.contains("sim_max_seq") && err.contains("prefix 8"), "{err}");
        // The rejected step was not consumed: it is retryable (e.g.
        // after raising the guard).
        let unguarded = PoolCapabilities::reference();
        let (env, _rx) = mk(AttentionRequest::decode(
            11, 9, 4, d, 1, 1, vec![0.0; d], vec![0.0; d], vec![0.0; d],
        ));
        assert!(admit_session_op(env, &sessions, &metrics, unguarded, 1).is_some());
    }

    #[test]
    fn group_keys_split_on_mask_kind_but_not_padding_valid() {
        // Masked and unmasked shards are different kernels and must not
        // share a batch; two key-padding requests padded to the same
        // bucket from different original lengths MUST share one (else
        // every padded length waits out its own batch timeout).
        let key = |m: MaskKind| std::mem::discriminant(&m);
        assert_ne!(key(MaskKind::None), key(MaskKind::Causal));
        assert_ne!(key(MaskKind::None), key(MaskKind::PaddingKeys { valid: 7 }));
        assert_eq!(
            key(MaskKind::PaddingKeys { valid: 100 }),
            key(MaskKind::PaddingKeys { valid: 101 })
        );
    }

    #[test]
    fn masked_requests_rejected_on_mask_incapable_pools_before_any_state() {
        let sessions = SessionTable::new();
        let metrics = Metrics::new();
        let d = 4;
        let mk = |req: AttentionRequest| -> (Envelope, mpsc::Receiver<AttentionResponse>) {
            let (tx, rx) = mpsc::channel();
            (Envelope { req, reply: tx, enqueued: std::time::Instant::now() }, rx)
        };
        // A causal prefill on a PJRT pool must be rejected WITHOUT
        // opening the session (else it would be orphaned-open: every
        // shard fails at the device, but the id stays registered).
        let incapable = PoolCapabilities::pjrt();
        let (env, rx) = mk(
            AttentionRequest::prefill(
                1, 7, 2, d, 2, 1,
                vec![0.0; 2 * 2 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
            )
            .with_mask(MaskKind::Causal),
        );
        assert!(admit_session_op(env, &sessions, &metrics, incapable, 1).is_none());
        assert!(rx.try_recv().unwrap().output.unwrap_err().contains("no attention mask"));
        assert!(!sessions.contains(7), "rejected prefill must not open the session");

        // Stateless masked traffic is rejected at admission too.
        let (env, rx) = mk(
            AttentionRequest::new(2, 2, d, vec![0.0; 2 * d], vec![0.0; 2 * d], vec![0.0; 2 * d])
                .with_mask(MaskKind::PaddingKeys { valid: 1 }),
        );
        assert!(admit_session_op(env, &sessions, &metrics, incapable, 1).is_none());
        assert!(rx.try_recv().unwrap().output.is_err());

        // The same requests pass admission on a mask-capable pool.
        let (env, _rx) = mk(
            AttentionRequest::prefill(
                3, 7, 2, d, 2, 1,
                vec![0.0; 2 * 2 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
            )
            .with_mask(MaskKind::Causal),
        );
        assert!(
            admit_session_op(env, &sessions, &metrics, PoolCapabilities::reference(), 1)
                .is_some()
        );
        assert!(sessions.contains(7));
    }

    #[test]
    fn chunking_respects_max_batch() {
        let g = envs(10, 8);
        let chunks = Batcher::chunks(g, 4);
        let sizes: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
        // No shard lost or duplicated.
        let mut ids: Vec<u64> = chunks.iter().flatten().map(|e| e.shard.req.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn empty_group_produces_no_chunks() {
        assert!(Batcher::chunks(vec![], 4).is_empty());
    }

    #[test]
    fn multi_head_request_contributes_one_shard_per_head() {
        let (seq, d, heads) = (8, 4, 4);
        let q = vec![0.0f32; heads * seq * d];
        let kv = vec![0.0f32; seq * d];
        let shards = explode(
            Envelope {
                req: AttentionRequest::gqa(1, seq, d, heads, 1, q, kv.clone(), kv),
                reply: mpsc::channel().0,
                enqueued: std::time::Instant::now(),
            },
            1,
        );
        // One 4-head request + batch limit 3 => chunks of 3 + 1.
        let sizes: Vec<usize> =
            Batcher::chunks(shards, 3).iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![3, 1]);
    }

    #[test]
    fn session_ops_are_resolved_before_dispatch() {
        let sessions = SessionTable::new();
        let metrics = Metrics::new();
        let d = 4;
        let caps = PoolCapabilities::reference();
        let mk = |req: AttentionRequest| -> (Envelope, mpsc::Receiver<AttentionResponse>) {
            let (tx, rx) = mpsc::channel();
            (Envelope { req, reply: tx, enqueued: std::time::Instant::now() }, rx)
        };

        // Decode before prefill: answered in place with an error.
        let (env, rx) = mk(AttentionRequest::decode(
            1, 7, 0, d, 2, 1, vec![0.0; 2 * d], vec![0.0; d], vec![0.0; d],
        ));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        assert!(rx.try_recv().unwrap().output.is_err());

        // Prefill opens the session and is stamped with its epoch.
        let (env, _rx) = mk(AttentionRequest::prefill(
            2, 7, 2, d, 2, 1, vec![0.0; 2 * 2 * d], vec![0.0; 2 * d], vec![0.0; 2 * d],
        ));
        let env2 = admit_session_op(env, &sessions, &metrics, caps, 1).unwrap();
        assert!(env2.req.epoch > 0);
        assert!(sessions.contains(7));

        // A valid decode is stamped with the prefix length, the
        // chunk-grid basis, and the epoch.
        let (env, _rx) = mk(AttentionRequest::decode(
            3, 7, 0, d, 2, 1, vec![0.0; 2 * d], vec![0.0; d], vec![0.0; d],
        ));
        let env = admit_session_op(env, &sessions, &metrics, caps, 1).unwrap();
        assert_eq!(env.req.prefix_len, 3);
        assert_eq!(env.req.prefill_len, 2);
        assert_eq!(env.req.epoch, env2.req.epoch);

        // On a decode-incapable pool (PJRT, including auto resolved to
        // PJRT) a decode is rejected BEFORE the step is consumed: no
        // state mutation, retryable after a backend change.
        let before = sessions.prefix_len(7);
        let (env, rx2) = mk(AttentionRequest::decode(
            9, 7, 1, d, 2, 1, vec![0.0; 2 * d], vec![0.0; d], vec![0.0; d],
        ));
        let no_decode = PoolCapabilities { decode: false, mask: true, seqpar: true, max_seq: None };
        assert!(admit_session_op(env, &sessions, &metrics, no_decode, 1).is_none());
        assert!(rx2.try_recv().unwrap().output.unwrap_err().contains("fsa_decode"));
        assert_eq!(sessions.prefix_len(7), before, "rejected step must not consume state");

        // Close is answered in place with an empty success.
        let (env, rx) = mk(AttentionRequest::close(4, 7));
        assert!(admit_session_op(env, &sessions, &metrics, caps, 1).is_none());
        let resp = rx.try_recv().unwrap();
        assert_eq!(resp.output.unwrap(), Vec::<f32>::new());
        assert!(!sessions.contains(7));

        let o = std::sync::atomic::Ordering::Relaxed;
        assert_eq!(metrics.sessions_opened.load(o), 1);
        assert_eq!(metrics.sessions_closed.load(o), 1);
        assert_eq!(metrics.decode_steps.load(o), 1);
        assert_eq!(metrics.completed.load(o), 3); // two error replies + close
        assert_eq!(metrics.failed.load(o), 2);
    }
}
