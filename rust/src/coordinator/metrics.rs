//! Serving metrics: counters + latency reservoir, lock-light.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::request::AttentionResponse;

#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicUsize,
    pub completed: AtomicUsize,
    pub failed: AtomicUsize,
    pub batches: AtomicUsize,
    /// Total simulated device cycles consumed.
    pub device_cycles: AtomicU64,
    /// Host latencies in ns (bounded reservoir).
    latencies_ns: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&self, resp: &AttentionResponse, ok: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        self.device_cycles.fetch_add(resp.device_cycles, Ordering::Relaxed);
        let mut l = super::lock(&self.latencies_ns);
        if l.len() < 65536 {
            l.push(resp.latency.as_nanos() as u64);
        }
    }

    /// (p50, p95, max) host latency.
    pub fn latency_percentiles(&self) -> (Duration, Duration, Duration) {
        let mut l = super::lock(&self.latencies_ns).clone();
        if l.is_empty() {
            return (Duration::ZERO, Duration::ZERO, Duration::ZERO);
        }
        l.sort_unstable();
        let pick = |p: f64| Duration::from_nanos(l[((l.len() - 1) as f64 * p) as usize]);
        (pick(0.5), pick(0.95), pick(1.0))
    }

    pub fn summary(&self) -> String {
        let (p50, p95, max) = self.latency_percentiles();
        format!(
            "submitted {} completed {} failed {} batches {} device_cycles {} \
             latency p50 {:?} p95 {:?} max {:?}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.device_cycles.load(Ordering::Relaxed),
            p50,
            p95,
            max,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(lat_ms: u64) -> AttentionResponse {
        AttentionResponse {
            id: 0,
            output: Ok(vec![]),
            device_cycles: 100,
            device_time: Duration::from_micros(1),
            latency: Duration::from_millis(lat_ms),
            device_id: 0,
            bucket: 128,
        }
    }

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=10 {
            m.record(&resp(i), i != 3);
        }
        assert_eq!(m.completed.load(Ordering::Relaxed), 10);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.device_cycles.load(Ordering::Relaxed), 1000);
        let (p50, p95, max) = m.latency_percentiles();
        assert!(p50 >= Duration::from_millis(4) && p50 <= Duration::from_millis(6));
        assert!(p95 >= p50 && max >= p95);
        assert!(m.summary().contains("completed 10"));
    }

    #[test]
    fn empty_percentiles_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentiles().0, Duration::ZERO);
    }
}
