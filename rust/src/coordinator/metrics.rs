//! Serving metrics: counters + latency reservoir, lock-light.
//!
//! Three granularities are tracked, matching the sharded request path:
//! whole requests (`submitted`/`completed`/`failed`, latency
//! percentiles, aggregate device cycles), executed shards
//! (`head_shards`, `shard_cycles`), and — distinctly — the
//! sequence-parallel dimension (`seqpar_requests`, `seq_chunk_shards`,
//! `merge_steps`, DESIGN.md §7), so an 8-head request sharded 4 ways
//! along the sequence counts once in `completed`, 32 times in
//! `head_shards`, 32 times in `seq_chunk_shards`, and 24 times in
//! `merge_steps`.  (Before sequence sharding, `head_shards` silently
//! conflated every future shard kind.)

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::request::AttentionResponse;

#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted by `Coordinator::submit`.
    pub submitted: AtomicUsize,
    /// Requests answered (one per gathered response).
    pub completed: AtomicUsize,
    /// Requests whose gathered output was an error.
    pub failed: AtomicUsize,
    /// Device batches dispatched by the batcher.
    pub batches: AtomicUsize,
    /// Shards executed by device workers (one per `(head, chunk)` grid
    /// cell).
    pub head_shards: AtomicUsize,
    /// Requests with more than one query head.
    pub multi_head_requests: AtomicUsize,
    /// Requests served sequence-sharded (`seq_chunks > 1`,
    /// DESIGN.md §7).
    pub seqpar_requests: AtomicUsize,
    /// Sequence-chunk shards executed by device workers (partial
    /// results merged at gather) — counted distinctly from
    /// `head_shards`, which they are a subset of.
    pub seq_chunk_shards: AtomicUsize,
    /// Online-softmax merge steps performed at gather.
    pub merge_steps: AtomicU64,
    /// Total simulated device cycles consumed (summed across shards).
    pub device_cycles: AtomicU64,
    /// Simulated device cycles as counted per shard at execution time;
    /// equals `device_cycles` once all gathers have completed (asserted
    /// by the coordinator tests).
    pub shard_cycles: AtomicU64,
    /// Sessions opened by prefill (decode-phase serving, DESIGN.md §5).
    pub sessions_opened: AtomicUsize,
    /// Sessions retired by close.
    pub sessions_closed: AtomicUsize,
    /// Decode steps admitted (one per validated decode request).
    pub decode_steps: AtomicUsize,
    /// Shards dispatched to the cycle-accurate sim backend
    /// (DESIGN.md §8).  The three dispatch counters split
    /// `head_shards` by executing engine, so a mixed fleet (or a
    /// config mistake) is visible in the summary.
    pub sim_dispatches: AtomicUsize,
    /// Shards dispatched to the in-crate reference twin.
    pub reference_dispatches: AtomicUsize,
    /// Shards dispatched to the PJRT artifact runtime.
    pub pjrt_dispatches: AtomicUsize,
    /// Decode shards served from KV-cache pages.
    pub kv_hits: AtomicU64,
    /// Decode shards that took the recompute fallback.
    pub kv_misses: AtomicU64,
    /// Live KV streams evicted from device caches under capacity
    /// pressure.
    pub kv_evictions: AtomicU64,
    /// Host latencies in ns (bounded reservoir).
    latencies_ns: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one executed head shard (called by device workers).
    pub fn record_shard(&self, cycles: u64) {
        self.head_shards.fetch_add(1, Ordering::Relaxed);
        self.shard_cycles.fetch_add(cycles, Ordering::Relaxed);
    }

    /// Count one shard dispatch against the executing backend kind
    /// (`Backend::name`): `sim`, `reference` or `pjrt`.  Unknown names
    /// are ignored rather than panicking a worker.
    pub fn record_dispatch(&self, backend: &str) {
        match backend {
            "sim" => self.sim_dispatches.fetch_add(1, Ordering::Relaxed),
            "reference" => self.reference_dispatches.fetch_add(1, Ordering::Relaxed),
            "pjrt" => self.pjrt_dispatches.fetch_add(1, Ordering::Relaxed),
            _ => 0,
        };
    }

    /// Record one gathered response (called by the completing worker).
    pub fn record(&self, resp: &AttentionResponse, ok: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        if resp.num_heads > 1 {
            self.multi_head_requests.fetch_add(1, Ordering::Relaxed);
        }
        if resp.seq_chunks > 1 {
            self.seqpar_requests.fetch_add(1, Ordering::Relaxed);
        }
        self.merge_steps.fetch_add(resp.merge_steps as u64, Ordering::Relaxed);
        self.device_cycles.fetch_add(resp.device_cycles, Ordering::Relaxed);
        let mut l = super::lock(&self.latencies_ns);
        if l.len() < 65536 {
            l.push(resp.latency.as_nanos() as u64);
        }
    }

    /// (p50, p95, max) host latency, nearest-rank selection: percentile
    /// `p` of `n` samples is the `ceil(p·n)`-th smallest — one shared
    /// implementation with the bench harness
    /// ([`crate::benchutil::nearest_rank`]) so both report the same
    /// statistic.  (The old `((n-1)·p) as usize` truncation biased p95
    /// low on small reservoirs — e.g. the 9th of 10 samples instead of
    /// the 10th.)
    pub fn latency_percentiles(&self) -> (Duration, Duration, Duration) {
        let mut l = super::lock(&self.latencies_ns).clone();
        if l.is_empty() {
            return (Duration::ZERO, Duration::ZERO, Duration::ZERO);
        }
        l.sort_unstable();
        let pick = |p: f64| Duration::from_nanos(crate::benchutil::nearest_rank(&l, p));
        (pick(0.5), pick(0.95), pick(1.0))
    }

    /// One-line human-readable summary of every counter.
    pub fn summary(&self) -> String {
        let (p50, p95, max) = self.latency_percentiles();
        format!(
            "submitted {} completed {} failed {} batches {} head_shards {} \
             multi_head {} seqpar {} seq_chunk_shards {} merge_steps {} \
             device_cycles {} dispatch sim/ref/pjrt {}/{}/{} \
             sessions {}/{} decode_steps {} \
             kv hit/miss/evict {}/{}/{} latency p50 {:?} p95 {:?} max {:?}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.head_shards.load(Ordering::Relaxed),
            self.multi_head_requests.load(Ordering::Relaxed),
            self.seqpar_requests.load(Ordering::Relaxed),
            self.seq_chunk_shards.load(Ordering::Relaxed),
            self.merge_steps.load(Ordering::Relaxed),
            self.device_cycles.load(Ordering::Relaxed),
            self.sim_dispatches.load(Ordering::Relaxed),
            self.reference_dispatches.load(Ordering::Relaxed),
            self.pjrt_dispatches.load(Ordering::Relaxed),
            self.sessions_opened.load(Ordering::Relaxed),
            self.sessions_closed.load(Ordering::Relaxed),
            self.decode_steps.load(Ordering::Relaxed),
            self.kv_hits.load(Ordering::Relaxed),
            self.kv_misses.load(Ordering::Relaxed),
            self.kv_evictions.load(Ordering::Relaxed),
            p50,
            p95,
            max,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(lat_ms: u64, heads: usize) -> AttentionResponse {
        AttentionResponse {
            id: 0,
            output: Ok(vec![]),
            num_heads: heads,
            num_kv_heads: heads,
            shards: heads,
            seq_chunks: 1,
            merge_steps: 0,
            device_cycles: 100,
            critical_path_cycles: 100,
            device_time: Duration::from_micros(1),
            utilization: 0.3,
            latency: Duration::from_millis(lat_ms),
            device_id: 0,
            devices_used: vec![0],
            bucket: 128,
            kv_hits: 0,
            kv_misses: 0,
            measured_shards: 0,
        }
    }

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=10 {
            m.record(&resp(i, 1), i != 3);
        }
        assert_eq!(m.completed.load(Ordering::Relaxed), 10);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.device_cycles.load(Ordering::Relaxed), 1000);
        let (p50, p95, max) = m.latency_percentiles();
        assert!(p50 >= Duration::from_millis(4) && p50 <= Duration::from_millis(6));
        assert!(p95 >= p50 && max >= p95);
        assert!(m.summary().contains("completed 10"));
    }

    #[test]
    fn shard_accounting_is_separate_from_requests() {
        let m = Metrics::new();
        for _ in 0..8 {
            m.record_shard(25);
        }
        m.record(&resp(1, 8), true);
        assert_eq!(m.head_shards.load(Ordering::Relaxed), 8);
        assert_eq!(m.shard_cycles.load(Ordering::Relaxed), 200);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.multi_head_requests.load(Ordering::Relaxed), 1);
        assert!(m.summary().contains("head_shards 8"));
    }

    #[test]
    fn empty_percentiles_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentiles().0, Duration::ZERO);
    }

    /// Satellite: dispatches are counted per backend kind, split out of
    /// `head_shards`, and surfaced in the summary.
    #[test]
    fn dispatches_counted_per_backend_kind() {
        let m = Metrics::new();
        for _ in 0..3 {
            m.record_dispatch("sim");
        }
        m.record_dispatch("reference");
        m.record_dispatch("pjrt");
        m.record_dispatch("quantum"); // unknown: ignored, not a panic
        let o = Ordering::Relaxed;
        assert_eq!(m.sim_dispatches.load(o), 3);
        assert_eq!(m.reference_dispatches.load(o), 1);
        assert_eq!(m.pjrt_dispatches.load(o), 1);
        assert!(m.summary().contains("dispatch sim/ref/pjrt 3/1/1"), "{}", m.summary());
    }

    /// Satellite: sequence shards and merge steps are counted
    /// distinctly from head shards — a sequence-sharded response bumps
    /// `seqpar_requests`/`merge_steps`, a plain multi-head one does not.
    #[test]
    fn sequence_shards_and_merges_counted_distinctly() {
        let m = Metrics::new();
        let mut r = resp(1, 4);
        r.seq_chunks = 4;
        r.shards = 16;
        r.merge_steps = 12;
        m.record(&r, true);
        m.record(&resp(1, 4), true); // legacy multi-head response
        let o = Ordering::Relaxed;
        assert_eq!(m.seqpar_requests.load(o), 1);
        assert_eq!(m.merge_steps.load(o), 12);
        assert_eq!(m.multi_head_requests.load(o), 2);
        // Worker-side shard counters stay independent.
        m.record_shard(10);
        m.seq_chunk_shards.fetch_add(1, o);
        assert_eq!(m.head_shards.load(o), 1);
        assert_eq!(m.seq_chunk_shards.load(o), 1);
        let s = m.summary();
        assert!(s.contains("seqpar 1") && s.contains("merge_steps 12"), "{s}");
    }

    /// Satellite: nearest-rank percentile selection, pinned on a known
    /// 20-element reservoir (1..=20 ms).  p50 is the 10th smallest,
    /// p95 the 19th, max the 20th.
    #[test]
    fn nearest_rank_percentiles_on_20_element_reservoir() {
        let m = Metrics::new();
        for ms in 1..=20u64 {
            m.record(&resp(ms, 1), true);
        }
        let (p50, p95, max) = m.latency_percentiles();
        assert_eq!(p50, Duration::from_millis(10));
        assert_eq!(p95, Duration::from_millis(19));
        assert_eq!(max, Duration::from_millis(20));
    }

    /// The old `((n-1)·p) as usize` truncation picked the 9th of 10
    /// samples for p95; nearest rank (`ceil(0.95·10) = 10`) picks the
    /// 10th.
    #[test]
    fn p95_is_not_truncated_low_on_small_reservoirs() {
        let m = Metrics::new();
        for ms in 1..=10u64 {
            m.record(&resp(ms, 1), true);
        }
        let (p50, p95, _) = m.latency_percentiles();
        assert_eq!(p50, Duration::from_millis(5));
        assert_eq!(p95, Duration::from_millis(10));
        // Single-sample reservoir: every percentile is that sample.
        let one = Metrics::new();
        one.record(&resp(3, 1), true);
        assert_eq!(one.latency_percentiles(), (
            Duration::from_millis(3),
            Duration::from_millis(3),
            Duration::from_millis(3),
        ));
    }
}
