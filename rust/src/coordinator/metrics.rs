//! Serving metrics: counters, per-op-kind SLO histograms, a bounded
//! latency reservoir, and a machine-readable snapshot (DESIGN.md §9).
//!
//! Three granularities are tracked, matching the sharded request path:
//! whole requests (`submitted`/`completed`/`failed`, latency
//! percentiles, aggregate device cycles), executed shards
//! (`head_shards`, `shard_cycles`), and — distinctly — the
//! sequence-parallel dimension (`seqpar_requests`, `seq_chunk_shards`,
//! `merge_steps`, DESIGN.md §7), so an 8-head request sharded 4 ways
//! along the sequence counts once in `completed`, 32 times in
//! `head_shards`, 32 times in `seq_chunk_shards`, and 24 times in
//! `merge_steps`.  (Before sequence sharding, `head_shards` silently
//! conflated every future shard kind.)
//!
//! SLO layer: every completion also lands its latency in the
//! [`OpKind`]-indexed log-scale [`Histogram`] — prefill latency *is*
//! time-to-first-token (TTFT), decode latency *is* time-per-output-token
//! (TPOT) — the scheduler records queue depth both at every admit and
//! once per working iteration (steady-state queueing, not just arrival
//! bursts), batch occupancy at every dispatched wave, and device
//! workers gauge their KV-cache page occupancy.  The `sched_*` and
//! wave-mix counters expose the continuous serving loop's decisions
//! (DESIGN.md §10): at quiescence
//! `sched_admitted = sched_queued − sched_rejected`.  [`Metrics::snapshot`]
//! freezes all of it into a [`MetricsSnapshot`] whose
//! [`MetricsSnapshot::to_json`] is the `fsa serve --metrics-json` /
//! `BENCH_serving.json` schema.
//!
//! The latency reservoir is bounded uniform sampling (Vitter's
//! Algorithm R): past [`DEFAULT_LATENCY_CAPACITY`] samples, each new
//! offer displaces a random retained one with probability `cap/seen`,
//! keeping the retained set a uniform sample of *everything* offered.
//! (It previously just stopped pushing at capacity — long runs reported
//! percentiles of only their first 65536 requests, silently.)  Offers
//! past capacity are counted in `latency_drops`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::numerics::SplitMix64;
use crate::telemetry::{json::Json, Histogram};

use super::request::{AttentionResponse, OpKind};
#[cfg(test)]
use super::request::ResponseStats;

/// Default bound on retained latency samples (the reservoir keeps a
/// uniform sample past this; [`Metrics::with_latency_capacity`] shrinks
/// it for tests).
pub const DEFAULT_LATENCY_CAPACITY: usize = 65536;

/// Bounded uniform reservoir (Vitter's Algorithm R) over `u64` samples.
#[derive(Debug)]
struct Reservoir {
    cap: usize,
    samples: Vec<u64>,
    /// Samples offered over the whole run (not just retained).
    seen: u64,
    rng: SplitMix64,
}

impl Default for Reservoir {
    fn default() -> Reservoir {
        Reservoir::new(DEFAULT_LATENCY_CAPACITY)
    }
}

impl Reservoir {
    fn new(cap: usize) -> Reservoir {
        Reservoir {
            cap: cap.max(1),
            samples: Vec::new(),
            seen: 0,
            rng: SplitMix64::new(0x5EED_CAFE),
        }
    }

    /// Offer one sample.  Returns `true` when the reservoir was already
    /// full — the offer was *sampled* (kept with probability
    /// `cap/seen`, displacing a uniform victim) rather than retained
    /// verbatim.
    fn offer(&mut self, v: u64) -> bool {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
            false
        } else {
            let j = self.rng.next_below(self.seen);
            if (j as usize) < self.cap {
                self.samples[j as usize] = v;
            }
            true
        }
    }
}

#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted by `Coordinator::submit`.
    pub submitted: AtomicUsize,
    /// Requests answered (one per gathered response).
    pub completed: AtomicUsize,
    /// Requests whose gathered output was an error.
    pub failed: AtomicUsize,
    /// Device batches (waves) dispatched by the scheduler.
    pub batches: AtomicUsize,
    /// Shards executed by device workers (one per `(head, chunk)` grid
    /// cell).
    pub head_shards: AtomicUsize,
    /// Requests with more than one query head.
    pub multi_head_requests: AtomicUsize,
    /// Requests served sequence-sharded (`seq_chunks > 1`,
    /// DESIGN.md §7).
    pub seqpar_requests: AtomicUsize,
    /// Sequence-chunk shards executed by device workers (partial
    /// results merged at gather) — counted distinctly from
    /// `head_shards`, which they are a subset of.
    pub seq_chunk_shards: AtomicUsize,
    /// Online-softmax merge steps performed at gather.
    pub merge_steps: AtomicU64,
    /// Total simulated device cycles consumed (summed across shards).
    pub device_cycles: AtomicU64,
    /// Simulated device cycles as counted per shard at execution time;
    /// equals `device_cycles` once all gathers have completed (asserted
    /// by the coordinator tests).
    pub shard_cycles: AtomicU64,
    /// Sessions opened by prefill (decode-phase serving, DESIGN.md §5).
    pub sessions_opened: AtomicUsize,
    /// Sessions retired by close.
    pub sessions_closed: AtomicUsize,
    /// Decode steps admitted (one per validated decode request).
    pub decode_steps: AtomicUsize,
    /// Scheduler iterations that had work in hand (ingested something,
    /// or held waiting entries / open shard groups).  Idle timeout
    /// ticks are not counted — the queue-depth histogram reflects
    /// steady-state queueing, not a flood of idle zeros.
    pub sched_iterations: AtomicU64,
    /// Envelopes ingested from the coordinator ingress into the wait
    /// queue.
    pub sched_queued: AtomicU64,
    /// Envelopes admitted past the budget + lifecycle gates and
    /// dispatched to the pool.
    pub sched_admitted: AtomicU64,
    /// Envelopes answered inline instead of dispatched: token-budget
    /// rejections, capability/lifecycle rejections, and close replies.
    /// At quiescence `sched_admitted = sched_queued − sched_rejected`.
    pub sched_rejected: AtomicU64,
    /// Dispatched waves containing at least one prefill-class
    /// (stateless or prefill) shard.
    pub prefill_waves: AtomicU64,
    /// Dispatched waves containing at least one decode shard.
    pub decode_waves: AtomicU64,
    /// Decode-carrying waves whose decode shards span more than one
    /// session — the continuous-batching payoff made countable.
    pub multi_session_decode_waves: AtomicU64,
    /// Shards dispatched to the cycle-accurate sim backend
    /// (DESIGN.md §8).  The dispatch counters split `head_shards` by
    /// executing engine, so a mixed fleet (or a config mistake) is
    /// visible in the summary.
    pub sim_dispatches: AtomicUsize,
    /// Shards dispatched to the in-crate reference twin.
    pub reference_dispatches: AtomicUsize,
    /// Shards dispatched to the PJRT artifact runtime.
    pub pjrt_dispatches: AtomicUsize,
    /// Dispatches whose backend name matched no known engine — always a
    /// bug somewhere, so it is counted loudly instead of ignored (the
    /// old `_ => 0` arm dropped them silently).
    pub unknown_dispatches: AtomicUsize,
    /// Decode shards served from KV-cache pages.
    pub kv_hits: AtomicU64,
    /// Decode shards that took the recompute fallback.
    pub kv_misses: AtomicU64,
    /// Live KV streams evicted from device caches under capacity
    /// pressure.
    pub kv_evictions: AtomicU64,
    /// Prefill admissions whose hash-chain walk found a cached prefix
    /// (DESIGN.md §11).  Only counted while the prefix cache is
    /// enabled, so `hits / (hits + misses)` is the true hit rate.
    pub prefix_hits: AtomicU64,
    /// Prefill admissions that found no cached prefix.
    pub prefix_misses: AtomicU64,
    /// KV pages attached by content match instead of copied (summed
    /// over completed requests).
    pub prefix_attached_pages: AtomicU64,
    /// Copy-on-write tail copies on the device caches.
    pub cow_copies: AtomicU64,
    /// Modeled device cycles resumed prefills avoided vs. cold runs.
    pub saved_prefill_cycles: AtomicU64,
    /// Sim-backend program lookups served from the compiled-program
    /// cache (DESIGN.md §12); harvested per batch from
    /// [`Backend::take_hotpath_stats`](crate::runtime::Backend::take_hotpath_stats).
    pub prog_cache_hits: AtomicU64,
    /// Sim-backend program lookups that ran the ISA builder (== programs
    /// actually built, in both cache-on and cache-off modes).
    pub prog_cache_misses: AtomicU64,
    /// Fresh sim machine allocations (first shard, reuse-off mode, or a
    /// grow-on-demand replacement).
    pub machines_allocated: AtomicU64,
    /// Latency samples offered to the reservoir (every completion).
    pub latency_samples: AtomicU64,
    /// Offers past reservoir capacity: retained only by uniform
    /// sampling, not verbatim (the explicit drop counter the old
    /// silent `len() < cap` guard lacked).
    pub latency_drops: AtomicU64,
    /// Exact maximum latency ns (the reservoir may displace its max).
    latency_max_ns: AtomicU64,
    /// Host latencies in ns (bounded uniform reservoir).
    latencies_ns: Mutex<Reservoir>,
    /// Per-[`OpKind`] completion latency histograms, indexed by
    /// [`OpKind::index`].  Prefill is TTFT, decode is TPOT.
    kind_latency: [Histogram; 4],
    /// Queue depth observed at each admit and once per working
    /// scheduler iteration (submitted − completed, resp. wait-queue
    /// length).
    queue_depth: Histogram,
    /// Shards per dispatched wave (batch occupancy).
    batch_occupancy: Histogram,
    /// Per-device KV-cache page occupancy `(used, capacity)`, gauged by
    /// workers after each batch.
    kv_gauges: Mutex<BTreeMap<usize, (usize, usize)>>,
}

/// The `(count, mean, p50, p95, p99, max)` bundle of one latency/depth
/// distribution, as serialized into snapshots.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HistStats {
    pub count: u64,
    pub mean: f64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub max: u64,
}

impl HistStats {
    fn of(h: &Histogram) -> HistStats {
        let (count, mean, p50, p95, p99, max) = h.stats();
        HistStats { count, mean, p50, p95, p99, max }
    }

    fn to_json(self) -> Json {
        let mut j = Json::obj();
        j.set("count", Json::u64(self.count))
            .set("mean", Json::Num(self.mean))
            .set("p50", Json::u64(self.p50))
            .set("p95", Json::u64(self.p95))
            .set("p99", Json::u64(self.p99))
            .set("max", Json::u64(self.max));
        j
    }
}

/// A frozen copy of every metric, ready for JSON serialization — the
/// `fsa serve --metrics-json` and `BENCH_serving.json` schema
/// (DESIGN.md §9).
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Flat monotonic counters, in declaration order.
    pub counters: Vec<(&'static str, u64)>,
    /// Whole-pool completion latency (ns) from the reservoir: exact
    /// count/max, uniform-sample percentiles.
    pub latency_ns: HistStats,
    /// Per-[`OpKind`] completion latency (ns), [`OpKind::ALL`] order.
    /// `prefill` is TTFT, `decode` is TPOT.
    pub op_kinds: Vec<(&'static str, HistStats)>,
    /// Queue depth at admit and per working scheduler iteration.
    pub queue_depth: HistStats,
    /// Shards per dispatched wave.
    pub batch_occupancy: HistStats,
    /// Per-device KV page occupancy `(device, used, capacity)`.
    pub kv_gauges: Vec<(usize, usize, usize)>,
}

impl MetricsSnapshot {
    /// Look up a flat counter by name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| *n == name).map(|&(_, v)| v)
    }

    /// The latency stats of one op kind.
    pub fn kind(&self, kind: OpKind) -> HistStats {
        self.op_kinds[kind.index()].1
    }

    /// Serialize (the schema documented in DESIGN.md §9).
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for &(name, v) in &self.counters {
            counters.set(name, Json::u64(v));
        }
        let mut kinds = Json::obj();
        for &(name, stats) in &self.op_kinds {
            kinds.set(name, stats.to_json());
        }
        let kv = self
            .kv_gauges
            .iter()
            .map(|&(dev, used, cap)| {
                let mut g = Json::obj();
                g.set("device", Json::u64(dev as u64))
                    .set("used_pages", Json::u64(used as u64))
                    .set("capacity_pages", Json::u64(cap as u64));
                g
            })
            .collect();
        let mut j = Json::obj();
        j.set("counters", counters)
            .set("latency_ns", self.latency_ns.to_json())
            .set("op_kinds", kinds)
            .set("ttft_ns", self.kind(OpKind::Prefill).to_json())
            .set("tpot_ns", self.kind(OpKind::Decode).to_json())
            .set("queue_depth", self.queue_depth.to_json())
            .set("batch_occupancy", self.batch_occupancy.to_json())
            .set("kv", Json::Arr(kv));
        j
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// A metrics sink whose latency reservoir holds at most `cap`
    /// samples — tests exercise the drop counter without 65537 records.
    pub fn with_latency_capacity(cap: usize) -> Metrics {
        let m = Metrics::new();
        *super::lock(&m.latencies_ns) = Reservoir::new(cap);
        m
    }

    /// Record one executed head shard (called by device workers).
    pub fn record_shard(&self, cycles: u64) {
        self.head_shards.fetch_add(1, Ordering::Relaxed);
        self.shard_cycles.fetch_add(cycles, Ordering::Relaxed);
    }

    /// Count one shard dispatch against the executing backend kind
    /// (`Backend::name`): `sim`, `reference` or `pjrt`.  Unknown names
    /// land in `unknown_dispatches` — counted, never silently ignored.
    pub fn record_dispatch(&self, backend: &str) {
        match backend {
            "sim" => self.sim_dispatches.fetch_add(1, Ordering::Relaxed),
            "reference" => self.reference_dispatches.fetch_add(1, Ordering::Relaxed),
            "pjrt" => self.pjrt_dispatches.fetch_add(1, Ordering::Relaxed),
            _ => self.unknown_dispatches.fetch_add(1, Ordering::Relaxed),
        };
    }

    /// Record an ingress queue depth observation: the scheduler calls
    /// this at every admit (`submitted − completed` at that instant)
    /// AND once per working iteration with the wait-queue length, so
    /// the histogram reflects steady-state queueing rather than
    /// arrival bursts only.
    pub fn record_queue_depth(&self, depth: u64) {
        self.queue_depth.record(depth);
    }

    /// Record the shard count of one dispatched wave (batch occupancy).
    pub fn record_batch_occupancy(&self, shards: u64) {
        self.batch_occupancy.record(shards);
    }

    /// Gauge one device's KV-cache page occupancy (called by workers
    /// after each batch).
    pub fn set_kv_gauge(&self, device: usize, used: usize, capacity: usize) {
        super::lock(&self.kv_gauges).insert(device, (used, capacity));
    }

    /// Record one gathered response (called by the completing worker).
    pub fn record(&self, resp: &AttentionResponse, ok: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        if resp.num_heads > 1 {
            self.multi_head_requests.fetch_add(1, Ordering::Relaxed);
        }
        if resp.stats.seq_chunks > 1 {
            self.seqpar_requests.fetch_add(1, Ordering::Relaxed);
        }
        self.merge_steps.fetch_add(resp.stats.merge_steps as u64, Ordering::Relaxed);
        self.prefix_attached_pages
            .fetch_add(resp.stats.prefix_attached_pages as u64, Ordering::Relaxed);
        self.cow_copies.fetch_add(resp.stats.cow_copies as u64, Ordering::Relaxed);
        self.saved_prefill_cycles
            .fetch_add(resp.stats.saved_prefill_cycles, Ordering::Relaxed);
        self.device_cycles.fetch_add(resp.device_cycles, Ordering::Relaxed);
        let ns = resp.latency.as_nanos() as u64;
        self.kind_latency[resp.kind.index()].record(ns);
        self.latency_samples.fetch_add(1, Ordering::Relaxed);
        self.latency_max_ns.fetch_max(ns, Ordering::Relaxed);
        if super::lock(&self.latencies_ns).offer(ns) {
            self.latency_drops.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Nearest-rank percentile of the latency reservoir (exact until
    /// the reservoir fills, a uniform-sample estimate after).
    pub fn latency_percentile(&self, p: f64) -> Duration {
        let mut l = super::lock(&self.latencies_ns).samples.clone();
        if l.is_empty() {
            return Duration::ZERO;
        }
        l.sort_unstable();
        Duration::from_nanos(crate::benchutil::nearest_rank(&l, p))
    }

    /// (p50, p95, max) host latency, nearest-rank selection: percentile
    /// `p` of `n` samples is the `ceil(p·n)`-th smallest — one shared
    /// implementation with the bench harness
    /// ([`crate::benchutil::nearest_rank`]) so both report the same
    /// statistic.  (The old `((n-1)·p) as usize` truncation biased p95
    /// low on small reservoirs — e.g. the 9th of 10 samples instead of
    /// the 10th.)
    pub fn latency_percentiles(&self) -> (Duration, Duration, Duration) {
        let mut l = super::lock(&self.latencies_ns).samples.clone();
        if l.is_empty() {
            return (Duration::ZERO, Duration::ZERO, Duration::ZERO);
        }
        l.sort_unstable();
        let pick = |p: f64| Duration::from_nanos(crate::benchutil::nearest_rank(&l, p));
        (pick(0.5), pick(0.95), pick(1.0))
    }

    /// Freeze every metric into a serializable [`MetricsSnapshot`].
    pub fn snapshot(&self) -> MetricsSnapshot {
        let o = Ordering::Relaxed;
        let counters = vec![
            ("submitted", self.submitted.load(o) as u64),
            ("completed", self.completed.load(o) as u64),
            ("failed", self.failed.load(o) as u64),
            ("batches", self.batches.load(o) as u64),
            ("head_shards", self.head_shards.load(o) as u64),
            ("multi_head_requests", self.multi_head_requests.load(o) as u64),
            ("seqpar_requests", self.seqpar_requests.load(o) as u64),
            ("seq_chunk_shards", self.seq_chunk_shards.load(o) as u64),
            ("merge_steps", self.merge_steps.load(o)),
            ("device_cycles", self.device_cycles.load(o)),
            ("shard_cycles", self.shard_cycles.load(o)),
            ("sessions_opened", self.sessions_opened.load(o) as u64),
            ("sessions_closed", self.sessions_closed.load(o) as u64),
            ("decode_steps", self.decode_steps.load(o) as u64),
            ("sched_iterations", self.sched_iterations.load(o)),
            ("sched_queued", self.sched_queued.load(o)),
            ("sched_admitted", self.sched_admitted.load(o)),
            ("sched_rejected", self.sched_rejected.load(o)),
            ("prefill_waves", self.prefill_waves.load(o)),
            ("decode_waves", self.decode_waves.load(o)),
            ("multi_session_decode_waves", self.multi_session_decode_waves.load(o)),
            ("sim_dispatches", self.sim_dispatches.load(o) as u64),
            ("reference_dispatches", self.reference_dispatches.load(o) as u64),
            ("pjrt_dispatches", self.pjrt_dispatches.load(o) as u64),
            ("unknown_dispatches", self.unknown_dispatches.load(o) as u64),
            ("kv_hits", self.kv_hits.load(o)),
            ("kv_misses", self.kv_misses.load(o)),
            ("kv_evictions", self.kv_evictions.load(o)),
            ("latency_samples", self.latency_samples.load(o)),
            ("latency_drops", self.latency_drops.load(o)),
            // Prefix-cache counters (DESIGN.md §11) — appended after the
            // historical names so existing schema consumers keep working.
            ("prefix_hits", self.prefix_hits.load(o)),
            ("prefix_misses", self.prefix_misses.load(o)),
            ("prefix_attached_pages", self.prefix_attached_pages.load(o)),
            ("cow_copies", self.cow_copies.load(o)),
            ("saved_prefill_cycles", self.saved_prefill_cycles.load(o)),
            // Hot-path counters (DESIGN.md §12) — appended after the
            // historical names so existing schema consumers keep working.
            ("prog_cache_hits", self.prog_cache_hits.load(o)),
            ("prog_cache_misses", self.prog_cache_misses.load(o)),
            ("machines_allocated", self.machines_allocated.load(o)),
        ];
        let latency_ns = {
            let res = super::lock(&self.latencies_ns);
            let mut l = res.samples.clone();
            drop(res);
            l.sort_unstable();
            let pick = |p: f64| {
                if l.is_empty() { 0 } else { crate::benchutil::nearest_rank(&l, p) }
            };
            let mean = if l.is_empty() {
                0.0
            } else {
                l.iter().sum::<u64>() as f64 / l.len() as f64
            };
            HistStats {
                count: self.latency_samples.load(o),
                mean,
                p50: pick(0.50),
                p95: pick(0.95),
                p99: pick(0.99),
                max: self.latency_max_ns.load(o),
            }
        };
        MetricsSnapshot {
            counters,
            latency_ns,
            op_kinds: OpKind::ALL
                .iter()
                .map(|k| (k.name(), HistStats::of(&self.kind_latency[k.index()])))
                .collect(),
            queue_depth: HistStats::of(&self.queue_depth),
            batch_occupancy: HistStats::of(&self.batch_occupancy),
            kv_gauges: super::lock(&self.kv_gauges)
                .iter()
                .map(|(&dev, &(used, cap))| (dev, used, cap))
                .collect(),
        }
    }

    /// One-line human-readable summary of every counter.
    pub fn summary(&self) -> String {
        let (p50, p95, max) = self.latency_percentiles();
        format!(
            "submitted {} completed {} failed {} batches {} head_shards {} \
             multi_head {} seqpar {} seq_chunk_shards {} merge_steps {} \
             device_cycles {} dispatch sim/ref/pjrt/unknown {}/{}/{}/{} \
             sessions {}/{} decode_steps {} \
             sched iter/queued/admitted/rejected {}/{}/{}/{} \
             waves prefill/decode/multi_session {}/{}/{} \
             kv hit/miss/evict {}/{}/{} \
             prefix hit/miss/attached/cow {}/{}/{}/{} saved_cycles {} \
             prog_cache hit/miss {}/{} machines {} \
             latency p50 {:?} p95 {:?} max {:?} \
             drops {}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.head_shards.load(Ordering::Relaxed),
            self.multi_head_requests.load(Ordering::Relaxed),
            self.seqpar_requests.load(Ordering::Relaxed),
            self.seq_chunk_shards.load(Ordering::Relaxed),
            self.merge_steps.load(Ordering::Relaxed),
            self.device_cycles.load(Ordering::Relaxed),
            self.sim_dispatches.load(Ordering::Relaxed),
            self.reference_dispatches.load(Ordering::Relaxed),
            self.pjrt_dispatches.load(Ordering::Relaxed),
            self.unknown_dispatches.load(Ordering::Relaxed),
            self.sessions_opened.load(Ordering::Relaxed),
            self.sessions_closed.load(Ordering::Relaxed),
            self.decode_steps.load(Ordering::Relaxed),
            self.sched_iterations.load(Ordering::Relaxed),
            self.sched_queued.load(Ordering::Relaxed),
            self.sched_admitted.load(Ordering::Relaxed),
            self.sched_rejected.load(Ordering::Relaxed),
            self.prefill_waves.load(Ordering::Relaxed),
            self.decode_waves.load(Ordering::Relaxed),
            self.multi_session_decode_waves.load(Ordering::Relaxed),
            self.kv_hits.load(Ordering::Relaxed),
            self.kv_misses.load(Ordering::Relaxed),
            self.kv_evictions.load(Ordering::Relaxed),
            self.prefix_hits.load(Ordering::Relaxed),
            self.prefix_misses.load(Ordering::Relaxed),
            self.prefix_attached_pages.load(Ordering::Relaxed),
            self.cow_copies.load(Ordering::Relaxed),
            self.saved_prefill_cycles.load(Ordering::Relaxed),
            self.prog_cache_hits.load(Ordering::Relaxed),
            self.prog_cache_misses.load(Ordering::Relaxed),
            self.machines_allocated.load(Ordering::Relaxed),
            p50,
            p95,
            max,
            self.latency_drops.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(lat_ms: u64, heads: usize) -> AttentionResponse {
        AttentionResponse {
            id: 0,
            output: Ok(vec![]),
            num_heads: heads,
            num_kv_heads: heads,
            shards: heads,
            device_cycles: 100,
            critical_path_cycles: 100,
            device_time: Duration::from_micros(1),
            utilization: 0.3,
            latency: Duration::from_millis(lat_ms),
            device_id: 0,
            devices_used: vec![0],
            bucket: 128,
            kind: OpKind::Stateless,
            stats: ResponseStats { seq_chunks: 1, ..ResponseStats::default() },
        }
    }

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=10 {
            m.record(&resp(i, 1), i != 3);
        }
        assert_eq!(m.completed.load(Ordering::Relaxed), 10);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.device_cycles.load(Ordering::Relaxed), 1000);
        let (p50, p95, max) = m.latency_percentiles();
        assert!(p50 >= Duration::from_millis(4) && p50 <= Duration::from_millis(6));
        assert!(p95 >= p50 && max >= p95);
        assert!(m.summary().contains("completed 10"));
    }

    #[test]
    fn shard_accounting_is_separate_from_requests() {
        let m = Metrics::new();
        for _ in 0..8 {
            m.record_shard(25);
        }
        m.record(&resp(1, 8), true);
        assert_eq!(m.head_shards.load(Ordering::Relaxed), 8);
        assert_eq!(m.shard_cycles.load(Ordering::Relaxed), 200);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.multi_head_requests.load(Ordering::Relaxed), 1);
        assert!(m.summary().contains("head_shards 8"));
    }

    #[test]
    fn empty_percentiles_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentiles().0, Duration::ZERO);
        assert_eq!(m.latency_percentile(0.99), Duration::ZERO);
    }

    /// Satellite: dispatches are counted per backend kind, split out of
    /// `head_shards`, and surfaced in the summary; unknown names are
    /// counted loudly instead of silently ignored.
    #[test]
    fn dispatches_counted_per_backend_kind() {
        let m = Metrics::new();
        for _ in 0..3 {
            m.record_dispatch("sim");
        }
        m.record_dispatch("reference");
        m.record_dispatch("pjrt");
        m.record_dispatch("quantum"); // unknown: counted, not dropped
        let o = Ordering::Relaxed;
        assert_eq!(m.sim_dispatches.load(o), 3);
        assert_eq!(m.reference_dispatches.load(o), 1);
        assert_eq!(m.pjrt_dispatches.load(o), 1);
        assert_eq!(m.unknown_dispatches.load(o), 1);
        assert!(
            m.summary().contains("dispatch sim/ref/pjrt/unknown 3/1/1/1"),
            "{}",
            m.summary()
        );
    }

    /// Satellite: sequence shards and merge steps are counted
    /// distinctly from head shards — a sequence-sharded response bumps
    /// `seqpar_requests`/`merge_steps`, a plain multi-head one does not.
    #[test]
    fn sequence_shards_and_merges_counted_distinctly() {
        let m = Metrics::new();
        let mut r = resp(1, 4);
        r.stats.seq_chunks = 4;
        r.shards = 16;
        r.stats.merge_steps = 12;
        m.record(&r, true);
        m.record(&resp(1, 4), true); // legacy multi-head response
        let o = Ordering::Relaxed;
        assert_eq!(m.seqpar_requests.load(o), 1);
        assert_eq!(m.merge_steps.load(o), 12);
        assert_eq!(m.multi_head_requests.load(o), 2);
        // Worker-side shard counters stay independent.
        m.record_shard(10);
        m.seq_chunk_shards.fetch_add(1, o);
        assert_eq!(m.head_shards.load(o), 1);
        assert_eq!(m.seq_chunk_shards.load(o), 1);
        let s = m.summary();
        assert!(s.contains("seqpar 1") && s.contains("merge_steps 12"), "{s}");
    }

    /// Satellite: nearest-rank percentile selection, pinned on a known
    /// 20-element reservoir (1..=20 ms).  p50 is the 10th smallest,
    /// p95 the 19th, max the 20th.
    #[test]
    fn nearest_rank_percentiles_on_20_element_reservoir() {
        let m = Metrics::new();
        for ms in 1..=20u64 {
            m.record(&resp(ms, 1), true);
        }
        let (p50, p95, max) = m.latency_percentiles();
        assert_eq!(p50, Duration::from_millis(10));
        assert_eq!(p95, Duration::from_millis(19));
        assert_eq!(max, Duration::from_millis(20));
        assert_eq!(m.latency_percentile(0.99), Duration::from_millis(20));
    }

    /// The old `((n-1)·p) as usize` truncation picked the 9th of 10
    /// samples for p95; nearest rank (`ceil(0.95·10) = 10`) picks the
    /// 10th.
    #[test]
    fn p95_is_not_truncated_low_on_small_reservoirs() {
        let m = Metrics::new();
        for ms in 1..=10u64 {
            m.record(&resp(ms, 1), true);
        }
        let (p50, p95, _) = m.latency_percentiles();
        assert_eq!(p50, Duration::from_millis(5));
        assert_eq!(p95, Duration::from_millis(10));
        // Single-sample reservoir: every percentile is that sample.
        let one = Metrics::new();
        one.record(&resp(3, 1), true);
        assert_eq!(one.latency_percentiles(), (
            Duration::from_millis(3),
            Duration::from_millis(3),
            Duration::from_millis(3),
        ));
    }

    /// Satellite: the reservoir no longer silently stops recording at
    /// capacity — past it, offers are uniform-sampled and the drop
    /// counter says exactly how many were not retained verbatim.
    #[test]
    fn reservoir_bounds_memory_and_counts_drops() {
        let m = Metrics::with_latency_capacity(8);
        for ms in 1..=20u64 {
            m.record(&resp(ms, 1), true);
        }
        let o = Ordering::Relaxed;
        assert_eq!(m.latency_samples.load(o), 20);
        assert_eq!(m.latency_drops.load(o), 12, "20 offers, 8 retained slots");
        let res = crate::coordinator::lock(&m.latencies_ns);
        assert_eq!(res.samples.len(), 8, "memory stays bounded");
        assert_eq!(res.seen, 20);
        // Every retained sample is a genuine offer (1..=20 ms in ns).
        assert!(res.samples.iter().all(|&v| v >= 1_000_000 && v <= 20_000_000));
        drop(res);
        // The exact max survives even if the reservoir displaced it.
        assert_eq!(m.snapshot().latency_ns.max, 20_000_000);
        assert!(m.summary().contains("drops 12"), "{}", m.summary());
    }

    /// Later samples really do displace earlier ones (Algorithm R keeps
    /// a uniform sample of the whole stream, not a prefix).
    #[test]
    fn reservoir_sampling_admits_late_samples() {
        let m = Metrics::with_latency_capacity(4);
        for _ in 0..4 {
            m.record(&resp(1, 1), true);
        }
        for _ in 0..400 {
            m.record(&resp(1000, 1), true);
        }
        let res = crate::coordinator::lock(&m.latencies_ns);
        assert!(
            res.samples.iter().any(|&v| v == 1_000_000_000),
            "400 late offers against 4 slots: some must have displaced \
             the prefix (P[none] < 1e-60)"
        );
    }

    /// Per-op-kind histograms split latency by SLO class: prefill
    /// feeds TTFT, decode feeds TPOT.
    #[test]
    fn op_kind_latency_histograms() {
        let m = Metrics::new();
        let mut pre = resp(8, 1);
        pre.kind = OpKind::Prefill;
        m.record(&pre, true);
        for _ in 0..3 {
            let mut dec = resp(2, 1);
            dec.kind = OpKind::Decode;
            m.record(&dec, true);
        }
        let snap = m.snapshot();
        assert_eq!(snap.kind(OpKind::Prefill).count, 1);
        assert_eq!(snap.kind(OpKind::Decode).count, 3);
        assert_eq!(snap.kind(OpKind::Stateless).count, 0);
        // TTFT == prefill stats; TPOT == decode stats; log-bucket
        // percentiles stay within 2x of the true 8 ms / 2 ms.
        let ttft = snap.kind(OpKind::Prefill);
        assert!(ttft.p50 >= 8_000_000 && ttft.p50 <= 16_000_000, "{ttft:?}");
        let tpot = snap.kind(OpKind::Decode);
        assert!(tpot.p50 >= 2_000_000 && tpot.p50 <= 4_000_000, "{tpot:?}");
        assert_eq!(tpot.max, 2_000_000);
    }

    /// Satellite: the snapshot serializes to JSON and parses back with
    /// the same shape and values (via the dependency-free
    /// [`crate::telemetry::json`] round trip).
    #[test]
    fn snapshot_json_round_trip() {
        let m = Metrics::new();
        m.submitted.fetch_add(5, Ordering::Relaxed);
        m.record_dispatch("sim");
        m.record_dispatch("warp"); // unknown
        m.record_queue_depth(3);
        m.record_batch_occupancy(4);
        m.sched_queued.fetch_add(5, Ordering::Relaxed);
        m.sched_admitted.fetch_add(4, Ordering::Relaxed);
        m.sched_rejected.fetch_add(1, Ordering::Relaxed);
        m.set_kv_gauge(0, 7, 64);
        m.set_kv_gauge(2, 0, 64);
        let mut dec = resp(4, 2);
        dec.kind = OpKind::Decode;
        m.record(&dec, true);
        let snap = m.snapshot();
        assert_eq!(snap.counter("submitted"), Some(5));
        assert_eq!(snap.counter("unknown_dispatches"), Some(1));
        assert_eq!(snap.counter("nonsense"), None);

        let text = snap.to_json().to_string();
        let back = crate::telemetry::json::parse(&text).unwrap();
        let c = back.get("counters").unwrap();
        assert_eq!(c.get("submitted").unwrap().as_u64(), Some(5));
        assert_eq!(c.get("sim_dispatches").unwrap().as_u64(), Some(1));
        assert_eq!(c.get("unknown_dispatches").unwrap().as_u64(), Some(1));
        assert_eq!(c.get("latency_samples").unwrap().as_u64(), Some(1));
        // Latency block: one 4 ms sample.
        let lat = back.get("latency_ns").unwrap();
        assert_eq!(lat.get("count").unwrap().as_u64(), Some(1));
        assert_eq!(lat.get("p50").unwrap().as_u64(), Some(4_000_000));
        assert_eq!(lat.get("max").unwrap().as_u64(), Some(4_000_000));
        // Op kinds + the TTFT/TPOT aliases.
        let kinds = back.get("op_kinds").unwrap();
        assert_eq!(kinds.get("decode").unwrap().get("count").unwrap().as_u64(), Some(1));
        assert_eq!(kinds.get("prefill").unwrap().get("count").unwrap().as_u64(), Some(0));
        assert_eq!(back.get("tpot_ns").unwrap().get("count").unwrap().as_u64(), Some(1));
        assert_eq!(back.get("ttft_ns").unwrap().get("count").unwrap().as_u64(), Some(0));
        // Scheduler counters reconcile in the serialized form too.
        assert_eq!(c.get("sched_queued").unwrap().as_u64(), Some(5));
        assert_eq!(c.get("sched_admitted").unwrap().as_u64(), Some(4));
        assert_eq!(c.get("sched_rejected").unwrap().as_u64(), Some(1));
        // Queue depth + batch occupancy + KV gauges.
        assert_eq!(back.get("queue_depth").unwrap().get("count").unwrap().as_u64(), Some(1));
        let occ = back.get("batch_occupancy").unwrap();
        assert_eq!(occ.get("count").unwrap().as_u64(), Some(1));
        assert!(occ.get("p50").unwrap().as_u64().unwrap() >= 4);
        let kv = back.get("kv").unwrap().as_arr().unwrap();
        assert_eq!(kv.len(), 2);
        assert_eq!(kv[0].get("device").unwrap().as_u64(), Some(0));
        assert_eq!(kv[0].get("used_pages").unwrap().as_u64(), Some(7));
        assert_eq!(kv[1].get("device").unwrap().as_u64(), Some(2));
        // The pretty form parses identically.
        let pretty = crate::telemetry::json::parse(&snap.to_json().pretty()).unwrap();
        assert_eq!(
            pretty.get("counters").unwrap().get("submitted").unwrap().as_u64(),
            Some(5)
        );
    }

    /// Prefix-cache counters flow from [`ResponseStats`] into the
    /// snapshot and summary (DESIGN.md §11).
    #[test]
    fn prefix_cache_counters_flow_from_stats_to_snapshot() {
        let m = Metrics::new();
        let o = Ordering::Relaxed;
        m.prefix_hits.fetch_add(3, o);
        m.prefix_misses.fetch_add(1, o);
        let mut r = resp(1, 1);
        r.kind = OpKind::Prefill;
        r.stats.prefix_reused_tokens = 32;
        r.stats.prefix_attached_pages = 2;
        r.stats.cow_copies = 1;
        r.stats.saved_prefill_cycles = 1234;
        m.record(&r, true);
        let snap = m.snapshot();
        assert_eq!(snap.counter("prefix_hits"), Some(3));
        assert_eq!(snap.counter("prefix_misses"), Some(1));
        assert_eq!(snap.counter("prefix_attached_pages"), Some(2));
        assert_eq!(snap.counter("cow_copies"), Some(1));
        assert_eq!(snap.counter("saved_prefill_cycles"), Some(1234));
        // The historical counter names stay where consumers expect them.
        assert!(snap.counter("kv_hits").is_some());
        assert!(snap.counter("latency_drops").is_some());
        let s = m.summary();
        assert!(s.contains("prefix hit/miss/attached/cow 3/1/2/1"), "{s}");
        assert!(s.contains("saved_cycles 1234"), "{s}");
    }

    /// Satellite (DESIGN.md §12): the hot-path counters the workers
    /// harvest from `Backend::take_hotpath_stats` surface in both the
    /// snapshot and the one-line summary.
    #[test]
    fn hotpath_counters_flow_to_snapshot_and_summary() {
        let m = Metrics::new();
        let o = Ordering::Relaxed;
        m.prog_cache_hits.fetch_add(7, o);
        m.prog_cache_misses.fetch_add(2, o);
        m.machines_allocated.fetch_add(3, o);
        let snap = m.snapshot();
        assert_eq!(snap.counter("prog_cache_hits"), Some(7));
        assert_eq!(snap.counter("prog_cache_misses"), Some(2));
        assert_eq!(snap.counter("machines_allocated"), Some(3));
        // The historical counter names stay where consumers expect them.
        assert!(snap.counter("saved_prefill_cycles").is_some());
        let s = m.summary();
        assert!(s.contains("prog_cache hit/miss 7/2 machines 3"), "{s}");
    }

    /// Satellite: the continuous-scheduler counters and the
    /// batch-occupancy histogram surface in both the snapshot and the
    /// one-line summary.
    #[test]
    fn scheduler_counters_and_batch_occupancy() {
        let m = Metrics::new();
        let o = Ordering::Relaxed;
        m.sched_iterations.fetch_add(7, o);
        m.sched_queued.fetch_add(10, o);
        m.sched_admitted.fetch_add(8, o);
        m.sched_rejected.fetch_add(2, o);
        m.prefill_waves.fetch_add(3, o);
        m.decode_waves.fetch_add(4, o);
        m.multi_session_decode_waves.fetch_add(2, o);
        m.record_batch_occupancy(2);
        m.record_batch_occupancy(6);
        let snap = m.snapshot();
        assert_eq!(snap.counter("sched_iterations"), Some(7));
        assert_eq!(
            snap.counter("sched_admitted").unwrap(),
            snap.counter("sched_queued").unwrap() - snap.counter("sched_rejected").unwrap(),
            "reconciliation: admitted = queued - rejected"
        );
        assert_eq!(snap.counter("prefill_waves"), Some(3));
        assert_eq!(snap.counter("decode_waves"), Some(4));
        assert_eq!(snap.counter("multi_session_decode_waves"), Some(2));
        assert_eq!(snap.batch_occupancy.count, 2);
        assert_eq!(snap.batch_occupancy.max, 6);
        let s = m.summary();
        assert!(s.contains("sched iter/queued/admitted/rejected 7/10/8/2"), "{s}");
        assert!(s.contains("waves prefill/decode/multi_session 3/4/2"), "{s}");
    }
}
