//! Serving metrics: counters + latency reservoir, lock-light.
//!
//! Two granularities are tracked, matching the sharded request path:
//! whole requests (`submitted`/`completed`/`failed`, latency
//! percentiles, aggregate device cycles) and per-head shards
//! (`head_shards`, `shard_cycles`) so head-sharded multi-head serving
//! is observable — e.g. an 8-head GQA request counts once in
//! `completed` and eight times in `head_shards`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::request::AttentionResponse;

#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted by `Coordinator::submit`.
    pub submitted: AtomicUsize,
    /// Requests answered (one per gathered response).
    pub completed: AtomicUsize,
    /// Requests whose gathered output was an error.
    pub failed: AtomicUsize,
    /// Device batches dispatched by the batcher.
    pub batches: AtomicUsize,
    /// Per-head shards executed by device workers.
    pub head_shards: AtomicUsize,
    /// Requests with more than one query head.
    pub multi_head_requests: AtomicUsize,
    /// Total simulated device cycles consumed (summed across shards).
    pub device_cycles: AtomicU64,
    /// Simulated device cycles as counted per shard at execution time;
    /// equals `device_cycles` once all gathers have completed (asserted
    /// by the coordinator tests).
    pub shard_cycles: AtomicU64,
    /// Host latencies in ns (bounded reservoir).
    latencies_ns: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one executed head shard (called by device workers).
    pub fn record_shard(&self, cycles: u64) {
        self.head_shards.fetch_add(1, Ordering::Relaxed);
        self.shard_cycles.fetch_add(cycles, Ordering::Relaxed);
    }

    /// Record one gathered response (called by the completing worker).
    pub fn record(&self, resp: &AttentionResponse, ok: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        if resp.num_heads > 1 {
            self.multi_head_requests.fetch_add(1, Ordering::Relaxed);
        }
        self.device_cycles.fetch_add(resp.device_cycles, Ordering::Relaxed);
        let mut l = super::lock(&self.latencies_ns);
        if l.len() < 65536 {
            l.push(resp.latency.as_nanos() as u64);
        }
    }

    /// (p50, p95, max) host latency.
    pub fn latency_percentiles(&self) -> (Duration, Duration, Duration) {
        let mut l = super::lock(&self.latencies_ns).clone();
        if l.is_empty() {
            return (Duration::ZERO, Duration::ZERO, Duration::ZERO);
        }
        l.sort_unstable();
        let pick = |p: f64| Duration::from_nanos(l[((l.len() - 1) as f64 * p) as usize]);
        (pick(0.5), pick(0.95), pick(1.0))
    }

    /// One-line human-readable summary of every counter.
    pub fn summary(&self) -> String {
        let (p50, p95, max) = self.latency_percentiles();
        format!(
            "submitted {} completed {} failed {} batches {} head_shards {} \
             multi_head {} device_cycles {} latency p50 {:?} p95 {:?} max {:?}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.head_shards.load(Ordering::Relaxed),
            self.multi_head_requests.load(Ordering::Relaxed),
            self.device_cycles.load(Ordering::Relaxed),
            p50,
            p95,
            max,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(lat_ms: u64, heads: usize) -> AttentionResponse {
        AttentionResponse {
            id: 0,
            output: Ok(vec![]),
            num_heads: heads,
            num_kv_heads: heads,
            shards: heads,
            device_cycles: 100,
            critical_path_cycles: 100,
            device_time: Duration::from_micros(1),
            utilization: 0.3,
            latency: Duration::from_millis(lat_ms),
            device_id: 0,
            devices_used: vec![0],
            bucket: 128,
        }
    }

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=10 {
            m.record(&resp(i, 1), i != 3);
        }
        assert_eq!(m.completed.load(Ordering::Relaxed), 10);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.device_cycles.load(Ordering::Relaxed), 1000);
        let (p50, p95, max) = m.latency_percentiles();
        assert!(p50 >= Duration::from_millis(4) && p50 <= Duration::from_millis(6));
        assert!(p95 >= p50 && max >= p95);
        assert!(m.summary().contains("completed 10"));
    }

    #[test]
    fn shard_accounting_is_separate_from_requests() {
        let m = Metrics::new();
        for _ in 0..8 {
            m.record_shard(25);
        }
        m.record(&resp(1, 8), true);
        assert_eq!(m.head_shards.load(Ordering::Relaxed), 8);
        assert_eq!(m.shard_cycles.load(Ordering::Relaxed), 200);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.multi_head_requests.load(Ordering::Relaxed), 1);
        assert!(m.summary().contains("head_shards 8"));
    }

    #[test]
    fn empty_percentiles_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentiles().0, Duration::ZERO);
    }
}
