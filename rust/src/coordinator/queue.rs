//! The scheduler's waiting queue (DESIGN.md §10): FIFO of ingressed
//! envelopes that have not yet been admitted onto the shard path.
//!
//! Continuous batching splits the old one-shot batcher in two — this
//! module is the *where requests wait* half, [`super::scheduler`] is
//! the *when they run* half.  The queue itself is policy-free storage
//! plus one operation, [`WaitQueue::pop_wave`]: given the scheduler's
//! per-iteration [`WavePolicy`] (token budgets and the prefill
//! go/no-go decision), it pops the prefix of entries that may run now
//! and returns a [`Verdict`] per popped entry.
//!
//! Ordering invariant — the heart of the bitwise one-shot-equivalence
//! contract: entries of one *session* are never reordered.  When a
//! prefill is deferred (budget or waiting-ratio), every later entry
//! carrying the same session id is deferred with it, so a pipelined
//! `prefill → decode → close` sequence reaches the admission gate in
//! submission order no matter how many waves it waits.  Entries of
//! *different* sessions (and stateless requests) may overtake a
//! deferred prefill — their numerics are independent, so overtaking
//! changes when they run, never what they compute.
//!
//! Budget semantics:
//! * `max_prefill_tokens` caps Σ `seq_len − resumed_from` over the
//!   prefill-class (stateless + prefill) entries admitted in ONE wave —
//!   the uncovered suffix each entry will actually compute; with the
//!   prefix cache off `resumed_from` is always 0 and this is plain
//!   Σ `seq_len`.  An entry whose own suffix exceeds the cap can never
//!   be scheduled and is rejected outright, with an error naming the
//!   knob.
//! * `max_total_tokens` caps live session tokens plus the
//!   prefill-class tokens admitted this wave.  An entry that would
//!   push past it *waits* (sessions close, tokens free up); one that
//!   exceeds it even against an empty pool is rejected.
//! * Decode and close entries are budget-exempt: their sessions were
//!   paid for at prefill admission (sim pools additionally bound
//!   decode growth via `sim_max_seq`, see [`super::batcher`]).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::Envelope;
use super::session::SessionOp;

/// Per-iteration admission inputs, computed by the scheduler from the
/// configured [`RunConfig`](crate::config::RunConfig) budgets and the
/// pool's live state.
#[derive(Clone, Copy, Debug)]
pub struct WavePolicy {
    /// Σ `seq_len` over prefill-class entries admitted per wave
    /// (`RunConfig::max_batch_prefill_tokens`).
    pub max_prefill_tokens: usize,
    /// Live session tokens + this wave's prefill-class tokens
    /// (`RunConfig::max_batch_total_tokens`).
    pub max_total_tokens: usize,
    /// Tokens currently held by open sessions
    /// ([`super::session::SessionTable::live_tokens`]).
    pub live_tokens: usize,
    /// The waiting-ratio decision (see
    /// [`super::scheduler::allow_prefill`]): `false` defers every
    /// prefill-class entry this wave so pending decode steps keep the
    /// array to themselves.
    pub allow_prefill: bool,
}

impl WavePolicy {
    /// The shutdown-flush policy: admit everything still waiting.
    /// Budgets are scheduling policy, not device capability — once the
    /// ingress is gone nothing will ever free tokens, so holding
    /// entries back would strand their clients instead of serving them.
    pub fn flush() -> WavePolicy {
        WavePolicy {
            max_prefill_tokens: usize::MAX,
            max_total_tokens: usize::MAX,
            live_tokens: 0,
            allow_prefill: true,
        }
    }
}

/// What [`WaitQueue::pop_wave`] decided for one popped entry.
pub enum Verdict {
    /// Run it this wave (next stop: the admission gate,
    /// [`super::batcher::admit_session_op`]).
    Admit(Envelope),
    /// It can never fit the configured budgets: answer inline with
    /// this error (which names the knob to raise).
    Reject(Envelope, String),
}

/// Scheduling class of one queued envelope.
enum Class {
    /// Costs `tokens` of both budgets; `session` is `Some` for prefill
    /// ops (whose deferral must block the session's later entries).
    PrefillClass { tokens: usize, session: Option<u64> },
    /// Budget-exempt, but ordered after any deferred entry of the same
    /// session.
    SessionFollowup { session: u64 },
}

/// Budget-relevant token count of a prefill-class entry: the uncovered
/// suffix the devices will actually compute.  `resumed_from` is stamped
/// by the scheduler's prefix match *before* the envelope enters the
/// queue (DESIGN.md §11), so cache-covered tokens stop competing for
/// prefill budget; 0 everywhere the prefix cache is off.
fn suffix_tokens(env: &Envelope) -> usize {
    env.req.seq_len - env.req.resumed_from.min(env.req.seq_len)
}

fn class(env: &Envelope) -> Class {
    match env.req.op {
        SessionOp::Stateless => {
            Class::PrefillClass { tokens: suffix_tokens(env), session: None }
        }
        SessionOp::Prefill { session } => {
            Class::PrefillClass { tokens: suffix_tokens(env), session: Some(session) }
        }
        SessionOp::Decode { session, .. } | SessionOp::Close { session } => {
            Class::SessionFollowup { session }
        }
    }
}

/// The waiting queue: submission-ordered envelopes not yet admitted.
#[derive(Default)]
pub struct WaitQueue {
    entries: VecDeque<Envelope>,
}

impl WaitQueue {
    pub fn new() -> WaitQueue {
        WaitQueue::default()
    }

    /// Append one ingressed envelope (FIFO).
    pub fn push(&mut self, env: Envelope) {
        self.entries.push_back(env);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Σ `seq_len` over waiting prefill-class entries — the numerator
    /// of the waiting-vs-served ratio.
    pub fn waiting_prefill_tokens(&self) -> usize {
        self.entries
            .iter()
            .filter_map(|e| match class(e) {
                Class::PrefillClass { tokens, .. } => Some(tokens),
                Class::SessionFollowup { .. } => None,
            })
            .sum()
    }

    /// Whether any *runnable* decode step is waiting — the case where
    /// admitting a fresh prefill delays live sessions' TPOT.  A decode
    /// queued behind its own session's not-yet-admitted prefill is not
    /// runnable: counting it would let it suppress the very prefill it
    /// waits on (a livelock the timeout bound would otherwise have to
    /// break).
    pub fn has_runnable_decode(&self) -> bool {
        let mut pending_prefill: Vec<u64> = Vec::new();
        for e in &self.entries {
            match e.req.op {
                SessionOp::Prefill { session } => pending_prefill.push(session),
                SessionOp::Decode { session, .. }
                    if !pending_prefill.contains(&session) =>
                {
                    return true;
                }
                _ => {}
            }
        }
        false
    }

    /// How long the oldest waiting prefill-class entry has been queued
    /// (`None` when none is waiting) — the starvation bound's input.
    pub fn oldest_prefill_wait(&self, now: Instant) -> Option<Duration> {
        self.entries
            .iter()
            .filter(|e| matches!(class(e), Class::PrefillClass { .. }))
            .map(|e| now.saturating_duration_since(e.enqueued))
            .max()
    }

    /// Pop this wave's entries under `policy`.  Verdicts come back in
    /// queue order; deferred entries stay queued in their original
    /// relative order.  See the module docs for the deferral/rejection
    /// semantics and the per-session ordering invariant.
    pub fn pop_wave(&mut self, policy: &WavePolicy) -> Vec<Verdict> {
        let mut wave = Vec::new();
        let mut kept: VecDeque<Envelope> = VecDeque::new();
        // Sessions with a deferred entry ahead: everything later for
        // them must wait too (tiny per-wave set; linear scan is fine).
        let mut blocked: Vec<u64> = Vec::new();
        let mut spent = 0usize; // prefill-class tokens admitted this wave
        while let Some(env) = self.entries.pop_front() {
            match class(&env) {
                Class::SessionFollowup { session } => {
                    if blocked.contains(&session) {
                        kept.push_back(env);
                    } else {
                        wave.push(Verdict::Admit(env));
                    }
                }
                Class::PrefillClass { tokens, session } => {
                    if session.map(|s| blocked.contains(&s)).unwrap_or(false) {
                        kept.push_back(env);
                        continue;
                    }
                    if tokens > policy.max_prefill_tokens {
                        wave.push(Verdict::Reject(
                            env,
                            format!(
                                "request of {tokens} tokens exceeds \
                                 max_batch_prefill_tokens ({}): it can never be \
                                 scheduled; raise `[run] max_batch_prefill_tokens` \
                                 / `--max-batch-prefill-tokens` (DESIGN.md §10)",
                                policy.max_prefill_tokens
                            ),
                        ));
                    } else if tokens > policy.max_total_tokens {
                        wave.push(Verdict::Reject(
                            env,
                            format!(
                                "request of {tokens} tokens exceeds \
                                 max_batch_total_tokens ({}) even against an idle \
                                 pool; raise `[run] max_batch_total_tokens` / \
                                 `--max-batch-total-tokens` (DESIGN.md §10)",
                                policy.max_total_tokens
                            ),
                        ));
                    } else if !policy.allow_prefill
                        || spent + tokens > policy.max_prefill_tokens
                        || policy.live_tokens + spent + tokens > policy.max_total_tokens
                    {
                        // Deferred: fits the knobs in principle, just
                        // not this wave.
                        if let Some(s) = session {
                            blocked.push(s);
                        }
                        kept.push_back(env);
                    } else {
                        spent += tokens;
                        wave.push(Verdict::Admit(env));
                    }
                }
            }
        }
        self.entries = kept;
        wave
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::AttentionRequest;
    use std::sync::mpsc;

    fn env(req: AttentionRequest) -> Envelope {
        Envelope { req, reply: mpsc::channel().0, enqueued: Instant::now() }
    }

    fn stateless(id: u64, seq: usize) -> Envelope {
        let d = 2;
        let m = vec![0.0f32; seq * d];
        env(AttentionRequest::new(id, seq, d, m.clone(), m.clone(), m))
    }

    fn prefill(id: u64, session: u64, seq: usize) -> Envelope {
        let d = 2;
        let m = vec![0.0f32; seq * d];
        env(AttentionRequest::prefill(id, session, seq, d, 1, 1, m.clone(), m.clone(), m))
    }

    fn decode(id: u64, session: u64, step: u64) -> Envelope {
        let d = 2;
        env(AttentionRequest::decode(
            id, session, step, d, 1, 1, vec![0.0; d], vec![0.0; d], vec![0.0; d],
        ))
    }

    fn policy(prefill: usize, total: usize, live: usize, allow: bool) -> WavePolicy {
        WavePolicy {
            max_prefill_tokens: prefill,
            max_total_tokens: total,
            live_tokens: live,
            allow_prefill: allow,
        }
    }

    fn ids(wave: &[Verdict]) -> Vec<(u64, bool)> {
        wave.iter()
            .map(|v| match v {
                Verdict::Admit(e) => (e.req.id, true),
                Verdict::Reject(e, _) => (e.req.id, false),
            })
            .collect()
    }

    /// Satellite (admission boundaries): a request exactly at the
    /// prefill cap is admitted; one token over is rejected with an
    /// error naming the knob; at budget zero everything prefill-class
    /// is rejected.
    #[test]
    fn prefill_budget_at_cap_over_cap_and_zero() {
        // Exactly at cap: admitted.
        let mut q = WaitQueue::new();
        q.push(stateless(1, 32));
        let wave = q.pop_wave(&policy(32, 1000, 0, true));
        assert_eq!(ids(&wave), vec![(1, true)]);
        assert!(q.is_empty());

        // One over: rejected outright (it can never fit), and the
        // error names the knob.
        let mut q = WaitQueue::new();
        q.push(stateless(2, 33));
        let wave = q.pop_wave(&policy(32, 1000, 0, true));
        assert_eq!(ids(&wave), vec![(2, false)]);
        match &wave[0] {
            Verdict::Reject(_, msg) => {
                assert!(msg.contains("max_batch_prefill_tokens"), "{msg}");
                assert!(msg.contains("33"), "{msg}");
            }
            Verdict::Admit(_) => panic!("must be rejected"),
        }

        // Zero budget: every prefill-class entry is rejected.
        let mut q = WaitQueue::new();
        q.push(stateless(3, 1));
        q.push(prefill(4, 7, 8));
        let wave = q.pop_wave(&policy(0, 1000, 0, true));
        assert_eq!(ids(&wave), vec![(3, false), (4, false)]);
    }

    /// Two requests that fit individually but not together: the first
    /// is admitted, the second waits for the next wave (deferred, not
    /// rejected).
    #[test]
    fn over_cap_in_aggregate_defers_the_second_entry() {
        let mut q = WaitQueue::new();
        q.push(stateless(1, 20));
        q.push(stateless(2, 20));
        let wave = q.pop_wave(&policy(32, 1000, 0, true));
        assert_eq!(ids(&wave), vec![(1, true)]);
        assert_eq!(q.len(), 1);
        // Next wave (tokens freed): the deferred entry is admitted.
        let wave = q.pop_wave(&policy(32, 1000, 0, true));
        assert_eq!(ids(&wave), vec![(2, true)]);
        assert!(q.is_empty());
    }

    /// Satellite (admission boundaries): the total-token budget counts
    /// live session tokens — at-cap admits, one over defers, and an
    /// entry larger than the whole budget is rejected.
    #[test]
    fn total_budget_counts_live_session_tokens() {
        // 60 live + 4 = 64 == cap: admitted.
        let mut q = WaitQueue::new();
        q.push(stateless(1, 4));
        assert_eq!(ids(&q.pop_wave(&policy(32, 64, 60, true))), vec![(1, true)]);

        // 60 live + 5 = 65 > cap: deferred until sessions close.
        let mut q = WaitQueue::new();
        q.push(stateless(2, 5));
        assert!(q.pop_wave(&policy(32, 64, 60, true)).is_empty());
        assert_eq!(q.len(), 1);
        // Sessions closed (live tokens freed): now admitted.
        assert_eq!(ids(&q.pop_wave(&policy(32, 64, 0, true))), vec![(2, true)]);

        // Larger than the whole budget: rejected, naming the knob.
        let mut q = WaitQueue::new();
        q.push(stateless(3, 100));
        let wave = q.pop_wave(&policy(200, 64, 0, true));
        assert_eq!(ids(&wave), vec![(3, false)]);
        match &wave[0] {
            Verdict::Reject(_, msg) => {
                assert!(msg.contains("max_batch_total_tokens"), "{msg}")
            }
            Verdict::Admit(_) => panic!("must be rejected"),
        }
    }

    /// The per-session ordering invariant: a deferred prefill blocks
    /// the session's later decode, while other sessions' decode steps
    /// overtake freely (their numerics are independent).
    #[test]
    fn deferred_prefill_blocks_its_sessions_followups_only() {
        let mut q = WaitQueue::new();
        q.push(prefill(1, 7, 16)); // deferred below (allow_prefill = false)
        q.push(decode(2, 7, 0)); // same session: must wait behind it
        q.push(decode(3, 9, 4)); // other session: admitted this wave
        let wave = q.pop_wave(&policy(32, 1000, 10, false));
        assert_eq!(ids(&wave), vec![(3, true)]);
        assert_eq!(q.len(), 2, "prefill and its follow-up stay queued");
        // Prefill allowed again: the pair drains in submission order.
        let wave = q.pop_wave(&policy(32, 1000, 10, true));
        assert_eq!(ids(&wave), vec![(1, true), (2, true)]);
        assert!(q.is_empty());
    }

    /// Satellite (prefix cache, DESIGN.md §11): a resumed prefill is
    /// priced at its uncovered suffix, not its full `seq_len` — the
    /// cache-covered tokens stop competing for prefill budget.
    #[test]
    fn resumed_prefill_is_priced_at_its_suffix() {
        let mut q = WaitQueue::new();
        let mut env = prefill(1, 7, 40);
        env.req.resumed_from = 32; // 8-token uncovered suffix
        q.push(env);
        assert_eq!(q.waiting_prefill_tokens(), 8);
        // A budget far below the full length admits it.
        let wave = q.pop_wave(&policy(8, 1000, 0, true));
        assert_eq!(ids(&wave), vec![(1, true)]);
        // One under the suffix still rejects (the error quotes the
        // suffix count, the work the wave would actually run).
        let mut q = WaitQueue::new();
        let mut env = prefill(2, 7, 40);
        env.req.resumed_from = 32;
        q.push(env);
        let wave = q.pop_wave(&policy(7, 1000, 0, true));
        assert_eq!(ids(&wave), vec![(2, false)]);
        match &wave[0] {
            Verdict::Reject(_, msg) => assert!(msg.contains("request of 8 tokens"), "{msg}"),
            Verdict::Admit(_) => panic!("must be rejected"),
        }
    }

    /// `allow_prefill = false` (the waiting-ratio gate) defers every
    /// prefill-class entry, stateless included, without rejecting any.
    #[test]
    fn ratio_gate_defers_prefill_class_without_rejecting() {
        let mut q = WaitQueue::new();
        q.push(stateless(1, 8));
        q.push(prefill(2, 5, 8));
        assert!(q.pop_wave(&policy(32, 1000, 10, false)).is_empty());
        assert_eq!(q.len(), 2);
        let wave = q.pop_wave(&policy(32, 1000, 10, true));
        assert_eq!(ids(&wave), vec![(1, true), (2, true)]);
    }

    /// The shutdown-flush policy admits everything, so no client is
    /// stranded waiting on tokens that will never free.
    #[test]
    fn flush_policy_admits_everything() {
        let mut q = WaitQueue::new();
        q.push(stateless(1, 1_000_000));
        q.push(prefill(2, 7, 64));
        q.push(decode(3, 7, 0));
        let wave = q.pop_wave(&WavePolicy::flush());
        assert_eq!(ids(&wave), vec![(1, true), (2, true), (3, true)]);
        assert!(q.is_empty());
    }

    /// Queue introspection feeding the scheduler's ratio decision.
    #[test]
    fn introspection_counts_prefill_tokens_and_runnable_decodes() {
        let mut q = WaitQueue::new();
        assert_eq!(q.waiting_prefill_tokens(), 0);
        assert!(!q.has_runnable_decode());
        assert!(q.oldest_prefill_wait(Instant::now()).is_none());
        q.push(stateless(1, 8));
        q.push(prefill(2, 7, 16));
        q.push(decode(3, 7, 0));
        assert_eq!(q.len(), 3);
        assert_eq!(q.waiting_prefill_tokens(), 24);
        // Session 7's decode waits on session 7's queued prefill: it is
        // not runnable, so it must not suppress prefill admission.
        assert!(!q.has_runnable_decode());
        // A decode of an already-live session IS runnable.
        q.push(decode(4, 9, 2));
        assert!(q.has_runnable_decode());
        assert!(q.oldest_prefill_wait(Instant::now()).is_some());
    }
}
