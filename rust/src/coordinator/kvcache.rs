//! Per-device paged KV cache (DESIGN.md §5): the device-HBM tier behind
//! decode-phase serving.
//!
//! Each device worker owns one [`KvCache`].  The cached unit is a
//! *stream* — the K/V prefix of one `(session, kv_head)` pair, exactly
//! the granularity the router's KV-head affinity pins to a device — and
//! the allocation unit is a fixed-size *page* of `page_size` tokens
//! (both the K and the V rows of those tokens, vLLM-style).  Capacity
//! is accounted in pages; one page models
//! `page_size · d · 2 (K+V) · 2 B (fp16)` of device HBM.
//!
//! Sequence-parallel serving (DESIGN.md §7) caches one *chunk* of a
//! stream per device; the worker folds the chunk index into the stream
//! key it passes as `kv_head` (`kv_head · seq_shards + chunk`), so this
//! cache stays chunk-agnostic — a stream is whatever contiguous K/V
//! range its owner decided to pin here.
//!
//! Policies ([`EvictionPolicy`]):
//!
//! * `Lru` — when an insert/append needs pages beyond capacity, closed
//!   sessions are reaped first, then whole least-recently-used streams
//!   are evicted (never the stream being grown).  Evicted keys are
//!   returned to the caller so it can clear the router's sticky pins —
//!   the next decode step for that stream takes the explicit cache-miss
//!   fallback (full recompute from the session host tier) and may be
//!   re-placed on a less loaded device.
//! * `None` — never evict: anything that does not fit is rejected and
//!   every later step for that stream recomputes.  (The paper-shaped
//!   baseline: no cache reuse across steps.)
//!
//! Whole-stream eviction (not page-granular) mirrors vLLM's sequence
//! preemption: a partially evicted prefix is useless for attention, so
//! pages of one stream live and die together.

use crate::config::EvictionPolicy;

use super::session::SessionId;

/// Cache geometry + policy (from `RunConfig::{kv_cache_pages,
/// kv_page_size, kv_eviction}`).
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Total pages on this device.
    pub pages: usize,
    /// Tokens per page.
    pub page_size: usize,
    pub policy: EvictionPolicy,
}

/// One fixed-size page: the K and V rows of up to `page_size` tokens.
struct Page {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// One cached `(session, kv_head)` K/V prefix.
struct Stream {
    session: SessionId,
    kv_head: usize,
    /// Session incarnation epoch the stream belongs to.  Session ids
    /// may be reused after close and closed streams are reaped lazily,
    /// so a same-id stream with a stale epoch must read as a miss —
    /// never be appended to or served.
    epoch: u64,
    d: usize,
    /// Tokens currently stored.
    len: usize,
    pages: Vec<Page>,
    /// LRU stamp (monotonic access clock).
    last_used: u64,
}

/// Monotonic counters, single-threaded per worker.
#[derive(Clone, Copy, Debug, Default)]
pub struct KvCacheStats {
    /// Decode lookups served from pages.
    pub hits: u64,
    /// Decode lookups that fell back to recompute.
    pub misses: u64,
    /// Whole streams inserted (prefill fills + miss re-caches).
    pub inserts: u64,
    /// Single-token appends.
    pub appends: u64,
    /// Live streams evicted under capacity pressure.
    pub evictions: u64,
    /// Closed-session streams reaped.
    pub reaped: u64,
    /// Inserts/appends refused for capacity (policy `None`, or a stream
    /// larger than the whole cache).
    pub rejected: u64,
}

/// Outcome of an insert/append.
#[derive(Debug, PartialEq, Eq)]
pub enum Admit {
    /// The stream is cached; `evicted` lists the `(session, kv_head)`
    /// streams sacrificed to make room (their pins must be cleared).
    Cached { evicted: Vec<(SessionId, usize)> },
    /// The stream could not be admitted; the caller must serve from the
    /// host tier (recompute fallback).
    Rejected,
}

pub struct KvCache {
    cfg: KvCacheConfig,
    streams: Vec<Stream>,
    used_pages: usize,
    clock: u64,
    pub stats: KvCacheStats,
}

impl KvCache {
    pub fn new(cfg: KvCacheConfig) -> KvCache {
        assert!(cfg.pages >= 1, "kv_cache_pages must be >= 1");
        assert!(cfg.page_size >= 1, "kv_page_size must be >= 1");
        KvCache { cfg, streams: Vec::new(), used_pages: 0, clock: 0, stats: KvCacheStats::default() }
    }

    pub fn capacity_pages(&self) -> usize {
        self.cfg.pages
    }

    pub fn used_pages(&self) -> usize {
        self.used_pages
    }

    pub fn stream_count(&self) -> usize {
        self.streams.len()
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.page_size)
    }

    fn find(&self, sid: SessionId, kv_head: usize) -> Option<usize> {
        self.streams.iter().position(|s| s.session == sid && s.kv_head == kv_head)
    }

    /// Cached `(token count, epoch)` of a stream, touching its LRU
    /// stamp.  Callers must match the epoch against the session's
    /// current incarnation before trusting the length.
    pub fn cached_state(&mut self, sid: SessionId, kv_head: usize) -> Option<(usize, u64)> {
        self.clock += 1;
        let clock = self.clock;
        let i = self.find(sid, kv_head)?;
        self.streams[i].last_used = clock;
        Some((self.streams[i].len, self.streams[i].epoch))
    }

    /// Cached token count of a stream, touching its LRU stamp
    /// (epoch-blind convenience; prefer [`KvCache::cached_state`] on
    /// the serving path).
    pub fn cached_len(&mut self, sid: SessionId, kv_head: usize) -> Option<usize> {
        self.cached_state(sid, kv_head).map(|(len, _)| len)
    }

    /// Drop one stream (if present), freeing its pages.
    pub fn remove(&mut self, sid: SessionId, kv_head: usize) -> bool {
        match self.find(sid, kv_head) {
            None => false,
            Some(i) => {
                let s = self.streams.swap_remove(i);
                self.used_pages -= s.pages.len();
                true
            }
        }
    }

    /// Free `need` pages: reap dead streams first (closed sessions and
    /// stale incarnations, per `live(session, epoch)`), then LRU-evict
    /// live streams.  `protect` is never reaped *or* evicted — the
    /// stream being grown must survive even if its session was closed
    /// mid-flight (the in-flight step still completes; the stream is
    /// reaped on a later allocation).  Returns the evicted live keys,
    /// or `Err` when the policy forbids eviction or nothing evictable
    /// remains.
    fn make_room(
        &mut self,
        need: usize,
        protect: Option<(SessionId, usize)>,
        live: &dyn Fn(SessionId, u64) -> bool,
    ) -> Result<Vec<(SessionId, usize)>, ()> {
        if self.used_pages + need > self.cfg.pages {
            // Dead streams are free capacity whatever the policy.
            let mut i = 0;
            while i < self.streams.len() {
                let s = &self.streams[i];
                if !live(s.session, s.epoch) && protect != Some((s.session, s.kv_head)) {
                    let s = self.streams.swap_remove(i);
                    self.used_pages -= s.pages.len();
                    self.stats.reaped += 1;
                } else {
                    i += 1;
                }
            }
        }
        let mut evicted = Vec::new();
        while self.used_pages + need > self.cfg.pages {
            if self.cfg.policy == EvictionPolicy::None {
                return Err(());
            }
            let victim = self
                .streams
                .iter()
                .enumerate()
                .filter(|(_, s)| protect != Some((s.session, s.kv_head)))
                .min_by_key(|(_, s)| s.last_used)
                .map(|(i, _)| i);
            match victim {
                None => return Err(()),
                Some(i) => {
                    let s = self.streams.swap_remove(i);
                    self.used_pages -= s.pages.len();
                    self.stats.evictions += 1;
                    evicted.push((s.session, s.kv_head));
                }
            }
        }
        Ok(evicted)
    }

    /// Insert (or replace) a whole stream of `len = k.len() / d` tokens
    /// belonging to session incarnation `epoch`.
    #[allow(clippy::too_many_arguments)]
    pub fn insert(
        &mut self,
        sid: SessionId,
        kv_head: usize,
        epoch: u64,
        d: usize,
        k: &[f32],
        v: &[f32],
        live: &dyn Fn(SessionId, u64) -> bool,
    ) -> Admit {
        assert!(d >= 1);
        assert_eq!(k.len() % d, 0, "K must be (len, d) row-major");
        assert_eq!(k.len(), v.len());
        let len = k.len() / d;
        self.remove(sid, kv_head);
        let need = self.pages_for(len);
        if len == 0 || need > self.cfg.pages {
            self.stats.rejected += 1;
            return Admit::Rejected;
        }
        let evicted = match self.make_room(need, None, live) {
            Ok(e) => e,
            Err(()) => {
                self.stats.rejected += 1;
                return Admit::Rejected;
            }
        };
        let rows_per_page = self.cfg.page_size;
        let mut pages = Vec::with_capacity(need);
        for p in 0..need {
            let lo = p * rows_per_page * d;
            let hi = ((p + 1) * rows_per_page * d).min(len * d);
            pages.push(Page { k: k[lo..hi].to_vec(), v: v[lo..hi].to_vec() });
        }
        self.clock += 1;
        self.streams.push(Stream {
            session: sid,
            kv_head,
            epoch,
            d,
            len,
            pages,
            last_used: self.clock,
        });
        self.used_pages += need;
        self.stats.inserts += 1;
        Admit::Cached { evicted }
    }

    /// Append one token's K/V row to an existing stream, allocating a
    /// new page when the last one is full.  On a capacity rejection the
    /// (now stale) stream is dropped entirely — a prefix missing its
    /// newest token is useless for this and every later step.
    pub fn append(
        &mut self,
        sid: SessionId,
        kv_head: usize,
        k_row: &[f32],
        v_row: &[f32],
        live: &dyn Fn(SessionId, u64) -> bool,
    ) -> Admit {
        let Some(i) = self.find(sid, kv_head) else {
            return Admit::Rejected;
        };
        assert_eq!(k_row.len(), self.streams[i].d, "append row must be (1, d)");
        assert_eq!(k_row.len(), v_row.len());
        let needs_page = self.streams[i].len % self.cfg.page_size == 0;
        let evicted = if needs_page {
            match self.make_room(1, Some((sid, kv_head)), live) {
                Ok(e) => e,
                Err(()) => {
                    self.remove(sid, kv_head);
                    self.stats.rejected += 1;
                    return Admit::Rejected;
                }
            }
        } else {
            Vec::new()
        };
        // Re-find: make_room may have swap-removed around our index.
        // (It never touches the protected stream itself, but stay
        // graceful — a worker thread must not die on a cache panic.)
        let page_cap = self.cfg.page_size * k_row.len();
        let Some(i) = self.find(sid, kv_head) else {
            self.stats.rejected += 1;
            return Admit::Rejected;
        };
        if needs_page {
            self.streams[i].pages.push(Page {
                k: Vec::with_capacity(page_cap),
                v: Vec::with_capacity(page_cap),
            });
            self.used_pages += 1;
        }
        let page = self.streams[i].pages.last_mut().expect("stream has a page");
        page.k.extend_from_slice(k_row);
        page.v.extend_from_slice(v_row);
        self.streams[i].len += 1;
        self.clock += 1;
        self.streams[i].last_used = self.clock;
        self.stats.appends += 1;
        Admit::Cached { evicted }
    }

    /// Copy a stream's pages into contiguous `(len, d)` K and V
    /// matrices — the model of the device streaming its pages through
    /// the array (the `O(len · d)` bytes `fsa_decode_perf` charges).
    pub fn gather(&self, sid: SessionId, kv_head: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        let i = self.find(sid, kv_head)?;
        let s = &self.streams[i];
        let mut k = Vec::with_capacity(s.len * s.d);
        let mut v = Vec::with_capacity(s.len * s.d);
        for p in &s.pages {
            k.extend_from_slice(&p.k);
            v.extend_from_slice(&p.v);
        }
        Some((k, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(pages: usize, page_size: usize, policy: EvictionPolicy) -> KvCache {
        KvCache::new(KvCacheConfig { pages, page_size, policy })
    }

    fn rows(len: usize, d: usize, base: f32) -> Vec<f32> {
        (0..len * d).map(|x| base + x as f32).collect()
    }

    fn all_live(_: SessionId, _: u64) -> bool {
        true
    }
    const LIVE: &fn(SessionId, u64) -> bool = &(all_live as fn(SessionId, u64) -> bool);

    #[test]
    fn insert_append_gather_round_trip() {
        let d = 4;
        let mut c = cache(8, 2, EvictionPolicy::Lru);
        let (k, v) = (rows(3, d, 0.0), rows(3, d, 100.0));
        assert_eq!(c.insert(1, 0, 1, d, &k, &v, LIVE), Admit::Cached { evicted: vec![] });
        assert_eq!(c.cached_len(1, 0), Some(3));
        assert_eq!(c.used_pages(), 2); // ceil(3/2)

        // Append fills the half-full page, then allocates a new one.
        assert_eq!(c.append(1, 0, &rows(1, d, 50.0), &rows(1, d, 60.0), LIVE), Admit::Cached { evicted: vec![] });
        assert_eq!(c.used_pages(), 2);
        assert_eq!(c.append(1, 0, &rows(1, d, 70.0), &rows(1, d, 80.0), LIVE), Admit::Cached { evicted: vec![] });
        assert_eq!(c.used_pages(), 3);
        assert_eq!(c.cached_len(1, 0), Some(5));

        let (gk, gv) = c.gather(1, 0).unwrap();
        assert_eq!(gk.len(), 5 * d);
        assert_eq!(&gk[..3 * d], &k[..]);
        assert_eq!(&gk[3 * d..4 * d], &rows(1, d, 50.0)[..]);
        assert_eq!(&gk[4 * d..], &rows(1, d, 70.0)[..]);
        assert_eq!(&gv[3 * d..4 * d], &rows(1, d, 60.0)[..]);
        assert_eq!(c.stats.inserts, 1);
        assert_eq!(c.stats.appends, 2);
    }

    #[test]
    fn lru_evicts_coldest_stream_and_reports_keys() {
        let d = 2;
        let mut c = cache(4, 1, EvictionPolicy::Lru);
        assert!(matches!(c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE), Admit::Cached { .. }));
        assert!(matches!(c.insert(2, 0, 2, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE), Admit::Cached { .. }));
        assert_eq!(c.used_pages(), 4);
        // Touch stream 1 so stream 2 is LRU.
        let _ = c.cached_len(1, 0);
        match c.insert(3, 0, 3, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE) {
            Admit::Cached { evicted } => assert_eq!(evicted, vec![(2, 0)]),
            r => panic!("expected eviction, got {r:?}"),
        }
        assert!(c.cached_len(2, 0).is_none());
        assert_eq!(c.cached_len(1, 0), Some(2));
        assert_eq!(c.stats.evictions, 1);
    }

    #[test]
    fn append_never_evicts_its_own_stream() {
        let d = 2;
        let mut c = cache(2, 1, EvictionPolicy::Lru);
        assert!(matches!(c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE), Admit::Cached { .. }));
        // Growing the only stream beyond capacity must reject (and drop
        // the stale stream), not evict-then-grow itself.
        assert_eq!(c.append(1, 0, &rows(1, d, 9.0), &rows(1, d, 9.0), LIVE), Admit::Rejected);
        assert!(c.cached_len(1, 0).is_none());
        assert_eq!(c.used_pages(), 0);
        assert_eq!(c.stats.rejected, 1);
    }

    #[test]
    fn policy_none_rejects_instead_of_evicting() {
        let d = 2;
        let mut c = cache(2, 1, EvictionPolicy::None);
        assert!(matches!(c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE), Admit::Cached { .. }));
        assert_eq!(c.insert(2, 0, 2, d, &rows(1, d, 0.0), &rows(1, d, 0.0), LIVE), Admit::Rejected);
        // The resident stream is untouched.
        assert_eq!(c.cached_len(1, 0), Some(2));
        assert_eq!(c.stats.evictions, 0);
    }

    #[test]
    fn oversized_stream_is_uncacheable() {
        let d = 2;
        let mut c = cache(2, 1, EvictionPolicy::Lru);
        assert_eq!(c.insert(1, 0, 1, d, &rows(3, d, 0.0), &rows(3, d, 0.0), LIVE), Admit::Rejected);
        assert_eq!(c.used_pages(), 0);
    }

    #[test]
    fn closed_sessions_are_reaped_before_live_evictions() {
        let d = 2;
        let mut c = cache(4, 1, EvictionPolicy::Lru);
        assert!(matches!(c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE), Admit::Cached { .. }));
        assert!(matches!(c.insert(2, 0, 2, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE), Admit::Cached { .. }));
        // Session 1 is closed: its pages are reclaimed, session 2 keeps its.
        let live = |sid: SessionId, _: u64| sid != 1;
        match c.insert(3, 0, 3, d, &rows(2, d, 0.0), &rows(2, d, 0.0), &live) {
            Admit::Cached { evicted } => assert!(evicted.is_empty(), "reap, not evict: {evicted:?}"),
            r => panic!("{r:?}"),
        }
        assert_eq!(c.stats.reaped, 1);
        assert_eq!(c.stats.evictions, 0);
        assert_eq!(c.cached_len(2, 0), Some(2));
        assert!(c.cached_len(1, 0).is_none());
    }

    #[test]
    fn append_survives_its_session_closing_mid_flight() {
        // The session was closed between admit and execution, and the
        // append needs a page under full capacity: the reap pass must
        // not take the protected (now-dead) stream out from under the
        // append — no panic, and the grown stream still serves this
        // in-flight step.
        let d = 2;
        let mut c = cache(3, 1, EvictionPolicy::Lru);
        c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE);
        let dead = |_: SessionId, _: u64| false;
        match c.append(1, 0, &rows(1, d, 9.0), &rows(1, d, 9.0), &dead) {
            Admit::Cached { evicted } => assert!(evicted.is_empty()),
            r => panic!("append must survive a dead session: {r:?}"),
        }
        assert_eq!(c.cached_len(1, 0), Some(3));
        // The dead stream is reaped on the next allocation pressure.
        c.insert(2, 0, 2, d, &rows(2, d, 0.0), &rows(2, d, 0.0), &dead);
        assert!(c.cached_len(1, 0).is_none());
        assert!(c.stats.reaped >= 1);
    }

    #[test]
    fn stale_epoch_streams_are_reaped_like_closed_sessions() {
        let d = 2;
        let mut c = cache(4, 1, EvictionPolicy::Lru);
        c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE);
        c.insert(2, 0, 2, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE);
        // Session 1 was closed and its id reused under epoch 7: the
        // epoch-1 stream is dead even though the id is live.
        let live = |sid: SessionId, epoch: u64| match sid {
            1 => epoch == 7,
            _ => true,
        };
        match c.insert(3, 0, 3, d, &rows(2, d, 0.0), &rows(2, d, 0.0), &live) {
            Admit::Cached { evicted } => assert!(evicted.is_empty(), "reap, not evict"),
            r => panic!("{r:?}"),
        }
        assert!(c.cached_state(1, 0).is_none());
        assert_eq!(c.cached_state(2, 0), Some((2, 2)));
    }

    #[test]
    fn cached_state_exposes_the_stream_epoch() {
        let d = 2;
        let mut c = cache(8, 2, EvictionPolicy::Lru);
        c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE);
        assert_eq!(c.cached_state(1, 0), Some((2, 1)));
        // A reused session id re-inserts under a fresh epoch; the old
        // stream is replaced, not appended to.
        c.insert(1, 0, 9, d, &rows(3, d, 5.0), &rows(3, d, 5.0), LIVE);
        assert_eq!(c.cached_state(1, 0), Some((3, 9)));
        assert_eq!(c.stream_count(), 1);
        let (k, _) = c.gather(1, 0).unwrap();
        assert_eq!(k, rows(3, d, 5.0));
    }

    #[test]
    fn per_kv_head_streams_are_independent() {
        let d = 2;
        let mut c = cache(8, 2, EvictionPolicy::Lru);
        c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE);
        c.insert(1, 1, 1, d, &rows(4, d, 9.0), &rows(4, d, 9.0), LIVE);
        assert_eq!(c.cached_len(1, 0), Some(2));
        assert_eq!(c.cached_len(1, 1), Some(4));
        assert_eq!(c.stream_count(), 2);
        assert!(c.remove(1, 0));
        assert_eq!(c.stream_count(), 1);
        assert_eq!(c.used_pages(), 2);
    }
}
