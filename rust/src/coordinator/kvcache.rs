//! Per-device paged KV cache (DESIGN.md §5, §11): the device-HBM tier
//! behind decode-phase serving, redesigned around *refcounted,
//! content-keyed, copy-on-write pages* so sessions sharing a prefix
//! (the system-prompt regime) share physical pages.
//!
//! Each device worker owns one [`KvCache`].  The externally visible
//! unit is still a *stream* — the K/V prefix of one `(session,
//! kv_head)` pair, exactly the granularity the router's KV-head
//! affinity pins to a device — but a stream no longer owns its pages:
//! it holds *references* into a page slab owned by the cache.  One page
//! stores up to `page_size` tokens of K and V rows and models
//! `page_size · d · 2 (K+V) · 2 B (fp16)` of device HBM; capacity is
//! accounted in physical pages, so a page shared by ten streams costs
//! one page.
//!
//! **Content keys.**  A full (immutable) page is identified by a hash
//! *chain* over the stream prefix: `key_i = h(key_{i-1}, K_i, V_i)`
//! seeded from `(d, page_size)`.  Two streams whose prefixes agree
//! byte-for-byte through page `i` compute the same chain key, so an
//! insert can *attach* (refcount + 1) a resident page instead of
//! copying it — every attach is byte-verified against the candidate
//! page, so a hash collision degrades to a copy, never to wrong K/V.
//! A stream's partially-filled *tail* attaches by a second index keyed
//! on the chain of the full prefix *before* a page: any resident page
//! with that prefix — a donor's mutable tail or a longer stream's full
//! page — is shared when the joiner's tail is a byte-verified prefix
//! of it (the stream just reads fewer rows than the page holds).
//!
//! **Copy-on-write.**  Full shared pages are never mutated.  A decode
//! append lands in the stream's tail page in place only when that page
//! is exclusively owned (`refs == 1`), still mutable, and exactly this
//! stream's length; otherwise the tail is copied first (`cow_copies`)
//! and the shared original keeps serving its other readers bitwise
//! unchanged.
//!
//! **Refcount-aware eviction.**  Detaching a stream (close, reap,
//! replacement) only drops references; a page is freed when — and only
//! when — its refcount is zero.  Unreferenced pages stay resident as
//! prefix-reuse candidates and are reclaimed LRU-first under capacity
//! pressure (`freed_pages`).  When freeing every refcount-0 page still
//! is not enough, policy `Lru` falls back to evicting whole
//! least-recently-used *streams* (never the stream being grown),
//! releasing their references — pages they shared with other live
//! streams survive (refs > 0), which is the "eviction skips shared
//! pages" invariant.  Evicted stream keys are returned so the caller
//! can clear the router's sticky pins.  Policy `None` never evicts
//! live streams: anything that does not fit after reaping dead streams
//! and freeing unreferenced pages is rejected.
//!
//! Sequence-parallel serving (DESIGN.md §7) still folds the chunk index
//! into the stream key (`kv_head · seq_shards + chunk`); the cache
//! stays chunk-agnostic.

use std::collections::HashMap;

use crate::config::EvictionPolicy;

use super::session::SessionId;

/// Cache geometry + policy (from `RunConfig::{kv_cache_pages,
/// kv_page_size, kv_eviction}`).
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Total physical pages on this device.
    pub pages: usize,
    /// Tokens per page.
    pub page_size: usize,
    pub policy: EvictionPolicy,
}

/// Slab index of a page (stable for the page's lifetime).
type PageId = usize;

/// One physical page: the K and V rows of up to `page_size` tokens,
/// shared by `refs` stream references.
struct PageEntry {
    d: usize,
    /// Tokens stored (== `page_size` once full/immutable).
    len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Stream references holding this page.  Never mutated while > 1;
    /// freed only at 0.
    refs: usize,
    /// LRU stamp (monotonic access clock) for refcount-0 reclamation.
    last_used: u64,
    /// Content chain key — `Some` exactly for full, immutable pages
    /// (registered in the content index).
    key: Option<u64>,
    /// Chain key of the full-page prefix *before* this page (the tail
    /// index key while mutable; the chain input when it fills).
    prefix_key: u64,
}

/// One cached `(session, kv_head)` K/V prefix: page references plus
/// the chain state needed to extend it.
struct Stream {
    session: SessionId,
    kv_head: usize,
    /// Session incarnation epoch the stream belongs to.  Session ids
    /// may be reused after close and closed streams are reaped lazily,
    /// so a same-id stream with a stale epoch must read as a miss —
    /// never be appended to or served.
    epoch: u64,
    d: usize,
    /// Tokens this stream covers (a shared tail page may physically
    /// hold more rows than this stream reads).
    len: usize,
    pages: Vec<PageId>,
    /// Chain key over this stream's full pages (the tail's prefix key).
    chain: u64,
    /// LRU stamp (monotonic access clock).
    last_used: u64,
}

/// Monotonic counters, single-threaded per worker.
#[derive(Clone, Copy, Debug, Default)]
pub struct KvCacheStats {
    /// Decode lookups served from pages.
    pub hits: u64,
    /// Decode lookups that fell back to recompute.
    pub misses: u64,
    /// Whole streams inserted (prefill fills + miss re-caches).
    pub inserts: u64,
    /// Single-token appends.
    pub appends: u64,
    /// Live streams evicted under capacity pressure (policy `Lru` last
    /// resort after refcount-0 reclamation).
    pub evictions: u64,
    /// Closed-session streams reaped.
    pub reaped: u64,
    /// Inserts/appends refused for capacity (policy `None`, or a stream
    /// larger than the whole cache).
    pub rejected: u64,
    /// Pages attached by content match instead of copied (prefix
    /// sharing at work).
    pub attached: u64,
    /// Copy-on-write tail copies (first divergent append to a shared
    /// tail).
    pub cow_copies: u64,
    /// Refcount-0 pages reclaimed under capacity pressure.
    pub freed_pages: u64,
}

/// Outcome of an insert/append.
#[derive(Debug, PartialEq, Eq)]
pub enum Admit {
    /// The stream is cached; `evicted` lists the `(session, kv_head)`
    /// streams sacrificed to make room (their pins must be cleared),
    /// and `attached_pages` counts pages shared by content match
    /// instead of copied (0 on appends).
    Cached { evicted: Vec<(SessionId, usize)>, attached_pages: usize },
    /// The stream could not be admitted; the caller must serve from the
    /// host tier (recompute fallback).
    Rejected,
}

pub struct KvCache {
    cfg: KvCacheConfig,
    /// Page slab; `None` slots are free (ids recycled via `free`).
    slots: Vec<Option<PageEntry>>,
    free: Vec<PageId>,
    streams: Vec<Stream>,
    /// Resident (allocated) physical pages — shared pages count once.
    used_pages: usize,
    clock: u64,
    /// Full-page content index: chain key → resident page.
    content: HashMap<u64, PageId>,
    /// Prefix index: full-prefix chain key → every resident page that
    /// extends that prefix (diverged tails and full pages alike) — the
    /// tail-attach candidate set.
    by_prefix: HashMap<u64, Vec<PageId>>,
    pub stats: KvCacheStats,
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn mix(h: u64, x: u32) -> u64 {
    let mut h = h;
    for b in x.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Chain seed for a `page_size`-token page geometry — shared with the
/// coordinator-level prefix index ([`super::session`]) so both layers
/// speak one definition of page identity.
pub(crate) fn chain_seed(page_size: usize) -> u64 {
    mix(mix(FNV_OFFSET, page_size as u32), 0x5eed)
}

/// Chain step: hash the previous chain value and one page's K/V bit
/// patterns (FNV-1a over the f32 bits — deterministic, bitwise).
pub(crate) fn chain_hash(prev: u64, k: &[f32], v: &[f32]) -> u64 {
    let mut h = mix(mix(FNV_OFFSET, prev as u32), (prev >> 32) as u32);
    for &x in k {
        h = mix(h, x.to_bits());
    }
    for &x in v {
        h = mix(h, x.to_bits());
    }
    h
}

impl KvCache {
    pub fn new(cfg: KvCacheConfig) -> KvCache {
        assert!(cfg.pages >= 1, "kv_cache_pages must be >= 1");
        assert!(cfg.page_size >= 1, "kv_page_size must be >= 1");
        KvCache {
            cfg,
            slots: Vec::new(),
            free: Vec::new(),
            streams: Vec::new(),
            used_pages: 0,
            clock: 0,
            content: HashMap::new(),
            by_prefix: HashMap::new(),
            stats: KvCacheStats::default(),
        }
    }

    pub fn capacity_pages(&self) -> usize {
        self.cfg.pages
    }

    /// Resident physical pages (a page shared by N streams counts
    /// once — the §11 sharing-aware accounting).
    pub fn used_pages(&self) -> usize {
        self.used_pages
    }

    pub fn stream_count(&self) -> usize {
        self.streams.len()
    }

    /// Chain seed: ties keys to the cache geometry so streams of a
    /// different page size can never alias.
    fn seed(&self) -> u64 {
        chain_seed(self.cfg.page_size)
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.page_size)
    }

    fn entry(&self, pid: PageId) -> &PageEntry {
        self.slots[pid].as_ref().expect("live page id")
    }

    fn entry_mut(&mut self, pid: PageId) -> &mut PageEntry {
        self.slots[pid].as_mut().expect("live page id")
    }

    fn touch_page(&mut self, pid: PageId) {
        self.clock += 1;
        let clock = self.clock;
        self.entry_mut(pid).last_used = clock;
    }

    /// Allocate a page and register it in the prefix index (and, when
    /// full, the content index) so later inserts can attach it.
    fn alloc_page(&mut self, e: PageEntry) -> PageId {
        let (prefix, key) = (e.prefix_key, e.key);
        self.used_pages += 1;
        let pid = match self.free.pop() {
            Some(pid) => {
                self.slots[pid] = Some(e);
                pid
            }
            None => {
                self.slots.push(Some(e));
                self.slots.len() - 1
            }
        };
        self.by_prefix.entry(prefix).or_default().push(pid);
        if let Some(k) = key {
            self.content.entry(k).or_insert(pid);
        }
        pid
    }

    /// Drop one stream reference to a page (the page stays resident as
    /// a reuse candidate; refcount-0 pages are reclaimed by
    /// [`KvCache::make_room`] under pressure).
    fn release(&mut self, pid: PageId) {
        let e = self.entry_mut(pid);
        e.refs = e.refs.saturating_sub(1);
    }

    /// Free a refcount-0 page: unindex it and return its slot.
    fn free_page(&mut self, pid: PageId) {
        let (key, prefix_key) = {
            let e = self.entry(pid);
            debug_assert_eq!(e.refs, 0, "never free a referenced page");
            (e.key, e.prefix_key)
        };
        if let Some(k) = key {
            if self.content.get(&k) == Some(&pid) {
                self.content.remove(&k);
            }
        }
        if let Some(cands) = self.by_prefix.get_mut(&prefix_key) {
            cands.retain(|&c| c != pid);
            if cands.is_empty() {
                self.by_prefix.remove(&prefix_key);
            }
        }
        self.slots[pid] = None;
        self.free.push(pid);
        self.used_pages -= 1;
    }

    /// Release every page reference a stream holds.
    fn release_stream_pages(&mut self, pages: &[PageId]) {
        for &pid in pages {
            self.release(pid);
        }
    }

    fn find(&self, sid: SessionId, kv_head: usize) -> Option<usize> {
        self.streams.iter().position(|s| s.session == sid && s.kv_head == kv_head)
    }

    /// Cached `(token count, epoch)` of a stream, touching its LRU
    /// stamp.  Callers must match the epoch against the session's
    /// current incarnation before trusting the length.
    pub fn cached_state(&mut self, sid: SessionId, kv_head: usize) -> Option<(usize, u64)> {
        self.clock += 1;
        let clock = self.clock;
        let i = self.find(sid, kv_head)?;
        self.streams[i].last_used = clock;
        Some((self.streams[i].len, self.streams[i].epoch))
    }

    /// Cached token count of a stream, touching its LRU stamp
    /// (epoch-blind convenience; prefer [`KvCache::cached_state`] on
    /// the serving path).
    pub fn cached_len(&mut self, sid: SessionId, kv_head: usize) -> Option<usize> {
        self.cached_state(sid, kv_head).map(|(len, _)| len)
    }

    /// Drop one stream (if present), releasing its page references.
    /// Pages it exclusively held stay resident (refcount 0) as prefix
    /// reuse candidates until capacity pressure reclaims them.
    pub fn remove(&mut self, sid: SessionId, kv_head: usize) -> bool {
        match self.find(sid, kv_head) {
            None => false,
            Some(i) => {
                let s = self.streams.swap_remove(i);
                self.release_stream_pages(&s.pages);
                true
            }
        }
    }

    /// Free `need` page slots.  Order (DESIGN.md §11): reap dead
    /// streams (closed sessions and stale incarnations, per
    /// `live(session, epoch)`) so their references drop; reclaim
    /// refcount-0 pages LRU-first (skipping `keep`, the pages a pending
    /// insert plans to attach); then — policy `Lru` only — evict whole
    /// LRU live streams, whose *shared* pages survive because their
    /// refcount stays positive.  `protect` is never reaped or evicted:
    /// the stream being grown must survive even if its session closed
    /// mid-flight.  Returns evicted live keys (pin clearing), or `Err`
    /// when the policy forbids eviction or nothing reclaimable remains.
    fn make_room(
        &mut self,
        need: usize,
        protect: Option<(SessionId, usize)>,
        keep: &[PageId],
        live: &dyn Fn(SessionId, u64) -> bool,
    ) -> Result<Vec<(SessionId, usize)>, ()> {
        if self.used_pages + need > self.cfg.pages {
            // Dead streams are free capacity whatever the policy.
            let mut i = 0;
            while i < self.streams.len() {
                let s = &self.streams[i];
                if !live(s.session, s.epoch) && protect != Some((s.session, s.kv_head)) {
                    let s = self.streams.swap_remove(i);
                    self.release_stream_pages(&s.pages);
                    self.stats.reaped += 1;
                } else {
                    i += 1;
                }
            }
        }
        let mut evicted = Vec::new();
        while self.used_pages + need > self.cfg.pages {
            // Refcount-0 pages first: unreferenced prefix candidates
            // are the only pages eviction may actually free.
            let freeable = self
                .slots
                .iter()
                .enumerate()
                .filter_map(|(pid, s)| s.as_ref().map(|e| (pid, e)))
                .filter(|&(pid, e)| e.refs == 0 && !keep.contains(&pid))
                .min_by_key(|&(_, e)| e.last_used)
                .map(|(pid, _)| pid);
            if let Some(pid) = freeable {
                self.free_page(pid);
                self.stats.freed_pages += 1;
                continue;
            }
            if self.cfg.policy == EvictionPolicy::None {
                return Err(());
            }
            // Last resort: evict the LRU live stream.  Its references
            // drop; only pages nobody else shares become freeable on
            // the next loop turn — shared pages survive by refcount.
            let victim = self
                .streams
                .iter()
                .enumerate()
                .filter(|(_, s)| protect != Some((s.session, s.kv_head)))
                .min_by_key(|(_, s)| s.last_used)
                .map(|(i, _)| i);
            match victim {
                None => return Err(()),
                Some(i) => {
                    let s = self.streams.swap_remove(i);
                    self.release_stream_pages(&s.pages);
                    self.stats.evictions += 1;
                    evicted.push((s.session, s.kv_head));
                }
            }
        }
        Ok(evicted)
    }

    /// Insert (or replace) a whole stream of `len = k.len() / d` tokens
    /// belonging to session incarnation `epoch`.  Full pages whose
    /// content chain matches a resident page (byte-verified) are
    /// *attached* instead of copied; a matching resident tail is shared
    /// the same way.  `Admit::Cached::attached_pages` reports how many
    /// pages the stream shares — the device worker's prefix-attach
    /// signal.
    #[allow(clippy::too_many_arguments)]
    pub fn insert(
        &mut self,
        sid: SessionId,
        kv_head: usize,
        epoch: u64,
        d: usize,
        k: &[f32],
        v: &[f32],
        live: &dyn Fn(SessionId, u64) -> bool,
    ) -> Admit {
        assert!(d >= 1);
        assert_eq!(k.len() % d, 0, "K must be (len, d) row-major");
        assert_eq!(k.len(), v.len());
        let len = k.len() / d;
        self.remove(sid, kv_head);
        if len == 0 || self.pages_for(len) > self.cfg.pages {
            self.stats.rejected += 1;
            return Admit::Rejected;
        }
        let ps = self.cfg.page_size;
        let full = len / ps;
        let tail = len - full * ps;

        // Pass 1: plan — walk the chain, matching resident pages.
        // `Some(pid)` attaches, `None` allocates; `chains[p]` is the
        // chain key *after* page p.
        let mut plan: Vec<Option<PageId>> = Vec::with_capacity(full);
        let mut chains: Vec<u64> = Vec::with_capacity(full);
        let mut chain = self.seed();
        for p in 0..full {
            let (lo, hi) = (p * ps * d, (p + 1) * ps * d);
            chain = chain_hash(chain, &k[lo..hi], &v[lo..hi]);
            chains.push(chain);
            let hit = self.content.get(&chain).copied().filter(|&pid| {
                let e = self.entry(pid);
                e.d == d && e.k == k[lo..hi] && e.v == v[lo..hi]
            });
            plan.push(hit);
        }
        let mut tail_plan: Option<PageId> = None;
        if tail > 0 {
            if let Some(cands) = self.by_prefix.get(&chain) {
                let lo = full * ps * d;
                tail_plan = cands.iter().copied().find(|&pid| {
                    let e = self.entry(pid);
                    e.d == d
                        && e.len >= tail
                        && e.k[..tail * d] == k[lo..]
                        && e.v[..tail * d] == v[lo..]
                });
            }
        }

        let new_pages = plan.iter().filter(|p| p.is_none()).count()
            + usize::from(tail > 0 && tail_plan.is_none());
        let keep: Vec<PageId> =
            plan.iter().flatten().copied().chain(tail_plan).collect();
        let evicted = match self.make_room(new_pages, None, &keep, live) {
            Ok(e) => e,
            Err(()) => {
                self.stats.rejected += 1;
                return Admit::Rejected;
            }
        };

        // Pass 2: materialize references.
        let mut pages = Vec::with_capacity(full + usize::from(tail > 0));
        let mut attached = 0usize;
        let mut prev = self.seed();
        for p in 0..full {
            let (lo, hi) = (p * ps * d, (p + 1) * ps * d);
            let key = chains[p];
            let pid = match plan[p] {
                Some(pid) => {
                    self.entry_mut(pid).refs += 1;
                    attached += 1;
                    pid
                }
                None => self.alloc_page(PageEntry {
                    d,
                    len: ps,
                    k: k[lo..hi].to_vec(),
                    v: v[lo..hi].to_vec(),
                    refs: 1,
                    last_used: 0,
                    key: Some(key),
                    prefix_key: prev,
                }),
            };
            self.touch_page(pid);
            pages.push(pid);
            prev = key;
        }
        if tail > 0 {
            let lo = full * ps * d;
            let pid = match tail_plan {
                Some(pid) => {
                    self.entry_mut(pid).refs += 1;
                    attached += 1;
                    pid
                }
                None => self.alloc_page(PageEntry {
                    d,
                    len: tail,
                    k: k[lo..].to_vec(),
                    v: v[lo..].to_vec(),
                    refs: 1,
                    last_used: 0,
                    key: None,
                    prefix_key: prev,
                }),
            };
            self.touch_page(pid);
            pages.push(pid);
        }
        self.clock += 1;
        self.streams.push(Stream {
            session: sid,
            kv_head,
            epoch,
            d,
            len,
            pages,
            chain: prev,
            last_used: self.clock,
        });
        self.stats.inserts += 1;
        self.stats.attached += attached as u64;
        Admit::Cached { evicted, attached_pages: attached }
    }

    /// Append one token's K/V row to an existing stream.  A full tail
    /// starts a fresh page; a shared (or longer-than-this-stream, or
    /// already-immutable) tail is copied first — copy-on-write, so the
    /// divergence never mutates what other streams read.  When the tail
    /// fills it freezes: it gets its chain key and joins the content
    /// index for future prefix matches.  On a capacity rejection the
    /// (now stale) stream is dropped entirely — a prefix missing its
    /// newest token is useless for this and every later step.
    pub fn append(
        &mut self,
        sid: SessionId,
        kv_head: usize,
        k_row: &[f32],
        v_row: &[f32],
        live: &dyn Fn(SessionId, u64) -> bool,
    ) -> Admit {
        let Some(i) = self.find(sid, kv_head) else {
            return Admit::Rejected;
        };
        assert_eq!(k_row.len(), self.streams[i].d, "append row must be (1, d)");
        assert_eq!(k_row.len(), v_row.len());
        let ps = self.cfg.page_size;
        let d = self.streams[i].d;
        let tail_len = self.streams[i].len % ps;
        let needs_page = tail_len == 0;
        // Copy-on-write test: mutate the tail in place only when it is
        // exclusively ours, still mutable, and exactly our length.
        let needs_cow = !needs_page && {
            let pid = *self.streams[i].pages.last().expect("stream has a page");
            let e = self.entry(pid);
            e.refs > 1 || e.key.is_some() || e.len != tail_len
        };
        let evicted = if needs_page || needs_cow {
            let keep: Vec<PageId> = self.streams[i].pages.clone();
            match self.make_room(1, Some((sid, kv_head)), &keep, live) {
                Ok(e) => e,
                Err(()) => {
                    self.remove(sid, kv_head);
                    self.stats.rejected += 1;
                    return Admit::Rejected;
                }
            }
        } else {
            Vec::new()
        };
        // Re-find: make_room may have swap-removed around our index.
        // (It never touches the protected stream itself, but stay
        // graceful — a worker thread must not die on a cache panic.)
        let Some(i) = self.find(sid, kv_head) else {
            self.stats.rejected += 1;
            return Admit::Rejected;
        };
        let chain = self.streams[i].chain;
        let pid = if needs_page {
            let pid = self.alloc_page(PageEntry {
                d,
                len: 0,
                k: Vec::with_capacity(ps * d),
                v: Vec::with_capacity(ps * d),
                refs: 1,
                last_used: 0,
                key: None,
                prefix_key: chain,
            });
            self.streams[i].pages.push(pid);
            pid
        } else {
            let old = *self.streams[i].pages.last().expect("stream has a page");
            if needs_cow {
                let (ck, cv) = {
                    let e = self.entry(old);
                    (e.k[..tail_len * d].to_vec(), e.v[..tail_len * d].to_vec())
                };
                let pid = self.alloc_page(PageEntry {
                    d,
                    len: tail_len,
                    k: ck,
                    v: cv,
                    refs: 1,
                    last_used: 0,
                    key: None,
                    prefix_key: chain,
                });
                self.release(old);
                *self.streams[i].pages.last_mut().expect("stream has a page") = pid;
                self.stats.cow_copies += 1;
                pid
            } else {
                old
            }
        };
        {
            let e = self.entry_mut(pid);
            e.k.extend_from_slice(k_row);
            e.v.extend_from_slice(v_row);
            e.len += 1;
        }
        // A tail that just filled freezes: it becomes immutable, gains
        // its chain key, and joins the content index so future inserts
        // can attach one page deeper.  (It stays in the prefix index —
        // full pages are tail-attach candidates too.)
        if self.entry(pid).len == ps {
            let key = {
                let e = self.entry(pid);
                chain_hash(e.prefix_key, &e.k, &e.v)
            };
            self.entry_mut(pid).key = Some(key);
            self.content.entry(key).or_insert(pid);
            self.streams[i].chain = key;
        }
        self.touch_page(pid);
        self.streams[i].len += 1;
        self.clock += 1;
        self.streams[i].last_used = self.clock;
        self.stats.appends += 1;
        Admit::Cached { evicted, attached_pages: 0 }
    }

    /// Copy a stream's pages into contiguous `(len, d)` K and V
    /// matrices — the model of the device streaming its pages through
    /// the array (the `O(len · d)` bytes `fsa_decode_perf` charges).  A
    /// shared tail page may hold more rows than this stream covers;
    /// only the stream's own `len` tokens are gathered.
    pub fn gather(&self, sid: SessionId, kv_head: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        let i = self.find(sid, kv_head)?;
        let s = &self.streams[i];
        let mut k = Vec::with_capacity(s.len * s.d);
        let mut v = Vec::with_capacity(s.len * s.d);
        let mut remaining = s.len;
        for &pid in &s.pages {
            let e = self.entry(pid);
            let rows = remaining.min(self.cfg.page_size).min(e.len);
            k.extend_from_slice(&e.k[..rows * s.d]);
            v.extend_from_slice(&e.v[..rows * s.d]);
            remaining -= rows;
        }
        debug_assert_eq!(remaining, 0, "stream pages cover its length");
        Some((k, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(pages: usize, page_size: usize, policy: EvictionPolicy) -> KvCache {
        KvCache::new(KvCacheConfig { pages, page_size, policy })
    }

    fn rows(len: usize, d: usize, base: f32) -> Vec<f32> {
        (0..len * d).map(|x| base + x as f32).collect()
    }

    fn all_live(_: SessionId, _: u64) -> bool {
        true
    }
    const LIVE: &fn(SessionId, u64) -> bool = &(all_live as fn(SessionId, u64) -> bool);

    fn cached(admit: Admit) -> (Vec<(SessionId, usize)>, usize) {
        match admit {
            Admit::Cached { evicted, attached_pages } => (evicted, attached_pages),
            Admit::Rejected => panic!("expected Cached"),
        }
    }

    #[test]
    fn insert_append_gather_round_trip() {
        let d = 4;
        let mut c = cache(8, 2, EvictionPolicy::Lru);
        let (k, v) = (rows(3, d, 0.0), rows(3, d, 100.0));
        assert_eq!(cached(c.insert(1, 0, 1, d, &k, &v, LIVE)), (vec![], 0));
        assert_eq!(c.cached_len(1, 0), Some(3));
        assert_eq!(c.used_pages(), 2); // ceil(3/2)

        // Append fills the half-full page, then allocates a new one.
        assert_eq!(cached(c.append(1, 0, &rows(1, d, 50.0), &rows(1, d, 60.0), LIVE)), (vec![], 0));
        assert_eq!(c.used_pages(), 2);
        assert_eq!(cached(c.append(1, 0, &rows(1, d, 70.0), &rows(1, d, 80.0), LIVE)), (vec![], 0));
        assert_eq!(c.used_pages(), 3);
        assert_eq!(c.cached_len(1, 0), Some(5));

        let (gk, gv) = c.gather(1, 0).unwrap();
        assert_eq!(gk.len(), 5 * d);
        assert_eq!(&gk[..3 * d], &k[..]);
        assert_eq!(&gk[3 * d..4 * d], &rows(1, d, 50.0)[..]);
        assert_eq!(&gk[4 * d..], &rows(1, d, 70.0)[..]);
        assert_eq!(&gv[3 * d..4 * d], &rows(1, d, 60.0)[..]);
        assert_eq!(c.stats.inserts, 1);
        assert_eq!(c.stats.appends, 2);
    }

    /// Tentpole: two streams carrying the same content share physical
    /// pages — used_pages counts them once, the joiner attaches instead
    /// of copying, and both gathers stay bitwise the inserted data.
    #[test]
    fn identical_prefixes_share_pages() {
        let d = 2;
        let mut c = cache(8, 2, EvictionPolicy::Lru);
        let (k, v) = (rows(5, d, 0.0), rows(5, d, 100.0));
        // Cold insert: 3 pages (2 full + tail), nothing to attach.
        assert_eq!(cached(c.insert(1, 0, 1, d, &k, &v, LIVE)), (vec![], 0));
        assert_eq!(c.used_pages(), 3);
        // Warm insert of the same content under another session: every
        // page (including the tail) attaches; zero new pages.
        assert_eq!(cached(c.insert(2, 0, 2, d, &k, &v, LIVE)), (vec![], 3));
        assert_eq!(c.used_pages(), 3);
        assert_eq!(c.stats.attached, 3);
        let (k1, v1) = c.gather(1, 0).unwrap();
        let (k2, v2) = c.gather(2, 0).unwrap();
        assert_eq!((&k1, &v1), (&k, &v));
        assert_eq!((k1, v1), (k2, v2));
        // A shorter prefix of the same content shares the full pages
        // and reads the shared tail partially.
        assert_eq!(cached(c.insert(3, 0, 3, d, &k[..3 * d], &v[..3 * d], LIVE)).1, 2);
        let (k3, _) = c.gather(3, 0).unwrap();
        assert_eq!(k3, &k[..3 * d]);
        // Divergent content does NOT share (byte-verified, not just
        // hash-trusted).
        let kx = rows(5, d, 7777.0);
        assert_eq!(cached(c.insert(4, 0, 4, d, &kx, &kx, LIVE)).1, 0);
        assert_eq!(c.used_pages(), 6);
    }

    /// Property (DESIGN.md §11): COW on tail divergence — appends to a
    /// shared tail copy first; the donor's bytes never move.
    #[test]
    fn cow_copies_a_shared_tail_on_divergent_append() {
        let d = 2;
        let mut c = cache(8, 4, EvictionPolicy::Lru);
        let (k, v) = (rows(3, d, 0.0), rows(3, d, 100.0));
        c.insert(1, 0, 1, d, &k, &v, LIVE);
        assert_eq!(cached(c.insert(2, 0, 2, d, &k, &v, LIVE)), (vec![], 1));
        assert_eq!(c.used_pages(), 1);
        // First divergent append: stream 1 copies the shared tail
        // before writing — the copy-on-write moment.
        cached(c.append(1, 0, &rows(1, d, 11.0), &rows(1, d, 11.5), LIVE));
        assert_eq!(c.stats.cow_copies, 1);
        assert_eq!(c.used_pages(), 2);
        // Stream 2 now owns the original exclusively, so its divergent
        // append mutates in place — no second copy needed.
        cached(c.append(2, 0, &rows(1, d, 22.0), &rows(1, d, 22.5), LIVE));
        assert_eq!(c.stats.cow_copies, 1);
        assert_eq!(c.used_pages(), 2);
        let (k1, _) = c.gather(1, 0).unwrap();
        let (k2, _) = c.gather(2, 0).unwrap();
        assert_eq!(&k1[..3 * d], &k[..]);
        assert_eq!(&k2[..3 * d], &k[..], "donor bytes must survive the divergence");
        assert_eq!(&k1[3 * d..], &rows(1, d, 11.0)[..]);
        assert_eq!(&k2[3 * d..], &rows(1, d, 22.0)[..]);
    }

    /// Property: a page is never freed while referenced — capacity
    /// pressure reclaims refcount-0 pages and evicts LRU streams, but a
    /// page shared with a surviving stream outlives the eviction and
    /// its reader still gathers bitwise-intact data.
    #[test]
    fn eviction_never_frees_referenced_pages() {
        let d = 2;
        let mut c = cache(4, 2, EvictionPolicy::Lru);
        let (k, v) = (rows(4, d, 0.0), rows(4, d, 100.0));
        // Sessions 1 and 2 share both pages; session 3 fills the rest.
        c.insert(1, 0, 1, d, &k, &v, LIVE);
        assert_eq!(cached(c.insert(2, 0, 2, d, &k, &v, LIVE)).1, 2);
        c.insert(3, 0, 3, d, &rows(4, d, 500.0), &rows(4, d, 500.0), LIVE);
        assert_eq!(c.used_pages(), 4);
        // Make session 1 the LRU stream, then force pressure: the LRU
        // eviction takes stream 1, but its pages survive via session
        // 2's references — the freed capacity comes from stream 3.
        let _ = c.cached_len(3, 0);
        let _ = c.cached_len(2, 0);
        let (evicted, _) = cached(c.insert(4, 0, 4, d, &rows(4, d, 900.0), &rows(4, d, 900.0), LIVE));
        assert!(!evicted.is_empty());
        let (k2, v2) = c.gather(2, 0).unwrap();
        assert_eq!((k2, v2), (k.clone(), v.clone()), "shared pages must survive eviction");
        assert!(c.used_pages() <= c.capacity_pages());
    }

    /// Property: close releases references — a removed stream's
    /// exclusive pages become refcount-0 and are reclaimed (not
    /// evicted-as-a-stream) under the next pressure.
    #[test]
    fn remove_releases_references_for_lru_reclaim() {
        let d = 2;
        let mut c = cache(4, 1, EvictionPolicy::Lru);
        c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE);
        c.insert(2, 0, 2, d, &rows(2, d, 50.0), &rows(2, d, 50.0), LIVE);
        assert_eq!(c.used_pages(), 4);
        assert!(c.remove(1, 0));
        // Pages stay resident as reuse candidates…
        assert_eq!(c.used_pages(), 4);
        // …until pressure reclaims exactly them, with no live-stream
        // eviction.
        let (evicted, _) = cached(c.insert(3, 0, 3, d, &rows(2, d, 70.0), &rows(2, d, 70.0), LIVE));
        assert!(evicted.is_empty(), "refcount-0 reclaim, not eviction: {evicted:?}");
        assert_eq!(c.stats.freed_pages, 2);
        assert_eq!(c.stats.evictions, 0);
        assert_eq!(c.cached_len(2, 0), Some(2));
    }

    /// A removed stream's pages stay attachable: the next session with
    /// the same content re-attaches them instead of re-copying (the
    /// cross-session prefix cache surviving the donor's close).
    #[test]
    fn unreferenced_pages_stay_attachable() {
        let d = 2;
        let mut c = cache(8, 2, EvictionPolicy::Lru);
        let (k, v) = (rows(4, d, 0.0), rows(4, d, 100.0));
        c.insert(1, 0, 1, d, &k, &v, LIVE);
        assert!(c.remove(1, 0));
        assert_eq!(c.used_pages(), 2);
        assert_eq!(cached(c.insert(2, 0, 2, d, &k, &v, LIVE)), (vec![], 2));
        assert_eq!(c.used_pages(), 2);
        let (k2, _) = c.gather(2, 0).unwrap();
        assert_eq!(k2, k);
    }

    #[test]
    fn lru_evicts_coldest_stream_and_reports_keys() {
        let d = 2;
        let mut c = cache(4, 1, EvictionPolicy::Lru);
        assert!(matches!(c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE), Admit::Cached { .. }));
        assert!(matches!(c.insert(2, 0, 2, d, &rows(2, d, 30.0), &rows(2, d, 30.0), LIVE), Admit::Cached { .. }));
        assert_eq!(c.used_pages(), 4);
        // Touch stream 1 so stream 2 is LRU.
        let _ = c.cached_len(1, 0);
        let (evicted, _) = cached(c.insert(3, 0, 3, d, &rows(2, d, 60.0), &rows(2, d, 60.0), LIVE));
        assert_eq!(evicted, vec![(2, 0)]);
        assert!(c.cached_len(2, 0).is_none());
        assert_eq!(c.cached_len(1, 0), Some(2));
        assert_eq!(c.stats.evictions, 1);
    }

    #[test]
    fn append_never_evicts_its_own_stream() {
        let d = 2;
        let mut c = cache(2, 1, EvictionPolicy::Lru);
        assert!(matches!(c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE), Admit::Cached { .. }));
        // Growing the only stream beyond capacity must reject (and drop
        // the stale stream), not evict-then-grow itself.
        assert_eq!(c.append(1, 0, &rows(1, d, 9.0), &rows(1, d, 9.0), LIVE), Admit::Rejected);
        assert!(c.cached_len(1, 0).is_none());
        assert_eq!(c.stats.rejected, 1);
    }

    #[test]
    fn policy_none_rejects_instead_of_evicting() {
        let d = 2;
        let mut c = cache(2, 1, EvictionPolicy::None);
        assert!(matches!(c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE), Admit::Cached { .. }));
        assert_eq!(c.insert(2, 0, 2, d, &rows(1, d, 50.0), &rows(1, d, 50.0), LIVE), Admit::Rejected);
        // The resident stream is untouched.
        assert_eq!(c.cached_len(1, 0), Some(2));
        assert_eq!(c.stats.evictions, 0);
    }

    #[test]
    fn oversized_stream_is_uncacheable() {
        let d = 2;
        let mut c = cache(2, 1, EvictionPolicy::Lru);
        assert_eq!(c.insert(1, 0, 1, d, &rows(3, d, 0.0), &rows(3, d, 0.0), LIVE), Admit::Rejected);
        assert_eq!(c.used_pages(), 0);
    }

    #[test]
    fn closed_sessions_are_reaped_before_live_evictions() {
        let d = 2;
        let mut c = cache(4, 1, EvictionPolicy::Lru);
        assert!(matches!(c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE), Admit::Cached { .. }));
        assert!(matches!(c.insert(2, 0, 2, d, &rows(2, d, 30.0), &rows(2, d, 30.0), LIVE), Admit::Cached { .. }));
        // Session 1 is closed: its pages are reclaimed, session 2 keeps its.
        let live = |sid: SessionId, _: u64| sid != 1;
        let (evicted, _) = cached(c.insert(3, 0, 3, d, &rows(2, d, 60.0), &rows(2, d, 60.0), &live));
        assert!(evicted.is_empty(), "reap, not evict: {evicted:?}");
        assert_eq!(c.stats.reaped, 1);
        assert_eq!(c.stats.evictions, 0);
        assert_eq!(c.cached_len(2, 0), Some(2));
        assert!(c.cached_len(1, 0).is_none());
    }

    #[test]
    fn append_survives_its_session_closing_mid_flight() {
        // The session was closed between admit and execution, and the
        // append needs a page under full capacity: the reap pass must
        // not take the protected (now-dead) stream out from under the
        // append — no panic, and the grown stream still serves this
        // in-flight step.
        let d = 2;
        let mut c = cache(3, 1, EvictionPolicy::Lru);
        c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE);
        let dead = |_: SessionId, _: u64| false;
        let (evicted, _) = cached(c.append(1, 0, &rows(1, d, 9.0), &rows(1, d, 9.0), &dead));
        assert!(evicted.is_empty());
        assert_eq!(c.cached_len(1, 0), Some(3));
        // The dead stream is reaped on the next allocation pressure.
        c.insert(2, 0, 2, d, &rows(3, d, 50.0), &rows(3, d, 50.0), &dead);
        assert!(c.cached_len(1, 0).is_none());
        assert!(c.stats.reaped >= 1);
    }

    /// Property: a reused session id under a fresh epoch cannot
    /// resurrect the dead incarnation's stream — the stale stream is
    /// reaped, the new insert is its own stream, and content-level page
    /// reuse (which IS legal across incarnations) stays byte-verified.
    #[test]
    fn stale_epoch_streams_are_reaped_like_closed_sessions() {
        let d = 2;
        let mut c = cache(4, 1, EvictionPolicy::Lru);
        c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE);
        c.insert(2, 0, 2, d, &rows(2, d, 30.0), &rows(2, d, 30.0), LIVE);
        // Session 1 was closed and its id reused under epoch 7: the
        // epoch-1 stream is dead even though the id is live.
        let live = |sid: SessionId, epoch: u64| match sid {
            1 => epoch == 7,
            _ => true,
        };
        let (evicted, _) = cached(c.insert(3, 0, 3, d, &rows(2, d, 60.0), &rows(2, d, 60.0), &live));
        assert!(evicted.is_empty(), "reap, not evict");
        assert!(c.cached_state(1, 0).is_none());
        assert_eq!(c.cached_state(2, 0), Some((2, 2)));
    }

    #[test]
    fn cached_state_exposes_the_stream_epoch() {
        let d = 2;
        let mut c = cache(8, 2, EvictionPolicy::Lru);
        c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE);
        assert_eq!(c.cached_state(1, 0), Some((2, 1)));
        // A reused session id re-inserts under a fresh epoch; the old
        // stream is replaced, not appended to.
        c.insert(1, 0, 9, d, &rows(3, d, 5.0), &rows(3, d, 5.0), LIVE);
        assert_eq!(c.cached_state(1, 0), Some((3, 9)));
        assert_eq!(c.stream_count(), 1);
        let (k, _) = c.gather(1, 0).unwrap();
        assert_eq!(k, rows(3, d, 5.0));
    }

    #[test]
    fn per_kv_head_streams_are_independent() {
        let d = 2;
        let mut c = cache(8, 2, EvictionPolicy::Lru);
        c.insert(1, 0, 1, d, &rows(2, d, 0.0), &rows(2, d, 0.0), LIVE);
        c.insert(1, 1, 1, d, &rows(4, d, 9.0), &rows(4, d, 9.0), LIVE);
        assert_eq!(c.cached_len(1, 0), Some(2));
        assert_eq!(c.cached_len(1, 1), Some(4));
        assert_eq!(c.stream_count(), 2);
        assert!(c.remove(1, 0));
        assert_eq!(c.stream_count(), 1);
    }

    /// An appended tail that fills freezes into the content index: the
    /// next same-content insert attaches the frozen page too.
    #[test]
    fn filled_tails_freeze_and_become_attachable() {
        let d = 2;
        let mut c = cache(8, 2, EvictionPolicy::Lru);
        let (k, v) = (rows(1, d, 0.0), rows(1, d, 100.0));
        c.insert(1, 0, 1, d, &k, &v, LIVE);
        cached(c.append(1, 0, &rows(1, d, 10.0), &rows(1, d, 110.0), LIVE));
        // Stream 1 now holds one full (frozen) page.  A session whose
        // prefill carries the same two tokens attaches it.
        let (k2, v2) = c.gather(1, 0).unwrap();
        assert_eq!(cached(c.insert(2, 0, 2, d, &k2, &v2, LIVE)), (vec![], 1));
        assert_eq!(c.used_pages(), 1);
        // And the frozen page is immutable for stream 1's next append:
        // the new token starts a fresh page, not a mutation.
        cached(c.append(1, 0, &rows(1, d, 20.0), &rows(1, d, 120.0), LIVE));
        let (k2b, _) = c.gather(2, 0).unwrap();
        assert_eq!(k2b, k2, "the shared frozen page must not move");
    }
}
