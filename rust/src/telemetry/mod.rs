//! Telemetry primitives shared by the serving metrics and the bench
//! harness (DESIGN.md §9): a lock-free fixed-bucket log-scale
//! [`Histogram`] and a dependency-free JSON writer/parser ([`json`]).
//!
//! The histogram's bucket rule: bucket 0 holds the value 0 and bucket
//! `b` (1..=63) holds values in `[2^(b-1), 2^b - 1]` — i.e. the bucket
//! index of `v > 0` is `floor(log2 v) + 1`, clamped to 63.  Percentile
//! queries return the bucket's upper bound (capped at the true observed
//! maximum), so any reported quantile is within 2x of the exact value
//! while `record` stays a handful of relaxed atomic adds — the overhead
//! bound that lets the serving hot path carry per-op-kind latency
//! tracking unconditionally.

pub mod json;

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log-scale buckets (covers the full `u64` range).
pub const HIST_BUCKETS: usize = 64;

/// Fixed-bucket log₂ histogram over `u64` samples (latencies in ns,
/// queue depths, cycle counts).  All operations are `&self` and
/// relaxed-atomic: safe to share across device workers without locks.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; HIST_BUCKETS],
    total: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Bucket index of a sample (see the module-level bucket rule).
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Upper bound of a bucket: the largest value the bucket can hold.
pub fn bucket_upper(b: usize) -> u64 {
    if b >= HIST_BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample.
    pub fn record(&self, v: u64) {
        self.counts[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Arithmetic mean of the recorded samples (0 when empty) — exact,
    /// unlike the percentiles, because the raw sum is kept.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Nearest-rank percentile (same rank rule as
    /// [`crate::benchutil::nearest_rank`]): the value returned is the
    /// upper bound of the bucket holding the `ceil(p·n)`-th smallest
    /// sample, capped at the observed maximum — so `percentile(1.0)`
    /// can overshoot the true max by at most 0 and any `p` by at most
    /// 2x.  Returns 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((p * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for b in 0..HIST_BUCKETS {
            seen += self.counts[b].load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_upper(b).min(self.max());
            }
        }
        self.max()
    }

    /// Fold another histogram into this one (per-device → pool rollup).
    pub fn merge(&self, other: &Histogram) {
        for b in 0..HIST_BUCKETS {
            let c = other.counts[b].load(Ordering::Relaxed);
            if c > 0 {
                self.counts[b].fetch_add(c, Ordering::Relaxed);
            }
        }
        self.total.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
        self.max.fetch_max(other.max(), Ordering::Relaxed);
    }

    /// Non-empty buckets as `(upper_bound, count)` pairs, ascending —
    /// the serialized shape of the histogram.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64)> {
        (0..HIST_BUCKETS)
            .filter_map(|b| {
                let c = self.counts[b].load(Ordering::Relaxed);
                (c > 0).then_some((bucket_upper(b), c))
            })
            .collect()
    }

    /// The standard stats bundle serialized into snapshots:
    /// `(count, mean, p50, p95, p99, max)`.
    pub fn stats(&self) -> (u64, f64, u64, u64, u64, u64) {
        (
            self.count(),
            self.mean(),
            self.percentile(0.50),
            self.percentile(0.95),
            self.percentile(0.99),
            self.max(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_rule_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), 63);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(63), u64::MAX);
        // Every value lands in a bucket whose range contains it.
        for v in [0u64, 1, 7, 8, 1000, 1 << 40, u64::MAX] {
            let b = bucket_of(v);
            assert!(v <= bucket_upper(b), "v={v} b={b}");
            if b > 0 {
                assert!(v > bucket_upper(b - 1), "v={v} b={b}");
            }
        }
    }

    #[test]
    fn percentiles_within_2x_and_capped_at_max() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.max(), 1000);
        assert_eq!(h.mean(), 500.5);
        // p50 rank = 500 → bucket of 500 is [256, 511] → upper 511.
        let p50 = h.percentile(0.5);
        assert!((500..=1000).contains(&p50) && p50 <= 2 * 500, "{p50}");
        // p100 is exact (capped at the observed max).
        assert_eq!(h.percentile(1.0), 1000);
        // Single sample: every percentile is that sample (upper bound
        // capped at max).
        let one = Histogram::new();
        one.record(7);
        assert_eq!(one.percentile(0.5), 7);
        assert_eq!(one.percentile(0.99), 7);
        // Empty histogram reports zeros.
        assert_eq!(Histogram::new().percentile(0.95), 0);
        assert_eq!(Histogram::new().mean(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(10);
        b.record(1000);
        b.record(0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.sum(), 1010);
        assert_eq!(a.max(), 1000);
        assert_eq!(a.nonzero_buckets().len(), 3);
    }
}
