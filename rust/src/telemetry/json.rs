//! Hand-rolled JSON tree, writer, and parser (no serde in the offline
//! environment, DESIGN.md §substitutions).  Small by design: enough for
//! the [`MetricsSnapshot`](crate::coordinator::metrics::MetricsSnapshot)
//! schema and the `BENCH_*.json` perf records, with a parser so tests
//! can assert the emitted documents round-trip.
//!
//! Numbers are `f64`; integer-valued finites inside the exact-`f64`
//! range print without a fraction, so `u64` counters below 2^53
//! round-trip exactly (serving counters and cycle counts live far below
//! that).  Object key order is preserved (insertion order), keeping the
//! emitted documents diffable across runs.

use std::fmt::Write as _;

use anyhow::bail;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Empty object (build up with [`Json::set`]).
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Integer counter as a JSON number (exact below 2^53).
    pub fn u64(v: u64) -> Json {
        Json::Num(v as f64)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Insert/overwrite a key on an object; panics on non-objects
    /// (builder misuse, not data).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
            }
            other => panic!("Json::set on non-object {other:?}"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Some(*v as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Indented serialization (2 spaces per level) for committed files.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = |out: &mut String, d: usize| out.push_str(&"  ".repeat(d));
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    pad(out, depth + 1);
                    item.write_pretty(out, depth + 1);
                    out.push_str(if i + 1 < items.len() { ",\n" } else { "\n" });
                }
                pad(out, depth);
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    pad(out, depth + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                    out.push_str(if i + 1 < pairs.len() { ",\n" } else { "\n" });
                }
                pad(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

/// Compact single-line serialization (`.to_string()` comes with it).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_num(out: &mut String, v: f64) {
    if !v.is_finite() {
        // JSON has no NaN/Inf; null is the least-lying encoding.
        out.push_str("null");
    } else if v.fract() == 0.0 && v.abs() < 9.007_199_254_740_992e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (strict enough for round-trip tests and the
/// CI JSON-validity gate; rejects trailing garbage).
pub fn parse(text: &str) -> crate::Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {} of JSON document", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> crate::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> crate::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("invalid literal at byte {}", self.pos);
        }
    }

    fn value(&mut self) -> crate::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii number");
        match s.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(e) => bail!("bad number {s:?} at byte {start}: {e}"),
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string at byte {}", self.pos),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| {
                        anyhow::anyhow!("unterminated escape at byte {}", self.pos)
                    })?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape at byte {}", self.pos);
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| anyhow::anyhow!("bad \\u{hex}: {e}"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => bail!("bad escape \\{} at byte {}", other as char, self.pos),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (the document came from a
                    // &str, so boundaries are valid).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..]).expect("utf8 input");
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']' got {other:?} at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => bail!("expected ',' or '}}' got {other:?} at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_parses_back() {
        let mut doc = Json::obj();
        doc.set("name", Json::str("serving"))
            .set("count", Json::u64(12345))
            .set("ratio", Json::Num(0.25))
            .set("ok", Json::Bool(true))
            .set("none", Json::Null)
            .set(
                "items",
                Json::Arr(vec![Json::u64(1), Json::str("a\"b\\c\nd"), Json::Num(-1.5)]),
            );
        for text in [doc.to_string(), doc.pretty()] {
            let back = parse(&text).unwrap();
            assert_eq!(back, doc, "round-trip failed for {text:?}");
        }
        assert_eq!(doc.get("count").and_then(Json::as_u64), Some(12345));
        assert_eq!(doc.get("name").and_then(Json::as_str), Some("serving"));
        assert_eq!(doc.get("items").and_then(Json::as_arr).map(|a| a.len()), Some(3));
    }

    #[test]
    fn integer_counters_print_without_fraction() {
        assert_eq!(Json::u64(0).to_string(), "0");
        assert_eq!(Json::u64(1_000_000_007).to_string(), "1000000007");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        // 2^53-safe exactness.
        let v = (1u64 << 53) - 1;
        assert_eq!(parse(&Json::u64(v).to_string()).unwrap().as_u64(), Some(v));
    }

    #[test]
    fn parser_rejects_malformed_documents() {
        assert!(parse("{").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("{\"a\": }").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("123 456").is_err()); // trailing garbage
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#"{"s": "tab\there A end", "n": -2.5e3}"#).unwrap();
        assert_eq!(v.get("s").and_then(Json::as_str), Some("tab\there A end"));
        assert_eq!(v.get("n").and_then(Json::as_f64), Some(-2500.0));
    }

    #[test]
    fn set_overwrites_existing_keys_in_place() {
        let mut doc = Json::obj();
        doc.set("a", Json::u64(1)).set("b", Json::u64(2)).set("a", Json::u64(3));
        assert_eq!(doc.get("a").and_then(Json::as_u64), Some(3));
        assert_eq!(doc.to_string(), "{\"a\":3,\"b\":2}");
    }
}
