//! Property-testing mini-harness (the offline environment has no
//! proptest): deterministic SplitMix64-driven generators, a fixed number
//! of cases per property, and first-failure reporting with the seed so a
//! case can be replayed.
//!
//! Usage (no_run: doctest binaries miss the xla rpath in this image):
//! ```no_run
//! use fsa::testutil::Prop;
//! Prop::new("add_commutes").cases(256).run(|g| {
//!     let (a, b) = (g.i64_in(-100, 100), g.i64_in(-100, 100));
//!     assert_eq!(a + b, b + a, "a={a} b={b}");
//! });
//! ```

use crate::numerics::SplitMix64;

/// Per-case generator handed to property closures.
pub struct Gen {
    rng: SplitMix64,
    pub seed: u64,
}

impl Gen {
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        lo + self.rng.next_below((hi_inclusive - lo + 1) as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi_inclusive: i64) -> i64 {
        lo + self.rng.next_below((hi_inclusive - lo + 1) as u64) as i64
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn f32_normal(&mut self) -> f32 {
        self.rng.next_normal() as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_below(2) == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }

    /// Row-major standard-normal matrix.
    pub fn matrix(&mut self, rows: usize, cols: usize) -> Vec<f32> {
        self.rng.normal_matrix(rows, cols)
    }
}

/// A property: named, seeded, with a case budget.
pub struct Prop {
    name: &'static str,
    cases: usize,
    base_seed: u64,
}

impl Prop {
    pub fn new(name: &'static str) -> Prop {
        // Stable per-name seed so failures are reproducible across runs.
        let base_seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        });
        Prop { name, cases: 100, base_seed }
    }

    pub fn cases(mut self, n: usize) -> Prop {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Prop {
        self.base_seed = s;
        self
    }

    /// Run the property; panics with the case seed on first failure.
    pub fn run<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(self, f: F) {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case as u64);
            let result = std::panic::catch_unwind(|| {
                let mut g = Gen { rng: SplitMix64::new(seed), seed };
                f(&mut g);
            });
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .map(|s| s.as_str())
                    .or_else(|| e.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                panic!(
                    "property {:?} failed at case {case} (replay with .seed({seed:#x}).cases(1)): {msg}",
                    self.name
                );
            }
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(got: &[f32], want: &[f32], atol: f32, rtol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "index {i}: got {g}, want {w} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn properties_pass_and_are_deterministic() {
        Prop::new("sum_is_linear").cases(64).run(|g| {
            let n = g.usize_in(1, 32);
            let xs = g.matrix(1, n);
            let s: f32 = xs.iter().sum();
            let s2: f32 = xs.iter().map(|x| 2.0 * x).sum();
            assert!((s2 - 2.0 * s).abs() < 1e-4 * s.abs().max(1.0));
        });
    }

    #[test]
    #[should_panic(expected = "property \"always_fails\" failed at case 0")]
    fn failures_report_seed() {
        Prop::new("always_fails").cases(5).run(|_| panic!("boom"));
    }

    #[test]
    fn gen_ranges_respected() {
        Prop::new("ranges").cases(200).run(|g| {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let i = g.i64_in(-5, 5);
            assert!((-5..=5).contains(&i));
            let f = g.f64_in(0.25, 0.75);
            assert!((0.25..0.75).contains(&f) || f == 0.75);
            let c = *g.choose(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&c));
        });
    }

    #[test]
    #[should_panic(expected = "index 1")]
    fn assert_close_reports_index() {
        assert_close(&[1.0, 2.0], &[1.0, 3.0], 1e-3, 1e-3);
    }
}
