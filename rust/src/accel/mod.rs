//! Accelerator comparison harness: Table 1 machines + the baseline
//! pipelined FlashAttention models (Fig. 1's active-time breakdown and
//! Fig. 11's FLOPs/s utilization comparison).

pub mod baseline;

use crate::config::AccelConfig;
use crate::perfmodel::{self};
use crate::schedule::Variant;

/// One Fig.-11 data point.
#[derive(Clone, Copy, Debug)]
pub struct UtilPoint {
    pub seq_len: usize,
    pub utilization: f64,
}

/// Utilization curve for any of the three machines across sequence
/// lengths (the Fig.-11 x-axis: 2048..=16384 step 2048 in the paper).
pub fn utilization_curve(name: &str, seq_lens: &[usize], d: usize) -> crate::Result<Vec<UtilPoint>> {
    let cfg = AccelConfig::builtin(name)?;
    let pts = seq_lens
        .iter()
        .map(|&l| {
            let u = match name {
                "fsa" => {
                    perfmodel::fsa_flash_perf(&cfg, l, d, Variant::DualPath, cfg.pwl_segments)
                        .utilization
                }
                _ => baseline::baseline_flash_perf(&cfg, l, d).utilization,
            };
            UtilPoint { seq_len: l, utilization: u }
        })
        .collect();
    Ok(pts)
}

/// Mean utilization ratio FSA / other — the paper's 1.77x / 4.83x claims.
pub fn mean_ratio(fsa: &[UtilPoint], other: &[UtilPoint]) -> f64 {
    assert_eq!(fsa.len(), other.len());
    let s: f64 = fsa
        .iter()
        .zip(other)
        .map(|(a, b)| a.utilization / b.utilization)
        .sum();
    s / fsa.len() as f64
}

/// The paper's Fig.-11 sweep.
pub fn paper_seq_lens() -> Vec<usize> {
    (1..=8).map(|i| i * 2048).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_headline_ratios() {
        // Reproduce the paper's 1.77x (TPUv5e) and 4.83x (Neuron-v2)
        // average utilization gaps within modeling tolerance.
        let lens = paper_seq_lens();
        let fsa = utilization_curve("fsa", &lens, 128).unwrap();
        let tpu = utilization_curve("tpuv5e", &lens, 128).unwrap();
        let neuron = utilization_curve("neuron-v2", &lens, 128).unwrap();
        let r_tpu = mean_ratio(&fsa, &tpu);
        let r_neuron = mean_ratio(&fsa, &neuron);
        assert!((r_tpu - 1.77).abs() < 0.35, "FSA/TPUv5e ratio {r_tpu}");
        assert!((r_neuron - 4.83).abs() < 1.0, "FSA/Neuron ratio {r_neuron}");
        // Ordering invariant: FSA > TPUv5e > Neuron at every point.
        for i in 0..lens.len() {
            assert!(fsa[i].utilization > tpu[i].utilization);
            assert!(tpu[i].utilization > neuron[i].utilization);
        }
    }

    #[test]
    fn unknown_machine_is_an_error() {
        assert!(utilization_curve("gpu", &[2048], 128).is_err());
    }
}
