//! Pipelined-FlashAttention performance models of the commercial
//! baselines (TPUv5e-like, NeuronCore-v2-like).
//!
//! Structure (paper §2.3): each inner tile needs (1) two matmuls on the
//! tensor engine, (2) softmax reductions/elementwise on the vector
//! engine, (3) exp on the scalar/activation engine, (4) S/P round trips
//! through SRAM, and (5) K/V DMA.  Software pipelining overlaps
//! iterations, so the steady-state initiation interval is the *max* of
//! the per-engine times plus an exposed synchronization term — which is
//! exactly why the machine with the slowest non-matmul engine stalls its
//! systolic array (Fig. 1).
//!
//! Two calibration constants per machine (documented in EXPERIMENTS.md,
//! fitted once against the paper's reported numbers — tensor-engine
//! efficiency and effective exp throughput); everything else is
//! structural, so the sequence-length *shape* of Fig. 11 and the
//! active-time split of Fig. 1 are genuine model outputs.

use crate::config::AccelConfig;
use crate::schedule::attention_flops;

/// Kernel + calibration profile for a baseline machine.
#[derive(Clone, Copy, Debug)]
pub struct KernelProfile {
    /// FlashAttention software tile sizes used by the vendor kernel.
    pub br: usize,
    pub bc: usize,
    /// Tensor-engine efficiency during matmul (preload bubbles, SRAM port
    /// contention with the concurrently-running softmax stage — §2.3).
    pub tensor_eff: f64,
    /// Effective exp throughput in elements/cycle (instruction overheads
    /// included; calibrated to Fig. 1's 80% scalar-active on Neuron).
    pub exp_per_cycle: f64,
    /// Vector-engine efficiency on reductions/elementwise.
    pub vector_eff: f64,
    /// Software-pipelining efficiency: the steady-state initiation
    /// interval is max(engine times) / pipeline_eff (dependency stalls,
    /// S->vector->P round trips and semaphore waits not fully hidden).
    pub pipeline_eff: f64,
}

impl KernelProfile {
    pub fn for_machine(name: &str) -> crate::Result<KernelProfile> {
        Ok(match name {
            // jax.experimental.pallas TPU flash kernel: large VMEM tiles.
            "tpuv5e" => KernelProfile {
                br: 512,
                bc: 1024,
                tensor_eff: 0.80,
                exp_per_cycle: 512.0,
                vector_eff: 0.115,
                pipeline_eff: 0.80,
            },
            // neuronxcc NKI flash_fwd: 128-row tiles, SBUF-resident KV.
            "neuron-v2" => KernelProfile {
                br: 128,
                bc: 512,
                tensor_eff: 0.55,
                exp_per_cycle: 6.6,
                vector_eff: 0.5,
                pipeline_eff: 0.80,
            },
            other => anyhow::bail!("no baseline profile for {other:?}"),
        })
    }
}

/// Per-engine occupancy + end-to-end utilization (Fig. 1 + Fig. 11 data).
#[derive(Clone, Copy, Debug)]
pub struct BaselinePerf {
    pub total_cycles: u64,
    pub utilization: f64,
    /// Active-time fractions (Fig. 1 bars).
    pub tensor_active: f64,
    pub vector_active: f64,
    pub scalar_active: f64,
    pub dma_active: f64,
    pub seconds: f64,
}

/// FlashAttention forward, one head of (seq_len, d), on a baseline
/// accelerator with an external vector/scalar unit.
pub fn baseline_flash_perf(cfg: &AccelConfig, seq_len: usize, d: usize) -> BaselinePerf {
    let prof = KernelProfile::for_machine(&cfg.name)
        .unwrap_or_else(|_| panic!("machine {} has no baseline profile", cfg.name));
    let vu = cfg
        .vector_unit
        .expect("baseline machines must declare a vector unit");
    let n = cfg.array_size;
    let arrays = cfg.num_arrays as f64;

    let br = prof.br.min(seq_len);
    let bc = prof.bc.min(seq_len);
    let tr = seq_len.div_ceil(br) as u64;
    let tc = seq_len.div_ceil(bc) as u64;

    // --- Tensor engine: two matmuls per inner tile (§2.2 timing). ---
    // S = Q K^T: (br x d) x (d x bc); stationary tiles: (d/N)*(bc/N)
    // passes of (br + 2N) cycles.  O += P V similarly.
    let passes1 = (d.div_ceil(n) * bc.div_ceil(n)) as f64;
    let passes2 = (bc.div_ceil(n) * d.div_ceil(n)) as f64;
    let mm_cycles = (passes1 + passes2) * (br as f64 + 2.0 * n as f64) / arrays;
    let tensor = mm_cycles / prof.tensor_eff;

    // --- Vector engine: rowmax + subtract + rowsum + O rescale. ---
    let vector_ops = (3 * br * bc + 2 * br * d + 4 * br) as f64;
    let vector = vector_ops / (vu.vector_flops_per_cycle * prof.vector_eff);

    // --- Scalar/activation engine: exp over the whole S tile. ---
    let scalar = (br * bc) as f64 / prof.exp_per_cycle;
    let _ = vu.scalar_flops_per_cycle; // superseded by calibrated exp rate

    // --- DMA: K + V tiles per inner iteration (fp16). ---
    let bpc = cfg.mem_bw_gbs / cfg.freq_ghz;
    let dma = 2.0 * (bc * d) as f64 * 2.0 / bpc;

    // Steady state: engines overlap via software pipelining; dependency
    // stalls and S->vector->P round trips cap the overlap efficiency.
    let ii = tensor.max(vector).max(scalar).max(dma) / prof.pipeline_eff;
    // Outer loop: final rescale of O on the vector engine + Q DMA.
    let outer = (br * d) as f64 / (vu.vector_flops_per_cycle * prof.vector_eff)
        + (br * d) as f64 * 2.0 / bpc;
    let total = tr as f64 * (tc as f64 * ii + outer);

    let flops = attention_flops(seq_len, d) as f64;
    let peak_per_cycle = 2.0 * (n * n) as f64 * arrays;
    BaselinePerf {
        total_cycles: total as u64,
        utilization: flops / (peak_per_cycle * total),
        tensor_active: (tensor / ii).min(1.0),
        vector_active: (vector / ii).min(1.0),
        scalar_active: (scalar / ii).min(1.0),
        dma_active: (dma / ii).min(1.0),
        seconds: total / (cfg.freq_ghz * 1e9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_neuron_active_time_split() {
        // Paper Fig. 1: tensor engine ~45% active, scalar ~80% on
        // NeuronCore-v2 running FlashAttention.
        let cfg = AccelConfig::builtin("neuron-v2").unwrap();
        let p = baseline_flash_perf(&cfg, 8192, 128);
        assert!((p.tensor_active - 0.45).abs() < 0.10, "tensor {}", p.tensor_active);
        assert!((p.scalar_active - 0.80).abs() < 0.10, "scalar {}", p.scalar_active);
        // §6.1: under 25% FLOPs/s utilization despite 45% active time.
        assert!(p.utilization < 0.25, "util {}", p.utilization);
    }

    #[test]
    fn scalar_engine_is_neuron_bottleneck() {
        let cfg = AccelConfig::builtin("neuron-v2").unwrap();
        let p = baseline_flash_perf(&cfg, 4096, 128);
        assert!(p.scalar_active > p.tensor_active);
        assert!(p.scalar_active > p.vector_active);
        assert!(p.scalar_active > p.dma_active);
    }

    #[test]
    fn tpu_beats_neuron_but_stays_under_fsa_ceiling() {
        let tpu = AccelConfig::builtin("tpuv5e").unwrap();
        let neuron = AccelConfig::builtin("neuron-v2").unwrap();
        for l in [2048usize, 8192, 16384] {
            let pt = baseline_flash_perf(&tpu, l, 128);
            let pn = baseline_flash_perf(&neuron, l, 128);
            assert!(pt.utilization > pn.utilization, "L={l}");
            assert!(pt.utilization < 0.4, "L={l} {}", pt.utilization);
        }
    }

    #[test]
    fn utilization_grows_with_seq_len() {
        let cfg = AccelConfig::builtin("tpuv5e").unwrap();
        let us: Vec<f64> = [2048usize, 4096, 8192, 16384]
            .iter()
            .map(|&l| baseline_flash_perf(&cfg, l, 128).utilization)
            .collect();
        assert!(us.windows(2).all(|w| w[1] >= w[0] * 0.98), "{us:?}");
    }
}
