//! Fixed-width binary encoding of FSA instructions.
//!
//! Two little-endian u64 words per instruction, mirroring the "wider bit
//! fields for DMA" note of §4.2: word 0 carries the opcode, flags and the
//! input-tile descriptor; word 1 carries the output-tile descriptor.
//!
//! Layout per descriptor (52 bits): addr:32 | rows:10 | cols:10, with the
//! row stride packed into the remaining bits of the word.  Tiles are
//! bounded at 1024 x 1024 elements, far above the 128 x 128 the device
//! uses.

use anyhow::{bail, ensure};

use super::{Instruction, LaneBound, Program, Space, TileDesc};

const OP_LOAD_TILE: u8 = 1;
const OP_STORE_TILE: u8 = 2;
const OP_LOAD_STATIONARY: u8 = 3;
const OP_ATTN_SCORE: u8 = 4;
const OP_ATTN_VALUE: u8 = 5;
const OP_RECIPROCAL: u8 = 6;
const OP_ATTN_LSE_NORM: u8 = 7;
const OP_MASK_BOUND: u8 = 8;

const FLAG_FIRST: u8 = 1 << 0;
/// AttnScore: apply the boundary register as the §8 mask wave.
const FLAG_MASKED: u8 = 1 << 1;
/// MaskBound: the boundary advances with the stationary column (causal).
const FLAG_DIAG: u8 = 1 << 2;

fn space_code(s: Space) -> u8 {
    match s {
        Space::Main => 0,
        Space::Spad => 1,
        Space::Accum => 2,
    }
}

fn space_from(code: u8) -> crate::Result<Space> {
    Ok(match code {
        0 => Space::Main,
        1 => Space::Spad,
        2 => Space::Accum,
        c => bail!("invalid space code {c}"),
    })
}

/// Tile dimensions are encoded as log2 (4 bits each): device tiles are
/// powers of two up to 1024, and 0 encodes an absent tile.
fn enc_dim(v: u16) -> crate::Result<u64> {
    ensure!(
        v == 0 || (v.is_power_of_two() && v <= 1024),
        "tile dims must be powers of two <= 1024, got {v}"
    );
    Ok(if v == 0 { 0xF } else { v.trailing_zeros() as u64 })
}

fn dec_dim(code: u64) -> u16 {
    if code == 0xF {
        0
    } else {
        1u16 << code
    }
}

/// Encode one instruction into two u64 words.
///
/// word0: opcode:8 | flags:8 | in_space:2 | out_space:2 | in_stride:20 | out_stride:20
/// word1: in_addr:24 | out_addr:24 | log2-dims:16 (in.rows, in.cols, out.rows, out.cols)
pub fn encode(i: &Instruction) -> crate::Result<[u64; 2]> {
    // MaskBound carries no tiles: word1 packs the boundary register
    // payload instead (base:32 | cap:16), the diag bit rides in flags.
    if let Instruction::MaskBound { bound } = *i {
        let flags = if bound.diag { FLAG_DIAG } else { 0 };
        let word0 = (OP_MASK_BOUND as u64) | ((flags as u64) << 8);
        let word1 = (bound.base as u32 as u64) | ((bound.cap as u64) << 32);
        return Ok([word0, word1]);
    }
    let (op, flags, input, output) = match *i {
        Instruction::LoadTile { src, dst } => (OP_LOAD_TILE, 0, src, Some(dst)),
        Instruction::StoreTile { src, dst } => (OP_STORE_TILE, 0, src, Some(dst)),
        Instruction::LoadStationary { src } => (OP_LOAD_STATIONARY, 0, src, None),
        Instruction::AttnScore { k, lse, first, masked } => (
            OP_ATTN_SCORE,
            if first { FLAG_FIRST } else { 0 } | if masked { FLAG_MASKED } else { 0 },
            k,
            Some(lse),
        ),
        Instruction::AttnValue { v, out, first } => {
            (OP_ATTN_VALUE, if first { FLAG_FIRST } else { 0 }, v, Some(out))
        }
        Instruction::Reciprocal { l } => (OP_RECIPROCAL, 0, l, None),
        Instruction::AttnLseNorm { out, l } => (OP_ATTN_LSE_NORM, 0, l, Some(out)),
    };
    let out = output.unwrap_or(TileDesc::contiguous(Space::Main, 0, 0, 0));
    ensure!(input.stride <= 0xF_FFFF && out.stride <= 0xF_FFFF, "stride too large");
    ensure!(
        input.addr < (1 << 24) && out.addr < (1 << 24),
        "address exceeds 24-bit field"
    );

    let word0 = (op as u64)
        | ((flags as u64) << 8)
        | ((space_code(input.space) as u64) << 16)
        | ((space_code(out.space) as u64) << 18)
        | ((input.stride as u64) << 20)
        | ((out.stride as u64) << 40);
    let dims = enc_dim(input.rows)?
        | (enc_dim(input.cols)? << 4)
        | (enc_dim(out.rows)? << 8)
        | (enc_dim(out.cols)? << 12);
    let word1 = (input.addr as u64) | ((out.addr as u64) << 24) | (dims << 48);
    Ok([word0, word1])
}

/// Decode two u64 words back into an instruction.
pub fn decode(words: [u64; 2]) -> crate::Result<Instruction> {
    let op = (words[0] & 0xFF) as u8;
    let flags = ((words[0] >> 8) & 0xFF) as u8;
    if op == OP_MASK_BOUND {
        return Ok(Instruction::MaskBound {
            bound: LaneBound {
                base: (words[1] & 0xFFFF_FFFF) as u32 as i32,
                diag: flags & FLAG_DIAG != 0,
                cap: ((words[1] >> 32) & 0xFFFF) as u16,
            },
        });
    }
    let in_space = space_from(((words[0] >> 16) & 0x3) as u8)?;
    let out_space = space_from(((words[0] >> 18) & 0x3) as u8)?;
    let in_stride = ((words[0] >> 20) & 0xF_FFFF) as u32;
    let out_stride = ((words[0] >> 40) & 0xF_FFFF) as u32;
    let in_addr = (words[1] & 0xFF_FFFF) as u32;
    let out_addr = ((words[1] >> 24) & 0xFF_FFFF) as u32;
    let dims = words[1] >> 48;
    let input = TileDesc {
        space: in_space,
        addr: in_addr,
        rows: dec_dim(dims & 0xF),
        cols: dec_dim((dims >> 4) & 0xF),
        stride: in_stride,
    };
    let output = TileDesc {
        space: out_space,
        addr: out_addr,
        rows: dec_dim((dims >> 8) & 0xF),
        cols: dec_dim((dims >> 12) & 0xF),
        stride: out_stride,
    };
    let first = flags & FLAG_FIRST != 0;
    let masked = flags & FLAG_MASKED != 0;
    Ok(match op {
        OP_LOAD_TILE => Instruction::LoadTile { src: input, dst: output },
        OP_STORE_TILE => Instruction::StoreTile { src: input, dst: output },
        OP_LOAD_STATIONARY => Instruction::LoadStationary { src: input },
        OP_ATTN_SCORE => Instruction::AttnScore { k: input, lse: output, first, masked },
        OP_ATTN_VALUE => Instruction::AttnValue { v: input, out: output, first },
        OP_RECIPROCAL => Instruction::Reciprocal { l: input },
        OP_ATTN_LSE_NORM => Instruction::AttnLseNorm { out: output, l: input },
        c => bail!("invalid opcode {c}"),
    })
}

/// Encode a whole program into a flat word stream.
pub fn encode_program(p: &Program) -> crate::Result<Vec<u64>> {
    let mut words = Vec::with_capacity(p.len() * 2);
    for i in &p.instructions {
        let [a, b] = encode(i)?;
        words.push(a);
        words.push(b);
    }
    Ok(words)
}

/// Decode a flat word stream back into a program.
pub fn decode_program(words: &[u64]) -> crate::Result<Program> {
    ensure!(words.len() % 2 == 0, "truncated instruction stream");
    let mut p = Program::new();
    for pair in words.chunks(2) {
        p.push(decode([pair[0], pair[1]])?);
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::SplitMix64;

    fn rand_tile(r: &mut SplitMix64, space: Space) -> TileDesc {
        let rows = 1u16 << r.next_below(8);
        let cols = 1u16 << r.next_below(8);
        TileDesc {
            space,
            addr: r.next_below(1 << 20) as u32,
            rows,
            cols,
            stride: cols as u32 + r.next_below(64) as u32,
        }
    }

    #[test]
    fn round_trip_all_opcodes() {
        let mut r = SplitMix64::new(99);
        for trial in 0..2000 {
            let a = rand_tile(&mut r, Space::Spad);
            let b = rand_tile(&mut r, Space::Accum);
            let m = rand_tile(&mut r, Space::Main);
            let first = r.next_below(2) == 0;
            let masked = r.next_below(2) == 0;
            let insns = [
                Instruction::LoadTile { src: m, dst: a },
                Instruction::StoreTile { src: b, dst: m },
                Instruction::LoadStationary { src: a },
                Instruction::AttnScore { k: a, lse: b, first, masked },
                Instruction::AttnValue { v: a, out: b, first },
                Instruction::Reciprocal { l: b },
                Instruction::AttnLseNorm { out: b, l: b },
                Instruction::MaskBound {
                    bound: LaneBound {
                        base: r.next_below(1 << 16) as i32 - (1 << 15),
                        diag: masked,
                        cap: r.next_below(1024) as u16,
                    },
                },
            ];
            let i = insns[(trial % insns.len()) as usize];
            let enc = encode(&i).unwrap();
            let dec = decode(enc).unwrap();
            assert_eq!(i, dec, "trial {trial}");
        }
    }

    #[test]
    fn program_stream_round_trip() {
        let mut p = Program::new();
        let t = TileDesc::contiguous(Space::Spad, 0x40, 128, 128);
        let l = TileDesc::contiguous(Space::Accum, 0, 1, 128);
        p.push(Instruction::LoadStationary { src: t });
        p.push(Instruction::MaskBound {
            bound: LaneBound { base: -7, diag: true, cap: 128 },
        });
        p.push(Instruction::AttnScore { k: t, lse: l, first: true, masked: true });
        p.push(Instruction::Reciprocal { l });
        let words = encode_program(&p).unwrap();
        assert_eq!(words.len(), 8);
        assert_eq!(decode_program(&words).unwrap(), p);
    }

    #[test]
    fn rejects_invalid_streams() {
        assert!(decode_program(&[1]).is_err()); // odd length
        assert!(decode([0xFF, 0]).is_err()); // bad opcode
        let t = TileDesc { space: Space::Spad, addr: 0, rows: 100, cols: 128, stride: 128 };
        // Non-power-of-two rows are rejected by the compact dim encoding.
        assert!(encode(&Instruction::LoadStationary { src: t }).is_err());
        // Oversized address.
        let big = TileDesc::contiguous(Space::Main, 1 << 27, 128, 128);
        assert!(encode(&Instruction::LoadStationary { src: big }).is_err());
    }
}
