//! The FSA instruction set (paper §4.2 + Fig. 9).
//!
//! Five compute instructions + two DMA instructions.  Compute
//! instructions are one-tile-in / one-tile-out and *fully deterministic*
//! once issued (the controller statically schedules every control signal
//! from a cycle counter); DMA instructions carry a 2D descriptor pair.
//! Instructions of different classes (load / store / compute) execute
//! asynchronously; within a class they issue in order.
//!
//! [`encode`] provides the fixed-width binary format (two u64 words per
//! instruction, like the real device's instruction queue entries).

pub mod encode;

/// Memory spaces visible to the ISA (paper §5.1's MTile/STile/ATile).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Space {
    /// Backing memory behind the AXI ports.
    Main,
    /// Scratchpad SRAM.
    Spad,
    /// Accumulation SRAM at the bottom edge of the array.
    Accum,
}

/// A 2D tile descriptor: `rows x cols` elements starting at `addr`
/// (element-addressed) with a row `stride` in elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileDesc {
    pub space: Space,
    pub addr: u32,
    pub rows: u16,
    pub cols: u16,
    pub stride: u32,
}

impl TileDesc {
    pub fn contiguous(space: Space, addr: u32, rows: u16, cols: u16) -> TileDesc {
        TileDesc { space, addr, rows, cols, stride: cols as u32 }
    }

    pub fn elems(&self) -> usize {
        self.rows as usize * self.cols as usize
    }

    /// Inclusive-exclusive element footprint [addr, end) assuming row-major.
    pub fn end_addr(&self) -> u32 {
        if self.rows == 0 {
            self.addr
        } else {
            self.addr + (self.rows as u32 - 1) * self.stride + self.cols as u32
        }
    }

    pub fn overlaps(&self, other: &TileDesc) -> bool {
        self.space == other.space
            && self.addr < other.end_addr()
            && other.addr < self.end_addr()
    }
}

/// The mask-wave boundary of one partially masked tile (DESIGN.md §8):
/// the value a [`Instruction::MaskBound`] writes into the controller's
/// boundary register.  For stationary (query) column `m`, key lanes
/// `>= clamp(base + diag·m, 0, cap)` are *masked*: the CMP row excludes
/// them from the running rowmax and re-streams them as zero with the
/// masked sideband bit set, so their P is exactly 0 through the rowsum
/// and PV waves.  Both mask kinds and zero-padded ragged tails are
/// linear in `m`: a causal diagonal tile is `base = q0 + 1 - k0`,
/// `diag = true`; a padding boundary or ragged tail is a uniform
/// `base = bound`, `diag = false`; `cap` is the number of real key
/// lanes in the tile (`< N` when a short tail rides in zero-padded).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaneBound {
    pub base: i32,
    pub diag: bool,
    pub cap: u16,
}

impl LaneBound {
    /// Valid-lane count of stationary column `m`.
    pub fn bound(&self, m: usize) -> u16 {
        let b = self.base + if self.diag { m as i32 } else { 0 };
        b.clamp(0, self.cap as i32) as u16
    }

    /// True when every lane of an `n`-wide tile is valid for every
    /// column — such a bound needs no mask wave and no `MaskBound`.
    pub fn is_full(&self, n: usize) -> bool {
        self.cap as usize == n && (0..n).all(|m| self.bound(m) as usize == n)
    }
}

/// The instruction set.  Operand conventions follow Listing 1 of the
/// paper; every compute instruction implicitly targets the systolic array
/// + accumulator of its device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Instruction {
    /// DMA: main memory -> scratchpad SRAM.
    LoadTile { src: TileDesc, dst: TileDesc },
    /// DMA: accumulation SRAM -> main memory.
    StoreTile { src: TileDesc, dst: TileDesc },
    /// Preload the stationary matrix (Q tile) into the PE array.
    LoadStationary { src: TileDesc },
    /// Program the controller's mask boundary register (DESIGN.md §8);
    /// consumed by the next [`Instruction::AttnScore`] with
    /// `masked = true`.  Zero-latency: a control-register write the
    /// sequencer folds into the score's issue.
    MaskBound { bound: LaneBound },
    /// First matmul S = Q K^T fused with online softmax: rowmax via the
    /// CMP row, in-place subtract/scale/exp2, rowsum; leaves P resident in
    /// the array and accumulates the (log-)exponent sum into `lse`.
    /// `first` resets the running max/denominator (j == 0 of Algorithm 1).
    /// `masked` applies the boundary register programmed by the
    /// preceding [`Instruction::MaskBound`] as the §8 mask wave (one
    /// extra element-wise cycle, `InnerSchedule::masked_inner_latency`).
    AttnScore { k: TileDesc, lse: TileDesc, first: bool, masked: bool },
    /// Second matmul O += P V into the accumulator (with diag(b) rescale).
    AttnValue { v: TileDesc, out: TileDesc, first: bool },
    /// Accumulator-local reciprocal of the exponent sum.
    Reciprocal { l: TileDesc },
    /// Scale the accumulated O tile by the reciprocal (line 21).
    AttnLseNorm { out: TileDesc, l: TileDesc },
}

/// Execution class for queue routing (paper §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    Load,
    Store,
    Compute,
}

impl Instruction {
    pub fn class(&self) -> Class {
        match self {
            Instruction::LoadTile { .. } => Class::Load,
            Instruction::StoreTile { .. } => Class::Store,
            _ => Class::Compute,
        }
    }

    /// Whether this is a masked [`Instruction::AttnScore`] (the §8 mask
    /// wave applies, costing one extra element-wise cycle).
    pub fn is_masked_score(&self) -> bool {
        matches!(self, Instruction::AttnScore { masked: true, .. })
    }

    /// Human-readable mnemonic (used by the disassembler and traces).
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instruction::LoadTile { .. } => "load_tile",
            Instruction::StoreTile { .. } => "store_tile",
            Instruction::LoadStationary { .. } => "load_stationary",
            Instruction::MaskBound { .. } => "mask_bound",
            Instruction::AttnScore { .. } => "attn_score",
            Instruction::AttnValue { .. } => "attn_value",
            Instruction::Reciprocal { .. } => "reciprocal",
            Instruction::AttnLseNorm { .. } => "attn_lse_norm",
        }
    }

    /// The SRAM tile this instruction reads (compute instructions read
    /// exactly one input tile — the §4.2 "one-tile-in" rule;
    /// `MaskBound` is a register write and reads none).
    pub fn input_tile(&self) -> Option<&TileDesc> {
        match self {
            Instruction::LoadTile { src, .. } => Some(src),
            Instruction::StoreTile { src, .. } => Some(src),
            Instruction::LoadStationary { src } => Some(src),
            Instruction::MaskBound { .. } => None,
            Instruction::AttnScore { k, .. } => Some(k),
            Instruction::AttnValue { v, .. } => Some(v),
            Instruction::Reciprocal { l } => Some(l),
            Instruction::AttnLseNorm { l, .. } => Some(l),
        }
    }

    /// The tile this instruction writes, if any.
    pub fn output_tile(&self) -> Option<&TileDesc> {
        match self {
            Instruction::LoadTile { dst, .. } => Some(dst),
            Instruction::StoreTile { dst, .. } => Some(dst),
            Instruction::LoadStationary { .. } | Instruction::MaskBound { .. } => None,
            Instruction::AttnScore { lse, .. } => Some(lse),
            Instruction::AttnValue { out, .. } => Some(out),
            Instruction::Reciprocal { l } => Some(l),
            Instruction::AttnLseNorm { out, .. } => Some(out),
        }
    }
}

/// A compiled FSA program: the unit the JIT builder emits and the device
/// consumes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Program {
    pub instructions: Vec<Instruction>,
}

impl Program {
    pub fn new() -> Program {
        Program::default()
    }

    pub fn push(&mut self, i: Instruction) {
        self.instructions.push(i);
    }

    pub fn len(&self) -> usize {
        self.instructions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instructions.is_empty()
    }

    /// Count instructions per class (used by scheduling sanity checks).
    pub fn class_counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for i in &self.instructions {
            match i.class() {
                Class::Load => c.0 += 1,
                Class::Store => c.1 += 1,
                Class::Compute => c.2 += 1,
            }
        }
        c
    }

    /// Disassemble into a printable listing.
    pub fn disasm(&self) -> String {
        let mut out = String::new();
        for (pc, i) in self.instructions.iter().enumerate() {
            out.push_str(&format!("{pc:5}: {}\n", disasm_one(i)));
        }
        out
    }
}

fn disasm_one(i: &Instruction) -> String {
    fn t(d: &TileDesc) -> String {
        let s = match d.space {
            Space::Main => "mem",
            Space::Spad => "spad",
            Space::Accum => "acc",
        };
        format!("{s}[{:#x} {}x{} stride {}]", d.addr, d.rows, d.cols, d.stride)
    }
    match i {
        Instruction::LoadTile { src, dst } => format!("load_tile {} -> {}", t(src), t(dst)),
        Instruction::StoreTile { src, dst } => format!("store_tile {} -> {}", t(src), t(dst)),
        Instruction::LoadStationary { src } => format!("load_stationary {}", t(src)),
        Instruction::MaskBound { bound } => format!(
            "mask_bound base={} diag={} cap={}",
            bound.base, bound.diag, bound.cap
        ),
        Instruction::AttnScore { k, lse, first, masked } => {
            format!("attn_score k={} lse={} first={first} masked={masked}", t(k), t(lse))
        }
        Instruction::AttnValue { v, out, first } => {
            format!("attn_value v={} out={} first={first}", t(v), t(out))
        }
        Instruction::Reciprocal { l } => format!("reciprocal {}", t(l)),
        Instruction::AttnLseNorm { out, l } => {
            format!("attn_lse_norm out={} l={}", t(out), t(l))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tile(addr: u32, rows: u16, cols: u16) -> TileDesc {
        TileDesc::contiguous(Space::Spad, addr, rows, cols)
    }

    #[test]
    fn classes_route_correctly() {
        let load = Instruction::LoadTile { src: tile(0, 4, 4), dst: tile(0, 4, 4) };
        let comp = Instruction::AttnScore {
            k: tile(0, 4, 4),
            lse: tile(0, 1, 4),
            first: true,
            masked: false,
        };
        let bound = Instruction::MaskBound { bound: LaneBound { base: 1, diag: true, cap: 4 } };
        assert_eq!(load.class(), Class::Load);
        assert_eq!(comp.class(), Class::Compute);
        assert_eq!(bound.class(), Class::Compute);
        assert!(bound.input_tile().is_none() && bound.output_tile().is_none());
        assert!(!comp.is_masked_score());
        let mut p = Program::new();
        p.push(load);
        p.push(comp);
        assert_eq!(p.class_counts(), (1, 0, 1));
    }

    #[test]
    fn lane_bound_arithmetic() {
        // Causal diagonal tile: column m attends m+1 lanes.
        let diag = LaneBound { base: 1, diag: true, cap: 8 };
        assert_eq!(diag.bound(0), 1);
        assert_eq!(diag.bound(7), 8);
        assert!(!diag.is_full(8));
        // Uniform padding boundary: every column attends 5 lanes.
        let pad = LaneBound { base: 5, diag: false, cap: 8 };
        assert!((0..8).all(|m| pad.bound(m) == 5));
        // Negative bases clamp to zero (a chunk's pre-diagonal rows).
        let neg = LaneBound { base: -3, diag: true, cap: 8 };
        assert_eq!(neg.bound(0), 0);
        assert_eq!(neg.bound(4), 2);
        // A saturated bound over full-width lanes is "no mask"; a
        // short cap (ragged tail) never is, even when every column
        // saturates at it.
        assert!(LaneBound { base: 8, diag: false, cap: 8 }.is_full(8));
        assert!(!LaneBound { base: 8, diag: false, cap: 6 }.is_full(8));
        assert!(LaneBound { base: 1, diag: true, cap: 1 }.is_full(1));
        assert!(!LaneBound { base: 1, diag: true, cap: 8 }.is_full(8));
    }

    #[test]
    fn tile_overlap_logic() {
        let a = tile(0, 2, 8); // [0, 16)
        let b = tile(8, 2, 8); // [8, 24)
        let c = tile(16, 2, 8); // [16, 32)
        assert!(a.overlaps(&b));
        assert!(!a.overlaps(&c));
        let mut d = b;
        d.space = Space::Accum;
        assert!(!a.overlaps(&d)); // different space
    }

    #[test]
    fn strided_tile_footprint() {
        let t = TileDesc { space: Space::Main, addr: 100, rows: 3, cols: 4, stride: 10 };
        assert_eq!(t.end_addr(), 100 + 2 * 10 + 4);
        assert_eq!(t.elems(), 12);
    }

    #[test]
    fn disasm_is_stable() {
        let i = Instruction::AttnValue { v: tile(64, 8, 8), out: tile(0, 8, 8), first: false };
        assert!(disasm_one(&i).contains("attn_value"));
        assert!(disasm_one(&i).contains("first=false"));
    }
}
