//! The SystolicAttention schedule (paper §3.5 + Fig. 7).
//!
//! FSA's controller statically schedules every control signal from a
//! per-instruction cycle counter (§4.3).  This module is the analytical
//! core shared by the cycle simulator and the performance model:
//!
//! * the closed-form latency/occupancy formulas the paper states
//!   (inner iteration `2*N_COLS + 3*N_ROWS + 10 = 5N + 10`, the
//!   single-direction variant `6N + 10`, the naive two-matmul bound
//!   `2(M + 3N - 1)`, and the `2N + 20` rescale), and
//! * the per-phase wavefront timing used to drive edge injections in
//!   [`crate::sim`] — every formula below is *derived* from the wave
//!   arithmetic and *validated* by the cycle-accurate simulator in
//!   `rust/tests/cycle_model.rs`.
//!
//! Wave timing (t = 0 at AttnScore issue = the cycle its first edge
//! injection is queued; an injection queued at cycle c enters the array at
//! c+1; N = array dim; segments = 8; derivation in DESIGN.md §3):
//!
//! | event                                   | cycle                      |
//! |-----------------------------------------|----------------------------|
//! | K row n queued at array row k           | `n + (N-1-k)`              |
//! | S[m,n] processed by CMP unit m          | `n + N + m`                |
//! | new_m[m] final                          | `2N + m`                   |
//! | S[m,n] parked at PE(n,m)                | `2n + N + m + 2`           |
//! | subtract wave applies at PE(n,m)        | `2N + m + n + 2`           |
//! | const-mult wave (and a=old_m-new_m down)| `2N + m + n + 3`           |
//! | PWL pair j in {0..7} applies at PE(n,m) | `2N + m + n + 4 + j`       |
//! | rowsum psum passes PE(n,m)              | `2N + m + n + 12`          |
//! | PV psum for O[m,h] passes PE(n,m)       | `2N + m + n + h + 13`      |
//! | O[m,h] received by the accumulator      | `3N + m + h + 12`          |
//! | last output (m = h = N-1)               | `5N + 10` exactly          |

use crate::mask::{MaskKind, TileCoverage};

/// Dataflow variant (§8.2): the full FSA uses both directions; the
/// area-optimized variant has a single (downward) accumulation path and
/// must wait for the whole P matrix before starting O = P V.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Upward first matmul + downward second matmul (the paper's FSA).
    DualPath,
    /// Single direction; +N cycles per inner iteration.
    SinglePath,
}

/// Static timing for an `N x N` SystolicAttention inner iteration.
#[derive(Clone, Copy, Debug)]
pub struct InnerSchedule {
    pub n: usize,
    pub variant: Variant,
    /// Number of PWL segments streamed (8 in the paper; the `+10` in the
    /// formula is 2 elementwise waves + 8 PWL waves).
    pub segments: usize,
}

impl InnerSchedule {
    pub fn new(n: usize, variant: Variant, segments: usize) -> InnerSchedule {
        assert!(n >= 2, "array dim must be >= 2");
        assert!(segments >= 1);
        InnerSchedule { n, variant, segments }
    }

    /// Paper formula: iteration latency in cycles.  For the default
    /// 8-segment PWL this is `5N + 10` (dual path) or `6N + 10` (single
    /// path); other segment counts shift the elementwise window.
    pub fn inner_latency(&self) -> u64 {
        let n = self.n as u64;
        let elementwise = 2 + self.segments as u64; // sub, const-mul, PWL waves
        match self.variant {
            Variant::DualPath => 5 * n + elementwise,
            Variant::SinglePath => 6 * n + elementwise,
        }
    }

    /// Cycle at which K row `n` must enter array row `k` (first matmul,
    /// upward path; reverse row skew).
    pub fn k_inject(&self, n: usize, k: usize) -> u64 {
        (n + (self.n - 1 - k)) as u64
    }

    /// Cycle at which S[m, n] is processed by CMP unit m (its one-cycle
    /// pipeline stage: max update + downward re-stream).
    pub fn s_at_cmp(&self, m: usize, n: usize) -> u64 {
        (n + self.n + m) as u64
    }

    /// Cycle at which the row max new_m[m] is final.
    pub fn rowmax_done(&self, m: usize) -> u64 {
        (2 * self.n + m) as u64
    }

    /// Cycle at which S[m, n] is parked in PE(row n, col m) after being
    /// re-streamed down from the CMP row.
    pub fn s_parked(&self, m: usize, n: usize) -> u64 {
        (2 * n + self.n + m + 2) as u64
    }

    /// Elementwise wave `w` (0 = subtract, 1 = const-mult, 2.. = PWL pair
    /// w-2) application cycle at PE(n, m).
    pub fn elementwise(&self, w: usize, n: usize, m: usize) -> u64 {
        debug_assert!(w < 2 + self.segments);
        (2 * self.n + m + n + 2 + w) as u64
    }

    /// Rowsum psum passes PE(n, m).
    pub fn rowsum_at(&self, n: usize, m: usize) -> u64 {
        (2 * self.n + m + n + 4 + self.segments) as u64
    }

    /// Queue cycle of the first V injection (h = 0, row 0).
    pub fn pv_start(&self) -> u64 {
        match self.variant {
            // One cycle behind the rowsum wave on the downward path.
            Variant::DualPath => (2 * self.n + 4 + self.segments) as u64,
            // Wait for the last P element (PE(N-1, N-1)) to be computed.
            Variant::SinglePath => (3 * self.n + 4 + self.segments) as u64,
        }
    }

    /// PV psum for output element O[m, h] passes PE(n, m).
    pub fn pv_at(&self, n: usize, m: usize, h: usize) -> u64 {
        self.pv_start() + (h + n + m) as u64 + 1
    }

    /// O[m, h] is received by the accumulator.
    pub fn o_exit(&self, m: usize, h: usize) -> u64 {
        self.pv_at(self.n - 1, m, h)
    }

    /// Last cycle with activity — the final output element lands in the
    /// accumulator exactly at `inner_latency` (== 5N+10 for 8 segments).
    pub fn last_cycle(&self) -> u64 {
        self.o_exit(self.n - 1, self.n - 1)
    }

    /// Iteration latency of a *partially masked* tile (causal diagonal
    /// tiles, the padding boundary tile): one extra element-wise wave —
    /// the mask wave that parks the finite `-inf` stand-in on masked
    /// lanes and zeroes their P — widens the `2 + segments` window by
    /// one cycle.  Fully-masked tiles cost nothing: the tile-skipping
    /// schedule never issues them (DESIGN.md §6).
    pub fn masked_inner_latency(&self) -> u64 {
        self.inner_latency() + 1
    }

    /// Inner-iteration latency with a single live query row — the
    /// decode-phase degeneration of the §3.5 wave (one stationary Q
    /// column, §8.3's `d < N` concern taken to its extreme).
    ///
    /// Model assumption (not a paper formula, and below the
    /// cycle-accurate simulator's granularity, which schedules full
    /// tiles): with `br = 1` the park stream and the PV psum chain no
    /// longer span the `N` query columns, collapsing the two
    /// column-indexed `+N` spans of the `5N + 10` derivation — K still
    /// streams `N` rows up, the elementwise window is unchanged, and
    /// the single output row drains in `O(d)`; `3N + 2 + segments`
    /// dual-path, one extra `N` single-path (wait for the whole P row
    /// before PV, §8.2).  The decode perfmodel and its O(L)-per-step
    /// claim only require this to be Θ(N) per column tile.
    pub fn decode_latency(&self) -> u64 {
        let n = self.n as u64;
        let elementwise = 2 + self.segments as u64;
        match self.variant {
            Variant::DualPath => 3 * n + elementwise,
            Variant::SinglePath => 4 * n + elementwise,
        }
    }
}

/// Outer-loop (per Q row-block) epilogue: Reciprocal + AttnLseNorm.
/// Paper: "this re-scaling step takes 2N + 20 cycles".
pub fn rescale_latency(n: usize) -> u64 {
    2 * n as u64 + 20
}

/// Stationary preload occupancy (N cycles); overlapped with the previous
/// iteration's PV phase in steady state, exposed only on the first
/// iteration of a row block.
pub fn preload_latency(n: usize) -> u64 {
    n as u64
}

/// Naive baseline (paper §2.2 / §3.5): two back-to-back `N x M` matmuls on
/// a standard weight-stationary array, each `M + 3N - 1` cycles including
/// preload and skew; softmax excluded.  `8N - 2` when M = N.
pub fn naive_two_matmul(n: usize, m: usize) -> u64 {
    2 * (m as u64 + 3 * n as u64 - 1)
}

/// Standard-array single matmul latency (preload + stream + drain).
pub fn standard_matmul(n: usize, m: usize) -> u64 {
    m as u64 + 3 * n as u64 - 1
}

/// FLOPs of one FlashAttention inner iteration on an N-tile (two N^3
/// matmuls, 2 FLOPs per MAC).
pub fn inner_flops(n: usize) -> u64 {
    4 * (n as u64).pow(3)
}

/// Total attention FLOPs for a full (seq_len, d) head — the paper's
/// `4 * SeqLen^2 * d` (§6.1).
pub fn attention_flops(seq_len: usize, d: usize) -> u64 {
    4 * (seq_len as u64) * (seq_len as u64) * d as u64
}

/// Tile census of a masked `(seq_len, seq_len)` score matrix at the
/// paper's `Br = Bc = N` tiling (sequence padded up to whole tiles, as
/// the array computes them): `(full, partial, skipped)` tile counts.
/// Skipped tiles are never issued by the tile-skipping schedule; partial
/// tiles take the element-wise mask pass
/// ([`InnerSchedule::masked_inner_latency`]).  For causal this is the
/// `t(t-1)/2` lower triangle + `t` diagonal tiles + `t(t-1)/2` skipped —
/// the ≈2× tile reduction.
pub fn masked_tile_counts(seq_len: usize, n: usize, mask: MaskKind) -> (u64, u64, u64) {
    assert!(n >= 1 && seq_len >= 1);
    let t = seq_len.div_ceil(n);
    let (mut full, mut partial, mut skipped) = (0u64, 0u64, 0u64);
    for i in 0..t {
        for j in 0..t {
            match mask.coverage(i * n, n, j * n, n) {
                TileCoverage::Full => full += 1,
                TileCoverage::Partial => partial += 1,
                TileCoverage::Empty => skipped += 1,
            }
        }
    }
    (full, partial, skipped)
}

/// Range-restricted [`masked_tile_counts`]: the tile census of one
/// sequence-parallel K/V *chunk* covering global keys `[key_start,
/// key_start + key_len)` (DESIGN.md §7).  Row tiles span the whole
/// query sequence (every device computes all rows of its chunk); column
/// tiles start at the chunk boundary (the device tiles its chunk
/// locally, ragged final tile allowed) but coverage is classified at
/// global key coordinates, so causal intersection and padding
/// boundaries skip exactly the tiles the device skips.  With the whole
/// key range and tile-aligned boundaries this reproduces
/// [`masked_tile_counts`].
pub fn masked_tile_counts_range(
    seq_len: usize,
    n: usize,
    mask: MaskKind,
    key_start: usize,
    key_len: usize,
) -> (u64, u64, u64) {
    assert!(n >= 1 && seq_len >= 1 && key_len >= 1);
    let t_r = seq_len.div_ceil(n);
    let t_c = key_len.div_ceil(n);
    let (mut full, mut partial, mut skipped) = (0u64, 0u64, 0u64);
    for i in 0..t_r {
        for j in 0..t_c {
            let c0 = key_start + j * n;
            let w = n.min(key_start + key_len - c0);
            match mask.coverage(i * n, n, c0, w) {
                TileCoverage::Full => full += 1,
                TileCoverage::Partial => partial += 1,
                TileCoverage::Empty => skipped += 1,
            }
        }
    }
    (full, partial, skipped)
}

/// Range-restricted [`masked_attention_flops`]: useful FLOPs of the
/// valid `(query, key)` pairs whose key falls in `[key_start,
/// key_start + key_len)` — the per-chunk share of the whole operator's
/// work.  Chunks of a partition sum exactly to the whole-operator
/// count (pinned by a unit test).
pub fn masked_attention_flops_range(
    seq_len: usize,
    d: usize,
    mask: MaskKind,
    key_start: usize,
    key_len: usize,
) -> u64 {
    let end = key_start + key_len;
    let mut pairs = 0u64;
    for i in 0..seq_len {
        // valid_keys clamps at its `lk` argument, so evaluating it at
        // the range end gives min(global valid prefix, range end).
        pairs += mask.valid_keys(i, end).saturating_sub(key_start) as u64;
    }
    4 * pairs * d as u64
}

/// Query-range-restricted [`masked_tile_counts_range`]: the tile census
/// of a *resumed* prefill that computes only the suffix query rows
/// `[query_start, seq_len)` over the key chunk `[key_start, key_start +
/// key_len)` (DESIGN.md §11).  The suffix rows are tiled locally from
/// the resume point (row tile `i` covers global rows `query_start +
/// i*n ..`), but coverage is classified at *global* query coordinates —
/// exactly how the resumed kernel evaluates its mask — so the causal
/// diagonal lands where the cold run's does.  `query_start == 0`
/// reproduces [`masked_tile_counts_range`] whenever the cold row tiling
/// is aligned, and the saved-prefill-cycles term in
/// [`crate::perfmodel`] is the difference between the two censuses.
pub fn masked_tile_counts_resumed(
    seq_len: usize,
    n: usize,
    mask: MaskKind,
    query_start: usize,
    key_start: usize,
    key_len: usize,
) -> (u64, u64, u64) {
    assert!(n >= 1 && seq_len >= 1 && key_len >= 1);
    assert!(query_start < seq_len, "resume point must leave suffix rows");
    let t_r = (seq_len - query_start).div_ceil(n);
    let t_c = key_len.div_ceil(n);
    let (mut full, mut partial, mut skipped) = (0u64, 0u64, 0u64);
    for i in 0..t_r {
        for j in 0..t_c {
            let c0 = key_start + j * n;
            let w = n.min(key_start + key_len - c0);
            match mask.coverage(query_start + i * n, n, c0, w) {
                TileCoverage::Full => full += 1,
                TileCoverage::Partial => partial += 1,
                TileCoverage::Empty => skipped += 1,
            }
        }
    }
    (full, partial, skipped)
}

/// Query-range-restricted [`masked_attention_flops_range`]: useful
/// FLOPs of the valid `(query, key)` pairs whose query row falls in
/// `[query_start, seq_len)` and whose key falls in `[key_start,
/// key_start + key_len)` — the work a resumed prefill actually
/// performs.  The covered-prefix complement (`query_start == 0` total
/// minus this) is the work the prefix cache saved.
pub fn masked_attention_flops_resumed(
    seq_len: usize,
    d: usize,
    mask: MaskKind,
    query_start: usize,
    key_start: usize,
    key_len: usize,
) -> u64 {
    let end = key_start + key_len;
    let mut pairs = 0u64;
    for i in query_start..seq_len {
        pairs += mask.valid_keys(i, end).saturating_sub(key_start) as u64;
    }
    4 * pairs * d as u64
}

/// Masked attention FLOPs for one `(seq_len, d)` head: only the valid
/// `(query, key)` pairs count as useful work (score + PV, 2 FLOPs per
/// MAC each).  `None` recovers the paper's `4 L² d`; causal is
/// `4 d Σ(i+1) = 2 L (L+1) d` (≈half); key padding is `4 L·valid·d`
/// (every computed query row over the `valid` real keys — padded query
/// rows are the caller's to slice, so they still count as computed
/// work).
pub fn masked_attention_flops(seq_len: usize, d: usize, mask: MaskKind) -> u64 {
    match mask {
        MaskKind::None => attention_flops(seq_len, d),
        MaskKind::Causal => {
            let l = seq_len as u64;
            2 * l * (l + 1) * d as u64
        }
        MaskKind::PaddingKeys { valid } => {
            4 * seq_len as u64 * valid.min(seq_len) as u64 * d as u64
        }
    }
}

/// Sequence-parallel chunk grid (DESIGN.md §7): split `total` tokens
/// into `n` contiguous ranges `(start, len)`.  The chunk width is
/// `ceil(basis / n)` — `basis == total` for prefill/stateless even
/// splits; for decode, `basis` is the session's *prefill* length, so
/// the first `n − 1` chunk boundaries stay fixed across decode steps
/// (their devices' cached pages stay valid) and the final chunk absorbs
/// every appended token (last-chunk-grows).  Trailing chunks may be
/// empty (`len == 0`) when `total < n·width`; callers skip them.  The
/// grid is a pure function of `(total, basis, n)` — the foundation of
/// the placement-invariance bitwise contract.
pub fn chunk_ranges(total: usize, basis: usize, n: usize) -> Vec<(usize, usize)> {
    assert!(n >= 1, "need at least one chunk");
    if n == 1 {
        return vec![(0, total)];
    }
    let w = basis.div_ceil(n).max(1);
    (0..n)
        .map(|c| {
            let start = (c * w).min(total);
            let end = if c == n - 1 { total } else { ((c + 1) * w).min(total) };
            (start, end - start)
        })
        .collect()
}

/// The *live* (dispatchable) entries of a chunk grid: `(chunk, (start,
/// len))` for every [`chunk_ranges`] entry that has tokens and is not
/// fully masked for every one of the `rows` query rows
/// ([`TileCoverage::Empty`]) — a dead chunk's partial would be the
/// merge identity, so it is neither dispatched (coordinator) nor
/// priced (perfmodel); this single helper keeps the two in lockstep.
/// Pass [`MaskKind::None`] for decode steps (they carry no mask).  May
/// return an empty vec (a fully-masked operator); callers fall back to
/// one legacy whole-sequence shard.
pub fn live_chunk_ranges(
    rows: usize,
    total: usize,
    basis: usize,
    n: usize,
    mask: MaskKind,
) -> Vec<(usize, (usize, usize))> {
    chunk_ranges(total, basis, n)
        .into_iter()
        .enumerate()
        .filter(|&(_, (start, len))| {
            len > 0 && mask.coverage(0, rows.max(1), start, len) != TileCoverage::Empty
        })
        .collect()
}

/// FLOPs of one decode step per head: a single query row over an
/// `L`-token prefix — `2 L d` for the score row plus `2 L d` for PV.
/// Linear in the prefix, which is why decode is paced by the memory
/// system and not the array (§8.3, DESIGN.md §5).
pub fn decode_attention_flops(prefix_len: usize, d: usize) -> u64 {
    4 * (prefix_len as u64) * d as u64
}

/// End-to-end FSA cycle count for one attention head of `seq_len` with
/// head dim `d = N` (paper tiling Br = Bc = d = N), compute-bound path.
///
/// `t_r * (t_c * (5N+10) + (2N+20))` plus the first-iteration stationary
/// preload; DMA is double-buffered behind compute (checked by
/// [`crate::perfmodel`], which models bandwidth explicitly).
pub fn fsa_total_cycles(seq_len: usize, n: usize, variant: Variant, segments: usize) -> u64 {
    assert!(seq_len % n == 0, "seq_len must be a multiple of the array dim");
    let sched = InnerSchedule::new(n, variant, segments);
    let t = (seq_len / n) as u64;
    t * (t * sched.inner_latency() + rescale_latency(n)) + preload_latency(n)
}

/// Achieved-vs-peak FLOPs/s utilization for the closed-form FSA model.
pub fn fsa_utilization(seq_len: usize, n: usize, variant: Variant, segments: usize) -> f64 {
    let cycles = fsa_total_cycles(seq_len, n, variant, segments) as f64;
    let flops = attention_flops(seq_len, n) as f64;
    let peak_per_cycle = 2.0 * (n * n) as f64;
    flops / (cycles * peak_per_cycle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_formulas() {
        for n in [4usize, 8, 16, 32, 64, 128] {
            let dual = InnerSchedule::new(n, Variant::DualPath, 8);
            assert_eq!(dual.inner_latency(), 5 * n as u64 + 10, "N={n}");
            let single = InnerSchedule::new(n, Variant::SinglePath, 8);
            assert_eq!(single.inner_latency(), 6 * n as u64 + 10, "N={n}");
            assert_eq!(naive_two_matmul(n, n), 8 * n as u64 - 2, "N={n}");
            assert_eq!(rescale_latency(n), 2 * n as u64 + 20);
        }
    }

    #[test]
    fn wave_arithmetic_consistency() {
        // The closed-form latency must equal the last wave event derived
        // from the per-element schedule.
        for n in [4usize, 8, 16, 128] {
            for variant in [Variant::DualPath, Variant::SinglePath] {
                let s = InnerSchedule::new(n, variant, 8);
                assert_eq!(
                    s.last_cycle(),
                    s.inner_latency(),
                    "N={n} variant={variant:?}"
                );
            }
        }
    }

    #[test]
    fn dataflow_ordering_invariants() {
        // For every (m, n): parked before subtract; subtract before PWL;
        // PWL done before the rowsum wave; rowsum before PV psum.
        let s = InnerSchedule::new(16, Variant::DualPath, 8);
        for m in 0..16 {
            assert!(s.rowmax_done(m) < s.elementwise(0, 0, m));
            for n in 0..16 {
                assert!(s.s_parked(m, n) <= s.elementwise(0, n, m));
                assert!(s.elementwise(9, n, m) < s.rowsum_at(n, m));
                assert!(s.rowsum_at(n, m) < s.pv_at(n, m, 0));
            }
        }
    }

    #[test]
    fn s_parked_after_cmp_visit() {
        let s = InnerSchedule::new(8, Variant::DualPath, 8);
        for m in 0..8 {
            for n in 0..8 {
                assert!(s.s_parked(m, n) > s.s_at_cmp(m, n));
            }
        }
    }

    #[test]
    fn utilization_asymptote() {
        // Utilization ceiling is 2N / (5N + 10) -> 0.4 for large N & L.
        let u = fsa_utilization(128 * 128, 128, Variant::DualPath, 8);
        let ceiling = 2.0 * 128.0 / (5.0 * 128.0 + 10.0);
        assert!(u < ceiling);
        assert!(u > ceiling - 0.01, "u={u} ceiling={ceiling}");
        // Single path is strictly worse but still well above the naive
        // two-matmul bound of 8N-2 cycles for 4N^3 flops (= N/(4N-1)).
        let us = fsa_utilization(128 * 128, 128, Variant::SinglePath, 8);
        assert!(us < u);
        assert!(us > 128.0 / (4.0 * 128.0 - 1.0) * 0.9);
    }

    #[test]
    fn flops_formulas() {
        assert_eq!(inner_flops(128), 4 * 128u64.pow(3));
        assert_eq!(attention_flops(2048, 128), 4 * 2048 * 2048 * 128);
    }

    #[test]
    fn masked_flops_formulas() {
        assert_eq!(
            masked_attention_flops(2048, 128, MaskKind::None),
            attention_flops(2048, 128)
        );
        // Causal: sum over rows of 4·(i+1)·d = 2·L·(L+1)·d, just over
        // half of the square count.
        let causal = masked_attention_flops(2048, 128, MaskKind::Causal);
        assert_eq!(causal, 2 * 2048 * 2049 * 128);
        assert!(causal > attention_flops(2048, 128) / 2);
        assert!(causal < attention_flops(2048, 128) / 2 + 4 * 2048 * 128);
        // Padding: every computed row over the valid prefix, clamped.
        assert_eq!(
            masked_attention_flops(128, 16, MaskKind::PaddingKeys { valid: 100 }),
            4 * 128 * 100 * 16
        );
        assert_eq!(
            masked_attention_flops(128, 16, MaskKind::PaddingKeys { valid: 1000 }),
            attention_flops(128, 16)
        );
    }

    #[test]
    fn masked_tile_census() {
        // Square: every tile full.
        assert_eq!(masked_tile_counts(1024, 128, MaskKind::None), (64, 0, 0));
        // Causal at t=8: 28 lower-triangle full, 8 diagonal partial, 28
        // skipped — the ≈2x tile reduction the schedule banks on.
        let t = 8u64;
        assert_eq!(
            masked_tile_counts(1024, 128, MaskKind::Causal),
            (t * (t - 1) / 2, t, t * (t - 1) / 2)
        );
        // Padding at valid=300 over 512 (t=4): per row, 2 full + 1
        // boundary partial + 1 skipped column tiles.
        assert_eq!(
            masked_tile_counts(512, 128, MaskKind::PaddingKeys { valid: 300 }),
            (8, 4, 4)
        );
        // Ragged seq pads up to whole tiles.
        assert_eq!(masked_tile_counts(100, 128, MaskKind::Causal), (0, 1, 0));
        // The mask wave is one extra cycle in the elementwise window.
        let s = InnerSchedule::new(128, Variant::DualPath, 8);
        assert_eq!(s.masked_inner_latency(), s.inner_latency() + 1);
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn rejects_ragged_seq() {
        fsa_total_cycles(100, 128, Variant::DualPath, 8);
    }

    #[test]
    fn chunk_grid_partitions_and_grows_at_the_tail() {
        // Even split when basis == total.
        assert_eq!(chunk_ranges(1024, 1024, 4), vec![(0, 256), (256, 256), (512, 256), (768, 256)]);
        assert_eq!(chunk_ranges(1024, 1024, 1), vec![(0, 1024)]);
        // Decode: boundaries fixed at the prefill basis, the last chunk
        // absorbs appended tokens.
        assert_eq!(chunk_ranges(1030, 1024, 4), vec![(0, 256), (256, 256), (512, 256), (768, 262)]);
        // Ragged basis rounds the width up; trailing chunks may start
        // empty and fill in as the sequence grows.
        assert_eq!(chunk_ranges(5, 5, 4), vec![(0, 2), (2, 2), (4, 1), (5, 0)]);
        assert_eq!(chunk_ranges(6, 5, 4), vec![(0, 2), (2, 2), (4, 2), (6, 0)]);
        assert_eq!(chunk_ranges(7, 5, 4), vec![(0, 2), (2, 2), (4, 2), (6, 1)]);
        // Partition property: concatenated ranges tile [0, total)
        // exactly, for growing totals over a fixed basis.
        for total in [5usize, 9, 16, 40] {
            let mut expect = 0;
            for (start, len) in chunk_ranges(total, 9, 3) {
                assert_eq!(start, expect);
                expect += len;
            }
            assert_eq!(expect, total);
        }
        // Liveness: empty chunks drop, a padding mask's dead tail is
        // never live, and a fully-masked operator yields no live chunks
        // (callers fall back to one legacy shard).
        assert_eq!(
            live_chunk_ranges(5, 5, 5, 4, MaskKind::None),
            vec![(0, (0, 2)), (1, (2, 2)), (2, (4, 1))]
        );
        assert_eq!(
            live_chunk_ranges(64, 64, 64, 4, MaskKind::PaddingKeys { valid: 20 }),
            vec![(0, (0, 16)), (1, (16, 16))]
        );
        assert!(live_chunk_ranges(64, 64, 64, 4, MaskKind::PaddingKeys { valid: 0 }).is_empty());
        assert_eq!(
            live_chunk_ranges(64, 64, 64, 1, MaskKind::Causal),
            vec![(0, (0, 64))]
        );
    }

    #[test]
    fn range_tile_census_partitions_the_square() {
        // A tile-aligned partition of the key range sums to the whole
        // census for every mask kind.
        let (l, n) = (1024usize, 128usize);
        for mask in [
            MaskKind::None,
            MaskKind::Causal,
            MaskKind::PaddingKeys { valid: 300 },
        ] {
            let whole = masked_tile_counts(l, n, mask);
            let mut sum = (0u64, 0u64, 0u64);
            for c in 0..4 {
                let (f, p, s) = masked_tile_counts_range(l, n, mask, c * 256, 256);
                sum = (sum.0 + f, sum.1 + p, sum.2 + s);
            }
            assert_eq!(sum, whole, "{mask:?}");
        }
        // Whole-range call reproduces the square census directly.
        assert_eq!(
            masked_tile_counts_range(1024, 128, MaskKind::Causal, 0, 1024),
            masked_tile_counts(1024, 128, MaskKind::Causal)
        );
        // Ragged chunk boundaries: a 100-key chunk is one ragged column
        // tile per row block; a causal second chunk skips its upper
        // (row-tile-0) tile and runs its diagonal tile with the mask
        // wave.
        assert_eq!(
            masked_tile_counts_range(256, 128, MaskKind::None, 300, 100),
            (2, 0, 0)
        );
        assert_eq!(
            masked_tile_counts_range(256, 128, MaskKind::Causal, 128, 128),
            (0, 1, 1)
        );
    }

    #[test]
    fn range_flops_partition_the_whole_operator() {
        let (l, d) = (512usize, 64usize);
        for mask in [
            MaskKind::None,
            MaskKind::Causal,
            MaskKind::PaddingKeys { valid: 300 },
        ] {
            let whole = masked_attention_flops(l, d, mask);
            // Uneven partition (not tile aligned): still sums exactly.
            let ranges = [(0usize, 100usize), (100, 200), (300, 212)];
            let sum: u64 = ranges
                .iter()
                .map(|&(s, len)| masked_attention_flops_range(l, d, mask, s, len))
                .sum();
            assert_eq!(sum, whole, "{mask:?}");
        }
        // Causal chunk beyond the last row's prefix has zero useful work.
        assert_eq!(
            masked_attention_flops_range(128, 16, MaskKind::Causal, 128, 64),
            0
        );
    }

    #[test]
    fn resumed_census_matches_range_census_at_query_start_zero() {
        for mask in [
            MaskKind::None,
            MaskKind::Causal,
            MaskKind::PaddingKeys { valid: 300 },
        ] {
            assert_eq!(
                masked_tile_counts_resumed(1024, 128, mask, 0, 0, 1024),
                masked_tile_counts_range(1024, 128, mask, 0, 1024),
                "{mask:?}"
            );
            assert_eq!(
                masked_attention_flops_resumed(512, 64, mask, 0, 0, 512),
                masked_attention_flops_range(512, 64, mask, 0, 512),
                "{mask:?}"
            );
        }
    }

    #[test]
    fn resumed_census_prices_only_suffix_rows_at_global_coordinates() {
        // 1024 tokens, resume at 512: four suffix row tiles over eight
        // column tiles.  Unmasked: all full.  Causal: row tile at global
        // r0 = 512 + 128i has (4 + i) full tiles below the diagonal, one
        // diagonal partial, and skips the rest.
        assert_eq!(
            masked_tile_counts_resumed(1024, 128, MaskKind::None, 512, 0, 1024),
            (32, 0, 0)
        );
        let (full, partial, skipped) =
            masked_tile_counts_resumed(1024, 128, MaskKind::Causal, 512, 0, 1024);
        assert_eq!((full, partial, skipped), (4 + 5 + 6 + 7, 4, 3 + 2 + 1));
        // A tile-misaligned resume point still classifies at global rows:
        // resume 100 over 256 keys => row tiles start at row 100.
        let (f, p, s) = masked_tile_counts_resumed(256, 128, MaskKind::Causal, 100, 0, 256);
        assert_eq!(f + p + s, 4);
        assert!(p >= 1, "diagonal straddle must be partial");
        // FLOPs: the resumed suffix plus the covered-prefix complement
        // partition the whole operator, for every mask.
        for mask in [
            MaskKind::None,
            MaskKind::Causal,
            MaskKind::PaddingKeys { valid: 300 },
        ] {
            let whole = masked_attention_flops(512, 64, mask);
            let suffix = masked_attention_flops_resumed(512, 64, mask, 100, 0, 512);
            let prefix_rows: u64 = (0..100)
                .map(|i| 4 * mask.valid_keys(i, 512) as u64 * 64)
                .sum();
            assert_eq!(suffix + prefix_rows, whole, "{mask:?}");
        }
        // Resumed suffix FLOPs also partition across key chunks.
        let whole = masked_attention_flops_resumed(512, 64, MaskKind::Causal, 200, 0, 512);
        let a = masked_attention_flops_resumed(512, 64, MaskKind::Causal, 200, 0, 256);
        let b = masked_attention_flops_resumed(512, 64, MaskKind::Causal, 200, 256, 256);
        assert_eq!(a + b, whole);
    }
}
