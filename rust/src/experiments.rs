//! Experiment drivers: one function per paper table/figure, shared by the
//! `fsa` CLI and the `benches/` targets so both print identical reports.
//! EXPERIMENTS.md records their output against the paper's numbers.

use std::path::Path;

use crate::accel::{self, baseline};
use crate::area::AreaBreakdown;
use crate::benchutil::Table;
use crate::config::AccelConfig;
use crate::kernel::flash::detranspose_output;
use crate::kernel::{flash_attention_program, FlashLayout, FlashParams};
use crate::numerics::pwl::{error_sweep_ref, EvalMode};
use crate::numerics::reference::{mat_error, Mat, MatError};
use crate::numerics::SplitMix64;
use crate::perfmodel::fsa_flash_perf;
use crate::runtime::Runtime;
use crate::schedule::{fsa_total_cycles, naive_two_matmul, InnerSchedule, Variant};
use crate::sim::{Machine, MachineConfig};

/// Paper §6.2.2 input distribution, one (L, d) matrix.
pub fn paper_input(rng: &mut SplitMix64, l: usize, d: usize) -> Mat {
    Mat::new(l, d, rng.spiky_matrix(l, d))
}

// ---------------------------------------------------------------------
// Figure 1: component active time on NeuronCore-v2 running FlashAttention
// ---------------------------------------------------------------------

pub fn fig1_report(seq: usize) -> String {
    let mut t = Table::new(&["machine", "seq", "tensor%", "vector%", "scalar%", "dma%", "util%"]);
    for name in ["neuron-v2", "tpuv5e"] {
        let cfg = AccelConfig::builtin(name).unwrap();
        let p = baseline::baseline_flash_perf(&cfg, seq, 128);
        t.row(&[
            name.into(),
            seq.to_string(),
            format!("{:.1}", 100.0 * p.tensor_active),
            format!("{:.1}", 100.0 * p.vector_active),
            format!("{:.1}", 100.0 * p.scalar_active),
            format!("{:.1}", 100.0 * p.dma_active),
            format!("{:.1}", 100.0 * p.utilization),
        ]);
    }
    // FSA for contrast: array active ~100%, no vector/scalar unit at all.
    let cfg = AccelConfig::builtin("fsa").unwrap();
    let p = fsa_flash_perf(&cfg, seq, 128, Variant::DualPath, 8);
    t.row(&[
        "fsa".into(),
        seq.to_string(),
        format!("{:.1}", 100.0 * p.array_active_cycles as f64 / p.total_cycles as f64),
        "-".into(),
        "-".into(),
        format!("{:.1}", 100.0 * p.dma_cycles as f64 / p.total_cycles as f64),
        format!("{:.1}", 100.0 * p.utilization),
    ]);
    format!(
        "Figure 1 — active time per component (paper: Neuron tensor ~45%, scalar ~80%)\n{}",
        t.to_string()
    )
}

// ---------------------------------------------------------------------
// Figure 11: FLOPs/s utilization, FSA vs TPUv5e vs Neuron-v2
// ---------------------------------------------------------------------

pub fn fig11_report(seq_lens: &[usize], d: usize) -> String {
    let fsa = accel::utilization_curve("fsa", seq_lens, d).unwrap();
    let tpu = accel::utilization_curve("tpuv5e", seq_lens, d).unwrap();
    let neuron = accel::utilization_curve("neuron-v2", seq_lens, d).unwrap();
    let mut t = Table::new(&["seq", "FSA%", "TPUv5e%", "Neuron-v2%", "FSA/TPU", "FSA/Neuron"]);
    for i in 0..seq_lens.len() {
        t.row(&[
            seq_lens[i].to_string(),
            format!("{:.1}", 100.0 * fsa[i].utilization),
            format!("{:.1}", 100.0 * tpu[i].utilization),
            format!("{:.1}", 100.0 * neuron[i].utilization),
            format!("{:.2}", fsa[i].utilization / tpu[i].utilization),
            format!("{:.2}", fsa[i].utilization / neuron[i].utilization),
        ]);
    }
    format!(
        "Figure 11 — FlashAttention FLOPs/s utilization (paper avg: 1.77x TPUv5e, 4.83x Neuron)\n{}\
         mean FSA/TPUv5e = {:.2}   mean FSA/Neuron-v2 = {:.2}\n",
        t.to_string(),
        accel::mean_ratio(&fsa, &tpu),
        accel::mean_ratio(&fsa, &neuron),
    )
}

// ---------------------------------------------------------------------
// Figure 12: exp2 PWL error vs segment count
// ---------------------------------------------------------------------

pub fn fig12_report(segments: &[usize]) -> String {
    let mut t = Table::new(&["segments", "MAE", "MRE", "MAE(f64 ref)", "MRE(f64 ref)"]);
    for &s in segments {
        // Paper mode: fp16 PWL with flush-to-zero vs fp16-rounded exp2
        // reference (reproduces MAE 0.00014 / MRE 0.02728 at 8 segments).
        let paper = error_sweep_ref(s, EvalMode::F16, true);
        let ideal = error_sweep_ref(s, EvalMode::Exact, false);
        t.row(&[
            s.to_string(),
            format!("{:.5e}", paper.mae),
            format!("{:.5}", paper.mre),
            format!("{:.5e}", ideal.mae),
            format!("{:.5e}", ideal.mre),
        ]);
    }
    format!(
        "Figure 12 — exp2 PWL error over all negative normal fp16 \
         (paper @8: MAE 0.00014, MRE 0.02728)\n{}",
        t.to_string()
    )
}

// ---------------------------------------------------------------------
// Table 2: end-to-end FlashAttention accuracy on FSA numerics
// ---------------------------------------------------------------------

/// One Table-2 row via the PJRT artifacts (fsa_attn vs dense SDPA when
/// available, else the exact-exp2 flash twin).
pub fn table2_row(rt: &mut Runtime, seq: usize, d: usize, seed: u64) -> crate::Result<(MatError, &'static str)> {
    let mut rng = SplitMix64::new(seed);
    let q = paper_input(&mut rng, seq, d);
    let k = paper_input(&mut rng, seq, d);
    let v = paper_input(&mut rng, seq, d);

    let fsa_name = rt
        .manifest
        .best_for("fsa_attn", seq, d)
        .filter(|m| m.seq_len == seq)
        .ok_or_else(|| anyhow::anyhow!("no fsa_attn artifact for seq {seq}"))?
        .name
        .clone();
    let got = rt.execute_attention(&fsa_name, &q.data, &k.data, &v.data)?;

    let (ref_kind, want) = match rt
        .manifest
        .best_for("sdpa", seq, d)
        .filter(|m| m.seq_len == seq)
        .map(|m| m.name.clone())
    {
        Some(name) => ("sdpa", rt.execute_attention(&name, &q.data, &k.data, &v.data)?),
        None => {
            let name = rt
                .manifest
                .best_for("flash_exact", seq, d)
                .filter(|m| m.seq_len == seq)
                .ok_or_else(|| anyhow::anyhow!("no reference artifact for seq {seq}"))?
                .name
                .clone();
            ("flash_exact", rt.execute_attention(&name, &q.data, &k.data, &v.data)?)
        }
    };
    Ok((
        mat_error(&Mat::new(seq, d, got), &Mat::new(seq, d, want)),
        ref_kind,
    ))
}

pub fn table2_report(artifacts: &Path, seqs: &[usize], d: usize, seed: u64) -> crate::Result<String> {
    let mut rt = Runtime::new(artifacts)?;
    let mut t = Table::new(&["SeqLen", "MAE", "RMSE", "MRE", "reference"]);
    for &seq in seqs {
        match table2_row(&mut rt, seq, d, seed ^ seq as u64) {
            Ok((e, kind)) => t.row(&[
                seq.to_string(),
                format!("{:.3e}", e.mae),
                format!("{:.3e}", e.rmse),
                format!("{:.3e}", e.mre),
                kind.into(),
            ]),
            Err(err) => t.row(&[
                seq.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("unavailable: {err}"),
            ]),
        }
    }
    Ok(format!(
        "Table 2 — FlashAttention accuracy on FSA vs exact reference \
         (paper @2048: MAE 7.98e-3, RMSE 1.32e-2, MRE 1.56e-2)\n{}",
        t.to_string()
    ))
}

// ---------------------------------------------------------------------
// Table 3: area breakdown
// ---------------------------------------------------------------------

pub fn table3_report(n: usize) -> String {
    let a = AreaBreakdown::for_array(n);
    format!(
        "Table 3 — FSA area breakdown at {n}x{n} (paper: +12.07% overhead)\n{}\
         overhead = {:.2}%\n",
        a.to_table(),
        100.0 * a.overhead_fraction()
    )
}

// ---------------------------------------------------------------------
// §3.5 / §8.2 cycle validation: cycle-accurate sim vs closed form
// ---------------------------------------------------------------------

pub fn cycles_report(sizes: &[usize]) -> String {
    let mut t = Table::new(&[
        "N", "formula 5N+10", "sim cycles (2x2 tiles)", "formula total", "naive 8N-2",
        "single-path 6N+10",
    ]);
    for &n in sizes {
        let p = FlashParams {
            seq_len: 2 * n,
            d: n,
            spad_elems: (6 * n * n) as u32,
            accum_elems: (n * n + n) as u32,
        };
        let layout = FlashLayout::packed(&p);
        let prog = flash_attention_program(&p, &layout).unwrap();
        let mut cfg = MachineConfig::small(n);
        cfg.mem_elems = layout.mem_elems(&p).max(1 << 16);
        let mut m = Machine::new(cfg);
        let mut rng = SplitMix64::new(n as u64);
        let data = rng.normal_matrix(2 * n, n);
        m.write_mem(layout.q_addr, &data);
        m.write_mem(layout.k_addr, &data);
        m.write_mem(layout.v_addr, &data);
        let stats = m.run_program(&prog).unwrap();
        let sched = InnerSchedule::new(n, Variant::DualPath, 8);
        let single = InnerSchedule::new(n, Variant::SinglePath, 8);
        t.row(&[
            n.to_string(),
            sched.inner_latency().to_string(),
            stats.cycles.to_string(),
            fsa_total_cycles(2 * n, n, Variant::DualPath, 8).to_string(),
            naive_two_matmul(n, n).to_string(),
            single.inner_latency().to_string(),
        ]);
    }
    format!(
        "Cycle validation — simulator vs §3.5 closed forms (inner loop 5N+10; \
         naive two-matmul 8N-2; §8.2 variant 6N+10)\n{}",
        t.to_string()
    )
}

// ---------------------------------------------------------------------
// Table 2 cross-check at small scale through the cycle-accurate machine
// ---------------------------------------------------------------------

/// Accuracy of the *cycle simulator* vs dense SDPA — closes the loop
/// device-sim <-> kernel <-> oracle at sizes the sim can chew.
pub fn sim_accuracy_row(n: usize, seq: usize, seed: u64) -> crate::Result<MatError> {
    let p = FlashParams {
        seq_len: seq,
        d: n,
        spad_elems: (6 * n * n) as u32,
        accum_elems: (n * n + n) as u32,
    };
    let layout = FlashLayout::packed(&p);
    let prog = flash_attention_program(&p, &layout)?;
    let mut cfg = MachineConfig::small(n);
    cfg.mem_elems = layout.mem_elems(&p).max(1 << 16);
    let mut m = Machine::new(cfg);
    let mut rng = SplitMix64::new(seed);
    let q = paper_input(&mut rng, seq, n);
    let k = paper_input(&mut rng, seq, n);
    let v = paper_input(&mut rng, seq, n);
    m.write_mem(layout.q_addr, &q.data);
    m.write_mem(layout.k_addr, &k.data);
    m.write_mem(layout.v_addr, &v.data);
    m.run_program(&prog)?;
    let out = detranspose_output(m.read_mem(0, layout.mem_elems(&p)), &layout, &p);
    let dense = crate::numerics::reference::sdpa(&q, &k, &v);
    Ok(mat_error(&Mat::new(seq, n, out), &dense))
}

pub fn table1_report() -> String {
    let mut t = Table::new(&[
        "Accelerator", "array", "#arrays", "TFLOPs/s", "freq GHz", "BW GB/s", "spad",
        "accum", "vector unit?",
    ]);
    for name in ["tpuv5e", "neuron-v2", "fsa"] {
        let c = AccelConfig::builtin(name).unwrap();
        t.row(&[
            c.name.clone(),
            format!("{0}x{0}", c.array_size),
            c.num_arrays.to_string(),
            format!("{:.2}", c.peak_tflops()),
            format!("{:.1}", c.freq_ghz),
            format!("{:.0}", c.mem_bw_gbs),
            format!("{}KiB", c.spad_bytes / 1024),
            format!("{}KiB", c.accum_bytes / 1024),
            if c.vector_unit.is_some() { "yes" } else { "no" }.into(),
        ]);
    }
    format!("Table 1 — accelerator configurations\n{}", t.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_render() {
        assert!(fig1_report(4096).contains("neuron-v2"));
        assert!(fig11_report(&[2048, 4096], 128).contains("FSA/Neuron"));
        assert!(fig12_report(&[2, 8]).contains("segments"));
        assert!(table3_report(128).contains("12.07"));
        assert!(table1_report().contains("tpuv5e"));
    }

    #[test]
    fn sim_accuracy_in_paper_error_band() {
        let e = sim_accuracy_row(16, 32, 5).unwrap();
        assert!(e.mae < 2e-2, "{e:?}");
    }
}
