//! The cycle-accurate serving backend (`backend=sim`, DESIGN.md §8):
//! executes attention shards by compiling an ISA program
//! ([`crate::kernel::flash`]'s chunk / decode-row / partial variants)
//! and running it on the [`crate::sim::Machine`] — the same dataflow
//! model that validates the paper's §3.5 schedule, now on the request
//! path.
//!
//! Two contracts distinguish it from the analytic path:
//!
//! * **Bitwise numerics.**  Outputs are bitwise-equal to the reference
//!   twin (`flash_pwl_masked` tiled at the array size): both sides share
//!   the PWL exp2, the fp16 quantization points and the accumulation
//!   orders, and the §8 mask wave makes partially-masked tiles and
//!   zero-padded ragged tails exact.  Pinned by `rust/tests/sim_backend.rs`
//!   and end-to-end by `rust/tests/coordinator_sim.rs`, and machine-
//!   verified by the float32 port in
//!   `python/tests/test_sim_backend_bitwise.py`.
//! * **Measured cycles.**  Every execution returns the machine's
//!   [`RunStats::cycles`]; device workers price shards with the
//!   *measured* number instead of the perfmodel's prediction
//!   ([`SimBackend::take_measured`]), and the perfmodel cross-validates
//!   against it (`perfmodel::sim_cross_check`) so the analytic model
//!   can never silently drift from the machine it claims to describe.
//!
//! Shapes: the head dim rides zero-padded to the array size (`d <= N`;
//! the softmax scale stays `log2(e)/sqrt(d)` via
//! [`MachineConfig::scale_dim`]), and any sequence length tiles with the
//! mask wave covering the padded tail.  Cost is the real reason for the
//! `sim_max_seq` admission guard: a program is O(L²/N²) tiles of
//! ~`5N+10` cycles, each cycle stepping N² PEs — O(L²·N) PE-steps per
//! head shard.

use std::sync::Arc;

use crate::config::AccelConfig;
use crate::isa::Program;
use crate::kernel::flash::{
    flash_chunk_partial_program, flash_chunk_program, ChunkLayout, ChunkParams,
};
use crate::mask::MaskKind;
use crate::numerics::reference::FlashPartial;
use crate::runtime::prog_cache::{ProgKey, ProgramCache};
use crate::runtime::{ShardOutput, ShardPlan};
use crate::sim::{CycleBreakdown, Machine, MachineConfig, RunStats};

/// Default shards per machine between hazard fences
/// ([`crate::config::RunConfig::sim_batch_shards`]'s default; any value
/// `> 1` now means "pool indefinitely" — see [`SimBackend::machine_for`]).
pub const DEFAULT_BATCH_SHARDS: usize = 8;

/// Default [`crate::config::RunConfig::sim_prog_cache`] entries.
pub const DEFAULT_PROG_CACHE: usize = 256;

/// Host-path counters of one backend since the last
/// [`SimBackend::take_hotpath_stats`] — the worker drains them into
/// [`crate::coordinator::metrics::Metrics`] after each batch.  None of
/// these
/// affect served bits or measured cycles; they only observe host work
/// avoided (or paid) on the dispatch path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HotpathStats {
    /// Program lookups served from the compiled-program cache.
    pub prog_cache_hits: u64,
    /// Program lookups that ran the ISA builder.  With the cache
    /// disabled every build lands here too, so in *both* modes
    /// `prog_cache_misses` == programs actually built.
    pub prog_cache_misses: u64,
    /// Fresh [`Machine`] allocations (first shard, `sim_batch_shards=1`
    /// reuse-off mode, or a grow-on-demand replacement).
    pub machines_allocated: u64,
}

/// One simulated FSA card behind a device worker.
pub struct SimBackend {
    /// Machine template: array dim, PWL segments, DMA bandwidth.
    cfg: MachineConfig,
    /// Measured cycles of the most recent execution (consumed by the
    /// worker for pricing; [`SimBackend::take_measured`]).
    measured: Option<u64>,
    /// Per-instruction-class attribution of `measured` (DESIGN.md §9);
    /// same lifecycle, consumed by
    /// [`SimBackend::take_measured_breakdown`].  Its `total()` always
    /// equals the `measured` cycles it rides with.
    measured_bd: Option<CycleBreakdown>,
    /// Persistent machine pool (DESIGN.md §8/§12): independent shards
    /// share one machine indefinitely, separated by
    /// [`Machine::reset_for_reuse`] hazard fences — every program ends
    /// array-quiescent and the fence zeroes all memories, registers and
    /// the DMA scoreboard, so a reused run is bitwise and
    /// cycle-for-cycle a fresh one, minus the ~3 large allocations per
    /// shard.  Replaced only when a shard's capacity needs exceed the
    /// resident machine ([`SimBackend::machine_for`]'s grow-on-demand).
    cached: Option<Machine>,
    /// Shards served by the cached machine since it was built
    /// (informational; reuse is no longer capped).
    cached_uses: usize,
    batch_shards: usize,
    /// Compiled-program LRU (DESIGN.md §12); `None` when
    /// `sim_prog_cache = 0` disables caching.
    prog_cache: Option<ProgramCache>,
    /// Host-path counters since the last [`SimBackend::take_hotpath_stats`].
    hotpath: HotpathStats,
}

impl SimBackend {
    pub fn new(accel: &AccelConfig) -> SimBackend {
        SimBackend {
            cfg: MachineConfig::from_accel(accel),
            measured: None,
            measured_bd: None,
            cached: None,
            cached_uses: 0,
            batch_shards: DEFAULT_BATCH_SHARDS,
            prog_cache: Some(ProgramCache::new(DEFAULT_PROG_CACHE)),
            hotpath: HotpathStats::default(),
        }
    }

    pub fn array_size(&self) -> usize {
        self.cfg.n
    }

    /// The measured device cycles of the last `execute_*` call, if it
    /// ran (cleared by the take).  Workers call this right after an
    /// execution to replace the modeled latency with the measured one.
    pub fn take_measured(&mut self) -> Option<u64> {
        self.measured.take()
    }

    /// The per-instruction-class cycle attribution of the last
    /// `execute_*` call (cleared by the take).  Always paired with
    /// [`SimBackend::take_measured`]: its `total()` equals the measured
    /// cycles of the same execution.
    pub fn take_measured_breakdown(&mut self) -> Option<CycleBreakdown> {
        self.measured_bd.take()
    }

    /// Set the machine-pooling mode (the `sim_batch_shards` knob):
    /// 1 disables reuse so every shard gets a freshly allocated machine
    /// (the cycle-equality oracle's fresh-machine twin); any value `> 1`
    /// keeps the machine across hazard fences indefinitely.
    pub fn set_batch_shards(&mut self, shards: usize) {
        self.batch_shards = shards.max(1);
        if self.batch_shards == 1 {
            self.cached = None;
        }
        self.cached_uses = 0;
    }

    /// Size (entries) of the compiled-program cache (the
    /// `sim_prog_cache` knob; 0 disables caching so every shard
    /// rebuilds its program — the recompilation twin).  Resizing starts
    /// an empty cache; hit/miss counters live in [`HotpathStats`].
    pub fn set_prog_cache(&mut self, entries: usize) {
        self.prog_cache = if entries == 0 { None } else { Some(ProgramCache::new(entries)) };
    }

    /// Drain the host-path counters accumulated since the last take
    /// (the worker calls this after each batch).
    pub fn take_hotpath_stats(&mut self) -> HotpathStats {
        std::mem::take(&mut self.hotpath)
    }

    /// Peek at the host-path counters without draining them.
    pub fn hotpath_stats(&self) -> HotpathStats {
        self.hotpath
    }

    /// Route array stepping through the frozen pre-refactor scalar path
    /// ([`crate::sim::MachineConfig::scalar_reference`]) — the
    /// differential harness and the old-vs-new bench sweep use this; it
    /// must never change outputs or measured cycles.
    pub fn set_scalar_reference(&mut self, on: bool) {
        self.cfg.scalar_reference = on;
        self.cached = None;
        self.cached_uses = 0;
    }

    /// The single typed entry point — [`crate::runtime::Backend::execute`]'s
    /// sim twin for callers holding a bare `SimBackend` (the differential
    /// harness and the cycle benches drive both steppers through it).
    pub fn execute(&mut self, plan: ShardPlan<'_>) -> Result<ShardOutput, String> {
        plan.validate()?;
        match plan {
            ShardPlan::Head { seq_len, d, q, k, v, mask } => {
                self.run_head(seq_len, d, q, k, v, mask).map(ShardOutput::Full)
            }
            ShardPlan::HeadChunk {
                seq_len,
                d,
                q,
                k_chunk,
                v_chunk,
                mask,
                key_offset,
                total_keys,
            } => self
                .run_head_chunk(seq_len, d, q, k_chunk, v_chunk, mask, key_offset, total_keys)
                .map(ShardOutput::Partial),
            ShardPlan::ResumedPrefill {
                seq_len,
                d,
                query_offset,
                q_suffix,
                k_chunk,
                v_chunk,
                mask,
                key_offset,
                total_keys,
            } => self.run_resumed(
                seq_len,
                d,
                query_offset,
                q_suffix,
                k_chunk,
                v_chunk,
                mask,
                key_offset,
                total_keys,
            ),
            ShardPlan::DecodeRow { prefix_len, d, q_row, k, v } => {
                self.run_decode_row(prefix_len, d, q_row, k, v).map(ShardOutput::Full)
            }
            ShardPlan::DecodeRange { range_len, d, q_row, k, v } => {
                self.run_decode_range(range_len, d, q_row, k, v).map(ShardOutput::Partial)
            }
        }
    }

    /// A machine for one shard: workload-sized memory, the shard's real
    /// head dim as the softmax-scale dim.  With pooling on
    /// (`batch_shards > 1`) the resident machine is reused across a
    /// hazard fence whenever its capacities cover the shard (zeroed
    /// surplus memory behaves exactly like a tighter fit — capacities
    /// appear only in bound checks, never in timing); a too-small
    /// resident triggers an explicit GROW: the replacement is sized to
    /// the max of the shard's needs and the resident's capacities, so
    /// the pool converges on a machine that covers every shape this
    /// backend has seen and stops reallocating.
    fn machine_for(&mut self, p: &ChunkParams, layout: &ChunkLayout, d: usize) -> Machine {
        let mut cfg = self.cfg.clone();
        cfg.scale_dim = d;
        cfg.spad_elems = cfg.spad_elems.max(p.spad_elems as usize);
        cfg.accum_elems = cfg.accum_elems.max(p.accum_elems as usize);
        cfg.mem_elems = layout.mem_elems(p).max(1 << 12);
        if self.batch_shards > 1 {
            if let Some(mut m) = self.cached.take() {
                if m.cfg.mem_elems >= cfg.mem_elems
                    && m.cfg.spad_elems >= cfg.spad_elems
                    && m.cfg.accum_elems >= cfg.accum_elems
                {
                    m.reset_for_reuse(d);
                    self.cached_uses += 1;
                    return m;
                }
                // GROW: carry the resident's capacities into the
                // replacement instead of silently dropping them.
                cfg.mem_elems = cfg.mem_elems.max(m.cfg.mem_elems);
                cfg.spad_elems = cfg.spad_elems.max(m.cfg.spad_elems);
                cfg.accum_elems = cfg.accum_elems.max(m.cfg.accum_elems);
            }
        }
        self.cached_uses = 1;
        self.hotpath.machines_allocated += 1;
        Machine::new(cfg)
    }

    /// Return a machine to the cache after its shard completed (its
    /// program left the array quiescent; the next [`Self::machine_for`]
    /// re-fences it).  Machines whose run errored are dropped instead —
    /// they never reach this call.
    fn retire(&mut self, m: Machine) {
        if self.batch_shards > 1 {
            self.cached = Some(m);
        }
    }

    /// Build (or fetch) the program for `(p, layout)` — the normalized
    /// whole-chunk program when `blk` is `None`, the per-row-block
    /// partial program otherwise (`Ok(None)` = the block is fully
    /// masked).  All six dispatch-path build sites funnel through here
    /// so the cache sees every shape and the hit/miss counters mean the
    /// same thing on every path.
    fn build_program(
        &mut self,
        p: &ChunkParams,
        layout: &ChunkLayout,
        blk: Option<usize>,
    ) -> Result<Option<Arc<Program>>, String> {
        let build = || -> Result<Option<Program>, String> {
            match blk {
                None => flash_chunk_program(p, layout).map(Some),
                Some(b) => flash_chunk_partial_program(p, layout, b),
            }
            .map_err(|e| format!("sim backend: {e:#}"))
        };
        let (prog, hit) = match &mut self.prog_cache {
            Some(c) => {
                let h0 = c.hits;
                let got = c.get_or_build(ProgKey::new(p, layout, blk), build)?;
                (got, c.hits > h0)
            }
            None => (build()?.map(Arc::new), false),
        };
        if hit {
            self.hotpath.prog_cache_hits += 1;
        } else {
            self.hotpath.prog_cache_misses += 1;
        }
        Ok(prog)
    }

    /// The normalized chunk program (head / whole-range resumed /
    /// decode-row paths), cached.
    fn chunk_program(
        &mut self,
        p: &ChunkParams,
        layout: &ChunkLayout,
    ) -> Result<Arc<Program>, String> {
        Ok(self
            .build_program(p, layout, None)?
            .expect("a normalized chunk program always exists"))
    }

    /// One row block's partial program (chunk / sub-range resumed /
    /// decode-range paths), cached; `None` = fully-masked block.
    fn chunk_partial_program(
        &mut self,
        p: &ChunkParams,
        layout: &ChunkLayout,
        blk: usize,
    ) -> Result<Option<Arc<Program>>, String> {
        self.build_program(p, layout, Some(blk))
    }

    /// Write a `(rows, d)` row-major host matrix into device memory as
    /// the zero-padded `(padded_rows, n)` layout the programs expect
    /// (device memory is zero-initialized, so only real data moves).
    fn write_padded(m: &mut Machine, addr: u32, data: &[f32], rows: usize, d: usize) {
        let n = m.cfg.n;
        for r in 0..rows {
            m.write_mem(addr + (r * n) as u32, &data[r * d..(r + 1) * d]);
        }
    }

    /// Read the de-transposed `(valid_queries, d)` output of a
    /// normalized chunk program.  Each O^T block is read as one
    /// borrowed slice (no per-element `read_mem` calls); the returned
    /// `Vec` is the single allocation left on this path — it escapes
    /// into [`ShardOutput::Full`] and must be owned.
    fn read_output(m: &Machine, p: &ChunkParams, layout: &ChunkLayout, d: usize) -> Vec<f32> {
        let n = p.n;
        let mut out = vec![0.0f32; p.valid_queries * d];
        for blk in 0..p.row_blocks() {
            let base = layout.o_addr as usize + blk * n * n;
            let block = m.read_mem(base as u32, n * n);
            for mcol in 0..n {
                let row = blk * n + mcol;
                if row >= p.valid_queries {
                    break;
                }
                for h in 0..d {
                    out[row * d + h] = block[h * n + mcol];
                }
            }
        }
        out
    }

    fn run(&mut self, m: &mut Machine, prog: &crate::isa::Program) -> Result<RunStats, String> {
        m.run_program(prog).map_err(|e| format!("sim backend: {e:#}"))
    }

    /// One whole head: `(seq_len, d)` Q/K/V, masked exactly.  Returns
    /// the output and records measured cycles.  (Dispatched from
    /// [`crate::runtime::Backend::execute`] — the `ShardPlan::Head`
    /// arm; the old public four-method surface is gone.)
    pub(crate) fn run_head(
        &mut self,
        seq_len: usize,
        d: usize,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        mask: MaskKind,
    ) -> Result<Vec<f32>, String> {
        self.measured = None;
        self.measured_bd = None;
        self.check_dims(seq_len, d)?;
        if q.len() != seq_len * d || k.len() != seq_len * d || v.len() != k.len() {
            return Err(format!(
                "sim backend: shape mismatch q {} k {} v {} for ({seq_len}, {d})",
                q.len(),
                k.len(),
                v.len()
            ));
        }
        // A fully-masked operator has no live tile in any row block:
        // the defined output is all-zero without running the array
        // (the same rule as `FlashPartial::finalize`).
        if (0..seq_len).all(|i| mask.valid_keys(i, seq_len) == 0) {
            self.measured = Some(0);
            self.measured_bd = Some(CycleBreakdown::default());
            return Ok(vec![0.0; seq_len * d]);
        }
        let p = ChunkParams::whole(self.cfg.n, seq_len, mask);
        let layout = ChunkLayout::packed(&p);
        let prog = self.chunk_program(&p, &layout)?;
        let mut m = self.machine_for(&p, &layout, d);
        Self::write_padded(&mut m, layout.q_addr, q, seq_len, d);
        Self::write_padded(&mut m, layout.k_addr, k, seq_len, d);
        Self::write_padded(&mut m, layout.v_addr, v, seq_len, d);
        let stats = self.run(&mut m, &prog)?;
        self.measured = Some(stats.cycles);
        self.measured_bd = Some(stats.breakdown);
        let out = Self::read_output(&m, &p, &layout, d);
        self.retire(m);
        Ok(out)
    }

    /// One sequence-parallel chunk of one head (DESIGN.md §7 shapes on
    /// the §8 programs): per-row-block partial programs — the CMP row
    /// holds one block's running max at a time, so the backend runs a
    /// block, reads `(O~, l)` from memory and `m` from the CMP
    /// registers, then moves on.  Measured cycles sum the block runs.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_head_chunk(
        &mut self,
        seq_len: usize,
        d: usize,
        q: &[f32],
        k_chunk: &[f32],
        v_chunk: &[f32],
        mask: MaskKind,
        key_offset: usize,
        total_keys: usize,
    ) -> Result<FlashPartial, String> {
        self.measured = None;
        self.measured_bd = None;
        self.check_dims(seq_len, d)?;
        if k_chunk.len() % d != 0 || k_chunk.len() != v_chunk.len() || q.len() != seq_len * d {
            return Err(format!(
                "sim backend: partial shape mismatch q {} k {} v {} for ({seq_len}, {d})",
                q.len(),
                k_chunk.len(),
                v_chunk.len()
            ));
        }
        let chunk_len = k_chunk.len() / d;
        if chunk_len == 0 || key_offset + chunk_len > total_keys {
            return Err(format!(
                "sim backend: chunk [{key_offset}, {}) outside the {total_keys}-key sequence",
                key_offset + chunk_len
            ));
        }
        let n = self.cfg.n;
        let p = ChunkParams::chunk(n, seq_len, mask, key_offset, chunk_len, total_keys);
        let layout = ChunkLayout::packed(&p);
        let mut m = self.machine_for(&p, &layout, d);
        Self::write_padded(&mut m, layout.q_addr, q, seq_len, d);
        Self::write_padded(&mut m, layout.k_addr, k_chunk, chunk_len, d);
        Self::write_padded(&mut m, layout.v_addr, v_chunk, chunk_len, d);

        let mut part = FlashPartial::empty(seq_len, d);
        let mut cycles = 0u64;
        let mut bd = CycleBreakdown::default();
        for blk in 0..p.row_blocks() {
            let prog = match self.chunk_partial_program(&p, &layout, blk)? {
                // Block fully masked in this chunk: its rows keep the
                // empty (0, -inf, 0) state — the merge identity.
                None => continue,
                Some(prog) => prog,
            };
            let stats = self.run(&mut m, &prog)?;
            cycles += stats.cycles;
            bd.add(&stats.breakdown);
            let o_base = layout.o_addr as usize + blk * n * n;
            let l_base = layout.l_addr as usize + blk * n;
            for mcol in 0..n {
                let row = blk * n + mcol;
                if row >= seq_len {
                    break;
                }
                part.m[row] = m.array.cmp_new_m(mcol);
                part.l[row] = m.read_mem((l_base + mcol) as u32, 1)[0];
                for h in 0..d {
                    part.acc[row * d + h] = m.read_mem((o_base + h * n + mcol) as u32, 1)[0];
                }
            }
        }
        self.measured = Some(cycles);
        self.measured_bd = Some(bd);
        self.retire(m);
        Ok(part)
    }

    /// One resumed (prefix-cache warm) prefill chunk (DESIGN.md §11):
    /// only the suffix query rows ride in the Q buffer, and the §8 mask
    /// wave is programmed at *global* query coordinates
    /// ([`ChunkParams::resumed`]), so every suffix row runs the exact
    /// tile sequence the cold run gave it.  A whole-range chunk runs
    /// the normalized program and returns the suffix rows; a sub-range
    /// runs per-row-block partial programs like
    /// [`SimBackend::run_head_chunk`].
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_resumed(
        &mut self,
        seq_len: usize,
        d: usize,
        query_offset: usize,
        q_suffix: &[f32],
        k_chunk: &[f32],
        v_chunk: &[f32],
        mask: MaskKind,
        key_offset: usize,
        total_keys: usize,
    ) -> Result<ShardOutput, String> {
        self.measured = None;
        self.measured_bd = None;
        self.check_dims(seq_len, d)?;
        if query_offset >= seq_len {
            return Err(format!(
                "sim backend: resume point {query_offset} leaves no suffix rows of {seq_len}"
            ));
        }
        let rows = seq_len - query_offset;
        if q_suffix.len() != rows * d || k_chunk.len() % d != 0 || k_chunk.len() != v_chunk.len() {
            return Err(format!(
                "sim backend: resumed shape mismatch q {} k {} v {} for ({seq_len}, {d}) \
                 resume {query_offset}",
                q_suffix.len(),
                k_chunk.len(),
                v_chunk.len()
            ));
        }
        let chunk_len = k_chunk.len() / d;
        if chunk_len == 0 || key_offset + chunk_len > total_keys {
            return Err(format!(
                "sim backend: chunk [{key_offset}, {}) outside the {total_keys}-key sequence",
                key_offset + chunk_len
            ));
        }
        let n = self.cfg.n;
        let p = ChunkParams::resumed(n, seq_len, mask, query_offset, key_offset, chunk_len, total_keys);
        let layout = ChunkLayout::packed(&p);
        if key_offset == 0 && chunk_len == total_keys {
            // Whole key range: normalized program over the suffix row
            // blocks — the warm mirror of the cold whole-head path.
            if (query_offset..seq_len).all(|i| mask.valid_keys(i, total_keys) == 0) {
                self.measured = Some(0);
                self.measured_bd = Some(CycleBreakdown::default());
                return Ok(ShardOutput::Full(vec![0.0; rows * d]));
            }
            let prog = self.chunk_program(&p, &layout)?;
            let mut m = self.machine_for(&p, &layout, d);
            Self::write_padded(&mut m, layout.q_addr, q_suffix, rows, d);
            Self::write_padded(&mut m, layout.k_addr, k_chunk, chunk_len, d);
            Self::write_padded(&mut m, layout.v_addr, v_chunk, chunk_len, d);
            let stats = self.run(&mut m, &prog)?;
            self.measured = Some(stats.cycles);
            self.measured_bd = Some(stats.breakdown);
            let out = Self::read_output(&m, &p, &layout, d);
            self.retire(m);
            return Ok(ShardOutput::Full(out));
        }
        // Sub-range chunk: per-row-block partial programs, exactly the
        // cold chunk path restricted to the suffix rows.
        let mut m = self.machine_for(&p, &layout, d);
        Self::write_padded(&mut m, layout.q_addr, q_suffix, rows, d);
        Self::write_padded(&mut m, layout.k_addr, k_chunk, chunk_len, d);
        Self::write_padded(&mut m, layout.v_addr, v_chunk, chunk_len, d);
        let mut part = FlashPartial::empty(rows, d);
        let mut cycles = 0u64;
        let mut bd = CycleBreakdown::default();
        for blk in 0..p.row_blocks() {
            let prog = match self.chunk_partial_program(&p, &layout, blk)? {
                None => continue,
                Some(prog) => prog,
            };
            let stats = self.run(&mut m, &prog)?;
            cycles += stats.cycles;
            bd.add(&stats.breakdown);
            let o_base = layout.o_addr as usize + blk * n * n;
            let l_base = layout.l_addr as usize + blk * n;
            for mcol in 0..n {
                let row = blk * n + mcol;
                if row >= rows {
                    break;
                }
                part.m[row] = m.array.cmp_new_m(mcol);
                part.l[row] = m.read_mem((l_base + mcol) as u32, 1)[0];
                for h in 0..d {
                    part.acc[row * d + h] = m.read_mem((o_base + h * n + mcol) as u32, 1)[0];
                }
            }
        }
        self.measured = Some(cycles);
        self.measured_bd = Some(bd);
        self.retire(m);
        Ok(ShardOutput::Partial(part))
    }

    /// One decode step (`br = 1`): a single query row over the
    /// `(prefix_len, d)` prefix, normalized on-device.
    pub(crate) fn run_decode_row(
        &mut self,
        prefix_len: usize,
        d: usize,
        q_row: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<Vec<f32>, String> {
        self.measured = None;
        self.measured_bd = None;
        self.check_dims(prefix_len, d)?;
        if q_row.len() != d || k.len() != prefix_len * d || v.len() != k.len() {
            return Err(format!(
                "sim backend: decode shape mismatch q {} k {} v {} for prefix {prefix_len} d {d}",
                q_row.len(),
                k.len(),
                v.len()
            ));
        }
        let p = ChunkParams::decode_row(self.cfg.n, prefix_len);
        let layout = ChunkLayout::packed(&p);
        let prog = self.chunk_program(&p, &layout)?;
        let mut m = self.machine_for(&p, &layout, d);
        Self::write_padded(&mut m, layout.q_addr, q_row, 1, d);
        Self::write_padded(&mut m, layout.k_addr, k, prefix_len, d);
        Self::write_padded(&mut m, layout.v_addr, v, prefix_len, d);
        let stats = self.run(&mut m, &prog)?;
        self.measured = Some(stats.cycles);
        self.measured_bd = Some(stats.breakdown);
        let out = Self::read_output(&m, &p, &layout, d);
        self.retire(m);
        Ok(out)
    }

    /// One split-KV decode range (`br = 1`, partial state).
    pub(crate) fn run_decode_range(
        &mut self,
        range_len: usize,
        d: usize,
        q_row: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<FlashPartial, String> {
        self.measured = None;
        self.measured_bd = None;
        self.check_dims(range_len, d)?;
        if q_row.len() != d || k.len() != range_len * d || v.len() != k.len() {
            return Err(format!(
                "sim backend: decode range shape mismatch q {} k {} v {} for range {range_len} d {d}",
                q_row.len(),
                k.len(),
                v.len()
            ));
        }
        let n = self.cfg.n;
        let p = ChunkParams::decode_row(n, range_len);
        let layout = ChunkLayout::packed(&p);
        let prog = self
            .chunk_partial_program(&p, &layout, 0)?
            .expect("an unmasked decode range always has live tiles");
        let mut m = self.machine_for(&p, &layout, d);
        Self::write_padded(&mut m, layout.q_addr, q_row, 1, d);
        Self::write_padded(&mut m, layout.k_addr, k, range_len, d);
        Self::write_padded(&mut m, layout.v_addr, v, range_len, d);
        let stats = self.run(&mut m, &prog)?;
        self.measured = Some(stats.cycles);
        self.measured_bd = Some(stats.breakdown);
        let mut part = FlashPartial::empty(1, d);
        part.m[0] = m.array.cmp_new_m(0);
        part.l[0] = m.read_mem(layout.l_addr, 1)[0];
        for h in 0..d {
            part.acc[h] = m.read_mem(layout.o_addr + (h * n) as u32, 1)[0];
        }
        self.retire(m);
        Ok(part)
    }

    fn check_dims(&self, seq_len: usize, d: usize) -> Result<(), String> {
        if d > self.cfg.n {
            return Err(format!(
                "sim backend: head dim {d} exceeds the {}-wide array",
                self.cfg.n
            ));
        }
        if seq_len == 0 {
            return Err("sim backend: empty sequence".into());
        }
        Ok(())
    }
}
