//! PJRT runtime: load the JAX/Pallas AOT artifacts and execute them on
//! the request path — Python never runs here.
//!
//! Interchange is HLO *text* (`artifacts/*.hlo.txt`): jax >= 0.5 emits
//! HloModuleProto with 64-bit instruction ids which this image's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).  `artifacts/manifest.txt` (written by
//! `python/compile/aot.py`) indexes every artifact with its workload
//! metadata; [`Runtime`] compiles lazily and caches executables.

pub mod prog_cache;
pub mod sim_backend;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context};

use crate::config::{AccelConfig, BackendKind};
use crate::mask::MaskKind;
use crate::numerics::reference::{
    decode_pwl, decode_pwl_partial, flash_pwl_masked_view, flash_pwl_partial_view,
    flash_pwl_resumed_view, FlashPartial, MatView,
};

pub use sim_backend::{HotpathStats, SimBackend};

/// One manifest row.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub dtype: String,
    pub seq_len: usize,
    pub d: usize,
    pub heads: usize,
    pub br: usize,
    pub bc: usize,
    pub segments: usize,
    pub num_inputs: usize,
}

/// Parsed `manifest.txt`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut entries = Vec::new();
        for (no, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            ensure!(f.len() == 11, "manifest line {}: want 11 fields, got {}", no + 1, f.len());
            entries.push(ArtifactMeta {
                name: f[0].into(),
                file: f[1].into(),
                kind: f[2].into(),
                dtype: f[3].into(),
                seq_len: f[4].parse().context("L")?,
                d: f[5].parse().context("d")?,
                heads: f[6].parse().context("heads")?,
                br: f[7].parse().context("br")?,
                bc: f[8].parse().context("bc")?,
                segments: f[9].parse().context("segments")?,
                num_inputs: f[10].parse().context("num_inputs")?,
            });
        }
        ensure!(!entries.is_empty(), "empty manifest at {}", path.display());
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Best artifact of `kind` for a sequence length: the smallest
    /// seq_len >= requested (requests are padded up to it).
    pub fn best_for(&self, kind: &str, seq_len: usize, d: usize) -> Option<&ArtifactMeta> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind && e.d == d && e.seq_len >= seq_len && e.heads == 1)
            .min_by_key(|e| e.seq_len)
    }

    pub fn kinds(&self) -> Vec<&str> {
        let mut ks: Vec<&str> = self.entries.iter().map(|e| e.kind.as_str()).collect();
        ks.sort_unstable();
        ks.dedup();
        ks
    }
}

/// PJRT client + lazy executable cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> crate::Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { manifest, client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&mut self, name: &str) -> crate::Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
                .clone();
            let path = self.manifest.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact on row-major f32 inputs, each `(rows, cols)`.
    /// Inputs are converted to the artifact dtype (fp16 activations) on
    /// the way in; the tuple output is converted back to f32.
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> crate::Result<Vec<f32>> {
        let meta = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
            .clone();
        ensure!(
            inputs.len() == meta.num_inputs,
            "{name}: expected {} inputs, got {}",
            meta.num_inputs,
            inputs.len()
        );
        let prim = match meta.dtype.as_str() {
            "f16" => xla::PrimitiveType::F16,
            "f32" => xla::PrimitiveType::F32,
            other => bail!("unsupported artifact dtype {other}"),
        };
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let expect: i64 = dims.iter().product();
            ensure!(
                expect as usize == data.len(),
                "{name}: input shape {dims:?} wants {expect} elems, got {}",
                data.len()
            );
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))?
                .convert(prim)
                .map_err(|e| anyhow!("convert to {prim:?}: {e:?}"))?;
            lits.push(lit);
        }
        let exe = self.compile(name)?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
        let out = out
            .convert(xla::PrimitiveType::F32)
            .map_err(|e| anyhow!("converting result: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("reading result: {e:?}"))
    }

    /// Convenience: run a single-head attention artifact on `(L, d)` Q/K/V.
    pub fn execute_attention(
        &mut self,
        name: &str,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> crate::Result<Vec<f32>> {
        let meta = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
            .clone();
        ensure!(meta.heads == 1, "{name} is multi-head; use execute()");
        let dims = [meta.seq_len as i64, meta.d as i64];
        self.execute(name, &[(q, &dims), (k, &dims), (v, &dims)])
    }
}

/// Numerics engine behind a device worker: where one head shard's
/// Q/K/V actually gets multiplied.
///
/// The coordinator shards requests per query head (see
/// [`crate::coordinator::shard`]); each shard is a single-head `(L, d)`
/// attention — exactly the granularity the AOT artifacts are exported
/// at, and the granularity the reference twin computes.  Which engine
/// runs is chosen per [`BackendKind`] at worker start.
pub enum Backend {
    /// PJRT execution of the `fsa_attn` AOT artifact ladder.
    Pjrt(Runtime),
    /// In-crate reference numerics: [`flash_pwl`], the strict software
    /// twin of the FSA device (PWL exp2 + fp16 operand quantization),
    /// tiled at the array size.  Used when PJRT/artifacts are absent
    /// (e.g. the offline `xla` stub build) and by tests that need the
    /// serving path without `make artifacts`.
    Reference {
        /// Tile size cap (the FSA array dimension).
        array_size: usize,
        /// PWL exp2 segment count.
        segments: usize,
    },
    /// The cycle-accurate machine (DESIGN.md §8): shards compile to ISA
    /// programs and execute on [`crate::sim::Machine`], bitwise-equal
    /// to the reference twin, with *measured* cycles replacing the
    /// modeled latency ([`Backend::take_measured`]).
    Sim(SimBackend),
}

impl Backend {
    /// Resolve a [`BackendKind`] against the artifacts directory.
    ///
    /// `Auto` picks PJRT when a manifest is present and the PJRT client
    /// boots, falling back to the reference twin otherwise; `Pjrt` is
    /// strict and returns the boot error instead of falling back.
    pub fn new(kind: BackendKind, artifacts: &Path, cfg: &AccelConfig) -> crate::Result<Backend> {
        let reference = || Backend::Reference {
            array_size: cfg.array_size,
            segments: cfg.pwl_segments.max(1),
        };
        match kind {
            BackendKind::Reference => Ok(reference()),
            BackendKind::Sim => Ok(Backend::Sim(SimBackend::new(cfg))),
            BackendKind::Pjrt => Ok(Backend::Pjrt(Runtime::new(artifacts)?)),
            BackendKind::Auto => {
                if artifacts.join("manifest.txt").exists() {
                    match Runtime::new(artifacts) {
                        Ok(rt) => Ok(Backend::Pjrt(rt)),
                        Err(e) => {
                            eprintln!(
                                "backend auto: manifest present but PJRT boot failed \
                                 ({e:#}); falling back to reference numerics"
                            );
                            Ok(reference())
                        }
                    }
                } else {
                    Ok(reference())
                }
            }
        }
    }

    /// Engine name for logs and metrics.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt(_) => "pjrt",
            Backend::Reference { .. } => "reference",
            Backend::Sim(_) => "sim",
        }
    }

    /// Measured device cycles of the last execution, when this backend
    /// measures rather than models (the sim backend).  Workers call
    /// this immediately after an `execute_*` and price the shard with
    /// the measured number, falling back to the perfmodel prediction
    /// on `None` (DESIGN.md §8's measured-vs-modeled contract).
    pub fn take_measured(&mut self) -> Option<u64> {
        match self {
            Backend::Sim(s) => s.take_measured(),
            _ => None,
        }
    }

    /// Per-instruction-class attribution of the last measured execution
    /// (DESIGN.md §9); `None` for backends that model instead of
    /// measure.  When `Some`, its `total()` equals the cycles returned
    /// by the paired [`Backend::take_measured`].
    pub fn take_measured_breakdown(&mut self) -> Option<crate::sim::CycleBreakdown> {
        match self {
            Backend::Sim(s) => s.take_measured_breakdown(),
            _ => None,
        }
    }

    /// Forward the `sim_batch_shards` knob to the sim backend (how many
    /// independent shards share one machine between hazard fences;
    /// no-op for backends that don't simulate).
    pub fn set_sim_batch_shards(&mut self, shards: usize) {
        if let Backend::Sim(s) = self {
            s.set_batch_shards(shards);
        }
    }

    /// Forward the `sim_prog_cache` knob to the sim backend (compiled
    /// ISA-program cache entries; 0 disables — DESIGN.md §12; no-op for
    /// backends that don't simulate).
    pub fn set_sim_prog_cache(&mut self, entries: usize) {
        if let Backend::Sim(s) = self {
            s.set_prog_cache(entries);
        }
    }

    /// Drain the sim backend's host-path counters (program-cache
    /// hits/misses, machine allocations) accumulated since the last
    /// take; zeros for backends that don't simulate.  Workers harvest
    /// these per batch into [`crate::coordinator::metrics::Metrics`].
    pub fn take_hotpath_stats(&mut self) -> HotpathStats {
        match self {
            Backend::Sim(s) => s.take_hotpath_stats(),
            _ => HotpathStats::default(),
        }
    }

    /// Execute one typed unit of backend work (the single entry point —
    /// the old `execute_head`/`execute_head_partial`/`execute_decode_row`/
    /// `execute_decode_row_partial` surface collapsed into a
    /// [`ShardPlan`] dispatch).  Errors are strings because they travel
    /// inside [`crate::coordinator::request::AttentionResponse`].
    pub fn execute(&mut self, plan: ShardPlan<'_>) -> Result<ShardOutput, String> {
        plan.validate()?;
        match plan {
            ShardPlan::Head { seq_len, d, q, k, v, mask } => {
                self.run_head(seq_len, d, q, k, v, mask).map(ShardOutput::Full)
            }
            ShardPlan::HeadChunk { seq_len, d, q, k_chunk, v_chunk, mask, key_offset, total_keys } => self
                .run_head_chunk(seq_len, d, q, k_chunk, v_chunk, mask, key_offset, total_keys)
                .map(ShardOutput::Partial),
            ShardPlan::ResumedPrefill {
                seq_len,
                d,
                query_offset,
                q_suffix,
                k_chunk,
                v_chunk,
                mask,
                key_offset,
                total_keys,
            } => self.run_resumed(
                seq_len, d, query_offset, q_suffix, k_chunk, v_chunk, mask, key_offset, total_keys,
            ),
            ShardPlan::DecodeRow { prefix_len, d, q_row, k, v } => {
                self.run_decode_row(prefix_len, d, q_row, k, v).map(ShardOutput::Full)
            }
            ShardPlan::DecodeRange { range_len, d, q_row, k, v } => {
                self.run_decode_range(range_len, d, q_row, k, v).map(ShardOutput::Partial)
            }
        }
    }

    /// Whole-head prefill/stateless attention: normalized `(seq_len, d)`
    /// rows, mask applied exactly (DESIGN.md §6).
    fn run_head(
        &mut self,
        seq_len: usize,
        d: usize,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        mask: MaskKind,
    ) -> Result<Vec<f32>, String> {
        match self {
            Backend::Pjrt(rt) => {
                // The AOT artifacts take no mask input: reject masked
                // shards instead of silently dropping the mask (masked
                // artifact export is DESIGN.md §future-work).
                if !mask.is_none() {
                    // Note: `auto` resolves to PJRT whenever artifacts
                    // exist, so the advice must be `reference`
                    // explicitly — recommending auto would loop the
                    // user straight back here.
                    return Err(format!(
                        "the AOT artifacts take no attention mask (got {mask}); \
                         masked serving needs backend=reference, or masked \
                         artifact export (DESIGN.md §6)"
                    ));
                }
                match rt.manifest.best_for("fsa_attn", seq_len, d) {
                    None => Err(format!("no fsa_attn artifact covers seq_len {seq_len} d {d}")),
                    Some(meta) if meta.seq_len != seq_len => Err(format!(
                        "strict mode: need exact artifact for seq_len {} (nearest is {}); \
                         pad client-side with AttentionRequest::padded and serve on \
                         backend=reference (exact, DESIGN.md §6; auto resolves to PJRT \
                         while artifacts exist), or export an exact-bucket artifact",
                        seq_len, meta.seq_len
                    )),
                    Some(meta) => {
                        let name = meta.name.clone();
                        rt.execute_attention(&name, q, k, v).map_err(|e| format!("{e:#}"))
                    }
                }
            }
            Backend::Reference { array_size, segments } => {
                // Tile at the array size with a ragged final tile, like
                // the device itself (and like the decode path).  This is
                // what makes bucket padding bitwise-exact: a padded
                // request and its unpadded original tile identically
                // over the valid region, and the mask excludes the rest.
                // The plan's slices execute as borrowed views — no
                // owned-Mat staging copies (DESIGN.md §12).
                Ok(flash_pwl_masked_view(
                    MatView::new(seq_len, d, q),
                    MatView::new(seq_len, d, k),
                    MatView::new(seq_len, d, v),
                    *array_size,
                    *array_size,
                    *segments,
                    mask,
                )
                .data)
            }
            Backend::Sim(s) => s.run_head(seq_len, d, q, k, v, mask),
        }
    }

    /// One sequence-parallel chunk of one head (DESIGN.md §7): the full
    /// `(seq_len, d)` Q against the `(chunk_len, d)` K/V chunk covering
    /// global keys `[key_offset, key_offset + chunk_len)` of a
    /// `total_keys`-key sequence, emitting the partial `(O~, m, l)`
    /// state the gather merges in chunk order.
    ///
    /// The reference twin runs [`flash_pwl_partial`] tiled at the array
    /// size — the same kernel whose single-chunk degeneration is
    /// bitwise the whole-head path.  The AOT artifacts emit only
    /// normalized outputs (no partial-state signature is exported), so
    /// the strict PJRT backend reports the gap instead of silently
    /// merging incompatible numerics.
    #[allow(clippy::too_many_arguments)]
    fn run_head_chunk(
        &mut self,
        seq_len: usize,
        d: usize,
        q: &[f32],
        k_chunk: &[f32],
        v_chunk: &[f32],
        mask: MaskKind,
        key_offset: usize,
        total_keys: usize,
    ) -> Result<FlashPartial, String> {
        match self {
            Backend::Pjrt(_) => Err(format!(
                "no partial (`fsa_attn_partial`) artifact kind is exported yet \
                 (chunk [{key_offset}, {}) of {total_keys} keys); sequence-parallel \
                 serving needs backend=reference (DESIGN.md §7)",
                key_offset + k_chunk.len() / d
            )),
            Backend::Reference { array_size, segments } => {
                let chunk_len = k_chunk.len() / d;
                Ok(flash_pwl_partial_view(
                    MatView::new(seq_len, d, q),
                    MatView::new(chunk_len, d, k_chunk),
                    MatView::new(chunk_len, d, v_chunk),
                    *array_size, *array_size, *segments,
                    mask, key_offset, total_keys,
                ))
            }
            Backend::Sim(s) => s.run_head_chunk(
                seq_len, d, q, k_chunk, v_chunk, mask, key_offset, total_keys,
            ),
        }
    }

    /// One resumed (prefix-cache warm) prefill chunk (DESIGN.md §11):
    /// only the suffix query rows `[query_offset, seq_len)` against the
    /// K/V chunk, with the mask evaluated at global query coordinates.
    /// A whole-range chunk (`key_offset == 0` covering `total_keys`)
    /// returns the normalized suffix rows ([`ShardOutput::Full`]) —
    /// mirroring the cold whole-head path — and a sub-range returns
    /// partial state the gather merges in chunk order, so the warm
    /// output composes bitwise with the cold run's suffix rows.
    #[allow(clippy::too_many_arguments)]
    fn run_resumed(
        &mut self,
        seq_len: usize,
        d: usize,
        query_offset: usize,
        q_suffix: &[f32],
        k_chunk: &[f32],
        v_chunk: &[f32],
        mask: MaskKind,
        key_offset: usize,
        total_keys: usize,
    ) -> Result<ShardOutput, String> {
        let chunk_len = k_chunk.len() / d;
        let whole_range = key_offset == 0 && chunk_len == total_keys;
        match self {
            Backend::Pjrt(_) => Err(format!(
                "no resumed-prefill artifact kind is exported yet (resume {query_offset} of \
                 {seq_len}); prefix-cache serving needs backend=reference|sim (DESIGN.md §11)"
            )),
            Backend::Reference { array_size, segments } => {
                let rows = seq_len - query_offset;
                let part = flash_pwl_resumed_view(
                    MatView::new(rows, d, q_suffix),
                    MatView::new(chunk_len, d, k_chunk),
                    MatView::new(chunk_len, d, v_chunk),
                    *array_size, *array_size, *segments,
                    mask, query_offset, key_offset, total_keys,
                );
                if whole_range {
                    Ok(ShardOutput::Full(part.finalize().data))
                } else {
                    Ok(ShardOutput::Partial(part))
                }
            }
            Backend::Sim(s) => s.run_resumed(
                seq_len, d, query_offset, q_suffix, k_chunk, v_chunk, mask, key_offset, total_keys,
            ),
        }
    }

    /// One decode step of one head: a single `(1, d)` query row over a
    /// `(prefix_len, d)` K/V prefix (cached pages or the host-tier
    /// fallback — numerically identical by construction).
    ///
    /// The reference twin tiles the prefix at the array size with a
    /// ragged tail ([`decode_pwl`]), matching the stateless oracle
    /// bit-for-bit.  PJRT has no decode artifact kind yet (`fsa_decode`
    /// would carry `(1, d) × (L, d)` signatures); exporting one is
    /// listed in DESIGN.md §future-work, so the strict backend reports
    /// the gap instead of silently changing numerics.
    fn run_decode_row(
        &mut self,
        prefix_len: usize,
        d: usize,
        q_row: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<Vec<f32>, String> {
        match self {
            Backend::Pjrt(_) => Err(format!(
                "no `fsa_decode` artifact kind is exported yet (prefix {prefix_len}, d {d}); \
                 decode serving needs backend=reference|auto (DESIGN.md §5)"
            )),
            Backend::Reference { array_size, segments } => {
                Ok(decode_pwl(q_row, k, v, d, *array_size, *segments))
            }
            Backend::Sim(s) => s.run_decode_row(prefix_len, d, q_row, k, v),
        }
    }

    /// One split-KV decode range of one head (DESIGN.md §7): the `(1,
    /// d)` query row against a `(range_len, d)` slice of the prefix,
    /// emitting the one-row partial the gather merges in range order.
    fn run_decode_range(
        &mut self,
        range_len: usize,
        d: usize,
        q_row: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<FlashPartial, String> {
        match self {
            Backend::Pjrt(_) => Err(format!(
                "no `fsa_decode` partial artifact kind is exported yet (range \
                 {range_len}, d {d}); split-KV decode needs backend=reference \
                 (DESIGN.md §7)"
            )),
            Backend::Reference { array_size, segments } => {
                Ok(decode_pwl_partial(q_row, k, v, d, *array_size, *segments))
            }
            Backend::Sim(s) => s.run_decode_range(range_len, d, q_row, k, v),
        }
    }
}

/// One typed unit of backend work — the single argument of
/// [`Backend::execute`].  Every serving shard the device workers run is
/// one of these variants; the per-variant parameters that used to ride
/// four parallel method signatures live on the enum, and a resumed
/// prefill is a variant rather than a fifth method.
#[derive(Clone, Copy, Debug)]
pub enum ShardPlan<'a> {
    /// Whole-head prefill/stateless attention: row-major `(seq_len, d)`
    /// Q/K/V, normalized `(seq_len, d)` output rows.
    Head {
        seq_len: usize,
        d: usize,
        q: &'a [f32],
        k: &'a [f32],
        v: &'a [f32],
        mask: MaskKind,
    },
    /// One sequence-parallel K/V chunk at global key coordinates
    /// (DESIGN.md §7): partial `(O~, m, l)` state out.
    HeadChunk {
        seq_len: usize,
        d: usize,
        q: &'a [f32],
        k_chunk: &'a [f32],
        v_chunk: &'a [f32],
        mask: MaskKind,
        key_offset: usize,
        total_keys: usize,
    },
    /// Resumed (prefix-cache warm) prefill (DESIGN.md §11): `q_suffix`
    /// holds only the `seq_len - query_offset` uncovered query rows;
    /// the mask is evaluated at global query coordinates so the rows
    /// compute bitwise what the cold run computed for them.  Output is
    /// [`ShardOutput::Full`] suffix rows for a whole-range chunk,
    /// [`ShardOutput::Partial`] for a sequence-parallel sub-range.
    ResumedPrefill {
        seq_len: usize,
        d: usize,
        query_offset: usize,
        q_suffix: &'a [f32],
        k_chunk: &'a [f32],
        v_chunk: &'a [f32],
        mask: MaskKind,
        key_offset: usize,
        total_keys: usize,
    },
    /// One decode step: a `(1, d)` query row over the `(prefix_len, d)`
    /// K/V prefix, normalized `(1, d)` output.
    DecodeRow {
        prefix_len: usize,
        d: usize,
        q_row: &'a [f32],
        k: &'a [f32],
        v: &'a [f32],
    },
    /// One split-KV decode range: partial one-row state out.
    DecodeRange {
        range_len: usize,
        d: usize,
        q_row: &'a [f32],
        k: &'a [f32],
        v: &'a [f32],
    },
}

impl ShardPlan<'_> {
    /// Plan kind for logs and error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            ShardPlan::Head { .. } => "head",
            ShardPlan::HeadChunk { .. } => "head_chunk",
            ShardPlan::ResumedPrefill { .. } => "resumed_prefill",
            ShardPlan::DecodeRow { .. } => "decode_row",
            ShardPlan::DecodeRange { .. } => "decode_range",
        }
    }

    /// Shape validation shared by every backend: reported as an error,
    /// never a panic, because it travels inside an `AttentionResponse`.
    fn validate(&self) -> Result<(), String> {
        match *self {
            ShardPlan::Head { seq_len, d, q, k, v, .. } => {
                if q.len() != seq_len * d || k.len() != q.len() || v.len() != q.len() {
                    return Err(format!(
                        "head shape mismatch: q {} k {} v {} for seq {seq_len} d {d}",
                        q.len(),
                        k.len(),
                        v.len()
                    ));
                }
            }
            ShardPlan::HeadChunk { seq_len, d, q, k_chunk, v_chunk, key_offset, total_keys, .. } => {
                if k_chunk.len() % d != 0
                    || k_chunk.len() != v_chunk.len()
                    || q.len() != seq_len * d
                {
                    return Err(format!(
                        "partial shape mismatch: q {} k {} v {} for seq {seq_len} d {d}",
                        q.len(),
                        k_chunk.len(),
                        v_chunk.len()
                    ));
                }
                if key_offset + k_chunk.len() / d > total_keys {
                    return Err(format!(
                        "chunk [{key_offset}, {}) exceeds the {total_keys}-key sequence",
                        key_offset + k_chunk.len() / d
                    ));
                }
            }
            ShardPlan::ResumedPrefill {
                seq_len,
                d,
                query_offset,
                q_suffix,
                k_chunk,
                v_chunk,
                key_offset,
                total_keys,
                ..
            } => {
                if query_offset >= seq_len {
                    return Err(format!(
                        "resume point {query_offset} leaves no suffix rows of seq {seq_len}"
                    ));
                }
                if q_suffix.len() != (seq_len - query_offset) * d
                    || k_chunk.len() % d != 0
                    || k_chunk.len() != v_chunk.len()
                {
                    return Err(format!(
                        "resumed shape mismatch: q {} k {} v {} for seq {seq_len} d {d} \
                         resume {query_offset}",
                        q_suffix.len(),
                        k_chunk.len(),
                        v_chunk.len()
                    ));
                }
                if key_offset + k_chunk.len() / d > total_keys {
                    return Err(format!(
                        "chunk [{key_offset}, {}) exceeds the {total_keys}-key sequence",
                        key_offset + k_chunk.len() / d
                    ));
                }
            }
            ShardPlan::DecodeRow { prefix_len, d, q_row, k, v } => {
                if q_row.len() != d || k.len() != prefix_len * d || v.len() != k.len() {
                    return Err(format!(
                        "decode shape mismatch: q {} k {} v {} for prefix {prefix_len} d {d}",
                        q_row.len(),
                        k.len(),
                        v.len()
                    ));
                }
            }
            ShardPlan::DecodeRange { range_len, d, q_row, k, v } => {
                if q_row.len() != d || k.len() != range_len * d || v.len() != k.len() {
                    return Err(format!(
                        "decode range shape mismatch: q {} k {} v {} for range {range_len} d {d}",
                        q_row.len(),
                        k.len(),
                        v.len()
                    ));
                }
            }
        }
        Ok(())
    }
}

/// What a [`ShardPlan`] produces: normalized output rows, or partial
/// online-softmax state for the gather's chunk-order merge.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardOutput {
    /// Normalized row-major rows — `(seq_len, d)` for [`ShardPlan::Head`],
    /// the suffix rows for a whole-range [`ShardPlan::ResumedPrefill`],
    /// `(1, d)` for [`ShardPlan::DecodeRow`].
    Full(Vec<f32>),
    /// Unnormalized `(O~, m, l)` state, merged in chunk order.
    Partial(FlashPartial),
}

impl ShardOutput {
    /// Unwrap normalized rows; reports (not panics) a variant mismatch.
    pub fn into_full(self) -> Result<Vec<f32>, String> {
        match self {
            ShardOutput::Full(rows) => Ok(rows),
            ShardOutput::Partial(_) => Err("expected normalized rows, got partial state".into()),
        }
    }

    /// Unwrap partial state; reports (not panics) a variant mismatch.
    pub fn into_partial(self) -> Result<FlashPartial, String> {
        match self {
            ShardOutput::Partial(p) => Ok(p),
            ShardOutput::Full(_) => Err("expected partial state, got normalized rows".into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::reference::{flash_pwl_masked, flash_pwl_partial, Mat};

    #[test]
    fn manifest_parsing_rejects_garbage() {
        let dir = std::env::temp_dir().join("fsa_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "# comment only\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(dir.join("manifest.txt"), "a b c\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(
            dir.join("manifest.txt"),
            "# h\nfsa_attn_L128_d128 f.hlo.txt fsa_attn f16 128 128 1 128 128 8 3\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.find("fsa_attn_L128_d128").unwrap().seq_len, 128);
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn best_for_picks_smallest_cover() {
        let mk = |name: &str, kind: &str, l: usize| ArtifactMeta {
            name: name.into(),
            file: format!("{name}.hlo.txt"),
            kind: kind.into(),
            dtype: "f16".into(),
            seq_len: l,
            d: 128,
            heads: 1,
            br: 128,
            bc: 128,
            segments: 8,
            num_inputs: 3,
        };
        let m = Manifest {
            dir: PathBuf::new(),
            entries: vec![
                mk("a", "fsa_attn", 128),
                mk("b", "fsa_attn", 512),
                mk("c", "fsa_attn", 2048),
                mk("d", "sdpa", 512),
            ],
        };
        assert_eq!(m.best_for("fsa_attn", 100, 128).unwrap().name, "a");
        assert_eq!(m.best_for("fsa_attn", 129, 128).unwrap().name, "b");
        assert_eq!(m.best_for("fsa_attn", 2048, 128).unwrap().name, "c");
        assert!(m.best_for("fsa_attn", 4096, 128).is_none());
        assert!(m.best_for("sdpa", 100, 64).is_none());
        assert_eq!(m.kinds(), vec!["fsa_attn", "sdpa"]);
    }

    fn head(
        be: &mut Backend,
        seq_len: usize,
        d: usize,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        mask: MaskKind,
    ) -> Result<Vec<f32>, String> {
        be.execute(ShardPlan::Head { seq_len, d, q, k, v, mask })?.into_full()
    }

    #[test]
    fn reference_backend_matches_flash_pwl_twin() {
        use crate::numerics::reference::flash_pwl;
        use crate::numerics::SplitMix64;
        let cfg = AccelConfig::builtin("fsa").unwrap();
        let mut be =
            Backend::new(BackendKind::Reference, Path::new("/nonexistent"), &cfg).unwrap();
        assert_eq!(be.name(), "reference");
        let (seq, d) = (32, 16);
        let mut rng = SplitMix64::new(3);
        let q = rng.normal_matrix(seq, d);
        let k = rng.normal_matrix(seq, d);
        let v = rng.normal_matrix(seq, d);
        let got = head(&mut be, seq, d, &q, &k, &v, MaskKind::None).unwrap();
        // seq (32) is below the 128 array dim: one ragged tile, which is
        // exactly one whole-sequence tile.
        let want = flash_pwl(
            &Mat::new(seq, d, q.clone()),
            &Mat::new(seq, d, k.clone()),
            &Mat::new(seq, d, v.clone()),
            seq,
            seq,
            cfg.pwl_segments,
        );
        assert_eq!(got, want.data);
        // Masked execution is the masked twin, bit for bit.
        let causal = head(&mut be, seq, d, &q, &k, &v, MaskKind::Causal).unwrap();
        let want = flash_pwl_masked(
            &Mat::new(seq, d, q.clone()),
            &Mat::new(seq, d, k.clone()),
            &Mat::new(seq, d, v.clone()),
            cfg.array_size,
            cfg.array_size,
            cfg.pwl_segments,
            MaskKind::Causal,
        );
        assert_eq!(causal, want.data);
        assert_ne!(causal, got, "the mask must change the output");
    }

    #[test]
    fn reference_backend_partials_match_the_numerics_twin() {
        use crate::numerics::reference::{merge_partials, Exp2};
        use crate::numerics::pwl::PwlExp2;
        use crate::numerics::SplitMix64;
        let cfg = AccelConfig::builtin("fsa").unwrap();
        let mut be =
            Backend::new(BackendKind::Reference, Path::new("/nonexistent"), &cfg).unwrap();
        let (seq, d) = (32usize, 16usize);
        let mut rng = SplitMix64::new(5);
        let q = rng.normal_matrix(seq, d);
        let k = rng.normal_matrix(seq, d);
        let v = rng.normal_matrix(seq, d);
        // Two chunks through the backend == the flash_pwl_partial twin,
        // and their in-order merge == the whole-head execute path
        // within the PWL band.
        let chunk = |be: &mut Backend, k_chunk: &[f32], v_chunk: &[f32], key_offset: usize| {
            be.execute(ShardPlan::HeadChunk {
                seq_len: seq,
                d,
                q: &q,
                k_chunk,
                v_chunk,
                mask: MaskKind::None,
                key_offset,
                total_keys: seq,
            })
            .and_then(ShardOutput::into_partial)
        };
        let p0 = chunk(&mut be, &k[..16 * d], &v[..16 * d], 0).unwrap();
        let p1 = chunk(&mut be, &k[16 * d..], &v[16 * d..], 16).unwrap();
        let want = flash_pwl_partial(
            &Mat::new(seq, d, q.clone()),
            &Mat::new(16, d, k[..16 * d].to_vec()),
            &Mat::new(16, d, v[..16 * d].to_vec()),
            cfg.array_size, cfg.array_size, cfg.pwl_segments,
            MaskKind::None, 0, seq,
        );
        assert_eq!(p0, want);
        let merged = merge_partials(&[p0, p1], &Exp2::PwlF16(PwlExp2::new(cfg.pwl_segments)));
        let whole = head(&mut be, seq, d, &q, &k, &v, MaskKind::None).unwrap();
        let err = crate::numerics::reference::mat_error(
            &merged,
            &Mat::new(seq, d, whole),
        );
        assert!(err.mae < 3e-2, "{err:?}");
        // Decode range partial == the decode_pwl_partial twin.
        let qr = rng.normal_matrix(1, d);
        let dp = be
            .execute(ShardPlan::DecodeRange {
                range_len: 16,
                d,
                q_row: &qr,
                k: &k[..16 * d],
                v: &v[..16 * d],
            })
            .unwrap()
            .into_partial()
            .unwrap();
        assert_eq!(dp, decode_pwl_partial(&qr, &k[..16 * d], &v[..16 * d], d, cfg.array_size, cfg.pwl_segments));
        // Shape mismatches are reported, not panicked.
        assert!(chunk(&mut be, &k[..d - 1], &v[..d - 1], 0).is_err());
        assert!(be
            .execute(ShardPlan::DecodeRange {
                range_len: 16,
                d,
                q_row: &qr,
                k: &k[..8 * d],
                v: &v[..8 * d],
            })
            .is_err());
    }

    #[test]
    fn reference_backend_resumed_rows_are_bitwise_the_cold_suffix() {
        use crate::numerics::SplitMix64;
        let cfg = AccelConfig::builtin("fsa").unwrap();
        let mut be =
            Backend::new(BackendKind::Reference, Path::new("/nonexistent"), &cfg).unwrap();
        let (seq, d, resume) = (40usize, 16usize, 13usize);
        let mut rng = SplitMix64::new(11);
        let q = rng.normal_matrix(seq, d);
        let k = rng.normal_matrix(seq, d);
        let v = rng.normal_matrix(seq, d);
        for mask in [MaskKind::None, MaskKind::Causal] {
            let cold = head(&mut be, seq, d, &q, &k, &v, mask).unwrap();
            let warm = be
                .execute(ShardPlan::ResumedPrefill {
                    seq_len: seq,
                    d,
                    query_offset: resume,
                    q_suffix: &q[resume * d..],
                    k_chunk: &k,
                    v_chunk: &v,
                    mask,
                    key_offset: 0,
                    total_keys: seq,
                })
                .unwrap()
                .into_full()
                .unwrap();
            assert_eq!(warm, cold[resume * d..], "{mask:?}");
        }
        // Resume point beyond the sequence is reported, not panicked.
        assert!(be
            .execute(ShardPlan::ResumedPrefill {
                seq_len: seq,
                d,
                query_offset: seq,
                q_suffix: &[],
                k_chunk: &k,
                v_chunk: &v,
                mask: MaskKind::None,
                key_offset: 0,
                total_keys: seq,
            })
            .is_err());
    }

    #[test]
    fn auto_backend_without_manifest_is_reference() {
        let cfg = AccelConfig::builtin("fsa").unwrap();
        let be = Backend::new(BackendKind::Auto, Path::new("/nonexistent"), &cfg).unwrap();
        assert_eq!(be.name(), "reference");
    }

    #[test]
    fn reference_decode_row_matches_oracle_and_validates_shapes() {
        use crate::numerics::SplitMix64;
        let cfg = AccelConfig::builtin("fsa").unwrap();
        let mut be =
            Backend::new(BackendKind::Reference, Path::new("/nonexistent"), &cfg).unwrap();
        let (prefix, d) = (37usize, 16usize);
        let mut rng = SplitMix64::new(21);
        let q = rng.normal_matrix(1, d);
        let k = rng.normal_matrix(prefix, d);
        let v = rng.normal_matrix(prefix, d);
        let got = be
            .execute(ShardPlan::DecodeRow { prefix_len: prefix, d, q_row: &q, k: &k, v: &v })
            .unwrap()
            .into_full()
            .unwrap();
        // Same tiling as the device path: array-size columns, ragged tail.
        let want = decode_pwl(&q, &k, &v, d, cfg.array_size, cfg.pwl_segments);
        assert_eq!(got, want);
        // Shape mismatches are reported, not panicked.
        assert!(be
            .execute(ShardPlan::DecodeRow { prefix_len: prefix, d, q_row: &q, k: &k[..d], v: &v })
            .is_err());
        // Plan kinds name themselves for logs.
        assert_eq!(
            ShardPlan::DecodeRow { prefix_len: prefix, d, q_row: &q, k: &k, v: &v }.kind(),
            "decode_row"
        );
    }
}
