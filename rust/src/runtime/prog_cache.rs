//! Compiled-program cache for the sim backend (DESIGN.md §12).
//!
//! An ISA program is a *pure function* of its construction inputs:
//! [`flash_chunk_program`](crate::kernel::flash::flash_chunk_program)
//! and [`flash_chunk_partial_program`](crate::kernel::flash::flash_chunk_partial_program)
//! read nothing but the [`ChunkParams`] fields (which embed the array
//! dim `n` and the mask bound form), the [`ChunkLayout`] addresses, and —
//! for the partial path — the row-block index.  PWL segment count and
//! fp16 quantization live in the *machine*, not the program, so they
//! cannot leak into the cached text.  [`ProgKey`] captures every one of
//! those inputs; a hit therefore hands back a program that is textually
//! identical to what a fresh build would produce, and reuse can change
//! host time only — never served bits, never measured cycles.  The
//! contract is pinned by the cache-on/cache-off twins in
//! `rust/tests/sim_differential.rs` and `rust/tests/coordinator_sim.rs`.
//!
//! The cache is a bounded LRU of `Arc<Program>` (decode waves re-execute
//! identical shapes every step, so the working set is small and hot).
//! A fully-masked partial block — where the builder returns `None` and
//! the backend skips the array — is memoized as `None` too: deciding
//! "no live tiles" walks the same tile census as building the program.

use std::collections::HashMap;
use std::sync::Arc;

use crate::isa::Program;
use crate::kernel::flash::{ChunkLayout, ChunkParams};
use crate::mask::MaskKind;

/// Every input of ISA program construction, by value.  Two shards with
/// equal keys get textually identical programs (see the module doc for
/// the purity argument).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProgKey {
    /// `Some(blk)` for a per-row-block partial program
    /// ([`flash_chunk_partial_program`](crate::kernel::flash::flash_chunk_partial_program));
    /// `None` for the normalized whole-chunk program
    /// ([`flash_chunk_program`](crate::kernel::flash::flash_chunk_program)).
    pub partial_block: Option<usize>,
    /// The [`ChunkParams`] fields, verbatim (`n` is the array dim).
    pub n: usize,
    pub valid_queries: usize,
    pub query_offset: usize,
    pub valid_keys: usize,
    pub key_offset: usize,
    pub total_keys: usize,
    pub mask: MaskKind,
    pub spad_elems: u32,
    pub accum_elems: u32,
    /// The [`ChunkLayout`] addresses (today always `packed(&p)`, but the
    /// key does not assume that).
    pub q_addr: u32,
    pub k_addr: u32,
    pub v_addr: u32,
    pub o_addr: u32,
    pub l_addr: u32,
}

impl ProgKey {
    pub fn new(p: &ChunkParams, layout: &ChunkLayout, partial_block: Option<usize>) -> ProgKey {
        ProgKey {
            partial_block,
            n: p.n,
            valid_queries: p.valid_queries,
            query_offset: p.query_offset,
            valid_keys: p.valid_keys,
            key_offset: p.key_offset,
            total_keys: p.total_keys,
            mask: p.mask,
            spad_elems: p.spad_elems,
            accum_elems: p.accum_elems,
            q_addr: layout.q_addr,
            k_addr: layout.k_addr,
            v_addr: layout.v_addr,
            o_addr: layout.o_addr,
            l_addr: layout.l_addr,
        }
    }
}

struct Entry {
    /// `None` memoizes a fully-masked partial block (builder said "no
    /// live tiles" — the backend skips the array run entirely).
    prog: Option<Arc<Program>>,
    /// Monotonic last-use stamp for LRU eviction.
    stamp: u64,
}

/// Bounded LRU of compiled programs, keyed by [`ProgKey`].
pub struct ProgramCache {
    capacity: usize,
    map: HashMap<ProgKey, Entry>,
    clock: u64,
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that ran the builder (cache-off backends count every
    /// build here too, so `misses` == programs built in both modes).
    pub misses: u64,
}

impl ProgramCache {
    /// A cache holding at most `capacity` programs (`capacity >= 1`).
    pub fn new(capacity: usize) -> ProgramCache {
        ProgramCache {
            capacity: capacity.max(1),
            map: HashMap::new(),
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look `key` up; on a miss run `build` and cache its product.
    /// Build errors are returned without being cached (the next lookup
    /// retries), so a transient failure can never poison the cache.
    pub fn get_or_build<E>(
        &mut self,
        key: ProgKey,
        build: impl FnOnce() -> Result<Option<Program>, E>,
    ) -> Result<Option<Arc<Program>>, E> {
        self.clock += 1;
        if let Some(e) = self.map.get_mut(&key) {
            e.stamp = self.clock;
            self.hits += 1;
            return Ok(e.prog.clone());
        }
        self.misses += 1;
        let prog = build()?.map(Arc::new);
        if self.map.len() >= self.capacity {
            // O(len) min-stamp scan: eviction only happens once the
            // cache is full, and serving working sets are far below any
            // sane capacity, so the scan is off the hot path.
            if let Some(victim) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| *k)
            {
                self.map.remove(&victim);
            }
        }
        self.map.insert(key, Entry { prog: prog.clone(), stamp: self.clock });
        Ok(prog)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::flash::{flash_chunk_partial_program, flash_chunk_program};

    fn key_for(seq_len: usize, mask: MaskKind) -> (ChunkParams, ChunkLayout, ProgKey) {
        let p = ChunkParams::whole(8, seq_len, mask);
        let layout = ChunkLayout::packed(&p);
        let key = ProgKey::new(&p, &layout, None);
        (p, layout, key)
    }

    #[test]
    fn hit_returns_the_same_arc_and_counts() {
        let (p, layout, key) = key_for(16, MaskKind::Causal);
        let mut c = ProgramCache::new(8);
        let a = c
            .get_or_build(key, || {
                flash_chunk_program(&p, &layout).map(Some).map_err(|e| format!("{e:#}"))
            })
            .unwrap()
            .unwrap();
        let b = c
            .get_or_build(key, || -> Result<_, String> { panic!("hit must not rebuild") })
            .unwrap()
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!((c.hits, c.misses, c.len()), (1, 1, 1));
    }

    #[test]
    fn distinct_shapes_get_distinct_entries() {
        let (p1, l1, k1) = key_for(16, MaskKind::Causal);
        let (p2, l2, k2) = key_for(16, MaskKind::None);
        assert_ne!(k1, k2);
        let mut c = ProgramCache::new(8);
        let a = c
            .get_or_build(k1, || {
                flash_chunk_program(&p1, &l1).map(Some).map_err(|e| format!("{e:#}"))
            })
            .unwrap()
            .unwrap();
        let b = c
            .get_or_build(k2, || {
                flash_chunk_program(&p2, &l2).map(Some).map_err(|e| format!("{e:#}"))
            })
            .unwrap()
            .unwrap();
        // Causal whole-head skips upper-triangular tiles; the unmasked
        // twin does not — the cached texts must differ.
        assert_ne!(*a, *b);
        assert_eq!((c.hits, c.misses, c.len()), (0, 2, 2));
    }

    #[test]
    fn lru_evicts_the_stalest_entry_at_capacity() {
        let shapes = [
            (8, MaskKind::None),
            (16, MaskKind::None),
            (24, MaskKind::None),
        ];
        let mut c = ProgramCache::new(2);
        let mut build = |c: &mut ProgramCache, i: usize| {
            let (p, layout, key) = key_for(shapes[i].0, shapes[i].1);
            c.get_or_build(key, || {
                flash_chunk_program(&p, &layout).map(Some).map_err(|e| format!("{e:#}"))
            })
            .unwrap()
        };
        build(&mut c, 0);
        build(&mut c, 1);
        build(&mut c, 0); // refresh 0 so 1 is now the LRU
        build(&mut c, 2); // evicts 1
        assert_eq!(c.len(), 2);
        build(&mut c, 0); // still resident
        assert_eq!(c.hits, 2);
        build(&mut c, 1); // evicted: rebuilds
        assert_eq!((c.hits, c.misses), (2, 4));
    }

    #[test]
    fn fully_masked_partial_block_memoizes_none() {
        // Causal chunk whose keys [8, 16) all exceed block 0's query
        // rows 0..8 — the builder reports no live tiles.
        let p = ChunkParams::chunk(8, 16, MaskKind::Causal, 8, 8, 16);
        let layout = ChunkLayout::packed(&p);
        let key = ProgKey::new(&p, &layout, Some(0));
        let mut c = ProgramCache::new(8);
        let first = c
            .get_or_build(key, || {
                flash_chunk_partial_program(&p, &layout, 0).map_err(|e| format!("{e:#}"))
            })
            .unwrap();
        assert!(first.is_none());
        let second = c
            .get_or_build(key, || -> Result<_, String> { panic!("memoized None must hit") })
            .unwrap();
        assert!(second.is_none());
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn build_errors_are_not_cached() {
        let (p, layout, key) = key_for(16, MaskKind::Causal);
        let mut c = ProgramCache::new(8);
        let err = c.get_or_build(key, || Err::<Option<Program>, _>("transient".to_string()));
        assert!(err.is_err());
        assert_eq!(c.len(), 0);
        let ok = c
            .get_or_build(key, || {
                flash_chunk_program(&p, &layout).map(Some).map_err(|e| format!("{e:#}"))
            })
            .unwrap();
        assert!(ok.is_some());
        assert_eq!((c.hits, c.misses), (0, 2));
    }
}
