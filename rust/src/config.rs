//! Config system: a small INI-style parser (no serde in the offline env)
//! plus the typed accelerator / run configurations.
//!
//! `configs/*.ini` ships the three Table-1 machines (fsa, tpuv5e,
//! neuron-v2); `AccelConfig::builtin` carries the same data compiled-in so
//! the binary also works without the files.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use anyhow::{anyhow, bail, ensure, Context};

use crate::coordinator::trace::TraceLevel;
use crate::mask::MaskKind;

/// Parsed INI document: section -> key -> value (last write wins).
#[derive(Clone, Debug, Default)]
pub struct Ini {
    pub sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Ini {
    pub fn parse(text: &str) -> crate::Result<Ini> {
        let mut doc = Ini::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: unterminated section header: {raw:?}", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                doc.sections.entry(section.clone()).or_default();
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim().to_string();
                let val = line[eq + 1..].trim().to_string();
                if key.is_empty() {
                    bail!("line {}: empty key", lineno + 1);
                }
                doc.sections.entry(section.clone()).or_default().insert(key, val);
            } else {
                bail!("line {}: expected `key = value` or `[section]`, got {raw:?}", lineno + 1);
            }
        }
        Ok(doc)
    }

    pub fn load(path: &Path) -> crate::Result<Ini> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, section: &str, key: &str) -> crate::Result<Option<T>>
    where
        T::Err: fmt::Display,
    {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("[{section}] {key} = {v:?}: {e}")),
        }
    }
}

/// Strip an INI comment: `#`/`;` starts a comment only at the start of
/// the line or after whitespace, so values that legitimately contain
/// them (paths, `artifact_dir = runs#3`) survive.  (The old
/// split-at-first-occurrence corrupted such values.)
fn strip_comment(raw: &str) -> &str {
    let mut prev_is_ws = true;
    for (i, ch) in raw.char_indices() {
        if (ch == '#' || ch == ';') && prev_is_ws {
            return &raw[..i];
        }
        prev_is_ws = ch.is_whitespace();
    }
    raw
}

/// Parse an on/off switch value (`--prefix-cache on|off` and the INI
/// key of the same name); also takes the usual boolean spellings.
pub fn parse_on_off(v: &str) -> Option<bool> {
    match v {
        "on" | "true" | "1" | "yes" => Some(true),
        "off" | "false" | "0" | "no" => Some(false),
        _ => None,
    }
}

/// Vector/scalar unit description for baseline machines (paper Fig. 1 &
/// §2.3: softmax runs on these and they are the bottleneck).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VectorUnit {
    /// Elementwise FLOPs per cycle (vector engine).
    pub vector_flops_per_cycle: f64,
    /// Special-function (exp) ops per cycle (scalar/activation engine).
    pub scalar_flops_per_cycle: f64,
}

/// One accelerator, Table 1 of the paper.
#[derive(Clone, Debug, PartialEq)]
pub struct AccelConfig {
    pub name: String,
    /// Systolic array dimension (square, N x N).
    pub array_size: usize,
    /// Number of independent arrays.
    pub num_arrays: usize,
    /// Clock in GHz.
    pub freq_ghz: f64,
    /// HBM/DDR bandwidth in GB/s.
    pub mem_bw_gbs: f64,
    /// Scratchpad SRAM bytes.
    pub spad_bytes: u64,
    /// Accumulation SRAM bytes.
    pub accum_bytes: u64,
    /// Present only on machines that need an external vector unit.
    pub vector_unit: Option<VectorUnit>,
    /// FSA only: PWL segments for exp2.
    pub pwl_segments: usize,
}

impl AccelConfig {
    /// Peak MAC-only TFLOPs/s (2 FLOPs per MAC per PE per cycle).
    ///
    /// Note: paper Table 1 lists FSA at 32.77 TFLOPs/s, which corresponds
    /// to 1.0 GHz even though the text simulates FSA at 1.5 GHz; the
    /// *utilization* metric of Fig. 11 is frequency-invariant, so we keep
    /// the self-consistent 2*N^2*f formula (49.15 TFLOPs at 1.5 GHz).
    pub fn peak_tflops(&self) -> f64 {
        let n = self.array_size as f64;
        2.0 * n * n * self.num_arrays as f64 * self.freq_ghz * 1e9 / 1e12
    }

    /// Memory bandwidth in bytes per clock cycle.
    pub fn bytes_per_cycle(&self) -> f64 {
        self.mem_bw_gbs * 1e9 / (self.freq_ghz * 1e9)
    }

    /// The three Table-1 machines.
    pub fn builtin(name: &str) -> crate::Result<AccelConfig> {
        let cfg = match name {
            // FSA: 128x128 @1.5GHz, 192KiB spad (double-buffered QKV
            // tiles), 64KiB accumulation SRAM, no vector unit.
            "fsa" => AccelConfig {
                name: "fsa".into(),
                array_size: 128,
                num_arrays: 1,
                freq_ghz: 1.5,
                mem_bw_gbs: 820.0,
                spad_bytes: 192 * 1024,
                accum_bytes: 64 * 1024,
                vector_unit: None,
                pwl_segments: 8,
            },
            // TPUv5e: 4 arrays of 128x128, 1.5GHz (inferred from 196.6
            // TFLOPs), 48MiB scratchpad, vector unit present. VPU
            // throughput modeled as 8x128x2 lanes.
            "tpuv5e" => AccelConfig {
                name: "tpuv5e".into(),
                array_size: 128,
                num_arrays: 4,
                freq_ghz: 1.5,
                mem_bw_gbs: 819.0,
                spad_bytes: 48 * 1024 * 1024,
                accum_bytes: 16 * 1024 * 1024,
                vector_unit: Some(VectorUnit {
                    vector_flops_per_cycle: 2048.0,
                    scalar_flops_per_cycle: 1024.0,
                }),
                pwl_segments: 0,
            },
            // NeuronCore-v2: one 128x128 array @2.8GHz, 24MiB SBUF, 2MiB
            // PSUM; vector + scalar (activation) engines (128-lane class).
            "neuron-v2" => AccelConfig {
                name: "neuron-v2".into(),
                array_size: 128,
                num_arrays: 1,
                freq_ghz: 2.8,
                mem_bw_gbs: 820.0,
                spad_bytes: 24 * 1024 * 1024,
                accum_bytes: 2 * 1024 * 1024,
                vector_unit: Some(VectorUnit {
                    vector_flops_per_cycle: 256.0,
                    scalar_flops_per_cycle: 128.0,
                }),
                pwl_segments: 0,
            },
            other => bail!("unknown builtin accelerator {other:?} (try fsa|tpuv5e|neuron-v2)"),
        };
        Ok(cfg)
    }

    /// Load from an INI file's `[accelerator]` section, with builtin
    /// defaults taken from `base = <builtin-name>` when present.
    pub fn from_ini(ini: &Ini) -> crate::Result<AccelConfig> {
        let sec = "accelerator";
        let mut cfg = match ini.get(sec, "base") {
            Some(base) => Self::builtin(base)?,
            None => Self::builtin("fsa")?,
        };
        if let Some(name) = ini.get(sec, "name") {
            cfg.name = name.to_string();
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "array_size")? {
            cfg.array_size = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "num_arrays")? {
            cfg.num_arrays = v;
        }
        if let Some(v) = ini.get_parsed::<f64>(sec, "freq_ghz")? {
            cfg.freq_ghz = v;
        }
        if let Some(v) = ini.get_parsed::<f64>(sec, "mem_bw_gbs")? {
            cfg.mem_bw_gbs = v;
        }
        if let Some(v) = ini.get_parsed::<u64>(sec, "spad_kib")? {
            cfg.spad_bytes = v * 1024;
        }
        if let Some(v) = ini.get_parsed::<u64>(sec, "accum_kib")? {
            cfg.accum_bytes = v * 1024;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "pwl_segments")? {
            cfg.pwl_segments = v;
        }
        if let Some(v) = ini.get_parsed::<f64>(sec, "vector_flops_per_cycle")? {
            let scalar = ini
                .get_parsed::<f64>(sec, "scalar_flops_per_cycle")?
                .unwrap_or(v / 2.0);
            cfg.vector_unit = Some(VectorUnit {
                vector_flops_per_cycle: v,
                scalar_flops_per_cycle: scalar,
            });
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> crate::Result<()> {
        if self.array_size == 0 || !self.array_size.is_power_of_two() {
            bail!("array_size must be a nonzero power of two, got {}", self.array_size);
        }
        if self.num_arrays == 0 {
            bail!("num_arrays must be >= 1");
        }
        if self.freq_ghz <= 0.0 || self.mem_bw_gbs <= 0.0 {
            bail!("freq/bandwidth must be positive");
        }
        Ok(())
    }
}

/// Which numerics engine the device workers execute heads on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT execution of the AOT Pallas artifacts; requires an
    /// artifacts manifest and the real `xla` bindings.  The strict
    /// default: identical behavior to the pre-multi-head coordinator.
    #[default]
    Pjrt,
    /// In-crate `flash_pwl` reference numerics (the device's software
    /// twin); no artifacts or PJRT needed.  Exact sequence lengths only.
    Reference,
    /// The cycle-accurate simulator as the execution engine
    /// (DESIGN.md §8): shards compile to ISA programs and run on
    /// `sim::Machine`, bitwise-equal to the reference twin, priced by
    /// *measured* cycles.  O(L²·N) PE-steps per shard — guarded by
    /// [`RunConfig::sim_max_seq`].
    Sim,
    /// PJRT when the artifacts manifest is present, reference
    /// otherwise.
    Auto,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> crate::Result<BackendKind> {
        match s {
            "pjrt" => Ok(BackendKind::Pjrt),
            "reference" | "ref" => Ok(BackendKind::Reference),
            "sim" => Ok(BackendKind::Sim),
            "auto" => Ok(BackendKind::Auto),
            other => bail!("unknown backend {other:?} (try pjrt|reference|sim|auto)"),
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Reference => "reference",
            BackendKind::Sim => "sim",
            BackendKind::Auto => "auto",
        })
    }
}

/// What a device's KV cache does under capacity pressure
/// (DESIGN.md §5).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Reap closed sessions, then evict whole least-recently-used
    /// streams; evicted streams fall back to recompute and may be
    /// re-placed.
    #[default]
    Lru,
    /// Never evict: streams that do not fit are rejected and recompute
    /// on every step (the no-cache-reuse baseline).
    None,
}

impl std::str::FromStr for EvictionPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> crate::Result<EvictionPolicy> {
        match s {
            "lru" => Ok(EvictionPolicy::Lru),
            "none" | "off" => Ok(EvictionPolicy::None),
            other => bail!("unknown eviction policy {other:?} (try lru|none)"),
        }
    }
}

impl fmt::Display for EvictionPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            EvictionPolicy::Lru => "lru",
            EvictionPolicy::None => "none",
        })
    }
}

/// Serving-run parameters (coordinator + e2e example).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub devices: usize,
    /// Batch size limit in *head shards*, not requests.
    pub max_batch: usize,
    pub batch_timeout_cycles: u64,
    pub queue_depth: usize,
    /// Continuous-scheduler prefill budget (DESIGN.md §10): the most
    /// prefill-class tokens (stateless + prefill `seq_len`s) one
    /// scheduler wave admits.  A single request above this cap is
    /// rejected outright with an error naming the knob; requests that
    /// only exceed it in aggregate wait their turn.
    pub max_batch_prefill_tokens: usize,
    /// Continuous-scheduler total-token budget (DESIGN.md §10): live
    /// session tokens (Σ open-session prefix lengths) plus this wave's
    /// admitted prefill-class tokens must stay at or under this cap.
    /// A request above it even against an empty pool is rejected;
    /// otherwise it waits for sessions to close.  Decode steps and
    /// closes are exempt — they shrink or bound live state.
    pub max_batch_total_tokens: usize,
    /// Continuous-scheduler prefill-vs-decode knob (DESIGN.md §10,
    /// TGI's `waiting_served_ratio`): with decode traffic runnable, a
    /// fresh prefill is admitted only when waiting prefill tokens ≥
    /// this ratio × live session tokens (or the oldest prefill has
    /// waited a full batch timeout — the starvation bound).  `0.0`
    /// disables deferral: prefills are admitted whenever the token
    /// budgets allow.
    pub waiting_served_ratio: f64,
    pub artifacts_dir: String,
    /// Numerics engine for the device workers.
    pub backend: BackendKind,
    /// Default query-head count for synthetic workloads (`fsa serve`,
    /// examples); per-request values always win.
    pub num_heads: usize,
    /// Default KV-head count for synthetic workloads; must divide
    /// `num_heads`.
    pub num_kv_heads: usize,
    /// Per-device KV-cache capacity in pages (decode-phase serving).
    /// At the defaults (4096 pages × 16 tokens × d=128 × 2 (K+V) ×
    /// 2 B fp16 = 33,554,432 B) this models 32 MiB of device HBM set
    /// aside for KV.
    pub kv_cache_pages: usize,
    /// Tokens per KV-cache page.
    pub kv_page_size: usize,
    /// Eviction policy of the per-device KV caches.
    pub kv_eviction: EvictionPolicy,
    /// Cross-session prefix caching (DESIGN.md §11): at admission the
    /// scheduler hash-walks each prefill against the live sessions'
    /// indexed prefixes (content-chained per `kv_page_size`-token
    /// block, then byte-verified) and, on a hit, stamps the request to
    /// resume from the first uncovered row — devices compute only the
    /// suffix (bitwise the cold run's suffix rows), the covered tokens
    /// stop competing for prefill budget, and the warm session's shards
    /// adopt the donor's placement so shared pages attach by refcount
    /// instead of copying.  Off by default: a resumed response carries
    /// only the suffix query rows (`stats.prefix_reused_tokens` says
    /// how many were skipped), which callers must opt into.  Requires a
    /// resumed-prefill-capable backend (reference|sim).
    pub prefix_cache: bool,
    /// Mask the *drivers* (`fsa serve --mask`, examples, benches) stamp
    /// onto the synthetic requests they generate.  This is a
    /// driver-side convenience only: the coordinator itself never
    /// applies it — a request is served with exactly the mask it
    /// carries (`AttentionRequest::with_mask`), and library callers
    /// must stamp their own.  `causal` is transformer prefill; padding
    /// masks are stamped per request by `AttentionRequest::padded`,
    /// not configured here.
    pub mask: MaskKind,
    /// Simulated device clock in GHz: converts `batch_timeout_cycles`
    /// to host time and prices device seconds.  Defaults to the paper's
    /// 1.5 GHz FSA clock (the batcher used to hard-code it, silently
    /// flushing batches early for any other configured clock).
    pub freq_ghz: f64,
    /// Sequence-parallel shard count (DESIGN.md §7): split every
    /// request's K/V into this many contiguous chunks, execute each
    /// chunk's partial `(O~, m, l)` on its own device, and merge the
    /// partials in chunk order at gather.  `1` (the default) is the
    /// legacy whole-sequence path, bit for bit.  Values `> 1` require
    /// the reference or sim backend (the AOT artifacts emit no partial
    /// state).
    pub seq_shards: usize,
    /// Longest `seq_len` a `backend=sim` pool admits (DESIGN.md §8).
    /// The cycle model is O(L²·N) PE-steps per head shard, so long
    /// requests (and decode steps whose *grown prefix* has reached the
    /// guard; each step runs a decode-row program over the whole
    /// prefix) are rejected at admission with an error naming this knob
    /// (`[run] sim_max_seq` / `--sim-max-seq`) instead of wedging a
    /// worker for minutes.  The vectorized array (DESIGN.md §8's SoA
    /// waves + shard batching) moved the default from 1024 to 8192 at
    /// N = 128.  Ignored by every other backend.
    pub sim_max_seq: usize,
    /// How many independent sim-backend shards share one machine
    /// between [`hazard fences`](crate::sim::Machine::reset_for_reuse)
    /// (DESIGN.md §8): the fence zeroes every memory and register, so a
    /// batched run is bitwise and cycle-for-cycle identical to fresh
    /// machines while skipping the per-shard allocations.  `1` disables
    /// reuse.  Ignored by every other backend.
    pub sim_batch_shards: usize,
    /// Compiled ISA-program cache entries per sim backend (DESIGN.md
    /// §12): programs are pure functions of their shape/mask/layout
    /// key, so a hit replays the identical text and skips the per-shard
    /// rebuild — host time only, never served bits or measured cycles.
    /// `0` disables caching (the recompilation twin the differential
    /// tests pin against).  Ignored by every other backend.
    pub sim_prog_cache: usize,
    /// Array dimension of the simulated devices (tiling for the
    /// reference backend, machine size for the sim backend, tile census
    /// for pricing).  Defaults to the paper's 128; tests shrink it so
    /// the cycle-accurate backend runs in milliseconds.  Must be a
    /// power of two (`AccelConfig::validate`'s rule).
    pub array_size: usize,
    /// Request-path tracing level (DESIGN.md §9): `off` (the default;
    /// the record call is a single branch), `summary` (per-kind event
    /// counts), or `full` (counts plus a ring of the last 4096 events).
    /// Tracing never changes served bits — asserted end to end by
    /// `rust/tests/coordinator_trace.rs`.
    pub trace: TraceLevel,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            devices: 2,
            max_batch: 8,
            batch_timeout_cycles: 200_000,
            queue_depth: 1024,
            max_batch_prefill_tokens: 8192,
            max_batch_total_tokens: 65536,
            waiting_served_ratio: 1.2,
            artifacts_dir: "artifacts".into(),
            backend: BackendKind::Pjrt,
            num_heads: 1,
            num_kv_heads: 1,
            kv_cache_pages: 4096,
            kv_page_size: 16,
            kv_eviction: EvictionPolicy::Lru,
            prefix_cache: false,
            mask: MaskKind::None,
            freq_ghz: 1.5,
            seq_shards: 1,
            sim_max_seq: 8192,
            sim_batch_shards: 8,
            sim_prog_cache: 256,
            array_size: 128,
            trace: TraceLevel::Off,
        }
    }
}

impl RunConfig {
    /// Cross-field invariants, checked wherever a `RunConfig` enters
    /// the system (INI load, `Coordinator::start`) so the GQA
    /// divisibility rule lives in exactly one place.
    pub fn validate(&self) -> crate::Result<()> {
        ensure!(self.devices >= 1, "need at least one device");
        ensure!(
            self.num_heads >= 1
                && self.num_kv_heads >= 1
                && self.num_heads % self.num_kv_heads == 0,
            "num_heads {} must be a positive multiple of num_kv_heads {}",
            self.num_heads,
            self.num_kv_heads
        );
        ensure!(
            self.kv_cache_pages >= 1 && self.kv_page_size >= 1,
            "kv_cache_pages ({}) and kv_page_size ({}) must be >= 1",
            self.kv_cache_pages,
            self.kv_page_size
        );
        ensure!(
            self.freq_ghz > 0.0,
            "freq_ghz must be positive, got {}",
            self.freq_ghz
        );
        ensure!(
            self.max_batch_prefill_tokens >= 1,
            "max_batch_prefill_tokens must be >= 1, got {}",
            self.max_batch_prefill_tokens
        );
        ensure!(
            self.max_batch_total_tokens >= self.max_batch_prefill_tokens,
            "max_batch_total_tokens ({}) must be >= max_batch_prefill_tokens ({}) \
             — a wave the prefill budget admits must fit the total budget",
            self.max_batch_total_tokens,
            self.max_batch_prefill_tokens
        );
        ensure!(
            self.waiting_served_ratio.is_finite() && self.waiting_served_ratio >= 0.0,
            "waiting_served_ratio must be finite and >= 0, got {}",
            self.waiting_served_ratio
        );
        ensure!(
            self.seq_shards >= 1,
            "seq_shards must be >= 1, got {}",
            self.seq_shards
        );
        ensure!(
            !(self.prefix_cache && self.backend == BackendKind::Pjrt),
            "prefix_cache requires a resumed-prefill-capable backend \
             (reference|sim|auto): the AOT PJRT artifacts have no resumed \
             kind (DESIGN.md §11)"
        );
        ensure!(
            self.sim_max_seq >= 1,
            "sim_max_seq must be >= 1, got {}",
            self.sim_max_seq
        );
        ensure!(
            self.sim_batch_shards >= 1,
            "sim_batch_shards must be >= 1, got {}",
            self.sim_batch_shards
        );
        ensure!(
            self.array_size >= 2 && self.array_size.is_power_of_two(),
            "array_size must be a power of two >= 2, got {}",
            self.array_size
        );
        Ok(())
    }

    pub fn from_ini(ini: &Ini) -> crate::Result<RunConfig> {
        let sec = "run";
        let mut cfg = RunConfig::default();
        if let Some(v) = ini.get_parsed::<usize>(sec, "devices")? {
            cfg.devices = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "max_batch")? {
            cfg.max_batch = v;
        }
        if let Some(v) = ini.get_parsed::<u64>(sec, "batch_timeout_cycles")? {
            cfg.batch_timeout_cycles = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "queue_depth")? {
            cfg.queue_depth = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "max_batch_prefill_tokens")? {
            cfg.max_batch_prefill_tokens = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "max_batch_total_tokens")? {
            cfg.max_batch_total_tokens = v;
        }
        if let Some(v) = ini.get_parsed::<f64>(sec, "waiting_served_ratio")? {
            cfg.waiting_served_ratio = v;
        }
        if let Some(v) = ini.get(sec, "artifacts_dir") {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = ini.get_parsed::<BackendKind>(sec, "backend")? {
            cfg.backend = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "num_heads")? {
            cfg.num_heads = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "num_kv_heads")? {
            cfg.num_kv_heads = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "kv_cache_pages")? {
            cfg.kv_cache_pages = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "kv_page_size")? {
            cfg.kv_page_size = v;
        }
        if let Some(v) = ini.get_parsed::<EvictionPolicy>(sec, "kv_eviction")? {
            cfg.kv_eviction = v;
        }
        if let Some(v) = ini.get(sec, "prefix_cache") {
            cfg.prefix_cache = parse_on_off(v)
                .ok_or_else(|| anyhow!("[run] prefix_cache = {v:?}: expected on|off"))?;
        }
        if let Some(v) = ini.get_parsed::<MaskKind>(sec, "mask")? {
            cfg.mask = v;
        }
        if let Some(v) = ini.get_parsed::<f64>(sec, "freq_ghz")? {
            cfg.freq_ghz = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "seq_shards")? {
            cfg.seq_shards = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "sim_max_seq")? {
            cfg.sim_max_seq = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "sim_batch_shards")? {
            cfg.sim_batch_shards = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "sim_prog_cache")? {
            cfg.sim_prog_cache = v;
        }
        if let Some(v) = ini.get_parsed::<usize>(sec, "array_size")? {
            cfg.array_size = v;
        }
        if let Some(v) = ini.get_parsed::<TraceLevel>(sec, "trace")? {
            cfg.trace = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_comments_and_overrides() {
        let text = "\n# comment\n[accelerator]\nbase = fsa\narray_size = 64 ; inline\nfreq_ghz = 2.0\n\n[run]\ndevices = 4\n";
        let ini = Ini::parse(text).unwrap();
        assert_eq!(ini.get("accelerator", "base"), Some("fsa"));
        let cfg = AccelConfig::from_ini(&ini).unwrap();
        assert_eq!(cfg.array_size, 64);
        assert_eq!(cfg.freq_ghz, 2.0);
        assert_eq!(cfg.pwl_segments, 8); // inherited from base
        let run = RunConfig::from_ini(&ini).unwrap();
        assert_eq!(run.devices, 4);
        assert_eq!(run.max_batch, 8); // default
        assert_eq!(run.backend, BackendKind::Pjrt); // default
        assert_eq!(run.num_heads, 1); // default
    }

    #[test]
    fn run_config_head_and_backend_knobs() {
        let text = "[run]\nbackend = reference\nnum_heads = 8\nnum_kv_heads = 2\n";
        let run = RunConfig::from_ini(&Ini::parse(text).unwrap()).unwrap();
        assert_eq!(run.backend, BackendKind::Reference);
        assert_eq!(run.num_heads, 8);
        assert_eq!(run.num_kv_heads, 2);
        assert_eq!("auto".parse::<BackendKind>().unwrap(), BackendKind::Auto);
        assert!("gpu".parse::<BackendKind>().is_err());
        // GQA divisibility is validated at config load.
        let bad = "[run]\nnum_heads = 3\nnum_kv_heads = 2\n";
        assert!(RunConfig::from_ini(&Ini::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn run_config_kv_cache_knobs() {
        let text = "[run]\nkv_cache_pages = 64\nkv_page_size = 8\nkv_eviction = none\n";
        let run = RunConfig::from_ini(&Ini::parse(text).unwrap()).unwrap();
        assert_eq!(run.kv_cache_pages, 64);
        assert_eq!(run.kv_page_size, 8);
        assert_eq!(run.kv_eviction, EvictionPolicy::None);
        // Defaults: LRU over 4096 x 16-token pages.
        let dflt = RunConfig::default();
        assert_eq!(dflt.kv_eviction, EvictionPolicy::Lru);
        assert_eq!((dflt.kv_cache_pages, dflt.kv_page_size), (4096, 16));
        assert_eq!("lru".parse::<EvictionPolicy>().unwrap(), EvictionPolicy::Lru);
        assert_eq!("off".parse::<EvictionPolicy>().unwrap(), EvictionPolicy::None);
        assert!("fifo".parse::<EvictionPolicy>().is_err());
        assert_eq!(EvictionPolicy::Lru.to_string(), "lru");
        // Zero-size caches are rejected at load.
        let bad = "[run]\nkv_cache_pages = 0\n";
        assert!(RunConfig::from_ini(&Ini::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn run_config_prefix_cache_knob() {
        // Satellite: the prefix-cache switch is INI-plumbed, off by
        // default, and refused on the resumed-incapable PJRT backend.
        let on = "[run]\nbackend = reference\nprefix_cache = on\n";
        let run = RunConfig::from_ini(&Ini::parse(on).unwrap()).unwrap();
        assert!(run.prefix_cache);
        assert!(!RunConfig::default().prefix_cache);
        assert_eq!(parse_on_off("off"), Some(false));
        assert_eq!(parse_on_off("true"), Some(true));
        assert_eq!(parse_on_off("maybe"), None);
        let bad = "[run]\nprefix_cache = maybe\n";
        assert!(RunConfig::from_ini(&Ini::parse(bad).unwrap()).is_err());
        // backend = pjrt (the default) has no resumed artifact kind.
        let pjrt = "[run]\nprefix_cache = on\n";
        let err = RunConfig::from_ini(&Ini::parse(pjrt).unwrap()).unwrap_err();
        assert!(err.to_string().contains("prefix_cache"), "{err}");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Ini::parse("[unterminated\n").is_err());
        assert!(Ini::parse("novalue\n").is_err());
        assert!(Ini::parse("= empty\n").is_err());
    }

    #[test]
    fn comment_markers_inside_values_survive() {
        // Regression (satellite): `#`/`;` only open a comment at line
        // start or after whitespace — values containing them are legal.
        let text = "[run]\nartifacts_dir = runs#3\npath = a;b#c\n";
        let ini = Ini::parse(text).unwrap();
        assert_eq!(ini.get("run", "artifacts_dir"), Some("runs#3"));
        assert_eq!(ini.get("run", "path"), Some("a;b#c"));
    }

    #[test]
    fn trailing_and_full_line_comments_still_work() {
        let text = "# leading\n  ; indented comment\n[run]\ndevices = 4 # trailing\nmax_batch = 2 ; semi\n";
        let ini = Ini::parse(text).unwrap();
        assert_eq!(ini.get("run", "devices"), Some("4"));
        assert_eq!(ini.get("run", "max_batch"), Some("2"));
    }

    #[test]
    fn run_config_mask_and_freq_knobs() {
        let text = "[run]\nmask = causal\nfreq_ghz = 1.0\n";
        let run = RunConfig::from_ini(&Ini::parse(text).unwrap()).unwrap();
        assert_eq!(run.mask, MaskKind::Causal);
        assert_eq!(run.freq_ghz, 1.0);
        // Defaults: unmasked at the paper's 1.5 GHz.
        let dflt = RunConfig::default();
        assert_eq!(dflt.mask, MaskKind::None);
        assert_eq!(dflt.freq_ghz, 1.5);
        // Bad values are rejected at load.
        assert!(RunConfig::from_ini(&Ini::parse("[run]\nmask = diag\n").unwrap()).is_err());
        assert!(RunConfig::from_ini(&Ini::parse("[run]\nfreq_ghz = 0\n").unwrap()).is_err());
    }

    #[test]
    fn run_config_sim_backend_knobs() {
        // Satellite: the sim backend parses, and the O(L²) guard plus
        // the device array dim are INI-plumbed and validated.
        let text = "[run]\nbackend = sim\nsim_max_seq = 256\nsim_batch_shards = 4\n\
                    sim_prog_cache = 64\narray_size = 32\n";
        let run = RunConfig::from_ini(&Ini::parse(text).unwrap()).unwrap();
        assert_eq!(run.backend, BackendKind::Sim);
        assert_eq!(run.sim_max_seq, 256);
        assert_eq!(run.sim_batch_shards, 4);
        assert_eq!(run.sim_prog_cache, 64);
        assert_eq!(run.array_size, 32);
        // 0 is a legal value: it disables the program cache.
        let off = RunConfig::from_ini(&Ini::parse("[run]\nsim_prog_cache = 0\n").unwrap()).unwrap();
        assert_eq!(off.sim_prog_cache, 0);
        assert_eq!("sim".parse::<BackendKind>().unwrap(), BackendKind::Sim);
        assert_eq!(BackendKind::Sim.to_string(), "sim");
        // Defaults: 8192-token guard (the vectorized array's budget) on
        // the paper's 128-array, 8 shards per machine between fences.
        let dflt = RunConfig::default();
        assert_eq!((dflt.sim_max_seq, dflt.array_size), (8192, 128));
        assert_eq!(dflt.sim_batch_shards, 8);
        assert_eq!(dflt.sim_prog_cache, 256);
        // Degenerate values are rejected at load.
        assert!(RunConfig::from_ini(&Ini::parse("[run]\nsim_max_seq = 0\n").unwrap()).is_err());
        assert!(
            RunConfig::from_ini(&Ini::parse("[run]\nsim_batch_shards = 0\n").unwrap()).is_err()
        );
        assert!(RunConfig::from_ini(&Ini::parse("[run]\narray_size = 48\n").unwrap()).is_err());
        assert!(RunConfig::from_ini(&Ini::parse("[run]\narray_size = 1\n").unwrap()).is_err());
    }

    #[test]
    fn run_config_continuous_scheduler_knobs() {
        // Satellite: the continuous-batching budgets are INI-plumbed
        // and validated (DESIGN.md §10).
        let text = "[run]\nmax_batch_prefill_tokens = 512\n\
                    max_batch_total_tokens = 2048\nwaiting_served_ratio = 0.5\n";
        let run = RunConfig::from_ini(&Ini::parse(text).unwrap()).unwrap();
        assert_eq!(run.max_batch_prefill_tokens, 512);
        assert_eq!(run.max_batch_total_tokens, 2048);
        assert_eq!(run.waiting_served_ratio, 0.5);
        // Defaults: TGI-shaped budgets, ratio 1.2.
        let dflt = RunConfig::default();
        assert_eq!(dflt.max_batch_prefill_tokens, 8192);
        assert_eq!(dflt.max_batch_total_tokens, 65536);
        assert_eq!(dflt.waiting_served_ratio, 1.2);
        // Degenerate values are rejected at load: zero prefill budget,
        // total below prefill, negative or non-finite ratio.
        assert!(RunConfig::from_ini(
            &Ini::parse("[run]\nmax_batch_prefill_tokens = 0\n").unwrap()
        )
        .is_err());
        assert!(RunConfig::from_ini(
            &Ini::parse("[run]\nmax_batch_total_tokens = 100\n").unwrap()
        )
        .is_err());
        assert!(RunConfig::from_ini(
            &Ini::parse("[run]\nwaiting_served_ratio = -1\n").unwrap()
        )
        .is_err());
        assert!(RunConfig::from_ini(
            &Ini::parse("[run]\nwaiting_served_ratio = inf\n").unwrap()
        )
        .is_err());
        // Ratio 0 is legal: it disables prefill deferral entirely.
        let run = RunConfig::from_ini(
            &Ini::parse("[run]\nwaiting_served_ratio = 0\n").unwrap(),
        )
        .unwrap();
        assert_eq!(run.waiting_served_ratio, 0.0);
    }

    #[test]
    fn run_config_trace_knob() {
        let run = RunConfig::from_ini(&Ini::parse("[run]\ntrace = full\n").unwrap()).unwrap();
        assert_eq!(run.trace, TraceLevel::Full);
        let run = RunConfig::from_ini(&Ini::parse("[run]\ntrace = summary\n").unwrap()).unwrap();
        assert_eq!(run.trace, TraceLevel::Summary);
        // Default: off (zero overhead on the request path).
        assert_eq!(RunConfig::default().trace, TraceLevel::Off);
        assert!(RunConfig::from_ini(&Ini::parse("[run]\ntrace = verbose\n").unwrap()).is_err());
    }

    #[test]
    fn run_config_seq_shards_knob() {
        let run =
            RunConfig::from_ini(&Ini::parse("[run]\nseq_shards = 4\n").unwrap()).unwrap();
        assert_eq!(run.seq_shards, 4);
        // Default: the legacy whole-sequence path.
        assert_eq!(RunConfig::default().seq_shards, 1);
        // Zero shards is rejected at load.
        assert!(RunConfig::from_ini(&Ini::parse("[run]\nseq_shards = 0\n").unwrap()).is_err());
    }

    #[test]
    fn builtin_table1_numbers() {
        // Cross-check against paper Table 1 (see peak_tflops note on FSA).
        let fsa = AccelConfig::builtin("fsa").unwrap();
        assert!((fsa.peak_tflops() - 49.15).abs() < 0.1);
        let tpu = AccelConfig::builtin("tpuv5e").unwrap();
        assert!((tpu.peak_tflops() - 196.6).abs() < 0.5);
        let neuron = AccelConfig::builtin("neuron-v2").unwrap();
        assert!((neuron.peak_tflops() - 91.75).abs() < 0.5);
        assert!(AccelConfig::builtin("gpu").is_err());
    }

    #[test]
    fn validation_catches_bad_sizes() {
        let mut cfg = AccelConfig::builtin("fsa").unwrap();
        cfg.array_size = 100;
        assert!(cfg.validate().is_err());
        cfg.array_size = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn ini_file_round_trip() {
        let dir = std::env::temp_dir().join("fsa_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.ini");
        std::fs::write(&p, "[accelerator]\nbase = neuron-v2\n").unwrap();
        let cfg = AccelConfig::from_ini(&Ini::load(&p).unwrap()).unwrap();
        assert_eq!(cfg.name, "neuron-v2");
        assert!(cfg.vector_unit.is_some());
    }
}
