//! Instruction-level FSA performance model for full workloads.
//!
//! The cycle-accurate simulator ([`crate::sim`]) validates that compute
//! instructions are fully deterministic with the §3.5 latencies; this
//! model replays those latencies plus the DMA bandwidth model over whole
//! FlashAttention workloads (up to the paper's 16 K sequence length) where
//! element-wise simulation would be needless — `rust/tests` asserts both
//! agree wherever both run.
//!
//! Covers compute-bound and bandwidth-bound regimes, head dims below the
//! array size (padding waste — the §8.3 decode-phase discussion), and the
//! two dataflow variants of §8.2.

use crate::config::AccelConfig;
use crate::schedule::{attention_flops, preload_latency, rescale_latency, InnerSchedule, Variant};
use crate::sim::dma::DmaConfig;

/// Timing breakdown for one attention head on FSA.
#[derive(Clone, Copy, Debug)]
pub struct FsaPerf {
    pub total_cycles: u64,
    /// Cycles the PE array has any wave in flight.
    pub array_active_cycles: u64,
    pub dma_cycles: u64,
    /// Achieved / peak FLOPs-per-second ratio (paper §6.1 metric).
    pub utilization: f64,
    /// Wall-clock at the config's frequency.
    pub seconds: f64,
    /// True when the DMA stream, not compute, sets the iteration pace.
    pub bandwidth_bound: bool,
}

/// FlashAttention forward, one head of (seq_len, d), on an FSA machine.
///
/// Tiling follows §3.5: Br = Bc = N (the array dim); `d` is padded up to N
/// when smaller (wasted lanes counted against utilization, cf. §8.3).
pub fn fsa_flash_perf(
    cfg: &AccelConfig,
    seq_len: usize,
    d: usize,
    variant: Variant,
    segments: usize,
) -> FsaPerf {
    let n = cfg.array_size;
    assert!(d <= n, "head dim {d} exceeds array size {n}");
    let sched = InnerSchedule::new(n, variant, segments);
    let ii = sched.inner_latency();

    let t = seq_len.div_ceil(n) as u64; // row and column tiles (padded)

    // DMA traffic per inner iteration: one K tile + one V tile (Q is
    // loaded once per row block), fp16 on the wire.
    let dma = DmaConfig::for_bandwidth(cfg.mem_bw_gbs, cfg.freq_ghz, 4);
    let tile_bytes = (n * n * 2) as f64;
    let bpc = cfg.mem_bw_gbs / cfg.freq_ghz;
    let dma_per_iter = dma.setup_cycles + (2.0 * tile_bytes / bpc).ceil() as u64;

    // Double buffering: iteration pace is the slower of compute and DMA.
    let ii_eff = ii.max(dma_per_iter);
    let bandwidth_bound = dma_per_iter > ii;

    let inner = t * ii_eff;
    let outer = rescale_latency(n);
    // Q-block DMA overlaps the previous epilogue; the first fill and the
    // stationary preload are exposed once.
    let startup = preload_latency(n) + dma_per_iter + dma.setup_cycles;
    let total = t * (inner + outer) + startup;

    // Useful FLOPs pad-corrected: the array computes N-wide tiles but only
    // d lanes carry real data.
    let flops = attention_flops(seq_len, d) as f64;
    let peak_per_cycle = 2.0 * (n * n) as f64;
    let utilization = flops / (peak_per_cycle * total as f64);

    let array_active = t * t * ii + t * preload_latency(n);
    FsaPerf {
        total_cycles: total,
        array_active_cycles: array_active.min(total),
        dma_cycles: t * t * dma_per_iter,
        utilization,
        seconds: total as f64 / (cfg.freq_ghz * 1e9),
        bandwidth_bound,
    }
}

/// Achieved TFLOPs/s for a workload + perf result.
pub fn achieved_tflops(seq_len: usize, d: usize, perf: &FsaPerf) -> f64 {
    attention_flops(seq_len, d) as f64 / perf.seconds / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fsa() -> AccelConfig {
        AccelConfig::builtin("fsa").unwrap()
    }

    #[test]
    fn compute_bound_at_paper_config() {
        // 820 GB/s @1.5 GHz: 64 KiB per iteration in ~136 cycles, far
        // under the 650-cycle iteration — compute-bound, as §6.1 assumes.
        let p = fsa_flash_perf(&fsa(), 2048, 128, Variant::DualPath, 8);
        assert!(!p.bandwidth_bound);
        assert!(p.utilization > 0.3 && p.utilization < 0.4, "{}", p.utilization);
    }

    #[test]
    fn utilization_rises_with_seq_len_to_asymptote() {
        let us: Vec<f64> = [2048usize, 4096, 8192, 16384]
            .iter()
            .map(|&l| fsa_flash_perf(&fsa(), l, 128, Variant::DualPath, 8).utilization)
            .collect();
        assert!(us.windows(2).all(|w| w[1] >= w[0]), "{us:?}");
        let ceiling = 2.0 * 128.0 / (5.0 * 128.0 + 10.0);
        assert!(us[3] < ceiling && us[3] > ceiling - 0.02, "{us:?}");
    }

    #[test]
    fn single_path_variant_is_slower_but_close() {
        // §8.2: 6N+10 vs 5N+10 — about 17% more cycles at N=128.
        let dual = fsa_flash_perf(&fsa(), 8192, 128, Variant::DualPath, 8);
        let single = fsa_flash_perf(&fsa(), 8192, 128, Variant::SinglePath, 8);
        let ratio = single.total_cycles as f64 / dual.total_cycles as f64;
        assert!(ratio > 1.1 && ratio < 1.25, "ratio {ratio}");
    }

    #[test]
    fn small_head_dim_wastes_lanes() {
        // §8.3: padding to 128-wide tiles burns utilization.
        let full = fsa_flash_perf(&fsa(), 4096, 128, Variant::DualPath, 8);
        let half = fsa_flash_perf(&fsa(), 4096, 64, Variant::DualPath, 8);
        assert!((half.utilization - full.utilization / 2.0).abs() < 0.02);
    }

    #[test]
    fn bandwidth_bound_when_starved() {
        let mut cfg = fsa();
        cfg.mem_bw_gbs = 40.0; // starve the DMA
        let p = fsa_flash_perf(&cfg, 4096, 128, Variant::DualPath, 8);
        assert!(p.bandwidth_bound);
        assert!(p.utilization < 0.3);
    }

    #[test]
    fn tflops_consistent_with_utilization() {
        let cfg = fsa();
        let p = fsa_flash_perf(&cfg, 8192, 128, Variant::DualPath, 8);
        let t = achieved_tflops(8192, 128, &p);
        assert!((t / cfg.peak_tflops() - p.utilization).abs() < 1e-9);
    }
}
