//! Instruction-level FSA performance model for full workloads.
//!
//! The cycle-accurate simulator ([`crate::sim`]) validates that compute
//! instructions are fully deterministic with the §3.5 latencies; this
//! model replays those latencies plus the DMA bandwidth model over whole
//! FlashAttention workloads (up to the paper's 16 K sequence length) where
//! element-wise simulation would be needless — `rust/tests` asserts both
//! agree wherever both run.
//!
//! Covers compute-bound and bandwidth-bound regimes, head dims below the
//! array size (padding waste — the §8.3 decode-phase discussion), and the
//! two dataflow variants of §8.2.

use crate::config::AccelConfig;
use crate::mask::MaskKind;
use crate::schedule::{
    attention_flops, decode_attention_flops, live_chunk_ranges, masked_attention_flops,
    masked_attention_flops_range, masked_attention_flops_resumed, masked_tile_counts,
    masked_tile_counts_range, masked_tile_counts_resumed, preload_latency, rescale_latency,
    InnerSchedule, Variant,
};
use crate::sim::dma::DmaConfig;

/// Timing breakdown for one attention head on FSA.
#[derive(Clone, Copy, Debug)]
pub struct FsaPerf {
    pub total_cycles: u64,
    /// Cycles the PE array has any wave in flight.
    pub array_active_cycles: u64,
    pub dma_cycles: u64,
    /// Achieved / peak FLOPs-per-second ratio (paper §6.1 metric).
    pub utilization: f64,
    /// Wall-clock at the config's frequency.
    pub seconds: f64,
    /// True when the DMA stream, not compute, sets the iteration pace.
    pub bandwidth_bound: bool,
}

/// FlashAttention forward, one head of (seq_len, d), on an FSA machine.
///
/// Tiling follows §3.5: Br = Bc = N (the array dim); `d` is padded up to N
/// when smaller (wasted lanes counted against utilization, cf. §8.3).
pub fn fsa_flash_perf(
    cfg: &AccelConfig,
    seq_len: usize,
    d: usize,
    variant: Variant,
    segments: usize,
) -> FsaPerf {
    fsa_flash_perf_masked(cfg, seq_len, d, variant, segments, MaskKind::None)
}

/// Masked [`fsa_flash_perf`]: the tile-skipping schedule prices only the
/// tiles actually issued ([`masked_tile_counts`]) — fully-masked tiles
/// cost nothing (their K/V tiles are never fetched either), partially
/// masked tiles (causal diagonal, padding boundary) take the one-cycle
/// element-wise mask wave ([`InnerSchedule::masked_inner_latency`]).
/// For causal this is ≈2× fewer tile-cycles than square attention at the
/// same L (asserted by the unit tests), matching the ≈2× FLOP reduction,
/// so utilization stays in the same band.  `MaskKind::None` is exactly
/// [`fsa_flash_perf`].
pub fn fsa_flash_perf_masked(
    cfg: &AccelConfig,
    seq_len: usize,
    d: usize,
    variant: Variant,
    segments: usize,
    mask: MaskKind,
) -> FsaPerf {
    let n = cfg.array_size;
    assert!(d <= n, "head dim {d} exceeds array size {n}");
    let sched = InnerSchedule::new(n, variant, segments);
    let ii = sched.inner_latency();
    let ii_masked = sched.masked_inner_latency();

    let t = seq_len.div_ceil(n) as u64; // row and column tiles (padded)
    let (full, partial, _skipped) = masked_tile_counts(seq_len, n, mask);

    // DMA traffic per issued inner iteration: one K tile + one V tile
    // (Q is loaded once per row block), fp16 on the wire.
    let dma = DmaConfig::for_bandwidth(cfg.mem_bw_gbs, cfg.freq_ghz, 4);
    let tile_bytes = (n * n * 2) as f64;
    let bpc = cfg.mem_bw_gbs / cfg.freq_ghz;
    let dma_per_iter = dma.setup_cycles + (2.0 * tile_bytes / bpc).ceil() as u64;

    // Double buffering: iteration pace is the slower of compute and DMA.
    let ii_eff = ii.max(dma_per_iter);
    let ii_masked_eff = ii_masked.max(dma_per_iter);
    let bandwidth_bound = dma_per_iter > ii;

    let inner = full * ii_eff + partial * ii_masked_eff;
    let outer = rescale_latency(n);
    // Q-block DMA overlaps the previous epilogue; the first fill and the
    // stationary preload are exposed once.
    let startup = preload_latency(n) + dma_per_iter + dma.setup_cycles;
    let total = inner + t * outer + startup;

    // Useful FLOPs mask- and pad-corrected: the array computes N-wide
    // tiles but only d lanes carry real data and only valid (query, key)
    // pairs count.
    let flops = masked_attention_flops(seq_len, d, mask) as f64;
    let peak_per_cycle = 2.0 * (n * n) as f64;
    let utilization = flops / (peak_per_cycle * total as f64);

    let array_active = full * ii + partial * ii_masked + t * preload_latency(n);
    FsaPerf {
        total_cycles: total,
        array_active_cycles: array_active.min(total),
        dma_cycles: (full + partial) * dma_per_iter,
        utilization,
        seconds: total as f64 / (cfg.freq_ghz * 1e9),
        bandwidth_bound,
    }
}

/// Achieved TFLOPs/s for a workload + perf result.
pub fn achieved_tflops(seq_len: usize, d: usize, perf: &FsaPerf) -> f64 {
    attention_flops(seq_len, d) as f64 / perf.seconds / 1e12
}

/// Timing of one decode step on one FSA device (DESIGN.md §5): a
/// single query row attending over an `L = prefix_len` token prefix.
#[derive(Clone, Copy, Debug)]
pub struct DecodePerf {
    /// Total device cycles charged to the step (`step_cycles` plus the
    /// miss-path recompute).
    pub total_cycles: u64,
    /// The one-row attention pass itself: `ceil(L/N)` column tiles at
    /// the br=1 wave latency, paced by the slower of compute and the
    /// K/V page stream (double-buffered), plus one epilogue.
    pub step_cycles: u64,
    /// Cache miss only: the full-prefix recompute charge (the upstream
    /// model re-running its forward pass over the prefix to regenerate
    /// K/V; we charge the attention share, O(L²) cycles via
    /// [`fsa_flash_perf`]).  0 on a hit.
    pub recompute_cycles: u64,
    /// DMA cycles of the one-row pass (prefix K/V stream).
    pub dma_cycles: u64,
    /// Bytes moved for the step: the O(L) fp16 K/V prefix stream plus
    /// the appended row (and, on a miss, the recompute's tile
    /// traffic).
    pub bytes_streamed: u64,
    /// Whether the step was served from KV-cache pages.
    pub cached: bool,
    /// True when the K/V stream, not the array wave, paces the tiles.
    pub bandwidth_bound: bool,
    /// Achieved/peak FLOPs/s of the step — collapses exactly as §8.3
    /// predicts (one useful row on an N-wide array).
    pub utilization: f64,
    pub seconds: f64,
}

/// One decode step for one head on FSA: `prefix_len` tokens of cached
/// context, one query row, one appended K/V row.
///
/// Cached (`cached = true`): the device streams the `O(L)` fp16 K/V
/// prefix from its pages through the array — per-step cost is linear
/// in the prefix.  Miss (`cached = false`): the full-prefix recompute
/// is charged on top (O(L²) cycles), which is the entire case for the
/// cache: the ratio `miss/hit` grows linearly with the prefix.
pub fn fsa_decode_perf(
    cfg: &AccelConfig,
    prefix_len: usize,
    d: usize,
    cached: bool,
    variant: Variant,
    segments: usize,
) -> DecodePerf {
    let n = cfg.array_size;
    assert!(d <= n, "head dim {d} exceeds array size {n}");
    assert!(prefix_len >= 1, "decode needs a non-empty prefix");
    let sched = InnerSchedule::new(n, variant, segments);
    let tile_compute = sched.decode_latency();
    let t_c = prefix_len.div_ceil(n) as u64;

    // Per column tile: stream N tokens of K and V (fp16) — only the d
    // useful lanes travel on the wire, padding is array-local.
    let dma = DmaConfig::for_bandwidth(cfg.mem_bw_gbs, cfg.freq_ghz, 4);
    let bpc = cfg.mem_bw_gbs / cfg.freq_ghz;
    let tile_bytes = (2 * n * d * 2) as f64;
    let dma_per_tile = dma.setup_cycles + (tile_bytes / bpc).ceil() as u64;

    let pace = tile_compute.max(dma_per_tile);
    let bandwidth_bound = dma_per_tile > tile_compute;
    let step_cycles = t_c * pace + rescale_latency(n) + dma.setup_cycles;

    // O(L) bytes: the K+V prefix (fp16) plus this step's appended row.
    let mut bytes_streamed = (2 * prefix_len * d * 2 + 2 * d * 2) as u64;
    let mut recompute_cycles = 0u64;
    if !cached {
        let refill = fsa_flash_perf(cfg, prefix_len, d, variant, segments);
        recompute_cycles = refill.total_cycles;
        bytes_streamed += (refill.dma_cycles as f64 * bpc) as u64;
    }
    let total_cycles = step_cycles + recompute_cycles;

    let flops = decode_attention_flops(prefix_len, d) as f64;
    let peak_per_cycle = 2.0 * (n * n) as f64;
    DecodePerf {
        total_cycles,
        step_cycles,
        recompute_cycles,
        dma_cycles: t_c * dma_per_tile,
        bytes_streamed,
        cached,
        bandwidth_bound,
        utilization: flops / (peak_per_cycle * total_cycles as f64),
        seconds: total_cycles as f64 / (cfg.freq_ghz * 1e9),
    }
}

/// Pool-level decode timing under a cache hit rate: the decode
/// analogue of [`multi_head_perf`], with the same KV-affinity
/// placement (a session's KV group stays on the device holding its
/// pages, capping one session's parallelism at `num_kv_heads`
/// devices).
#[derive(Clone, Copy, Debug)]
pub struct DecodePoolPerf {
    /// Per-head step timing when served from pages.
    pub hit: DecodePerf,
    /// Per-head step timing on the recompute fallback.
    pub miss: DecodePerf,
    pub hit_rate: f64,
    pub devices_used: usize,
    /// Query heads the busiest device serves per step.
    pub rounds: usize,
    /// Expected per-head step cycles at the hit rate.
    pub expected_head_cycles: f64,
    /// Expected whole-operator step latency (busiest device).
    pub critical_path_cycles: f64,
    /// Cache-hit-aware whole-operator FLOPs/s utilization over the
    /// devices used.
    pub utilization: f64,
    /// Decode throughput of one session at this prefix: steps (tokens)
    /// per second.
    pub tokens_per_sec: f64,
    /// Expected whole-operator bytes per step: each KV head's stream is
    /// fetched once per device thanks to affinity, so this scales with
    /// `num_kv_heads`, not `num_heads`.
    pub bytes_per_step: f64,
}

/// Compose [`fsa_decode_perf`] into a whole decode step of a
/// `num_heads`/`num_kv_heads` operator on a `devices` pool with an
/// expected KV-cache `hit_rate` (1.0 = steady-state resident session,
/// 0.0 = every step recomputes — the no-cache baseline).
#[allow(clippy::too_many_arguments)]
pub fn decode_pool_perf(
    cfg: &AccelConfig,
    prefix_len: usize,
    d: usize,
    num_heads: usize,
    num_kv_heads: usize,
    devices: usize,
    hit_rate: f64,
    variant: Variant,
    segments: usize,
) -> DecodePoolPerf {
    assert!(num_heads >= 1 && num_kv_heads >= 1 && devices >= 1);
    assert_eq!(num_heads % num_kv_heads, 0, "GQA head counts must divide");
    assert!((0.0..=1.0).contains(&hit_rate), "hit rate is a probability");
    let hit = fsa_decode_perf(cfg, prefix_len, d, true, variant, segments);
    let miss = fsa_decode_perf(cfg, prefix_len, d, false, variant, segments);
    let group_size = num_heads / num_kv_heads;
    let devices_used = devices.min(num_kv_heads);
    let rounds = group_size * num_kv_heads.div_ceil(devices);
    let expected_head_cycles =
        hit_rate * hit.total_cycles as f64 + (1.0 - hit_rate) * miss.total_cycles as f64;
    let critical_path_cycles = rounds as f64 * expected_head_cycles;
    let flops = num_heads as f64 * decode_attention_flops(prefix_len, d) as f64;
    let peak_per_cycle =
        2.0 * (cfg.array_size * cfg.array_size) as f64 * devices_used as f64;
    let expected_bytes =
        hit_rate * hit.bytes_streamed as f64 + (1.0 - hit_rate) * miss.bytes_streamed as f64;
    DecodePoolPerf {
        hit,
        miss,
        hit_rate,
        devices_used,
        rounds,
        expected_head_cycles,
        critical_path_cycles,
        utilization: flops / (peak_per_cycle * critical_path_cycles),
        tokens_per_sec: cfg.freq_ghz * 1e9 / critical_path_cycles,
        bytes_per_step: num_kv_heads as f64 * expected_bytes,
    }
}

/// Whole-operator timing for a multi-head (or grouped-query) SDPA
/// sharded across a pool of FSA devices — the granularity the paper's
/// §6.1 baselines (TPUv5e, NeuronCore-v2) are measured at.
#[derive(Clone, Copy, Debug)]
pub struct MultiHeadPerf {
    /// Timing of one head on one array (all heads are identical work).
    pub head: FsaPerf,
    pub num_heads: usize,
    pub num_kv_heads: usize,
    /// Configured pool size.
    pub devices: usize,
    /// Devices one request can actually occupy: KV-head affinity pins a
    /// whole KV group to one device, so `min(devices, num_kv_heads)`.
    pub devices_used: usize,
    /// Query heads the busiest device serves:
    /// `(num_heads / num_kv_heads) * ceil(num_kv_heads / devices)`.
    pub rounds: usize,
    /// Device cycles *consumed* across the pool (cost):
    /// `num_heads * head.total_cycles`.
    pub total_cycles: u64,
    /// Whole-operator latency in cycles (the busiest device):
    /// `rounds * head.total_cycles`.
    pub critical_path_cycles: u64,
    /// Whole-operator achieved/peak FLOPs/s over the `devices_used`
    /// devices for the critical-path duration — the same quantity
    /// [`pool_utilization`] computes from the coordinator's gathered
    /// measurements, comparable to Fig. 11 / Table 2, and degraded by
    /// ragged KV-group/device splits exactly as the real router is.
    pub utilization: f64,
    /// Critical path at the config clock.
    pub seconds: f64,
}

/// Compose [`fsa_flash_perf`] per-head timing into a whole multi-head
/// operator scheduled the way the coordinator's router actually places
/// it: shards are scattered least-loaded *per KV group* (GQA heads
/// sharing a KV head stay on one device so K/V tiles are fetched once
/// per device — the win is real when bandwidth-bound), which caps one
/// request's parallelism at `num_kv_heads` devices.  A pool larger
/// than `num_kv_heads` does not shorten a single operator's critical
/// path; it adds capacity for *concurrent* requests instead.
///
/// `num_kv_heads` does not change FLOPs — every query head runs full
/// `4 L² d` attention.
#[allow(clippy::too_many_arguments)]
pub fn multi_head_perf(
    cfg: &AccelConfig,
    seq_len: usize,
    d: usize,
    num_heads: usize,
    num_kv_heads: usize,
    devices: usize,
    variant: Variant,
    segments: usize,
) -> MultiHeadPerf {
    multi_head_perf_masked(
        cfg, seq_len, d, num_heads, num_kv_heads, devices, variant, segments, MaskKind::None,
    )
}

/// Masked [`multi_head_perf`]: every head carries the same mask (one
/// operator, one mask), so per-head timing comes from
/// [`fsa_flash_perf_masked`] and the whole-operator FLOPs from
/// [`masked_attention_flops`].  `MaskKind::None` is exactly
/// [`multi_head_perf`].
#[allow(clippy::too_many_arguments)]
pub fn multi_head_perf_masked(
    cfg: &AccelConfig,
    seq_len: usize,
    d: usize,
    num_heads: usize,
    num_kv_heads: usize,
    devices: usize,
    variant: Variant,
    segments: usize,
    mask: MaskKind,
) -> MultiHeadPerf {
    assert!(num_heads >= 1 && num_kv_heads >= 1 && devices >= 1);
    assert_eq!(num_heads % num_kv_heads, 0, "GQA head counts must divide");
    let head = fsa_flash_perf_masked(cfg, seq_len, d, variant, segments, mask);
    let group_size = num_heads / num_kv_heads;
    let devices_used = devices.min(num_kv_heads);
    let rounds = group_size * num_kv_heads.div_ceil(devices);
    let total_cycles = num_heads as u64 * head.total_cycles;
    let critical_path_cycles = rounds as u64 * head.total_cycles;
    let flops = num_heads as u64 * masked_attention_flops(seq_len, d, mask);
    let peak_per_cycle = 2.0 * (cfg.array_size * cfg.array_size) as f64 * devices_used as f64;
    MultiHeadPerf {
        head,
        num_heads,
        num_kv_heads,
        devices,
        devices_used,
        rounds,
        total_cycles,
        critical_path_cycles,
        utilization: flops as f64 / (peak_per_cycle * critical_path_cycles as f64),
        seconds: critical_path_cycles as f64 / (cfg.freq_ghz * 1e9),
    }
}

/// Timing of one sequence-parallel K/V *chunk* of one head
/// (DESIGN.md §7): the full query sequence against global keys
/// `[key_start, key_start + key_len)`.  Identical structure to
/// [`fsa_flash_perf_masked`] — tile-skipping schedule, double-buffered
/// DMA, per-row-block epilogue — but the tile census and useful FLOPs
/// are restricted to the chunk ([`masked_tile_counts_range`] /
/// [`masked_attention_flops_range`]).  With the whole key range and
/// tile-aligned boundaries this reproduces the unsharded model exactly
/// (pinned by a unit test).
#[allow(clippy::too_many_arguments)]
pub fn fsa_flash_chunk_perf(
    cfg: &AccelConfig,
    seq_len: usize,
    d: usize,
    key_start: usize,
    key_len: usize,
    variant: Variant,
    segments: usize,
    mask: MaskKind,
) -> FsaPerf {
    let n = cfg.array_size;
    assert!(d <= n, "head dim {d} exceeds array size {n}");
    assert!(key_len >= 1, "chunk must cover at least one key");
    let sched = InnerSchedule::new(n, variant, segments);
    let ii = sched.inner_latency();
    let ii_masked = sched.masked_inner_latency();

    let t_r = seq_len.div_ceil(n) as u64;
    let (full, partial, _skipped) = masked_tile_counts_range(seq_len, n, mask, key_start, key_len);

    let dma = DmaConfig::for_bandwidth(cfg.mem_bw_gbs, cfg.freq_ghz, 4);
    let tile_bytes = (n * n * 2) as f64;
    let bpc = cfg.mem_bw_gbs / cfg.freq_ghz;
    let dma_per_iter = dma.setup_cycles + (2.0 * tile_bytes / bpc).ceil() as u64;

    let ii_eff = ii.max(dma_per_iter);
    let ii_masked_eff = ii_masked.max(dma_per_iter);
    let bandwidth_bound = dma_per_iter > ii;

    let inner = full * ii_eff + partial * ii_masked_eff;
    let outer = rescale_latency(n);
    let startup = preload_latency(n) + dma_per_iter + dma.setup_cycles;
    let total = inner + t_r * outer + startup;

    let flops = masked_attention_flops_range(seq_len, d, mask, key_start, key_len) as f64;
    let peak_per_cycle = 2.0 * (n * n) as f64;
    let utilization = flops / (peak_per_cycle * total as f64);

    let array_active = full * ii + partial * ii_masked + t_r * preload_latency(n);
    FsaPerf {
        total_cycles: total,
        array_active_cycles: array_active.min(total),
        dma_cycles: (full + partial) * dma_per_iter,
        utilization,
        seconds: total as f64 / (cfg.freq_ghz * 1e9),
        bandwidth_bound,
    }
}

/// Timing of a *resumed* (prefix-cache warm) prefill chunk
/// (DESIGN.md §11): only the `seq_len - query_start` uncovered suffix
/// query rows run, against global keys `[key_start, key_start +
/// key_len)`.  The structure is [`fsa_flash_chunk_perf`] with the tile
/// census and useful FLOPs further restricted to the suffix rows
/// ([`masked_tile_counts_resumed`] / [`masked_attention_flops_resumed`]);
/// suffix rows tile locally from the resume point but their mask
/// coverage is classified at global query coordinates, exactly like the
/// resumed kernel.  `query_start == 0` reproduces the chunk model, and
/// the device worker's `saved_prefill_cycles` term is the cold chunk
/// model minus this.
#[allow(clippy::too_many_arguments)]
pub fn fsa_flash_resumed_perf(
    cfg: &AccelConfig,
    seq_len: usize,
    d: usize,
    query_start: usize,
    key_start: usize,
    key_len: usize,
    variant: Variant,
    segments: usize,
    mask: MaskKind,
) -> FsaPerf {
    let n = cfg.array_size;
    assert!(d <= n, "head dim {d} exceeds array size {n}");
    assert!(key_len >= 1, "chunk must cover at least one key");
    assert!(query_start < seq_len, "resume point must leave suffix rows");
    let sched = InnerSchedule::new(n, variant, segments);
    let ii = sched.inner_latency();
    let ii_masked = sched.masked_inner_latency();

    let t_r = (seq_len - query_start).div_ceil(n) as u64;
    let (full, partial, _skipped) =
        masked_tile_counts_resumed(seq_len, n, mask, query_start, key_start, key_len);

    let dma = DmaConfig::for_bandwidth(cfg.mem_bw_gbs, cfg.freq_ghz, 4);
    let tile_bytes = (n * n * 2) as f64;
    let bpc = cfg.mem_bw_gbs / cfg.freq_ghz;
    let dma_per_iter = dma.setup_cycles + (2.0 * tile_bytes / bpc).ceil() as u64;

    let ii_eff = ii.max(dma_per_iter);
    let ii_masked_eff = ii_masked.max(dma_per_iter);
    let bandwidth_bound = dma_per_iter > ii;

    let inner = full * ii_eff + partial * ii_masked_eff;
    let outer = rescale_latency(n);
    let startup = preload_latency(n) + dma_per_iter + dma.setup_cycles;
    let total = inner + t_r * outer + startup;

    let flops =
        masked_attention_flops_resumed(seq_len, d, mask, query_start, key_start, key_len) as f64;
    let peak_per_cycle = 2.0 * (n * n) as f64;
    let utilization = flops / (peak_per_cycle * total as f64);

    let array_active = full * ii + partial * ii_masked + t_r * preload_latency(n);
    FsaPerf {
        total_cycles: total,
        array_active_cycles: array_active.min(total),
        dma_cycles: (full + partial) * dma_per_iter,
        utilization,
        seconds: total as f64 / (cfg.freq_ghz * 1e9),
        bandwidth_bound,
    }
}

/// Timing of one sequence-parallel head (DESIGN.md §7): the K/V split
/// into `seq_shards` even chunks computed concurrently, their partial
/// `(O~, m, l)` triples shipped to the gathering device and merged in
/// chunk order.
#[derive(Clone, Copy, Debug)]
pub struct SeqParPerf {
    pub seq_shards: usize,
    /// Chunks actually issued (fully-masked chunks are never
    /// dispatched — zero compute, zero DMA, zero communication).
    pub live_chunks: usize,
    /// The slowest chunk's cycles — the parallel phase's span.  Under a
    /// causal mask chunk 0 is the critical one (it owns the most
    /// below-diagonal tiles), a real load imbalance the even split
    /// accepts (DESIGN.md §7).
    pub chunk_cycles_max: u64,
    /// Cycles consumed across all chunks (the pool cost).
    pub chunk_cycles_total: u64,
    /// Gather-side merge: `live − 1` online-softmax merge steps over
    /// `seq_len` rows of `(d + 2)`-wide state, priced at `N` elementwise
    /// lanes per cycle (§3.3-style wave, ~3 ops per element).
    pub merge_cycles: u64,
    /// Partial-state traffic to the gathering device: `live − 1`
    /// partials of `seq_len · (d + 2)` f32 values.
    pub comm_bytes: u64,
    pub comm_cycles: u64,
    /// Whole-head latency: slowest chunk, then communication, then the
    /// in-order merge.
    pub critical_path_cycles: u64,
    /// The unsharded single-device baseline ([`fsa_flash_perf_masked`]).
    pub single_device_cycles: u64,
    /// `single_device_cycles / critical_path_cycles` — > 1 when
    /// sequence sharding wins; the crossover L is where this passes 1.
    pub speedup: f64,
    /// Whole-head achieved/peak FLOPs/s over the `live_chunks` devices
    /// for the critical-path duration.
    pub utilization: f64,
    pub seconds: f64,
}

/// Model one head sharded `seq_shards` ways across the sequence
/// (DESIGN.md §7).  `seq_shards = 1` degenerates to
/// [`fsa_flash_perf_masked`] with zero merge/communication.
pub fn seqpar_perf(
    cfg: &AccelConfig,
    seq_len: usize,
    d: usize,
    seq_shards: usize,
    variant: Variant,
    segments: usize,
    mask: MaskKind,
) -> SeqParPerf {
    assert!(seq_shards >= 1);
    let n = cfg.array_size;
    let single = fsa_flash_perf_masked(cfg, seq_len, d, variant, segments, mask);
    if seq_shards == 1 {
        // Unsharded degeneration: the legacy whole-head path, no merge,
        // no communication.
        return SeqParPerf {
            seq_shards,
            live_chunks: 1,
            chunk_cycles_max: single.total_cycles,
            chunk_cycles_total: single.total_cycles,
            merge_cycles: 0,
            comm_bytes: 0,
            comm_cycles: 0,
            critical_path_cycles: single.total_cycles,
            single_device_cycles: single.total_cycles,
            speedup: 1.0,
            utilization: single.utilization,
            seconds: single.seconds,
        };
    }

    // The same liveness rule the coordinator dispatches with
    // ([`live_chunk_ranges`]): dead chunks are neither dispatched nor
    // priced, and a fully-masked operator falls back to one legacy
    // shard.
    let grid = live_chunk_ranges(seq_len, seq_len, seq_len, seq_shards, mask);
    let mut chunk_max = 0u64;
    let mut chunk_total = 0u64;
    let mut live = grid.len();
    for &(_, (start, len)) in &grid {
        let c = fsa_flash_chunk_perf(cfg, seq_len, d, start, len, variant, segments, mask);
        chunk_max = chunk_max.max(c.total_cycles);
        chunk_total += c.total_cycles;
    }
    if live == 0 {
        chunk_max = single.total_cycles;
        chunk_total = single.total_cycles;
        live = 1;
    }

    let (merge_cycles, comm_bytes) = if live > 1 {
        let rows = seq_len as u64;
        let state = (d + 2) as u64; // acc row + m + l
        (
            ((live as u64 - 1) * rows * 3 * state).div_ceil(n as u64),
            (live as u64 - 1) * rows * state * 4,
        )
    } else {
        (0, 0)
    };
    let bpc = cfg.mem_bw_gbs / cfg.freq_ghz;
    let dma = DmaConfig::for_bandwidth(cfg.mem_bw_gbs, cfg.freq_ghz, 4);
    let comm_cycles = if live > 1 {
        (comm_bytes as f64 / bpc).ceil() as u64 + (live as u64 - 1) * dma.setup_cycles
    } else {
        0
    };

    let critical = chunk_max + comm_cycles + merge_cycles;
    let flops = masked_attention_flops(seq_len, d, mask) as f64;
    let peak_per_cycle = 2.0 * (n * n) as f64 * live as f64;
    SeqParPerf {
        seq_shards,
        live_chunks: live,
        chunk_cycles_max: chunk_max,
        chunk_cycles_total: chunk_total,
        merge_cycles,
        comm_bytes,
        comm_cycles,
        critical_path_cycles: critical,
        single_device_cycles: single.total_cycles,
        speedup: single.total_cycles as f64 / critical as f64,
        utilization: flops / (peak_per_cycle * critical as f64),
        seconds: critical as f64 / (cfg.freq_ghz * 1e9),
    }
}

/// The modeled crossover: the smallest `L` in `ls` where `seq_shards`-way
/// sequence sharding beats the single-device latency
/// (`seqpar_perf(..).speedup > 1`).  `None` when sharding never wins in
/// the sweep — e.g. at tile-quantized short sequences where the merge
/// and communication terms dominate.
pub fn seqpar_crossover(
    cfg: &AccelConfig,
    d: usize,
    seq_shards: usize,
    variant: Variant,
    segments: usize,
    mask: MaskKind,
    ls: &[usize],
) -> Option<usize> {
    ls.iter()
        .copied()
        .find(|&l| seqpar_perf(cfg, l, d, seq_shards, variant, segments, mask).speedup > 1.0)
}

/// Pool-level sequence-parallel timing of a whole multi-head operator:
/// the sequence-sharded analogue of [`multi_head_perf_masked`].
#[derive(Clone, Copy, Debug)]
pub struct SeqParPoolPerf {
    /// Per-head sharding model (chunk span, merge, communication).
    pub head: SeqParPerf,
    pub num_heads: usize,
    pub num_kv_heads: usize,
    pub devices: usize,
    /// Routing units one request scatters into: `(kv_head, chunk)`
    /// affinity groups — sequence sharding multiplies a request's
    /// parallelism by `live_chunks`, which is exactly how it beats the
    /// `num_kv_heads`-device ceiling of head sharding alone.
    pub devices_used: usize,
    /// Chunk executions the busiest device serves.
    pub rounds: usize,
    /// Whole-operator latency: `rounds` chunk spans plus one round of
    /// per-head merge + communication on the gathering device.
    pub critical_path_cycles: u64,
    pub total_cycles: u64,
    pub utilization: f64,
    pub seconds: f64,
}

/// Compose [`seqpar_perf`] per-head chunks into a whole operator the way
/// the router actually places them: one `(kv_head, chunk)` group — all
/// `group_size` query heads of a KV head attending one chunk — per
/// device, least-loaded.  `seq_shards = 1` reproduces
/// [`multi_head_perf_masked`] (pinned by a unit test).
#[allow(clippy::too_many_arguments)]
pub fn seqpar_pool_perf(
    cfg: &AccelConfig,
    seq_len: usize,
    d: usize,
    num_heads: usize,
    num_kv_heads: usize,
    devices: usize,
    seq_shards: usize,
    variant: Variant,
    segments: usize,
    mask: MaskKind,
) -> SeqParPoolPerf {
    assert!(num_heads >= 1 && num_kv_heads >= 1 && devices >= 1);
    assert_eq!(num_heads % num_kv_heads, 0, "GQA head counts must divide");
    let head = seqpar_perf(cfg, seq_len, d, seq_shards, variant, segments, mask);
    let group_size = num_heads / num_kv_heads;
    let groups = num_kv_heads * head.live_chunks;
    let devices_used = devices.min(groups);
    let rounds = group_size * groups.div_ceil(devices);
    let merge_overhead = (head.merge_cycles + head.comm_cycles) * group_size as u64;
    let critical_path_cycles = rounds as u64 * head.chunk_cycles_max + merge_overhead;
    let total_cycles =
        num_heads as u64 * (head.chunk_cycles_total + head.merge_cycles + head.comm_cycles);
    let flops = num_heads as u64 * masked_attention_flops(seq_len, d, mask);
    let peak_per_cycle = 2.0 * (cfg.array_size * cfg.array_size) as f64 * devices_used as f64;
    SeqParPoolPerf {
        head,
        num_heads,
        num_kv_heads,
        devices,
        devices_used,
        rounds,
        critical_path_cycles,
        total_cycles,
        utilization: flops as f64 / (peak_per_cycle * critical_path_cycles as f64),
        seconds: critical_path_cycles as f64 / (cfg.freq_ghz * 1e9),
    }
}

/// One modeled-vs-measured comparison (DESIGN.md §8): the analytic
/// tile-cycle prediction of [`fsa_flash_perf_masked`] against the
/// cycles the cycle-accurate machine actually takes executing the same
/// masked program shape.
#[derive(Clone, Copy, Debug)]
pub struct SimCrossCheck {
    pub seq_len: usize,
    pub mask: MaskKind,
    /// `fsa_flash_perf_masked(..).total_cycles`.
    pub modeled: u64,
    /// `sim::RunStats::cycles` of the compiled program on the machine.
    pub measured: u64,
    /// `measured / modeled`.
    pub ratio: f64,
}

/// The pinned agreement band of [`sim_cross_check`]: the model prices
/// issued tiles at the §3.5 chained latencies plus DMA
/// startup/epilogues, while the machine additionally exposes real
/// scoreboard stalls and the final store drain — they must agree within
/// ±15% or one of them has drifted (asserted by the perfmodel tests,
/// the `simcycles` bench, and the coordinator e2e).
pub const SIM_MODEL_BAND: (f64, f64) = (0.85, 1.15);

impl SimCrossCheck {
    pub fn within_band(&self) -> bool {
        self.ratio >= SIM_MODEL_BAND.0 && self.ratio <= SIM_MODEL_BAND.1
    }
}

/// Cross-validate the analytic model against the machine: compile the
/// `(seq_len, mask)` head at the config's array size, run it on a
/// [`crate::sim::Machine`] built from the same config (same DMA
/// bandwidth, clock, PWL segments), and compare cycle counts.  Timing
/// is data-independent, so the device memory stays zeroed.  This is the
/// §8 contract that keeps the perfmodel honest — `backend=sim` prices
/// shards with the measured number, and this function is how tests
/// assert the modeled number tracks it.
pub fn sim_cross_check(
    cfg: &AccelConfig,
    seq_len: usize,
    mask: MaskKind,
    segments: usize,
) -> crate::Result<SimCrossCheck> {
    use crate::kernel::flash::{flash_chunk_program, ChunkLayout, ChunkParams};
    use crate::sim::{Machine, MachineConfig};

    let n = cfg.array_size;
    let modeled =
        fsa_flash_perf_masked(cfg, seq_len, n, Variant::DualPath, segments, mask).total_cycles;
    let p = ChunkParams::whole(n, seq_len, mask);
    let layout = ChunkLayout::packed(&p);
    let prog = flash_chunk_program(&p, &layout)?;
    let mut mc = MachineConfig::from_accel(cfg);
    mc.segments = segments;
    mc.mem_elems = layout.mem_elems(&p).max(1 << 12);
    let mut machine = Machine::new(mc);
    let stats = machine.run_program(&prog)?;
    Ok(SimCrossCheck {
        seq_len,
        mask,
        modeled,
        measured: stats.cycles,
        ratio: stats.cycles as f64 / modeled.max(1) as f64,
    })
}

/// Whole-operator FLOPs/s utilization from *observed* per-device cycle
/// totals (what the coordinator's gather measures): achieved FLOPs over
/// the pool's peak for the critical-path duration.  Returns 0 when no
/// cycles were recorded.
pub fn pool_utilization(cfg: &AccelConfig, total_flops: u64, per_device_cycles: &[u64]) -> f64 {
    let critical = per_device_cycles.iter().copied().max().unwrap_or(0);
    if critical == 0 || per_device_cycles.is_empty() {
        return 0.0;
    }
    let peak_per_cycle =
        2.0 * (cfg.array_size * cfg.array_size) as f64 * per_device_cycles.len() as f64;
    total_flops as f64 / (peak_per_cycle * critical as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fsa() -> AccelConfig {
        AccelConfig::builtin("fsa").unwrap()
    }

    #[test]
    fn resumed_perf_at_query_start_zero_is_the_chunk_model() {
        // DESIGN.md §11: with nothing resumed, the resumed model must
        // be the chunk model cycle for cycle — whole range and a
        // key-chunk sub-range, masked and unmasked.
        let cfg = fsa();
        for mask in [MaskKind::None, MaskKind::Causal] {
            for (ks, kl) in [(0usize, 2048usize), (1024, 1024)] {
                let cold =
                    fsa_flash_chunk_perf(&cfg, 2048, 128, ks, kl, Variant::DualPath, 8, mask);
                let warm = fsa_flash_resumed_perf(
                    &cfg, 2048, 128, 0, ks, kl, Variant::DualPath, 8, mask,
                );
                assert_eq!(warm.total_cycles, cold.total_cycles, "{mask:?} [{ks},{kl})");
                assert_eq!(warm.dma_cycles, cold.dma_cycles, "{mask:?} [{ks},{kl})");
            }
        }
    }

    #[test]
    fn resumed_prefill_saves_cycles_proportionally_to_coverage() {
        // The saved-prefill-cycles term (cold minus resumed) must be
        // positive and grow with the covered prefix.
        let cfg = fsa();
        for mask in [MaskKind::None, MaskKind::Causal] {
            let cold = fsa_flash_chunk_perf(&cfg, 4096, 128, 0, 4096, Variant::DualPath, 8, mask);
            let saved: Vec<u64> = [1024usize, 2048, 3072]
                .iter()
                .map(|&qs| {
                    let warm = fsa_flash_resumed_perf(
                        &cfg, 4096, 128, qs, 0, 4096, Variant::DualPath, 8, mask,
                    );
                    assert!(warm.total_cycles < cold.total_cycles, "{mask:?} resume {qs}");
                    cold.total_cycles - warm.total_cycles
                })
                .collect();
            assert!(saved.windows(2).all(|w| w[1] > w[0]), "{mask:?}: {saved:?}");
        }
    }

    #[test]
    fn compute_bound_at_paper_config() {
        // 820 GB/s @1.5 GHz: 64 KiB per iteration in ~136 cycles, far
        // under the 650-cycle iteration — compute-bound, as §6.1 assumes.
        let p = fsa_flash_perf(&fsa(), 2048, 128, Variant::DualPath, 8);
        assert!(!p.bandwidth_bound);
        assert!(p.utilization > 0.3 && p.utilization < 0.4, "{}", p.utilization);
    }

    #[test]
    fn utilization_rises_with_seq_len_to_asymptote() {
        let us: Vec<f64> = [2048usize, 4096, 8192, 16384]
            .iter()
            .map(|&l| fsa_flash_perf(&fsa(), l, 128, Variant::DualPath, 8).utilization)
            .collect();
        assert!(us.windows(2).all(|w| w[1] >= w[0]), "{us:?}");
        let ceiling = 2.0 * 128.0 / (5.0 * 128.0 + 10.0);
        assert!(us[3] < ceiling && us[3] > ceiling - 0.02, "{us:?}");
    }

    #[test]
    fn single_path_variant_is_slower_but_close() {
        // §8.2: 6N+10 vs 5N+10 — about 17% more cycles at N=128.
        let dual = fsa_flash_perf(&fsa(), 8192, 128, Variant::DualPath, 8);
        let single = fsa_flash_perf(&fsa(), 8192, 128, Variant::SinglePath, 8);
        let ratio = single.total_cycles as f64 / dual.total_cycles as f64;
        assert!(ratio > 1.1 && ratio < 1.25, "ratio {ratio}");
    }

    #[test]
    fn small_head_dim_wastes_lanes() {
        // §8.3: padding to 128-wide tiles burns utilization.
        let full = fsa_flash_perf(&fsa(), 4096, 128, Variant::DualPath, 8);
        let half = fsa_flash_perf(&fsa(), 4096, 64, Variant::DualPath, 8);
        assert!((half.utilization - full.utilization / 2.0).abs() < 0.02);
    }

    #[test]
    fn bandwidth_bound_when_starved() {
        let mut cfg = fsa();
        cfg.mem_bw_gbs = 40.0; // starve the DMA
        let p = fsa_flash_perf(&cfg, 4096, 128, Variant::DualPath, 8);
        assert!(p.bandwidth_bound);
        assert!(p.utilization < 0.3);
    }

    #[test]
    fn multi_head_scales_and_respects_affinity_and_ragged_tails() {
        let cfg = fsa();
        let one = fsa_flash_perf(&cfg, 4096, 128, Variant::DualPath, 8);
        // 8 MHA heads on 1 device: 8x the cycles, same utilization.
        let mh = multi_head_perf(&cfg, 4096, 128, 8, 8, 1, Variant::DualPath, 8);
        assert_eq!((mh.devices_used, mh.rounds), (1, 8));
        assert_eq!(mh.critical_path_cycles, 8 * one.total_cycles);
        assert!((mh.utilization - one.utilization).abs() < 1e-12);
        // 8 MHA heads on 4 devices: 2 rounds, same pool utilization,
        // 4x faster wall clock.
        let mh4 = multi_head_perf(&cfg, 4096, 128, 8, 8, 4, Variant::DualPath, 8);
        assert_eq!((mh4.devices_used, mh4.rounds), (4, 2));
        assert_eq!(mh4.total_cycles, mh.total_cycles);
        assert!((mh4.seconds - mh.seconds / 4.0).abs() < 1e-12);
        assert!((mh4.utilization - one.utilization).abs() < 1e-12);
        // GQA 8q/2kv on 4 devices: KV affinity caps the request at 2
        // devices, so the busiest device runs a whole 4-head group —
        // a pool bigger than num_kv_heads doesn't cut this latency.
        let gqa = multi_head_perf(&cfg, 4096, 128, 8, 2, 4, Variant::DualPath, 8);
        assert_eq!((gqa.devices_used, gqa.rounds), (2, 4));
        assert_eq!(gqa.critical_path_cycles, 4 * one.total_cycles);
        assert!((gqa.utilization - one.utilization).abs() < 1e-12);
        // Ragged: 8 MHA heads on 3 devices -> 3 rounds, tail 2/3 idle.
        let mh3 = multi_head_perf(&cfg, 4096, 128, 8, 8, 3, Variant::DualPath, 8);
        assert_eq!((mh3.devices_used, mh3.rounds), (3, 3));
        let expect = one.utilization * 8.0 / 9.0;
        assert!((mh3.utilization - expect).abs() < 1e-12, "{} vs {expect}", mh3.utilization);
        // Ragged KV groups: 8q/4kv on 3 devices -> busiest device gets
        // 2 groups of 2 heads = 4 rounds over 3 devices.
        let gqa3 = multi_head_perf(&cfg, 4096, 128, 8, 4, 3, Variant::DualPath, 8);
        assert_eq!((gqa3.devices_used, gqa3.rounds), (3, 4));
        let expect3 = one.utilization * 8.0 / 12.0;
        assert!((gqa3.utilization - expect3).abs() < 1e-12);
    }

    #[test]
    fn causal_mask_halves_tile_cycles_at_matched_utilization() {
        // Acceptance: the tile-skipping schedule must report ≈2x fewer
        // causal tile-cycles than square at the same L — the (t²-t(t+1)/2)
        // skipped upper-triangle tiles, with the diagonal paying only the
        // one-cycle mask wave.
        let cfg = fsa();
        for &l in &[2048usize, 4096, 8192, 16384] {
            let square = fsa_flash_perf(&cfg, l, 128, Variant::DualPath, 8);
            let causal =
                fsa_flash_perf_masked(&cfg, l, 128, Variant::DualPath, 8, MaskKind::Causal);
            let ratio = causal.total_cycles as f64 / square.total_cycles as f64;
            // (t(t+1)/2) / t² -> 1/2 from above as t grows; epilogues and
            // startup add a little.
            assert!(ratio > 0.5 && ratio < 0.62, "L={l}: cycle ratio {ratio}");
            // FLOPs halve with the cycles, so utilization stays in band.
            assert!(
                (causal.utilization - square.utilization).abs() < 0.05,
                "L={l}: {} vs {}",
                causal.utilization,
                square.utilization
            );
            // Skipped tiles are never fetched: DMA traffic drops too.
            assert!(causal.dma_cycles < square.dma_cycles * 3 / 5);
        }
    }

    #[test]
    fn unmasked_wrappers_are_bitwise_the_masked_model() {
        let cfg = fsa();
        let a = fsa_flash_perf(&cfg, 4096, 128, Variant::DualPath, 8);
        let b = fsa_flash_perf_masked(&cfg, 4096, 128, Variant::DualPath, 8, MaskKind::None);
        assert_eq!(
            (a.total_cycles, a.array_active_cycles, a.dma_cycles),
            (b.total_cycles, b.array_active_cycles, b.dma_cycles)
        );
        assert_eq!(a.utilization, b.utilization);
        let m = multi_head_perf(&cfg, 4096, 128, 8, 2, 4, Variant::DualPath, 8);
        let mm = multi_head_perf_masked(
            &cfg, 4096, 128, 8, 2, 4, Variant::DualPath, 8, MaskKind::None,
        );
        assert_eq!(m.critical_path_cycles, mm.critical_path_cycles);
        assert_eq!(m.utilization, mm.utilization);
    }

    #[test]
    fn padding_mask_prices_only_the_valid_prefix() {
        let cfg = fsa();
        // A 512-bucket request with 300 valid keys: per row-block, 2 full
        // + 1 boundary tile instead of 4 — cheaper than square, and the
        // fully-padded column tile is neither computed nor fetched.
        let square = fsa_flash_perf(&cfg, 512, 128, Variant::DualPath, 8);
        let padded = fsa_flash_perf_masked(
            &cfg, 512, 128, Variant::DualPath, 8,
            MaskKind::PaddingKeys { valid: 300 },
        );
        assert!(padded.total_cycles < square.total_cycles);
        assert!(padded.dma_cycles < square.dma_cycles);
        // valid == seq_len degenerates to square exactly.
        let same = fsa_flash_perf_masked(
            &cfg, 512, 128, Variant::DualPath, 8,
            MaskKind::PaddingKeys { valid: 512 },
        );
        assert_eq!(same.total_cycles, square.total_cycles);
        assert_eq!(same.utilization, square.utilization);
    }

    #[test]
    fn pool_utilization_from_observed_cycles() {
        let cfg = fsa();
        let one = fsa_flash_perf(&cfg, 4096, 128, Variant::DualPath, 8);
        let flops = 8 * crate::schedule::attention_flops(4096, 128);
        // Perfectly balanced 8 heads over 4 devices matches the model.
        let per_dev = vec![2 * one.total_cycles; 4];
        let u = pool_utilization(&cfg, flops, &per_dev);
        let model = multi_head_perf(&cfg, 4096, 128, 8, 8, 4, Variant::DualPath, 8);
        assert!((u - model.utilization).abs() < 1e-12);
        // Degenerate inputs.
        assert_eq!(pool_utilization(&cfg, flops, &[]), 0.0);
        assert_eq!(pool_utilization(&cfg, flops, &[0]), 0.0);
    }

    #[test]
    fn cached_decode_is_linear_recompute_quadratic() {
        let cfg = fsa();
        // Doubling the prefix doubles the cached step (cycles and
        // bytes) but quadruples the recompute charge — the O(L) vs
        // O(L²) separation the KV cache exists for.
        let l = 4096usize;
        let hit1 = fsa_decode_perf(&cfg, l, 128, true, Variant::DualPath, 8);
        let hit2 = fsa_decode_perf(&cfg, 2 * l, 128, true, Variant::DualPath, 8);
        let byte_ratio = hit2.bytes_streamed as f64 / hit1.bytes_streamed as f64;
        assert!((byte_ratio - 2.0).abs() < 0.01, "bytes ratio {byte_ratio}");
        let cycle_ratio = hit2.step_cycles as f64 / hit1.step_cycles as f64;
        assert!(cycle_ratio > 1.8 && cycle_ratio < 2.2, "cycle ratio {cycle_ratio}");

        let miss1 = fsa_decode_perf(&cfg, l, 128, false, Variant::DualPath, 8);
        let miss2 = fsa_decode_perf(&cfg, 2 * l, 128, false, Variant::DualPath, 8);
        let rc_ratio = miss2.recompute_cycles as f64 / miss1.recompute_cycles as f64;
        assert!(rc_ratio > 3.5 && rc_ratio < 4.5, "recompute ratio {rc_ratio}");
        // The miss premium dwarfs the cached step and grows with L.
        assert!(miss1.total_cycles > 10 * hit1.total_cycles);
        assert!(
            miss2.total_cycles as f64 / hit2.total_cycles as f64
                > miss1.total_cycles as f64 / hit1.total_cycles as f64
        );
        // Hit carries no recompute and the step cost is shared.
        assert_eq!(hit1.recompute_cycles, 0);
        assert_eq!(hit1.step_cycles, miss1.step_cycles);
        // One-row utilization collapses (§8.3): over an order of
        // magnitude below the prefill utilization at the same prefix.
        let prefill = fsa_flash_perf(&cfg, l, 128, Variant::DualPath, 8);
        assert!(hit1.utilization < prefill.utilization / 20.0);
    }

    #[test]
    fn decode_pool_perf_is_hit_rate_aware() {
        let cfg = fsa();
        let (l, d) = (4096usize, 128usize);
        let all_hit = decode_pool_perf(&cfg, l, d, 8, 2, 4, 1.0, Variant::DualPath, 8);
        let all_miss = decode_pool_perf(&cfg, l, d, 8, 2, 4, 0.0, Variant::DualPath, 8);
        let half = decode_pool_perf(&cfg, l, d, 8, 2, 4, 0.5, Variant::DualPath, 8);
        // Affinity caps a session at num_kv_heads devices; the busiest
        // runs a whole 4-head group per step.
        assert_eq!((all_hit.devices_used, all_hit.rounds), (2, 4));
        assert_eq!(
            all_hit.critical_path_cycles,
            4.0 * all_hit.hit.total_cycles as f64
        );
        assert_eq!(
            all_miss.critical_path_cycles,
            4.0 * all_miss.miss.total_cycles as f64
        );
        let mid = 0.5 * (all_hit.critical_path_cycles + all_miss.critical_path_cycles);
        assert!((half.critical_path_cycles - mid).abs() < 1.0);
        // Hits mean fewer cycles for the same FLOPs: better utilization
        // and more tokens per second.
        assert!(all_hit.utilization > 5.0 * all_miss.utilization);
        assert!(all_hit.tokens_per_sec > 5.0 * all_miss.tokens_per_sec);
        assert!(half.tokens_per_sec < all_hit.tokens_per_sec);
        // Bytes scale with KV heads (affinity fetches each stream once).
        assert!(
            (all_hit.bytes_per_step - 2.0 * all_hit.hit.bytes_streamed as f64).abs() < 1.0
        );
    }

    #[test]
    fn chunk_perf_reproduces_the_masked_model_on_the_whole_range() {
        let cfg = fsa();
        for mask in [MaskKind::None, MaskKind::Causal, MaskKind::PaddingKeys { valid: 3000 }] {
            let whole = fsa_flash_perf_masked(&cfg, 4096, 128, Variant::DualPath, 8, mask);
            let chunk =
                fsa_flash_chunk_perf(&cfg, 4096, 128, 0, 4096, Variant::DualPath, 8, mask);
            assert_eq!(chunk.total_cycles, whole.total_cycles, "{mask:?}");
            assert_eq!(chunk.dma_cycles, whole.dma_cycles, "{mask:?}");
            assert_eq!(chunk.utilization, whole.utilization, "{mask:?}");
        }
        // A quarter chunk prices ~a quarter of the inner work (plus its
        // own epilogues/startup), and chunks of a partition cover all
        // the single-device tiles.
        let whole = fsa_flash_perf(&cfg, 4096, 128, Variant::DualPath, 8);
        let quarter =
            fsa_flash_chunk_perf(&cfg, 4096, 128, 1024, 1024, Variant::DualPath, 8, MaskKind::None);
        assert!(quarter.total_cycles < whole.total_cycles / 3);
        let sum: u64 = (0..4)
            .map(|c| {
                fsa_flash_chunk_perf(
                    &cfg, 4096, 128, c * 1024, 1024, Variant::DualPath, 8, MaskKind::None,
                )
                .total_cycles
            })
            .sum();
        assert!(sum >= whole.total_cycles, "chunks re-pay epilogues/startup");
    }

    #[test]
    fn seqpar_speedup_crosses_over_with_sequence_length() {
        // Acceptance: the crossover L where 4-way sequence sharding
        // beats single-device latency is a modeled, asserted quantity —
        // short sequences lose to the merge/communication overhead
        // (tile-quantized chunks don't even shrink the span), long ones
        // approach the seq_shards-fold span reduction.
        let cfg = fsa();
        let ls = [128usize, 256, 512, 1024, 2048, 4096, 8192, 16384];
        let crossover = seqpar_crossover(
            &cfg, 128, 4, Variant::DualPath, 8, MaskKind::None, &ls,
        )
        .expect("4-way sharding must win somewhere in the sweep");
        assert!(
            (257..=1024).contains(&crossover),
            "crossover L = {crossover} out of the expected band"
        );
        let short = seqpar_perf(&cfg, 128, 128, 4, Variant::DualPath, 8, MaskKind::None);
        assert!(short.speedup < 1.0, "short sequences must not win: {}", short.speedup);
        let long = seqpar_perf(&cfg, 16384, 128, 4, Variant::DualPath, 8, MaskKind::None);
        assert!(long.speedup > 2.0, "long sequences must win big: {}", long.speedup);
        assert!(long.speedup < 4.0, "speedup is bounded by the shard count");
        // Unmasked even chunks are identical work: the span is exactly
        // the per-chunk cost.
        assert_eq!(long.chunk_cycles_max * long.live_chunks as u64, long.chunk_cycles_total);
        // Causal chunks are imbalanced: chunk 0 owns the most
        // below-diagonal tiles and sets the span.
        let causal = seqpar_perf(&cfg, 16384, 128, 4, Variant::DualPath, 8, MaskKind::Causal);
        assert!(
            causal.chunk_cycles_max as f64
                > 1.5 * causal.chunk_cycles_total as f64 / causal.live_chunks as f64,
            "causal even split must be imbalanced"
        );
        // Degeneration: one shard is the legacy model, no overhead.
        let one = seqpar_perf(&cfg, 4096, 128, 1, Variant::DualPath, 8, MaskKind::None);
        let legacy = fsa_flash_perf(&cfg, 4096, 128, Variant::DualPath, 8);
        assert_eq!(one.critical_path_cycles, legacy.total_cycles);
        assert_eq!((one.merge_cycles, one.comm_cycles, one.live_chunks), (0, 0, 1));
        assert_eq!(one.speedup, 1.0);
    }

    #[test]
    fn seqpar_pool_degenerates_to_multi_head_and_beats_the_kv_ceiling() {
        let cfg = fsa();
        let (l, d) = (8192usize, 128usize);
        // seq_shards = 1 reproduces the head-sharded model exactly.
        let mh = multi_head_perf(&cfg, l, d, 8, 2, 8, Variant::DualPath, 8);
        let sp1 = seqpar_pool_perf(
            &cfg, l, d, 8, 2, 8, 1, Variant::DualPath, 8, MaskKind::None,
        );
        assert_eq!(sp1.critical_path_cycles, mh.critical_path_cycles);
        assert_eq!((sp1.devices_used, sp1.rounds), (mh.devices_used, mh.rounds));
        assert_eq!(sp1.utilization, mh.utilization);
        // 4-way sequence sharding lifts the num_kv_heads device ceiling:
        // the same 8q/2kv operator now scatters into 8 (kv_head, chunk)
        // groups and actually uses all 8 devices.
        let sp4 = seqpar_pool_perf(
            &cfg, l, d, 8, 2, 8, 4, Variant::DualPath, 8, MaskKind::None,
        );
        assert_eq!(sp4.devices_used, 8);
        assert!(
            sp4.critical_path_cycles < mh.critical_path_cycles / 2,
            "sequence sharding must beat the KV-affinity latency ceiling: {} vs {}",
            sp4.critical_path_cycles,
            mh.critical_path_cycles
        );
        // Cost is conserved up to merge/communication overhead.
        assert!(sp4.total_cycles >= mh.total_cycles);
        assert!(sp4.utilization > 0.0 && sp4.utilization < 1.0);
    }

    /// Acceptance: measured sim cycles track the modeled tile-cycles
    /// within the pinned band on at least 3 shapes — the §8
    /// cross-validation that keeps the analytic model from silently
    /// drifting away from the machine it describes.
    #[test]
    fn modeled_cycles_match_measured_sim_cycles_within_band() {
        // A shrunken FSA (32-array) so the cycle-accurate runs stay in
        // the millisecond range; bandwidth/clock are the paper's.
        let mut cfg = fsa();
        cfg.array_size = 32;
        let shapes = [
            (64usize, MaskKind::None),
            (96, MaskKind::Causal),
            (64, MaskKind::PaddingKeys { valid: 40 }),
            (128, MaskKind::None),
        ];
        for &(l, mask) in &shapes {
            let c = sim_cross_check(&cfg, l, mask, 8).unwrap();
            assert!(
                c.within_band(),
                "L={l} {mask:?}: measured {} vs modeled {} (ratio {:.3}) outside {:?}",
                c.measured,
                c.modeled,
                c.ratio,
                SIM_MODEL_BAND
            );
        }
        // The masked model prices fewer tiles, and the machine takes
        // correspondingly fewer cycles: both sides must agree that
        // causal ≈ halves the square cost at the same L.
        let square = sim_cross_check(&cfg, 128, MaskKind::None, 8).unwrap();
        let causal = sim_cross_check(&cfg, 128, MaskKind::Causal, 8).unwrap();
        let measured_ratio = causal.measured as f64 / square.measured as f64;
        assert!(
            measured_ratio > 0.45 && measured_ratio < 0.75,
            "measured causal/square = {measured_ratio}"
        );
    }

    #[test]
    fn tflops_consistent_with_utilization() {
        let cfg = fsa();
        let p = fsa_flash_perf(&cfg, 8192, 128, Variant::DualPath, 8);
        let t = achieved_tflops(8192, 128, &p);
        assert!((t / cfg.peak_tflops() - p.utilization).abs() < 1e-9);
    }
}
