//! Instruction-level FSA performance model for full workloads.
//!
//! The cycle-accurate simulator ([`crate::sim`]) validates that compute
//! instructions are fully deterministic with the §3.5 latencies; this
//! model replays those latencies plus the DMA bandwidth model over whole
//! FlashAttention workloads (up to the paper's 16 K sequence length) where
//! element-wise simulation would be needless — `rust/tests` asserts both
//! agree wherever both run.
//!
//! Covers compute-bound and bandwidth-bound regimes, head dims below the
//! array size (padding waste — the §8.3 decode-phase discussion), and the
//! two dataflow variants of §8.2.

use crate::config::AccelConfig;
use crate::schedule::{attention_flops, preload_latency, rescale_latency, InnerSchedule, Variant};
use crate::sim::dma::DmaConfig;

/// Timing breakdown for one attention head on FSA.
#[derive(Clone, Copy, Debug)]
pub struct FsaPerf {
    pub total_cycles: u64,
    /// Cycles the PE array has any wave in flight.
    pub array_active_cycles: u64,
    pub dma_cycles: u64,
    /// Achieved / peak FLOPs-per-second ratio (paper §6.1 metric).
    pub utilization: f64,
    /// Wall-clock at the config's frequency.
    pub seconds: f64,
    /// True when the DMA stream, not compute, sets the iteration pace.
    pub bandwidth_bound: bool,
}

/// FlashAttention forward, one head of (seq_len, d), on an FSA machine.
///
/// Tiling follows §3.5: Br = Bc = N (the array dim); `d` is padded up to N
/// when smaller (wasted lanes counted against utilization, cf. §8.3).
pub fn fsa_flash_perf(
    cfg: &AccelConfig,
    seq_len: usize,
    d: usize,
    variant: Variant,
    segments: usize,
) -> FsaPerf {
    let n = cfg.array_size;
    assert!(d <= n, "head dim {d} exceeds array size {n}");
    let sched = InnerSchedule::new(n, variant, segments);
    let ii = sched.inner_latency();

    let t = seq_len.div_ceil(n) as u64; // row and column tiles (padded)

    // DMA traffic per inner iteration: one K tile + one V tile (Q is
    // loaded once per row block), fp16 on the wire.
    let dma = DmaConfig::for_bandwidth(cfg.mem_bw_gbs, cfg.freq_ghz, 4);
    let tile_bytes = (n * n * 2) as f64;
    let bpc = cfg.mem_bw_gbs / cfg.freq_ghz;
    let dma_per_iter = dma.setup_cycles + (2.0 * tile_bytes / bpc).ceil() as u64;

    // Double buffering: iteration pace is the slower of compute and DMA.
    let ii_eff = ii.max(dma_per_iter);
    let bandwidth_bound = dma_per_iter > ii;

    let inner = t * ii_eff;
    let outer = rescale_latency(n);
    // Q-block DMA overlaps the previous epilogue; the first fill and the
    // stationary preload are exposed once.
    let startup = preload_latency(n) + dma_per_iter + dma.setup_cycles;
    let total = t * (inner + outer) + startup;

    // Useful FLOPs pad-corrected: the array computes N-wide tiles but only
    // d lanes carry real data.
    let flops = attention_flops(seq_len, d) as f64;
    let peak_per_cycle = 2.0 * (n * n) as f64;
    let utilization = flops / (peak_per_cycle * total as f64);

    let array_active = t * t * ii + t * preload_latency(n);
    FsaPerf {
        total_cycles: total,
        array_active_cycles: array_active.min(total),
        dma_cycles: t * t * dma_per_iter,
        utilization,
        seconds: total as f64 / (cfg.freq_ghz * 1e9),
        bandwidth_bound,
    }
}

/// Achieved TFLOPs/s for a workload + perf result.
pub fn achieved_tflops(seq_len: usize, d: usize, perf: &FsaPerf) -> f64 {
    attention_flops(seq_len, d) as f64 / perf.seconds / 1e12
}

/// Whole-operator timing for a multi-head (or grouped-query) SDPA
/// sharded across a pool of FSA devices — the granularity the paper's
/// §6.1 baselines (TPUv5e, NeuronCore-v2) are measured at.
#[derive(Clone, Copy, Debug)]
pub struct MultiHeadPerf {
    /// Timing of one head on one array (all heads are identical work).
    pub head: FsaPerf,
    pub num_heads: usize,
    pub num_kv_heads: usize,
    /// Configured pool size.
    pub devices: usize,
    /// Devices one request can actually occupy: KV-head affinity pins a
    /// whole KV group to one device, so `min(devices, num_kv_heads)`.
    pub devices_used: usize,
    /// Query heads the busiest device serves:
    /// `(num_heads / num_kv_heads) * ceil(num_kv_heads / devices)`.
    pub rounds: usize,
    /// Device cycles *consumed* across the pool (cost):
    /// `num_heads * head.total_cycles`.
    pub total_cycles: u64,
    /// Whole-operator latency in cycles (the busiest device):
    /// `rounds * head.total_cycles`.
    pub critical_path_cycles: u64,
    /// Whole-operator achieved/peak FLOPs/s over the `devices_used`
    /// devices for the critical-path duration — the same quantity
    /// [`pool_utilization`] computes from the coordinator's gathered
    /// measurements, comparable to Fig. 11 / Table 2, and degraded by
    /// ragged KV-group/device splits exactly as the real router is.
    pub utilization: f64,
    /// Critical path at the config clock.
    pub seconds: f64,
}

/// Compose [`fsa_flash_perf`] per-head timing into a whole multi-head
/// operator scheduled the way the coordinator's router actually places
/// it: shards are scattered least-loaded *per KV group* (GQA heads
/// sharing a KV head stay on one device so K/V tiles are fetched once
/// per device — the win is real when bandwidth-bound), which caps one
/// request's parallelism at `num_kv_heads` devices.  A pool larger
/// than `num_kv_heads` does not shorten a single operator's critical
/// path; it adds capacity for *concurrent* requests instead.
///
/// `num_kv_heads` does not change FLOPs — every query head runs full
/// `4 L² d` attention.
pub fn multi_head_perf(
    cfg: &AccelConfig,
    seq_len: usize,
    d: usize,
    num_heads: usize,
    num_kv_heads: usize,
    devices: usize,
    variant: Variant,
    segments: usize,
) -> MultiHeadPerf {
    assert!(num_heads >= 1 && num_kv_heads >= 1 && devices >= 1);
    assert_eq!(num_heads % num_kv_heads, 0, "GQA head counts must divide");
    let head = fsa_flash_perf(cfg, seq_len, d, variant, segments);
    let group_size = num_heads / num_kv_heads;
    let devices_used = devices.min(num_kv_heads);
    let rounds = group_size * num_kv_heads.div_ceil(devices);
    let total_cycles = num_heads as u64 * head.total_cycles;
    let critical_path_cycles = rounds as u64 * head.total_cycles;
    let flops = num_heads as u64 * attention_flops(seq_len, d);
    let peak_per_cycle = 2.0 * (cfg.array_size * cfg.array_size) as f64 * devices_used as f64;
    MultiHeadPerf {
        head,
        num_heads,
        num_kv_heads,
        devices,
        devices_used,
        rounds,
        total_cycles,
        critical_path_cycles,
        utilization: flops as f64 / (peak_per_cycle * critical_path_cycles as f64),
        seconds: critical_path_cycles as f64 / (cfg.freq_ghz * 1e9),
    }
}

/// Whole-operator FLOPs/s utilization from *observed* per-device cycle
/// totals (what the coordinator's gather measures): achieved FLOPs over
/// the pool's peak for the critical-path duration.  Returns 0 when no
/// cycles were recorded.
pub fn pool_utilization(cfg: &AccelConfig, total_flops: u64, per_device_cycles: &[u64]) -> f64 {
    let critical = per_device_cycles.iter().copied().max().unwrap_or(0);
    if critical == 0 || per_device_cycles.is_empty() {
        return 0.0;
    }
    let peak_per_cycle =
        2.0 * (cfg.array_size * cfg.array_size) as f64 * per_device_cycles.len() as f64;
    total_flops as f64 / (peak_per_cycle * critical as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fsa() -> AccelConfig {
        AccelConfig::builtin("fsa").unwrap()
    }

    #[test]
    fn compute_bound_at_paper_config() {
        // 820 GB/s @1.5 GHz: 64 KiB per iteration in ~136 cycles, far
        // under the 650-cycle iteration — compute-bound, as §6.1 assumes.
        let p = fsa_flash_perf(&fsa(), 2048, 128, Variant::DualPath, 8);
        assert!(!p.bandwidth_bound);
        assert!(p.utilization > 0.3 && p.utilization < 0.4, "{}", p.utilization);
    }

    #[test]
    fn utilization_rises_with_seq_len_to_asymptote() {
        let us: Vec<f64> = [2048usize, 4096, 8192, 16384]
            .iter()
            .map(|&l| fsa_flash_perf(&fsa(), l, 128, Variant::DualPath, 8).utilization)
            .collect();
        assert!(us.windows(2).all(|w| w[1] >= w[0]), "{us:?}");
        let ceiling = 2.0 * 128.0 / (5.0 * 128.0 + 10.0);
        assert!(us[3] < ceiling && us[3] > ceiling - 0.02, "{us:?}");
    }

    #[test]
    fn single_path_variant_is_slower_but_close() {
        // §8.2: 6N+10 vs 5N+10 — about 17% more cycles at N=128.
        let dual = fsa_flash_perf(&fsa(), 8192, 128, Variant::DualPath, 8);
        let single = fsa_flash_perf(&fsa(), 8192, 128, Variant::SinglePath, 8);
        let ratio = single.total_cycles as f64 / dual.total_cycles as f64;
        assert!(ratio > 1.1 && ratio < 1.25, "ratio {ratio}");
    }

    #[test]
    fn small_head_dim_wastes_lanes() {
        // §8.3: padding to 128-wide tiles burns utilization.
        let full = fsa_flash_perf(&fsa(), 4096, 128, Variant::DualPath, 8);
        let half = fsa_flash_perf(&fsa(), 4096, 64, Variant::DualPath, 8);
        assert!((half.utilization - full.utilization / 2.0).abs() < 0.02);
    }

    #[test]
    fn bandwidth_bound_when_starved() {
        let mut cfg = fsa();
        cfg.mem_bw_gbs = 40.0; // starve the DMA
        let p = fsa_flash_perf(&cfg, 4096, 128, Variant::DualPath, 8);
        assert!(p.bandwidth_bound);
        assert!(p.utilization < 0.3);
    }

    #[test]
    fn multi_head_scales_and_respects_affinity_and_ragged_tails() {
        let cfg = fsa();
        let one = fsa_flash_perf(&cfg, 4096, 128, Variant::DualPath, 8);
        // 8 MHA heads on 1 device: 8x the cycles, same utilization.
        let mh = multi_head_perf(&cfg, 4096, 128, 8, 8, 1, Variant::DualPath, 8);
        assert_eq!((mh.devices_used, mh.rounds), (1, 8));
        assert_eq!(mh.critical_path_cycles, 8 * one.total_cycles);
        assert!((mh.utilization - one.utilization).abs() < 1e-12);
        // 8 MHA heads on 4 devices: 2 rounds, same pool utilization,
        // 4x faster wall clock.
        let mh4 = multi_head_perf(&cfg, 4096, 128, 8, 8, 4, Variant::DualPath, 8);
        assert_eq!((mh4.devices_used, mh4.rounds), (4, 2));
        assert_eq!(mh4.total_cycles, mh.total_cycles);
        assert!((mh4.seconds - mh.seconds / 4.0).abs() < 1e-12);
        assert!((mh4.utilization - one.utilization).abs() < 1e-12);
        // GQA 8q/2kv on 4 devices: KV affinity caps the request at 2
        // devices, so the busiest device runs a whole 4-head group —
        // a pool bigger than num_kv_heads doesn't cut this latency.
        let gqa = multi_head_perf(&cfg, 4096, 128, 8, 2, 4, Variant::DualPath, 8);
        assert_eq!((gqa.devices_used, gqa.rounds), (2, 4));
        assert_eq!(gqa.critical_path_cycles, 4 * one.total_cycles);
        assert!((gqa.utilization - one.utilization).abs() < 1e-12);
        // Ragged: 8 MHA heads on 3 devices -> 3 rounds, tail 2/3 idle.
        let mh3 = multi_head_perf(&cfg, 4096, 128, 8, 8, 3, Variant::DualPath, 8);
        assert_eq!((mh3.devices_used, mh3.rounds), (3, 3));
        let expect = one.utilization * 8.0 / 9.0;
        assert!((mh3.utilization - expect).abs() < 1e-12, "{} vs {expect}", mh3.utilization);
        // Ragged KV groups: 8q/4kv on 3 devices -> busiest device gets
        // 2 groups of 2 heads = 4 rounds over 3 devices.
        let gqa3 = multi_head_perf(&cfg, 4096, 128, 8, 4, 3, Variant::DualPath, 8);
        assert_eq!((gqa3.devices_used, gqa3.rounds), (3, 4));
        let expect3 = one.utilization * 8.0 / 12.0;
        assert!((gqa3.utilization - expect3).abs() < 1e-12);
    }

    #[test]
    fn pool_utilization_from_observed_cycles() {
        let cfg = fsa();
        let one = fsa_flash_perf(&cfg, 4096, 128, Variant::DualPath, 8);
        let flops = 8 * crate::schedule::attention_flops(4096, 128);
        // Perfectly balanced 8 heads over 4 devices matches the model.
        let per_dev = vec![2 * one.total_cycles; 4];
        let u = pool_utilization(&cfg, flops, &per_dev);
        let model = multi_head_perf(&cfg, 4096, 128, 8, 8, 4, Variant::DualPath, 8);
        assert!((u - model.utilization).abs() < 1e-12);
        // Degenerate inputs.
        assert_eq!(pool_utilization(&cfg, flops, &[]), 0.0);
        assert_eq!(pool_utilization(&cfg, flops, &[0]), 0.0);
    }

    #[test]
    fn tflops_consistent_with_utilization() {
        let cfg = fsa();
        let p = fsa_flash_perf(&cfg, 8192, 128, Variant::DualPath, 8);
        let t = achieved_tflops(8192, 128, &p);
        assert!((t / cfg.peak_tflops() - p.utilization).abs() < 1e-9);
    }
}
