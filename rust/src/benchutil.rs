//! Benchmark mini-harness (criterion is unavailable offline): warmup,
//! fixed-iteration timing, median/p95 statistics, and aligned table
//! printing shared by every `benches/*.rs` target (all declared with
//! `harness = false`).

use std::time::{Duration, Instant};

/// Timing statistics over the measured iterations.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    stats_from(samples)
}

/// Nearest-rank selection on an ascending-sorted slice: percentile `p`
/// of `n` samples is the `ceil(p·n)`-th smallest (rank clamped into
/// range, so `p = 0` returns the minimum and `p = 1` the maximum).
/// The single implementation shared by [`Stats`] and the serving-side
/// `Metrics::latency_percentiles`, so bench and serving metrics report
/// the same statistic.
pub fn nearest_rank<T: Copy>(sorted: &[T], p: f64) -> T {
    assert!(!sorted.is_empty(), "need at least one sample");
    sorted[((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1]
}

/// Reduce raw samples to [`Stats`] with [`nearest_rank`] percentile
/// selection.  (The old floor-rank indexing (`samples[iters / 2]`,
/// `samples[iters * 95 / 100]`) was off by one position on exact-rank
/// sample counts.)
fn stats_from(mut samples: Vec<Duration>) -> Stats {
    samples.sort();
    let n = samples.len();
    let sum: Duration = samples.iter().sum();
    Stats {
        iters: n,
        mean: sum / n as u32,
        median: nearest_rank(&samples, 0.5),
        p95: nearest_rank(&samples, 0.95),
        min: samples[0],
    }
}

/// Adaptive variant: picks an iteration count so total time ~ `budget`.
///
/// Under `make bench-smoke` ([`smoke`]) the budget is capped at 20 ms
/// so every bench target — all of them time through this function —
/// runs its full code path at minimal iterations.
pub fn bench_for<F: FnMut()>(budget: Duration, mut f: F) -> Stats {
    let budget = if smoke() { budget.min(Duration::from_millis(20)) } else { budget };
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_nanos() / once.as_nanos()).clamp(3, 10_000) as usize;
    bench(1, iters, f)
}

/// Black-box to defeat optimizer dead-code elimination (std::hint wrapper).
#[inline]
pub fn observe<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// True when the bench runs under `make bench-smoke` (`FSA_BENCH_SMOKE`
/// set): targets shrink their sweeps/budgets to a quick exit-0 sanity
/// pass so CI can exercise every bench without paying full runtimes.
pub fn smoke() -> bool {
    std::env::var_os("FSA_BENCH_SMOKE").is_some()
}

/// Simple fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

/// Format a duration in human units.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let mut acc = 0u64;
        let st = bench(2, 16, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(observe(i));
            }
        });
        assert_eq!(st.iters, 16);
        assert!(st.min <= st.median && st.median <= st.p95);
        assert!(st.mean.as_nanos() > 0);
    }

    /// Satellite: nearest-rank selection pinned on fixed vectors, the
    /// same style as the Metrics::latency_percentiles regression tests.
    #[test]
    fn nearest_rank_on_fixed_sample_vectors() {
        let ms = |v: &[u64]| v.iter().map(|&x| Duration::from_millis(x)).collect::<Vec<_>>();
        // 20 samples 1..=20: p50 is the 10th smallest, p95 the 19th.
        let st = stats_from(ms(&(1..=20).collect::<Vec<_>>()));
        assert_eq!(st.median, Duration::from_millis(10));
        assert_eq!(st.p95, Duration::from_millis(19));
        assert_eq!(st.min, Duration::from_millis(1));
        // 10 samples: the old floor rank picked the 6th for the median
        // and nearest rank picks the 5th; p95 is the 10th either way.
        let st = stats_from(ms(&(1..=10).collect::<Vec<_>>()));
        assert_eq!(st.median, Duration::from_millis(5));
        assert_eq!(st.p95, Duration::from_millis(10));
        // Single sample: every statistic is that sample.
        let st = stats_from(ms(&[7]));
        assert_eq!((st.median, st.p95, st.min), (
            Duration::from_millis(7),
            Duration::from_millis(7),
            Duration::from_millis(7),
        ));
        // Unsorted input is sorted before selection.
        let st = stats_from(ms(&[9, 1, 5]));
        assert_eq!(st.median, Duration::from_millis(5));
        assert_eq!(st.iters, 3);
    }

    #[test]
    fn adaptive_bench_respects_budget_order() {
        let st = bench_for(Duration::from_millis(5), || {
            observe((0..100).sum::<u64>());
        });
        assert!(st.iters >= 3);
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["seq", "util"]);
        t.row(&["2048".into(), "0.39".into()]);
        t.row(&["16384".into(), "0.40".into()]);
        let s = t.to_string();
        assert!(s.contains("seq"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(12)), "12 ns");
        assert!(fmt_duration(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).contains("s"));
    }
}
