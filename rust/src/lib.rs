//! # fsa — SystolicAttention / FSA reproduction
//!
//! A three-layer reproduction of *"SystolicAttention: Fusing FlashAttention
//! within a Single Systolic Array"* (Lin et al., EPFL, 2025).
//!
//! This crate is layer 3: the FSA **device** (a cycle-accurate simulator of
//! the enhanced systolic array, its ISA, controller and DMA), the
//! **SystolicAttention** static schedule, instruction-level **performance
//! models** of FSA and of the commercial baselines (TPUv5e-like,
//! NeuronCore-v2-like), the **kernel programming model** of paper §5
//! (typed tiles + JIT builder), a **runtime** that executes the
//! JAX/Pallas AOT artifacts via PJRT (with an in-crate reference
//! fallback), and a serving **coordinator** (router, continuous
//! queue + scheduler, device pool) that puts it all on a request path — full multi-head / GQA
//! operators, sharded per head across the pool, plus decode-phase
//! serving: a prefill→decode→close session lifecycle over per-device
//! paged KV caches — with Python nowhere in sight.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`numerics`] — software fp16, PWL exp2 (the Split-unit contract), RNG.
//! * [`mask`] — attention mask kinds (causal / key padding) shared by
//!   numerics, schedule, perfmodel and the serving path (DESIGN.md §6).
//! * [`isa`] — the 8-instruction FSA ISA (incl. the §8 `MaskBound`
//!   boundary register) with binary encode/decode.
//! * [`schedule`] — SystolicAttention wavefront schedules + latency formulas.
//! * [`sim`] — cycle-accurate array/accumulator/SRAM/DMA/controller model.
//! * [`perfmodel`] — deterministic instruction-level timing for full
//!   workloads, composed per head into whole-operator pool metrics.
//! * [`accel`] — Table-1 accelerator configs + baseline pipeline models.
//! * [`area`] — Table-3 area model.
//! * [`kernel`] — §5 programming model: MTile/STile/ATile + KernelBuilder.
//! * [`runtime`] — artifact loading + the per-head execution
//!   [`runtime::Backend`] (PJRT HLO-text path, the reference twin, or
//!   the cycle-accurate sim backend with measured-cycle pricing, §8).
//! * [`coordinator`] — multi-head request path: head sharding/gather,
//!   affinity router, continuous queue + scheduler (token-budget
//!   admission, DESIGN.md §10), device workers, metrics; session
//!   lifecycle + paged KV caches for decode-phase serving.
//! * [`telemetry`] — log-scale histograms + hand-rolled JSON shared by
//!   serving metrics and the bench harness (DESIGN.md §9).
//! * [`config`] — INI-style config system for machines and runs.
//! * [`cli`], [`benchutil`], [`testutil`] — offline-environment stand-ins
//!   for clap / criterion / proptest (see DESIGN.md §substitutions).

pub mod accel;
pub mod area;
pub mod benchutil;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod isa;
pub mod kernel;
pub mod mask;
pub mod numerics;
pub mod perfmodel;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod telemetry;
pub mod testutil;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
pub mod experiments;
